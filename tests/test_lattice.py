"""Lattice geometry, SU(3) fields, packing bijections."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (LatticeShape, field_dot, field_norm2, merge_eo,
                        merge_eo_gauge, pack_gauge, pack_spinor, parity_masks,
                        random_gauge, random_spinor, split_eo, split_eo_gauge,
                        unit_gauge, unpack_gauge, unpack_spinor)
from repro.testing import maybe_hypothesis

given, settings, st = maybe_hypothesis()

LAT = LatticeShape(4, 4, 4, 8)


def test_su3_unitarity_and_det(rng):
    u = random_gauge(rng, LAT)
    uu = jnp.einsum("dtzyxab,dtzyxcb->dtzyxac", u, jnp.conj(u))
    eye = jnp.eye(3, dtype=u.dtype)
    assert jnp.max(jnp.abs(uu - eye)) < 5e-6
    det = jnp.linalg.det(u)
    assert jnp.max(jnp.abs(det - 1.0)) < 5e-6


def test_unit_gauge_is_identity():
    u = unit_gauge(LAT)
    assert u.shape == (4, 4, 4, 4, 8, 3, 3)
    assert jnp.allclose(u[0, 0, 0, 0, 0], jnp.eye(3, dtype=u.dtype))


def test_pack_unpack_spinor_roundtrip(rng):
    psi = random_spinor(rng, LAT)
    assert jnp.allclose(unpack_spinor(pack_spinor(psi)), psi, atol=1e-6)


def test_pack_unpack_gauge_roundtrip(rng):
    u = random_gauge(rng, LAT)
    assert jnp.allclose(unpack_gauge(pack_gauge(u)), u, atol=1e-6)


def test_packed_layout_axes(rng):
    psi = random_spinor(rng, LAT)
    p = pack_spinor(psi)
    assert p.shape == (4, 4, 4, 24, 8)  # (T, Z, Y, S, X) — X innermost
    # component (spin=1, color=2, im) of site (t,z,y,x)
    s_idx = (1 * 3 + 2) * 2 + 1
    assert np.isclose(float(p[2, 1, 3, s_idx, 5]),
                      float(jnp.imag(psi[2, 1, 3, 5, 1, 2])), atol=1e-6)


def test_split_merge_eo_roundtrip(rng):
    psi = random_spinor(rng, LAT)
    e, o = split_eo(psi)
    assert e.shape == (4, 4, 4, 4, 4, 3) and o.shape == e.shape
    assert jnp.array_equal(merge_eo(e, o), psi)  # exact bijection


def test_split_eo_gauge_roundtrip(rng):
    u = random_gauge(rng, LAT)
    ue, uo = split_eo_gauge(u)
    assert ue.shape == (4, 4, 4, 4, 4, 3, 3)
    assert jnp.array_equal(merge_eo_gauge(ue, uo), u)


def test_split_eo_site_addressing(rng):
    """Even field index (t,z,y,j) addresses site x = 2j + (t+z+y)%2."""
    psi = random_spinor(rng, LAT)
    e, o = split_eo(psi)
    full = np.asarray(psi)
    for (t, z, y, j) in [(0, 0, 0, 1), (1, 0, 0, 2), (2, 3, 1, 0),
                         (3, 3, 3, 3)]:
        s = (t + z + y) % 2
        assert np.array_equal(np.asarray(e)[t, z, y, j], full[t, z, y, 2 * j + s])
        assert np.array_equal(np.asarray(o)[t, z, y, j],
                              full[t, z, y, 2 * j + 1 - s])


def test_parity_masks_partition():
    even, odd = parity_masks(LAT)
    assert even.shape == LAT.dims
    assert int(even.sum()) == LAT.volume // 2
    assert not np.any(even & odd) and np.all(even | odd)
    # parity really is (t+z+y+x) % 2
    assert bool(even[0, 0, 0, 0]) and not bool(even[0, 0, 0, 1])
    assert not bool(even[1, 0, 0, 0]) and bool(even[1, 1, 0, 0])


def test_split_eo_requires_even_x(rng):
    psi = random_spinor(rng, LatticeShape(2, 2, 2, 3))
    with pytest.raises(AssertionError):
        split_eo(psi)


def test_dot_matches_norm(rng):
    psi = random_spinor(rng, LAT)
    assert np.isclose(float(jnp.real(field_dot(psi, psi))),
                      float(field_norm2(psi)), rtol=1e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.floats(-3, 3), st.floats(-3, 3))
def test_field_dot_sesquilinear(seed, a_re, a_im):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    lat = LatticeShape(2, 2, 2, 4)
    x = random_spinor(k1, lat)
    y = random_spinor(k2, lat)
    alpha = jnp.complex64(a_re + 1j * a_im)
    lhs = field_dot(x, alpha * y)
    rhs = alpha * field_dot(x, y)
    assert np.isclose(complex(lhs), complex(rhs), rtol=2e-4, atol=1e-3)
    # conjugate symmetry
    assert np.isclose(complex(field_dot(x, y)),
                      np.conj(complex(field_dot(y, x))), rtol=2e-4,
                      atol=1e-3)
