"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (LatticeShape, pack_gauge, pack_spinor, random_gauge,
                        random_spinor)
from repro.kernels.cg_fused import (cg_pallas, cg_update, cg_update_ref,
                                    cg_xpay)
from repro.kernels.wilson_dslash import dslash as dslash_k
from repro.kernels.wilson_dslash import dslash_ref
from repro.kernels.wilson_dslash.ops import normal_op as normal_k
from repro.core.wilson import dslash_dagger_packed
from repro.testing import maybe_hypothesis

given, settings, st = maybe_hypothesis()

SHAPES = [LatticeShape(2, 2, 4, 8), LatticeShape(4, 4, 4, 8),
          LatticeShape(3, 6, 8, 16), LatticeShape(2, 8, 8, 8)]


@pytest.fixture(scope="module")
def fields():
    key = jax.random.PRNGKey(11)
    out = {}
    for lat in SHAPES:
        ku, kp = jax.random.split(jax.random.fold_in(key, lat.volume))
        out[lat.dims] = (pack_gauge(random_gauge(ku, lat)),
                         pack_spinor(random_spinor(kp, lat)))
    return out


@pytest.mark.parametrize("lat", SHAPES, ids=str)
@pytest.mark.parametrize("mass", [0.0, 0.25])
def test_dslash_kernel_shape_sweep(fields, lat, mass):
    up, pp = fields[lat.dims]
    ref = dslash_ref(up, pp, mass)
    out = dslash_k(up, pp, mass)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=1e-4)


@pytest.mark.parametrize("bz", [1, 2, 4])
def test_dslash_kernel_block_sizes(fields, bz):
    lat = SHAPES[1]
    up, pp = fields[lat.dims]
    ref = dslash_ref(up, pp, 0.1)
    out = dslash_k(up, pp, 0.1, bz=bz)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dslash_kernel_dtype_sweep(fields, dtype):
    lat = SHAPES[0]
    up, pp = fields[lat.dims]
    upd, ppd = up.astype(dtype), pp.astype(dtype)
    ref32 = dslash_ref(up, pp, 0.1)
    out = dslash_k(upd, ppd, 0.1).astype(jnp.float32)
    tol = 2e-5 if dtype == jnp.float32 else 0.15
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref32),
                               atol=tol, rtol=tol)


def test_dslash_kernel_dagger_hermiticity(fields):
    lat = SHAPES[1]
    up, pp = fields[lat.dims]
    key = jax.random.PRNGKey(3)
    qq = pack_spinor(random_spinor(key, lat))
    from repro.kernels.wilson_dslash.ops import dslash_dagger as dag_k
    lhs = float(jnp.sum(qq * dslash_k(up, pp, 0.1)))
    rhs = float(jnp.sum(dag_k(up, qq, 0.1) * pp))
    assert np.isclose(lhs, rhs, rtol=1e-4)


@pytest.mark.parametrize("shape", [(128, 128), (3, 5, 7, 24, 8), (1000,),
                                   (256, 24, 8)])
def test_cg_update_shapes(shape):
    key = jax.random.PRNGKey(5)
    ks = jax.random.split(key, 4)
    x, r, p, ap = (jax.random.normal(k, shape, jnp.float32) for k in ks)
    alpha = jnp.float32(0.37)
    xo, ro, rs = cg_update(alpha, x, r, p, ap)
    xr, rr, rsr = cg_update_ref(alpha, x, r, p, ap)
    np.testing.assert_allclose(np.asarray(xo), np.asarray(xr), atol=1e-6)
    np.testing.assert_allclose(np.asarray(ro), np.asarray(rr), atol=1e-6)
    assert np.isclose(float(rs), float(rsr), rtol=1e-5)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.floats(-2, 2), st.floats(-2, 2))
def test_cg_fused_property(seed, alpha, beta):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    shape = (37, 11)  # deliberately not lane-aligned: exercises padding
    x, r, p, ap = (jax.random.normal(k, shape, jnp.float32) for k in ks)
    xo, ro, rs = cg_update(jnp.float32(alpha), x, r, p, ap)
    assert np.allclose(np.asarray(xo), np.asarray(x + alpha * p), atol=1e-5)
    assert np.allclose(np.asarray(ro), np.asarray(r - alpha * ap), atol=1e-5)
    assert np.isclose(float(rs), float(jnp.sum(ro * ro)), rtol=1e-4)
    po = cg_xpay(jnp.float32(beta), r, p)
    assert np.allclose(np.asarray(po), np.asarray(r + beta * p), atol=1e-5)


def test_cg_pallas_end_to_end(fields):
    """Full CG through both Pallas kernels solves the Wilson system."""
    lat = SHAPES[1]
    up, pp = fields[lat.dims]
    m = 0.4
    b = dslash_dagger_packed(up, pp, m)
    x, (k, rs) = cg_pallas(lambda v: normal_k(up, v, m), b, tol=1e-6,
                           maxiter=300)
    res = dslash_k(up, x, m) - pp
    rel = float(jnp.linalg.norm(res.ravel()) / jnp.linalg.norm(pp.ravel()))
    assert rel < 1e-5
