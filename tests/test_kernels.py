"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (LatticeShape, complex_to_real_pair, pack_gauge,
                        pack_spinor, random_gauge, random_spinor,
                        real_pair_to_complex, split_eo, split_eo_gauge)
from repro.kernels.cg_fused import (cg_pallas, cg_update, cg_update_ref,
                                    cg_xpay, cg_xpay_ref)
from repro.kernels.wilson_dslash import dslash as dslash_k
from repro.kernels.wilson_dslash import (dslash_eo_ref, dslash_oe_ref,
                                         dslash_ref, schur_normal_op_ref,
                                         schur_op_ref)
from repro.kernels.wilson_dslash.ops import dslash_eo as eo_k
from repro.kernels.wilson_dslash.ops import dslash_oe as oe_k
from repro.kernels.wilson_dslash.ops import normal_op as normal_k
from repro.kernels.wilson_dslash.ops import schur_normal_op as schur_nk
from repro.kernels.wilson_dslash.ops import schur_op as schur_k
from repro.core.wilson import dslash_dagger_packed
from repro.testing import full_field_passes, maybe_hypothesis, pallas_call_eqns

given, settings, st = maybe_hypothesis()

SHAPES = [LatticeShape(2, 2, 4, 8), LatticeShape(4, 4, 4, 8),
          LatticeShape(3, 6, 8, 16), LatticeShape(2, 8, 8, 8)]

# the acceptance lattices for the parity kernels: 4^4 and 8*4^3
EO_SHAPES = [LatticeShape(4, 4, 4, 4), LatticeShape(8, 4, 4, 4)]
EO_MASS = 0.1


@pytest.fixture(scope="module")
def fields():
    key = jax.random.PRNGKey(11)
    out = {}
    for lat in SHAPES:
        ku, kp = jax.random.split(jax.random.fold_in(key, lat.volume))
        out[lat.dims] = (pack_gauge(random_gauge(ku, lat)),
                         pack_spinor(random_spinor(kp, lat)))
    return out


@pytest.mark.parametrize("lat", SHAPES, ids=str)
@pytest.mark.parametrize("mass", [0.0, 0.25])
def test_dslash_kernel_shape_sweep(fields, lat, mass):
    up, pp = fields[lat.dims]
    ref = dslash_ref(up, pp, mass)
    out = dslash_k(up, pp, mass)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=1e-4)


@pytest.mark.parametrize("bz", [1, 2, 4])
def test_dslash_kernel_block_sizes(fields, bz):
    lat = SHAPES[1]
    up, pp = fields[lat.dims]
    ref = dslash_ref(up, pp, 0.1)
    out = dslash_k(up, pp, 0.1, bz=bz)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dslash_kernel_dtype_sweep(fields, dtype):
    lat = SHAPES[0]
    up, pp = fields[lat.dims]
    upd, ppd = up.astype(dtype), pp.astype(dtype)
    ref32 = dslash_ref(up, pp, 0.1)
    out = dslash_k(upd, ppd, 0.1).astype(jnp.float32)
    tol = 2e-5 if dtype == jnp.float32 else 0.15
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref32),
                               atol=tol, rtol=tol)


def test_dslash_kernel_dagger_hermiticity(fields):
    lat = SHAPES[1]
    up, pp = fields[lat.dims]
    key = jax.random.PRNGKey(3)
    qq = pack_spinor(random_spinor(key, lat))
    from repro.kernels.wilson_dslash.ops import dslash_dagger as dag_k
    lhs = float(jnp.sum(qq * dslash_k(up, pp, 0.1)))
    rhs = float(jnp.sum(dag_k(up, qq, 0.1) * pp))
    assert np.isclose(lhs, rhs, rtol=1e-4)


# ---------------------------------------------------------------------------
# Parity (even-odd) kernels vs the core/wilson.py references
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def eo_fields():
    """Packed per-parity fields + packed halves of a random spinor."""
    key = jax.random.PRNGKey(23)
    out = {}
    for lat in EO_SHAPES:
        ku, kp = jax.random.split(jax.random.fold_in(key, lat.volume))
        u = random_gauge(ku, lat)
        psi = random_spinor(kp, lat)
        u_e, u_o = split_eo_gauge(u)
        p_e, p_o = split_eo(psi)
        out[lat.dims] = (pack_gauge(u_e), pack_gauge(u_o),
                         pack_spinor(p_e), pack_spinor(p_o))
    return out


@pytest.mark.parametrize("lat", EO_SHAPES, ids=str)
def test_parity_kernels_match_core(eo_fields, lat):
    """D_eo / D_oe Pallas kernels match the core oracles to <= 1e-5."""
    upe, upo, ppe, ppo = eo_fields[lat.dims]
    np.testing.assert_allclose(np.asarray(eo_k(upe, upo, ppo)),
                               np.asarray(dslash_eo_ref(upe, upo, ppo)),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(oe_k(upe, upo, ppe)),
                               np.asarray(dslash_oe_ref(upe, upo, ppe)),
                               atol=1e-5)


@pytest.mark.parametrize("lat", EO_SHAPES, ids=str)
@pytest.mark.parametrize("dagger", [False, True], ids=["plain", "dagger"])
def test_schur_kernel_matches_core(eo_fields, lat, dagger):
    """The 2-launch Schur kernel (γ5 + axpy folded) matches the oracle,
    including the γ5-folded dagger path."""
    upe, upo, ppe, _ = eo_fields[lat.dims]
    out = schur_k(upe, upo, ppe, EO_MASS, dagger=dagger)
    ref = schur_op_ref(upe, upo, ppe, EO_MASS, dagger=dagger)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_schur_normal_op_matches_core(eo_fields):
    lat = EO_SHAPES[0]
    upe, upo, ppe, _ = eo_fields[lat.dims]
    out = schur_nk(upe, upo, ppe, EO_MASS)
    ref = schur_normal_op_ref(upe, upo, ppe, EO_MASS)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_parity_gamma5_flags_match_ref(eo_fields):
    """The folded gamma5_in/gamma5_out flags equal explicit γ5 wrapping."""
    lat = EO_SHAPES[0]
    upe, upo, ppe, _ = eo_fields[lat.dims]
    out = oe_k(upe, upo, ppe, gamma5_in=True, gamma5_out=True)
    ref = dslash_oe_ref(upe, upo, ppe, gamma5_in=True, gamma5_out=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_gamma5_folding_zero_extra_passes(fields, eo_fields):
    """γ5 folding means the normal operators are PURE kernel launches: no
    non-pallas equation in the jaxpr materializes a full field — i.e. zero
    standalone apply_gamma5_packed (or axpy) HBM passes."""
    lat = SHAPES[0]
    up, pp = fields[lat.dims]
    jx = jax.make_jaxpr(
        lambda u, p: normal_k(u, p, 0.1, interpret=True))(up, pp)
    assert len(pallas_call_eqns(jx)) == 2
    assert full_field_passes(jx, pp.size) == []

    upe, upo, ppe, _ = eo_fields[EO_SHAPES[0].dims]
    jx = jax.make_jaxpr(
        lambda a, b, v: schur_nk(a, b, v, EO_MASS, interpret=True))(
            upe, upo, ppe)
    assert len(pallas_call_eqns(jx)) == 4
    assert full_field_passes(jx, ppe.size) == []


@pytest.mark.parametrize("shape", [(128, 128), (3, 5, 7, 24, 8), (1000,),
                                   (256, 24, 8)])
def test_cg_update_shapes(shape):
    key = jax.random.PRNGKey(5)
    ks = jax.random.split(key, 4)
    x, r, p, ap = (jax.random.normal(k, shape, jnp.float32) for k in ks)
    alpha = jnp.float32(0.37)
    xo, ro, rs = cg_update(alpha, x, r, p, ap)
    xr, rr, rsr = cg_update_ref(alpha, x, r, p, ap)
    np.testing.assert_allclose(np.asarray(xo), np.asarray(xr), atol=1e-6)
    np.testing.assert_allclose(np.asarray(ro), np.asarray(rr), atol=1e-6)
    assert np.isclose(float(rs), float(rsr), rtol=1e-5)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.floats(-2, 2), st.floats(-2, 2))
def test_cg_fused_property(seed, alpha, beta):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    shape = (37, 11)  # deliberately not lane-aligned: exercises padding
    x, r, p, ap = (jax.random.normal(k, shape, jnp.float32) for k in ks)
    xo, ro, rs = cg_update(jnp.float32(alpha), x, r, p, ap)
    assert np.allclose(np.asarray(xo), np.asarray(x + alpha * p), atol=1e-5)
    assert np.allclose(np.asarray(ro), np.asarray(r - alpha * ap), atol=1e-5)
    assert np.isclose(float(rs), float(jnp.sum(ro * ro)), rtol=1e-4)
    po = cg_xpay(jnp.float32(beta), r, p)
    assert np.allclose(np.asarray(po), np.asarray(r + beta * p), atol=1e-5)


def test_cg_update_complex_via_real_pair_view():
    """complex64 CG state runs through the fused kernels as f32 real pairs;
    the result equals the complex arithmetic and the reduction is the
    complex ||r||^2."""
    key = jax.random.PRNGKey(17)
    shape = (5, 7, 3)
    ks = jax.random.split(key, 8)
    mk = lambda kr, ki: (jax.random.normal(kr, shape)
                         + 1j * jax.random.normal(ki, shape)
                         ).astype(jnp.complex64)
    x, r, p, ap = (mk(ks[2 * i], ks[2 * i + 1]) for i in range(4))
    alpha = jnp.float32(0.61)
    pairs = [complex_to_real_pair(v) for v in (x, r, p, ap)]
    xo, ro, rs = cg_update(alpha, *pairs)
    np.testing.assert_allclose(np.asarray(real_pair_to_complex(xo)),
                               np.asarray(x + alpha * p), atol=1e-6)
    np.testing.assert_allclose(np.asarray(real_pair_to_complex(ro)),
                               np.asarray(r - alpha * ap), atol=1e-6)
    r_new = r - alpha * ap
    assert np.isclose(float(rs),
                      float(jnp.sum(jnp.abs(r_new) ** 2)), rtol=1e-5)
    po = cg_xpay(jnp.float32(0.3), pairs[1], pairs[2])
    np.testing.assert_allclose(np.asarray(real_pair_to_complex(po)),
                               np.asarray(r + 0.3 * p), atol=1e-6)


def test_cg_update_bf16_storage():
    """bf16 storage dtype round-trips (narrow storage, f32 accumulate)."""
    key = jax.random.PRNGKey(29)
    ks = jax.random.split(key, 4)
    shape = (64, 24, 8)
    x, r, p, ap = (jax.random.normal(k, shape, jnp.float32).astype(
        jnp.bfloat16) for k in ks)
    alpha = jnp.float32(0.37)
    xo, ro, rs = cg_update(alpha, x, r, p, ap)
    assert xo.dtype == ro.dtype == jnp.bfloat16
    assert rs.dtype == jnp.float32
    xr, rr, rsr = cg_update_ref(alpha, x, r, p, ap)
    np.testing.assert_allclose(np.asarray(xo, np.float32),
                               np.asarray(xr, np.float32), atol=1e-6)
    np.testing.assert_allclose(np.asarray(ro, np.float32),
                               np.asarray(rr, np.float32), atol=1e-6)
    assert np.isclose(float(rs), float(rsr), rtol=1e-5)
    po = cg_xpay(jnp.float32(0.25), r, p)
    assert po.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(po, np.float32),
        np.asarray(cg_xpay_ref(jnp.float32(0.25), r, p), np.float32),
        atol=1e-6)


# ---------------------------------------------------------------------------
# Multi-RHS (batched) kernels: gauge-amortized stencils + batched vector engine
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def batched_eo_fields():
    """Packed per-parity gauge + an N=3 stack of packed spinor halves."""
    lat = EO_SHAPES[0]
    key = jax.random.PRNGKey(41)
    ku, kp = jax.random.split(key)
    u = random_gauge(ku, lat)
    u_e, u_o = split_eo_gauge(u)
    halves = [split_eo(random_spinor(jax.random.fold_in(kp, i), lat))
              for i in range(3)]
    ppe = jnp.stack([pack_spinor(h[0]) for h in halves])
    ppo = jnp.stack([pack_spinor(h[1]) for h in halves])
    return pack_gauge(u_e), pack_gauge(u_o), ppe, ppo


def test_batched_parity_kernels_bitwise_match_looped(batched_eo_fields):
    """The batched parity kernels (one launch, N spinor planes per gauge
    fetch) produce bitwise the same halves as N single-RHS launches."""
    upe, upo, ppe, ppo = batched_eo_fields
    n = ppe.shape[0]
    out = eo_k(upe, upo, ppo)
    ref = jnp.stack([eo_k(upe, upo, ppo[i]) for i in range(n)])
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    out = oe_k(upe, upo, ppe, gamma5_in=True, gamma5_out=True)
    ref = jnp.stack([oe_k(upe, upo, ppe[i], gamma5_in=True, gamma5_out=True)
                     for i in range(n)])
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("dagger", [False, True], ids=["plain", "dagger"])
def test_batched_schur_matches_looped_and_ref(batched_eo_fields, dagger):
    upe, upo, ppe, _ = batched_eo_fields
    n = ppe.shape[0]
    out = schur_k(upe, upo, ppe, EO_MASS, dagger=dagger)
    looped = jnp.stack([schur_k(upe, upo, ppe[i], EO_MASS, dagger=dagger)
                        for i in range(n)])
    np.testing.assert_array_equal(np.asarray(out), np.asarray(looped))
    ref = schur_op_ref(upe, upo, ppe, EO_MASS, dagger=dagger)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_batched_full_dslash_matches_looped(fields):
    lat = SHAPES[0]
    up, pp = fields[lat.dims]
    key = jax.random.PRNGKey(31)
    pps = jnp.stack([pack_spinor(random_spinor(jax.random.fold_in(key, i),
                                               lat)) for i in range(2)])
    out = dslash_k(up, pps, 0.1)
    looped = jnp.stack([dslash_k(up, pps[i], 0.1) for i in range(2)])
    np.testing.assert_array_equal(np.asarray(out), np.asarray(looped))
    # reference fallback takes the same batched rank
    ref = dslash_k(up, pps, 0.1, use_pallas=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=1e-4)


@pytest.mark.parametrize("n_rhs", [2, 5])
def test_batched_schur_normal_op_launch_count_independent_of_n(
        batched_eo_fields, n_rhs):
    """Acceptance: the batched A_hat is STILL exactly 4 kernel launches with
    zero standalone full-field γ5/axpy/cast passes, whatever N is."""
    upe, upo, ppe, _ = batched_eo_fields
    batch = jnp.concatenate([ppe] * 2)[:n_rhs]
    jx = jax.make_jaxpr(
        lambda a, b, v: schur_nk(a, b, v, EO_MASS, interpret=True))(
            upe, upo, batch)
    assert len(pallas_call_eqns(jx)) == 4
    assert full_field_passes(jx, batch.size) == []       # batched fields
    assert full_field_passes(jx, batch.size // n_rhs) == []  # per-RHS halves


def test_batched_cg_update_matches_looped_and_ref():
    from repro.kernels.cg_fused import (cg_update_batched,
                                        cg_update_batched_ref,
                                        cg_xpay_batched, cg_xpay_batched_ref)
    key = jax.random.PRNGKey(43)
    n, shape = 3, (37, 11)  # not lane-aligned: exercises per-RHS padding
    ks = jax.random.split(key, 4)
    x, r, p, ap = (jax.random.normal(k, (n,) + shape, jnp.float32)
                   for k in ks)
    alpha = jnp.asarray([0.5, 0.0, -1.2], jnp.float32)
    xo, ro, rs = cg_update_batched(alpha, x, r, p, ap)
    assert rs.shape == (n,)
    # bitwise vs the unbatched fused kernel per RHS (the solver equivalence
    # contract), close vs the jnp oracle (FMA fusion differs by ulps)
    for i in range(n):
        xi, ri, rsi = cg_update(alpha[i], x[i], r[i], p[i], ap[i])
        np.testing.assert_array_equal(np.asarray(xo[i]), np.asarray(xi))
        np.testing.assert_array_equal(np.asarray(ro[i]), np.asarray(ri))
        assert float(rs[i]) == float(rsi)
    xr, rr, rsr = cg_update_batched_ref(alpha, x, r, p, ap)
    np.testing.assert_allclose(np.asarray(xo), np.asarray(xr), atol=1e-6)
    np.testing.assert_allclose(np.asarray(ro), np.asarray(rr), atol=1e-6)
    np.testing.assert_allclose(np.asarray(rs), np.asarray(rsr), rtol=1e-5)
    # alpha = 0 slice is bitwise frozen
    np.testing.assert_array_equal(np.asarray(xo[1]), np.asarray(x[1]))
    np.testing.assert_array_equal(np.asarray(ro[1]), np.asarray(r[1]))

    beta = jnp.asarray([0.3, 7.7, -0.7], jnp.float32)
    gate = jnp.asarray([True, False, True])
    po = cg_xpay_batched(beta, r, p, gate)
    np.testing.assert_allclose(
        np.asarray(po), np.asarray(cg_xpay_batched_ref(beta, r, p, gate)),
        atol=1e-6)
    # gated-off slice is bitwise frozen; gated-on matches the unbatched kernel
    np.testing.assert_array_equal(np.asarray(po[1]), np.asarray(p[1]))
    np.testing.assert_array_equal(np.asarray(po[0]),
                                  np.asarray(cg_xpay(beta[0], r[0], p[0])))


@pytest.mark.parametrize("n", [130, 407, 1000])
def test_cg_update_pad_region_contributes_exactly_zero(n):
    """Sizes that are not multiples of 128*block_rows: the streaming pad
    must contribute EXACTLY 0 to the ||r||^2 partial sums."""
    x = jnp.zeros((n,), jnp.float32)
    r = jnp.ones((n,), jnp.float32)
    p = jnp.full((n,), 2.0, jnp.float32)
    ap = jnp.full((n,), 3.0, jnp.float32)
    # alpha = 0: r is untouched, so any nonzero pad contribution is visible
    xo, ro, rs = cg_update(jnp.float32(0.0), x, r, p, ap)
    assert float(rs) == float(n)
    assert xo.shape == ro.shape == (n,)
    # alpha != 0: pad lanes are 0 - alpha*0 = 0 and must stay invisible
    _, ro2, rs2 = cg_update(jnp.float32(0.5), x, r, p, ap)
    assert float(rs2) == float(jnp.sum(ro2 * ro2))
    np.testing.assert_allclose(np.asarray(ro2), np.full((n,), -0.5),
                               atol=1e-7)


def test_cg_pallas_end_to_end(fields):
    """Full CG through both Pallas kernels solves the Wilson system."""
    lat = SHAPES[1]
    up, pp = fields[lat.dims]
    m = 0.4
    b = dslash_dagger_packed(up, pp, m)
    x, (k, rs) = cg_pallas(lambda v: normal_k(up, v, m), b, tol=1e-6,
                           maxiter=300)
    res = dslash_k(up, x, m) - pp
    rel = float(jnp.linalg.norm(res.ravel()) / jnp.linalg.norm(pp.ravel()))
    assert rel < 1e-5
