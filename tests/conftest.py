"""Shared fixtures.  NOTE: never set xla_force_host_platform_device_count
here — smoke tests and benches must see 1 device (the dry-run sets its own
flag as the first line of repro.launch.dryrun)."""

import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
