"""Serving stack: plan cache keying, pad-and-mask, batching policy, server.

The server tests run real (small-lattice) solves through the compiled-plan
cache; a module-scoped PlanCache is shared across them so each distinct
(plan, mass, maxiter) program compiles at most once per test session.
asyncio is driven with ``asyncio.run`` directly — no plugin needed.
"""

import asyncio

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import LatticeShape, random_gauge, random_spinor
from repro.core.plan import SolverPlan
from repro.serve import (BatchPolicy, PlanCache, SolveRequest, SolverServer,
                         pad_batch, pad_tols, rung_for, validate_ladder)

MASS = 0.1
TOL = 1e-6
MAXITER = 500
LAT = LatticeShape(4, 4, 4, 4)


@pytest.fixture(scope="module")
def fields():
    key = jax.random.PRNGKey(7)
    ku, kb = jax.random.split(key)
    gauges = {f"cfg{g}": random_gauge(jax.random.fold_in(ku, g), LAT)
              for g in range(2)}
    pool = [random_spinor(jax.random.fold_in(kb, i), LAT) for i in range(8)]
    return gauges, pool


@pytest.fixture(scope="module")
def plans():
    # shared across every test in this module: compiles amortize
    return PlanCache()


def _wilson(nrhs):
    return SolverPlan(operator="eo-schur", operator_family="wilson",
                      nrhs=nrhs)


def _twisted(nrhs, mu=0.25):
    return SolverPlan(operator="eo-schur", operator_family="twisted-mass",
                      mu=mu, nrhs=nrhs)


# -- plan-cache keying -------------------------------------------------------

def test_plan_cache_same_plan_shares_compiled_callable():
    cache = PlanCache()
    fn1, hit1 = cache.get(_wilson(4), MASS, MAXITER)
    fn2, hit2 = cache.get(_wilson(4), MASS, MAXITER)
    assert (hit1, hit2) == (False, True)
    assert fn1 is fn2
    assert len(cache) == 1
    assert cache.stats() == {"size": 1, "hits": 1, "misses": 1,
                             "hit_rate": 0.5}


def test_plan_cache_distinguishes_family_mu_nrhs_mass_maxiter():
    cache = PlanCache()
    base = (_wilson(4), MASS, MAXITER)
    cache.get(*base)
    variants = [
        (_twisted(4), MASS, MAXITER),          # family (+ mu)
        (_twisted(4, mu=0.5), MASS, MAXITER),  # mu within a family
        (_wilson(8), MASS, MAXITER),           # batch rung
        (_wilson(4), 0.2, MAXITER),            # mass is trace-time
        (_wilson(4), MASS, 100),               # iteration cap is static
    ]
    for i, variant in enumerate(variants):
        _, hit = cache.get(*variant)
        assert not hit, f"variant {i} aliased the base plan"
    assert len(cache) == 1 + len(variants)


def test_solver_plan_cache_key_is_stable_and_hashable():
    a = _wilson(4).cache_key()
    b = _wilson(4).cache_key()
    assert a == b and hash(a) == hash(b)
    assert _wilson(8).cache_key() != a
    assert _twisted(4).cache_key() != a


# -- ladder / padding helpers ------------------------------------------------

def test_rung_for_picks_smallest_sufficient_rung():
    ladder = validate_ladder((1, 4, 8))
    assert [rung_for(n, ladder) for n in (1, 2, 4, 5, 8)] == [1, 4, 4, 8, 8]
    with pytest.raises(ValueError):
        rung_for(9, ladder)
    with pytest.raises(ValueError):
        validate_ladder(())


def test_pad_batch_zero_fills_and_pad_tols_are_inert(fields):
    _, pool = fields
    b = pad_batch(pool[:3], 4)
    assert b.shape == (4,) + pool[0].shape
    assert np.array_equal(np.asarray(b[2]), np.asarray(pool[2]))
    assert not np.any(np.asarray(b[3]))
    tols = pad_tols([1e-6, 1e-8, 1e-6], 4)
    assert tols.shape == (4,) and float(tols[3]) == 1.0


# -- pad-and-mask correctness at every ladder rung ---------------------------

@pytest.mark.parametrize("k,rung", [(1, 1), (3, 4), (5, 8)])
def test_padded_batch_is_bitwise_the_unpadded_solve(fields, plans, k, rung):
    """A batch of k padded to a rung returns bitwise the unpadded k-RHS
    solve: zero-RHS pad slots have a zero stopping limit, so they are
    inactive from iteration 0 and the masked update never perturbs the
    real systems."""
    gauges, pool = fields
    u = gauges["cfg0"]
    assert rung_for(k, (1, 4, 8)) == rung
    b = pad_batch(pool[:k], rung)
    tol = pad_tols([TOL] * k, rung)
    fn_pad, _ = plans.get(_wilson(rung), MASS, MAXITER)
    x_pad, stats = fn_pad(u, b, tol)
    fn_ref, _ = plans.get(_wilson(k), MASS, MAXITER)
    x_ref, _ = fn_ref(u, jnp.stack(pool[:k]),
                      jnp.full((k,), TOL, jnp.float32))
    assert np.array_equal(np.asarray(x_pad[:k]), np.asarray(x_ref))
    conv = np.asarray(stats.converged)
    assert conv[:k].all()
    # pad slots converge trivially at iteration 0
    assert np.asarray(stats.rhs_iterations)[k:].max(initial=0) == 0


# -- server behaviour --------------------------------------------------------

def _make_server(gauges, plans, **kw):
    kw.setdefault("mass", MASS)
    kw.setdefault("maxiter", MAXITER)
    kw.setdefault("ladder", (1, 4))
    server = SolverServer(plan_cache=plans, **kw)
    for gid, u in gauges.items():
        server.register_gauge(gid, u)
    return server


def _direct(plans, u, rhs, family="wilson", mu=0.0):
    plan = SolverPlan(operator="eo-schur", operator_family=family, mu=mu)
    fn, _ = plans.get(plan, MASS, MAXITER)
    x, _ = fn(u, rhs, jnp.float32(TOL))
    return x


def test_lone_request_dispatches_at_deadline_not_starved(fields, plans):
    gauges, pool = fields

    async def main():
        async with _make_server(gauges, plans,
                                policy=BatchPolicy(max_wait=0.05)) as server:
            req = SolveRequest(operator_family="wilson", gauge_id="cfg0",
                               rhs=pool[0], tol=TOL)
            # generous timeout: a cold cache pays one compile here, but the
            # 0.05 s batching deadline must still fire for a batch of ONE
            result = await asyncio.wait_for(server.submit(req), timeout=120)
            return result, server.metrics()

    result, metrics = asyncio.run(main())
    assert result.stats.batch_size == 1
    assert result.stats.padded_to == 1
    assert result.stats.converged
    assert metrics["batch_hist"] == {"1": 1}


def test_concurrent_requests_coalesce_into_one_padded_batch(fields, plans):
    gauges, pool = fields

    async def main():
        async with _make_server(
                gauges, plans,
                policy=BatchPolicy(max_wait=0.5)) as server:
            reqs = [SolveRequest(operator_family="wilson", gauge_id="cfg0",
                                 rhs=pool[i], tol=TOL) for i in range(3)]
            results = await asyncio.gather(*(server.submit(r) for r in reqs))
            return results, server.metrics()

    results, metrics = asyncio.run(main())
    assert metrics["batches"] == 1
    assert metrics["batch_hist"] == {"3": 1}
    assert metrics["rung_hist"] == {"4": 1}
    assert metrics["padded_slots"] == 1
    gauges_, pool_ = fields
    for i, res in enumerate(results):
        assert res.stats.batch_size == 3 and res.stats.padded_to == 4
        x_direct = _direct(plans, gauges_["cfg0"], pool_[i])
        assert float(jnp.max(jnp.abs(res.x - x_direct))) <= 1e-5


def test_mixed_gauges_and_families_do_not_share_batches(fields, plans):
    gauges, pool = fields

    async def main():
        async with _make_server(
                gauges, plans,
                policy=BatchPolicy(max_wait=0.5)) as server:
            reqs = []
            for gid in ("cfg0", "cfg1"):
                for family, mu in (("wilson", 0.0), ("twisted-mass", 0.25)):
                    for j in range(2):
                        reqs.append(SolveRequest(
                            operator_family=family, mu=mu, gauge_id=gid,
                            rhs=pool[j], tol=TOL))
            results = await asyncio.gather(*(server.submit(r) for r in reqs))
            return reqs, results, server.metrics()

    reqs, results, metrics = asyncio.run(main())
    # 4 coalesce keys (2 gauges x 2 families) x 2 requests each
    assert metrics["requests"] == 8
    assert metrics["batches"] == 4
    assert metrics["batch_hist"] == {"2": 4}
    for req, res in zip(reqs, results):
        assert res.stats.converged
        x_direct = _direct(plans, gauges[req.gauge_id], req.rhs,
                           family=req.operator_family, mu=req.mu)
        assert float(jnp.max(jnp.abs(res.x - x_direct))) <= 1e-5


def test_warmup_precompiles_ladder_and_requests_hit_cache(fields):
    gauges, pool = fields

    async def main():
        # private cache: this test asserts cold-vs-warm behaviour
        async with _make_server(gauges, PlanCache(), ladder=(1, 2),
                                policy=BatchPolicy(max_wait=0.2)) as server:
            warmed = await server.warmup(families=(("wilson", 0.0),))
            warmed_again = await server.warmup(families=(("wilson", 0.0),))
            req = SolveRequest(operator_family="wilson", gauge_id="cfg0",
                               rhs=pool[0], tol=TOL)
            result = await server.submit(req)
            return warmed, warmed_again, result, server.metrics()

    warmed, warmed_again, result, metrics = asyncio.run(main())
    assert warmed == 2          # one program per ladder rung
    assert warmed_again == 0    # idempotent: everything already cached
    assert result.stats.plan_cache_hit
    assert metrics["request_cache_hit_rate"] == 1.0


def test_unknown_gauge_id_and_bad_family_fail_fast(fields, plans):
    gauges, pool = fields

    async def main():
        async with _make_server(gauges, plans) as server:
            with pytest.raises(KeyError, match="unknown gauge_id"):
                await server.submit(SolveRequest(
                    operator_family="wilson", gauge_id="nope", rhs=pool[0]))
            with pytest.raises(Exception):
                await server.submit(SolveRequest(
                    operator_family="no-such-family", gauge_id="cfg0",
                    rhs=pool[0]))
            return server.metrics()

    metrics = asyncio.run(main())
    assert metrics["requests"] == 0  # rejected before entering a queue


def test_submit_after_close_is_rejected(fields, plans):
    gauges, pool = fields

    async def main():
        server = _make_server(gauges, plans)
        await server.close()
        with pytest.raises(RuntimeError, match="closed"):
            await server.submit(SolveRequest(
                operator_family="wilson", gauge_id="cfg0", rhs=pool[0]))

    asyncio.run(main())


def test_close_drains_queued_requests(fields, plans):
    """close(drain=True) — the default — completes every already-queued
    request before the dispatchers exit: a clean shutdown loses nothing."""
    gauges, pool = fields

    async def main():
        server = _make_server(gauges, plans,
                              policy=BatchPolicy(max_wait=0.25))
        tasks = [asyncio.create_task(server.submit(SolveRequest(
            operator_family="wilson", gauge_id="cfg0", rhs=pool[i],
            tol=TOL))) for i in range(3)]
        # close immediately: the requests are still queued/batching
        await asyncio.sleep(0)
        await server.close()
        out = await asyncio.gather(*tasks, return_exceptions=True)
        return out, server.metrics()

    out, metrics = asyncio.run(main())
    assert all(not isinstance(r, Exception) for r in out)
    assert all(r.stats.verified for r in out)
    assert metrics["requests"] == 3
    assert metrics["containment"]["failed_requests"] == 0


def test_close_abort_fails_pending_with_server_closed(fields, plans):
    """close(drain=False) cancels dispatchers and fails queued requests
    with ServerClosed — awaiters are never left hanging."""
    from repro.serve import ServerClosed
    gauges, pool = fields

    async def main():
        server = _make_server(gauges, plans,
                              policy=BatchPolicy(max_wait=5.0))
        tasks = [asyncio.create_task(server.submit(SolveRequest(
            operator_family="wilson", gauge_id="cfg0", rhs=pool[i],
            tol=TOL))) for i in range(3)]
        await asyncio.sleep(0)
        await server.close(drain=False)
        return await asyncio.gather(*tasks, return_exceptions=True)

    out = asyncio.run(main())
    # every awaiter resolves promptly; anything not already solved gets
    # ServerClosed (the first batch may have been dispatched already)
    assert all(isinstance(r, ServerClosed) or hasattr(r, "stats")
               for r in out)
    assert any(isinstance(r, ServerClosed) for r in out)
