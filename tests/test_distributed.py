"""Distributed lattice solver + sharded train step, on 8 fake CPU devices.

Runs in a SUBPROCESS with XLA_FLAGS=--xla_force_host_platform_device_count=8
(the main pytest process must keep the default single device)."""

import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
from repro.core import *
from repro.core import distributed as dist
from repro.core.wilson import dslash_packed

from repro.compat import make_mesh, shard_map

out = {}
mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
lat = LatticeShape(4, 4, 4, 8)
ku, kp = jax.random.split(jax.random.PRNGKey(3))
U = random_gauge(ku, lat); psi = random_spinor(kp, lat); m = 0.3
up, pp = pack_gauge(U), pack_spinor(psi)
upd, ppd = dist.shard_lattice_fields(mesh, up, pp)

psi_spec, gauge_spec, sharded = dist.lattice_specs(mesh)
f = jax.jit(shard_map(lambda u, p: dist.dslash_halo(u, p, m, sharded),
                      mesh=mesh, in_specs=(gauge_spec, psi_spec),
                      out_specs=psi_spec))
err = float(jnp.max(jnp.abs(f(upd, ppd) - dslash_packed(up, pp, m))))
out["halo_dslash_err"] = err

# the TPU path: Pallas plane-streaming kernel as the bulk stencil
fk = jax.jit(shard_map(
    lambda u, p: dist.dslash_halo(u, p, m, sharded, use_pallas=True),
    mesh=mesh, in_specs=(gauge_spec, psi_spec), out_specs=psi_spec,
    check_vma=False))
out["halo_pallas_err"] = float(
    jnp.max(jnp.abs(fk(upd, ppd) - dslash_packed(up, pp, m))))

for sv in ("cg", "pipecg", "mpcg"):
    x, st = dist.solve_wilson(mesh, upd, ppd, m, solver=sv, tol=1e-6,
                              maxiter=500)
    res = dslash_packed(up, jax.device_get(x), m) - pp
    rel = float(jnp.linalg.norm(res.ravel()) / jnp.linalg.norm(pp.ravel()))
    out[sv] = {"iters": int(st.iterations), "rel_res": rel,
               "converged": bool(st.converged)}

# sharded LM train step on a debug mesh
from repro import configs
from repro.models import steps as S
from repro.optim import AdamWConfig
from repro.data import SyntheticLM
from jax.sharding import NamedSharding, PartitionSpec as P
mesh2 = make_mesh((2, 2), ("data", "model"))
cfg = configs.get_smoke("glm4-9b")
opt = AdamWConfig(lr=1e-3)
state = S.init_train_state(cfg, jax.random.PRNGKey(0), opt)
specs = S.state_specs(cfg, jax.eval_shape(lambda: state))
shardings = jax.tree.map(lambda sp: NamedSharding(mesh2, sp), specs,
                         is_leaf=lambda x: isinstance(x, P))
state = jax.device_put(state, shardings)
fn = jax.jit(S.make_train_step(cfg, opt, mesh=mesh2,
                               compute_dtype=jnp.float32),
             in_shardings=(shardings, None),
             out_shardings=(shardings, None))
data = SyntheticLM(cfg, batch=4, seq_len=32)
losses = []
for i in range(8):
    state, metr = fn(state, data.batch_at(i))
    losses.append(float(metr["loss"]))
out["sharded_train"] = {"first": losses[0], "last": losses[-1]}

print("RESULT" + json.dumps(out))
"""


@pytest.fixture(scope="module")
def results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, r.stderr[-3000:]
    line = [ln for ln in r.stdout.splitlines() if ln.startswith("RESULT")][-1]
    return json.loads(line[len("RESULT"):])


def test_halo_dslash_matches_global(results):
    assert results["halo_dslash_err"] < 1e-5


def test_halo_pallas_kernel_matches_global(results):
    assert results["halo_pallas_err"] < 1e-4


@pytest.mark.parametrize("solver", ["cg", "pipecg", "mpcg"])
def test_distributed_solvers_converge(results, solver):
    r = results[solver]
    assert r["converged"] and r["rel_res"] < 1e-4, r


def test_sharded_train_step_learns(results):
    r = results["sharded_train"]
    assert r["last"] < r["first"]
