"""Distributed lattice solver + sharded train step, on 8 fake CPU devices.

Runs in a SUBPROCESS with XLA_FLAGS=--xla_force_host_platform_device_count=8
(the main pytest process must keep the default single device)."""

import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
from repro.core import *
from repro.core import distributed as dist
from repro.core.wilson import dslash_packed

from repro.compat import make_mesh, shard_map

out = {}
mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
lat = LatticeShape(4, 4, 4, 8)
ku, kp = jax.random.split(jax.random.PRNGKey(3))
U = random_gauge(ku, lat); psi = random_spinor(kp, lat); m = 0.3
up, pp = pack_gauge(U), pack_spinor(psi)
upd, ppd = dist.shard_lattice_fields(mesh, up, pp)

psi_spec, gauge_spec, sharded = dist.lattice_specs(mesh)
f = jax.jit(shard_map(lambda u, p: dist.dslash_halo(u, p, m, sharded),
                      mesh=mesh, in_specs=(gauge_spec, psi_spec),
                      out_specs=psi_spec))
err = float(jnp.max(jnp.abs(f(upd, ppd) - dslash_packed(up, pp, m))))
out["halo_dslash_err"] = err

# the TPU path: Pallas plane-streaming kernel as the bulk stencil
fk = jax.jit(shard_map(
    lambda u, p: dist.dslash_halo(u, p, m, sharded, use_pallas=True),
    mesh=mesh, in_specs=(gauge_spec, psi_spec), out_specs=psi_spec,
    check_vma=False))
out["halo_pallas_err"] = float(
    jnp.max(jnp.abs(fk(upd, ppd) - dslash_packed(up, pp, m))))

for sv in ("cg", "pipecg", "mpcg"):
    x, st = dist.solve_wilson(mesh, upd, ppd, m, solver=sv, tol=1e-6,
                              maxiter=500)
    res = dslash_packed(up, jax.device_get(x), m) - pp
    rel = float(jnp.linalg.norm(res.ravel()) / jnp.linalg.norm(pp.ravel()))
    out[sv] = {"iters": int(st.iterations), "rel_res": rel,
               "converged": bool(st.converged)}

# sharded LM train step on a debug mesh
from repro import configs
from repro.models import steps as S
from repro.optim import AdamWConfig
from repro.data import SyntheticLM
from jax.sharding import NamedSharding, PartitionSpec as P
mesh2 = make_mesh((2, 2), ("data", "model"))
cfg = configs.get_smoke("glm4-9b")
opt = AdamWConfig(lr=1e-3)
state = S.init_train_state(cfg, jax.random.PRNGKey(0), opt)
specs = S.state_specs(cfg, jax.eval_shape(lambda: state))
shardings = jax.tree.map(lambda sp: NamedSharding(mesh2, sp), specs,
                         is_leaf=lambda x: isinstance(x, P))
state = jax.device_put(state, shardings)
fn = jax.jit(S.make_train_step(cfg, opt, mesh=mesh2,
                               compute_dtype=jnp.float32),
             in_shardings=(shardings, None),
             out_shardings=(shardings, None))
data = SyntheticLM(cfg, batch=4, seq_len=32)
losses = []
for i in range(8):
    state, metr = fn(state, data.batch_at(i))
    losses.append(float(metr["loss"]))
out["sharded_train"] = {"first": losses[0], "last": losses[-1]}

# --- sharded even-odd Schur fast path (plan-driven) --------------------
from repro.core import plan as plan_mod
from repro.core import solvers
from repro.core.lattice import split_eo, split_eo_gauge
from repro.kernels.wilson_dslash import ops as wops
from repro.testing import while_body_psum_counts

N = 2
bb = jnp.stack([random_spinor(jax.random.fold_in(kp, i), lat)
                for i in range(N)])
pl_eo = plan_mod.SolverPlan(operator="eo-schur", backend="reference",
                            solver="pipecg", nrhs=N, mesh=mesh)
xsh, stsh = plan_mod.solve(pl_eo, U, bb, m, tol=1e-6, maxiter=500)
xs1, sts1 = solve_wilson_eo_batched(U, bb, m, tol=1e-6, maxiter=500,
                                    use_pallas=False)
res = jax.vmap(lambda xx, bv: dslash(U, xx, m) - bv)(xsh, bb)
rels = (jnp.linalg.norm(res.reshape(N, -1), axis=1)
        / jnp.linalg.norm(bb.reshape(N, -1), axis=1))
out["eo_sharded"] = {
    "iters": int(stsh.iterations),
    "rhs_iters": [int(v) for v in stsh.rhs_iterations],
    "all_converged": bool(jnp.all(stsh.converged)),
    "max_rel_res": float(jnp.max(rels)),
    "max_dev_vs_single_device": float(jnp.max(jnp.abs(xsh - xs1))),
}

# the Pallas parity kernels as the sharded bulk stencil: one halo matvec
# against the global single-device operator
u_e, u_o = split_eo_gauge(U)
upe, upo = pack_gauge(u_e), pack_gauge(u_o)
pe = pack_spinor(split_eo(psi)[0])
psi_spec2, gauge_spec2, sharded2 = dist.lattice_specs(mesh)
upe_d = jax.device_put(upe, NamedSharding(mesh, gauge_spec2))
upo_d = jax.device_put(upo, NamedSharding(mesh, gauge_spec2))
pe_d = jax.device_put(pe, NamedSharding(mesh, psi_spec2))
fk = jax.jit(shard_map(
    lambda ue, uo, p: dist.schur_normal_op_halo(ue, uo, p, m, sharded2,
                                                use_pallas=True),
    mesh=mesh, in_specs=(gauge_spec2, gauge_spec2, psi_spec2),
    out_specs=psi_spec2, check_vma=False))
ref = wops.schur_normal_op(upe, upo, pe, m, use_pallas=False)
out["eo_halo_pallas_err"] = float(jnp.max(jnp.abs(
    fk(upe_d, upo_d, pe_d) - ref)))

# the fused-reduction contract: the pipelined sharded CGNR's while body
# holds EXACTLY ONE psum, for the whole batch (jaxpr-level, no execution)
bspec = P(None, *psi_spec2)
pbe = pack_spinor(jax.vmap(split_eo)(bb)[0])
pbo = pack_spinor(jax.vmap(split_eo)(bb)[1])
kkw = dict(sharded=sharded2, use_pallas=False)
pdot, pnorm2 = dist.make_psum_dots(mesh, batched=True)
fused = dist.make_fused_psum_dots(mesh, batched=True)

def local_pipecg(ue, uo, be, bo):
    a_hat = lambda v: dist.schur_normal_op_halo(ue, uo, v, m, **kkw)
    d_eo = lambda v: dist.parity_hop_halo("eo", ue, uo, v, **kkw)
    ddag = lambda v: dist.schur_op_halo(ue, uo, v, m, dagger=True, **kkw)
    rhs = ddag(be - d_eo(bo / (m + 4.0)))
    x_e, _ = solvers.pipecg(a_hat, rhs, tol=1e-6, maxiter=500,
                            dot=pdot, norm2=pnorm2, batched=True,
                            fused_dots=fused)
    return x_e

jx = jax.make_jaxpr(shard_map(
    local_pipecg, mesh=mesh,
    in_specs=(gauge_spec2, gauge_spec2, bspec, bspec),
    out_specs=bspec, check_vma=False))(upe, upo, pbe, pbo)
out["pipecg_psums_per_iteration"] = while_body_psum_counts(jx)

print("RESULT" + json.dumps(out))
"""


@pytest.fixture(scope="module")
def results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    line = [ln for ln in r.stdout.splitlines() if ln.startswith("RESULT")][-1]
    return json.loads(line[len("RESULT"):])


def test_halo_dslash_matches_global(results):
    assert results["halo_dslash_err"] < 1e-5


def test_halo_pallas_kernel_matches_global(results):
    assert results["halo_pallas_err"] < 1e-4


@pytest.mark.parametrize("solver", ["cg", "pipecg", "mpcg"])
def test_distributed_solvers_converge(results, solver):
    r = results[solver]
    assert r["converged"] and r["rel_res"] < 1e-4, r


def test_sharded_train_step_learns(results):
    r = results["sharded_train"]
    assert r["last"] < r["first"]


def test_sharded_eo_schur_matches_single_device(results):
    """A plan-driven sharded batched EO Schur solve converges per RHS and
    matches the single-device solve_wilson_eo_batched iterates to <=1e-5
    (float reassociation across the psum tree is the only difference)."""
    r = results["eo_sharded"]
    assert r["all_converged"], r
    assert r["max_rel_res"] < 1e-4, r
    assert r["max_dev_vs_single_device"] <= 1e-5, r
    assert all(n <= r["iters"] for n in r["rhs_iters"])
    assert max(r["rhs_iters"]) == r["iters"]


def test_sharded_eo_pallas_bulk_kernel_matches_global(results):
    """schur_normal_op_halo with the Pallas parity kernels as the bulk
    stencil reproduces the global single-device Schur normal operator."""
    assert results["eo_halo_pallas_err"] < 1e-4


def test_sharded_pipecg_is_one_psum_per_iteration(results):
    """The fused-reduction contract (DESIGN.md §7): the sharded pipelined
    CGNR's while-loop body contains EXACTLY ONE psum — gamma and delta
    for every RHS of the batch travel in a single stacked collective."""
    assert results["pipecg_psums_per_iteration"] == [1]
