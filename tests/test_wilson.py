"""Dirac-Wilson operator: gamma algebra, Hermiticity structure, layouts."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (LatticeShape, dslash, dslash_dagger, field_dot,
                        merge_eo, pack_gauge, pack_spinor, random_gauge,
                        random_spinor, split_eo, split_eo_gauge, unit_gauge,
                        unpack_spinor)
from repro.core.wilson import (DSLASH_FLOPS_PER_SITE, GAMMAS, GAMMA5,
                               dslash_eo, dslash_oe, dslash_packed,
                               dslash_dagger_packed, hop_term_packed,
                               normal_op, normal_op_packed, schur_dagger,
                               schur_normal_op, schur_op)

LAT = LatticeShape(4, 4, 4, 8)
MASS = 0.3


def test_gamma_algebra():
    for mu in range(4):
        assert np.allclose(GAMMAS[mu] @ GAMMAS[mu], np.eye(4), atol=1e-7)
        assert np.allclose(GAMMAS[mu].conj().T, GAMMAS[mu], atol=1e-7)
        for nu in range(mu + 1, 4):
            anti = GAMMAS[mu] @ GAMMAS[nu] + GAMMAS[nu] @ GAMMAS[mu]
            assert np.allclose(anti, 0, atol=1e-7)
    g5 = GAMMAS[0] @ GAMMAS[3] @ GAMMAS[2] @ GAMMAS[1]
    # gamma5 is diagonal ±1 in this basis (overall sign conventional)
    assert np.allclose(np.abs(np.diag(g5)), np.ones(4), atol=1e-7)
    assert np.allclose(GAMMA5 @ GAMMA5, np.eye(4), atol=1e-7)


def test_free_field_constant_mode(rng):
    """With unit links, a constant spinor is an eigenvector: D psi = m psi."""
    u = unit_gauge(LAT)
    psi = jnp.ones(LAT.dims + (4, 3), dtype=jnp.complex64)
    out = dslash(u, psi, MASS)
    assert jnp.max(jnp.abs(out - MASS * psi)) < 1e-5


def test_dslash_linearity(rng):
    k1, k2, ku = jax.random.split(rng, 3)
    u = random_gauge(ku, LAT)
    a, b = random_spinor(k1, LAT), random_spinor(k2, LAT)
    lhs = dslash(u, 2.0 * a + 1j * b, MASS)
    rhs = 2.0 * dslash(u, a, MASS) + 1j * dslash(u, b, MASS)
    assert jnp.max(jnp.abs(lhs - rhs)) < 1e-4


def test_gamma5_hermiticity(rng):
    """<phi, D psi> == <D^dag phi, psi> with D^dag = g5 D g5."""
    k1, k2, ku = jax.random.split(rng, 3)
    u = random_gauge(ku, LAT)
    phi, psi = random_spinor(k1, LAT), random_spinor(k2, LAT)
    lhs = complex(field_dot(phi, dslash(u, psi, MASS)))
    rhs = complex(field_dot(dslash_dagger(u, phi, MASS), psi))
    assert np.isclose(lhs, rhs, rtol=1e-4)


def test_normal_op_hpd(rng):
    k1, ku = jax.random.split(rng)
    u = random_gauge(ku, LAT)
    psi = random_spinor(k1, LAT)
    quad = complex(field_dot(psi, normal_op(u, psi, MASS)))
    assert abs(quad.imag) < 1e-3 * abs(quad.real)
    assert quad.real > 0


def test_packed_matches_natural(rng):
    k1, ku = jax.random.split(rng)
    u = random_gauge(ku, LAT)
    psi = random_spinor(k1, LAT)
    ref = dslash(u, psi, MASS)
    out = unpack_spinor(dslash_packed(pack_gauge(u), pack_spinor(psi), MASS))
    assert jnp.max(jnp.abs(out - ref)) < 1e-5


def test_packed_dagger_and_normal(rng):
    k1, ku = jax.random.split(rng)
    u = random_gauge(ku, LAT)
    psi = random_spinor(k1, LAT)
    up, pp = pack_gauge(u), pack_spinor(psi)
    ref = dslash_dagger(u, psi, MASS)
    out = unpack_spinor(dslash_dagger_packed(up, pp, MASS))
    assert jnp.max(jnp.abs(out - ref)) < 1e-5
    refn = normal_op(u, psi, MASS)
    outn = unpack_spinor(normal_op_packed(up, pp, MASS))
    assert jnp.max(jnp.abs(outn - refn)) < 2e-4


def test_hop_term_consistency(rng):
    """Sum of mass term + 8 aligned hop terms == dslash_packed."""
    k1, ku = jax.random.split(rng)
    u = random_gauge(ku, LAT)
    psi = random_spinor(k1, LAT)
    up, pp = pack_gauge(u), pack_spinor(psi)
    acc = (MASS + 4.0) * pp
    ax = {0: 0, 1: 1, 2: 2, 3: 4}
    for mu in range(4):
        fwd = jnp.roll(pp, -1, axis=ax[mu])
        acc = acc + hop_term_packed(up[mu], fwd, mu, forward=True)
        bwd = jnp.roll(pp, 1, axis=ax[mu])
        ub = jnp.roll(up[mu], 1, axis=ax[mu] if mu < 3 else 4)
        acc = acc + hop_term_packed(ub, bwd, mu, forward=False)
    ref = dslash_packed(up, pp, MASS)
    assert jnp.max(jnp.abs(acc - ref)) < 1e-5


def test_eo_blocks_reassemble_dslash(rng):
    """D reassembled from {M, dslash_eo, dslash_oe} matches dslash exactly:
    merge(M psi_e + D_eo psi_o, M psi_o + D_oe psi_e) == D psi."""
    k1, ku = jax.random.split(rng)
    u = random_gauge(ku, LAT)
    psi = random_spinor(k1, LAT)
    ue, uo = split_eo_gauge(u)
    pe, po = split_eo(psi)
    m = MASS + 4.0
    even = m * pe + dslash_eo(ue, uo, po)
    odd = m * po + dslash_oe(ue, uo, pe)
    ref = dslash(u, psi, MASS)
    assert jnp.max(jnp.abs(merge_eo(even, odd) - ref)) < 1e-5


def test_eo_hop_free_field(rng):
    """Unit links, constant spinor: D psi = m psi implies the even-output
    hop block contributes exactly -4r psi_e."""
    u = unit_gauge(LAT)
    ue, uo = split_eo_gauge(u)
    psi = jnp.ones(LAT.dims + (4, 3), dtype=jnp.complex64)
    pe, po = split_eo(psi)
    # free-field D psi = m psi  =>  hop block contribution is -4r psi_e
    hop = dslash_eo(ue, uo, po)
    assert jnp.max(jnp.abs(hop + 4.0 * pe)) < 1e-5


def test_schur_gamma5_hermiticity(rng):
    """<phi_e, D_hat psi_e> == <D_hat^dag phi_e, psi_e> with
    D_hat^dag = g5 D_hat g5 — CGNR applies to the reduced operator."""
    k1, k2, ku = jax.random.split(rng, 3)
    u = random_gauge(ku, LAT)
    ue, uo = split_eo_gauge(u)
    phi = split_eo(random_spinor(k1, LAT))[0]
    psi = split_eo(random_spinor(k2, LAT))[0]
    lhs = complex(field_dot(phi, schur_op(ue, uo, psi, MASS)))
    rhs = complex(field_dot(schur_dagger(ue, uo, phi, MASS), psi))
    assert np.isclose(lhs, rhs, rtol=1e-4)


def test_schur_normal_op_hpd(rng):
    k1, ku = jax.random.split(rng)
    u = random_gauge(ku, LAT)
    ue, uo = split_eo_gauge(u)
    psi = split_eo(random_spinor(k1, LAT))[0]
    quad = complex(field_dot(psi, schur_normal_op(ue, uo, psi, MASS)))
    assert abs(quad.imag) < 1e-3 * abs(quad.real)
    assert quad.real > 0


def test_flops_constant():
    assert DSLASH_FLOPS_PER_SITE == 1320  # the standard Wilson count
