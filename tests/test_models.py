"""Per-architecture smoke tests + component-level model tests.

Every assigned architecture instantiates its REDUCED (smoke) config and
runs forward / prefill / decode on CPU, asserting shapes and finiteness;
decode must agree with the full forward for deterministic-routing models.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import encdec as E
from repro.models import recurrent as R
from repro.models import transformer as T
from repro.models.layers import attention

B, S = 2, 32
ARCHS = configs.all_arch_names()


def _inputs(cfg, key, seq=S):
    toks = jax.random.randint(key, (B, seq), 0, cfg.vocab_size)
    extras = {}
    if cfg.is_encdec:
        extras["frames"] = 0.02 * jax.random.normal(
            key, (B, 16, cfg.d_model), jnp.float32)
    if cfg.num_prefix_embeds:
        extras["prefix_embeds"] = 0.02 * jax.random.normal(
            key, (B, cfg.num_prefix_embeds, cfg.d_model), jnp.float32)
    return toks, extras


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_forward_prefill_decode(arch, rng):
    cfg = configs.get_smoke(arch)
    key = jax.random.fold_in(rng, hash(arch) % 2 ** 31)
    toks, extras = _inputs(cfg, key)
    prefix = cfg.num_prefix_embeds or 0

    if cfg.is_encdec:
        params = E.init_params(cfg, key)
        logits, _ = E.forward(cfg, params, toks, frames=extras["frames"])
        lp, caches = E.prefill(cfg, params, toks, frames=extras["frames"],
                               cache_len=S + 4)
        ld, caches = E.decode_step(cfg, params, toks[:, :1], S, caches)
    else:
        params = T.init_params(cfg, key)
        pe = extras.get("prefix_embeds")
        logits, _ = T.forward(cfg, params, toks, prefix_embeds=pe)
        lp, caches = T.prefill(cfg, params, toks, cache_len=S + prefix + 4,
                               prefix_embeds=pe)
        ld, caches = T.decode_step(cfg, params, toks[:, :1],
                                   jnp.int32(S + prefix), caches)
    assert logits.shape[0] == B and logits.shape[-1] == cfg.vocab_size
    assert ld.shape == (B, 1, cfg.vocab_size)
    for t in (logits, lp, ld):
        assert bool(jnp.all(jnp.isfinite(t))), arch


@pytest.mark.parametrize("arch", ["glm4-9b", "gemma-7b", "recurrentgemma-9b",
                                  "rwkv6-1.6b", "seamless-m4t-large-v2"])
def test_decode_matches_forward(arch, rng):
    """prefill(S) + decode(1) logits == forward(S+1) last-position logits."""
    cfg = configs.get_smoke(arch)
    key = jax.random.fold_in(rng, 1234)
    toks, extras = _inputs(cfg, key, seq=S + 1)
    if cfg.is_encdec:
        params = E.init_params(cfg, key)
        full, _ = E.forward(cfg, params, toks, frames=extras["frames"])
        _, caches = E.prefill(cfg, params, toks[:, :S],
                              frames=extras["frames"], cache_len=S + 8)
        ld, _ = E.decode_step(cfg, params, toks[:, S:S + 1], S, caches)
    else:
        params = T.init_params(cfg, key)
        full, _ = T.forward(cfg, params, toks)
        _, caches = T.prefill(cfg, params, toks[:, :S], cache_len=S + 8)
        ld, _ = T.decode_step(cfg, params, toks[:, S:S + 1],
                              jnp.int32(S), caches)
    err = float(jnp.max(jnp.abs(full[:, -1] - ld[:, 0])))
    assert err < 1e-4, f"{arch}: {err}"


def test_sliding_window_matches_dense_mask(rng):
    """Ring-buffer decode == dense attention with a window mask."""
    cfg = configs.get_smoke("recurrentgemma-9b")
    w = cfg.window
    key = jax.random.fold_in(rng, 99)
    params = T.init_params(cfg, key)
    toks = jax.random.randint(key, (B, w + 9), 0, cfg.vocab_size)
    full, _ = T.forward(cfg, params, toks)          # windowed internally
    _, caches = T.prefill(cfg, params, toks[:, :w + 8], cache_len=w + 16)
    ld, _ = T.decode_step(cfg, params, toks[:, w + 8:w + 9],
                          jnp.int32(w + 8), caches)
    err = float(jnp.max(jnp.abs(full[:, -1] - ld[:, 0])))
    assert err < 1e-4


def test_flash_attention_vs_dense(rng):
    b, sq, skv, hq, hkv, hd = 2, 16, 48, 8, 2, 16
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (b, sq, hq, hd))
    k = jax.random.normal(ks[1], (b, skv, hkv, hd))
    v = jax.random.normal(ks[2], (b, skv, hkv, hd))
    qp = jnp.arange(32, 32 + sq)
    kp = jnp.arange(skv)

    def dense(q, k, v, window):
        g = hq // hkv
        qg = q.reshape(b, sq, hkv, g, hd).astype(jnp.float32)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg,
                       k.astype(jnp.float32)) / np.sqrt(hd)
        valid = kp[None, :] <= qp[:, None]
        if window:
            valid &= qp[:, None] - kp[None, :] < window
        s = jnp.where(valid[None, None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhgqk,bkhd->bhgqd", p, v.astype(jnp.float32))
        return o.transpose(0, 3, 1, 2, 4).reshape(b, sq, hq, hd)

    for window in (0, 12):
        out = attention(q, k, v, q_pos=qp, kv_pos=kp, window=window,
                        chunk=16)
        ref = dense(q, k, v, window)
        assert float(jnp.max(jnp.abs(out - ref))) < 1e-5
        # gradients through the custom VJP
        f = lambda *a: attention(*a, q_pos=qp, kv_pos=kp, window=window,
                                 chunk=16).sum()
        r = lambda *a: dense(*a, window).sum()
        gf = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
        gr = jax.grad(r, argnums=(0, 1, 2))(q, k, v)
        for a, bb in zip(gf, gr):
            assert float(jnp.max(jnp.abs(a - bb))) < 5e-5


def test_chunked_wkv_matches_sequential(rng):
    b, s, h, d = 2, 48, 4, 16
    ks = jax.random.split(rng, 5)
    r = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, h, d))
    v = jax.random.normal(ks[2], (b, s, h, d))
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (b, s, h, d)) - 3.0))
    u = 0.1 * jax.random.normal(ks[4], (h, d))
    s0 = 0.1 * jax.random.normal(ks[0], (b, h, d, d))

    def seq(r, k, v, w, u, s0):
        def step(S, inp):
            rt, kt, vt, wt = inp
            kv = kt[..., :, None] * vt[..., None, :]
            out = jnp.einsum("bhk,bhkv->bhv", rt,
                             S + u[None, :, :, None] * kv)
            return wt[..., :, None] * S + kv, out
        xs = tuple(t.swapaxes(0, 1) for t in (r, k, v, w))
        S, ys = jax.lax.scan(step, s0, xs)
        return S, ys.swapaxes(0, 1)

    s1, y1 = seq(r, k, v, w, u, s0)
    s2, y2 = R._wkv_chunked(r, k, v, w, u, s0)
    assert float(jnp.max(jnp.abs(y1 - y2))) < 1e-4
    assert float(jnp.max(jnp.abs(s1 - s2))) < 1e-4


def test_moe_load_balance_and_shapes(rng):
    cfg = configs.get_smoke("qwen3-moe-235b-a22b")
    from repro.models.moe import moe_apply, moe_init
    p = moe_init(rng, cfg, jnp.float32)
    x = jax.random.normal(rng, (2, 16, cfg.d_model))
    y, aux = moe_apply(p, x, cfg)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(aux["load_balance_loss"]))
    # perfectly uniform routing gives lb ~= 1; anything sane is near that
    assert 0.5 < float(aux["load_balance_loss"]) < float(cfg.moe.num_experts)


def test_moe_grouped_matches_global_dispatch(rng):
    """Per-sequence capacity groups change only capacity-drop boundaries;
    with ample capacity the grouped and global dispatch agree exactly."""
    import dataclasses
    from repro.models.moe import moe_apply, moe_init
    cfg = configs.get_smoke("qwen2-moe-a2.7b")
    big_cap = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=4.0))
    p = moe_init(rng, big_cap, jnp.float32)
    x = jax.random.normal(rng, (3, 16, cfg.d_model))
    y1, _ = moe_apply(p, x, big_cap)
    y0, _ = moe_apply(p, x, dataclasses.replace(big_cap,
                                                moe_dispatch_shard=False))
    assert float(jnp.max(jnp.abs(y1 - y0))) < 1e-5


def test_moe_capacity_drops_tokens(rng):
    """Tiny capacity must drop tokens (outputs differ from ample capacity)
    without producing NaNs — the overflow path is exercised."""
    import dataclasses
    from repro.models.moe import moe_apply, moe_init
    cfg = configs.get_smoke("qwen3-moe-235b-a22b")
    tiny = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.1))
    p = moe_init(rng, cfg, jnp.float32)
    x = jax.random.normal(rng, (2, 32, cfg.d_model))
    y_tiny, _ = moe_apply(p, x, tiny)
    y_full, _ = moe_apply(p, x, cfg)
    assert bool(jnp.all(jnp.isfinite(y_tiny)))
    assert float(jnp.max(jnp.abs(y_tiny - y_full))) > 1e-6


def test_param_counts_in_family_ballpark():
    """Full configs should land near their advertised sizes."""
    expect = {"glm4-9b": (8e9, 14e9), "yi-9b": (8e9, 12e9),
              "gemma-7b": (7e9, 10e9), "nemotron-4-340b": (3e11, 4e11),
              "qwen3-moe-235b-a22b": (2.0e11, 2.6e11),
              "qwen2-moe-a2.7b": (12e9, 17e9),
              "recurrentgemma-9b": (7e9, 12e9),
              "rwkv6-1.6b": (1.2e9, 2.2e9),
              "pixtral-12b": (11e9, 15e9)}
    for arch, (lo, hi) in expect.items():
        n = configs.get(arch).param_count()
        assert lo < n < hi, f"{arch}: {n:.2e} not in ({lo:.0e},{hi:.0e})"


def test_stack_plan_covers_depth():
    for arch in ARCHS:
        cfg = configs.get(arch)
        if cfg.is_encdec:
            continue
        plan = T.stack_plan(cfg)
        total = sum(len(pat) * count for pat, count in plan)
        assert total == cfg.num_layers, arch
