"""Krylov solvers: convergence, equivalences, the mixed-precision variant."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (LatticeShape, bicgstab, cg, cg_trace, cgnr, dslash,
                        dslash_dagger, mpcg, normal_op, pack_gauge,
                        pack_spinor, pipecg, random_gauge, random_spinor)
from repro.core import solvers
from repro.core.wilson import (dslash_dagger_packed, dslash_packed,
                               normal_op_packed)
from repro.kernels.cg_fused import fused_engine
from repro.testing import maybe_hypothesis

given, settings, st = maybe_hypothesis()

LAT = LatticeShape(4, 4, 4, 8)
MASS = 0.4


@pytest.fixture(scope="module")
def problem():
    key = jax.random.PRNGKey(7)
    ku, kb = jax.random.split(key)
    u = random_gauge(ku, LAT)
    b = random_spinor(kb, LAT)
    return u, b


def _rel_res(u, x, b):
    r = dslash(u, x, MASS) - b
    return float(jnp.linalg.norm(r.ravel()) / jnp.linalg.norm(b.ravel()))


def test_cgnr_solves_wilson(problem):
    u, b = problem
    x, st_ = cgnr(lambda v: dslash(u, v, MASS),
                  lambda v: dslash_dagger(u, v, MASS), b,
                  tol=1e-6, maxiter=500)
    assert bool(st_.converged)
    assert _rel_res(u, x, b) < 1e-5


def test_pipecg_matches_cg(problem):
    u, b = problem
    op = lambda v: normal_op(u, v, MASS)
    rhs = dslash_dagger(u, b, MASS)
    x1, s1 = cg(op, rhs, tol=1e-6, maxiter=500)
    x2, s2 = pipecg(op, rhs, tol=1e-6, maxiter=500)
    assert bool(s2.converged)
    # same solution; iteration counts within a few of each other
    assert jnp.max(jnp.abs(x1 - x2)) < 1e-3
    assert abs(int(s1.iterations) - int(s2.iterations)) <= 10


def test_bicgstab_direct_solve(problem):
    u, b = problem
    x, st_ = bicgstab(lambda v: dslash(u, v, MASS), b, tol=1e-6, maxiter=500)
    assert bool(st_.converged)
    assert _rel_res(u, x, b) < 1e-5


def test_mpcg_bf16_reaches_f32_tolerance(problem):
    """The paper's two-precision CG: bulk iterations in bf16, reliable
    updates in f32, converges to the f32 tolerance (Ref. [10] claim)."""
    u, b = problem
    up, bp = pack_gauge(u), pack_spinor(b)
    up_lo = up.astype(jnp.bfloat16)
    op_hi = lambda v: normal_op_packed(up, v, MASS)
    op_lo = lambda v: normal_op_packed(up_lo, v, MASS)
    rhs = dslash_dagger_packed(up, bp, MASS)
    x, st_ = mpcg(op_lo, op_hi, rhs, tol=1e-6, inner_tol=5e-2,
                  inner_maxiter=100, max_outer=40)
    assert bool(st_.converged)
    r = dslash_packed(up, x, MASS) - bp
    rel = float(jnp.linalg.norm(r.ravel()) / jnp.linalg.norm(bp.ravel()))
    assert rel < 1e-5
    # most work happened in the low-precision inner solver
    assert int(st_.iterations) >= 3 * int(st_.outer_iterations)


def test_mpcg_iteration_overhead_is_modest(problem):
    """Mixed precision should not blow up total iteration count vs f32."""
    u, b = problem
    up, bp = pack_gauge(u), pack_spinor(b)
    rhs = dslash_dagger_packed(up, bp, MASS)
    op_hi = lambda v: normal_op_packed(up, v, MASS)
    _, s_f32 = cg(op_hi, rhs, tol=1e-6, maxiter=500)
    up_lo = up.astype(jnp.bfloat16)
    op_lo = lambda v: normal_op_packed(up_lo, v, MASS)
    _, s_mp = mpcg(op_lo, op_hi, rhs, tol=1e-6, inner_tol=5e-2,
                   inner_maxiter=100, max_outer=40)
    assert int(s_mp.iterations) <= 3 * int(s_f32.iterations)


def test_cg_fused_engine_matches_default(problem):
    """CG with the Pallas fused vector engine injected produces the same
    iterates (iteration count and solution) as the default jnp algebra."""
    u, b = problem
    up, bp = pack_gauge(u), pack_spinor(b)
    rhs = dslash_dagger_packed(up, bp, MASS)
    op = lambda v: normal_op_packed(up, v, MASS)
    x1, s1 = cg(op, rhs, tol=1e-6, maxiter=300)
    update, xpay = fused_engine(interpret=True)
    x2, s2 = cg(op, rhs, tol=1e-6, maxiter=300, update=update, xpay=xpay)
    assert bool(s2.converged)
    assert abs(int(s1.iterations) - int(s2.iterations)) <= 1
    assert float(jnp.max(jnp.abs(x1 - x2))) < 1e-4
    # and the solution actually solves the Wilson system
    r = dslash_packed(up, x2, MASS)
    rel = float(jnp.linalg.norm((r - bp).ravel())
                / jnp.linalg.norm(bp.ravel()))
    assert rel < 1e-4


def test_cg_trace_fused_engine_matches_default(problem):
    u, b = problem
    up, bp = pack_gauge(u), pack_spinor(b)
    rhs = dslash_dagger_packed(up, bp, MASS)
    op = lambda v: normal_op_packed(up, v, MASS)
    _, hist1 = cg_trace(op, rhs, iters=12)
    update, xpay = fused_engine(interpret=True)
    _, hist2 = cg_trace(op, rhs, iters=12, update=update, xpay=xpay)
    np.testing.assert_allclose(np.asarray(hist2), np.asarray(hist1),
                               rtol=1e-3)


def test_cg_trace_monotone_tail(problem):
    u, b = problem
    op = lambda v: normal_op(u, v, MASS)
    rhs = dslash_dagger(u, b, MASS)
    _, hist = cg_trace(op, rhs, iters=30)
    assert float(hist[-1]) < float(hist[0]) * 1e-6


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_cg_property_random_spd(seed):
    """CG solves random SPD systems A = B B^T + I to tolerance."""
    key = jax.random.PRNGKey(seed)
    n = 24
    bmat = jax.random.normal(key, (n, n), dtype=jnp.float32) / np.sqrt(n)
    amat = bmat @ bmat.T + jnp.eye(n)
    rhs = jax.random.normal(jax.random.fold_in(key, 1), (n,), jnp.float32)
    x, st_ = cg(lambda v: amat @ v, rhs, tol=1e-6, maxiter=200)
    assert bool(st_.converged)
    assert float(jnp.linalg.norm(amat @ x - rhs)) < 1e-4 * max(
        1.0, float(jnp.linalg.norm(rhs)))


def test_solver_respects_maxiter(problem):
    u, b = problem
    op = lambda v: normal_op(u, v, MASS)
    rhs = dslash_dagger(u, b, MASS)
    _, st_ = cg(op, rhs, tol=1e-30, maxiter=5)
    assert int(st_.iterations) == 5
    assert not bool(st_.converged)


def test_cg_per_rhs_tol_vector_freezes_loose_system_earlier(problem):
    """tol may be a per-RHS (N,) vector on batched solves (the serving
    layer coalesces mixed-tolerance requests into one batch): the
    loose-tol system hits its own limit and freezes before the tight one,
    and a uniform vector is bitwise the scalar tol."""
    u, b = problem
    b2 = random_spinor(jax.random.PRNGKey(3), LAT)
    op = jax.vmap(lambda v: normal_op(u, v, MASS))
    rhs = jnp.stack([dslash_dagger(u, b, MASS), dslash_dagger(u, b2, MASS)])
    x, st_ = cg(op, rhs, tol=jnp.array([1e-6, 1e-2], jnp.float32),
                maxiter=500, batched=True)
    assert np.asarray(st_.converged).all()
    iters = np.asarray(st_.rhs_iterations)
    assert iters[1] < iters[0]
    x_vec, s_vec = cg(op, rhs, tol=jnp.full((2,), 1e-6, jnp.float32),
                      maxiter=500, batched=True)
    x_scal, s_scal = cg(op, rhs, tol=1e-6, maxiter=500, batched=True)
    assert np.array_equal(np.asarray(x_vec), np.asarray(x_scal))
    assert np.array_equal(np.asarray(s_vec.rhs_iterations),
                          np.asarray(s_scal.rhs_iterations))


def test_cg_rejects_tol_vector_on_unbatched_solve(problem):
    u, b = problem
    rhs = dslash_dagger(u, b, MASS)
    with pytest.raises(ValueError, match="tol"):
        cg(lambda v: normal_op(u, v, MASS), rhs,
           tol=jnp.array([1e-6, 1e-5], jnp.float32), maxiter=10)


# -- failure taxonomy (DESIGN.md §10): every solver exit is classified ------


def test_cg_breakdown_guard_keeps_iterate_finite():
    """p·Ap == 0 (singular operator): the guard must flag BREAKDOWN at the
    first iteration and keep x finite instead of flooding it with inf."""
    rhs = jnp.ones((24,), jnp.float32)
    x, st_ = cg(lambda v: 0.0 * v, rhs, tol=1e-8, maxiter=50)
    assert int(st_.verdict) == solvers.BREAKDOWN
    assert not bool(st_.converged)
    # the broken lane leaves the loop immediately — it must not burn maxiter
    assert int(st_.iterations) <= 2
    assert bool(jnp.all(jnp.isfinite(x)))


def test_cg_batched_breakdown_blast_radius_is_one():
    """One singular lane in a batch breaks down alone; its batchmate
    converges in exactly the iterations a solo solve takes."""
    key = jax.random.PRNGKey(0)
    bmat = jax.random.normal(key, (16, 16), jnp.float32) / 4
    amat = bmat @ bmat.T + jnp.eye(16)
    op = lambda v: jnp.stack([amat @ v[0], 0.0 * v[1]])
    rhs = jnp.stack([jnp.ones((16,), jnp.float32)] * 2)
    x, st_ = cg(op, rhs, tol=1e-6, maxiter=100, batched=True)
    verdicts = np.asarray(st_.verdict)
    assert verdicts[0] == solvers.CONVERGED
    assert verdicts[1] == solvers.BREAKDOWN
    assert bool(jnp.all(jnp.isfinite(x)))
    _, solo = cg(lambda v: amat @ v, rhs[0], tol=1e-6, maxiter=100)
    assert int(np.asarray(st_.rhs_iterations)[0]) == int(solo.iterations)


def test_cg_nonfinite_rhs_classified_without_iterating():
    """A NaN RHS makes ‖r‖² NaN: the lane is inactive from iteration 0
    (NaN comparisons are False) and the exit classifies NONFINITE."""
    rhs = jnp.ones((24,), jnp.float32).at[0].set(jnp.nan)
    _, st_ = cg(lambda v: v, rhs, tol=1e-8, maxiter=50)
    assert int(st_.verdict) == solvers.NONFINITE
    assert int(st_.iterations) == 0
    assert not bool(st_.converged)


def test_cg_stagnation_detected_on_float32_plateau():
    """An ill-conditioned SPD system with an unreachable tol plateaus at
    float32 accuracy: the watermark stops shrinking and the exit says
    STAGNATION, not plain maxiter exhaustion."""
    d = jnp.logspace(0, 8, 32).astype(jnp.float32)
    rhs = jnp.ones((32,), jnp.float32)
    _, st_ = cg(lambda v: d * v, rhs, tol=1e-30, maxiter=200)
    assert int(st_.verdict) == solvers.STAGNATION
    assert int(st_.iterations) == 200


def test_cg_maxiter_exhaustion_verdict(problem):
    u, b = problem
    op = lambda v: normal_op(u, v, MASS)
    rhs = dslash_dagger(u, b, MASS)
    _, st_ = cg(op, rhs, tol=1e-30, maxiter=5)
    # exhausted well before the stagnation window: plain MAXITER_EXHAUSTED
    assert int(st_.verdict) == solvers.MAXITER_EXHAUSTED


def test_bicgstab_respects_stop_limit_contract(problem):
    """bicgstab goes through the shared ``_stop_limit`` stopping contract:
    a tol vector is rejected on its unbatched loop, and a breakdown-free
    healthy solve classifies CONVERGED."""
    u, b = problem
    with pytest.raises(ValueError, match="tol"):
        bicgstab(lambda v: dslash(u, v, MASS), b,
                 tol=jnp.array([1e-6, 1e-5], jnp.float32), maxiter=10)
    _, st_ = bicgstab(lambda v: dslash(u, v, MASS), b, tol=1e-6, maxiter=500)
    assert int(st_.verdict) == solvers.CONVERGED


def test_pipecg_breakdown_guard():
    rhs = jnp.ones((24,), jnp.float32)
    _, st_ = pipecg(lambda v: 0.0 * v, rhs, tol=1e-8, maxiter=50)
    assert int(st_.verdict) == solvers.BREAKDOWN
    assert not bool(st_.converged)


def test_mpcg_propagates_inner_verdict(problem):
    u, b = problem
    up, bp = pack_gauge(u), pack_spinor(b)
    op_hi = lambda v: normal_op_packed(up, v, MASS)
    up_lo = up.astype(jnp.bfloat16)
    op_lo = lambda v: normal_op_packed(up_lo, v, MASS)
    rhs = dslash_dagger_packed(up, bp, MASS)
    # bf16 cannot reach tol=1e-30: the outer loop exhausts and the exit
    # classifies the plateau (stagnation once the true residual stops
    # contracting between reliable updates, else maxiter exhaustion)
    _, st_ = mpcg(op_lo, op_hi, rhs, tol=1e-30, inner_tol=5e-2,
                  inner_maxiter=20, max_outer=4)
    assert int(st_.verdict) in (solvers.MAXITER_EXHAUSTED,
                                solvers.STAGNATION)
    assert not bool(st_.converged)
