"""Fault-tolerance paths: SIGTERM checkpoint-and-exit, elastic restore
across mesh shapes, straggler watchdog plumbing."""

import os
import signal
import subprocess
import sys
import time

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _env():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    return env


def test_sigterm_checkpoints_and_exits(tmp_path):
    """A pre-empted trainer (SIGTERM) must write a checkpoint and exit 0,
    and a restarted trainer must resume from it."""
    ck = str(tmp_path / "ck")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.train", "--arch", "glm4-9b",
         "--steps", "400", "--batch", "2", "--seq-len", "32",
         "--ckpt-dir", ck, "--ckpt-every", "1000", "--log-every", "1"],
        env=_env(), stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
        text=True)
    # wait until training is underway, then pre-empt
    t0 = time.time()
    started = False
    lines = []
    while time.time() - t0 < 240:
        line = proc.stdout.readline()
        lines.append(line)
        if "step=3" in line:
            started = True
            break
    assert started, "".join(lines[-20:])
    proc.send_signal(signal.SIGTERM)
    out, _ = proc.communicate(timeout=240)
    assert proc.returncode == 0, out[-2000:]
    assert "SIGTERM received; checkpointed" in out
    steps = [d for d in os.listdir(ck) if d.startswith("step_")]
    assert steps, "no checkpoint written on SIGTERM"

    # resume must pick the checkpoint up
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "glm4-9b",
         "--steps", "8", "--batch", "2", "--seq-len", "32",
         "--ckpt-dir", ck, "--resume", "auto", "--log-every", "1"],
        env=_env(), capture_output=True, text=True, timeout=420)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-1000:]
    assert "resuming from step" in r.stdout


def test_elastic_restore_across_meshes(tmp_path):
    """A checkpoint written from a sharded 8-device run restores bit-exact
    onto a DIFFERENT mesh (elasticity after losing/gaining hardware)."""
    script = r"""
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import configs
from repro.models import steps as S
from repro.optim import AdamWConfig
from repro.checkpoint import save_checkpoint, restore_checkpoint

ck = sys.argv[1]
cfg = configs.get_smoke("glm4-9b")
opt = AdamWConfig()
state = S.init_train_state(cfg, jax.random.PRNGKey(0), opt)

from repro.compat import make_mesh
mesh_a = make_mesh((4, 2), ("data", "model"))
specs = S.state_specs(cfg, jax.eval_shape(lambda: state))
sh_a = jax.tree.map(lambda sp: NamedSharding(mesh_a, sp), specs,
                    is_leaf=lambda x: isinstance(x, P))
state_a = jax.device_put(state, sh_a)
save_checkpoint(ck, 1, state_a)

mesh_b = make_mesh((2, 4), ("data", "model"))
sh_b = jax.tree.map(lambda sp: NamedSharding(mesh_b, sp), specs,
                    is_leaf=lambda x: isinstance(x, P))
restored = restore_checkpoint(ck, 1, jax.eval_shape(lambda: state), sh_b)
for a, b in zip(jax.tree.leaves(state_a), jax.tree.leaves(restored)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print("ELASTIC_OK")
"""
    r = subprocess.run([sys.executable, "-c", script,
                        str(tmp_path / "ck")],
                       env=_env(), capture_output=True, text=True,
                       timeout=420)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "ELASTIC_OK" in r.stdout
