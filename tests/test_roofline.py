"""The §6 streaming-traffic model behind every bw_fraction in the bench
JSONs: closed-form values, monotonicity, and input validation.

``benchmarks/roofline.dslash_intensity`` is the denominator of the
achieved-vs-roofline column gated by ``check_solver_regression.py
--perf`` — a wrong model silently re-scales every committed bandwidth
fraction, so the closed form is pinned here:

    bytes/site/RHS = (144 / N + 48) · dtype_bytes
    flops/site     = 1320
"""

import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.roofline import dslash_intensity  # noqa: E402

from repro.testing import maybe_hypothesis  # noqa: E402

given, settings, st = maybe_hypothesis()


@pytest.mark.parametrize("n_rhs,dtype_bytes,bytes_per_site", [
    (1, 4, (144 + 48) * 4),         # 768: single RHS, f32
    (1, 2, (144 + 48) * 2),         # 384: single RHS, bf16
    (8, 4, (144 / 8 + 48) * 4),     # 264: gauge amortized over 8 RHS
    (8, 2, (144 / 8 + 48) * 2),     # 132
])
def test_closed_form(n_rhs, dtype_bytes, bytes_per_site):
    m = dslash_intensity(n_rhs, dtype_bytes)
    assert m["bytes_per_site"] == pytest.approx(bytes_per_site)
    assert m["flops_per_site"] == 1320.0
    assert m["flops_per_byte"] == pytest.approx(1320.0 / bytes_per_site)
    assert m["n_rhs"] == n_rhs and m["dtype_bytes"] == dtype_bytes


def test_gauge_amortization_limit():
    """As N -> inf only the spinor term survives: 48 reals/site."""
    m = dslash_intensity(10**6, 4)
    assert m["bytes_per_site"] == pytest.approx(48 * 4, rel=1e-3)


def test_invalid_n_rhs():
    for bad in (0, -1):
        with pytest.raises(ValueError, match="n_rhs"):
            dslash_intensity(bad)


@settings(max_examples=50, deadline=None)
@given(st.integers(min_value=1, max_value=4096),
       st.sampled_from([2, 4, 8]))
def test_intensity_monotone_in_n(n, dtype_bytes):
    """Batching strictly increases arithmetic intensity (gauge reads
    amortize; spinor traffic is constant per RHS)."""
    a = dslash_intensity(n, dtype_bytes)
    b = dslash_intensity(n + 1, dtype_bytes)
    assert b["flops_per_byte"] > a["flops_per_byte"]
    assert b["bytes_per_site"] < a["bytes_per_site"]


def test_intensity_monotone_deterministic():
    """Non-hypothesis fallback: monotone over a fixed ladder."""
    vals = [dslash_intensity(n)["flops_per_byte"]
            for n in (1, 2, 4, 8, 16, 32)]
    assert vals == sorted(vals)
    assert all(b > a for a, b in zip(vals, vals[1:]))
