"""Retry/escalation ladder + the defended-solve cost acceptance tests.

The jaxpr-asserted acceptance gate for DESIGN.md §10 lives here: the
defended warm path (taxonomy + verification) costs at most ONE extra
operator application per solve, all of it AFTER the iteration loop, and
adds zero host synchronizations inside the loop body.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import LatticeShape, random_gauge, random_spinor
from repro.core import plan as plan_mod
from repro.core import solvers
from repro.core.resilience import (AttemptRecord, RetryPolicy, SolveFailure,
                                   defended_solve)
from repro.testing import collect_eqns

LAT = LatticeShape(4, 4, 4, 4)
MASS = 0.1
TOL = 1e-6


@pytest.fixture(scope="module")
def problem():
    key = jax.random.PRNGKey(7)
    ku, kb = jax.random.split(key)
    return random_gauge(ku, LAT), random_spinor(kb, LAT)


def _plan(**kw):
    base = dict(operator="eo-schur", backend="reference", solver="cgnr",
                precision="single")
    base.update(kw)
    return plan_mod.SolverPlan(**base)


# -- the ladder -------------------------------------------------------------


def test_ladder_escalates_precision_then_backend():
    plan = _plan(backend="pallas", precision="mixed", operator="full")
    rungs = RetryPolicy().ladder(plan)
    assert [(r.precision, r.backend) for r in rungs] == [
        ("mixed", "pallas"), ("single", "pallas"),
        ("mixed", "reference"), ("single", "reference")]


def test_ladder_is_identity_for_reference_single():
    plan = _plan()
    assert RetryPolicy().ladder(plan) == (plan,)


def test_ladder_respects_disabled_rungs():
    plan = _plan(backend="pallas", precision="mixed", operator="full")
    rungs = RetryPolicy(escalate_precision=False,
                        fallback_backend=False).ladder(plan)
    assert rungs == (plan,)


def test_retry_policy_rejects_zero_attempts():
    with pytest.raises(ValueError, match="max_attempts"):
        RetryPolicy(max_attempts=0)


# -- defended_solve ---------------------------------------------------------


def test_defended_solve_healthy_is_one_attempt(problem):
    u, b = problem
    x, st, attempts = defended_solve(_plan(), u, b, MASS, tol=TOL,
                                     maxiter=500)
    assert len(attempts) == 1
    assert attempts[0].verdict == "converged"
    assert not attempts[0].restarted
    assert bool(np.asarray(st.verified).all())
    x_direct, _ = plan_mod.solve(_plan(), u, b, MASS, tol=TOL, maxiter=500)
    assert np.array_equal(np.asarray(x), np.asarray(x_direct))


def test_defended_solve_restart_accumulates_progress(problem):
    """A maxiter-starved first attempt leaves a finite partial iterate;
    the retry restarts from it (defect correction) and the ACCUMULATED
    solution verifies against the original system."""
    u, b = problem
    _, st_full = plan_mod.solve(_plan(), u, b, MASS, tol=TOL, maxiter=500)
    need = int(st_full.iterations)
    starve = max(need // 2, 1)
    x, st, attempts = defended_solve(
        _plan(), u, b, MASS, tol=TOL, maxiter=starve,
        policy=RetryPolicy(max_attempts=4))
    assert len(attempts) >= 2
    assert attempts[0].verdict == "maxiter_exhausted"
    assert attempts[1].restarted
    assert attempts[-1].verified
    assert bool(np.asarray(st.verified).all())
    # the defect-correction rungs each ran within the starved budget —
    # progress came from accumulation, not from one long solve
    assert all(a.iterations <= starve for a in attempts)


def test_defended_solve_raises_structured_failure(problem):
    u, b = problem
    bad = jnp.asarray(b).at[(0,) * b.ndim].set(jnp.nan)
    with pytest.raises(SolveFailure) as exc:
        defended_solve(_plan(), u, bad, MASS, tol=TOL, maxiter=50,
                       policy=RetryPolicy(max_attempts=2))
    assert exc.value.verdict == "nonfinite"
    assert len(exc.value.attempts) == 2
    assert all(isinstance(a, AttemptRecord) and not a.verified
               for a in exc.value.attempts)


def test_defended_solve_never_returns_unverified(problem):
    """Exhaustion raises — a bad x is never handed back silently."""
    u, b = problem
    with pytest.raises(SolveFailure):
        defended_solve(_plan(), u, b, MASS, tol=1e-12, maxiter=2,
                       policy=RetryPolicy(max_attempts=1,
                                          restart_from_iterate=False))


# -- cost acceptance: <= 1 extra matvec, zero in-loop additions -------------


def _while_eqns(jaxpr):
    return [e for e in collect_eqns(jaxpr) if e.primitive.name == "while"]


def _eqn_signature(jaxpr):
    """Flat (primitive, out-shapes) fingerprint of a jaxpr, recursively."""
    return [(e.primitive.name,
             tuple(tuple(getattr(v.aval, "shape", ())) for v in e.outvars))
            for e in collect_eqns(jaxpr)]


@pytest.mark.parametrize("operator", ["full", "eo-schur"])
def test_defended_warm_path_costs_at_most_one_matvec(problem, operator):
    """Jaxpr-asserted acceptance gate: verification leaves every iteration
    loop UNTOUCHED (bitwise-identical while bodies with verify on/off) and
    its epilogue is at most one operator application of extra work."""
    u, b = problem
    plan = _plan(operator=operator)
    j_on = jax.make_jaxpr(
        lambda uu, bb: plan_mod.solve(plan, uu, bb, MASS, tol=TOL,
                                      maxiter=50))(u, b)
    j_off = jax.make_jaxpr(
        lambda uu, bb: plan_mod.solve(plan, uu, bb, MASS, tol=TOL,
                                      maxiter=50, verify=False))(u, b)
    w_on, w_off = _while_eqns(j_on), _while_eqns(j_off)
    assert len(w_on) == len(w_off) >= 1
    for eq_on, eq_off in zip(w_on, w_off):
        assert (_eqn_signature(eq_on.params["body_jaxpr"])
                == _eqn_signature(eq_off.params["body_jaxpr"]))
    # epilogue budget: one application of the FULL operator (the
    # verification oracle) plus O(1) scalar reductions/comparisons.  A
    # second matvec would roughly double the delta — the 1.5x ceiling
    # catches that while absorbing the cheap gate arithmetic.
    from repro.core.operators import dslash_g
    n_on = len(_eqn_signature(j_on))
    n_off = len(_eqn_signature(j_off))
    n_matvec = len(_eqn_signature(
        jax.make_jaxpr(lambda uu, v: dslash_g(uu, v, MASS))(u, b)))
    assert n_on > n_off
    assert n_on - n_off <= 1.5 * n_matvec


def test_defended_warm_path_adds_no_host_syncs(problem):
    """No callback/infeed/outfeed primitive anywhere in the defended
    solve's jaxpr: taxonomy + verification stay on-device end to end."""
    u, b = problem
    j = jax.make_jaxpr(
        lambda uu, bb: plan_mod.solve(_plan(), uu, bb, MASS, tol=TOL,
                                      maxiter=50))(u, b)
    host_prims = [e.primitive.name for e in collect_eqns(j)
                  if any(tag in e.primitive.name
                         for tag in ("callback", "infeed", "outfeed",
                                     "host", "debug"))]
    assert host_prims == []


def test_taxonomy_survives_jit_of_plan_solve(problem):
    """The verdict/verified fields come out of a jitted plan.solve as
    concrete per-solve values (the serving layer jits the plan callable)."""
    u, b = problem
    plan = _plan()
    f = jax.jit(lambda uu, bb: plan_mod.solve(plan, uu, bb, MASS, tol=TOL,
                                              maxiter=500))
    _, st = f(u, b)
    assert int(st.verdict) == solvers.CONVERGED
    assert bool(st.verified)
    assert float(st.true_residual_norm2) >= 0.0


def test_maxiter_exhaustion_propagates_through_plan_solve(problem):
    """Satellite: a starved plan.solve reports MAXITER_EXHAUSTED and
    verification correctly refuses the partial iterate."""
    u, b = problem
    _, st = plan_mod.solve(_plan(), u, b, MASS, tol=1e-10, maxiter=3)
    assert int(st.verdict) == solvers.MAXITER_EXHAUSTED
    assert not bool(st.verified)
    assert not bool(st.converged)


def test_plans_are_replaceable_dataclasses():
    """The ladder relies on dataclasses.replace producing valid plans."""
    plan = _plan(backend="pallas", operator="full")
    again = dataclasses.replace(plan, backend="reference")
    assert again.backend == "reference"
    assert again.operator == plan.operator


# -- the ladder on a 2x2x2 mesh (subprocess; 8 fake CPU devices) ------------


def test_defended_solve_on_mesh_reaches_backend_fallback_rung():
    """The retry ladder works unchanged on a sharded plan: a starved
    pallas attempt exhausts, the defect-correction retry runs on the
    backend-fallback REFERENCE rung (same 2x2x2 mesh), and the
    accumulated solution verifies against the original system."""
    import os
    import subprocess
    import sys

    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.experimental.mesh_utils import create_device_mesh
from jax.sharding import Mesh
from repro.core import LatticeShape, random_gauge, random_spinor
from repro.core import plan as plan_mod
from repro.core.resilience import RetryPolicy, defended_solve

lat = LatticeShape(4, 4, 4, 8)
key = jax.random.PRNGKey(7)
ku, kb = jax.random.split(key)
u, b = random_gauge(ku, lat), random_spinor(kb, lat)
mesh = Mesh(create_device_mesh((2, 2, 2)), ("pod", "data", "model"))
plan = plan_mod.SolverPlan(operator="eo-schur", solver="cgnr",
                           backend="pallas", mesh=mesh)
_, st_full = plan_mod.solve(plan, u, b, 0.1, tol=1e-6, maxiter=500)
need = int(st_full.iterations)
starve = max(need // 2, 1)
x, st, attempts = defended_solve(plan, u, b, 0.1, tol=1e-6,
                                 maxiter=starve,
                                 policy=RetryPolicy(max_attempts=4))
backends = [a.plan_desc.split("/")[2] for a in attempts]
assert backends[0] == "pallas", backends
assert attempts[0].verdict == "maxiter_exhausted", attempts
assert "reference" in backends[1:], backends
assert attempts[-1].verified, attempts
assert bool(np.asarray(st.verified).all())
assert all(a.iterations <= starve for a in attempts), attempts
print("LADDER=" + ",".join(backends))
print("SHARDED_DEFENDED_OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    assert "SHARDED_DEFENDED_OK" in r.stdout
