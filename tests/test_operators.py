"""The operator registry: site-local physics decoupled from hop transport.

Covers the registry surface (round-trip, did-you-mean validation), the
SiteTerm algebra, and the acceptance contract for the second operator
family: twisted-mass EO-Schur solves (single, batched, sharded) match
their reference-backend counterparts to <= 1e-5 per RHS, mu -> 0 reduces
BITWISE to Wilson on both backends, and ``schur_normal_op`` stays exactly
4 kernel launches with zero standalone full-field passes for BOTH
families."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (LatticeShape, SolverPlan, pack_gauge, pack_spinor,
                        random_gauge, random_spinor, solve_plan, split_eo,
                        split_eo_gauge)
from repro.core.lattice import field_dot
from repro.core.operators import (LatticeOperator, SiteTerm,
                                  apply_igamma5_packed, dslash_dagger_g,
                                  dslash_g, get_operator, operator_names,
                                  register_operator, schur_dagger_g,
                                  schur_op_g)
from repro.testing import full_field_passes, pallas_call_eqns

LAT = LatticeShape(2, 4, 4, 4)  # small: interpret-mode trace cost
MASS = 0.1
MU = 0.3
TOL = 1e-6


@pytest.fixture(scope="module")
def problem():
    key = jax.random.PRNGKey(7)
    ku, kb = jax.random.split(key)
    return random_gauge(ku, LAT), random_spinor(kb, LAT)


@pytest.fixture(scope="module")
def eo_packed(problem):
    u, b = problem
    u_e, u_o = split_eo_gauge(u)
    p_e, _ = split_eo(b)
    return pack_gauge(u_e), pack_gauge(u_o), pack_spinor(p_e)


def _rel_res_tm(u, x, b):
    r = dslash_g(u, x, MASS, twist=MU) - b
    return float(jnp.linalg.norm(r.ravel()) / jnp.linalg.norm(b.ravel()))


def _tm_plan(**kw):
    kw.setdefault("mu", MU)
    return SolverPlan(operator="eo-schur", operator_family="twisted-mass",
                      **kw)


# ---------------------------------------------------------------------------
# Registry surface
# ---------------------------------------------------------------------------


def test_registry_round_trip():
    names = operator_names()
    assert {"wilson", "twisted-mass"} <= set(names)
    for name in names:
        spec = get_operator(name)
        assert spec.name == name
        assert get_operator(spec.name) is spec
    assert get_operator("wilson").params == ()
    assert get_operator("twisted-mass").params == ("mu",)


def test_registry_rejects_duplicate_names():
    with pytest.raises(ValueError, match="already registered"):
        register_operator(LatticeOperator(
            name="wilson", description="dup", params=(),
            make_site_term=lambda mass, r: SiteTerm(mass + 4.0 * r)))


def test_unknown_operator_family_suggests_registered_names():
    with pytest.raises(ValueError) as e:
        get_operator("twisted_mass")
    msg = str(e.value)
    assert "did you mean 'twisted-mass'" in msg
    for name in operator_names():  # the full registered list is shown
        assert repr(name) in msg
    # the same validation fires from the plan surface
    with pytest.raises(ValueError, match="twisted-mass"):
        SolverPlan(operator_family="twisted_mass")


def test_unknown_backend_suggests_allowed_names():
    with pytest.raises(ValueError) as e:
        SolverPlan(backend="palas")
    msg = str(e.value)
    assert "did you mean 'pallas'" in msg and "'reference'" in msg


def test_mu_requires_a_family_that_declares_it():
    with pytest.raises(ValueError, match="twisted-mass"):
        SolverPlan(mu=0.3)  # wilson has no 'mu' site parameter
    # declared family: fine, and the twist is exposed to the transport
    assert _tm_plan().twist == MU
    assert SolverPlan().twist == 0.0
    assert _tm_plan(mu=0.0).twist == 0.0


def test_plan_site_term_comes_from_registry():
    site = _tm_plan().site_term(MASS)
    assert site.scale == pytest.approx(MASS + 4.0) and site.twist == MU
    w = SolverPlan().site_term(MASS)
    assert w.scale == pytest.approx(MASS + 4.0) and w.twist == 0.0


def test_family_with_nonstandard_scale_fails_loudly(problem):
    """The transport kernels fold the site scale mass+4r at trace time,
    so a registered family declaring any OTHER scale must be rejected at
    resolve time — loudly, never silently solved with the Wilson scale."""
    name = "test-bad-scale"
    try:
        get_operator(name)
    except ValueError:
        register_operator(LatticeOperator(
            name=name, description="scale contract probe", params=(),
            make_site_term=lambda mass, r: SiteTerm(mass + 5.0 * r, 0.0)))
    u, b = problem
    with pytest.raises(NotImplementedError, match="scale"):
        solve_plan(SolverPlan(operator="eo-schur", operator_family=name),
                   u, b, MASS, tol=TOL, maxiter=10)


# ---------------------------------------------------------------------------
# SiteTerm algebra
# ---------------------------------------------------------------------------


def test_site_term_apply_solve_round_trip(problem):
    _, b = problem
    site = SiteTerm(MASS + 4.0, MU)
    # natural complex layout
    v = split_eo(b)[0]
    np.testing.assert_allclose(np.asarray(site.solve(site.apply(v))),
                               np.asarray(v), atol=1e-6)
    # packed real layout (dispatch on dtype) round-trips too
    p = pack_spinor(v)
    np.testing.assert_allclose(np.asarray(site.solve(site.apply(p))),
                               np.asarray(p), atol=1e-6)
    # packed apply agrees with the natural-layout definition
    nat = site.apply(v)
    np.testing.assert_allclose(np.asarray(site.apply(p)),
                               np.asarray(pack_spinor(nat)), atol=1e-6)
    # dagger flips the twist; inverse is analytic
    assert site.dag.twist == -MU and site.inv.twist == pytest.approx(
        -MU / ((MASS + 4.0) ** 2 + MU ** 2))


def test_wilson_site_term_solve_is_bitwise_division(problem):
    _, b = problem
    site = SiteTerm(MASS + 4.0, 0.0)
    v = split_eo(b)[0]
    np.testing.assert_array_equal(np.asarray(site.solve(v)),
                                  np.asarray(v / (MASS + 4.0)))


def test_igamma5_packed_matches_natural(problem):
    _, b = problem
    p = pack_spinor(b)
    np.testing.assert_allclose(np.asarray(apply_igamma5_packed(p)),
                               np.asarray(pack_spinor(
                                   1j * b * jnp.asarray(
                                       [1, 1, -1, -1],
                                       b.dtype)[:, None])), atol=1e-6)


# ---------------------------------------------------------------------------
# Twisted-mass operator identities (natural-layout oracles)
# ---------------------------------------------------------------------------


def test_twisted_dagger_is_the_adjoint(problem):
    """<q, D p> == <D^dag q, p> for the twisted full AND Schur operators
    (D is NOT gamma5-hermitian for mu != 0 — the dagger flips mu)."""
    u, b = problem
    q = random_spinor(jax.random.PRNGKey(3), LAT)
    lhs = complex(field_dot(q, dslash_g(u, b, MASS, twist=MU)))
    rhs = complex(field_dot(dslash_dagger_g(u, q, MASS, twist=MU), b))
    assert abs(lhs - rhs) < 1e-3 * abs(lhs)
    u_e, u_o = split_eo_gauge(u)
    b_e, q_e = split_eo(b)[0], split_eo(q)[0]
    lhs = complex(field_dot(q_e, schur_op_g(u_e, u_o, b_e, MASS, twist=MU)))
    rhs = complex(field_dot(schur_dagger_g(u_e, u_o, q_e, MASS, twist=MU),
                            b_e))
    assert abs(lhs - rhs) < 1e-3 * abs(lhs)


# ---------------------------------------------------------------------------
# Twisted-mass Pallas kernels vs the reference backend
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dagger", [False, True], ids=["plain", "dagger"])
def test_twisted_schur_kernel_matches_reference(eo_packed, dagger):
    from repro.kernels.wilson_dslash import ops as wops
    from repro.kernels.wilson_dslash.ref import schur_op_ref
    upe, upo, ppe = eo_packed
    out = wops.schur_op(upe, upo, ppe, MASS, twist=MU, dagger=dagger,
                        interpret=True)
    ref = schur_op_ref(upe, upo, ppe, MASS, twist=MU, dagger=dagger)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_twisted_batched_schur_matches_looped(eo_packed):
    """The gauge-amortized batched kernels extend to the second family
    UNCHANGED: each batched slice equals its single-RHS launch.  (Unlike
    Wilson — whose batched-equals-looped contract IS bitwise and stays
    so, see test_kernels.py — the twisted epilogue's longer multiply-add
    chain lets XLA pick fma contractions differently between the batched
    and unbatched compilations, so this family's contract is ulp-level.)"""
    from repro.kernels.wilson_dslash import ops as wops
    upe, upo, ppe = eo_packed
    key = jax.random.PRNGKey(11)
    batch = jnp.stack([ppe * (i + 1.0) for i in range(3)]) \
        + jax.random.normal(key, (3,) + ppe.shape, jnp.float32)
    out = wops.schur_op(upe, upo, batch, MASS, twist=MU, interpret=True)
    looped = jnp.stack([wops.schur_op(upe, upo, batch[i], MASS, twist=MU,
                                      interpret=True) for i in range(3)])
    np.testing.assert_allclose(np.asarray(out), np.asarray(looped),
                               atol=2e-6)


@pytest.mark.parametrize("twist", [0.0, MU], ids=["wilson", "twisted"])
@pytest.mark.parametrize("n_rhs", [None, 2], ids=["single", "batched"])
def test_schur_normal_op_is_4_launches_for_both_families(eo_packed, twist,
                                                         n_rhs):
    """Acceptance: A_hat is EXACTLY 4 kernel launches with zero standalone
    full-field passes for BOTH operator families — the site term (and its
    twist) rides the kernel epilogues, never a separate pass."""
    from repro.kernels.wilson_dslash import ops as wops
    upe, upo, ppe = eo_packed
    v = ppe if n_rhs is None else jnp.stack([ppe] * n_rhs)
    jx = jax.make_jaxpr(
        lambda a, b, w: wops.schur_normal_op(a, b, w, MASS, twist=twist,
                                             interpret=True))(upe, upo, v)
    assert len(pallas_call_eqns(jx)) == 4
    assert full_field_passes(jx, v.size) == []
    if n_rhs is not None:  # per-RHS halves are never materialized either
        assert full_field_passes(jx, v.size // n_rhs) == []


# ---------------------------------------------------------------------------
# End-to-end: twisted-mass EO-Schur solves on every path
# ---------------------------------------------------------------------------


def test_mu_zero_reduces_bitwise_to_wilson(problem):
    """operator_family='twisted-mass' with mu=0 IS Wilson, bitwise, on
    both backends: every twist gate is a trace-time float compare, so the
    emitted program is identical."""
    u, b = problem
    for backend in ("reference", "pallas"):
        pw = SolverPlan(operator="eo-schur", backend=backend,
                        interpret=True)
        pt = _tm_plan(mu=0.0, backend=backend, interpret=True)
        xw, sw = solve_plan(pw, u, b, MASS, tol=TOL, maxiter=1000)
        xt, st = solve_plan(pt, u, b, MASS, tol=TOL, maxiter=1000)
        np.testing.assert_array_equal(np.asarray(xw), np.asarray(xt))
        assert int(sw.iterations) == int(st.iterations)


def test_twisted_eo_solve_pallas_matches_reference(problem):
    """Single-RHS twisted EO-Schur: the Pallas fast path reproduces the
    reference backend to <= 1e-5 and solves the twisted system."""
    u, b = problem
    x_ref, st_ref = solve_plan(_tm_plan(), u, b, MASS, tol=TOL,
                               maxiter=1000)
    x_pal, st_pal = solve_plan(_tm_plan(backend="pallas", interpret=True),
                               u, b, MASS, tol=TOL, maxiter=1000)
    assert bool(st_ref.converged) and bool(st_pal.converged)
    assert _rel_res_tm(u, x_ref, b) < 1e-5
    assert _rel_res_tm(u, x_pal, b) < 1e-5
    assert abs(int(st_pal.iterations) - int(st_ref.iterations)) <= 1
    assert float(jnp.max(jnp.abs(x_pal - x_ref))) <= 1e-5


def test_twisted_batched_solve_matches_reference_singles(problem):
    """Batched (N=4) twisted EO-Schur on the Pallas path: every RHS
    matches its independent reference-backend solve to <= 1e-5 (the
    acceptance bound), and its own single-RHS Pallas solve to ulp-level
    (same fma-contraction caveat as the kernel test above — the WILSON
    batched-equals-looped contract remains bitwise in test_eo.py)."""
    u, _ = problem
    n = 4
    kb = jax.random.PRNGKey(17)
    b = jnp.stack([random_spinor(jax.random.fold_in(kb, i), LAT)
                   for i in range(n)])
    xb, stb = solve_plan(_tm_plan(backend="pallas", nrhs=n, interpret=True),
                         u, b, MASS, tol=TOL, maxiter=1000)
    assert stb.converged.shape == (n,) and bool(jnp.all(stb.converged))
    for i in range(n):
        xi, _ = solve_plan(_tm_plan(), u, b[i], MASS, tol=TOL, maxiter=1000)
        assert float(jnp.max(jnp.abs(xb[i] - xi))) <= 1e-5
        assert _rel_res_tm(u, xb[i], b[i]) < 1e-5
    x0, st0 = solve_plan(_tm_plan(backend="pallas", interpret=True),
                         u, b[0], MASS, tol=TOL, maxiter=1000)
    np.testing.assert_allclose(np.asarray(xb[0]), np.asarray(x0),
                               atol=1e-5)
    assert int(st0.iterations) <= int(stb.iterations)


def test_twisted_mixed_precision_composes(problem):
    """The reliable-update mixed-precision Schur solve is operator-
    agnostic: bf16 inner iterations on the twisted operator still reach
    the f32 tolerance."""
    u, b = problem
    x, st = solve_plan(_tm_plan(precision="mixed"), u, b, MASS, tol=TOL,
                       maxiter=1000)
    assert bool(st.converged)
    assert _rel_res_tm(u, x, b) < 1e-5
    assert int(st.iterations) >= 2 * int(st.outer_iterations)


# ---------------------------------------------------------------------------
# Sharded: the 8-device mesh runs the second family unchanged
# ---------------------------------------------------------------------------

_SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
from repro.compat import make_mesh
from repro.core import (LatticeShape, SolverPlan, random_gauge,
                        random_spinor, solve_plan)
from repro.core.operators import dslash_g

mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
lat = LatticeShape(4, 4, 4, 8)
m, mu, tol, N = 0.1, 0.3, 1e-6, 2
ku, kb = jax.random.split(jax.random.PRNGKey(7))
u = random_gauge(ku, lat)
b = jnp.stack([random_spinor(jax.random.fold_in(kb, i), lat)
               for i in range(N)])
psh = SolverPlan(operator="eo-schur", operator_family="twisted-mass",
                 mu=mu, solver="pipecg", nrhs=N, mesh=mesh)
xsh, stsh = solve_plan(psh, u, b, m, tol=tol, maxiter=500)
p1 = SolverPlan(operator="eo-schur", operator_family="twisted-mass",
                mu=mu, nrhs=N)
x1, _ = solve_plan(p1, u, b, m, tol=tol, maxiter=500)
res = jax.vmap(lambda xx, bv: dslash_g(u, xx, m, twist=mu) - bv)(xsh, b)
rels = (jnp.linalg.norm(res.reshape(N, -1), axis=1)
        / jnp.linalg.norm(b.reshape(N, -1), axis=1))
out = {"all_converged": bool(jnp.all(stsh.converged)),
       "iters": int(stsh.iterations),
       "rhs_iters": [int(v) for v in stsh.rhs_iterations],
       "max_rel_res": float(jnp.max(rels)),
       "max_dev_vs_single_device": float(jnp.max(jnp.abs(xsh - x1)))}
print("RESULT" + json.dumps(out))
"""


@pytest.fixture(scope="module")
def sharded_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", _SHARDED_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    line = [ln for ln in r.stdout.splitlines() if ln.startswith("RESULT")][-1]
    return json.loads(line[len("RESULT"):])


def test_sharded_twisted_solve_matches_single_device(sharded_results):
    """Acceptance: the sharded (2x2x2 mesh, one-psum pipelined) twisted
    batched Schur solve converges per RHS and matches the single-device
    reference solve to <= 1e-5 — the halo transport never looked at the
    operator family."""
    r = sharded_results
    assert r["all_converged"], r
    assert r["max_rel_res"] < 1e-4, r
    assert r["max_dev_vs_single_device"] <= 1e-5, r
    assert max(r["rhs_iters"]) == r["iters"]
