"""Even-odd (Schur) preconditioned solves: equivalence with plain CGNR,
iteration savings, the mixed-precision composition, and the Pallas fast
path (parity kernels + fused CG engine)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (LatticeShape, cgnr, dslash, dslash_dagger,
                        eo_operators, eo_operators_packed, random_gauge,
                        random_spinor, solve_wilson_eo,
                        solve_wilson_eo_batched, solve_wilson_eo_mp,
                        split_eo, unit_gauge)
from repro.core import solvers
from repro.core.lattice import field_norm2_batched

LAT = LatticeShape(4, 4, 4, 4)  # the 4^4 acceptance lattice
MASS = 0.1
TOL = 1e-6

_BASELINE = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                         "BENCH_solvers_baseline.json")


@pytest.fixture(scope="module")
def problem():
    key = jax.random.PRNGKey(7)
    ku, kb = jax.random.split(key)
    return random_gauge(ku, LAT), random_spinor(kb, LAT)


def _rel_res(u, x, b):
    r = dslash(u, x, MASS) - b
    return float(jnp.linalg.norm(r.ravel()) / jnp.linalg.norm(b.ravel()))


def test_cgnr_eo_matches_plain_cgnr(problem):
    """Reconstructed full-lattice solution agrees with plain CGNR's to the
    solve tolerance, in at most 60% of the inner iterations."""
    u, b = problem
    x_full, st_full = cgnr(lambda v: dslash(u, v, MASS),
                           lambda v: dslash_dagger(u, v, MASS), b,
                           tol=TOL, maxiter=1000)
    x_eo, st_eo = solve_wilson_eo(u, b, MASS, tol=TOL, maxiter=1000)
    assert bool(st_full.converged) and bool(st_eo.converged)
    assert _rel_res(u, x_full, b) < 1e-5
    assert _rel_res(u, x_eo, b) < 1e-5
    # both solve the same nonsingular system to tolerance
    assert jnp.max(jnp.abs(x_eo - x_full)) < 1e-4
    # the Schur system is better conditioned AND half the size
    assert int(st_eo.iterations) <= 0.6 * int(st_full.iterations)


def test_eo_mixed_precision_composes(problem):
    """Even-odd inner solve in bf16 real pairs + f32 reliable updates still
    converges to the f32 tolerance (paper's two optimizations composed)."""
    u, b = problem
    x, st = solve_wilson_eo_mp(u, b, MASS, tol=TOL, inner_tol=5e-2,
                               inner_maxiter=100, max_outer=40)
    assert bool(st.converged)
    assert _rel_res(u, x, b) < 1e-5
    # bulk of the work happened in the low-precision inner iterations
    assert int(st.iterations) >= 2 * int(st.outer_iterations)


def test_eo_solve_non_cubic_lattice():
    """Anisotropic (all-even) extents solve correctly too."""
    lat = LatticeShape(2, 4, 2, 8)
    key = jax.random.PRNGKey(11)
    ku, kb = jax.random.split(key)
    u, b = random_gauge(ku, lat), random_spinor(kb, lat)
    x, st = solve_wilson_eo(u, b, MASS, tol=TOL, maxiter=1000)
    assert bool(st.converged)
    assert _rel_res(u, x, b) < 1e-5


def test_eo_pallas_fast_path_matches_reference():
    """The Pallas fast path (parity stencil kernels + fused CG triads)
    reproduces the reference Schur solve: same iterates, same solution.

    Small lattice: the interpret-mode kernels trace one program per grid
    point, so compile time scales with T * Z/BZ."""
    lat = LatticeShape(2, 4, 4, 4)
    key = jax.random.PRNGKey(5)
    ku, kb = jax.random.split(key)
    u, b = random_gauge(ku, lat), random_spinor(kb, lat)
    x_ref, st_ref = solve_wilson_eo(u, b, MASS, tol=TOL, maxiter=1000)
    x_pal, st_pal = solve_wilson_eo(u, b, MASS, tol=TOL, maxiter=1000,
                                    use_pallas=True)
    assert bool(st_ref.converged) and bool(st_pal.converged)

    def rel(x):
        r = dslash(u, x, MASS) - b
        return float(jnp.linalg.norm(r.ravel()) / jnp.linalg.norm(b.ravel()))

    assert rel(x_pal) < 1e-5
    # CG in the packed real representation is the SAME Krylov iteration
    assert abs(int(st_pal.iterations) - int(st_ref.iterations)) <= 1
    assert float(jnp.max(jnp.abs(x_pal - x_ref))) < 1e-4


def test_eo_iteration_count_vs_committed_baseline(problem):
    """Blocking CI guard: the 4^4 smoke solve — reference AND Pallas fast
    path — must not take more iterations than the committed
    BENCH_solvers_baseline.json (same seed/mass/tol as
    benchmarks/bench_solvers.py's eo_smoke entry)."""
    with open(_BASELINE) as f:
        base = json.load(f)["eo_smoke"]
    # the baseline only guards THIS problem; a drifted baseline is an error
    assert base["lattice"] == str(LAT)
    assert (base["mass"], base["tol"], base["seed"]) == (MASS, TOL, 7)
    u, b = problem
    _, st = solve_wilson_eo(u, b, MASS, tol=TOL, maxiter=1000)
    assert bool(st.converged)
    assert int(st.iterations) <= int(base["cgnr_eo_iters"]) + 2
    _, st_pal = solve_wilson_eo(u, b, MASS, tol=TOL, maxiter=1000,
                                use_pallas=True)
    assert bool(st_pal.converged)
    assert (int(st_pal.iterations)
            <= int(base["cgnr_eo_pallas_iters"]) + 2)


def test_eo_operators_reject_odd_extent():
    """Odd periodic T/Z/Y extents break bipartiteness and are refused."""
    lat = LatticeShape(3, 2, 2, 4)
    key = jax.random.PRNGKey(13)
    ku, kb = jax.random.split(key)
    u, b = random_gauge(ku, lat), random_spinor(kb, lat)
    with pytest.raises(AssertionError, match="bipartite"):
        solve_wilson_eo(u, b, MASS, tol=TOL, maxiter=10)


def test_eo_packed_path_rejects_r_not_one():
    """The packed/Pallas path supports r = 1 ONLY (rank-2 projectors are
    baked into the kernels' trace-time tables): any other r must raise a
    documented NotImplementedError, while the natural-layout path solves
    the r != 1 system fine."""
    lat = LatticeShape(2, 2, 2, 4)
    key = jax.random.PRNGKey(19)
    ku, kb = jax.random.split(key)
    u, b = random_gauge(ku, lat), random_spinor(kb, lat)
    with pytest.raises(NotImplementedError, match="r=1"):
        eo_operators_packed(u, MASS, r=0.5)
    with pytest.raises(NotImplementedError, match="r=1"):
        solve_wilson_eo(u, b, MASS, r=0.5, tol=TOL, maxiter=10,
                        use_pallas=True)
    # the restriction is the packed path's, not the decomposition's
    x, st = solve_wilson_eo(u, b, MASS, r=0.5, tol=TOL, maxiter=1000,
                            use_pallas=False)
    assert bool(st.converged)
    res = dslash(u, x, MASS, r=0.5) - b
    assert float(jnp.linalg.norm(res.ravel())
                 / jnp.linalg.norm(b.ravel())) < 1e-5


# ---------------------------------------------------------------------------
# Multi-RHS batched solves (gauge-amortized matvec + convergence masking)
# ---------------------------------------------------------------------------

BATCH_LAT = LatticeShape(2, 4, 4, 4)  # small: interpret-mode trace cost


@pytest.fixture(scope="module")
def batched_problem():
    key = jax.random.PRNGKey(5)
    ku, kb = jax.random.split(key)
    u = random_gauge(ku, BATCH_LAT)
    b = jnp.stack([random_spinor(jax.random.fold_in(kb, i), BATCH_LAT)
                   for i in range(3)])
    return u, b


@pytest.mark.parametrize("use_pallas", [False, True], ids=["ref", "pallas"])
def test_batched_solve_bitwise_matches_looped_singles(batched_problem,
                                                      use_pallas):
    """An N-RHS batched solve returns, for every RHS, BITWISE the iterate
    of its independent single-RHS solve: identical Krylov scalars while
    all systems are active, and an exact freeze (masked alpha=0 update,
    gated direction) from each system's own convergence point on."""
    u, b = batched_problem
    n = b.shape[0]
    xb, stb = solve_wilson_eo_batched(u, b, MASS, tol=TOL, maxiter=1000,
                                      use_pallas=use_pallas)
    assert stb.converged.shape == (n,) and bool(jnp.all(stb.converged))
    assert stb.residual_norm2.shape == (n,)
    iters = []
    for i in range(n):
        xi, sti = solve_wilson_eo(u, b[i], MASS, tol=TOL, maxiter=1000,
                                  use_pallas=use_pallas)
        np.testing.assert_array_equal(np.asarray(xb[i]), np.asarray(xi))
        iters.append(int(sti.iterations))
    # the masked loop runs exactly as long as the slowest system
    assert int(stb.iterations) == max(iters)
    for i in range(n):
        assert _rel_res(u, xb[i], b[i]) < 1e-5


@pytest.mark.parametrize("use_pallas", [False, True], ids=["ref", "pallas"])
def test_batched_mask_freezes_easy_rhs(use_pallas):
    """A deliberately easy RHS (free-field zero-momentum eigenmode: the
    constant spinor is an exact eigenvector of the unit-gauge Schur
    operator) mixed with a hard random RHS converges within ~1 iteration
    and stays FROZEN while the hard one iterates on."""
    u = unit_gauge(BATCH_LAT)
    easy = jnp.ones(BATCH_LAT.dims + (4, 3), jnp.complex64)
    hard = random_spinor(jax.random.PRNGKey(9), BATCH_LAT)
    b = jnp.stack([easy, hard])
    x_easy, st_easy = solve_wilson_eo(u, easy, MASS, tol=TOL, maxiter=1000,
                                      use_pallas=use_pallas)
    x_hard, st_hard = solve_wilson_eo(u, hard, MASS, tol=TOL, maxiter=1000,
                                      use_pallas=use_pallas)
    assert int(st_easy.iterations) <= 2 < int(st_hard.iterations)
    xb, stb = solve_wilson_eo_batched(u, b, MASS, tol=TOL, maxiter=1000,
                                      use_pallas=use_pallas)
    assert bool(jnp.all(stb.converged))
    assert int(stb.iterations) == int(st_hard.iterations)
    # the easy system froze at ITS early convergence point — bitwise the
    # single-solve result, not a further-iterated one
    np.testing.assert_array_equal(np.asarray(xb[0]), np.asarray(x_easy))
    np.testing.assert_array_equal(np.asarray(xb[1]), np.asarray(x_hard))


def test_batched_trace_residual_history_freezes_after_convergence():
    """cg_trace(batched=True, tol=...) per-RHS histories: once a system
    crosses its limit its recorded ||r||² stays EXACTLY flat (the masked
    update recomputes the same frozen residual), and the easy system
    crosses strictly earlier than the hard one."""
    u = unit_gauge(BATCH_LAT)
    easy = jnp.ones(BATCH_LAT.dims + (4, 3), jnp.complex64)
    hard = random_spinor(jax.random.PRNGKey(9), BATCH_LAT)
    ops = eo_operators(u, MASS)
    b_e, b_o = jax.vmap(split_eo)(jnp.stack([easy, hard]))
    b_hat = b_e - jax.vmap(ops.d_eo)(ops.m_inv(b_o))
    rhs = jax.vmap(ops.dhat_dag)(b_hat)
    a_hat = jax.vmap(lambda v: ops.dhat_dag(ops.dhat(v)))
    _, hist = solvers.cg_trace(a_hat, rhs, iters=12, batched=True, tol=TOL)
    hist = np.asarray(hist)
    assert hist.shape == (12, 2)
    limit = (TOL ** 2) * np.asarray(field_norm2_batched(rhs))
    crossings = []
    for i in range(2):
        below = np.nonzero(hist[:, i] <= limit[i])[0]
        assert below.size, f"RHS {i} never converged in the trace window"
        k0 = below[0]
        crossings.append(k0)
        assert np.all(hist[k0:, i] == hist[k0, i]), (
            f"RHS {i} kept moving after its convergence at iter {k0}")
    assert crossings[0] < crossings[1]


def test_eo_mp_pallas_fast_path(batched_problem):
    """solve_wilson_eo_mp(use_pallas=True): the bf16-inner mixed-precision
    solve rides the packed parity kernels + fused engine and still
    converges to the f32 tolerance, matching the reference mp solve."""
    u, b = batched_problem
    b0 = b[0]
    x_ref, st_ref = solve_wilson_eo_mp(u, b0, MASS, tol=TOL, inner_tol=5e-2,
                                       inner_maxiter=100, max_outer=40)
    x_pal, st_pal = solve_wilson_eo_mp(u, b0, MASS, tol=TOL, inner_tol=5e-2,
                                       inner_maxiter=100, max_outer=40,
                                       use_pallas=True)
    assert bool(st_ref.converged) and bool(st_pal.converged)
    assert _rel_res(u, x_pal, b0) < 1e-5
    # same two-level structure: bulk work in low-precision inner iterations
    assert int(st_pal.iterations) >= 2 * int(st_pal.outer_iterations)
    assert float(jnp.max(jnp.abs(x_pal - x_ref))) < 1e-3
