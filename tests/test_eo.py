"""Even-odd (Schur) preconditioned solves: equivalence with plain CGNR,
iteration savings, the mixed-precision composition, and the Pallas fast
path (parity kernels + fused CG engine)."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from repro.core import (LatticeShape, cgnr, dslash, dslash_dagger,
                        random_gauge, random_spinor, solve_wilson_eo,
                        solve_wilson_eo_mp)

LAT = LatticeShape(4, 4, 4, 4)  # the 4^4 acceptance lattice
MASS = 0.1
TOL = 1e-6

_BASELINE = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                         "BENCH_solvers_baseline.json")


@pytest.fixture(scope="module")
def problem():
    key = jax.random.PRNGKey(7)
    ku, kb = jax.random.split(key)
    return random_gauge(ku, LAT), random_spinor(kb, LAT)


def _rel_res(u, x, b):
    r = dslash(u, x, MASS) - b
    return float(jnp.linalg.norm(r.ravel()) / jnp.linalg.norm(b.ravel()))


def test_cgnr_eo_matches_plain_cgnr(problem):
    """Reconstructed full-lattice solution agrees with plain CGNR's to the
    solve tolerance, in at most 60% of the inner iterations."""
    u, b = problem
    x_full, st_full = cgnr(lambda v: dslash(u, v, MASS),
                           lambda v: dslash_dagger(u, v, MASS), b,
                           tol=TOL, maxiter=1000)
    x_eo, st_eo = solve_wilson_eo(u, b, MASS, tol=TOL, maxiter=1000)
    assert bool(st_full.converged) and bool(st_eo.converged)
    assert _rel_res(u, x_full, b) < 1e-5
    assert _rel_res(u, x_eo, b) < 1e-5
    # both solve the same nonsingular system to tolerance
    assert jnp.max(jnp.abs(x_eo - x_full)) < 1e-4
    # the Schur system is better conditioned AND half the size
    assert int(st_eo.iterations) <= 0.6 * int(st_full.iterations)


def test_eo_mixed_precision_composes(problem):
    """Even-odd inner solve in bf16 real pairs + f32 reliable updates still
    converges to the f32 tolerance (paper's two optimizations composed)."""
    u, b = problem
    x, st = solve_wilson_eo_mp(u, b, MASS, tol=TOL, inner_tol=5e-2,
                               inner_maxiter=100, max_outer=40)
    assert bool(st.converged)
    assert _rel_res(u, x, b) < 1e-5
    # bulk of the work happened in the low-precision inner iterations
    assert int(st.iterations) >= 2 * int(st.outer_iterations)


def test_eo_solve_non_cubic_lattice():
    """Anisotropic (all-even) extents solve correctly too."""
    lat = LatticeShape(2, 4, 2, 8)
    key = jax.random.PRNGKey(11)
    ku, kb = jax.random.split(key)
    u, b = random_gauge(ku, lat), random_spinor(kb, lat)
    x, st = solve_wilson_eo(u, b, MASS, tol=TOL, maxiter=1000)
    assert bool(st.converged)
    assert _rel_res(u, x, b) < 1e-5


def test_eo_pallas_fast_path_matches_reference():
    """The Pallas fast path (parity stencil kernels + fused CG triads)
    reproduces the reference Schur solve: same iterates, same solution.

    Small lattice: the interpret-mode kernels trace one program per grid
    point, so compile time scales with T * Z/BZ."""
    lat = LatticeShape(2, 4, 4, 4)
    key = jax.random.PRNGKey(5)
    ku, kb = jax.random.split(key)
    u, b = random_gauge(ku, lat), random_spinor(kb, lat)
    x_ref, st_ref = solve_wilson_eo(u, b, MASS, tol=TOL, maxiter=1000)
    x_pal, st_pal = solve_wilson_eo(u, b, MASS, tol=TOL, maxiter=1000,
                                    use_pallas=True)
    assert bool(st_ref.converged) and bool(st_pal.converged)

    def rel(x):
        r = dslash(u, x, MASS) - b
        return float(jnp.linalg.norm(r.ravel()) / jnp.linalg.norm(b.ravel()))

    assert rel(x_pal) < 1e-5
    # CG in the packed real representation is the SAME Krylov iteration
    assert abs(int(st_pal.iterations) - int(st_ref.iterations)) <= 1
    assert float(jnp.max(jnp.abs(x_pal - x_ref))) < 1e-4


def test_eo_iteration_count_vs_committed_baseline(problem):
    """Blocking CI guard: the 4^4 smoke solve — reference AND Pallas fast
    path — must not take more iterations than the committed
    BENCH_solvers_baseline.json (same seed/mass/tol as
    benchmarks/bench_solvers.py's eo_smoke entry)."""
    with open(_BASELINE) as f:
        base = json.load(f)["eo_smoke"]
    # the baseline only guards THIS problem; a drifted baseline is an error
    assert base["lattice"] == str(LAT)
    assert (base["mass"], base["tol"], base["seed"]) == (MASS, TOL, 7)
    u, b = problem
    _, st = solve_wilson_eo(u, b, MASS, tol=TOL, maxiter=1000)
    assert bool(st.converged)
    assert int(st.iterations) <= int(base["cgnr_eo_iters"]) + 2
    _, st_pal = solve_wilson_eo(u, b, MASS, tol=TOL, maxiter=1000,
                                use_pallas=True)
    assert bool(st_pal.converged)
    assert (int(st_pal.iterations)
            <= int(base["cgnr_eo_pallas_iters"]) + 2)


def test_eo_operators_reject_odd_extent():
    """Odd periodic T/Z/Y extents break bipartiteness and are refused."""
    lat = LatticeShape(3, 2, 2, 4)
    key = jax.random.PRNGKey(13)
    ku, kb = jax.random.split(key)
    u, b = random_gauge(ku, lat), random_spinor(kb, lat)
    with pytest.raises(AssertionError, match="bipartite"):
        solve_wilson_eo(u, b, MASS, tol=TOL, maxiter=10)
