"""Durable solves (DESIGN.md §11): segmented checkpointing + crash resume.

The acceptance gates for the in-flight Krylov checkpointing tentpole:

* the segmented solve is BITWISE identical to the one-shot solve on the
  single-device paths (same iterate, same iteration count) — segmenting
  only augments the while-loop's STOPPING CONDITION, never its body;
* jaxpr-asserted: the segment step's while body is primitive-for-
  primitive the one-shot solve's body, and contains no host callbacks;
* a crash between segments costs at most one segment of work:
  ``resume_solve`` restores the newest VALID snapshot (corrupt newest
  falls back to the previous complete step), defect-corrects from the
  saved iterate and re-verifies the accumulated solution;
* checkpoints are unsharded host arrays — a solve checkpointed on a
  2x2x2 mesh resumes on a single device (subprocess test below).
"""

import os
import shutil
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.core import LatticeShape, random_gauge, random_spinor
from repro.core import plan as plan_mod
from repro.core.resilience import RetryPolicy, resume_solve
from repro.testing import collect_eqns

LAT = LatticeShape(4, 4, 4, 4)
MASS = 0.1
TOL = 1e-6
SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.fixture(scope="module")
def problem():
    key = jax.random.PRNGKey(11)
    ku, kb = jax.random.split(key)
    return random_gauge(ku, LAT), random_spinor(kb, LAT)


@pytest.fixture(scope="module")
def batched_rhs():
    key = jax.random.PRNGKey(12)
    return jnp.stack([random_spinor(jax.random.fold_in(key, i), LAT)
                      for i in range(2)])


def _plan(**kw):
    base = dict(operator="eo-schur", backend="reference", solver="cgnr",
                precision="single")
    base.update(kw)
    return plan_mod.SolverPlan(**base)


# -- CheckpointPolicy validation --------------------------------------------


def test_checkpoint_policy_validation(tmp_path):
    plan_mod.CheckpointPolicy(dir=str(tmp_path))  # defaults are valid
    with pytest.raises(ValueError, match="dir"):
        plan_mod.CheckpointPolicy(dir="")
    with pytest.raises(ValueError, match="every_iters"):
        plan_mod.CheckpointPolicy(dir=str(tmp_path), every_iters=0)
    with pytest.raises(ValueError, match="keep"):
        plan_mod.CheckpointPolicy(dir=str(tmp_path), keep=0)


# -- segmented == one-shot, bitwise -----------------------------------------


_VARIANTS = {
    "eo-cgnr": dict(),
    "eo-pipecg": dict(solver="pipecg"),
    "eo-mixed": dict(precision="mixed"),
    "full-cgnr": dict(operator="full"),
    "eo-batched": dict(nrhs=2),
}


@pytest.mark.parametrize("variant", sorted(_VARIANTS))
def test_segmented_solve_is_bitwise_identical(problem, batched_rhs,
                                              tmp_path, variant):
    u, b = problem
    plan = _plan(**_VARIANTS[variant])
    if plan.batched:
        b = batched_rhs
    x_ref, st_ref = plan_mod.solve(plan, u, b, MASS, tol=TOL, maxiter=500)
    policy = plan_mod.CheckpointPolicy(dir=str(tmp_path / variant),
                                       every_iters=5)
    x_seg, st_seg = plan_mod.solve(plan, u, b, MASS, tol=TOL, maxiter=500,
                                   checkpoint=policy)
    assert np.array_equal(np.asarray(x_seg), np.asarray(x_ref))
    assert int(st_seg.iterations) == int(st_ref.iterations)
    assert bool(np.asarray(st_seg.verified).all())
    # snapshots were written, keyed by iteration, pruned to `keep`
    steps = ckpt.valid_steps(policy.dir)
    assert 1 <= len(steps) <= policy.keep
    assert steps[-1] == int(st_seg.iterations)


def test_snapshot_prunes_to_keep(problem, tmp_path):
    u, b = problem
    policy = plan_mod.CheckpointPolicy(dir=str(tmp_path / "k3"),
                                       every_iters=2, keep=3)
    _, st = plan_mod.solve(_plan(), u, b, MASS, tol=TOL, maxiter=500,
                           checkpoint=policy)
    steps = ckpt.valid_steps(policy.dir)
    assert len(steps) == 3
    assert steps[-1] == int(st.iterations)


# -- jaxpr gates: identical loop body, no host syncs in the segment ---------


def _while_eqns(jaxpr):
    return [e for e in collect_eqns(jaxpr) if e.primitive.name == "while"]


def _eqn_signature(jaxpr):
    return [(e.primitive.name,
             tuple(tuple(getattr(v.aval, "shape", ())) for v in e.outvars))
            for e in collect_eqns(jaxpr)]


@pytest.mark.parametrize("variant", ["eo-cgnr", "eo-pipecg", "full-cgnr"])
def test_segment_while_body_is_bitwise_the_solve_body(problem, variant):
    """The hot loop is untouched: the segmented step's while BODY is
    primitive-for-primitive the one-shot solve's body (only the stopping
    condition gains the ``counter < stop`` bound)."""
    u, b = problem
    plan = _plan(**_VARIANTS[variant])
    prog = plan_mod.loop_program(plan, u, b, MASS, tol=TOL, maxiter=50)
    carry, _ = prog.start()
    j_seg = jax.make_jaxpr(lambda c, s: prog.step(c, s))(
        carry, jnp.asarray(10, jnp.int32))
    j_one = jax.make_jaxpr(
        lambda uu, bb: plan_mod.solve(plan, uu, bb, MASS, tol=TOL,
                                      maxiter=50, verify=False))(u, b)
    w_seg, w_one = _while_eqns(j_seg), _while_eqns(j_one)
    assert len(w_seg) == len(w_one) >= 1
    for eq_seg, eq_one in zip(w_seg, w_one):
        assert (_eqn_signature(eq_seg.params["body_jaxpr"])
                == _eqn_signature(eq_one.params["body_jaxpr"]))


def test_segment_step_has_no_host_callbacks(problem):
    """All snapshot I/O happens at segment boundaries on the host — the
    compiled segment itself contains zero callback/infeed primitives."""
    u, b = problem
    prog = plan_mod.loop_program(_plan(), u, b, MASS, tol=TOL, maxiter=50)
    carry, _ = prog.start()
    j = jax.make_jaxpr(lambda c, s: prog.step(c, s))(
        carry, jnp.asarray(10, jnp.int32))
    host_prims = [e.primitive.name for e in collect_eqns(j)
                  if any(tag in e.primitive.name
                         for tag in ("callback", "infeed", "outfeed",
                                     "host", "debug"))]
    assert host_prims == []


# -- crash resume -----------------------------------------------------------


def _direct(plan, u, b):
    x, _ = plan_mod.solve(plan, u, b, MASS, tol=TOL, maxiter=500)
    return x


def _crash_after_some_segments(plan, u, b, ckpt_dir, *, every=4):
    """Run a checkpointed solve to completion, then delete the newest
    snapshots — indistinguishable on disk from a SIGKILL a few segments
    before the end."""
    policy = plan_mod.CheckpointPolicy(dir=ckpt_dir, every_iters=every,
                                       keep=100)
    plan_mod.solve(plan, u, b, MASS, tol=TOL, maxiter=500,
                   checkpoint=policy)
    steps = ckpt.valid_steps(ckpt_dir)
    assert len(steps) >= 3, "solve too short to simulate a mid-run crash"
    for s in steps[len(steps) // 2:]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"))
    return ckpt.valid_steps(ckpt_dir)[-1]


@pytest.mark.parametrize("nrhs", [None, 2])
def test_resume_solve_continues_from_checkpoint(problem, batched_rhs,
                                                tmp_path, nrhs):
    u, b = problem
    plan = _plan(nrhs=nrhs)
    if plan.batched:
        b = batched_rhs
    d = str(tmp_path / "crash")
    surviving = _crash_after_some_segments(plan, u, b, d)
    x, st, record = resume_solve(plan, u, b, MASS, checkpoint_dir=d,
                                 tol=TOL, maxiter=500)
    assert record.resumed_from_step == surviving
    assert record.checkpoint_iterations == surviving
    assert bool(np.asarray(st.verified).all())
    # the resumed attempt is a defect correction seeded by the snapshot,
    # not a from-scratch solve
    assert record.attempts[0].restarted
    assert record.attempts[0].iterations < int(
        plan_mod.solve(plan, u, b, MASS, tol=TOL, maxiter=500)[1].iterations)
    np.testing.assert_allclose(np.asarray(x), np.asarray(_direct(plan, u, b)),
                               rtol=1e-4, atol=1e-5)
    # the verified accumulated iterate was banked: a crash right now
    # resumes from DONE
    assert ckpt.valid_steps(d)[-1] > surviving


def test_resume_solve_missing_ok_runs_fresh_checkpointed(problem, tmp_path):
    u, b = problem
    d = str(tmp_path / "fresh")
    with pytest.raises(FileNotFoundError):
        resume_solve(_plan(), u, b, MASS, checkpoint_dir=d, tol=TOL,
                     maxiter=500)
    x, st, record = resume_solve(_plan(), u, b, MASS, checkpoint_dir=d,
                                 tol=TOL, maxiter=500, missing_ok=True)
    assert record.resumed_from_step is None
    assert bool(np.asarray(st.verified).all())
    assert ckpt.valid_steps(d), "fresh resume must start checkpointing"


# -- corruption satellites: fall back to the previous complete step ---------


def _two_snapshots(plan, u, b, ckpt_dir):
    policy = plan_mod.CheckpointPolicy(dir=ckpt_dir, every_iters=4,
                                       keep=100)
    plan_mod.solve(plan, u, b, MASS, tol=TOL, maxiter=500,
                   checkpoint=policy)
    steps = ckpt.valid_steps(ckpt_dir)
    assert len(steps) >= 2
    return steps


def _target(b):
    return {
        "iteration": jax.ShapeDtypeStruct((), jnp.int32),
        "rhs_mask": jax.ShapeDtypeStruct((), jnp.bool_),
        "verdict": jax.ShapeDtypeStruct((), jnp.int32),
        "x": jax.ShapeDtypeStruct(b.shape, b.dtype),
    }


def test_truncated_arrays_falls_back_to_previous_step(problem, tmp_path,
                                                      capsys):
    u, b = problem
    d = str(tmp_path / "trunc")
    steps = _two_snapshots(_plan(), u, b, d)
    npz = os.path.join(d, f"step_{steps[-1]:08d}", "arrays.npz")
    raw = open(npz, "rb").read()
    open(npz, "wb").write(raw[: len(raw) // 2])  # torn write
    step, tree = ckpt.restore_latest(d, _target(b))
    assert step == steps[-2]
    assert int(np.asarray(tree["iteration"])) == steps[-2]


def test_tampered_manifest_falls_back_to_previous_step(problem, tmp_path):
    u, b = problem
    d = str(tmp_path / "tamper")
    steps = _two_snapshots(_plan(), u, b, d)
    man = os.path.join(d, f"step_{steps[-1]:08d}", "manifest.json")
    open(man, "w").write('{"step": %d}' % steps[-1])  # sha256 stripped
    step, _ = ckpt.restore_latest(d, _target(b))
    assert step == steps[-2]


def test_every_step_corrupt_raises(problem, tmp_path):
    u, b = problem
    d = str(tmp_path / "allbad")
    steps = _two_snapshots(_plan(), u, b, d)
    for s in steps:
        npz = os.path.join(d, f"step_{s:08d}", "arrays.npz")
        raw = bytearray(open(npz, "rb").read())
        raw[len(raw) // 2] ^= 0xFF
        open(npz, "wb").write(bytes(raw))
    with pytest.raises(IOError):
        ckpt.restore_latest(d, _target(b))
    # resume_solve treats "every checkpoint corrupt" as a hard error even
    # with missing_ok (data EXISTS but cannot be trusted)
    with pytest.raises(IOError):
        resume_solve(_plan(), u, b, MASS, checkpoint_dir=d, tol=TOL,
                     maxiter=500, missing_ok=True)


def test_resume_falls_back_past_corrupt_newest(problem, tmp_path):
    """The end-to-end satellite: newest snapshot truncated, resume still
    succeeds from the previous complete step."""
    u, b = problem
    d = str(tmp_path / "fallback")
    steps = _two_snapshots(_plan(), u, b, d)
    npz = os.path.join(d, f"step_{steps[-1]:08d}", "arrays.npz")
    raw = open(npz, "rb").read()
    open(npz, "wb").write(raw[: len(raw) // 3])
    x, st, record = resume_solve(_plan(), u, b, MASS, checkpoint_dir=d,
                                 tol=TOL, maxiter=500)
    assert record.resumed_from_step == steps[-2]
    assert bool(np.asarray(st.verified).all())


# -- elastic: checkpointed on a 2x2x2 mesh, resumed on one device -----------


def test_mesh_checkpoint_resumes_on_single_device(tmp_path):
    """Snapshots store unsharded host arrays: a solve checkpointed on a
    2x2x2 mesh (8 fake CPU devices) resumes to a VERIFIED solution on a
    meshless single-device plan."""
    script = r"""
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.experimental.mesh_utils import create_device_mesh
from jax.sharding import Mesh
from repro.checkpoint import ckpt
from repro.core import LatticeShape, random_gauge, random_spinor
from repro.core import plan as plan_mod
from repro.core.resilience import resume_solve

d = sys.argv[1]
lat = LatticeShape(4, 4, 4, 8)
key = jax.random.PRNGKey(11)
ku, kb = jax.random.split(key)
u, b = random_gauge(ku, lat), random_spinor(kb, lat)
mesh = Mesh(create_device_mesh((2, 2, 2)), ("pod", "data", "model"))
sharded = plan_mod.SolverPlan(operator="eo-schur", solver="cgnr",
                              mesh=mesh)
# starve the sharded run so it stops partway with snapshots on disk —
# a crash, as far as the resume path can tell
plan_mod.solve(sharded, u, b, 0.1, tol=1e-6, maxiter=6,
               checkpoint=plan_mod.CheckpointPolicy(dir=d, every_iters=3,
                                                    keep=100))
steps = ckpt.valid_steps(d)
assert steps, "sharded solve wrote no snapshots"
print(f"SHARDED_STEPS={steps}")
single = plan_mod.SolverPlan(operator="eo-schur", solver="cgnr")
x, st, rec = resume_solve(single, u, b, 0.1, checkpoint_dir=d, tol=1e-6,
                          maxiter=500)
assert rec.resumed_from_step == steps[-1], rec
assert bool(np.asarray(st.verified).all()), st
assert rec.attempts[0].restarted
print("ELASTIC_RESUME_OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", script,
                        str(tmp_path / "mesh_ck")],
                       env=env, capture_output=True, text=True, timeout=420)
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    assert "ELASTIC_RESUME_OK" in r.stdout


# -- retry ladder interplay: restarted attempts never checkpoint ------------


def test_restarted_attempts_do_not_poison_the_checkpoint(problem, tmp_path):
    """A starved first attempt checkpoints; the defect-correction retries
    must NOT snapshot their (defect-space) iterates — only resume_solve
    re-banks the verified accumulated solution."""
    u, b = problem
    d = str(tmp_path / "ladder")
    _, st_full = plan_mod.solve(_plan(), u, b, MASS, tol=TOL, maxiter=500)
    starve = max(int(st_full.iterations) // 2, 1)
    x, st, record = resume_solve(
        _plan(), u, b, MASS, checkpoint_dir=d, tol=TOL, maxiter=starve,
        policy=RetryPolicy(max_attempts=4), missing_ok=True)
    assert bool(np.asarray(st.verified).all())
    assert len(record.attempts) >= 2
    # every surviving snapshot holds either the from-scratch attempt's
    # partial iterate or the final verified solution — restore each and
    # check it is finite and solution-shaped (defect iterates would be
    # near-duplicates of x only at tiny norm; shape alone can't tell, so
    # assert the FINAL snapshot is the verified accumulated solution)
    step, tree = ckpt.restore_latest(d, _target(b))
    np.testing.assert_array_equal(np.asarray(tree["x"]), np.asarray(x))
    assert bool(np.asarray(tree["rhs_mask"]).all())
