"""Fault injection vs the server's containment rings (DESIGN.md §10).

Each test corrupts exactly one thing — a request, a gauge field, the
worker — and asserts the blast radius: the poisoned request fails with a
classified verdict, every other request is served and verified.  All
injection is deterministic (fixed schedules, fixed coordinates), so these
are containment proofs, not flaky chaos monkeys.
"""

import asyncio

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import LatticeShape, random_gauge, random_spinor
from repro.serve import (BatchFaultInjector, BatchPolicy, InjectedFault,
                         PlanCache, RequestFailed, RequestRejected,
                         ServerOverloaded, SolveRequest, SolveTimeout,
                         SolverServer, bit_flip, nan_plane, poison_nan,
                         poison_overflow)

MASS = 0.1
TOL = 1e-6
MAXITER = 500
LAT = LatticeShape(4, 4, 4, 4)


@pytest.fixture(scope="module")
def fields():
    key = jax.random.PRNGKey(7)
    ku, kb = jax.random.split(key)
    gauges = {f"cfg{g}": random_gauge(jax.random.fold_in(ku, g), LAT)
              for g in range(2)}
    pool = [random_spinor(jax.random.fold_in(kb, i), LAT) for i in range(8)]
    return gauges, pool


@pytest.fixture(scope="module")
def plans():
    return PlanCache()


def _make_server(gauges, plans, **kw):
    kw.setdefault("mass", MASS)
    kw.setdefault("maxiter", MAXITER)
    kw.setdefault("ladder", (1, 4))
    server = SolverServer(plan_cache=plans, **kw)
    for gid, u in gauges.items():
        server.register_gauge(gid, u)
    return server


def _req(pool, i=0, **kw):
    kw.setdefault("operator_family", "wilson")
    kw.setdefault("gauge_id", "cfg0")
    kw.setdefault("tol", TOL)
    return SolveRequest(rhs=pool[i], **kw)


# -- the injectors themselves are deterministic and well-formed -------------


def test_poison_overflow_is_finite_but_norm_overflows(fields):
    _, pool = fields
    bad = poison_overflow(pool[0])
    assert bool(jnp.all(jnp.isfinite(bad)))
    assert not bool(jnp.isfinite(jnp.sum(jnp.abs(bad) ** 2)))


def test_poison_nan_corrupts_one_entry(fields):
    _, pool = fields
    bad = poison_nan(pool[0], site=3)
    flat = np.asarray(bad).reshape(-1)
    assert np.isnan(flat[3])
    assert np.isfinite(np.delete(flat, 3)).all()


def test_nan_plane_hits_exactly_one_time_slice(fields):
    gauges, _ = fields
    u = nan_plane(gauges["cfg0"], t=1)
    host = np.asarray(u)
    assert np.isnan(host[:, 1]).all()
    assert np.isfinite(np.delete(host, 1, axis=1)).all()


def test_bit_flip_changes_exactly_one_word(fields):
    gauges, _ = fields
    before = np.asarray(gauges["cfg0"]).view(np.float32).reshape(-1)
    after = np.asarray(bit_flip(gauges["cfg0"], site=5)
                       ).view(np.float32).reshape(-1)
    assert (before != after).sum() == 1
    assert before[5] != after[5]


def test_injector_schedule_is_deterministic():
    inj = BatchFaultInjector(mode="stall", every=3, at=1, stall_s=0.0)
    u = jnp.zeros((2,))
    fired = []
    for _ in range(9):
        inj(u, u)
        fired.append(inj.fired)
    assert fired == [0, 1, 1, 1, 2, 2, 2, 3, 3]


def test_injector_rejects_bad_config():
    with pytest.raises(ValueError, match="mode"):
        BatchFaultInjector(mode="meteor")
    with pytest.raises(ValueError, match="every"):
        BatchFaultInjector(every=0)


# -- ring 1: admission ------------------------------------------------------


def test_nan_rhs_rejected_at_admission(fields, plans):
    gauges, pool = fields

    async def main():
        async with _make_server(gauges, plans) as server:
            with pytest.raises(RequestRejected) as exc:
                await server.submit(_req([poison_nan(pool[0])]))
            return exc.value.reason, server.metrics()

    reason, metrics = asyncio.run(main())
    assert reason == "nonfinite_rhs"
    assert metrics["containment"]["admission_rejected"] == 1
    # rejection happened before any queue/batch work
    assert metrics["batches"] == 0


def test_bad_tol_rejected_at_admission(fields, plans):
    gauges, pool = fields

    async def main():
        async with _make_server(gauges, plans) as server:
            for tol in (float("nan"), float("inf"), 0.0, -1e-6):
                with pytest.raises(RequestRejected, match="tol"):
                    await server.submit(_req(pool, tol=tol))
            with pytest.raises(RequestRejected, match="deadline"):
                await server.submit(_req(pool, deadline_s=float("nan")))

    asyncio.run(main())


def test_backpressure_bounds_queue_depth(fields, plans):
    gauges, pool = fields

    async def main():
        # long max_wait: everything queues behind the first dispatch
        async with _make_server(
                gauges, plans, max_queue_depth=2, ladder=(1,),
                policy=BatchPolicy(max_wait=0.02, max_batch=1)) as server:
            tasks = [asyncio.create_task(server.submit(_req(pool, i % 8)))
                     for i in range(6)]
            out = await asyncio.gather(*tasks, return_exceptions=True)
            return out, server.metrics()

    out, metrics = asyncio.run(main())
    overloaded = [r for r in out if isinstance(r, ServerOverloaded)]
    served = [r for r in out if not isinstance(r, Exception)]
    assert len(overloaded) >= 1
    assert metrics["containment"]["overload_rejected"] == len(overloaded)
    # everyone who was admitted got served
    assert len(served) == 6 - len(overloaded)


# -- ring 2: taxonomy + verification (defense in depth) ---------------------


def test_nan_rhs_classified_when_admission_is_off(fields, plans):
    """With the admission ring disabled the poison reaches the solver:
    the taxonomy classifies it nonfinite and the request fails loudly —
    never a silent wrong answer."""
    gauges, pool = fields

    async def main():
        async with _make_server(gauges, plans,
                                admission_validation=False) as server:
            with pytest.raises(RequestFailed) as exc:
                await server.submit(_req([poison_nan(pool[0])]))
            return exc.value.verdict, server.metrics()

    verdict, metrics = asyncio.run(main())
    assert verdict == "nonfinite"
    assert metrics["containment"]["verdict_hist"] == {"nonfinite": 1}


def test_overflow_poison_blast_radius_is_one(fields, plans):
    """The overflow poison passes admission by construction (finite
    entries) and must be caught downstream WITHOUT hurting its batch:
    3 healthy batchmates are served and verified."""
    gauges, pool = fields

    async def main():
        async with _make_server(
                gauges, plans,
                policy=BatchPolicy(max_wait=0.25)) as server:
            reqs = [_req(pool, 0), _req(pool, 1),
                    _req([poison_overflow(pool[2])]), _req(pool, 3)]
            tasks = [asyncio.create_task(server.submit(r)) for r in reqs]
            out = await asyncio.gather(*tasks, return_exceptions=True)
            return out, server.metrics()

    out, metrics = asyncio.run(main())
    assert isinstance(out[2], RequestFailed)
    assert out[2].verdict == "nonfinite"
    healthy = [out[0], out[1], out[3]]
    assert all(not isinstance(r, Exception) for r in healthy)
    assert all(r.stats.verified for r in healthy)
    assert metrics["containment"]["failed_requests"] == 1


# -- ring 3: transient faults are rescued by the clean re-solve -------------


def test_transient_gauge_fault_rescues_every_healthy_member(fields, plans):
    """A NaN plane hits the gauge field of the PRIMARY dispatch: every
    lane fails verification, and the per-lane clean re-solve (the
    injector never sees retries) rescues all of them."""
    gauges, pool = fields
    inj = BatchFaultInjector(mode="gauge_nan_plane", every=1)

    async def main():
        async with _make_server(
                gauges, plans, fault_injector=inj,
                policy=BatchPolicy(max_wait=0.25)) as server:
            tasks = [asyncio.create_task(server.submit(_req(pool, i)))
                     for i in range(4)]
            out = await asyncio.gather(*tasks, return_exceptions=True)
            return out, server.metrics()

    out, metrics = asyncio.run(main())
    assert inj.fired >= 1
    assert all(not isinstance(r, Exception) for r in out)
    assert all(r.stats.verified and r.stats.retried for r in out)
    c = metrics["containment"]
    assert c["lane_retries_rescued"] == len(out)
    assert c["failed_requests"] == 0


def test_injected_crash_triggers_bisection_and_rescue(fields, plans):
    """mode='raise' crashes the whole primary batch solve; bisection
    re-solves each member individually and every request succeeds."""
    gauges, pool = fields
    inj = BatchFaultInjector(mode="raise", every=1)

    async def main():
        async with _make_server(
                gauges, plans, fault_injector=inj,
                policy=BatchPolicy(max_wait=0.25)) as server:
            tasks = [asyncio.create_task(server.submit(_req(pool, i)))
                     for i in range(3)]
            out = await asyncio.gather(*tasks, return_exceptions=True)
            return out, server.metrics()

    out, metrics = asyncio.run(main())
    assert all(not isinstance(r, Exception) for r in out)
    c = metrics["containment"]
    assert c["batch_failures"] >= 1
    assert c["lane_retries"] >= len(out)
    assert c["failed_requests"] == 0


def test_transient_fault_on_lone_request_is_rescued(fields, plans):
    """Containment must hold for a singleton batch too: a lone healthy
    request hit by a transient fault gets the same clean re-solve."""
    gauges, pool = fields
    inj = BatchFaultInjector(mode="gauge_nan_plane", every=1)

    async def main():
        async with _make_server(gauges, plans,
                                fault_injector=inj) as server:
            return await server.submit(_req(pool, 0)), server.metrics()

    result, metrics = asyncio.run(main())
    assert result.stats.verified and result.stats.retried
    assert metrics["containment"]["failed_requests"] == 0


def test_stall_fault_expires_deadline_without_burning_a_slot(fields, plans):
    """A stalled worker delays dispatch; a request whose deadline passed
    while it waited fails with SolveTimeout BEFORE batch shaping — it
    never consumes a solve slot — while undeadlined requests survive."""
    gauges, pool = fields
    inj = BatchFaultInjector(mode="stall", every=1, stall_s=0.3)

    async def main():
        async with _make_server(
                gauges, plans, fault_injector=inj, ladder=(1,),
                policy=BatchPolicy(max_wait=0.01, max_batch=1)) as server:
            # first request occupies the worker (and takes the stall);
            # the second's deadline expires while it queues behind it
            t1 = asyncio.create_task(server.submit(_req(pool, 0)))
            await asyncio.sleep(0.05)
            t2 = asyncio.create_task(
                server.submit(_req(pool, 1, deadline_s=0.05)))
            out = await asyncio.gather(t1, t2, return_exceptions=True)
            return out, server.metrics()

    out, metrics = asyncio.run(main())
    assert not isinstance(out[0], Exception)
    assert isinstance(out[1], SolveTimeout)
    c = metrics["containment"]
    assert c["deadline_expired"] == 1
    # the expired request must not appear in any batch histogram slot
    assert sum(metrics["batch_hist"].values()) == metrics["batches"]


def test_injected_fault_is_an_exception_type():
    with pytest.raises(InjectedFault):
        BatchFaultInjector(mode="raise", every=1)(None, None)
