"""Write-ahead serving journal + crash recovery (DESIGN.md §11).

The serving half of durability: every ADMITTED request is journaled
(fsync'd, RHS first) before it can touch a queue; completions — results
AND classified failures — are marked; a crash leaves exactly the
in-flight entries unmarked, and ``SolverServer.recover`` replays them to
completion on a fresh server over the same journal directory.
"""

import asyncio
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import LatticeShape, random_gauge, random_spinor
from repro.serve import journal as jm
from repro.serve.loadgen import (WorkloadConfig, poisoned_indices,
                                 summarize_chaos)
from repro.serve.server import (ServerClosed, SolveRequest, SolveResult,
                                SolverServer)

LAT = LatticeShape(4, 4, 4, 4)
MASS = 0.1
TOL = 1e-6


@pytest.fixture(scope="module")
def fields():
    key = jax.random.PRNGKey(7)
    ku, kb = jax.random.split(key)
    u = random_gauge(ku, LAT)
    pool = [random_spinor(jax.random.fold_in(kb, i), LAT) for i in range(4)]
    return u, pool


def _req(rhs, **kw):
    base = dict(operator_family="wilson", gauge_id="cfg0", tol=TOL)
    base.update(kw)
    return SolveRequest(rhs=rhs, **base)


# -- journal file format ----------------------------------------------------


def test_admit_complete_scan_roundtrip(tmp_path, fields):
    _, pool = fields
    d = str(tmp_path)
    j = jm.RequestJournal(d)
    for rid in range(3):
        j.admit(rid, operator_family="wilson", gauge_id="cfg0",
                rhs=pool[rid], tol=TOL, mu=0.0, mass=None, deadline_s=None)
    j.complete(1, "ok")
    j.close()
    events = jm.scan_journal(d)
    assert [e["event"] for e in events] == ["admit"] * 3 + ["complete"]
    inc = jm.incomplete_requests(d)
    assert [e["rid"] for e in inc] == [0, 2]
    # the journaled RHS round-trips bit-exactly
    np.testing.assert_array_equal(jm.load_rhs(d, inc[0]),
                                  np.asarray(pool[0]))


def test_external_mark_complete_retires_entries(tmp_path, fields):
    _, pool = fields
    d = str(tmp_path)
    j = jm.RequestJournal(d)
    j.admit(0, operator_family="wilson", gauge_id="cfg0", rhs=pool[0],
            tol=TOL, mu=0.0, mass=None, deadline_s=None)
    j.close()
    jm.mark_complete(d, 0, "recovered")
    assert jm.incomplete_requests(d) == []


def test_torn_tail_is_tolerated_mid_corruption_raises(tmp_path, fields):
    _, pool = fields
    d = str(tmp_path)
    j = jm.RequestJournal(d)
    for rid in range(2):
        j.admit(rid, operator_family="wilson", gauge_id="cfg0",
                rhs=pool[rid], tol=TOL, mu=0.0, mass=None, deadline_s=None)
    j.close()
    log = os.path.join(d, "journal.jsonl")
    # a torn FINAL line is the crash artifact fsync-per-line permits
    with open(log, "a") as f:
        f.write('{"event": "admit", "rid":')
    assert [e["rid"] for e in jm.scan_journal(d)] == [0, 1]
    # but a torn line ANYWHERE ELSE is corruption and must raise
    lines = open(log).read().splitlines()
    lines[0] = lines[0][: len(lines[0]) // 2]
    open(log, "w").write("\n".join(lines) + "\n")
    with pytest.raises(IOError):
        jm.scan_journal(d)


def test_empty_or_absent_journal_scans_empty(tmp_path):
    assert jm.scan_journal(str(tmp_path / "nope")) == []
    assert jm.incomplete_requests(str(tmp_path / "nope")) == []


# -- server lifecycle -------------------------------------------------------


def test_drained_server_completes_every_journal_entry(tmp_path, fields):
    u, pool = fields
    d = str(tmp_path)

    async def main():
        server = SolverServer(mass=MASS, ladder=(1, 4), journal_dir=d)
        server.register_gauge("cfg0", u)
        results = await asyncio.gather(
            *[server.submit(_req(pool[i])) for i in range(3)])
        await server.close()  # drain
        return results

    results = asyncio.run(main())
    assert all(isinstance(r, SolveResult) for r in results)
    assert jm.incomplete_requests(d) == []
    events = jm.scan_journal(d)
    assert sum(e["event"] == "admit" for e in events) == 3
    assert all(e["status"] == "ok" for e in events
               if e["event"] == "complete")


def test_classified_failure_is_a_completion(tmp_path, fields):
    """A structured failure IS a durable answer — the entry must NOT be
    replayed after a crash."""
    u, pool = fields
    d = str(tmp_path)
    from repro.serve.chaos import poison_overflow
    poisoned = poison_overflow(pool[0])

    async def main():
        server = SolverServer(mass=MASS, ladder=(1, 4), journal_dir=d)
        server.register_gauge("cfg0", u)
        try:
            await server.submit(_req(poisoned))
        except Exception as e:
            return type(e).__name__
        finally:
            await server.close()
        return None

    failure = asyncio.run(main())
    assert failure is not None
    assert jm.incomplete_requests(d) == []


def test_crash_then_recover_completes_all(tmp_path, fields):
    """The §11 serving acceptance gate, in-process: abort mid-flight
    (futures die with ServerClosed), then a fresh journaled server over
    the same directory replays every incomplete entry to a verified
    completion."""
    u, pool = fields
    d = str(tmp_path)

    async def crash():
        server = SolverServer(mass=MASS, ladder=(1, 4), journal_dir=d)
        server.register_gauge("cfg0", u)
        futs = [asyncio.ensure_future(server.submit(_req(pool[i])))
                for i in range(4)]
        await asyncio.sleep(0)      # admits land; nothing completes
        await server.close(drain=False)
        outcomes = []
        for f in futs:
            try:
                await f
                outcomes.append("ok")
            except ServerClosed:
                outcomes.append("closed")
        return outcomes

    outcomes = asyncio.run(crash())
    lost = outcomes.count("closed")
    assert lost >= 1
    incomplete = jm.incomplete_requests(d)
    assert len(incomplete) == lost

    async def recover():
        server = SolverServer(mass=MASS, ladder=(1, 4), journal_dir=d)
        server.register_gauge("cfg0", u)
        summary = await server.recover()
        await server.close()
        return summary

    summary = asyncio.run(recover())
    assert summary["found"] == summary["replayed"] == lost
    assert summary["completed"] == lost
    assert summary["failed"] == 0
    assert jm.incomplete_requests(d) == []
    # rids stay unique across the two server generations
    rids = [e["rid"] for e in jm.scan_journal(d) if e["event"] == "admit"]
    assert len(set(rids)) == len(rids)


def test_recover_skips_unknown_gauge(tmp_path, fields):
    """An incomplete entry whose gauge was never re-registered is retired
    as skipped — it must not poison every future recovery pass."""
    u, pool = fields
    d = str(tmp_path)
    j = jm.RequestJournal(d)
    j.admit(0, operator_family="wilson", gauge_id="gone", rhs=pool[0],
            tol=TOL, mu=0.0, mass=None, deadline_s=None)
    j.admit(1, operator_family="wilson", gauge_id="cfg0", rhs=pool[1],
            tol=TOL, mu=0.0, mass=None, deadline_s=None)
    j.close()

    async def main():
        server = SolverServer(mass=MASS, ladder=(1, 4), journal_dir=d)
        server.register_gauge("cfg0", u)
        summary = await server.recover()
        await server.close()
        return summary

    summary = asyncio.run(main())
    assert summary["skipped_unknown_gauge"] == 1
    assert summary["completed"] == 1
    assert jm.incomplete_requests(d) == []


# -- chaos accounting: every submitted request lands in one bucket ----------


def _cfg(**kw):
    base = dict(requests=10, chaos=True, chaos_poison_fraction=0.2)
    base.update(kw)
    return WorkloadConfig(**base)


class _FakeStats:
    converged = True
    verified = True
    retried = False


def _fake_results(cfg, crash_from):
    """Synthetic outcome list: poisoned fail classified, the tail is
    crash-lost, the rest served."""
    poison = poisoned_indices(cfg)
    out = []
    for i in range(cfg.requests):
        if i >= crash_from:
            out.append((0.0, ServerClosed("died")))
        elif i in poison:
            exc = RuntimeError("poisoned")
            exc.verdict = "nonfinite"
            out.append((0.0, exc))
        else:
            out.append((0.1, SolveResult(x=None, stats=_FakeStats())))
    return out


def test_summarize_chaos_accounts_for_every_request():
    cfg = _cfg()
    poison = poisoned_indices(cfg)
    crash_from = 7
    results = _fake_results(cfg, crash_from)
    lost_poisoned = sum(1 for i in poison if i >= crash_from)
    lost_healthy = (cfg.requests - crash_from) - lost_poisoned
    recovery = {"found": cfg.requests - crash_from,
                "replayed": cfg.requests - crash_from,
                "completed": lost_healthy, "failed": lost_poisoned,
                "skipped_unknown_gauge": 0}
    c = summarize_chaos(cfg, results, wall_s=1.0, recovery=recovery)
    assert c["all_accounted"]
    assert c["crash_lost"] == cfg.requests - crash_from
    assert c["healthy_crash_lost"] == lost_healthy
    assert c["poisoned_crash_lost"] == lost_poisoned
    assert c["resumed_after_recovery"] == lost_healthy
    assert c["containment_ok"]
    assert c["recovery_ok"]


def test_summarize_chaos_flags_unbalanced_recovery():
    cfg = _cfg()
    results = _fake_results(cfg, crash_from=7)
    # no recovery pass at all: the ledger must NOT balance
    c = summarize_chaos(cfg, results, wall_s=1.0)
    assert c["crash_lost"] > 0
    assert not c["recovery_ok"]
    # a recovery that completed fewer than it lost also fails
    c2 = summarize_chaos(cfg, results, wall_s=1.0,
                         recovery={"completed": 0, "failed": 0})
    assert not c2["recovery_ok"]


def test_summarize_chaos_without_crashes_matches_pr7_shape():
    """The normal chaos lane (no crash) keeps its PR 7 semantics: crash
    buckets zero, containment gate unchanged."""
    cfg = _cfg()
    results = _fake_results(cfg, crash_from=cfg.requests)
    c = summarize_chaos(cfg, results, wall_s=1.0)
    assert c["crash_lost"] == 0
    assert c["all_accounted"]
    assert c["containment_ok"]
    assert c["recovery_ok"]
