"""Training step, optimizer, checkpointing, fault-tolerant resume."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.data import SyntheticLM
from repro.models import steps as S
from repro.optim import AdamWConfig, adamw_init, adamw_update, warmup_cosine

OPT = AdamWConfig(lr=1e-3, weight_decay=0.01)


def _train(arch, steps, seed=0, state=None, start=0):
    cfg = configs.get_smoke(arch)
    if state is None:
        state = S.init_train_state(cfg, jax.random.PRNGKey(seed), OPT)
    fn = jax.jit(S.make_train_step(cfg, OPT, compute_dtype=jnp.float32))
    seq = 48 + (cfg.num_prefix_embeds or 0)
    data = SyntheticLM(cfg, batch=4, seq_len=seq, seed=seed)
    losses = []
    for i in range(start, start + steps):
        state, m = fn(state, data.batch_at(i))
        losses.append(float(m["loss"]))
    return state, losses


@pytest.mark.parametrize("arch", ["glm4-9b", "qwen2-moe-a2.7b",
                                  "recurrentgemma-9b",
                                  "seamless-m4t-large-v2"])
def test_loss_decreases(arch):
    _, losses = _train(arch, 25)
    assert losses[-1] < losses[0] - 0.3, losses[::6]
    assert np.isfinite(losses).all()


def test_grad_compression_is_bf16():
    """Grads are taken w.r.t. the bf16 compute copy (compressed comms)."""
    cfg = configs.get_smoke("glm4-9b")
    state = S.init_train_state(cfg, jax.random.PRNGKey(0), OPT)
    batch = SyntheticLM(cfg, batch=2, seq_len=16).batch_at(0)
    cparams = S.cast_compute(state["params"], jnp.bfloat16)
    grads = jax.grad(
        lambda cp: S.loss_fn(cfg, cp, batch, jnp.bfloat16)[0])(cparams)
    wq = grads["segments"][0]["b0"]["attn"]["wq"]
    assert wq.dtype == jnp.bfloat16
    # norm scales stay f32 (they were not cast)
    assert grads["segments"][0]["b0"]["ln1"].dtype == jnp.float32


def test_adamw_moment_dtype_knob():
    p = {"w": jnp.zeros((4, 4), jnp.float32)}
    st8 = adamw_init(p, AdamWConfig(moment_dtype="bfloat16"))
    assert st8["m"]["w"].dtype == jnp.bfloat16
    g = {"w": jnp.ones((4, 4), jnp.float32)}
    newp, newst, gn = adamw_update(p, g, st8,
                                   AdamWConfig(moment_dtype="bfloat16"))
    assert newst["m"]["w"].dtype == jnp.bfloat16
    assert newp["w"].dtype == jnp.float32
    assert float(gn) > 0


def test_warmup_cosine_shape():
    s = warmup_cosine(jnp.asarray(0), warmup=10, total=100)
    e = warmup_cosine(jnp.asarray(99), warmup=10, total=100)
    m = warmup_cosine(jnp.asarray(10), warmup=10, total=100)
    assert float(s) == 0.0 and float(m) == pytest.approx(1.0, abs=0.01)
    assert float(e) < 0.2


def test_checkpoint_roundtrip_and_resume():
    state, losses_a = _train("glm4-9b", 6)
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 6, state)
        assert latest_step(d) == 6
        restored = restore_checkpoint(d, 6, jax.eval_shape(lambda: state))
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # training continued from the restore matches continuing in-memory
        s1, l1 = _train("glm4-9b", 3, state=state, start=6)
        s2, l2 = _train("glm4-9b", 3, state=restored, start=6)
        assert np.allclose(l1, l2, rtol=1e-5)


def test_checkpoint_checksum_detects_corruption():
    state, _ = _train("glm4-9b", 1)
    with tempfile.TemporaryDirectory() as d:
        path = save_checkpoint(d, 1, state)
        npz = os.path.join(path, "arrays.npz")
        raw = bytearray(open(npz, "rb").read())
        raw[len(raw) // 2] ^= 0xFF
        open(npz, "wb").write(bytes(raw))
        with pytest.raises(IOError):
            restore_checkpoint(d, 1, jax.eval_shape(lambda: state))


def test_data_pipeline_deterministic_restart():
    cfg = configs.get_smoke("glm4-9b")
    d1 = SyntheticLM(cfg, batch=4, seq_len=32, seed=3)
    d2 = SyntheticLM(cfg, batch=4, seq_len=32, seed=3)
    a = d1.batch_at(17)["tokens"]
    b = d2.batch_at(17)["tokens"]
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
