"""Block CG + EigCG deflation (DESIGN.md §12): solver paths, caches, server.

Three tiers, cheapest first:

* pure cache/guard tests — no solves at all (dummy bases);
* smoke-mass solves (0.1, ~14 iterations) — matvec accounting, blockcg
  correctness, harvest plumbing.  Deflation is physically INERT here (the
  Krylov space is too shallow for Ritz pairs to matter), so these assert
  wiring, not iteration drops;
* near-critical-mass solves (-1.7, ~120 iterations) — the actual
  iteration cut, end-to-end through the core API and the serving layer.
  Kept to a handful of solves; the bench lane (BENCH_solvers_baseline
  ``eo_deflation`` / ``blockcg_16rhs`` / ``deflation_serve``) guards the
  exact counts.
"""

import asyncio

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import LatticeShape, random_gauge, random_spinor
from repro.core import plan as plan_mod
from repro.core import resilience, solvers
from repro.core.plan import SolverPlan
from repro.serve import DeflationCache, PlanCache, SolveRequest, SolverServer

LAT = LatticeShape(4, 4, 4, 4)
TOL = 1e-6
MAXITER = 500
SMOKE_MASS = 0.1
DEFL_MASS = -1.7   # near-critical: ~120-iteration Krylov space


@pytest.fixture(scope="module")
def fields():
    key = jax.random.PRNGKey(7)
    ku, kb = jax.random.split(key)
    gauges = {f"cfg{g}": random_gauge(jax.random.fold_in(ku, g), LAT)
              for g in range(2)}
    pool = [random_spinor(jax.random.fold_in(kb, i), LAT) for i in range(4)]
    return gauges, pool


def _eo(nrhs=None, **kw):
    return SolverPlan(operator="eo-schur", operator_family="wilson",
                      nrhs=nrhs, **kw)


def _dummy_basis(nev=2):
    w = jnp.zeros((nev, 8), jnp.complex64)
    return solvers.DeflationBasis(w=w, gram=jnp.eye(nev, dtype=w.dtype))


def _key(gid, mass=DEFL_MASS):
    return (gid, "wilson", 0.0, mass)


# -- DeflationCache lifecycle (no solves) ------------------------------------

def test_deflation_cache_miss_store_hit_and_stats():
    cache = DeflationCache()
    assert cache.lookup(_key("g0")) is None          # miss
    basis = _dummy_basis()
    cache.store(_key("g0"), basis)
    assert cache.lookup(_key("g0")) is basis          # hit
    assert cache.peek(_key("g0")) is basis            # peek: no counters
    assert cache.lookup(_key("g0", mass=0.2)) is None  # mass is in the key
    s = cache.stats()
    assert (s["hits"], s["misses"], s["harvests"]) == (1, 2, 1)
    assert s["hit_rate"] == pytest.approx(1 / 3)
    assert (s["size"], s["gauges"]) == (1, 1)


def test_deflation_cache_lru_evicts_coldest_gauge_wholesale():
    cache = DeflationCache(max_gauges=2)
    cache.store(_key("g0"), _dummy_basis())
    cache.store(_key("g0", mass=0.2), _dummy_basis())  # same gauge: no evict
    cache.store(_key("g1"), _dummy_basis())
    assert cache.lookup(_key("g0")) is not None        # touch g0: g1 coldest
    cache.store(_key("g2"), _dummy_basis())            # third gauge: evict g1
    assert cache.peek(_key("g1")) is None
    assert cache.peek(_key("g0")) is not None
    assert cache.peek(_key("g0", mass=0.2)) is not None
    assert cache.peek(_key("g2")) is not None
    s = cache.stats()
    assert s["evictions"] == 1 and s["gauges"] == 2 and s["size"] == 3


def test_deflation_cache_invalidate_gauge_drops_every_key():
    cache = DeflationCache()
    cache.store(_key("g0"), _dummy_basis())
    cache.store(_key("g0", mass=0.2), _dummy_basis())
    cache.store(_key("g1"), _dummy_basis())
    assert cache.invalidate_gauge("g0") == 2
    assert cache.peek(_key("g0")) is None
    assert cache.peek(_key("g1")) is not None
    assert cache.invalidate_gauge("nope") == 0
    s = cache.stats()
    assert s["invalidations"] == 2 and s["size"] == 1


def test_deflation_cache_rejects_bad_capacity():
    with pytest.raises(ValueError):
        DeflationCache(max_gauges=0)


# -- plan construction / dispatch guards (no solves) -------------------------

def test_blockcg_plan_requires_nrhs():
    with pytest.raises(ValueError):
        SolverPlan(operator="eo-schur", solver="blockcg")


def test_deflation_guard_rejects_unsupported_compositions(fields):
    gauges, pool = fields
    u, b = gauges["cfg0"], pool[0]
    basis = _dummy_basis()
    for plan in (_eo(solver="pipecg"), _eo(precision="mixed")):
        with pytest.raises(NotImplementedError):
            plan_mod.solve(plan, u, b, SMOKE_MASS, tol=TOL, maxiter=MAXITER,
                           deflation=basis)
    with pytest.raises(NotImplementedError):
        plan_mod.solve(_eo(), u, b, SMOKE_MASS, tol=TOL, maxiter=MAXITER,
                       deflation=basis,
                       checkpoint=plan_mod.CheckpointPolicy(dir="/tmp/x"))


def test_harvest_guard_rejects_batched_and_full(fields):
    gauges, pool = fields
    u = gauges["cfg0"]
    with pytest.raises(NotImplementedError):
        plan_mod.harvest_deflation(
            _eo(nrhs=2), u, jnp.stack(pool[:2]), SMOKE_MASS)
    with pytest.raises(NotImplementedError):
        plan_mod.harvest_deflation(
            SolverPlan(operator="full"), u, pool[0], SMOKE_MASS)


# -- PlanCache.get_deflated ---------------------------------------------------

def test_plan_cache_deflated_entry_is_distinct_and_basis_is_runtime(fields):
    gauges, pool = fields
    u, b = gauges["cfg0"], pool[0]
    cache = PlanCache()
    fn_plain, _ = cache.get(_eo(), SMOKE_MASS, MAXITER)
    fn_defl, hit1 = cache.get_deflated(_eo(), SMOKE_MASS, MAXITER)
    fn_defl2, hit2 = cache.get_deflated(_eo(), SMOKE_MASS, MAXITER)
    assert (hit1, hit2) == (False, True)
    assert fn_defl is fn_defl2 and fn_defl is not fn_plain
    assert len(cache) == 2
    # the basis is a RUNTIME argument: swapping bases reuses the callable,
    # and an all-zero basis (in the plan's WORKING layout — the Schur
    # even field) is an inert x0=0 warm start — bitwise the plain solve
    x_plain, st_plain = fn_plain(u, b, jnp.float32(TOL))
    _, _, harvested = plan_mod.harvest_deflation(
        _eo(), u, b, SMOKE_MASS, tol=1e-8, maxiter=MAXITER, nev=4,
        m_max=48, verify_tol=TOL)
    zero = solvers.DeflationBasis(
        w=jnp.zeros_like(harvested.w),
        gram=jnp.eye(harvested.w.shape[0], dtype=harvested.w.dtype))
    x_defl, st_defl = fn_defl(u, b, jnp.float32(TOL), zero.w, zero.gram)
    assert np.array_equal(np.asarray(x_plain), np.asarray(x_defl))
    assert int(st_plain.iterations) == int(st_defl.iterations)


# -- matvec accounting (smoke mass) ------------------------------------------

def test_matvecs_counted_on_each_dispatch_path(fields):
    gauges, pool = fields
    u = gauges["cfg0"]
    # unbatched eo: one Krylov matvec per iteration from x0 = 0
    _, st = plan_mod.solve(_eo(), u, pool[0], SMOKE_MASS, tol=TOL,
                           maxiter=MAXITER)
    assert int(st.matvecs) == int(st.iterations)
    # batched eo: per-RHS counters freeze with the RHS
    _, stb = plan_mod.solve(_eo(nrhs=2), u, jnp.stack(pool[:2]), SMOKE_MASS,
                            tol=TOL, maxiter=MAXITER)
    assert np.array_equal(np.asarray(stb.matvecs),
                          np.asarray(stb.rhs_iterations))
    # full-operator path counts too
    _, stf = plan_mod.solve(SolverPlan(operator="full"), u, pool[0],
                            SMOKE_MASS, tol=TOL, maxiter=MAXITER)
    assert int(stf.matvecs) == int(stf.iterations) > 0


def test_blockcg_solves_every_rhs_and_counts_matvecs(fields):
    gauges, pool = fields
    u = gauges["cfg0"]
    n = 3
    plan = _eo(nrhs=n, solver="blockcg")
    x, st = plan_mod.solve(plan, u, jnp.stack(pool[:n]), SMOKE_MASS,
                           tol=TOL, maxiter=MAXITER)
    assert np.asarray(st.converged).all() and np.asarray(st.verified).all()
    assert np.array_equal(np.asarray(st.matvecs),
                          np.asarray(st.rhs_iterations))
    # true residual of every RHS against the full operator
    from repro.core.operators import dslash_g
    res = jax.vmap(lambda xx, bb: dslash_g(u, xx, SMOKE_MASS) - bb)(
        x, jnp.stack(pool[:n]))
    rels = (jnp.linalg.norm(res.reshape(n, -1), axis=1)
            / jnp.linalg.norm(jnp.stack(pool[:n]).reshape(n, -1), axis=1))
    assert float(jnp.max(rels)) < 10 * TOL


# -- harvest plumbing (smoke mass, cheap) ------------------------------------

def test_harvest_verify_tol_gates_the_true_residual_check(fields):
    """A deep harvest (tol 1e-8) converges by RECURSIVE residual but f32
    cannot push the TRUE residual below ~1e-7 relative — so verification
    must be gated at the tolerance the x is served at, not the mining
    depth."""
    gauges, pool = fields
    u, b = gauges["cfg0"], pool[0]
    x, st, basis = plan_mod.harvest_deflation(
        _eo(), u, b, SMOKE_MASS, tol=1e-8, maxiter=MAXITER, nev=4,
        m_max=48, verify_tol=TOL)
    assert bool(np.asarray(st.verified).all())
    assert bool(np.asarray(st.converged).all())
    assert basis.nev == 4 and basis.w.shape[0] == 4
    # the WᴴAW projection is charged to the harvest solve
    assert int(st.matvecs) > int(st.iterations)
    _, st_deep, _ = plan_mod.harvest_deflation(
        _eo(), u, b, SMOKE_MASS, tol=1e-8, maxiter=MAXITER, nev=4,
        m_max=48)   # default gate = harvest tol: below the f32 floor
    assert not bool(np.asarray(st_deep.verified).all())


def test_defended_solve_passes_deflation_to_first_attempt(fields):
    gauges, pool = fields
    u = gauges["cfg0"]
    _, _, basis = plan_mod.harvest_deflation(
        _eo(), u, pool[0], SMOKE_MASS, tol=1e-8, maxiter=MAXITER, nev=4,
        m_max=48, verify_tol=TOL)
    x, st, attempts = resilience.defended_solve(
        _eo(), u, pool[1], SMOKE_MASS, tol=TOL, maxiter=MAXITER,
        deflation=basis)
    assert len(attempts) == 1 and attempts[0].verified
    assert bool(np.asarray(st.verified).all())


# -- the actual iteration cut (near-critical mass) ---------------------------

@pytest.fixture(scope="module")
def light_mass_basis(fields):
    gauges, pool = fields
    u = gauges["cfg0"]
    x, st, basis = plan_mod.harvest_deflation(
        _eo(), u, pool[0], DEFL_MASS, tol=1e-8, maxiter=MAXITER, nev=32,
        m_max=160, verify_tol=TOL)
    assert bool(np.asarray(st.verified).all())
    return u, basis


def test_deflated_solve_cuts_iterations_at_light_mass(fields,
                                                      light_mass_basis):
    _, pool = fields
    u, basis = light_mass_basis
    b = pool[1]
    _, st_cold = plan_mod.solve(_eo(), u, b, DEFL_MASS, tol=TOL,
                                maxiter=MAXITER)
    _, st_defl = plan_mod.solve(_eo(), u, b, DEFL_MASS, tol=TOL,
                                maxiter=MAXITER, deflation=basis)
    assert bool(np.asarray(st_defl.verified).all())
    assert int(st_defl.iterations) < int(st_cold.iterations)
    # deflated warm start costs ONE extra matvec (r0 = b - A x0)
    assert int(st_defl.matvecs) == int(st_defl.iterations) + 1


def test_server_harvests_then_hits_with_iteration_drop(fields):
    gauges, pool = fields

    async def main():
        server = SolverServer(
            plan_cache=PlanCache(), mass=DEFL_MASS, maxiter=MAXITER,
            ladder=(1, 4), deflation_nev=32, deflation_m_max=160,
            deflation_harvest_tol=1e-8)
        server.register_gauge("cfg0", gauges["cfg0"])
        async with server:
            def req(i):
                return SolveRequest(operator_family="wilson",
                                    gauge_id="cfg0", rhs=pool[i], tol=TOL)
            cold = await asyncio.wait_for(server.submit(req(0)), timeout=600)
            # results resolve BEFORE the harvest runs; wait for it
            for _ in range(600):
                if server.deflations.stats()["harvests"] > 0:
                    break
                await asyncio.sleep(0.1)
            warm = await asyncio.wait_for(server.submit(req(1)), timeout=600)
            m = server.metrics()
            # re-registering the gauge invalidates its bases
            server.register_gauge("cfg0", gauges["cfg0"])
            key = ("cfg0", "wilson", 0.0, DEFL_MASS)
            return cold, warm, m, server.deflations.peek(key)

    cold, warm, metrics, peeked = asyncio.run(main())
    assert not cold.stats.deflation_cache_hit
    assert warm.stats.deflation_cache_hit
    assert warm.stats.verified and cold.stats.verified
    assert warm.stats.iterations < cold.stats.iterations
    d = metrics["deflation"]
    assert d["enabled"] and d["harvests"] == 1 and d["hits"] >= 1
    assert d["harvest_failures"] == 0
    assert peeked is None   # invalidated on re-register
