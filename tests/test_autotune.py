"""Tuning cache + autotuner: tile selection changes speed, never results.

The launch-space contract of DESIGN.md §13: every tile knob (bz, by,
batch placement, gauge stream) is bitwise-neutral — it steers HBM->VMEM
data movement only, never per-site FMA order — so the checked-in
tuning cache can only change speed.  These tests pin that contract:

* a cache hit visibly changes :func:`pick_tile`'s selection while the
  kernel output stays bitwise identical to the cold-cache default;
* the ``REPRO_DSLASH_TILE`` env override beats the cache;
* the 4-launch jaxpr of ``schur_normal_op`` survives any forced tile;
* illegal bz/by report the legal divisor list in the error message.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (LatticeShape, pack_gauge, pack_spinor, random_gauge,
                        random_spinor, split_eo, split_eo_gauge)
from repro.kernels import autotune, dispatch
from repro.kernels.dispatch import (DEFAULT_TILE, TileConfig, cache_key,
                                    parse_tile, pick_tile, save_tuning_cache)
from repro.kernels.wilson_dslash.kernel import (_divisors, _pick_by,
                                                _pick_bz, dslash_pallas)
from repro.kernels.wilson_dslash.ops import schur_normal_op
from repro.testing import pallas_call_eqns

# compute tests run interpret-mode kernels — keep the lattice tiny; the
# pure-Python constraint tests use the richer RICH dims below
LAT = LatticeShape(2, 2, 2, 8)
RICH = (2, 4, 4, 8)
MASS = 0.1


@pytest.fixture(scope="module")
def fields():
    key = jax.random.PRNGKey(71)
    ku, kp = jax.random.split(key)
    up = pack_gauge(random_gauge(ku, LAT))
    pp = pack_spinor(random_spinor(kp, LAT))
    ppb = jnp.stack([pack_spinor(random_spinor(
        jax.random.fold_in(kp, i), LAT)) for i in range(2)])
    return up, pp, ppb


@pytest.fixture(autouse=True)
def _clean_tile_env(monkeypatch):
    """Tile selection must come from each test's own setup, not the
    ambient environment or the checked-in cache."""
    monkeypatch.delenv("REPRO_DSLASH_TILE", raising=False)
    monkeypatch.delenv("REPRO_TUNING_CACHE_PATH", raising=False)
    monkeypatch.setenv("REPRO_TUNING_CACHE", "0")


# ---------------------------------------------------------------- knobs


def test_divisors():
    assert _divisors(1) == [1]
    assert _divisors(6) == [1, 2, 3, 6]
    assert _divisors(8) == [1, 2, 4, 8]


def test_pick_bz_defaults():
    # None -> largest divisor <= 4 (the historical heuristic)
    assert _pick_bz(4, None) == 4
    assert _pick_bz(6, None) == 3
    assert _pick_bz(8, None) == 4
    assert _pick_bz(5, None) == 1
    # explicit valid values pass through
    assert _pick_bz(6, 2) == 2


def test_pick_bz_error_lists_legal_values():
    with pytest.raises(ValueError, match=r"bz=3 does not tile the Z extent "
                                         r"4.*legal bz values for Z=4: "
                                         r"\[1, 2, 4\]"):
        _pick_bz(4, 3)
    for bad in (0, -2, 5):
        with pytest.raises(ValueError, match=r"legal bz values for Z=6: "
                                             r"\[1, 2, 3, 6\]"):
            _pick_bz(6, bad)


def test_pick_by_error_lists_legal_values():
    assert _pick_by(4, None) == 4          # None -> full Y
    assert _pick_by(4, 2) == 2
    with pytest.raises(ValueError, match=r"by=3 does not tile the Y extent "
                                         r"4.*\[1, 2, 4\]"):
        _pick_by(4, 3)


def test_tile_config_validates():
    with pytest.raises(ValueError, match="batch placement"):
        TileConfig(batch="rows")
    with pytest.raises(ValueError, match="gauge stream"):
        TileConfig(stream="prefetch")


def test_parse_tile():
    t = parse_tile("bz=2,by=4,batch=grid,stream=db")
    assert t == TileConfig(bz=2, by=4, batch="grid", stream="db")
    assert parse_tile("bz=2") == TileConfig(bz=2)
    assert parse_tile("bz=none,stream=db") == TileConfig(stream="db")
    with pytest.raises(ValueError, match="legal keys"):
        parse_tile("bx=2")


def test_cache_key_format():
    assert (cache_key("cpu", (4, 4, 4, 8), 8, jnp.float32)
            == "cpu|4x4x4x8|nrhs8|float32")
    assert (cache_key("tpu", (8, 8, 8, 16), 1, jnp.bfloat16)
            == "tpu|8x8x8x16|nrhs1|bfloat16")


# ------------------------------------------------------- cache dispatch


def test_pick_tile_cold_cache_is_default():
    assert pick_tile(LAT.dims, 1, jnp.float32) == DEFAULT_TILE


def test_cache_round_trip(tmp_path, monkeypatch):
    path = str(tmp_path / "cache.json")
    tuned = TileConfig(bz=2, by=1, batch="block", stream="blockspec")
    save_tuning_cache(
        {cache_key("cpu", LAT.dims, 1, jnp.float32): tuned.to_entry()},
        path=path)
    monkeypatch.setenv("REPRO_TUNING_CACHE", "1")
    monkeypatch.setenv("REPRO_TUNING_CACHE_PATH", path)
    # hit: the persisted winner comes back
    assert pick_tile(LAT.dims, 1, jnp.float32) == tuned
    # miss (different nrhs): deterministic defaults
    assert pick_tile(LAT.dims, 8, jnp.float32) == DEFAULT_TILE
    # kill switch
    monkeypatch.setenv("REPRO_TUNING_CACHE", "0")
    assert pick_tile(LAT.dims, 1, jnp.float32) == DEFAULT_TILE
    # env override beats the cache
    monkeypatch.setenv("REPRO_TUNING_CACHE", "1")
    monkeypatch.setenv("REPRO_DSLASH_TILE", "bz=1,stream=db")
    assert pick_tile(LAT.dims, 1, jnp.float32) == TileConfig(bz=1,
                                                             stream="db")


def test_cache_hit_changes_tile_not_results(tmp_path, monkeypatch, fields):
    """The acceptance property: a cache hit changes the tile selection
    (visible via pick_tile) without changing the kernel output bitwise."""
    up, pp, _ = fields
    ref = np.asarray(dslash_pallas(up, pp, MASS))       # cache disabled

    path = str(tmp_path / "cache.json")
    tuned = TileConfig(bz=1, by=1, batch="block", stream="blockspec")
    save_tuning_cache(
        {cache_key("cpu", LAT.dims, 1, jnp.float32): tuned.to_entry()},
        path=path)
    monkeypatch.setenv("REPRO_TUNING_CACHE", "1")
    monkeypatch.setenv("REPRO_TUNING_CACHE_PATH", path)
    assert pick_tile(LAT.dims, 1, jnp.float32) == tuned != DEFAULT_TILE
    out = np.asarray(dslash_pallas(up, pp, MASS))       # all-None -> cache
    assert np.array_equal(out, ref)


def test_env_tile_bitwise(monkeypatch, fields):
    up, pp, _ = fields
    ref = np.asarray(dslash_pallas(up, pp, MASS))
    monkeypatch.setenv("REPRO_DSLASH_TILE", "bz=2,stream=db")
    assert np.array_equal(np.asarray(dslash_pallas(up, pp, MASS)), ref)


# ------------------------------------------------ launch-space sweep


def test_candidates_respect_constraints():
    cands = autotune.candidates(RICH, 1, max_bz=8)
    assert cands, "empty candidate list"
    for c in cands:
        assert RICH[1] % c.bz == 0
        assert RICH[2] % c.by == 0
        assert c.batch == "block"                      # nrhs=1: no grid
        if c.stream == "db":                           # db: untiled Y only
            assert c.by == RICH[2]
    batched = autotune.candidates(RICH, 8, max_bz=8)
    assert any(c.batch == "grid" for c in batched)
    assert not any(c.batch == "grid" and c.stream == "db" for c in batched)


# one representative per launch-space knob + the all-knobs composite
# (the full candidate product is swept nightly by the autotuner itself;
# interpret-mode tracing makes each config ~10s, so tier-1 samples)
TILE_SAMPLE = [
    TileConfig(bz=1),                                  # non-default z block
    TileConfig(by=1),                                  # y-tiled splice path
    TileConfig(batch="grid"),                          # trailing batch dim
    TileConfig(stream="db"),                           # explicit dbl-buffer
    TileConfig(bz=1, by=1, batch="grid"),              # composite
]


@pytest.mark.parametrize("tile", TILE_SAMPLE, ids=str)
def test_tile_knobs_bitwise(fields, tile):
    """Each launch-space knob produces bitwise-identical output — the
    property that lets autotune skip accuracy checks."""
    up, _, ppb = fields
    ref = np.asarray(dslash_pallas(up, ppb, MASS))
    out = dslash_pallas(up, ppb, MASS, bz=tile.bz, by=tile.by,
                        batch=tile.batch, stream=tile.stream)
    assert np.array_equal(np.asarray(out), ref), tile


def test_sweep_smoke_and_autotune_roundtrip(tmp_path, monkeypatch):
    """Tiny end-to-end sweep: winner comes from the candidate list, the
    persisted entry round-trips through pick_tile."""
    dims = (2, 2, 2, 8)
    winner, results = autotune.sweep(dims, 1, max_bz=2, sweep_by=False,
                                     iters=1, reps=1)
    assert len(results) == len(autotune.candidates(dims, 1, max_bz=2,
                                                   sweep_by=False))
    assert all(r["us_warm"] > 0 for r in results)
    assert winner in autotune.candidates(dims, 1, max_bz=2, sweep_by=False)

    entries = {cache_key(jax.default_backend(), dims, 1, jnp.float32):
               {**winner.to_entry(), "us_warm": 1.0, "candidates":
                len(results)}}
    path = str(tmp_path / "cache.json")
    save_tuning_cache(entries, path=path,
                      meta={"backend": jax.default_backend()})
    monkeypatch.setenv("REPRO_TUNING_CACHE", "1")
    monkeypatch.setenv("REPRO_TUNING_CACHE_PATH", path)
    assert pick_tile(dims, 1, jnp.float32) == winner


# --------------------------------------------- launch-count invariants


def test_schur_four_launches_under_forced_tile(monkeypatch):
    """schur_normal_op stays exactly 4 kernel launches (and bitwise
    stable) under a non-default forced tile."""
    lat = LatticeShape(2, 2, 2, 4)
    ku, kp = jax.random.split(jax.random.PRNGKey(5))
    u_e, u_o = split_eo_gauge(random_gauge(ku, lat))
    p_e, _ = split_eo(random_spinor(kp, lat))
    upe, upo, ppe = pack_gauge(u_e), pack_gauge(u_o), pack_spinor(p_e)
    ref = np.asarray(schur_normal_op(upe, upo, ppe, MASS))

    monkeypatch.setenv("REPRO_DSLASH_TILE", "bz=2,stream=db")
    jaxpr = jax.make_jaxpr(
        lambda a, b, c: schur_normal_op(a, b, c, MASS))(upe, upo, ppe)
    assert len(pallas_call_eqns(jaxpr)) == 4
    assert np.array_equal(np.asarray(schur_normal_op(upe, upo, ppe, MASS)),
                          ref)


def test_checked_in_cache_is_well_formed():
    """The committed tuning_cache.json parses and every entry is a legal
    TileConfig under its own key's lattice."""
    import json
    with open(dispatch.DEFAULT_CACHE_PATH) as f:
        doc = json.load(f)
    assert doc["schema"] == 1
    assert doc["entries"], "checked-in cache has no entries"
    for key, e in doc["entries"].items():
        backend, dims, nrhs, dtype = key.split("|")
        t, z, y, x = (int(d) for d in dims.split("x"))
        tile = TileConfig(bz=e["bz"], by=e["by"], batch=e["batch"],
                          stream=e["stream"])
        assert z % tile.bz == 0 and y % tile.by == 0, key
        assert nrhs.startswith("nrhs") and int(nrhs[4:]) >= 1
        jnp.dtype(dtype)                       # parses
