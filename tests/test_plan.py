"""SolverPlan: validation, resolution, and the unified solve entry point.

The sharded plans (mesh != None) are exercised on 8 fake devices in
tests/test_distributed.py; here we pin down the single-device resolution
table — that one ``plan.solve`` call reproduces each legacy path — and
the declarative surface (field validation, layout/batch contracts, CLI
mapping).
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (LatticeShape, SolverPlan, cgnr, dslash,
                        dslash_dagger, random_gauge, random_spinor,
                        resolve_plan, solve_plan, solve_wilson_eo)
from repro.core.eo import EOContext

LAT = LatticeShape(4, 4, 4, 4)
MASS = 0.1
TOL = 1e-6


@pytest.fixture(scope="module")
def problem():
    key = jax.random.PRNGKey(7)
    ku, kb = jax.random.split(key)
    return random_gauge(ku, LAT), random_spinor(kb, LAT)


def _rel_res(u, x, b):
    r = dslash(u, x, MASS) - b
    return float(jnp.linalg.norm(r.ravel()) / jnp.linalg.norm(b.ravel()))


# ---------------------------------------------------------------------------
# Declarative surface
# ---------------------------------------------------------------------------


def test_plan_field_validation():
    with pytest.raises(ValueError, match="operator"):
        SolverPlan(operator="odd-even")
    with pytest.raises(ValueError, match="backend"):
        SolverPlan(backend="cuda")
    with pytest.raises(ValueError, match="solver"):
        SolverPlan(solver="gmres")
    with pytest.raises(ValueError, match="precision"):
        SolverPlan(precision="double")
    with pytest.raises(ValueError, match="pipecg"):
        SolverPlan(solver="pipecg", precision="mixed")
    with pytest.raises(ValueError, match="full"):
        SolverPlan(operator="eo-schur", precision="low")
    with pytest.raises(ValueError, match="nrhs"):
        SolverPlan(nrhs=0)


def test_plan_batch_and_layout_contracts(problem):
    u, b = problem
    with pytest.raises(ValueError, match="rank-7"):
        solve_plan(SolverPlan(nrhs=2), u, b, MASS)  # single-RHS b
    bb = jnp.stack([b, b, b])
    with pytest.raises(ValueError, match="batch axis"):
        solve_plan(SolverPlan(nrhs=2), u, bb, MASS)  # N mismatch
    with pytest.raises(ValueError, match="natural"):
        solve_plan(SolverPlan(operator="eo-schur"), u, b, MASS,
                   layout="packed")
    with pytest.raises(ValueError, match="layout"):
        solve_plan(SolverPlan(), u, b, MASS, layout="interleaved")


def test_resolve_builds_backend_specific_context(problem):
    u, _ = problem
    ref = resolve_plan(SolverPlan(operator="eo-schur"), u, MASS)
    assert isinstance(ref, EOContext)
    assert not ref.packed and ref.engine is None
    pal = resolve_plan(SolverPlan(operator="eo-schur", backend="pallas"),
                       u, MASS)
    assert pal.packed and pal.engine is not None and len(pal.engine) == 2
    with pytest.raises(ValueError, match="even-odd"):
        resolve_plan(SolverPlan(operator="full"), u, MASS)


# ---------------------------------------------------------------------------
# The resolution table, single-device rows
# ---------------------------------------------------------------------------


def test_full_plan_matches_plain_cgnr(problem):
    """operator='full' reproduces CGNR on D†D: same solution, packed
    working layout notwithstanding."""
    u, b = problem
    x_ref, st_ref = cgnr(lambda v: dslash(u, v, MASS),
                         lambda v: dslash_dagger(u, v, MASS), b,
                         tol=TOL, maxiter=1000)
    x, st = solve_plan(SolverPlan(operator="full"), u, b, MASS,
                       tol=TOL, maxiter=1000)
    assert bool(st.converged) and st.rhs_iterations is None
    assert _rel_res(u, x, b) < 1e-5
    assert float(jnp.max(jnp.abs(x - x_ref))) < 1e-4
    # the packed real CG is the same Krylov iteration as the complex one
    assert abs(int(st.iterations) - int(st_ref.iterations)) <= 1


def test_eo_plan_is_the_forwarder_path(problem):
    """solve_wilson_eo forwards to plan.solve: identical array out."""
    u, b = problem
    x_fwd, st_fwd = solve_wilson_eo(u, b, MASS, tol=TOL, maxiter=1000)
    x_pl, st_pl = solve_plan(SolverPlan(operator="eo-schur"), u, b, MASS,
                             tol=TOL, maxiter=1000)
    np.testing.assert_array_equal(np.asarray(x_fwd), np.asarray(x_pl))
    assert int(st_fwd.iterations) == int(st_pl.iterations)


def test_pipelined_eo_plan_converges(problem):
    """solver='pipecg' on the Schur system: same answer, pipelined loop."""
    u, b = problem
    x_cg, st_cg = solve_plan(SolverPlan(operator="eo-schur"), u, b, MASS,
                             tol=TOL, maxiter=1000)
    x_pi, st_pi = solve_plan(SolverPlan(operator="eo-schur",
                                        solver="pipecg"),
                             u, b, MASS, tol=TOL, maxiter=1000)
    assert bool(st_pi.converged)
    assert _rel_res(u, x_pi, b) < 1e-5
    assert float(jnp.max(jnp.abs(x_pi - x_cg))) < 1e-4
    # the three-term recurrence costs at most a few extra iterations
    assert int(st_pi.iterations) <= int(st_cg.iterations) + 5


def test_batched_full_plan_per_rhs_stats(problem):
    """operator='full' + nrhs: masked batched CGNR with per-RHS stats —
    the batch axis is a plan field, not an eo-schur special case."""
    u, b0 = problem
    easy = jnp.zeros_like(b0)  # zero RHS converges at iteration 0
    b = jnp.stack([b0, easy])
    x, st = solve_plan(SolverPlan(operator="full", nrhs=2), u, b, MASS,
                       tol=TOL, maxiter=1000)
    assert st.converged.shape == (2,) and bool(jnp.all(st.converged))
    assert st.rhs_iterations.shape == (2,)
    assert int(st.rhs_iterations[1]) == 0  # frozen from the start
    assert int(st.rhs_iterations[0]) == int(st.iterations)
    assert _rel_res(u, x[0], b0) < 1e-5
    np.testing.assert_array_equal(np.asarray(x[1]),
                                  np.zeros_like(np.asarray(x[1])))


def test_mesh_plan_combinations_rejected(problem):
    """Unsupported sharded combinations fail loudly, not wrongly."""
    u, b = problem
    # a fake mesh is enough: validation fires before any device work
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                             ("data", "model"))
    with pytest.raises(NotImplementedError, match="single"):
        solve_plan(SolverPlan(operator="eo-schur", precision="mixed",
                              mesh=mesh), u, b, MASS)
    with pytest.raises(NotImplementedError, match="eo-schur"):
        solve_plan(SolverPlan(operator="full", nrhs=2, mesh=mesh),
                   u, jnp.stack([b, b]), MASS)
    # the whole sharded parity stack (bulk blocks AND halo corrections)
    # hard-codes r=1 — on BOTH backends it must refuse, not answer wrongly
    with pytest.raises(NotImplementedError, match="r=1"):
        solve_plan(SolverPlan(operator="eo-schur", mesh=mesh, r=0.5),
                   u, b, MASS)


# ---------------------------------------------------------------------------
# CLI mapping (launch/solve.py is plan-driven)
# ---------------------------------------------------------------------------


def _args(**kw):
    base = dict(solver="mpcg", parity="full", backend="reference",
                operator="wilson", mu=0.0, nrhs=None, mesh="none")
    base.update(kw)
    return argparse.Namespace(**base)


def test_cli_builds_plans_from_orthogonal_axes():
    """The CLI axes map 1:1 onto plan fields — the compound legacy solver
    names (cg-pallas, cgnr_eo, ...) are gone in favour of --parity /
    --backend / --operator."""
    from repro.launch.solve import build_plan
    p = build_plan(_args(solver="cgnr", parity="eo"))
    assert (p.operator, p.solver, p.precision) == ("eo-schur", "cgnr",
                                                   "single")
    p = build_plan(_args(solver="mpcg"))
    assert (p.operator, p.precision) == ("full", "mixed")
    p = build_plan(_args(solver="cgnr", backend="pallas"))
    assert (p.operator, p.backend) == ("full", "pallas")
    p = build_plan(_args(solver="pipecg", parity="eo", backend="pallas",
                         nrhs=8))
    assert (p.operator, p.backend, p.solver, p.nrhs) == (
        "eo-schur", "pallas", "pipecg", 8)


def test_cli_selects_operator_family_from_registry():
    from repro.launch.solve import build_plan
    p = build_plan(_args(solver="cgnr", parity="eo",
                         operator="twisted-mass", mu=0.25))
    assert (p.operator_family, p.mu, p.twist) == ("twisted-mass", 0.25,
                                                  0.25)
    p = build_plan(_args(solver="cgnr"))
    assert (p.operator_family, p.twist) == ("wilson", 0.0)
    with pytest.raises(ValueError, match="twisted-mass"):
        build_plan(_args(solver="cgnr", mu=0.25))  # wilson takes no mu
