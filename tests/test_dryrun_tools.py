"""Unit tests for the dry-run analysis tooling (no 512-device env needed:
these test the pure parsing/extrapolation helpers)."""

import pytest

from repro.launch.dryrun import _extrapolate, collective_bytes


def test_collective_parser_result_types():
    hlo = """
  %ar = f32[1024]{0} all-reduce(%x), replica_groups=[16,32]<=[512], to_apply=%add
  %ag = bf16[16,4096]{1,0} all-gather(%y), replica_groups=[32,16]<=[512], dimensions={0}
  %rs = f32[256]{0} reduce-scatter(%z), replica_groups=[1,16]<=[16], to_apply=%add
  %cp = f32[8,8]{1,0} collective-permute(%w), source_target_pairs={{0,1}}
  %a2a = bf16[64]{0} all-to-all(%v), replica_groups=[2,8]<=[16]
  %notacoll = f32[999]{0} add(%a, %b)
"""
    out = collective_bytes(hlo)
    assert out["all-reduce"] == {"count": 1, "bytes": 4096}
    # all-gather operand = result / group_size(16)
    assert out["all-gather"] == {"count": 1, "bytes": 16 * 4096 * 2 // 16}
    # reduce-scatter operand = result * group_size(16)
    assert out["reduce-scatter"] == {"count": 1, "bytes": 256 * 4 * 16}
    assert out["collective-permute"] == {"count": 1, "bytes": 256}
    assert out["all-to-all"] == {"count": 1, "bytes": 128}
    assert out["total_count"] == 5


def test_collective_parser_ignores_operand_references():
    hlo = "%t = f32[4]{0} add(%all-gather.3, %all-reduce.1)\n"
    out = collective_bytes(hlo)
    assert out["total_count"] == 0


def test_extrapolation_linear():
    m1 = {"flops": 100.0, "bytes": 10.0, "coll_bytes": 4.0, "coll_count": 2}
    m2 = {"flops": 150.0, "bytes": 14.0, "coll_bytes": 6.0, "coll_count": 3}
    ext = _extrapolate(m1, m2, 1, 2, 10)
    assert ext["flops"] == pytest.approx(100 + 9 * 50)
    assert ext["bytes"] == pytest.approx(10 + 9 * 4)
    assert ext["coll_bytes"] == pytest.approx(4 + 9 * 2)
    assert ext["flops_per_layer"] == pytest.approx(50)


def test_roofline_terms_formula():
    from repro.launch.dryrun import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
    assert PEAK_FLOPS_BF16 == 197e12
    assert HBM_BW == 819e9
    assert ICI_BW == 50e9
