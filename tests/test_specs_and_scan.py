"""Dry-run spec builders (no allocation) + scan-control equivalence."""

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import transformer as T
from repro.models.config import SHAPES
from repro.models.scan_ctl import maybe_scan, scans_unrolled, unrolled_scans


def test_maybe_scan_equivalence():
    xs = jnp.arange(12.0).reshape(6, 2)

    def f(c, x):
        return c + jnp.sum(x), c
    c1, y1 = jax.lax.scan(f, jnp.float32(0), xs)
    with unrolled_scans():
        assert scans_unrolled()
        c2, y2 = maybe_scan(f, jnp.float32(0), xs)
    assert not scans_unrolled()
    assert float(c1) == float(c2)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2))


def test_unrolled_forward_matches_scanned(rng):
    cfg = configs.get_smoke("recurrentgemma-9b")  # exercises segments+tail
    params = T.init_params(cfg, rng)
    toks = jax.random.randint(rng, (2, 24), 0, cfg.vocab_size)
    a, _ = T.forward(cfg, params, toks)
    with unrolled_scans():
        b, _ = T.forward(cfg, params, toks)
    assert float(jnp.max(jnp.abs(a - b))) < 1e-5


def test_skip_reason_long_context():
    from repro.launch.specs import skip_reason
    assert skip_reason("glm4-9b", "long_500k") is not None
    assert skip_reason("rwkv6-1.6b", "long_500k") is None
    assert skip_reason("recurrentgemma-9b", "long_500k") is None
    assert skip_reason("glm4-9b", "train_4k") is None


def test_vocab_padding_only_where_needed():
    assert configs.get("seamless-m4t-large-v2").padded_vocab == 256256
    assert configs.get("glm4-9b").padded_vocab == 151552  # already aligned


def test_cell_enumeration_counts():
    """40 assigned cells; long_500k runs only for sub-quadratic archs."""
    from repro.launch.specs import skip_reason
    cells = [(a, s) for a in configs.all_arch_names() for s in SHAPES]
    assert len(cells) == 40
    skipped = [c for c in cells if skip_reason(*c)]
    assert len(skipped) == 8  # 8 full-attention archs × long_500k
