"""Shared benchmark configuration: one coherent lowering + uniform labels.

Every bench module historically decided ``interpret`` on its own, so a
single run could mix interpret-mode Pallas rows with compiled jnp rows
and nothing in the JSON said which was which.  This module is the single
source of truth:

* :func:`configure` — called once by ``run.py`` (``--backend``,
  ``--compiled``) or by ``launch_bench.sh`` via the ``BENCH_BACKEND`` /
  ``BENCH_COMPILED`` environment variables; standalone module runs read
  the same env vars, so ``python benchmarks/bench_dslash.py`` under the
  launcher behaves identically to the harness.
* :func:`interpret` — the tri-state ``interpret`` argument every kernel
  call in every bench module must pass through (None = historical
  default = interpret on CPU; False = compiled: Mosaic on device, the
  XLA half-spinor lowering on CPU).
* :func:`labels` — the uniform per-entry label block
  (``platform``/``device_kind``/``compiled``/``interpret``/``lowering``)
  merged into EVERY JSON entry of every bench module.
* :func:`time_first_warm` — the warm-vs-compile-inclusive timing
  protocol (ISSUE: perf trajectory separates ``us_first`` from
  ``us_warm``).
* :func:`peak_bandwidth_gbs` — the roofline denominator: the §6 model
  bandwidth of a timing divided by this is its achieved-vs-roofline
  ``bw_fraction``.  On CPU the peak is *measured* (a big jnp triad, the
  STREAM idiom) rather than assumed; on TPU it is the HBM peak from
  ``roofline.PEAK``.
"""

from __future__ import annotations

import functools
import os
import time

import jax
import jax.numpy as jnp

_STATE = {"configured": False, "compiled": False}


def configure(backend: str | None = None, compiled: bool = False) -> None:
    """Pin the JAX platform and the compiled/interpret mode for this
    process.  Must run before the first JAX computation when ``backend``
    is given (the platform cannot change once initialized)."""
    if backend:
        jax.config.update("jax_platform_name", backend)
        os.environ["BENCH_BACKEND"] = backend
    _STATE["configured"] = True
    _STATE["compiled"] = bool(compiled)
    os.environ["BENCH_COMPILED"] = "1" if compiled else "0"


def is_compiled() -> bool:
    if _STATE["configured"]:
        return _STATE["compiled"]
    return os.environ.get("BENCH_COMPILED", "0") in ("1", "true", "on")


def interpret() -> bool | None:
    """The tri-state ``interpret`` argument for kernel entry points."""
    return False if is_compiled() else None


def lowering_name() -> str:
    from repro.kernels.dispatch import resolve_lowering
    return resolve_lowering(interpret())


def labels() -> dict:
    """The uniform label block for every benchmark JSON entry."""
    from repro.kernels.dispatch import device_kind, resolve_interpret
    return {
        "platform": jax.default_backend(),
        "device_kind": device_kind(),
        "compiled": is_compiled(),
        "interpret": resolve_interpret(interpret()),
        "lowering": lowering_name(),
    }


def label_entry(entry: dict, **overrides) -> dict:
    """Merge the uniform labels into one entry (entry's own keys win —
    a module may legitimately pin e.g. ``interpret`` for a row that
    deliberately runs the other lowering, and must then say so)."""
    return {**labels(), **overrides, **entry}


def launch_env() -> dict:
    """The launcher-pinned environment, dumped into each bench JSON so a
    committed number carries its own repro recipe (SNIPPETS.md idiom)."""
    keys = ("XLA_FLAGS", "LD_PRELOAD", "JAX_DEFAULT_DTYPE_BITS",
            "TF_CPP_MIN_LOG_LEVEL", "BENCH_BACKEND", "BENCH_COMPILED")
    env = {k: os.environ[k] for k in keys if k in os.environ}
    env["jax_version"] = jax.__version__
    return env


def time_first_warm(fn, *args, iters: int = 3, reps: int = 2) -> dict:
    """Compile-inclusive first call + warm steady state (best-of-reps
    mean-of-iters), in microseconds."""
    t0 = time.perf_counter()
    jax.block_until_ready(fn(*args))
    us_first = (time.perf_counter() - t0) * 1e6
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) / iters)
    return {"us_first": us_first, "us_warm": best * 1e6}


@functools.lru_cache(maxsize=None)
def peak_bandwidth_gbs() -> float:
    """Roofline bandwidth denominator for the active platform, GB/s.

    CPU: measured — a 64 MiB f32 triad ``a = 2b + c`` (3 streams, the
    STREAM benchmark shape) compiled by XLA, best of 5.  Device backends:
    the HBM peak from ``roofline.PEAK`` (819 GB/s, TPU v4).
    """
    if jax.default_backend() != "cpu":
        from benchmarks.roofline import PEAK
        return PEAK["hbm"] / 1e9
    n = 1 << 24  # 16M f32 per stream = 64 MiB, far past cache
    b = jnp.arange(n, dtype=jnp.float32)
    c = jnp.ones(n, dtype=jnp.float32)
    triad = jax.jit(lambda x, y: 2.0 * x + y)
    jax.block_until_ready(triad(b, c))
    best = float("inf")
    for _ in range(5):
        t0 = time.perf_counter()
        out = triad(b, c)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return 3 * 4 * n / best / 1e9


def bw_fraction(model_bw_gbs: float) -> float:
    """Achieved-vs-roofline fraction: the bandwidth this timing would
    need at exactly the §6 model traffic, over the platform peak."""
    return model_bw_gbs / peak_bandwidth_gbs()
