"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  bench_dslash           — paper §5 sustained-GFLOP/s table
  bench_mixed_precision  — paper §2/§3.2 two-precision CG (Ref. [10])
  bench_overlap          — paper Fig. 2 transfer/compute overlap
  bench_solvers          — collectives-per-iteration (pipelined CG)
  roofline               — §Roofline aggregation from the dry-run JSONs
  bench_serve            — serving-lane latency smoke (``--with-serve``
                           only; the CI serve-smoke job runs it directly)

``--backend`` pins the JAX platform and ``--compiled`` switches EVERY
module to the compiled (non-interpret) lowering coherently through
:mod:`benchmarks.bench_config` — no module decides ``interpret`` on its
own, and every JSON entry carries the same
platform/device_kind/compiled/interpret/lowering label block.
``benchmarks/launch_bench.sh`` wraps this entry point with the pinned
XLA environment for reproducible compiled numbers.
"""

from __future__ import annotations

import argparse
import os
import sys
import traceback

# Make `python benchmarks/run.py` work from anywhere: the interpreter puts
# benchmarks/ (not the repo root) on sys.path for direct script runs.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

MODULE_NAMES = ["dslash", "mixed_precision", "overlap", "solvers",
                "roofline"]


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(description="benchmark CSV sweep")
    parser.add_argument("--backend", choices=["cpu", "gpu", "tpu"],
                        default=None,
                        help="pin the JAX platform (default: jax's own "
                             "backend selection)")
    parser.add_argument("--compiled", action="store_true",
                        help="run kernels through the compiled lowering "
                             "(Mosaic on gpu/tpu, the XLA half-spinor "
                             "path on cpu) instead of the historical "
                             "interpret-on-CPU default")
    parser.add_argument("--only", nargs="+", choices=MODULE_NAMES,
                        default=None,
                        help="run only these modules (default: all)")
    parser.add_argument("--with-serve", action="store_true",
                        help="append the serving-lane smoke (slower; it "
                             "spins up the batching server)")
    args = parser.parse_args(argv)

    # configure BEFORE the bench modules import jax and read the mode
    from benchmarks import bench_config
    bench_config.configure(backend=args.backend, compiled=args.compiled)

    from benchmarks import (bench_dslash, bench_mixed_precision,
                            bench_overlap, bench_solvers, roofline)
    by_name = {"dslash": bench_dslash,
               "mixed_precision": bench_mixed_precision,
               "overlap": bench_overlap, "solvers": bench_solvers,
               "roofline": roofline}
    names = args.only or MODULE_NAMES
    modules = [(n, by_name[n]) for n in names]
    if args.with_serve:
        from benchmarks import bench_serve
        modules.append(("serve", bench_serve))
    print("name,us_per_call,derived")
    failed = 0
    for name, mod in modules:
        try:
            for row in mod.run():
                n, us, derived = row
                print(f"{n},{us:.1f},{derived}")
        except Exception:
            failed += 1
            traceback.print_exc()
            print(f"{name},-1,ERROR")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
