"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  bench_dslash           — paper §5 sustained-GFLOP/s table
  bench_mixed_precision  — paper §2/§3.2 two-precision CG (Ref. [10])
  bench_overlap          — paper Fig. 2 transfer/compute overlap
  bench_solvers          — collectives-per-iteration (pipelined CG)
  roofline               — §Roofline aggregation from the dry-run JSONs
  bench_serve            — serving-lane latency smoke (``--with-serve``
                           only; the CI serve-smoke job runs it directly)
"""

from __future__ import annotations

import argparse
import os
import sys
import traceback

# Make `python benchmarks/run.py` work from anywhere: the interpreter puts
# benchmarks/ (not the repo root) on sys.path for direct script runs.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks import (bench_dslash, bench_mixed_precision,  # noqa: E402
                        bench_overlap, bench_solvers, roofline)  # noqa: E402

MODULES = [("dslash", bench_dslash),
           ("mixed_precision", bench_mixed_precision),
           ("overlap", bench_overlap), ("solvers", bench_solvers),
           ("roofline", roofline)]


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(description="benchmark CSV sweep")
    parser.add_argument("--with-serve", action="store_true",
                        help="append the serving-lane smoke (slower; it "
                             "spins up the batching server)")
    args = parser.parse_args(argv)
    modules = list(MODULES)
    if args.with_serve:
        from benchmarks import bench_serve
        modules.append(("serve", bench_serve))
    print("name,us_per_call,derived")
    failed = 0
    for name, mod in modules:
        try:
            for row in mod.run():
                n, us, derived = row
                print(f"{n},{us:.1f},{derived}")
        except Exception:
            failed += 1
            traceback.print_exc()
            print(f"{name},-1,ERROR")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
