#!/usr/bin/env python
"""CI guard: the even-odd Schur CGNR must not regress on the smoke lattice.

Compares the ``eo_smoke`` entry of a freshly generated ``BENCH_solvers.json``
against the committed ``benchmarks/BENCH_solvers_baseline.json``, plus the
``batch_sweep`` per-N iteration counts of the multi-RHS batched solve (the
masked batched loop must converge in as few iterations as the committed
run for every batch size N).  Iteration count is an ALGORITHMIC property
(deterministic seed, fixed tolerance), so it is the cheap, noise-free
regression signal — wall-clock on shared CI runners is not.  A small slack
absorbs cross-platform float reduction differences.

Usage:  check_solver_regression.py [BENCH_solvers.json] [baseline.json]
        check_solver_regression.py --generate [baseline.json]

``--generate`` runs the smoke solves itself (no full benchmark harness
needed) and guards the result — the standalone/dev mode.  CI uses the
artifact-comparing mode in the smoke-bench job; the BLOCKING guard is
tests/test_eo.py::test_eo_iteration_count_vs_committed_baseline, which
checks the same baseline inside the tier-1 suite.
Exit 0 on pass, 1 on regression or missing/invalid inputs.
"""

from __future__ import annotations

import json
import os
import sys

SLACK_ITERS = 2  # float-reduction jitter across platforms, not a budget

GUARDED_KEYS = ("cgnr_eo_iters", "cgnr_eo_pallas_iters")

# the guarded solve is only comparable if its parameters match the baseline
PROBLEM_KEYS = ("lattice", "mass", "tol", "seed")


def _check_batch_sweep(cur: dict, base: dict) -> bool:
    """Guard the per-N iteration counts of the multi-RHS batched smoke.

    The batched loop's trip count is the slowest RHS's iteration count —
    deterministic for the committed seed, so regressions in the masked
    batched solver (or the batched kernels feeding it) show up here.
    Returns True on failure.
    """
    cur_bs, base_bs = cur.get("batch_sweep"), base.get("batch_sweep")
    if not base_bs:
        return False  # baseline predates the batched path: nothing to guard
    if not cur_bs:
        print("solver-regression guard: baseline has 'batch_sweep' but the "
              "current BENCH_solvers.json does not")
        return True
    for key in PROBLEM_KEYS:
        if cur_bs.get(key) != base_bs.get(key):
            print(f"solver-regression guard: batch_sweep '{key}' mismatch "
                  f"({cur_bs.get(key)} vs baseline {base_bs.get(key)}) — "
                  "regenerate benchmarks/BENCH_solvers_baseline.json")
            return True
    cur_by_n = {e.get("n_rhs"): e for e in cur_bs.get("entries", [])}
    failed = False
    for ref in base_bs.get("entries", []):
        n = ref.get("n_rhs")
        got = cur_by_n.get(n)
        if got is None:
            print(f"solver-regression guard: batch_sweep entry n_rhs={n} "
                  "missing from current run")
            failed = True
            continue
        limit = int(ref["iters"]) + SLACK_ITERS
        verdict = "OK" if int(got["iters"]) <= limit else "REGRESSION"
        print(f"  batched n_rhs={n}: {got['iters']} iters "
              f"(baseline {ref['iters']}, limit {limit}) {verdict}")
        failed = failed or int(got["iters"]) > limit
    return failed


def _check_eo_sharded(cur: dict, base: dict) -> bool:
    """Guard the sharded batched EO Schur solve's iteration count.

    The fused one-psum-per-iteration reduction and the parity halo
    corrections must not change the Krylov math: the 8-way sharded
    pipelined CGNR's trip count is deterministic for the committed seed
    and compared directly (same slack as the single-device entries).
    Returns True on failure.
    """
    cur_s, base_s = cur.get("eo_sharded"), base.get("eo_sharded")
    if not base_s:
        return False  # baseline predates the sharded path: nothing to guard
    if not cur_s:
        print("solver-regression guard: baseline has 'eo_sharded' but the "
              "current BENCH_solvers.json does not")
        return True
    for key in PROBLEM_KEYS + ("n_rhs", "mesh", "solver"):
        if cur_s.get(key) != base_s.get(key):
            print(f"solver-regression guard: eo_sharded '{key}' mismatch "
                  f"({cur_s.get(key)} vs baseline {base_s.get(key)}) — "
                  "regenerate benchmarks/BENCH_solvers_baseline.json")
            return True
    limit = int(base_s["iters"]) + SLACK_ITERS
    verdict = "OK" if int(cur_s["iters"]) <= limit else "REGRESSION"
    print(f"  eo_sharded n_rhs={cur_s['n_rhs']} mesh={cur_s['mesh']}: "
          f"{cur_s['iters']} iters (baseline {base_s['iters']}, "
          f"limit {limit}) {verdict}")
    return int(cur_s["iters"]) > limit


def main(argv: list[str]) -> int:
    base_path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                             "BENCH_solvers_baseline.json")
    if len(argv) > 1 and argv[1] == "--generate":
        if len(argv) > 2:
            base_path = argv[2]
        sys.path.insert(0, os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))))
        from benchmarks import bench_solvers
        cur = {"eo_smoke": bench_solvers._run_eo_smoke(),
               "batch_sweep": bench_solvers._run_batch_sweep(),
               "eo_sharded": bench_solvers._run_eo_sharded()}
    else:
        cur_path = argv[1] if len(argv) > 1 else "BENCH_solvers.json"
        if len(argv) > 2:
            base_path = argv[2]
        try:
            with open(cur_path) as f:
                cur = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"solver-regression guard: cannot load {cur_path}: {e}")
            return 1

    try:
        with open(base_path) as f:
            base = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"solver-regression guard: cannot load {base_path}: {e}")
        return 1

    cur_eo = cur.get("eo_smoke")
    base_eo = base.get("eo_smoke")
    if not cur_eo or not base_eo:
        print("solver-regression guard: missing 'eo_smoke' section "
              f"(current: {bool(cur_eo)}, baseline: {bool(base_eo)})")
        return 1
    for key in PROBLEM_KEYS:
        if cur_eo.get(key) != base_eo.get(key):
            print(f"solver-regression guard: '{key}' mismatch "
                  f"({cur_eo.get(key)} vs baseline {base_eo.get(key)}) — "
                  "regenerate benchmarks/BENCH_solvers_baseline.json")
            return 1

    failed = False
    for key in GUARDED_KEYS:
        got, ref = cur_eo.get(key), base_eo.get(key)
        if got is None or ref is None:
            print(f"solver-regression guard: '{key}' missing "
                  f"(current: {got}, baseline: {ref})")
            failed = True
            continue
        limit = int(ref) + SLACK_ITERS
        verdict = "OK" if int(got) <= limit else "REGRESSION"
        print(f"  {key}: {got} (baseline {ref}, limit {limit}) {verdict}")
        failed = failed or int(got) > limit
    failed = _check_batch_sweep(cur, base) or failed
    failed = _check_eo_sharded(cur, base) or failed
    if failed:
        print("solver-regression guard: FAILED — a guarded iteration count "
              f"regressed on the {base_eo['lattice']} smoke lattice (see "
              "the REGRESSION line(s) above)")
        return 1
    print("solver-regression guard: passed")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
