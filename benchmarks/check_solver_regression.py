#!/usr/bin/env python
"""CI guard: solver iteration counts must not regress on the smoke lattice.

Compares a freshly generated ``BENCH_solvers.json`` against the committed
``benchmarks/BENCH_solvers_baseline.json``:

* ``eo_smoke``    — single-RHS Schur CGNR, reference + Pallas backends;
* ``eo_smoke_tm`` — the same solve through the operator registry's
  twisted-mass family (site-term epilogues folded into the same kernels);
* ``batch_sweep`` — per-N iteration counts of the multi-RHS batched solve;
* ``eo_sharded``  — the 8-way sharded pipelined Schur solve's trip count;
* ``blockcg_16rhs`` — block CGNR over 16 RHS at near-critical mass: the
  iteration/matvec counts AND the headline inequality (total matvecs
  <= 0.7 x 16 x the single-RHS count, ROADMAP item 2);
* ``eo_deflation`` — EigCG harvest + deflated re-solve: exact counts and
  the strict deflated < undeflated iteration drop, verified against the
  original system.

Iteration count is an ALGORITHMIC property (deterministic seed, fixed
tolerance), so it is the cheap, noise-free regression signal — wall-clock
on shared CI runners is not.  A small slack absorbs cross-platform float
reduction differences.

EVERY guarded entry is checked and the full expected-vs-actual table is
printed — a failure never hides the state of the other entries behind the
first mismatch.

Usage:  check_solver_regression.py [BENCH_solvers.json] [baseline.json]
        check_solver_regression.py --generate [baseline.json]
        check_solver_regression.py --serve [BENCH_serve.json] [baseline.json]
        check_solver_regression.py --chaos [BENCH_serve.json] [baseline.json]
        check_solver_regression.py --resume [BENCH_resume.json] [baseline.json]
        check_solver_regression.py --perf [BENCH_perf_trajectory.json]

``--perf`` guards the compiled-backend perf trajectory (produced by
``benchmarks/launch_bench.sh`` -> ``perf_trajectory.py``): within the
LATEST snapshot every compiled Pallas dslash row must beat the jnp
reference at equal N on the same lattice (the interpret-mode 79-vs-1179
inversion stays closed — a machine-independent invariant), every gated
row must carry an achieved-vs-roofline ``bw_fraction``, and versus the
previous snapshot on the SAME device_kind the warm sites·RHS/s and
``bw_fraction`` must not collapse below ``PERF_SLACK`` of their prior
values (generous: shared-runner wall-clock is noisy and absolute
throughput varies between hosts of one device_kind; the gate exists to
catch structural collapses — losing a compiled lowering is 10x+ —
while iteration counts remain the precise signal).

``--generate`` runs the smoke solves itself (no full benchmark harness
needed) and guards the result — the BLOCKING ``bench-guard`` CI job and
the standalone/dev mode.  ``--serve`` guards a serving-lane report
(benchmarks/bench_serve.py --verify) against the baseline's ``serve``
section: request volume, direct-solve verification, plan-cache hit rate
after warmup, that coalescing reached a multi-RHS rung, convergence, and
the iteration-count ceiling.  ``--chaos`` guards a fault-injection report
(bench_serve.py --chaos) against the baseline's ``chaos`` section: every
poisoned request failed classified, zero healthy casualties (blast radius
exactly 1), and both fault surfaces actually exercised.  ``--resume``
guards a crash-resume lane report (benchmarks/bench_resume.py): SIGKILLed
solves resumed from their latest checkpoint (including across mesh
shapes) and a killed server's journal replayed to zero incomplete
entries.  The artifact-comparing default mode stays in the non-blocking
smoke-bench job for timing context.
Exit 0 on pass, 1 on regression or missing/invalid inputs.
"""

from __future__ import annotations

import json
import os
import sys

SLACK_ITERS = 2  # float-reduction jitter across platforms, not a budget

# --perf: warm throughput / bw_fraction may not fall below this fraction
# of the previous same-device snapshot (wall-clock on shared runners is
# noisy, so the slack is deliberately generous — a real regression from
# e.g. losing the compiled lowering is 10x+, far past any noise)
PERF_SLACK = 0.5

# section -> guarded iteration-count keys inside it
GUARDED_SECTIONS = {
    "eo_smoke": ("cgnr_eo_iters", "cgnr_eo_pallas_iters"),
    "eo_smoke_tm": ("cgnr_eo_tm_iters", "cgnr_eo_tm_pallas_iters"),
}

# section -> extra problem keys beyond PROBLEM_KEYS that must match
EXTRA_PROBLEM_KEYS = {"eo_smoke_tm": ("mu", "operator")}

# the guarded solve is only comparable if its parameters match the baseline
PROBLEM_KEYS = ("lattice", "mass", "tol", "seed")


class _Table:
    """Collects every comparison; prints one expected-vs-actual table."""

    def __init__(self):
        self.rows: list[tuple[str, str, object, object, object, str]] = []

    def add(self, section, metric, baseline, actual, limit, verdict):
        self.rows.append((section, metric, baseline, actual, limit, verdict))

    def mismatch(self, section, metric, baseline, actual):
        self.add(section, metric, baseline, actual, "-", "MISMATCH")

    def missing(self, section, metric, baseline):
        self.add(section, metric, baseline, "-", "-", "MISSING")

    def iters(self, section, metric, baseline, actual):
        limit = int(baseline) + SLACK_ITERS
        verdict = "OK" if int(actual) <= limit else "REGRESSION"
        self.add(section, metric, int(baseline), int(actual), limit, verdict)

    @property
    def failed(self) -> bool:
        return any(r[-1] != "OK" for r in self.rows)

    def print(self):
        header = ("section", "metric", "baseline", "actual", "limit",
                  "verdict")
        rows = [header] + [tuple(str(v) for v in r) for r in self.rows]
        widths = [max(len(r[i]) for r in rows) for i in range(len(header))]
        for i, row in enumerate(rows):
            print("  " + "  ".join(v.ljust(w) for v, w in zip(row, widths)))
            if i == 0:
                print("  " + "  ".join("-" * w for w in widths))


def _problem_match(table, name, cur, base, extra=()) -> bool:
    """Record (and fail on) any problem-parameter drift; True if usable."""
    ok = True
    for key in PROBLEM_KEYS + tuple(extra):
        if cur.get(key) != base.get(key):
            table.mismatch(name, key, base.get(key), cur.get(key))
            ok = False
    return ok


def _check_section(table, name, cur, base):
    """Guard the flat iteration-count keys of one smoke section."""
    keys = GUARDED_SECTIONS[name]
    base_s = base.get(name)
    if not base_s:
        return  # baseline predates this section: nothing to guard
    cur_s = cur.get(name)
    if not cur_s:
        table.missing(name, "(section)", "present")
        return
    if not _problem_match(table, name, cur_s, base_s,
                          extra=EXTRA_PROBLEM_KEYS.get(name, ())):
        return
    for key in keys:
        got, ref = cur_s.get(key), base_s.get(key)
        if got is None or ref is None:
            table.missing(name, key, ref)
            continue
        table.iters(name, key, ref, got)


def _check_batch_sweep(table, cur, base):
    """Guard the per-N iteration counts of the multi-RHS batched smoke."""
    base_bs = base.get("batch_sweep")
    if not base_bs:
        return
    cur_bs = cur.get("batch_sweep")
    if not cur_bs:
        table.missing("batch_sweep", "(section)", "present")
        return
    if not _problem_match(table, "batch_sweep", cur_bs, base_bs):
        return
    cur_by_n = {e.get("n_rhs"): e for e in cur_bs.get("entries", [])}
    for ref in base_bs.get("entries", []):
        n = ref.get("n_rhs")
        got = cur_by_n.get(n)
        if got is None:
            table.missing("batch_sweep", f"n_rhs={n} iters", ref.get("iters"))
            continue
        table.iters("batch_sweep", f"n_rhs={n} iters", ref["iters"],
                    got["iters"])


def _check_eo_sharded(table, cur, base):
    """Guard the sharded batched EO Schur solve's iteration count."""
    base_s = base.get("eo_sharded")
    if not base_s:
        return
    cur_s = cur.get("eo_sharded")
    if not cur_s:
        table.missing("eo_sharded", "(section)", "present")
        return
    if not _problem_match(table, "eo_sharded", cur_s, base_s,
                          extra=("n_rhs", "mesh", "solver")):
        return
    table.iters("eo_sharded", "iters", base_s["iters"], cur_s["iters"])


def _check_blockcg(table, cur, base):
    """Guard the block-CG row: exact counts + the 0.7x matvec headline.

    Iteration and matvec counts hold the usual baseline+slack ceiling;
    additionally the ISSUE-9 acceptance inequality is recomputed from the
    CURRENT run — total matvecs for the 16-RHS block solve must stay
    <= max_matvec_ratio x (16 x the single-RHS matvec count) — so the
    win is guarded as a property, not just pinned as a number.
    """
    base_s = base.get("blockcg_16rhs")
    if not base_s:
        return  # baseline predates block CG: nothing to guard
    cur_s = cur.get("blockcg_16rhs")
    if not cur_s:
        table.missing("blockcg_16rhs", "(section)", "present")
        return
    if not _problem_match(table, "blockcg_16rhs", cur_s, base_s,
                          extra=("n_rhs", "backend")):
        return
    for key in ("single_iters", "single_matvecs", "blockcg_iters",
                "blockcg_matvecs"):
        table.iters("blockcg_16rhs", key, base_s[key], cur_s[key])
    for key in ("all_converged", "all_verified"):
        ok = bool(cur_s.get(key, False))
        table.add("blockcg_16rhs", key, True, ok, "-",
                  "OK" if ok else "REGRESSION")
    ratio_cap = float(base_s.get("max_matvec_ratio", 0.7))
    total = int(cur_s.get("total_matvecs", 0))
    cap = ratio_cap * int(cur_s.get("total_matvecs_single16", 0))
    table.add("blockcg_16rhs", "total_matvecs", f"<={cap:.0f}", total,
              f"{ratio_cap}x16xsingle",
              "OK" if total and total <= cap else "REGRESSION")


def _check_eo_deflation(table, cur, base):
    """Guard the EigCG row: exact counts + the strict iteration drop.

    The deflated solve must take STRICTLY fewer iterations than the
    identical undeflated solve (the warm-gauge-field product the serving
    cache sells), and still pass true-residual verification against the
    ORIGINAL system.
    """
    base_s = base.get("eo_deflation")
    if not base_s:
        return  # baseline predates deflation: nothing to guard
    cur_s = cur.get("eo_deflation")
    if not cur_s:
        table.missing("eo_deflation", "(section)", "present")
        return
    if not _problem_match(table, "eo_deflation", cur_s, base_s,
                          extra=("nev", "m_max", "harvest_tol", "backend")):
        return
    for key in ("harvest_iters", "harvest_matvecs", "undeflated_iters",
                "undeflated_matvecs", "deflated_iters", "deflated_matvecs"):
        table.iters("eo_deflation", key, base_s[key], cur_s[key])
    drop = (int(cur_s.get("deflated_iters", 1 << 30))
            < int(cur_s.get("undeflated_iters", 0)))
    table.add("eo_deflation", "deflated<undeflated",
              True, drop, "-", "OK" if drop else "REGRESSION")
    for key in ("harvest_verified", "deflated_converged",
                "deflated_verified"):
        ok = bool(cur_s.get(key, False))
        table.add("eo_deflation", key, True, ok, "-",
                  "OK" if ok else "REGRESSION")


def _check_ckpt_overhead(table, cur, base):
    """Guard the segmented (checkpointed) smoke solve.

    Three properties, all algorithmic: the one-shot iteration count holds
    the usual baseline+slack ceiling, the SEGMENTED solve takes exactly
    as many iterations as the one-shot solve it mirrors, and the two
    iterates are bitwise equal — segmenting may cost snapshot I/O, never
    Krylov math.  Wall-clock overhead stays unguarded.
    """
    base_s = base.get("ckpt_overhead")
    if not base_s:
        return  # baseline predates durable solves: nothing to guard
    cur_s = cur.get("ckpt_overhead")
    if not cur_s:
        table.missing("ckpt_overhead", "(section)", "present")
        return
    if not _problem_match(table, "ckpt_overhead", cur_s, base_s,
                          extra=("every_iters",)):
        return
    table.iters("ckpt_overhead", "iters", base_s["iters"], cur_s["iters"])
    same = (int(cur_s.get("iters_checkpointed", -1))
            == int(cur_s.get("iters", -2)))
    table.add("ckpt_overhead", "iters_checkpointed", cur_s.get("iters"),
              cur_s.get("iters_checkpointed"), "-",
              "OK" if same else "REGRESSION")
    bw = bool(cur_s.get("bitwise_equal", False))
    table.add("ckpt_overhead", "bitwise_equal", True, bw, "-",
              "OK" if bw else "REGRESSION")


def _check_resume(table, cur, base):
    """Guard a crash-resume lane report (benchmarks/bench_resume.py).

    The lane SIGKILLs real subprocesses and the report records what
    recovery achieved; the gate demands each experiment actually ran to
    its kill (``killed`` — an early-exiting child proves nothing) and
    that recovery met the durability contract (DESIGN.md §11):

    * solver: resumed from a checkpoint step >= 1 and the resumed solve
      passed true-residual verification;
    * elastic: a solve checkpointed on a mesh resumed VERIFIED without
      the mesh (smaller-world restart);
    * journal: the killed server left >= min_incomplete journaled
      requests unfinished and recovery replayed EVERY one of them.
    """
    base_r = base.get("resume")
    if not base_r:
        table.missing("resume", "(baseline section)", "present")
        return
    for lane in ("solver", "elastic"):
        s = cur.get(lane)
        if not s:
            table.missing(lane, "(report section)", "present")
            continue
        table.add(lane, "killed", True, s.get("killed"), "-",
                  "OK" if s.get("killed") else "REGRESSION")
        step = s.get("resumed_from_step")
        table.add(lane, "resumed_from_step", ">=1", step, 1,
                  "OK" if isinstance(step, int) and step >= 1
                  else "REGRESSION")
        table.add(lane, "resume_ok", True, s.get("resume_ok"), "-",
                  "OK" if s.get("resume_ok") else "REGRESSION")
    j = cur.get("journal")
    if not j:
        table.missing("journal", "(report section)", "present")
        return
    table.add("journal", "killed", True, j.get("killed"), "-",
              "OK" if j.get("killed") else "REGRESSION")
    found = int(j.get("incomplete_found", 0))
    need = int(base_r.get("min_incomplete", 1))
    table.add("journal", "incomplete_found", f">={need}", found, need,
              "OK" if found >= need else "REGRESSION")
    recovered = int(j.get("recovered", -1))
    table.add("journal", "recovered", found, recovered, found,
              "OK" if recovered == found else "REGRESSION")
    left = int(j.get("incomplete_after_recovery", -1))
    table.add("journal", "incomplete_after_recovery", 0, left, 0,
              "OK" if left == 0 else "REGRESSION")


def _check_serve(table, cur, base):
    """Guard a serving-lane report against the baseline ``serve`` section.

    The serving lane's algorithmic signal is the same as the solver
    smoke's (iteration counts, deterministic seed) plus the serving
    invariants: every response verified against a direct solve, the
    compiled-plan cache effective after warmup, and request coalescing
    actually reaching a multi-RHS ladder rung.  Throughput/latency stay
    unguarded — wall-clock on shared runners is noise.
    """
    base_s = base.get("serve")
    if not base_s:
        table.missing("serve", "(baseline section)", "present")
        return
    if not _problem_match(table, "serve", cur, base_s, extra=("backend",)):
        return
    n = int(cur.get("requests", 0))
    need = int(base_s.get("min_requests", 0))
    table.add("serve", "requests", f">={need}", n, need,
              "OK" if n >= need else "REGRESSION")
    conv = bool(cur.get("all_converged", False))
    table.add("serve", "all_converged", True, conv, "-",
              "OK" if conv else "REGRESSION")
    v = cur.get("verify")
    if not v:
        # the lane must run with --verify; a report without the section
        # never passed the direct-solve comparison
        table.missing("serve", "verify", "passed")
    else:
        table.add("serve", "verify.max_abs_err", f"<={v.get('tol')}",
                  v.get("max_abs_err"), v.get("tol"),
                  "OK" if v.get("passed") else "REGRESSION")
    rate = float(cur.get("request_cache_hit_rate", 0.0))
    min_rate = float(base_s.get("min_hit_rate", 0.9))
    table.add("serve", "request_cache_hit_rate", f">={min_rate}",
              round(rate, 3), min_rate,
              "OK" if rate >= min_rate else "REGRESSION")
    min_rung = int(base_s.get("min_coalesced_rung", 4))
    rungs = {int(k): int(c) for k, c in cur.get("rung_hist", {}).items()}
    coalesced = any(r >= min_rung and c > 0 for r, c in rungs.items())
    table.add("serve", "coalesced_rung", f">={min_rung}",
              sorted(rungs) if rungs else "-", min_rung,
              "OK" if coalesced else "REGRESSION")
    iters_max = cur.get("iters", {}).get("max")
    if iters_max is None:
        table.missing("serve", "iters.max", base_s.get("max_iters"))
    else:
        table.iters("serve", "iters.max", base_s["max_iters"], iters_max)
    _check_deflation_serve(table, cur, base)


def _check_deflation_serve(table, cur, base):
    """Guard the warm-gauge deflation lane embedded in the serve report.

    bench_serve.py runs a second, light-mass workload with the deflation
    cache ON and embeds its report under ``deflation_serve``.  The gate
    is the ISSUE-9 serving acceptance: enough requests were served off a
    deflation-cache hit (``min_hits``), every hit converged in STRICTLY
    fewer iterations than the cold solve on its coalesce key, everything
    converged+verified, and the direct-oracle comparison (re-solved with
    the SAME basis) passed.
    """
    base_d = base.get("deflation_serve")
    if not base_d:
        return  # baseline predates the deflation lane: nothing to guard
    d = cur.get("deflation_serve")
    if not d:
        table.missing("deflation_serve", "(report section)", "present")
        return
    if not _problem_match(table, "deflation_serve", d, base_d,
                          extra=("backend",)):
        return
    conv = bool(d.get("all_converged", False))
    table.add("deflation_serve", "all_converged", True, conv, "-",
              "OK" if conv else "REGRESSION")
    drop = d.get("deflation_drop", {})
    hits = int(drop.get("hit_requests", 0))
    need = int(base_d.get("min_hits", 1))
    table.add("deflation_serve", "hit_requests", f">={need}", hits, need,
              "OK" if hits >= need else "REGRESSION")
    dropped = bool(drop.get("all_hits_dropped", False))
    table.add("deflation_serve", "all_hits_dropped", True, dropped, "-",
              "OK" if dropped else "REGRESSION")
    harvests = int(d.get("deflation", {}).get("harvests", 0))
    need_h = int(base_d.get("min_harvests", 1))
    table.add("deflation_serve", "harvests", f">={need_h}", harvests,
              need_h, "OK" if harvests >= need_h else "REGRESSION")
    v = d.get("verify")
    if not v:
        table.missing("deflation_serve", "verify", "passed")
    else:
        table.add("deflation_serve", "verify.max_abs_err",
                  f"<={v.get('tol')}", v.get("max_abs_err"), v.get("tol"),
                  "OK" if v.get("passed") else "REGRESSION")


def _check_chaos(table, cur, base):
    """Guard a chaos-lane report against the baseline ``chaos`` section.

    The chaos lane (bench_serve.py --chaos --chaos-fault-every N) poisons
    a fraction of the RHS stream and injects transient gauge faults into
    primary batch dispatches.  The containment contract (DESIGN.md §10):

    * every poisoned request fails WITH A CLASSIFIED VERDICT — none is
      silently served;
    * blast radius is exactly 1: zero healthy requests fail or come back
      unverified, however many shared a batch with a poison or a fault;
    * the lane actually exercised both fault surfaces (min_poisoned
      poisons admitted to the stream, transient injection enabled).
    """
    base_c = base.get("chaos")
    if not base_c:
        table.missing("chaos", "(baseline section)", "present")
        return
    c = cur.get("chaos")
    if not c:
        # the report was not produced with --chaos: nothing was injected,
        # so the containment properties were never exercised
        table.missing("chaos", "(report section)", "present")
        return
    poisoned = int(c.get("poisoned", 0))
    need_poison = int(base_c.get("min_poisoned", 1))
    table.add("chaos", "poisoned", f">={need_poison}", poisoned, need_poison,
              "OK" if poisoned >= need_poison else "REGRESSION")
    failed = int(c.get("poisoned_failed", 0))
    table.add("chaos", "poisoned_failed", poisoned, failed, poisoned,
              "OK" if failed == poisoned else "REGRESSION")
    served = int(c.get("poisoned_served", -1))
    table.add("chaos", "poisoned_served", 0, served, 0,
              "OK" if served == 0 else "REGRESSION")
    for metric in ("healthy_failed", "healthy_unverified"):
        got = int(c.get(metric, -1))
        table.add("chaos", metric, 0, got, 0,
                  "OK" if got == 0 else "REGRESSION")
    fault_every = int(c.get("fault_every", 0))
    need_fault = bool(base_c.get("require_fault_injection", True))
    if need_fault:
        table.add("chaos", "fault_every", ">=1", fault_every, 1,
                  "OK" if fault_every >= 1 else "REGRESSION")
    ok = bool(c.get("containment_ok", False))
    table.add("chaos", "containment_ok", True, ok, "-",
              "OK" if ok else "REGRESSION")
    v = cur.get("verify")
    if v is not None:
        # when the chaos lane also re-solves served responses directly,
        # the comparison must still pass — containment may not trade
        # correctness of the healthy stream for isolation
        table.add("chaos", "verify.max_abs_err", f"<={v.get('tol')}",
                  v.get("max_abs_err"), v.get("tol"),
                  "OK" if v.get("passed") else "REGRESSION")


def _check_perf(table: _Table, doc: dict) -> None:
    """Gate the compiled-backend perf trajectory (see module docstring)."""
    snaps = doc.get("snapshots") or []
    if not snaps:
        table.missing("perf", "snapshots", ">=1")
        return
    latest = snaps[-1]
    entries = {e["name"]: e for e in latest.get("entries", [])}

    # --- invariant: the compiled Pallas lane exists and is non-interpret
    pallas = {n: e for n, e in entries.items()
              if n.startswith("dslash_pallas_compiled")}
    if not pallas:
        table.missing("perf", "dslash_pallas_compiled*", "present")
    for name, e in sorted(pallas.items()):
        interp = bool(e.get("interpret", False))
        table.add("perf", f"{name}.interpret", False, interp, "-",
                  "OK" if not interp else "REGRESSION")
        # --- invariant: compiled Pallas beats the jnp reference at the
        # same N on the same lattice (names end with the lattice dims)
        lattice = name.rsplit("_", 1)[-1]
        n = int(e.get("n_rhs", 1))
        jnp_name = (f"dslash_jnp_{lattice}" if n == 1
                    else f"dslash_jnp_nrhs{n}_{lattice}")
        ref = entries.get(jnp_name)
        if ref is None:
            table.missing("perf", jnp_name, "present")
            continue
        got = float(e.get("sites_rhs_per_s", 0.0))
        need = float(ref.get("sites_rhs_per_s", 0.0))
        table.add("perf", f"{name}>=jnp", f">={need:.0f}", round(got),
                  round(need), "OK" if got >= need else "REGRESSION")

    # --- invariant: every gated row carries a roofline fraction
    for name, e in sorted(entries.items()):
        if name.startswith("dslash_") and "bw_fraction" not in e:
            table.missing("perf", f"{name}.bw_fraction", "present")

    # --- trajectory: compare against the previous same-device snapshot.
    # bw_fraction is normalized by the running host's OWN measured peak,
    # so it travels between runners of the same device_kind; absolute
    # sites_rhs_per_s is host-dependent, which is what the generous
    # PERF_SLACK is for — the regression this catches is structural
    # (losing a compiled lowering is 10x+), not runner jitter.
    prev = next((s for s in reversed(snaps[:-1])
                 if s.get("device_kind") == latest.get("device_kind")
                 and s.get("platform") == latest.get("platform")), None)
    if prev is None:
        table.add("perf", "trajectory", "first snapshot",
                  "first snapshot", "-", "OK")
        return
    prev_entries = {e["name"]: e for e in prev.get("entries", [])}
    for name, e in sorted(entries.items()):
        p = prev_entries.get(name)
        if p is None:
            continue
        for metric in ("sites_rhs_per_s", "bw_fraction"):
            if metric not in e or not p.get(metric):
                continue
            floor = PERF_SLACK * float(p[metric])
            got = float(e[metric])
            table.add("perf", f"{name}.{metric}",
                      f">={floor:.3g}", f"{got:.3g}", f"{floor:.3g}",
                      "OK" if got >= floor else "REGRESSION")


def _load(path: str, what: str) -> dict | None:
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"solver-regression guard: cannot load {what} {path}: {e}")
        return None


def main(argv: list[str]) -> int:
    base_path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                             "BENCH_solvers_baseline.json")
    if len(argv) > 1 and argv[1] == "--perf":
        traj_path = argv[2] if len(argv) > 2 else os.environ.get(
            "BENCH_PERF_TRAJECTORY_JSON", "BENCH_perf_trajectory.json")
        doc = _load(traj_path, "perf trajectory")
        if doc is None:
            return 1
        table = _Table()
        _check_perf(table, doc)
        table.print()
        if table.failed:
            print("perf guard: FAILED — see the non-OK rows above")
            return 1
        print("perf guard: passed")
        return 0
    if len(argv) > 1 and argv[1] in ("--serve", "--chaos", "--resume"):
        mode = argv[1].lstrip("-")
        default_report = ("BENCH_resume.json" if mode == "resume"
                          else "BENCH_serve.json")
        cur_path = argv[2] if len(argv) > 2 else default_report
        if len(argv) > 3:
            base_path = argv[3]
        cur = _load(cur_path, f"{mode} report")
        base = _load(base_path, "baseline")
        if cur is None or base is None:
            return 1
        table = _Table()
        if mode == "serve":
            _check_serve(table, cur, base)
        elif mode == "resume":
            _check_resume(table, cur, base)
        else:
            _check_chaos(table, cur, base)
        table.print()
        if table.failed:
            print(f"{mode} guard: FAILED — see the non-OK rows above")
            return 1
        print(f"{mode} guard: passed")
        return 0
    if len(argv) > 1 and argv[1] == "--generate":
        if len(argv) > 2:
            base_path = argv[2]
        sys.path.insert(0, os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))))
        from benchmarks import bench_solvers
        cur = {"eo_smoke": bench_solvers._run_eo_smoke(),
               "eo_smoke_tm": bench_solvers._run_eo_smoke_tm(),
               "batch_sweep": bench_solvers._run_batch_sweep(),
               "blockcg_16rhs": bench_solvers._run_blockcg(),
               "eo_deflation": bench_solvers._run_eo_deflation(),
               "eo_sharded": bench_solvers._run_eo_sharded(),
               "ckpt_overhead": bench_solvers._run_ckpt_overhead()}
    else:
        cur_path = argv[1] if len(argv) > 1 else "BENCH_solvers.json"
        if len(argv) > 2:
            base_path = argv[2]
        try:
            with open(cur_path) as f:
                cur = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"solver-regression guard: cannot load {cur_path}: {e}")
            return 1

    try:
        with open(base_path) as f:
            base = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"solver-regression guard: cannot load {base_path}: {e}")
        return 1

    table = _Table()
    for name in GUARDED_SECTIONS:
        _check_section(table, name, cur, base)
    _check_batch_sweep(table, cur, base)
    _check_blockcg(table, cur, base)
    _check_eo_deflation(table, cur, base)
    _check_eo_sharded(table, cur, base)
    _check_ckpt_overhead(table, cur, base)
    if not table.rows:
        print("solver-regression guard: nothing to compare (baseline has "
              "no guarded sections)")
        return 1
    table.print()
    if table.failed:
        print("solver-regression guard: FAILED — see the non-OK rows above "
              "(MISMATCH = regenerate benchmarks/BENCH_solvers_baseline."
              "json, MISSING = a guarded entry disappeared, REGRESSION = "
              "an iteration count exceeded baseline + slack)")
        return 1
    print("solver-regression guard: passed")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
