#!/usr/bin/env bash
# Reproducible compiled-backend bench launcher (DESIGN.md §13).
#
# Pins the XLA launch environment (the SNIPPETS.md / HomebrewNLP run.sh
# idiom) so committed numbers carry a repro recipe: host device count,
# tcmalloc preload, f32 dtype pinning, quiet logs.  The pinned env is
# dumped into every bench JSON by bench_config.launch_env().
#
# Usage:
#   benchmarks/launch_bench.sh                    # dslash + solvers, CPU
#   BENCH_BACKEND=tpu benchmarks/launch_bench.sh  # device run
#   benchmarks/launch_bench.sh --only dslash      # extra run.py args pass through
#
# Produces BENCH_dslash.json / BENCH_solvers.json in the CWD and appends
# the snapshot for the current commit to BENCH_perf_trajectory.json
# (gated in CI by check_solver_regression.py --perf).
set -euo pipefail

REPO="$(cd "$(dirname "${BASH_SOURCE[0]}")/.." && pwd)"

export BENCH_BACKEND="${BENCH_BACKEND:-cpu}"
export BENCH_COMPILED=1

# fixed host device count: results must not depend on the runner's cores
HOSTDEV="${BENCH_HOST_DEVICES:-1}"
case " ${XLA_FLAGS:-} " in
  *xla_force_host_platform_device_count*) ;;
  *) export XLA_FLAGS="--xla_force_host_platform_device_count=${HOSTDEV}${XLA_FLAGS:+ ${XLA_FLAGS}}" ;;
esac

# dtype pinning + quiet C++ logs
export JAX_DEFAULT_DTYPE_BITS="${JAX_DEFAULT_DTYPE_BITS:-32}"
export TF_CPP_MIN_LOG_LEVEL="${TF_CPP_MIN_LOG_LEVEL:-4}"

# tcmalloc when the host has it (allocator noise dominates small-kernel
# timings on glibc malloc); silently skipped when absent
if [ -z "${LD_PRELOAD:-}" ]; then
  for so in /usr/lib/x86_64-linux-gnu/libtcmalloc.so.4 \
            /usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4 \
            /usr/lib/libtcmalloc.so.4; do
    if [ -f "$so" ]; then export LD_PRELOAD="$so"; break; fi
  done
fi

export PYTHONPATH="${REPO}/src${PYTHONPATH:+:$PYTHONPATH}"

ARGS=("--backend" "${BENCH_BACKEND}" "--compiled")
if [ "$#" -eq 0 ]; then
  ARGS+=("--only" "dslash" "solvers")
fi
python "${REPO}/benchmarks/run.py" "${ARGS[@]}" "$@"
python "${REPO}/benchmarks/perf_trajectory.py" --append
