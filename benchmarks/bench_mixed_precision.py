"""Paper §2/§3.2 — the two-precision CG variant (its Ref. [10]).

Reproduces the claim: bulk iterations run in the LOW type while the
solution converges to the HIGH-type tolerance, with modest iteration
overhead vs a pure high-precision solve.
"""

from __future__ import annotations

import time

import jax.numpy as jnp

from repro.core import LatticeShape, cg, mpcg
from repro.core.wilson import (dslash_dagger_packed, dslash_packed,
                               normal_op_packed)
from repro.data import lattice_problem

MASS = 0.3
TOL = 1e-6


def run() -> list[tuple[str, float, str]]:
    lat = LatticeShape(4, 4, 4, 8)
    up, b = lattice_problem(lat, mass=MASS, seed=1)
    rhs = dslash_dagger_packed(up, b, MASS)
    op_hi = lambda v: normal_op_packed(up, v, MASS)
    rows = []

    t0 = time.time()
    x32, s32 = cg(op_hi, rhs, tol=TOL, maxiter=1000)
    t_f32 = time.time() - t0
    r = dslash_packed(up, x32, MASS) - b
    rel32 = float(jnp.linalg.norm(r.ravel()) / jnp.linalg.norm(b.ravel()))
    rows.append(("cg_f32", t_f32 * 1e6,
                 f"iters={int(s32.iterations)};rel_res={rel32:.2e}"))

    up_lo = up.astype(jnp.bfloat16)
    op_lo = lambda v: normal_op_packed(up_lo, v, MASS)
    t0 = time.time()
    xmp, smp = mpcg(op_lo, op_hi, rhs, tol=TOL, inner_tol=5e-2,
                    inner_maxiter=200, max_outer=40)
    t_mp = time.time() - t0
    r = dslash_packed(up, xmp, MASS) - b
    relmp = float(jnp.linalg.norm(r.ravel()) / jnp.linalg.norm(b.ravel()))
    inner = int(smp.iterations)
    outer = int(smp.outer_iterations)
    low_frac = inner / (inner + outer)
    rows.append(("mpcg_bf16_f32", t_mp * 1e6,
                 f"inner={inner};outer={outer};rel_res={relmp:.2e};"
                 f"low_prec_frac={low_frac:.2f};"
                 f"iter_overhead={inner / max(int(s32.iterations), 1):.2f}x"))
    return rows
