"""Paper §5 (sustained GFLOP/s of the dslash-dominated solver).

Timings run under the lowering picked by :mod:`benchmarks.bench_config`
(``--compiled``/``launch_bench.sh`` => compiled; default => the
historical interpret-on-CPU smoke), and EVERY JSON entry carries the
uniform label block (platform/device_kind/compiled/interpret/lowering)
plus the warm-vs-compile-inclusive split (``us_warm``/``us_first``).

Each timing is also scored against the DESIGN.md §6 streaming-traffic
model (``roofline.dslash_intensity``): ``model_bw_gbs`` is the memory
bandwidth the WARM measurement would need if it streamed exactly the
model's ``(144/N + 48)·dtype_bytes`` bytes per site, and ``bw_fraction``
divides that by the platform's roofline bandwidth (measured STREAM triad
on CPU, HBM peak on device — ``bench_config.peak_bandwidth_gbs``).  So a
batched row whose model bandwidth does NOT drop ~(144+48)/(144/N+48)×
versus single-RHS is leaving the gauge-reuse win on the table, and a
``bw_fraction`` near 1 means the lowering is at the paper's
bandwidth-bound operating point.  The JSON (path overridable via
``$BENCH_DSLASH_JSON``) carries one entry per timing.

In compiled mode the kernel rows are the performance-truth lane gated by
``check_solver_regression.py --perf``: ``dslash_pallas_*`` at N=1 and
N=8 must beat the jnp reference on the same backend (the interpret-mode
79-vs-1179 inversion, closed).
"""

from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp

from benchmarks import bench_config
from benchmarks.roofline import dslash_intensity
from repro.core import LatticeShape, dslash_flops
from repro.core.wilson import dslash_packed
from repro.data import lattice_problem

OUT_JSON = os.environ.get("BENCH_DSLASH_JSON", "BENCH_dslash.json")

BATCH_NRHS = 8  # batched-gauge-reuse timing point (DESIGN.md §6)


def _entry(name, timing, volume, n_rhs=1, dtype_bytes=4, **labels):
    """One JSON row: warm/first split, achieved GFLOP/s, §6-model-implied
    bandwidth and its roofline fraction, uniform labels."""
    model = dslash_intensity(n_rhs=n_rhs, dtype_bytes=dtype_bytes)
    t_s = timing["us_warm"] / 1e6
    flops = dslash_flops(volume) * n_rhs
    model_bytes = model["bytes_per_site"] * volume * n_rhs
    model_bw = model_bytes / t_s / 1e9
    return bench_config.label_entry({
        "name": name,
        "us_per_call": timing["us_warm"],  # back-compat alias
        "us_warm": timing["us_warm"],
        "us_first": timing["us_first"],
        "gflops": flops / t_s / 1e9,
        "sites_rhs_per_s": volume * n_rhs / t_s,
        "model_bytes_per_site": model["bytes_per_site"],
        "model_flops_per_byte": model["flops_per_byte"],
        # bandwidth this timing would need at exactly the model traffic
        "model_bw_gbs": model_bw,
        "bw_fraction": bench_config.bw_fraction(model_bw),
        "n_rhs": n_rhs,
        "dtype_bytes": dtype_bytes,
    }, **labels)


def run() -> list[tuple[str, float, str]]:
    from repro.kernels.wilson_dslash import dslash as dslash_k

    rows, entries = [], []
    compiled = bench_config.is_compiled()
    interp = bench_config.interpret()

    def emit(name, timing, volume, n_rhs=1, dtype_bytes=4, **labels):
        e = _entry(name, timing, volume, n_rhs=n_rhs,
                   dtype_bytes=dtype_bytes, **labels)
        entries.append(e)
        rows.append((name, e["us_warm"],
                     f"{e['gflops']:.3f}GFLOP/s;"
                     f"model_bw={e['model_bw_gbs']:.2f}GB/s"
                     f"({e['bw_fraction']:.3f}xroof)"
                     f"@{e['model_bytes_per_site']:.0f}B/site"))

    m = 0.1
    for dims in ((4, 4, 4, 8), (8, 8, 8, 8), (8, 8, 8, 16)):
        lat = LatticeShape(*dims)
        up, pp = lattice_problem(lat, mass=m)
        jnp_op = jax.jit(lambda u, p: dslash_packed(u, p, m))
        emit(f"dslash_jnp_{lat}",
             bench_config.time_first_warm(jnp_op, up, pp), lat.volume,
             interpret=False, lowering="xla")  # jnp rows are always compiled
        # bf16 storage variant (the paper's low-precision datapath):
        # halves every byte in the §6 model, so the model bandwidth for
        # equal wall-time is half the f32 row's
        up16, pp16 = up.astype(jnp.bfloat16), pp.astype(jnp.bfloat16)
        emit(f"dslash_jnp_bf16_{lat}",
             bench_config.time_first_warm(
                 jax.jit(lambda u, p: dslash_packed(u, p, m)), up16, pp16),
             lat.volume, dtype_bytes=2, interpret=False, lowering="xla")

    # batched N-RHS point: N spinors stream through ONE gauge read, so
    # the §6 per-RHS traffic drops from 192 to 144/N + 48 bytes-reals —
    # this row's model_bw_gbs is the honest amortized number
    lat = LatticeShape(4, 4, 4, 8)
    up, pp = lattice_problem(lat, mass=m)
    pb = jnp.stack([pp] * BATCH_NRHS)
    batched_op = jax.jit(lambda u, p: jax.vmap(
        lambda s: dslash_packed(u, s, m))(p))
    emit(f"dslash_jnp_nrhs{BATCH_NRHS}_{lat}",
         bench_config.time_first_warm(batched_op, up, pb), lat.volume,
         n_rhs=BATCH_NRHS, interpret=False, lowering="xla")

    # Pallas kernel entry point under the configured lowering.  Compiled
    # mode (the perf-truth lane): N=1 and N=8 rows that the --perf gate
    # requires to beat the jnp reference above.  Default mode: the
    # historical interpret-mode correctness row (slow by design).
    mode = "compiled" if compiled else "interp"
    kern = jax.jit(lambda u, p: dslash_k(u, p, m, interpret=interp))
    if compiled:
        emit(f"dslash_pallas_{mode}_{lat}",
             bench_config.time_first_warm(kern, up, pp), lat.volume)
        emit(f"dslash_pallas_{mode}_nrhs{BATCH_NRHS}_{lat}",
             bench_config.time_first_warm(kern, up, pb), lat.volume,
             n_rhs=BATCH_NRHS)
    else:
        emit(f"dslash_pallas_{mode}_{lat}",
             bench_config.time_first_warm(kern, up, pp, iters=1, reps=1),
             lat.volume)

    with open(OUT_JSON, "w") as f:
        json.dump({"bench": "dslash", "schema": 2,
                   "model": "DESIGN.md §6: (144/N + 48) * dtype_bytes "
                            "bytes/site, 1320 flops/site",
                   "peak_bw_gbs": bench_config.peak_bandwidth_gbs(),
                   "launch": bench_config.launch_env(),
                   "entries": entries}, f, indent=2, sort_keys=True)
        f.write("\n")
    return rows
