"""Paper §5 (sustained GFLOP/s of the dslash-dominated solver).

CPU wall-times here are *interpret-mode* lower bounds used for relative
comparisons (jnp packed op vs Pallas path); absolute TPU projections come
from the dry-run roofline (EXPERIMENTS.md §Roofline), exactly as the paper
separates simulation traces from device numbers.

Each timing is also scored against the DESIGN.md §6 streaming-traffic
model (``roofline.dslash_intensity``): the derived CSV column and the
``model_bw_gbs`` field in **BENCH_dslash.json** report the memory
bandwidth the measurement WOULD need if it streamed exactly the model's
``(144/N + 48)·dtype_bytes`` bytes per site — so a batched row whose
model bandwidth does NOT drop ~(144+48)/(144/N+48)× versus single-RHS is
leaving the gauge-reuse win on the table.  The JSON (path overridable
via ``$BENCH_DSLASH_JSON``) carries one entry per timing with the model
bytes/site, arithmetic intensity, and implied bandwidth alongside the
achieved GFLOP/s.
"""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp

from benchmarks.roofline import dslash_intensity
from repro.core import LatticeShape, dslash_flops
from repro.core.wilson import dslash_packed
from repro.data import lattice_problem

OUT_JSON = os.environ.get("BENCH_DSLASH_JSON", "BENCH_dslash.json")

BATCH_NRHS = 8  # batched-gauge-reuse timing point (DESIGN.md §6)


def _time(f, *args, iters=3):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else \
        jax.block_until_ready(f(*args))
    t0 = time.time()
    for _ in range(iters):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters


def _entry(name, t_s, volume, n_rhs=1, dtype_bytes=4):
    """One JSON row: achieved GFLOP/s + §6-model-implied bandwidth."""
    model = dslash_intensity(n_rhs=n_rhs, dtype_bytes=dtype_bytes)
    flops = dslash_flops(volume) * n_rhs
    model_bytes = model["bytes_per_site"] * volume * n_rhs
    return {
        "name": name,
        "us_per_call": t_s * 1e6,
        "gflops": flops / t_s / 1e9,
        "model_bytes_per_site": model["bytes_per_site"],
        "model_flops_per_byte": model["flops_per_byte"],
        # bandwidth this timing would need at exactly the model traffic
        "model_bw_gbs": model_bytes / t_s / 1e9,
        "n_rhs": n_rhs,
        "dtype_bytes": dtype_bytes,
    }


def run() -> list[tuple[str, float, str]]:
    rows, entries = [], []

    def emit(name, t_s, volume, n_rhs=1, dtype_bytes=4):
        e = _entry(name, t_s, volume, n_rhs=n_rhs, dtype_bytes=dtype_bytes)
        entries.append(e)
        rows.append((name, t_s * 1e6,
                     f"{e['gflops']:.3f}GFLOP/s;"
                     f"model_bw={e['model_bw_gbs']:.2f}GB/s"
                     f"@{e['model_bytes_per_site']:.0f}B/site"))

    for dims in ((4, 4, 4, 8), (8, 8, 8, 8), (8, 8, 8, 16)):
        lat = LatticeShape(*dims)
        up, pp = lattice_problem(lat, mass=0.1)
        m = 0.1
        jnp_op = jax.jit(lambda u, p: dslash_packed(u, p, m))
        emit(f"dslash_jnp_{lat}", _time(jnp_op, up, pp), lat.volume)
        # bf16 storage variant (the paper's low-precision datapath):
        # halves every byte in the §6 model, so the model bandwidth for
        # equal wall-time is half the f32 row's
        up16, pp16 = up.astype(jnp.bfloat16), pp.astype(jnp.bfloat16)
        t_16 = _time(jax.jit(lambda u, p: dslash_packed(u, p, m)),
                     up16, pp16)
        emit(f"dslash_jnp_bf16_{lat}", t_16, lat.volume, dtype_bytes=2)
    # batched N-RHS point: N spinors stream through ONE gauge read, so
    # the §6 per-RHS traffic drops from 192 to 144/N + 48 bytes-reals —
    # this row's model_bw_gbs is the honest amortized number
    lat = LatticeShape(4, 4, 4, 8)
    up, pp = lattice_problem(lat, mass=0.1)
    pb = jnp.stack([pp] * BATCH_NRHS)
    batched_op = jax.jit(lambda u, p: jax.vmap(
        lambda s: dslash_packed(u, s, 0.1))(p))
    emit(f"dslash_jnp_nrhs{BATCH_NRHS}_{lat}",
         _time(batched_op, up, pb), lat.volume, n_rhs=BATCH_NRHS)
    # Pallas kernel, interpret mode (correctness path; slow by design)
    from repro.kernels.wilson_dslash import dslash as dslash_k
    t_pal = _time(jax.jit(lambda u, p: dslash_k(u, p, 0.1)), up, pp,
                  iters=1)
    emit(f"dslash_pallas_interp_{lat}", t_pal, lat.volume)

    with open(OUT_JSON, "w") as f:
        json.dump({"bench": "dslash", "schema": 1,
                   "model": "DESIGN.md §6: (144/N + 48) * dtype_bytes "
                            "bytes/site, 1320 flops/site",
                   "entries": entries}, f, indent=2, sort_keys=True)
        f.write("\n")
    return rows
