"""Paper §5 (sustained GFLOP/s of the dslash-dominated solver).

CPU wall-times here are *interpret-mode* lower bounds used for relative
comparisons (jnp packed op vs Pallas path); absolute TPU projections come
from the dry-run roofline (EXPERIMENTS.md §Roofline), exactly as the paper
separates simulation traces from device numbers.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import LatticeShape, dslash_flops
from repro.core.wilson import dslash_packed
from repro.data import lattice_problem


def _time(f, *args, iters=3):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else \
        jax.block_until_ready(f(*args))
    t0 = time.time()
    for _ in range(iters):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters


def run() -> list[tuple[str, float, str]]:
    rows = []
    for dims in ((4, 4, 4, 8), (8, 8, 8, 8), (8, 8, 8, 16)):
        lat = LatticeShape(*dims)
        up, pp = lattice_problem(lat, mass=0.1)
        m = 0.1
        jnp_op = jax.jit(lambda u, p: dslash_packed(u, p, m))
        t_jnp = _time(jnp_op, up, pp)
        fl = dslash_flops(lat.volume)
        rows.append((f"dslash_jnp_{lat}", t_jnp * 1e6,
                     f"{fl / t_jnp / 1e9:.3f}GFLOP/s"))
        # bf16 storage variant (the paper's low-precision datapath)
        up16, pp16 = up.astype(jnp.bfloat16), pp.astype(jnp.bfloat16)
        t_16 = _time(jax.jit(lambda u, p: dslash_packed(u, p, m)), up16, pp16)
        rows.append((f"dslash_jnp_bf16_{lat}", t_16 * 1e6,
                     f"{fl / t_16 / 1e9:.3f}GFLOP/s"))
    # Pallas kernel, interpret mode (correctness path; slow by design)
    lat = LatticeShape(4, 4, 4, 8)
    up, pp = lattice_problem(lat, mass=0.1)
    from repro.kernels.wilson_dslash import dslash as dslash_k
    t_pal = _time(jax.jit(lambda u, p: dslash_k(u, p, 0.1)), up, pp, iters=1)
    rows.append((f"dslash_pallas_interp_{lat}", t_pal * 1e6,
                 f"{dslash_flops(lat.volume) / t_pal / 1e9:.3f}GFLOP/s"))
    return rows
