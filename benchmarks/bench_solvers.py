"""Solver comparison table: iterations + collectives per iteration.

The paper motivates minimizing "global communications ... for total error
estimates"; ``pipecg`` restructures CG to ONE fused reduction per
iteration.  This bench counts all-reduces in the lowered HLO of one
iteration body per solver (8 fake devices, subprocess), plus CPU
convergence behaviour.

It also compares plain CGNR against the even-odd (Schur) preconditioned
``cgnr_eo`` on the same lattice — iterations and wall-clock µs — and the
``mpcg``-composed even-odd variant (bf16 inner solve, f32 reliable
updates): the paper's two central optimizations running together.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import time

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
from repro.core import LatticeShape
from repro.core import distributed as dist
from repro.data import lattice_problem
from repro.core.wilson import dslash_packed

from repro.compat import make_mesh
mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
lat = LatticeShape(4, 4, 4, 8)
up, pp = lattice_problem(lat, mass=0.3)
upd, ppd = dist.shard_lattice_fields(mesh, up, pp)

out = {}
for sv in ("cg", "pipecg", "mpcg"):
    x, st = dist.solve_wilson(mesh, upd, ppd, 0.3, solver=sv, tol=1e-6,
                              maxiter=500)
    res = dslash_packed(up, jax.device_get(x), 0.3) - pp
    rel = float(jnp.linalg.norm(res.ravel()) / jnp.linalg.norm(pp.ravel()))
    # count reductions in the whole compiled solve (while-body counted once
    # == per-iteration collective count for the loop)
    import functools
    f = functools.partial(dist.solve_wilson, mesh, solver=sv, tol=1e-6,
                          maxiter=500)
    txt = jax.jit(lambda u, b: dist.solve_wilson(mesh, u, b, 0.3, solver=sv,
                                                 tol=1e-6, maxiter=500)
                  ).lower(upd, ppd).compile().as_text()
    out[sv] = {"iters": int(st.iterations), "rel_res": rel,
               "all_reduce_in_body": txt.count(" all-reduce(")}
print("RESULT" + json.dumps(out))
"""


def _run_eo_comparison() -> list[tuple[str, float, str]]:
    """Plain CGNR vs even-odd Schur CGNR vs even-odd mpcg, same lattice."""
    import jax
    import jax.numpy as jnp
    from repro.core import (LatticeShape, cgnr, dslash, dslash_dagger,
                            random_gauge, random_spinor, solve_wilson_eo,
                            solve_wilson_eo_mp)

    lat = LatticeShape(4, 4, 4, 8)
    mass, tol = 0.1, 1e-6
    key = jax.random.PRNGKey(7)
    ku, kb = jax.random.split(key)
    u, b = random_gauge(ku, lat), random_spinor(kb, lat)

    def rel(x):
        r = dslash(u, x, mass) - b
        return float(jnp.linalg.norm(r.ravel()) / jnp.linalg.norm(b.ravel()))

    def timed(fn):
        jax.block_until_ready(fn()[0])  # warm-up/compile, fully drained
        t0 = time.time()
        out = fn()
        jax.block_until_ready(out[0])
        return out, (time.time() - t0) * 1e6

    (x_f, st_f), us_f = timed(lambda: cgnr(
        lambda v: dslash(u, v, mass), lambda v: dslash_dagger(u, v, mass),
        b, tol=tol, maxiter=1000))
    (x_e, st_e), us_e = timed(lambda: solve_wilson_eo(
        u, b, mass, tol=tol, maxiter=1000))
    (x_m, st_m), us_m = timed(lambda: solve_wilson_eo_mp(
        u, b, mass, tol=tol, inner_maxiter=100, max_outer=40))

    it_f, it_e = int(st_f.iterations), int(st_e.iterations)
    return [
        ("cgnr_full", us_f, f"iters={it_f};rel_res={rel(x_f):.2e}"),
        ("cgnr_eo", us_e,
         f"iters={it_e};rel_res={rel(x_e):.2e};"
         f"iter_ratio={it_e / max(it_f, 1):.2f};"
         f"speedup={us_f / max(us_e, 1e-9):.2f}x"),
        ("cgnr_eo_mpcg", us_m,
         f"inner={int(st_m.iterations)};outer={int(st_m.outer_iterations)};"
         f"rel_res={rel(x_m):.2e}"),
    ]


def run() -> list[tuple[str, float, str]]:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=560)
    if r.returncode != 0:
        rows = [("solver_comparison", -1.0, "FAILED:" + r.stderr[-200:])]
    else:
        line = [ln for ln in r.stdout.splitlines()
                if ln.startswith("RESULT")][-1]
        d = json.loads(line[len("RESULT"):])
        rows = []
        for sv, v in d.items():
            rows.append((f"solver_{sv}", float(v["iters"]),
                         f"rel_res={v['rel_res']:.2e};"
                         f"all_reduces={v['all_reduce_in_body']}"))
    try:
        rows.extend(_run_eo_comparison())
    except Exception as e:  # keep the subprocess rows; degrade like above
        rows.append(("eo_comparison", -1.0, f"FAILED:{e!r:.200}"))
    return rows
