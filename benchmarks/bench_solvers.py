"""Solver comparison table: iterations + collectives per iteration.

The paper motivates minimizing "global communications ... for total error
estimates"; ``pipecg`` restructures CG to ONE fused reduction per
iteration.  This bench counts all-reduces in the lowered HLO of one
iteration body per solver (8 fake devices, subprocess), plus CPU
convergence behaviour.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
from repro.core import LatticeShape
from repro.core import distributed as dist
from repro.data import lattice_problem
from repro.core.wilson import dslash_packed

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 3)
lat = LatticeShape(4, 4, 4, 8)
up, pp = lattice_problem(lat, mass=0.3)
upd, ppd = dist.shard_lattice_fields(mesh, up, pp)

out = {}
for sv in ("cg", "pipecg", "mpcg"):
    x, st = dist.solve_wilson(mesh, upd, ppd, 0.3, solver=sv, tol=1e-6,
                              maxiter=500)
    res = dslash_packed(up, jax.device_get(x), 0.3) - pp
    rel = float(jnp.linalg.norm(res.ravel()) / jnp.linalg.norm(pp.ravel()))
    # count reductions in the whole compiled solve (while-body counted once
    # == per-iteration collective count for the loop)
    import functools
    f = functools.partial(dist.solve_wilson, mesh, solver=sv, tol=1e-6,
                          maxiter=500)
    txt = jax.jit(lambda u, b: dist.solve_wilson(mesh, u, b, 0.3, solver=sv,
                                                 tol=1e-6, maxiter=500)
                  ).lower(upd, ppd).compile().as_text()
    out[sv] = {"iters": int(st.iterations), "rel_res": rel,
               "all_reduce_in_body": txt.count(" all-reduce(")}
print("RESULT" + json.dumps(out))
"""


def run() -> list[tuple[str, float, str]]:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=560)
    if r.returncode != 0:
        return [("solver_comparison", -1.0, "FAILED:" + r.stderr[-200:])]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT")][-1]
    d = json.loads(line[len("RESULT"):])
    rows = []
    for sv, v in d.items():
        rows.append((f"solver_{sv}", float(v["iters"]),
                     f"rel_res={v['rel_res']:.2e};"
                     f"all_reduces={v['all_reduce_in_body']}"))
    return rows
