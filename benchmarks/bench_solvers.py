"""Solver comparison table: iterations + collectives per iteration.

The paper motivates minimizing "global communications ... for total error
estimates"; ``pipecg`` restructures CG to ONE fused reduction per
iteration.  This bench counts all-reduces in the lowered HLO of one
iteration body per solver (8 fake devices, subprocess), plus CPU
convergence behaviour.

It also compares plain CGNR against the even-odd (Schur) preconditioned
``cgnr_eo`` on the same lattice — iterations and wall-clock µs — and the
``mpcg``-composed even-odd variant (bf16 inner solve, f32 reliable
updates): the paper's two central optimizations running together.

Beyond the CSV rows, ``run()`` writes **BENCH_solvers.json** — the
machine-readable perf trajectory (iterations, wall-clock, sites/s, and the
fused CG engine's per-iteration kernel/traffic shape).  CI uploads it and
``check_solver_regression.py`` guards the 4⁴ smoke-lattice iteration count
against ``benchmarks/BENCH_solvers_baseline.json``.

The ``batch_sweep`` section records the multi-RHS batched Schur solve for
N ∈ {1, 4, 8, 16} right-hand sides on the Pallas parity-dslash path —
sites·RHS/s per batch size, demonstrating the gauge-amortization win (one
gauge read feeds N spinors), with per-N iteration counts regression-guarded
by the same baseline file.

The ``eo_sharded`` section records the plan-driven sharded batched EO
Schur solve (8 fake host devices, pipelined CGNR with its single fused
psum per iteration) — its trip count is guarded too, pinning the
distributed fast path's Krylov math to the committed baseline.

The ``eo_smoke_tm`` section runs the SAME smoke problem through the
operator registry's second family (twisted-mass, site term
(m+4) + i·mu·gamma5) on both backends — guarding that the site-term
epilogue hook keeps the transport stack's Krylov math stable for a
non-Wilson operator.

Every timed entry is tagged with its ``backend`` (reference/pallas) and
``interpret`` mode, and reports the warm steady-state call (``us_warm``)
separately from the first, compile-inclusive call (``us_first``).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import time

from benchmarks import bench_config

# Kept in sync with tests/test_eo.py's module fixture so the committed
# baseline guards the same solve the tier-1 suite runs.
SMOKE_DIMS = (4, 4, 4, 4)
SMOKE_SEED = 7
SMOKE_MASS = 0.1
SMOKE_TOL = 1e-6

# twisted-mass smoke row: same problem, second operator family (the
# registry's proof that the transport stack is operator-agnostic)
SMOKE_TM_MU = 0.25

# RHS-batch sizes for the gauge-amortization sweep (ISSUE 3 acceptance:
# sites·RHS/s must grow monotonically from N=1 to N>=8 on the Pallas path).
BATCH_SIZES = (1, 4, 8, 16)

# Iteration-cutting rows (blockcg_16rhs / eo_deflation) run the SAME 4⁴
# lattice and seed at a NEAR-CRITICAL mass: the 14-iteration smoke
# operator at mass 0.1 has no low-mode structure worth sharing or
# deflating, so the demonstration regime is where the Krylov space is
# deep (~120 iterations) and the paper's iteration budget actually hurts.
DEFL_MASS = -1.7
DEFL_TOL = 1e-6
DEFL_NEV = 32          # deflation-basis slots harvested
DEFL_M_MAX = 160       # Lanczos vectors recorded by the harvest solve
DEFL_HARVEST_TOL = 1e-8  # harvest solves past serving tol: deeper basis
BLOCK_NRHS = 16        # the ROADMAP item-2 headline batch


def _timed(fn):
    """((result, ...), first-call µs, warm µs) of fn().

    The FIRST call includes compilation (trace + lower + compile); the
    second call hits the jit cache and measures steady-state execution.
    Both are reported so the JSON separates compile cost from the warm
    throughput the paper's §5 tables are about.  ``fn`` must return a
    tuple whose first element is the jax output to drain
    (block_until_ready) — the shared timing protocol of every solve
    section below.
    """
    import jax

    t0 = time.time()
    jax.block_until_ready(fn()[0])  # compile-inclusive first call
    us_first = (time.time() - t0) * 1e6
    t0 = time.time()
    out = fn()
    jax.block_until_ready(out[0])
    return out, us_first, (time.time() - t0) * 1e6

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
from repro.core import LatticeShape
from repro.core import distributed as dist
from repro.data import lattice_problem
from repro.core.wilson import dslash_packed

from repro.compat import make_mesh
mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
lat = LatticeShape(4, 4, 4, 8)
up, pp = lattice_problem(lat, mass=0.3)
upd, ppd = dist.shard_lattice_fields(mesh, up, pp)

out = {}
for sv in ("cg", "pipecg", "mpcg"):
    x, st = dist.solve_wilson(mesh, upd, ppd, 0.3, solver=sv, tol=1e-6,
                              maxiter=500)
    res = dslash_packed(up, jax.device_get(x), 0.3) - pp
    rel = float(jnp.linalg.norm(res.ravel()) / jnp.linalg.norm(pp.ravel()))
    # count reductions in the whole compiled solve (while-body counted once
    # == per-iteration collective count for the loop)
    import functools
    f = functools.partial(dist.solve_wilson, mesh, solver=sv, tol=1e-6,
                          maxiter=500)
    txt = jax.jit(lambda u, b: dist.solve_wilson(mesh, u, b, 0.3, solver=sv,
                                                 tol=1e-6, maxiter=500)
                  ).lower(upd, ppd).compile().as_text()
    out[sv] = {"iters": int(st.iterations), "rel_res": rel,
               "all_reduce_in_body": txt.count(" all-reduce(")}
print("RESULT" + json.dumps(out))
"""


def _run_eo_comparison() -> list[tuple[str, float, str]]:
    """Plain CGNR vs even-odd Schur CGNR vs even-odd mpcg, same lattice."""
    import jax
    import jax.numpy as jnp
    from repro.core import (LatticeShape, cgnr, dslash, dslash_dagger,
                            random_gauge, random_spinor, solve_wilson_eo,
                            solve_wilson_eo_mp)

    lat = LatticeShape(4, 4, 4, 8)
    mass, tol = 0.1, 1e-6
    key = jax.random.PRNGKey(7)
    ku, kb = jax.random.split(key)
    u, b = random_gauge(ku, lat), random_spinor(kb, lat)

    def rel(x):
        r = dslash(u, x, mass) - b
        return float(jnp.linalg.norm(r.ravel()) / jnp.linalg.norm(b.ravel()))

    (x_f, st_f), _, us_f = _timed(lambda: cgnr(
        lambda v: dslash(u, v, mass), lambda v: dslash_dagger(u, v, mass),
        b, tol=tol, maxiter=1000))
    (x_e, st_e), _, us_e = _timed(lambda: solve_wilson_eo(
        u, b, mass, tol=tol, maxiter=1000))
    (x_m, st_m), _, us_m = _timed(lambda: solve_wilson_eo_mp(
        u, b, mass, tol=tol, inner_maxiter=100, max_outer=40))

    it_f, it_e = int(st_f.iterations), int(st_e.iterations)
    return [
        ("cgnr_full", us_f, f"iters={it_f};rel_res={rel(x_f):.2e}"),
        ("cgnr_eo", us_e,
         f"iters={it_e};rel_res={rel(x_e):.2e};"
         f"iter_ratio={it_e / max(it_f, 1):.2f};"
         f"speedup={us_f / max(us_e, 1e-9):.2f}x"),
        ("cgnr_eo_mpcg", us_m,
         f"inner={int(st_m.iterations)};outer={int(st_m.outer_iterations)};"
         f"rel_res={rel(x_m):.2e}"),
    ]


def _run_eo_smoke() -> dict:
    """Reference vs Pallas-fast-path Schur solve on the 4⁴ smoke lattice.

    This is the guarded trajectory entry: cgnr_eo iteration counts here
    feed ``BENCH_solvers.json`` and must not regress versus the committed
    baseline (see check_solver_regression.py).
    """
    import jax
    import jax.numpy as jnp
    from repro.core import (LatticeShape, random_gauge, random_spinor,
                            solve_wilson_eo)
    from repro.core.wilson import dslash

    lat = LatticeShape(*SMOKE_DIMS)
    key = jax.random.PRNGKey(SMOKE_SEED)
    ku, kb = jax.random.split(key)
    u, b = random_gauge(ku, lat), random_spinor(kb, lat)

    def rel(x):
        r = dslash(u, x, SMOKE_MASS) - b
        return float(jnp.linalg.norm(r.ravel()) / jnp.linalg.norm(b.ravel()))

    (x_ref, st_ref), us_ref_first, us_ref = _timed(lambda: solve_wilson_eo(
        u, b, SMOKE_MASS, tol=SMOKE_TOL, maxiter=1000))
    (x_pal, st_pal), us_pal_first, us_pal = _timed(lambda: solve_wilson_eo(
        u, b, SMOKE_MASS, tol=SMOKE_TOL, maxiter=1000,
        use_pallas=True, interpret=True))

    def sites_per_s(st, us):
        return lat.volume * int(st.iterations) / max(us / 1e6, 1e-12)

    # compiled-lowering row (launch_bench.sh / --compiled): the SAME solve
    # through the kernels' compiled path — on CPU the XLA half-spinor
    # lowering, on device Mosaic.  Not iteration-guarded (compiled
    # reductions may reorder; counts can differ by roundoff), the perf
    # trajectory consumes its warm timing.
    compiled_entries = []
    if bench_config.is_compiled():
        (x_cmp, st_cmp), us_cmp_first, us_cmp = _timed(
            lambda: solve_wilson_eo(
                u, b, SMOKE_MASS, tol=SMOKE_TOL, maxiter=1000,
                use_pallas=True, interpret=False))
        compiled_entries.append({
            "name": "cgnr_eo_pallas_compiled", "backend": "pallas",
            "interpret": False, "iters": int(st_cmp.iterations),
            "matvecs": int(st_cmp.matvecs), "us_first": us_cmp_first,
            "us_warm": us_cmp, "rel_res": rel(x_cmp),
            "sites_per_s": sites_per_s(st_cmp, us_cmp)})

    return {
        "lattice": str(lat), "mass": SMOKE_MASS, "tol": SMOKE_TOL,
        "seed": SMOKE_SEED,
        "cgnr_eo_iters": int(st_ref.iterations),
        "cgnr_eo_pallas_iters": int(st_pal.iterations),
        "cgnr_eo_matvecs": int(st_ref.matvecs),
        "cgnr_eo_pallas_matvecs": int(st_pal.matvecs),
        "cgnr_eo_us": us_ref, "cgnr_eo_pallas_us": us_pal,
        "rel_res_ref": rel(x_ref), "rel_res_pallas": rel(x_pal),
        "sites_per_s_ref": sites_per_s(st_ref, us_ref),
        "sites_per_s_pallas": sites_per_s(st_pal, us_pal),
        "pallas_interpret_mode": True,
        # per-backend tagged entries: warm steady-state timing separated
        # from the first (compile-inclusive) call
        "entries": [
            {"name": "cgnr_eo", "backend": "reference", "interpret": None,
             "iters": int(st_ref.iterations),
             "matvecs": int(st_ref.matvecs), "us_first": us_ref_first,
             "us_warm": us_ref},
            {"name": "cgnr_eo_pallas", "backend": "pallas",
             "interpret": True, "iters": int(st_pal.iterations),
             "matvecs": int(st_pal.matvecs), "us_first": us_pal_first,
             "us_warm": us_pal},
        ] + compiled_entries,
    }


def _run_eo_smoke_tm() -> dict:
    """Twisted-mass EO Schur smoke: the registry's second operator family.

    Same lattice/seed/tolerance as ``eo_smoke``, site term
    (m+4) + i·mu·gamma5 — the iteration counts are the guarded signal
    that the operator-registry indirection (site-term epilogues folded
    into the SAME four hop-kernel launches) keeps the Krylov math stable
    on both backends.
    """
    import jax
    import jax.numpy as jnp
    from repro.core import (LatticeShape, SolverPlan, random_gauge,
                            random_spinor, solve_plan)
    from repro.core.operators import dslash_g

    lat = LatticeShape(*SMOKE_DIMS)
    key = jax.random.PRNGKey(SMOKE_SEED)
    ku, kb = jax.random.split(key)
    u, b = random_gauge(ku, lat), random_spinor(kb, lat)

    def rel(x):
        r = dslash_g(u, x, SMOKE_MASS, twist=SMOKE_TM_MU) - b
        return float(jnp.linalg.norm(r.ravel()) / jnp.linalg.norm(b.ravel()))

    def plan(backend):
        return SolverPlan(operator="eo-schur",
                          operator_family="twisted-mass", mu=SMOKE_TM_MU,
                          backend=backend,
                          interpret=True if backend == "pallas" else None)

    (x_ref, st_ref), us_ref_first, us_ref = _timed(lambda: solve_plan(
        plan("reference"), u, b, SMOKE_MASS, tol=SMOKE_TOL, maxiter=1000))
    (x_pal, st_pal), us_pal_first, us_pal = _timed(lambda: solve_plan(
        plan("pallas"), u, b, SMOKE_MASS, tol=SMOKE_TOL, maxiter=1000))

    return {
        "lattice": str(lat), "mass": SMOKE_MASS, "mu": SMOKE_TM_MU,
        "tol": SMOKE_TOL, "seed": SMOKE_SEED, "operator": "twisted-mass",
        "cgnr_eo_tm_iters": int(st_ref.iterations),
        "cgnr_eo_tm_pallas_iters": int(st_pal.iterations),
        "cgnr_eo_tm_matvecs": int(st_ref.matvecs),
        "cgnr_eo_tm_pallas_matvecs": int(st_pal.matvecs),
        "rel_res_ref": rel(x_ref), "rel_res_pallas": rel(x_pal),
        "pallas_interpret_mode": True,
        "entries": [
            {"name": "cgnr_eo_tm", "backend": "reference",
             "interpret": None, "iters": int(st_ref.iterations),
             "matvecs": int(st_ref.matvecs),
             "us_first": us_ref_first, "us_warm": us_ref},
            {"name": "cgnr_eo_tm_pallas", "backend": "pallas",
             "interpret": True, "iters": int(st_pal.iterations),
             "matvecs": int(st_pal.matvecs),
             "us_first": us_pal_first, "us_warm": us_pal},
        ],
    }


def _run_batch_sweep() -> dict:
    """Multi-RHS batched Schur solve: throughput vs batch size N.

    One gauge field, N random right-hand sides, one masked CG loop on the
    Pallas parity-dslash path: every matvec reads each gauge plane once
    and streams all N spinor planes through it, so the per-RHS cost of
    the launch/transport overhead falls like 1/N — sites·RHS/s should
    rise monotonically with N until compute dominates.  Per-N iteration
    counts feed the committed baseline (deterministic seed), wall-clock
    is informational.
    """
    import jax
    import jax.numpy as jnp
    from repro.core import (LatticeShape, random_gauge, random_spinor,
                            solve_wilson_eo_batched)
    from repro.core.wilson import dslash

    lat = LatticeShape(*SMOKE_DIMS)
    key = jax.random.PRNGKey(SMOKE_SEED)
    ku, kb = jax.random.split(key)
    u = random_gauge(ku, lat)
    n_max = max(BATCH_SIZES)
    b_all = jnp.stack([random_spinor(jax.random.fold_in(kb, i), lat)
                       for i in range(n_max)])

    entries = []
    for n in BATCH_SIZES:
        b_n = b_all[:n]
        (x, st), us_first, us = _timed(lambda b=b_n: solve_wilson_eo_batched(
            u, b, SMOKE_MASS, tol=SMOKE_TOL, maxiter=1000,
            use_pallas=True, interpret=True))
        res = jax.vmap(lambda xx, bb: dslash(u, xx, SMOKE_MASS) - bb)(x, b_n)
        rel = float(jnp.max(
            jnp.linalg.norm(res.reshape(n, -1), axis=1)
            / jnp.linalg.norm(b_n.reshape(n, -1), axis=1)))
        iters = int(st.iterations)
        mv = jax.device_get(st.matvecs)
        entries.append({
            "n_rhs": n, "iters": iters, "us_warm": us, "us_first": us_first,
            "backend": "pallas", "interpret": True,
            # per-RHS operator applications: max over lanes matches the
            # "iters" convention; the SUM is the gauge-amortization ledger
            "matvecs": int(mv.max()), "matvecs_total": int(mv.sum()),
            "max_rel_res": rel, "all_converged": bool(jnp.all(st.converged)),
            "sites_rhs_per_s": lat.volume * n * iters / max(us / 1e6, 1e-12),
        })
    return {
        "lattice": str(lat), "mass": SMOKE_MASS, "tol": SMOKE_TOL,
        "seed": SMOKE_SEED, "pallas_interpret_mode": True,
        "backend": "pallas", "interpret": True,
        "entries": entries,
    }


def _run_blockcg() -> dict:
    """Block CGNR vs 16 independent solves: the shared-Krylov-space win.

    Same 4⁴ lattice/seed as the smoke rows, near-critical mass (see
    DEFL_MASS).  The guarded headline (ROADMAP item 2): TOTAL matvecs for
    16 RHS through one block solve must come in well under 16× the
    single-RHS count — the block search space lets every lane ride the
    others' directions, so the block iteration count (= each lane's
    matvec count) drops far below the single-RHS iteration count.
    """
    import jax
    import jax.numpy as jnp
    from repro.core import (LatticeShape, random_gauge, random_spinor)
    from repro.core import plan as plan_mod
    from repro.core.wilson import dslash

    lat = LatticeShape(*SMOKE_DIMS)
    key = jax.random.PRNGKey(SMOKE_SEED)
    ku, kb = jax.random.split(key)
    u = random_gauge(ku, lat)
    b_all = jnp.stack([random_spinor(jax.random.fold_in(kb, i), lat)
                       for i in range(BLOCK_NRHS)])

    single = plan_mod.SolverPlan(operator="eo-schur", backend="reference")
    (x_s, st_s), _, us_s = _timed(lambda: plan_mod.solve(
        single, u, b_all[0], DEFL_MASS, tol=DEFL_TOL, maxiter=500))

    block = plan_mod.SolverPlan(operator="eo-schur", backend="reference",
                                solver="blockcg", nrhs=BLOCK_NRHS)
    (x_b, st_b), us_b_first, us_b = _timed(lambda: plan_mod.solve(
        block, u, b_all, DEFL_MASS, tol=DEFL_TOL, maxiter=500))

    res = jax.vmap(lambda xx, bb: dslash(u, xx, DEFL_MASS) - bb)(x_b, b_all)
    rel = float(jnp.max(
        jnp.linalg.norm(res.reshape(BLOCK_NRHS, -1), axis=1)
        / jnp.linalg.norm(b_all.reshape(BLOCK_NRHS, -1), axis=1)))
    mv = jax.device_get(st_b.matvecs)
    total = int(mv.sum())
    total_single16 = BLOCK_NRHS * int(st_s.matvecs)
    return {
        "lattice": str(lat), "mass": DEFL_MASS, "tol": DEFL_TOL,
        "seed": SMOKE_SEED, "n_rhs": BLOCK_NRHS, "backend": "reference",
        "single_iters": int(st_s.iterations),
        "single_matvecs": int(st_s.matvecs),
        "blockcg_iters": int(st_b.iterations),
        "blockcg_matvecs": int(mv.max()),
        "total_matvecs": total,
        "total_matvecs_single16": total_single16,
        "matvec_ratio": total / max(total_single16, 1),
        "max_rel_res": rel,
        "all_converged": bool(jnp.all(st_b.converged)),
        "all_verified": bool(jnp.all(st_b.verified)),
        "us_warm": us_b, "us_first": us_b_first, "us_single_warm": us_s,
    }


def _run_eo_deflation() -> dict:
    """EigCG deflation: harvest on the first solve, deflate the second.

    The harvest solve runs past serving tolerance (DEFL_HARVEST_TOL) to
    record a deep Krylov space, condenses it into DEFL_NEV approximate
    low modes, and every LATER solve on this gauge field starts from the
    Galerkin projection — the guarded signal is the strict iteration drop
    of the deflated solve versus the identical undeflated one, and the
    deflated solve still passing true-residual verification against the
    ORIGINAL system.
    """
    import jax
    import jax.numpy as jnp
    from repro.core import (LatticeShape, random_gauge, random_spinor)
    from repro.core import plan as plan_mod

    lat = LatticeShape(*SMOKE_DIMS)
    key = jax.random.PRNGKey(SMOKE_SEED)
    ku, kb = jax.random.split(key)
    u = random_gauge(ku, lat)
    b0 = random_spinor(jax.random.fold_in(kb, 0), lat)
    b1 = random_spinor(jax.random.fold_in(kb, 1), lat)

    plan = plan_mod.SolverPlan(operator="eo-schur", backend="reference")
    _, st_h, basis = plan_mod.harvest_deflation(
        plan, u, b0, DEFL_MASS, tol=DEFL_HARVEST_TOL, maxiter=500,
        nev=DEFL_NEV, m_max=DEFL_M_MAX, verify_tol=DEFL_TOL)

    (x_u, st_u), _, us_u = _timed(lambda: plan_mod.solve(
        plan, u, b1, DEFL_MASS, tol=DEFL_TOL, maxiter=500))
    (x_d, st_d), us_d_first, us_d = _timed(lambda: plan_mod.solve(
        plan, u, b1, DEFL_MASS, tol=DEFL_TOL, maxiter=500,
        deflation=basis))

    return {
        "lattice": str(lat), "mass": DEFL_MASS, "tol": DEFL_TOL,
        "seed": SMOKE_SEED, "backend": "reference",
        "nev": DEFL_NEV, "m_max": DEFL_M_MAX,
        "harvest_tol": DEFL_HARVEST_TOL,
        "harvest_iters": int(st_h.iterations),
        "harvest_matvecs": int(st_h.matvecs),
        "harvest_verified": bool(st_h.verified),
        "undeflated_iters": int(st_u.iterations),
        "undeflated_matvecs": int(st_u.matvecs),
        "deflated_iters": int(st_d.iterations),
        "deflated_matvecs": int(st_d.matvecs),
        "iteration_drop": int(st_u.iterations) - int(st_d.iterations),
        "deflated_converged": bool(st_d.converged),
        "deflated_verified": bool(st_d.verified),
        "us_undeflated_warm": us_u, "us_deflated_warm": us_d,
        "us_deflated_first": us_d_first,
    }


_SHARDED_EO_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, time
import jax, jax.numpy as jnp
from repro.compat import make_mesh
from repro.core import LatticeShape, random_gauge, random_spinor
from repro.core import plan as plan_mod
from repro.core.wilson import dslash

mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
lat = LatticeShape(%(t)d, %(z)d, %(y)d, 8)
mass, tol, seed, n = %(mass)r, %(tol)r, %(seed)d, %(n)d
ku, kb = jax.random.split(jax.random.PRNGKey(seed))
u = random_gauge(ku, lat)
b = jnp.stack([random_spinor(jax.random.fold_in(kb, i), lat)
               for i in range(n)])
p = plan_mod.SolverPlan(operator="eo-schur", backend="reference",
                        solver="pipecg", nrhs=n, mesh=mesh)
t0 = time.time()
x, st = plan_mod.solve(p, u, b, mass, tol=tol, maxiter=500)
jax.block_until_ready(x)             # compile-inclusive first call
us_first = (time.time() - t0) * 1e6
t0 = time.time()
x, st = plan_mod.solve(p, u, b, mass, tol=tol, maxiter=500)
jax.block_until_ready(x)
us = (time.time() - t0) * 1e6
res = jax.vmap(lambda xx, bb: dslash(u, xx, mass) - bb)(x, b)
rel = float(jnp.max(jnp.linalg.norm(res.reshape(n, -1), axis=1)
                    / jnp.linalg.norm(b.reshape(n, -1), axis=1)))
out = {"lattice": str(lat), "mass": mass, "tol": tol, "seed": seed,
       "n_rhs": n, "mesh": "2x2x2", "solver": "pipecg",
       "backend": "reference", "interpret": None,
       "iters": int(st.iterations),
       "rhs_iters": [int(v) for v in st.rhs_iterations],
       "matvecs": int(jnp.max(st.matvecs)),
       "matvecs_total": int(jnp.sum(st.matvecs)),
       "max_rel_res": rel, "all_converged": bool(jnp.all(st.converged)),
       "us_warm": us, "us_first": us_first,
       "sites_rhs_per_s": lat.volume * n * int(st.iterations)
                          / max(us / 1e6, 1e-12)}
print("RESULT" + json.dumps(out))
"""


def _run_eo_sharded() -> dict:
    """Sharded batched EO Schur pipelined CGNR on an 8-way host mesh.

    The iteration count is the guarded trajectory signal (deterministic
    seed; the fused single-psum reduction must not change the Krylov
    math); wall-clock on 8 fake CPU devices is informational only.
    Subprocess because the host-device count must be set before jax
    initializes.
    """
    script = _SHARDED_EO_SCRIPT % dict(
        t=SMOKE_DIMS[0], z=SMOKE_DIMS[1], y=SMOKE_DIMS[2],
        mass=SMOKE_MASS, tol=SMOKE_TOL, seed=SMOKE_SEED, n=2)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=560)
    if r.returncode != 0:
        raise RuntimeError("sharded eo bench failed: " + r.stderr[-500:])
    line = [ln for ln in r.stdout.splitlines() if ln.startswith("RESULT")][-1]
    return json.loads(line[len("RESULT"):])


def _run_ckpt_overhead() -> dict:
    """Segmented (checkpointed) vs one-shot smoke solve (DESIGN.md §11).

    The guarded signal is algorithmic, like every other row: the
    segmented solve must run the SAME number of iterations and produce a
    BITWISE-identical iterate — segmenting only augments the while-loop's
    stopping condition, never its body.  The wall-clock cost per snapshot
    (host sync + npz write + prune) is recorded for trend context but not
    gated; CI runner I/O is noise.
    """
    import tempfile

    import jax
    import numpy as np
    from repro.core import LatticeShape, random_gauge, random_spinor
    from repro.core import plan as plan_mod

    lat = LatticeShape(*SMOKE_DIMS)
    key = jax.random.PRNGKey(SMOKE_SEED)
    ku, kb = jax.random.split(key)
    u, b = random_gauge(ku, lat), random_spinor(kb, lat)
    plan = plan_mod.SolverPlan(operator="eo-schur")
    every = 5

    (x_ref, st_ref), _, us_ref = _timed(lambda: plan_mod.solve(
        plan, u, b, SMOKE_MASS, tol=SMOKE_TOL, maxiter=1000))
    with tempfile.TemporaryDirectory() as d:
        policy = plan_mod.CheckpointPolicy(dir=d, every_iters=every)
        (x_seg, st_seg), _, us_seg = _timed(lambda: plan_mod.solve(
            plan, u, b, SMOKE_MASS, tol=SMOKE_TOL, maxiter=1000,
            checkpoint=policy))
    iters = int(st_ref.iterations)
    segments = -(-iters // every)
    return {
        "lattice": str(lat), "mass": SMOKE_MASS, "tol": SMOKE_TOL,
        "seed": SMOKE_SEED, "every_iters": every,
        "iters": iters,
        "matvecs": int(st_ref.matvecs),
        "iters_checkpointed": int(st_seg.iterations),
        "matvecs_checkpointed": int(st_seg.matvecs),
        "bitwise_equal": bool(np.array_equal(np.asarray(x_seg),
                                             np.asarray(x_ref))),
        "segments": segments,
        "us_oneshot": us_ref, "us_checkpointed": us_seg,
        "overhead_us_per_snapshot": (max(us_seg - us_ref, 0.0)
                                     / max(segments, 1)),
    }


def _fused_engine_shape() -> dict:
    """Per-iteration kernel count and HBM traffic shape of the fused CG.

    Inspects the jaxpr of ONE fused iteration body: the vector algebra
    must be exactly two pallas_call launches — the x/r/||r||² triad
    (4 vector reads, 2 vector writes + negligible partials) and the
    direction xpay (2 reads, 1 write) — versus 7 reads + 3 writes for the
    naive jnp expression chain.
    """
    import jax
    import jax.numpy as jnp
    from repro.kernels.cg_fused import fused_engine
    from repro.testing import pallas_call_eqns

    n = (256, 128)
    update, xpay = fused_engine(interpret=True)

    def body(x, r, p, ap, rs):
        alpha = rs / jnp.sum(p * ap)
        x, r, rs_new = update(alpha, x, r, p, ap)
        p = xpay(rs_new / rs, r, p)
        return x, r, p, rs_new

    args = [jnp.zeros(n, jnp.float32)] * 4 + [jnp.float32(1.0)]
    calls = pallas_call_eqns(jax.make_jaxpr(body)(*args))
    size = n[0] * n[1]

    def shape_of(eqn):
        reads = sum(1 for v in eqn.invars
                    if getattr(v.aval, "size", 0) == size)
        writes = sum(1 for v in eqn.outvars
                     if getattr(v.aval, "size", 0) == size)
        return reads, writes

    shapes = sorted((shape_of(e) for e in calls), reverse=True)
    out = {"pallas_calls_per_iteration": len(calls),
           "backend": "pallas", "interpret": True,
           "naive_traffic": "7R+3W",
           "kernel_traffic": "+".join(f"{r}R{w}W" for r, w in shapes)}
    if len(shapes) == 2:
        (out["update_reads"], out["update_writes"]) = shapes[0]
        (out["xpay_reads"], out["xpay_writes"]) = shapes[1]
    return out


def run() -> list[tuple[str, float, str]]:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=560)
    if r.returncode != 0:
        rows = [("solver_comparison", -1.0, "FAILED:" + r.stderr[-200:])]
    else:
        line = [ln for ln in r.stdout.splitlines()
                if ln.startswith("RESULT")][-1]
        d = json.loads(line[len("RESULT"):])
        rows = []
        for sv, v in d.items():
            rows.append((f"solver_{sv}", float(v["iters"]),
                         f"rel_res={v['rel_res']:.2e};"
                         f"all_reduces={v['all_reduce_in_body']}"))
    try:
        rows.extend(_run_eo_comparison())
    except Exception as e:  # keep the subprocess rows; degrade like above
        rows.append(("eo_comparison", -1.0, f"FAILED:{e!r:.200}"))

    report = {"schema": 1, "bench": "solvers",
              "generated_by": "benchmarks/bench_solvers.py"}
    try:
        smoke = _run_eo_smoke()
        report["eo_smoke"] = smoke
        rows.append(("cgnr_eo_pallas_4x4x4x4", smoke["cgnr_eo_pallas_us"],
                     f"iters={smoke['cgnr_eo_pallas_iters']};"
                     f"rel_res={smoke['rel_res_pallas']:.2e};"
                     f"sites_per_s={smoke['sites_per_s_pallas']:.0f}"))
    except Exception as e:
        rows.append(("eo_smoke", -1.0, f"FAILED:{e!r:.200}"))
    try:
        tm = _run_eo_smoke_tm()
        report["eo_smoke_tm"] = tm
        for e in tm["entries"]:
            rows.append((e["name"] + "_4x4x4x4", e["us_warm"],
                         f"iters={e['iters']};backend={e['backend']};"
                         f"us_first={e['us_first']:.0f}"))
    except Exception as e:
        rows.append(("eo_smoke_tm", -1.0, f"FAILED:{e!r:.200}"))
    try:
        sweep = _run_batch_sweep()
        report["batch_sweep"] = sweep
        for e in sweep["entries"]:
            rows.append((f"cgnr_eo_batched_n{e['n_rhs']}", e["us_warm"],
                         f"iters={e['iters']};"
                         f"max_rel_res={e['max_rel_res']:.2e};"
                         f"sites_rhs_per_s={e['sites_rhs_per_s']:.0f}"))
    except Exception as e:
        rows.append(("batch_sweep", -1.0, f"FAILED:{e!r:.200}"))
    try:
        blk = _run_blockcg()
        report["blockcg_16rhs"] = blk
        rows.append((f"blockcg_n{blk['n_rhs']}", blk["us_warm"],
                     f"iters={blk['blockcg_iters']};"
                     f"total_matvecs={blk['total_matvecs']};"
                     f"vs_16x_single={blk['matvec_ratio']:.2f}x"))
    except Exception as e:
        rows.append(("blockcg_16rhs", -1.0, f"FAILED:{e!r:.200}"))
    try:
        dfl = _run_eo_deflation()
        report["eo_deflation"] = dfl
        rows.append(("eo_deflation", dfl["us_deflated_warm"],
                     f"iters={dfl['deflated_iters']}"
                     f"(undeflated={dfl['undeflated_iters']});"
                     f"harvest={dfl['harvest_iters']};"
                     f"nev={dfl['nev']}"))
    except Exception as e:
        rows.append(("eo_deflation", -1.0, f"FAILED:{e!r:.200}"))
    try:
        sh = _run_eo_sharded()
        report["eo_sharded"] = sh
        rows.append((f"cgnr_eo_sharded_n{sh['n_rhs']}", sh["us_warm"],
                     f"iters={sh['iters']};mesh={sh['mesh']};"
                     f"max_rel_res={sh['max_rel_res']:.2e};"
                     f"sites_rhs_per_s={sh['sites_rhs_per_s']:.0f}"))
    except Exception as e:
        rows.append(("eo_sharded", -1.0, f"FAILED:{e!r:.200}"))
    try:
        ck = _run_ckpt_overhead()
        report["ckpt_overhead"] = ck
        rows.append(("cgnr_eo_checkpointed_4x4x4x4", ck["us_checkpointed"],
                     f"iters={ck['iters_checkpointed']};"
                     f"bitwise_equal={ck['bitwise_equal']};"
                     f"segments={ck['segments']};"
                     f"us_per_snapshot="
                     f"{ck['overhead_us_per_snapshot']:.0f}"))
    except Exception as e:
        rows.append(("ckpt_overhead", -1.0, f"FAILED:{e!r:.200}"))
    try:
        shape = _fused_engine_shape()
        report["fused_engine"] = shape
        rows.append(("cg_fused_engine", float(
            shape["pallas_calls_per_iteration"]),
            f"traffic={shape['kernel_traffic']};"
            f"naive={shape['naive_traffic']}"))
    except Exception as e:
        rows.append(("fused_engine_shape", -1.0, f"FAILED:{e!r:.200}"))
    report["rows"] = [list(row) for row in rows]

    # Uniform labels + achieved-vs-roofline bandwidth on every tagged
    # entry (ISSUE 10).  The traffic model: one Schur matvec streams
    # ~one full-lattice dslash's §6 traffic ((144/N + 48)·4 bytes/site
    # over the two half-lattice hop passes), a LOWER bound that ignores
    # the CG vector engine's 48 reals/site — so bw_fraction here is
    # conservative.  Entries keep their own interpret/backend tags (a
    # row that deliberately ran the other lowering says so).
    from benchmarks.roofline import dslash_intensity
    smoke_volume = 1
    for d in SMOKE_DIMS:
        smoke_volume *= d

    def _annotate(e):
        n = int(e.get("n_rhs", 1))
        mv = e.get("matvecs")
        if mv and e.get("us_warm"):
            model = dslash_intensity(n_rhs=n, dtype_bytes=4)
            total = model["bytes_per_site"] * smoke_volume * n * mv
            bw = total / (e["us_warm"] / 1e6) / 1e9
            e = {**e, "model_bw_gbs": bw,
                 "bw_fraction": bench_config.bw_fraction(bw)}
        return bench_config.label_entry(e)

    for sec_name in ("eo_smoke", "eo_smoke_tm", "batch_sweep"):
        sec = report.get(sec_name)
        if sec and "entries" in sec:
            sec["entries"] = [_annotate(e) for e in sec["entries"]]
    report["labels"] = bench_config.labels()
    report["launch"] = bench_config.launch_env()
    try:
        report["peak_bw_gbs"] = bench_config.peak_bandwidth_gbs()
    except Exception:
        pass

    path = os.environ.get("BENCH_SOLVERS_JSON", "BENCH_solvers.json")
    try:
        with open(path, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
            f.write("\n")
    except OSError as e:
        rows.append(("bench_solvers_json", -1.0, f"FAILED:{e!r:.120}"))
    return rows
