#!/usr/bin/env python
"""Append-per-PR performance trajectory (``BENCH_perf_trajectory.json``).

Condenses one compiled-backend bench run (the ``BENCH_dslash.json`` and
``BENCH_solvers.json`` artifacts produced under ``launch_bench.sh``)
into a snapshot — warm sites·RHS/s, warm/first split, and
achieved-vs-roofline ``bw_fraction`` per perf-critical entry — and
appends it to the committed trajectory file.  One snapshot per commit:
re-running on the same commit replaces its snapshot instead of
duplicating it, so CI re-runs stay idempotent.

``check_solver_regression.py --perf`` gates on this file: within the
latest snapshot the compiled Pallas dslash rows must beat the jnp
reference at equal N (the interpret-mode inversion stays closed), and
across snapshots on the same device_kind the warm throughput and
bandwidth fraction must not collapse (generous slack — wall-clock on
shared runners is noisy; the hard, noise-free signal stays the
iteration-count guard).

Usage:  perf_trajectory.py --append [--dslash BENCH_dslash.json]
            [--solvers BENCH_solvers.json] [--out BENCH_perf_trajectory.json]
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

DEFAULT_OUT = "BENCH_perf_trajectory.json"

# dslash entries whose trajectory the --perf gate watches (warm
# steady-state rows of the compiled lane; name prefixes)
PERF_PREFIXES = ("dslash_jnp_", "dslash_pallas_")


def _git_commit() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def _load(path):
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError):
        return None


def snapshot(dslash_doc, solvers_doc, commit: str | None = None) -> dict:
    """One trajectory snapshot from the bench artifacts."""
    entries = []
    labels = {}
    if dslash_doc:
        for e in dslash_doc.get("entries", []):
            if not e["name"].startswith(PERF_PREFIXES):
                continue
            entries.append({k: e[k] for k in (
                "name", "us_warm", "us_first", "sites_rhs_per_s",
                "model_bw_gbs", "bw_fraction", "n_rhs", "interpret",
                "lowering") if k in e})
        labels = {k: dslash_doc["entries"][0].get(k) for k in
                  ("platform", "device_kind", "compiled")
                  if dslash_doc.get("entries")}
    if solvers_doc:
        for sec in ("eo_smoke", "batch_sweep"):
            for e in (solvers_doc.get(sec) or {}).get("entries", []):
                name = e.get("name") or f"cgnr_eo_batched_n{e['n_rhs']}"
                row = {"name": f"solver_{name}", "us_warm": e.get("us_warm"),
                       "us_first": e.get("us_first")}
                for k in ("sites_per_s", "sites_rhs_per_s", "bw_fraction",
                          "model_bw_gbs", "iters", "n_rhs", "interpret",
                          "lowering"):
                    if k in e:
                        row[k] = e[k]
                entries.append(row)
    snap = {
        "commit": commit or _git_commit(),
        "date": time.strftime("%Y-%m-%d"),
        "entries": entries,
    }
    snap.update(labels)
    for doc in (dslash_doc, solvers_doc):
        if doc and "peak_bw_gbs" in doc:
            snap["peak_bw_gbs"] = doc["peak_bw_gbs"]
            break
    if dslash_doc and "launch" in dslash_doc:
        snap["launch"] = dslash_doc["launch"]
    return snap


def append(snap: dict, out_path: str) -> dict:
    doc = _load(out_path) or {
        "schema": 1,
        "comment": "append-per-PR compiled-backend perf trajectory; "
                   "regenerate a snapshot with benchmarks/launch_bench.sh; "
                   "gated by check_solver_regression.py --perf",
        "snapshots": [],
    }
    doc["snapshots"] = [s for s in doc["snapshots"]
                        if s.get("commit") != snap["commit"]]
    doc["snapshots"].append(snap)
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    return doc


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description="append a perf snapshot")
    p.add_argument("--append", action="store_true",
                   help="append/replace the snapshot for the current commit")
    p.add_argument("--dslash", default=os.environ.get(
        "BENCH_DSLASH_JSON", "BENCH_dslash.json"))
    p.add_argument("--solvers", default=os.environ.get(
        "BENCH_SOLVERS_JSON", "BENCH_solvers.json"))
    p.add_argument("--out", default=os.environ.get(
        "BENCH_PERF_TRAJECTORY_JSON", DEFAULT_OUT))
    p.add_argument("--commit", default=None,
                   help="override the snapshot's commit id")
    args = p.parse_args(argv)

    dslash_doc = _load(args.dslash)
    solvers_doc = _load(args.solvers)
    if dslash_doc is None and solvers_doc is None:
        print(f"perf_trajectory: neither {args.dslash} nor {args.solvers} "
              "readable; run the benches first", file=sys.stderr)
        return 1
    snap = snapshot(dslash_doc, solvers_doc, commit=args.commit)
    if not snap["entries"]:
        print("perf_trajectory: no perf-critical entries found",
              file=sys.stderr)
        return 1
    if args.append:
        doc = append(snap, args.out)
        print(f"perf_trajectory: {len(snap['entries'])} entries @ "
              f"{snap['commit']} -> {args.out} "
              f"({len(doc['snapshots'])} snapshots)")
    else:
        json.dump(snap, sys.stdout, indent=2, sort_keys=True)
        print()
    return 0


if __name__ == "__main__":
    sys.exit(main())
