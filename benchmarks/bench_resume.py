#!/usr/bin/env python
"""Crash-resume lane: SIGKILL real processes, then prove nothing was lost.

Three experiments, each against a REAL subprocess (not an in-process
simulation — the point is surviving a kill the victim cannot observe):

* **solver** — a checkpointed ``repro.launch.solve`` run is SIGKILLed the
  moment its first snapshot lands; a second invocation with ``--resume``
  must restore the newest valid checkpoint, defect-correct, verify the
  accumulated solution against the true residual and exit 0.
* **elastic** — a solve checkpointed on a 2x2x2 mesh (8 fake host
  devices) is SIGKILLed mid-run; the resume runs WITHOUT the mesh
  (single device) — checkpoints store unsharded host arrays, so losing
  hardware costs a segment of work, not the run.
* **journal** — a journaled ``repro.launch.serve_solver`` run is
  SIGKILLed mid-stream; ``SolverServer.recover`` on a fresh server over
  the same journal directory must replay every admitted-but-incomplete
  request to completion.

Writes **BENCH_resume.json**; ``check_solver_regression.py --resume``
gates it in the blocking ``crash-resume`` CI lane.  Each kill is retried
a few times (a fast child can finish before the trigger fires on a slow
runner) — the report records the attempt count.
"""

from __future__ import annotations

import asyncio
import json
import os
import re
import sys

SRC = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "src")
KILL_RETRIES = 3


def _env():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    return env


def _steps_in(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    return sorted(
        int(n.split("_")[1]) for n in os.listdir(ckpt_dir)
        if n.startswith("step_")
        and os.path.exists(os.path.join(ckpt_dir, n, "manifest.json")))


def _kill_when_steps(ckpt_dir: str, n: int):
    return lambda _out="": len(_steps_in(ckpt_dir)) >= n


def _admit_lines(journal_dir: str) -> int:
    path = os.path.join(journal_dir, "journal.jsonl")
    if not os.path.exists(path):
        return 0
    with open(path, encoding="utf-8") as f:
        return sum(1 for line in f if '"admit"' in line)


def _run_solver_lane(workdir: str) -> dict:
    """Kill a checkpointing solve mid-segment, resume it, gate on exit 0."""
    from repro.serve.chaos import run_and_sigkill

    out: dict = {"lane": "solver"}
    base_args = [sys.executable, "-m", "repro.launch.solve",
                 "--lattice", "4x4x4x8", "--parity", "eo",
                 "--solver", "cgnr", "--tol", "1e-7", "--maxiter", "2000"]
    killed = False
    for attempt in range(1, KILL_RETRIES + 1):
        ck = os.path.join(workdir, f"solver_ck_{attempt}")
        crash = run_and_sigkill(
            base_args + ["--checkpoint-dir", ck, "--checkpoint-every", "2"],
            kill_when=_kill_when_steps(ck, 1), env=_env(), poll_s=0.01,
            timeout_s=420)
        out["kill_attempts"] = attempt
        if crash.killed:
            killed = True
            break
    out["killed"] = killed
    out["steps_at_kill"] = _steps_in(ck)
    if not killed:
        return out
    import subprocess
    r = subprocess.run(
        base_args + ["--checkpoint-dir", ck, "--resume"],
        env=_env(), capture_output=True, text=True, timeout=420)
    out["resume_exit"] = r.returncode
    m = re.search(r"resumed from step (\d+)", r.stdout)
    out["resumed_from_step"] = int(m.group(1)) if m else None
    out["resume_ok"] = (r.returncode == 0 and m is not None)
    if not out["resume_ok"]:
        out["resume_tail"] = r.stdout[-1500:] + r.stderr[-500:]
    return out


_ELASTIC_SOLVE = r"""
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from jax.experimental.mesh_utils import create_device_mesh
from jax.sharding import Mesh
from repro.core import LatticeShape, random_gauge, random_spinor
from repro.core import plan as plan_mod

d = sys.argv[1]
lat = LatticeShape(4, 4, 4, 8)
key = jax.random.PRNGKey(11)
ku, kb = jax.random.split(key)
u, b = random_gauge(ku, lat), random_spinor(kb, lat)
mesh = Mesh(create_device_mesh((2, 2, 2)), ("pod", "data", "model"))
plan = plan_mod.SolverPlan(operator="eo-schur", solver="cgnr", mesh=mesh)
plan_mod.solve(plan, u, b, 0.1, tol=1e-7, maxiter=2000,
               checkpoint=plan_mod.CheckpointPolicy(dir=d, every_iters=2))
print("SHARDED_SOLVE_DONE")
"""

_ELASTIC_RESUME = r"""
import sys
import jax, numpy as np
from repro.core import LatticeShape, random_gauge, random_spinor
from repro.core import plan as plan_mod
from repro.core.resilience import resume_solve

d = sys.argv[1]
lat = LatticeShape(4, 4, 4, 8)
key = jax.random.PRNGKey(11)
ku, kb = jax.random.split(key)
u, b = random_gauge(ku, lat), random_spinor(kb, lat)
plan = plan_mod.SolverPlan(operator="eo-schur", solver="cgnr")
x, st, rec = resume_solve(plan, u, b, 0.1, checkpoint_dir=d, tol=1e-7,
                          maxiter=2000)
assert bool(np.asarray(st.verified).all()), st
print(f"RESUMED_FROM={rec.resumed_from_step}")
"""


def _run_elastic_lane(workdir: str) -> dict:
    """Kill a 2x2x2-mesh checkpointed solve, resume it on one device."""
    import subprocess

    from repro.serve.chaos import run_and_sigkill

    out: dict = {"lane": "elastic", "mesh": "2x2x2"}
    killed = False
    for attempt in range(1, KILL_RETRIES + 1):
        ck = os.path.join(workdir, f"elastic_ck_{attempt}")
        crash = run_and_sigkill(
            [sys.executable, "-c", _ELASTIC_SOLVE, ck],
            kill_when=_kill_when_steps(ck, 1), env=_env(), poll_s=0.01,
            timeout_s=420)
        out["kill_attempts"] = attempt
        if crash.killed:
            killed = True
            break
    out["killed"] = killed
    out["steps_at_kill"] = _steps_in(ck)
    if not killed:
        return out
    r = subprocess.run([sys.executable, "-c", _ELASTIC_RESUME, ck],
                       env=_env(), capture_output=True, text=True,
                       timeout=420)
    out["resume_exit"] = r.returncode
    m = re.search(r"RESUMED_FROM=(\d+)", r.stdout)
    out["resumed_from_step"] = int(m.group(1)) if m else None
    out["resume_ok"] = (r.returncode == 0 and m is not None)
    if not out["resume_ok"]:
        out["resume_tail"] = r.stdout[-1500:] + r.stderr[-1500:]
    return out


def _run_journal_lane(workdir: str) -> dict:
    """Kill a journaled server mid-stream, recover, gate on zero leftover."""
    from repro.serve import journal as jm
    from repro.serve.chaos import run_and_sigkill
    from repro.serve.loadgen import WorkloadConfig, build_workload
    from repro.serve.server import SolverServer

    out: dict = {"lane": "journal"}
    args = [sys.executable, "-m", "repro.launch.serve_solver",
            "--lattice", "4x4x4x4", "--requests", "40", "--burst", "4",
            "--interarrival-ms", "20", "--ladder", "1,4"]
    killed = False
    for attempt in range(1, KILL_RETRIES + 1):
        jd = os.path.join(workdir, f"journal_{attempt}")
        crash = run_and_sigkill(
            args + ["--journal-dir", jd],
            kill_when=lambda _out="", d=jd: _admit_lines(d) >= 8,
            env=_env(), poll_s=0.01, timeout_s=420)
        out["kill_attempts"] = attempt
        if crash.killed:
            killed = True
            break
    out["killed"] = killed
    out["admits_at_kill"] = _admit_lines(jd)
    if not killed:
        return out
    incomplete = jm.incomplete_requests(jd)
    out["incomplete_found"] = len(incomplete)
    # same WorkloadConfig the CLI resolved -> same deterministic gauges
    cfg = WorkloadConfig(requests=40, burst=4, interarrival_s=0.02,
                         ladder=(1, 4))
    gauges, _ = build_workload(cfg)

    async def recover():
        server = SolverServer(mass=cfg.mass, ladder=cfg.ladder,
                              maxiter=cfg.maxiter, journal_dir=jd)
        for gid, u in gauges.items():
            server.register_gauge(gid, u)
        summary = await server.recover()
        await server.close()
        return summary

    summary = asyncio.run(recover())
    out["recovered"] = int(summary["completed"]) + int(summary["failed"]) \
        + int(summary["skipped_unknown_gauge"])
    out["recovery"] = {k: v for k, v in summary.items() if k != "results"}
    out["incomplete_after_recovery"] = len(jm.incomplete_requests(jd))
    return out


def main(argv=None) -> int:
    import argparse
    import tempfile

    sys.path.insert(0, SRC)
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default=os.environ.get("BENCH_RESUME_JSON",
                                                   "BENCH_resume.json"))
    p.add_argument("--workdir", default=None,
                   help="scratch directory for checkpoints/journals "
                        "(default: a fresh temp dir)")
    args = p.parse_args(argv)
    workdir = args.workdir or tempfile.mkdtemp(prefix="bench_resume_")

    report = {"schema": 1, "bench": "resume",
              "generated_by": "benchmarks/bench_resume.py"}
    for name, lane in (("solver", _run_solver_lane),
                       ("elastic", _run_elastic_lane),
                       ("journal", _run_journal_lane)):
        try:
            report[name] = lane(workdir)
        except Exception as e:
            report[name] = {"lane": name, "error": f"{e!r:.300}"}
        print(f"[bench_resume] {name}: "
              + json.dumps(report[name], default=str))

    with open(args.out, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True, default=str)
        f.write("\n")
    print(f"[bench_resume] wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
