"""Paper Fig. 2 — transfer/compute overlap, at the inter-chip level.

The FPGA trace shows input DMA / compute / output DMA overlapping until
transfer is "invisible".  The TPU analogue: the halo-exchange dslash's
boundary corrections are independent of the bulk stencil, so the
collective-permutes overlap bulk compute.  This bench runs in a
subprocess on 8 fake devices and reports (a) the HLO structural evidence
(collective-permute count + bytes vs bulk FLOPs), (b) measured step times
for halo vs bulk-only (CPU; the roofline terms give the TPU projection).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, time
import jax, jax.numpy as jnp
from repro.core import LatticeShape, pack_gauge, pack_spinor
from repro.core import distributed as dist
from repro.data import lattice_problem

from repro.compat import make_mesh, shard_map
mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
lat = LatticeShape(8, 8, 8, 8)
up, pp = lattice_problem(lat, mass=0.1)
upd, ppd = dist.shard_lattice_fields(mesh, up, pp)
psi_spec, gauge_spec, sharded = dist.lattice_specs(mesh)

halo = jax.jit(shard_map(lambda u, p: dist.dslash_halo(u, p, 0.1, sharded),
                         mesh=mesh, in_specs=(gauge_spec, psi_spec),
                         out_specs=psi_spec))
from repro.core.wilson import dslash_packed
bulk = jax.jit(shard_map(lambda u, p: dslash_packed(u, p, 0.1),
                         mesh=mesh, in_specs=(gauge_spec, psi_spec),
                         out_specs=psi_spec))

def timeit(f):
    f(upd, ppd).block_until_ready()
    t0 = time.time()
    for _ in range(5):
        out = f(upd, ppd)
    out.block_until_ready()
    return (time.time() - t0) / 5

t_halo, t_bulk = timeit(halo), timeit(bulk)
txt = halo.lower(upd, ppd).compile().as_text()
n_perm = txt.count(" collective-permute(")
print("RESULT" + json.dumps({"t_halo_us": t_halo * 1e6,
                             "t_bulk_us": t_bulk * 1e6,
                             "halo_overhead": t_halo / t_bulk,
                             "collective_permutes": n_perm}))
"""


def run() -> list[tuple[str, float, str]]:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=560)
    if r.returncode != 0:
        return [("overlap_halo_vs_bulk", -1.0, "FAILED:" + r.stderr[-200:])]
    line = [ln for ln in r.stdout.splitlines() if ln.startswith("RESULT")][-1]
    d = json.loads(line[len("RESULT"):])
    return [("dslash_halo_8dev", d["t_halo_us"],
             f"overhead_vs_bulk={d['halo_overhead']:.2f}x;"
             f"collective_permutes={d['collective_permutes']}"),
            ("dslash_bulk_8dev", d["t_bulk_us"], "no-comm baseline")]
