"""Serving benchmark — open-loop load against the continuous-batching server.

Two entry points:

* ``run()`` — the benchmarks/run.py harness protocol: a SMALL smoke
  workload, returning ``(name, us_per_call, derived)`` rows (mean latency
  per request; derived column carries req/s and the batch histogram).
  Excluded from the default CSV sweep — opt in with ``run.py --with-serve``.
* ``main(argv)`` — the CI ``serve-smoke`` lane: a configurable workload,
  ``--verify`` re-solving every response against a direct unbatched
  ``plan.solve`` (gate: max abs deviation ≤ 1e-5), and the full report
  written to ``BENCH_serve.json`` (or ``$BENCH_SERVE_JSON``) for the
  regression guard (check_solver_regression.py --serve) and artifact
  upload.  Exits nonzero on verify failure or non-convergence.

``main`` additionally runs the warm-gauge DEFLATION lane (unless
``--chaos`` or ``--skip-deflation-serve``): a light-mass workload with
the per-gauge EigCG deflation cache on, embedded in the report as
``deflation_serve`` — the guarded proof that a second request on a hot
gauge field converges in strictly fewer iterations than the first.

Latency numbers here include queueing by construction (open-loop
arrivals), so they are throughput-honest but NOT a kernel benchmark —
see bench_solvers.py for per-iteration timings.
"""

from __future__ import annotations

import json
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)  # `benchmarks` package for direct script runs

from repro.launch.serve_solver import build_config, make_parser  # noqa: E402
from repro.serve.loadgen import WorkloadConfig, run_workload  # noqa: E402

OUT_JSON = os.environ.get("BENCH_SERVE_JSON", "BENCH_serve.json")

# run.py harness smoke: small enough to finish alongside the other CSV
# modules, large enough that coalescing actually happens.
SMOKE = WorkloadConfig(requests=40, burst=4, interarrival_s=0.02,
                       ladder=(1, 4, 8), maxiter=500)

# Warm-gauge deflation lane (ISSUE 9): a second, light-mass workload with
# the per-gauge deflation cache ON.  At the smoke mass (0.1, 14
# iterations) deflation is physically inert, so this lane runs
# near-critical mass where the Krylov space is ~120 deep — the first
# verified solve per (gauge, family) harvests an EigCG basis, and every
# later request on that key must converge in STRICTLY fewer iterations
# (guarded by check_solver_regression.py --serve via the
# ``deflation_serve`` report section).  Wilson-only keeps it cheap; both
# gauges exercise the per-gauge keying.
DEFLATION_SERVE = WorkloadConfig(
    families=(("wilson", 0.0),), mass=-1.7, tol=1e-6, requests=32,
    burst=4, interarrival_s=0.01, rhs_pool=8, n_gauge=2, ladder=(1, 4, 8),
    max_wait_s=0.05, maxiter=500, verify=True,
    deflation_nev=32, deflation_m_max=160, deflation_harvest_tol=1e-8)


def run():
    """Harness protocol: yield (name, us_per_call, derived) rows."""
    from benchmarks import bench_config
    report = run_workload(SMOKE)
    # uniform label block, same schema as bench_dslash/bench_solvers
    report["labels"] = bench_config.labels()
    report["launch"] = bench_config.launch_env()
    with open(OUT_JSON, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    lat = report["latency_ms"]
    hist = ";".join(f"{k}x{v}" for k, v in sorted(
        report["batch_hist"].items(), key=lambda kv: int(kv[0])))
    yield ("serve_p50", lat["p50"] * 1e3,
           f"{report['requests_per_s']:.1f}req/s")
    yield ("serve_p99", lat["p99"] * 1e3, f"batches={hist}")
    yield ("serve_mean", lat["mean"] * 1e3,
           f"hit_rate={report['request_cache_hit_rate']:.2f}")


def main(argv=None) -> int:
    parser = make_parser()
    parser.add_argument("--skip-deflation-serve", action="store_true",
                        help="skip the embedded warm-gauge deflation lane "
                             "(DEFLATION_SERVE); it also auto-skips under "
                             "--chaos")
    parser.set_defaults(out=OUT_JSON)
    args = parser.parse_args(argv)
    cfg = build_config(args)
    print(f"[bench_serve] {cfg.requests} requests, "
          f"{cfg.n_gauge} gauges x {len(cfg.families)} families, "
          f"ladder={list(cfg.ladder)}, verify={cfg.verify}")
    report = run_workload(cfg)
    lat = report["latency_ms"]
    print(f"[bench_serve] {report['requests_per_s']:.1f} req/s  "
          f"p50={lat['p50']:.1f}ms p99={lat['p99']:.1f}ms  "
          f"batches={report['batch_hist']}  "
          f"hit_rate={report['request_cache_hit_rate']:.3f}")
    deflation_ok = True
    if not (args.skip_deflation_serve or args.chaos):
        d = DEFLATION_SERVE
        print(f"[bench_serve] deflation lane: {d.requests} requests at "
              f"mass={d.mass}, nev={d.deflation_nev}, "
              f"harvest_tol={d.deflation_harvest_tol}")
        defl = run_workload(d)
        report["deflation_serve"] = defl
        drop = defl["deflation_drop"]
        cache = defl["deflation"]
        print(f"[bench_serve] deflation lane: {cache['harvests']} "
              f"harvests, {drop['hit_requests']} cache-hit requests, "
              f"keys={drop['keys']}")
        deflation_ok = (bool(defl["all_converged"])
                        and drop["all_hits_dropped"]
                        and drop["hit_requests"] > 0
                        and defl.get("verify", {}).get("passed", True))
        print(f"[bench_serve] deflation lane: "
              f"{'OK' if deflation_ok else 'FAIL'} (strict iteration "
              f"drop on every warm-gauge hit)")
    if args.out:
        from benchmarks import bench_config
        report["labels"] = bench_config.labels()
        report["launch"] = bench_config.launch_env()
        with open(args.out, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"[bench_serve] wrote {args.out}")
    ok = bool(report["all_converged"]) and deflation_ok
    if "chaos" in report:
        c = report["chaos"]
        print(f"[bench_serve] chaos: poisoned {c['poisoned_failed']}/"
              f"{c['poisoned']} failed-classified, healthy "
              f"{c['healthy_ok']}/{c['healthy']} ok "
              f"(rescued={c['healthy_rescued_by_retry']}), "
              f"goodput={c['goodput_rps']:.1f} req/s, "
              f"containment={'OK' if c['containment_ok'] else 'FAIL'}")
        ok = ok and c["containment_ok"]
    if "verify" in report:
        v = report["verify"]
        print(f"[bench_serve] verify: max_abs_err={v['max_abs_err']:.2e} "
              f"({'OK' if v['passed'] else 'FAIL'})")
        ok = ok and v["passed"]
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
