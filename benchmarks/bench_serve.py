"""Serving benchmark — open-loop load against the continuous-batching server.

Two entry points:

* ``run()`` — the benchmarks/run.py harness protocol: a SMALL smoke
  workload, returning ``(name, us_per_call, derived)`` rows (mean latency
  per request; derived column carries req/s and the batch histogram).
  Excluded from the default CSV sweep — opt in with ``run.py --with-serve``.
* ``main(argv)`` — the CI ``serve-smoke`` lane: a configurable workload,
  ``--verify`` re-solving every response against a direct unbatched
  ``plan.solve`` (gate: max abs deviation ≤ 1e-5), and the full report
  written to ``BENCH_serve.json`` (or ``$BENCH_SERVE_JSON``) for the
  regression guard (check_solver_regression.py --serve) and artifact
  upload.  Exits nonzero on verify failure or non-convergence.

Latency numbers here include queueing by construction (open-loop
arrivals), so they are throughput-honest but NOT a kernel benchmark —
see bench_solvers.py for per-iteration timings.
"""

from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

from repro.launch.serve_solver import build_config, make_parser  # noqa: E402
from repro.serve.loadgen import WorkloadConfig, run_workload  # noqa: E402

OUT_JSON = os.environ.get("BENCH_SERVE_JSON", "BENCH_serve.json")

# run.py harness smoke: small enough to finish alongside the other CSV
# modules, large enough that coalescing actually happens.
SMOKE = WorkloadConfig(requests=40, burst=4, interarrival_s=0.02,
                       ladder=(1, 4, 8), maxiter=500)


def run():
    """Harness protocol: yield (name, us_per_call, derived) rows."""
    report = run_workload(SMOKE)
    with open(OUT_JSON, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    lat = report["latency_ms"]
    hist = ";".join(f"{k}x{v}" for k, v in sorted(
        report["batch_hist"].items(), key=lambda kv: int(kv[0])))
    yield ("serve_p50", lat["p50"] * 1e3,
           f"{report['requests_per_s']:.1f}req/s")
    yield ("serve_p99", lat["p99"] * 1e3, f"batches={hist}")
    yield ("serve_mean", lat["mean"] * 1e3,
           f"hit_rate={report['request_cache_hit_rate']:.2f}")


def main(argv=None) -> int:
    parser = make_parser()
    parser.set_defaults(out=OUT_JSON)
    args = parser.parse_args(argv)
    cfg = build_config(args)
    print(f"[bench_serve] {cfg.requests} requests, "
          f"{cfg.n_gauge} gauges x {len(cfg.families)} families, "
          f"ladder={list(cfg.ladder)}, verify={cfg.verify}")
    report = run_workload(cfg)
    lat = report["latency_ms"]
    print(f"[bench_serve] {report['requests_per_s']:.1f} req/s  "
          f"p50={lat['p50']:.1f}ms p99={lat['p99']:.1f}ms  "
          f"batches={report['batch_hist']}  "
          f"hit_rate={report['request_cache_hit_rate']:.3f}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"[bench_serve] wrote {args.out}")
    ok = bool(report["all_converged"])
    if "chaos" in report:
        c = report["chaos"]
        print(f"[bench_serve] chaos: poisoned {c['poisoned_failed']}/"
              f"{c['poisoned']} failed-classified, healthy "
              f"{c['healthy_ok']}/{c['healthy']} ok "
              f"(rescued={c['healthy_rescued_by_retry']}), "
              f"goodput={c['goodput_rps']:.1f} req/s, "
              f"containment={'OK' if c['containment_ok'] else 'FAIL'}")
        ok = ok and c["containment_ok"]
    if "verify" in report:
        v = report["verify"]
        print(f"[bench_serve] verify: max_abs_err={v['max_abs_err']:.2e} "
              f"({'OK' if v['passed'] else 'FAIL'})")
        ok = ok and v["passed"]
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
