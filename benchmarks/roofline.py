"""Roofline aggregation: reads experiments/dryrun/*.json into the
EXPERIMENTS.md §Dry-run / §Roofline tables.

Conventions (see also repro.launch.dryrun):
  * cost_analysis / collective bytes come from the per-device SPMD HLO of
    reduced-depth UNROLLED lowerings, linearly extrapolated to full depth
    (XLA counts while bodies once) — so all three terms are PER-CHIP
    seconds and the chips factor in the roofline formulas is already
    applied.
  * "bytes accessed" from CPU-compiled HLO over-counts TPU HBM traffic
    (CPU fuses less), so the memory term is an upper bound; relative
    before/after comparisons in §Perf remain valid.
  * model FLOPs = 6·N_active·tokens (train) or 2·N_active·tokens (serve).
"""

from __future__ import annotations

import glob
import json
import os

PEAK = {"compute": 197e12, "hbm": 819e9, "ici": 50e9}


def dslash_intensity(n_rhs: int = 1, dtype_bytes: int = 4) -> dict:
    """DESIGN.md §6 streaming-traffic model for the packed Wilson dslash.

    Per output site one application reads 8 links × 18 reals = 144 reals
    of gauge plus 24 reals of spinor and writes 24; batching N RHS
    through one gauge read amortizes only the gauge term:

        bytes/site/RHS = (144 / N + 48) · dtype_bytes
        flops/site     = 1320                  (paper §5 convention)

    Returns the model's bytes/site, flops/site and arithmetic intensity
    (flops per byte).  bench_dslash.py divides measured wall-time into
    this model to report the memory bandwidth each timing WOULD need if
    it streamed exactly the model traffic — the achieved-vs-model
    column in BENCH_dslash.json.
    """
    if n_rhs < 1:
        raise ValueError(f"n_rhs must be >= 1, got {n_rhs}")
    bytes_per_site = (144.0 / n_rhs + 48.0) * dtype_bytes
    flops_per_site = 1320.0
    return {"n_rhs": int(n_rhs), "dtype_bytes": int(dtype_bytes),
            "bytes_per_site": bytes_per_site,
            "flops_per_site": flops_per_site,
            "flops_per_byte": flops_per_site / bytes_per_site}

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def load_cells(dryrun_dir: str = DRYRUN_DIR) -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def fmt_bytes(n) -> str:
    return f"{n / 2**30:.2f}GiB"


def table(cells: list[dict], mesh: str = "pod") -> str:
    """Markdown roofline table for one mesh."""
    hdr = ("| arch | shape | fits (arg+temp/chip) | compute_s | memory_s | "
           "collective_s | dominant | useful_flops | note |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for c in cells:
        if c["mesh"] != mesh:
            continue
        if c["status"] == "skipped":
            lines.append(f"| {c['arch']} | {c['shape']} | — | — | — | — | — "
                         f"| — | SKIP: {c['reason'][:60]}… |")
            continue
        r = c["roofline"]
        per_dev = c["per_device_bytes"]
        fits = "Y" if per_dev < 16 * 2**30 else "OVER"
        ufr = c.get("useful_flops_ratio")
        lines.append(
            f"| {c['arch']} | {c['shape']} | {fits} {fmt_bytes(per_dev)} | "
            f"{r['compute_s']:.4f} | {r['memory_s']:.4f} | "
            f"{r['collective_s']:.4f} | {r['dominant']} | "
            f"{ufr:.2f} | compile {c['compile_s']:.0f}s |")
    return "\n".join(lines)


def summarize(cells: list[dict]) -> dict:
    ok = [c for c in cells if c["status"] == "ok"]
    skipped = [c for c in cells if c["status"] == "skipped"]
    doms = {}
    for c in ok:
        doms[c["roofline"]["dominant"]] = \
            doms.get(c["roofline"]["dominant"], 0) + 1
    return {"ok": len(ok), "skipped": len(skipped), "dominant": doms}


def run() -> list[tuple[str, float, str]]:
    cells = load_cells()
    s = summarize(cells)
    rows = [("dryrun_cells_ok", float(s["ok"]),
             f"skipped={s['skipped']};dominant={s['dominant']}")]
    worst = None
    for c in cells:
        if c["status"] != "ok" or c["mesh"] != "pod":
            continue
        r = c["roofline"]
        tot = r["compute_s"] + 1e-12
        frac = r["compute_s"] / max(r["compute_s"], r["memory_s"],
                                    r["collective_s"])
        if worst is None or frac < worst[1]:
            worst = (f"{c['arch']}/{c['shape']}", frac)
    if worst:
        rows.append(("worst_roofline_fraction", worst[1], worst[0]))
    return rows


if __name__ == "__main__":
    cells = load_cells()
    print(table(cells, "pod"))
    print()
    print(table(cells, "multipod"))
    print(summarize(cells))
