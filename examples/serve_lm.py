"""Batched serving example: prefill once, decode greedily — the code path
the ``prefill_32k`` / ``decode_32k`` dry-run shapes lower at scale.

    PYTHONPATH=src python examples/serve_lm.py --arch recurrentgemma-9b
"""

import sys

from repro.launch.serve import main

if __name__ == "__main__":
    sys.exit(main(sys.argv[1:] or
                  ["--arch", "glm4-9b", "--requests", "4",
                   "--prompt-len", "32", "--gen", "12"]))
