"""End-to-end driver: train a ~100M-parameter GLM4-family model.

    PYTHONPATH=src python examples/train_lm.py --steps 300

Defaults train ~300 steps of a 98M-param decoder on the synthetic zipf
stream with the full production substrate: mixed-precision AdamW,
warmup-cosine schedule, atomic checkpoints every 50 steps, auto-resume.
(~10 s/step on a single CPU core; on accelerators point --mesh at a real
topology via repro.launch.train.)
"""

import argparse
import dataclasses
import sys
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.data import SyntheticLM
from repro.models import steps as S
from repro.optim import AdamWConfig, warmup_cosine


def model_100m():
    base = configs.get_smoke("glm4-9b")
    return dataclasses.replace(
        base, name="glm4-100m", num_layers=12, d_model=512, num_heads=8,
        num_kv_heads=2, head_dim=64, d_ff=2048, vocab_size=32_768)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = model_100m()
    n = cfg.param_count()
    print(f"[example] {cfg.name}: {n/1e6:.0f}M params")

    opt = AdamWConfig(lr=3e-4, weight_decay=0.1)
    state = S.init_train_state(cfg, jax.random.PRNGKey(0), opt)
    sched = lambda s: warmup_cosine(s, warmup=30, total=args.steps)
    step_fn = jax.jit(S.make_train_step(cfg, opt, compute_dtype=jnp.float32,
                                        lr_schedule=sched))
    data = SyntheticLM(cfg, batch=args.batch, seq_len=args.seq_len)

    start = latest_step(args.ckpt_dir) or 0
    if start:
        print(f"[example] resuming from step {start}")
        state = restore_checkpoint(args.ckpt_dir, start,
                                   jax.eval_shape(lambda: state))

    t0 = time.time()
    for step in range(start, args.steps):
        state, m = step_fn(state, data.batch_at(step))
        if step % args.log_every == 0 or step == args.steps - 1:
            tok_s = args.batch * args.seq_len * (step - start + 1) / \
                (time.time() - t0)
            print(f"[example] step={step:4d} loss={float(m['loss']):.4f} "
                  f"gnorm={float(m['grad_norm']):.2f} ({tok_s:.0f} tok/s)")
        if (step + 1) % 50 == 0 or step == args.steps - 1:
            save_checkpoint(args.ckpt_dir, step + 1, state)
    print(f"[example] done in {time.time()-t0:.0f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
