"""Quickstart: solve a Dirac-Wilson system with the paper's mixed-precision
CG in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp

from repro.core import LatticeShape, cg, mpcg
from repro.core.wilson import (dslash_dagger_packed, dslash_packed,
                               normal_op_packed)
from repro.data import lattice_problem

# 1) a 4^3 x 8 lattice with a random SU(3) gauge field and source b
lat = LatticeShape(4, 4, 4, 8)
gauge, b = lattice_problem(lat, mass=0.3, seed=0)
mass = 0.3

# 2) CGNR: solve D^dag D x = D^dag b (D is not Hermitian; D^dag D is HPD)
rhs = dslash_dagger_packed(gauge, b, mass)
op_high = lambda v: normal_op_packed(gauge, v, mass)           # f32
gauge_low = gauge.astype(jnp.bfloat16)
op_low = lambda v: normal_op_packed(gauge_low, v, mass)        # bf16

# 3) the paper's two-precision reliable-update CG (its Ref. [10] variant):
#    bulk iterations in bf16, true-residual corrections in f32
x, stats = mpcg(op_low, op_high, rhs, tol=1e-6, inner_tol=5e-2,
                inner_maxiter=200, max_outer=30)

residual = dslash_packed(gauge, x, mass) - b
rel = float(jnp.linalg.norm(residual.ravel()) / jnp.linalg.norm(b.ravel()))
print(f"mpcg: {int(stats.iterations)} bf16 inner iterations, "
      f"{int(stats.outer_iterations)} f32 reliable updates, "
      f"true relative residual {rel:.2e}")

# compare: pure f32 CG
x32, stats32 = cg(op_high, rhs, tol=1e-6, maxiter=1000)
print(f"pure f32 cg: {int(stats32.iterations)} iterations "
      f"(mixed precision moved {int(stats.iterations)} of them to bf16)")
assert rel < 1e-5
