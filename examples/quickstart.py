"""Quickstart: one SolverPlan solves any registered lattice operator.

The whole stack is plan-driven: pick an operator FAMILY from the registry
(`wilson` or `twisted-mass`), and the same even-odd Schur CGNR — same
transport kernels, same batching, same precision machinery — solves it.

    PYTHONPATH=src python examples/quickstart.py
    PYTHONPATH=src python examples/quickstart.py \
        --operator twisted-mass --mu 0.25
"""

import argparse

import jax
import jax.numpy as jnp

from repro.core import (LatticeShape, SolverPlan, random_gauge,
                        random_spinor, solve_plan)
from repro.core.operators import dslash_g, operator_names

parser = argparse.ArgumentParser(description=__doc__)
parser.add_argument("--operator", default="wilson",
                    choices=sorted(operator_names()),
                    help="lattice operator family from the registry")
parser.add_argument("--mu", type=float, default=0.0,
                    help="twisted-mass site parameter (i*mu*gamma5 term)")
args = parser.parse_args()

# 1) a 4^3 x 8 lattice with a random SU(3) gauge field and source b
lat = LatticeShape(4, 4, 4, 8)
mass = 0.3
ku, kb = jax.random.split(jax.random.PRNGKey(0))
gauge, b = random_gauge(ku, lat), random_spinor(kb, lat)

# 2) name the solve as data: even-odd Schur CGNR on the chosen operator.
#    The family only swaps the site-local term; every transport layer
#    (hop kernels, halo exchange, batching, packing) is shared.
plan = SolverPlan(operator="eo-schur", operator_family=args.operator,
                  mu=args.mu)
x, stats = solve_plan(plan, gauge, b, mass, tol=1e-6, maxiter=1000)

residual = dslash_g(gauge, x, mass, twist=plan.twist) - b
rel = float(jnp.linalg.norm(residual.ravel()) / jnp.linalg.norm(b.ravel()))
print(f"{args.operator} eo-schur cgnr: {int(stats.iterations)} iterations, "
      f"true relative residual {rel:.2e}")

# 3) the paper's mixed-precision reliable-update CG composes with any
#    family: bulk iterations in bf16, true-residual corrections in f32
mp = SolverPlan(operator="eo-schur", operator_family=args.operator,
                mu=args.mu, precision="mixed")
x_mp, st_mp = solve_plan(mp, gauge, b, mass, tol=1e-6)
res_mp = dslash_g(gauge, x_mp, mass, twist=plan.twist) - b
rel_mp = float(jnp.linalg.norm(res_mp.ravel()) / jnp.linalg.norm(b.ravel()))
print(f"{args.operator} eo-schur mpcg: {int(st_mp.iterations)} bf16 inner "
      f"iterations, {int(st_mp.outer_iterations)} f32 reliable updates, "
      f"true relative residual {rel_mp:.2e}")
assert rel < 1e-5 and rel_mp < 1e-5
