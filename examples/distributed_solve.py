"""Distributed lattice solve: 4D domain decomposition + halo exchange over
a (pod, data, model) mesh, with the pipelined single-reduction CG.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/distributed_solve.py

(On a real TPU slice, drop the XLA_FLAGS and the same code distributes
over the physical mesh — the point of the dry-run deliverable.)
"""

import os
import sys

if "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=8")

import jax                                                      # noqa: E402
import jax.numpy as jnp                                         # noqa: E402

from repro.compat import make_mesh                              # noqa: E402
from repro.core import LatticeShape                             # noqa: E402
from repro.core import distributed as dist                      # noqa: E402
from repro.core.wilson import dslash_packed                     # noqa: E402
from repro.data import lattice_problem                          # noqa: E402


def main():
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    print(f"[dist] devices={len(jax.devices())} mesh={dict(mesh.shape)}")

    lat = LatticeShape(8, 8, 8, 8)
    gauge, b = lattice_problem(lat, mass=0.2, seed=0)
    gauge_d, b_d = dist.shard_lattice_fields(mesh, gauge, b)
    print(f"[dist] lattice {lat} decomposed T->data Z->model Y->pod")

    for solver in ("pipecg", "mpcg"):
        x, st = dist.solve_wilson(mesh, gauge_d, b_d, 0.2, solver=solver,
                                  tol=1e-6, maxiter=1000)
        r = dslash_packed(gauge, jax.device_get(x), 0.2) - b
        rel = float(jnp.linalg.norm(r.ravel()) / jnp.linalg.norm(b.ravel()))
        print(f"[dist] {solver}: iters={int(st.iterations)} "
              f"outer={int(st.outer_iterations)} rel_res={rel:.2e}")
        assert rel < 1e-5
    return 0


if __name__ == "__main__":
    sys.exit(main())
