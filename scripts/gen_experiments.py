"""Regenerate the §Dry-run and §Roofline sections of EXPERIMENTS.md from
experiments/dryrun/*.json (the §Validation and §Perf sections are
maintained by hand around the AUTOGEN markers).

    PYTHONPATH=src:. python scripts/gen_experiments.py
"""

import json
import os
import re
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks.roofline import load_cells, summarize, table  # noqa: E402

EXP = os.path.join(os.path.dirname(__file__), "..", "EXPERIMENTS.md")


def dryrun_section(cells) -> str:
    s = summarize(cells)
    wilson = [c for c in cells if c["arch"].startswith("wilson-")]
    lm = [c for c in cells if not c["arch"].startswith("wilson-")]
    ok_lm = [c for c in lm if c["status"] == "ok"]
    lines = [
        f"Lower+compile against 512 placeholder CPU devices: "
        f"**{len(ok_lm)} LM cells compiled** "
        f"({len([c for c in lm if c['status']=='skipped'])} skipped by "
        f"design — `long_500k` on full-attention archs), plus "
        f"{len(wilson)} Wilson-solver cells.  Meshes: (16,16)="
        f"(data,model) single pod and (2,16,16)=(pod,data,model) "
        f"multi-pod; the multi-pod pass proves the `pod` axis shards "
        f"(gradient/batch DP across pods).",
        "",
        "Worst per-chip footprints (argument+temp bytes from "
        "`memory_analysis()`, 16 GiB HBM budget):",
        "",
        "| cell | per-chip bytes |",
        "|---|---|",
    ]
    worst = sorted((c for c in ok_lm if c["mesh"] == "pod"),
                   key=lambda c: -c["per_device_bytes"])[:8]
    for c in worst:
        gb = c["per_device_bytes"] / 2**30
        flag = " ⚠" if gb > 16 else ""
        lines.append(f"| {c['arch']} {c['shape']} | {gb:.1f} GiB{flag} |")
    return "\n".join(lines)


def main():
    cells = load_cells()
    gen = {
        "DRYRUN": dryrun_section(cells),
        "ROOFLINE_POD": table(cells, "pod"),
        "ROOFLINE_MULTIPOD": table(cells, "multipod"),
    }
    text = open(EXP).read()
    for key, body in gen.items():
        pat = re.compile(rf"(<!-- AUTOGEN:{key} -->).*?(<!-- /AUTOGEN -->)",
                         re.S)
        if not pat.search(text):
            print(f"marker {key} missing in EXPERIMENTS.md", file=sys.stderr)
            continue
        text = pat.sub(lambda m: m.group(1) + "\n" + body + "\n"
                       + m.group(2), text)
    open(EXP, "w").write(text)
    print("EXPERIMENTS.md regenerated "
          f"({len(cells)} cells, {summarize(cells)})")


if __name__ == "__main__":
    main()
