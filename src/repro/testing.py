"""Test-support utilities.

``collect_eqns``/``pallas_call_eqns`` walk a (Closed)Jaxpr recursively —
through ``pjit``/``while``/``scan``/``cond`` sub-jaxprs but NOT into Pallas
kernel bodies — so tests and benchmarks can assert memory-traffic shapes:
"this operator is exactly N kernel launches and zero other full-field
passes" (the γ5-folding and fused-triad acceptance checks).

``maybe_hypothesis`` lets the property-based tests degrade gracefully on
minimal environments (e.g. the CPU CI job before ``pip install -e .[test]``
has run, or a bare container): when :mod:`hypothesis` is importable it is
returned unchanged; otherwise drop-in stand-ins are returned whose
``@given`` replaces the test with a single ``pytest.skip`` — so the rest
of the module still collects and runs.

Usage in a test module::

    given, settings, st = maybe_hypothesis()

    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 100))
    def test_property(n):
        ...
"""

from __future__ import annotations


def collect_eqns(jaxpr, *, into_pallas: bool = False):
    """Yield every equation reachable from ``jaxpr`` (Jaxpr or ClosedJaxpr).

    Recurses through call-like primitives (pjit, while, scan, cond, ...)
    via their jaxpr-valued params; skips the kernel-body jaxpr of
    ``pallas_call`` equations unless ``into_pallas`` — equations inside a
    kernel run from VMEM and must not count as HBM passes.
    """
    from jax.core import ClosedJaxpr, Jaxpr

    if isinstance(jaxpr, ClosedJaxpr):
        jaxpr = jaxpr.jaxpr
    for eqn in jaxpr.eqns:
        yield eqn
        if eqn.primitive.name == "pallas_call" and not into_pallas:
            continue
        for v in eqn.params.values():
            vals = v if isinstance(v, (list, tuple)) else [v]
            for sub in vals:
                if isinstance(sub, (ClosedJaxpr, Jaxpr)):
                    yield from collect_eqns(sub, into_pallas=into_pallas)


def pallas_call_eqns(jaxpr):
    """All ``pallas_call`` equations reachable from ``jaxpr``."""
    return [e for e in collect_eqns(jaxpr)
            if e.primitive.name == "pallas_call"]


# Call-like primitives are containers: their outputs are produced by inner
# equations that collect_eqns already walks, so they are not HBM passes
# themselves.
_CONTAINER_PRIMS = frozenset({
    "pjit", "closed_call", "core_call", "xla_call", "remat", "remat2",
    "custom_jvp_call", "custom_vjp_call", "custom_vjp_call_jaxpr",
    "while", "scan", "cond", "checkpoint", "named_call",
})


def full_field_passes(jaxpr, size: int):
    """Non-pallas compute equations producing an output of ``size`` elements.

    Each such equation materializes a full field outside a kernel — an
    extra HBM round-trip on a real backend.  An operator whose every
    full-field output comes from a ``pallas_call`` returns [] here.
    """
    return [e for e in collect_eqns(jaxpr)
            if e.primitive.name != "pallas_call"
            and e.primitive.name not in _CONTAINER_PRIMS
            and any(getattr(v.aval, "size", 0) == size for v in e.outvars)]


def while_body_psum_counts(jaxpr):
    """Per-``while_loop`` count of ``psum`` collectives in its body.

    Walks every ``while`` equation reachable from ``jaxpr`` (through
    ``shard_map``/``pjit``/... via :func:`collect_eqns`) and counts the
    psum-family equations inside each loop body — the per-iteration
    collective cost of a distributed solver.  The fused-reduction
    contract of DESIGN.md §7 is ``while_body_psum_counts(...) == [1]``
    for the sharded pipelined CGNR: one stacked psum per CG iteration,
    regardless of batch size.
    """
    counts = []
    for eqn in collect_eqns(jaxpr):
        if eqn.primitive.name != "while":
            continue
        body = eqn.params["body_jaxpr"]
        counts.append(sum(1 for e in collect_eqns(body)
                          if e.primitive.name.startswith("psum")))
    return counts


def maybe_hypothesis():
    """Return (given, settings, st) — real hypothesis or skipping stubs."""
    try:
        from hypothesis import given, settings, strategies as st
        return given, settings, st
    except ImportError:
        pass

    import pytest

    class _AnyStrategy:
        """Accepts any strategy construction; never actually draws."""

        def __getattr__(self, name):
            return lambda *args, **kwargs: None

    def _given(*_args, **_kwargs):
        def deco(fn):
            def _skipped():
                pytest.skip("hypothesis not installed")

            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            return _skipped

        return deco

    def _settings(*_args, **_kwargs):
        return lambda fn: fn

    return _given, _settings, _AnyStrategy()
