"""Test-support utilities.

``maybe_hypothesis`` lets the property-based tests degrade gracefully on
minimal environments (e.g. the CPU CI job before ``pip install -e .[test]``
has run, or a bare container): when :mod:`hypothesis` is importable it is
returned unchanged; otherwise drop-in stand-ins are returned whose
``@given`` replaces the test with a single ``pytest.skip`` — so the rest
of the module still collects and runs.

Usage in a test module::

    given, settings, st = maybe_hypothesis()

    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 100))
    def test_property(n):
        ...
"""

from __future__ import annotations


def maybe_hypothesis():
    """Return (given, settings, st) — real hypothesis or skipping stubs."""
    try:
        from hypothesis import given, settings, strategies as st
        return given, settings, st
    except ImportError:
        pass

    import pytest

    class _AnyStrategy:
        """Accepts any strategy construction; never actually draws."""

        def __getattr__(self, name):
            return lambda *args, **kwargs: None

    def _given(*_args, **_kwargs):
        def deco(fn):
            def _skipped():
                pytest.skip("hypothesis not installed")

            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            return _skipped

        return deco

    def _settings(*_args, **_kwargs):
        return lambda fn: fn

    return _given, _settings, _AnyStrategy()
