"""Compatibility shims over the moving parts of the jax API.

The package targets current jax but must also run (and pass tier-1 CI) on
older releases such as 0.4.x, where:

* ``jax.shard_map`` is still ``jax.experimental.shard_map.shard_map`` and
  the replication-check kwarg is ``check_rep`` rather than ``check_vma``;
* ``jax.make_mesh`` has no ``axis_types`` parameter (and
  ``jax.sharding.AxisType`` does not exist).  ``AxisType.Auto`` is the
  default on versions that have it, so omitting the argument is
  behaviour-preserving everywhere.

Only shims for APIs this package actually uses belong here.
"""

from __future__ import annotations

from typing import Callable

import jax


def make_mesh(axis_shapes, axis_names) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with all axes in Auto sharding mode.

    Auto is the default ``axis_types`` on jax versions that support the
    parameter, so this simply omits it for portability.  Releases older
    than ``jax.make_mesh`` itself (< 0.4.35) fall back to building the
    Mesh from ``mesh_utils.create_device_mesh``.
    """
    shapes, names = tuple(axis_shapes), tuple(axis_names)
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(shapes, names)
    from jax.experimental import mesh_utils
    return jax.sharding.Mesh(mesh_utils.create_device_mesh(shapes), names)


def shard_map(f: Callable, *, mesh, in_specs, out_specs,
              check_vma: bool = True) -> Callable:
    """``jax.shard_map`` across jax versions.

    Maps ``check_vma`` onto the old ``check_rep`` name when running on a
    jax that predates the rename/promotion out of ``jax.experimental``.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check_vma)
