"""Serving launcher: batched prefill + greedy decode loop.

``python -m repro.launch.serve --arch glm4-9b --requests 4 --gen 16``

Demonstrates the serving path the ``prefill_32k`` / ``decode_32k`` dry-run
shapes exercise: one batched prefill builds the KV caches, then a decode
loop emits one token per step for the whole batch.
"""

from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.data import SyntheticLM
from repro.models import steps as S


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default="glm4-9b",
                   choices=configs.all_arch_names())
    p.add_argument("--scale", default="smoke", choices=["smoke", "full"])
    p.add_argument("--requests", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--gen", type=int, default=16)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    cfg = (configs.get if args.scale == "full" else configs.get_smoke)(
        args.arch)
    key = jax.random.PRNGKey(args.seed)
    params = S.model_module(cfg).init_params(cfg, key)

    prefix = cfg.num_prefix_embeds or 0
    cache_len = prefix + args.prompt_len + args.gen
    data = SyntheticLM(cfg, batch=args.requests,
                       seq_len=args.prompt_len + prefix, seed=args.seed)
    batch = data.batch_at(0)

    prefill = jax.jit(S.make_prefill_step(cfg, cache_len=cache_len,
                                          compute_dtype=jnp.float32))
    decode = jax.jit(S.make_decode_step(cfg, compute_dtype=jnp.float32))

    t0 = time.time()
    logits, caches = prefill(params, batch)
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    t_prefill = time.time() - t0

    out_tokens = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        pos = jnp.asarray(prefix + args.prompt_len + i, jnp.int32)
        tok, logits, caches = decode(params, caches, tok, pos)
        out_tokens.append(tok)
    toks = jnp.concatenate(out_tokens, axis=1)
    t_decode = time.time() - t0

    print(f"[serve] arch={cfg.name} requests={args.requests} "
          f"prompt={args.prompt_len} gen={args.gen}")
    print(f"[serve] prefill {t_prefill*1e3:.1f} ms, decode "
          f"{t_decode/max(args.gen-1,1)*1e3:.2f} ms/token")
    print(f"[serve] sample continuations: {toks[:, :8].tolist()}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
