import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hill-climbing measurements: lower+compile VARIANTS of the three
chosen cells and record their roofline terms side by side.

Each variant is (cell, config-overrides); results land in
experiments/perf/<tag>.json with the same schema as the dry-run cells, so
the EXPERIMENTS.md §Perf table diffs them directly.

  python -m repro.launch.perf_variants --run h1   # glm4 train_4k ladder
  python -m repro.launch.perf_variants --run h2   # nemotron decode ladder
  python -m repro.launch.perf_variants --all
"""

import argparse
import dataclasses
import json
import subprocess
import sys

# (tag, arch, shape, overrides)
H1 = [  # glm4-9b train_4k: activation-memory ladder
    ("h1a_baseline_no_seqshard", "glm4-9b", "train_4k",
     {"seq_shard": False}),
    ("h1b_seq_shard", "glm4-9b", "train_4k", {}),
    ("h1c_no_remat", "glm4-9b", "train_4k", {"remat": False}),
]
H2 = [  # nemotron-4-340b decode_32k: KV-cache sharding ladder
    ("h2a_baseline_replicated_kv", "nemotron-4-340b", "decode_32k",
     {"kv_seq_shard": False}),
    ("h2b_seq_sharded_kv", "nemotron-4-340b", "decode_32k", {}),
]
H4 = [  # qwen3-moe train_4k: dispatch-buffer sharding (bonus climb)
    ("h4a_baseline_ep_only", "qwen3-moe-235b-a22b", "train_4k",
     {"moe_dispatch_shard": False}),
    ("h4b_cap_sharded", "qwen3-moe-235b-a22b", "train_4k", {}),
]
H5 = [  # yi-9b train_4k: KV-head replication for the TP-divisibility gap
    # baseline = the sweep cell (attention replicated over TP: kv=4, g=8,
    # neither divides 16); optimized = rep=4 virtual kv heads
    ("h5b_kv_replicated_heads", "yi-9b", "train_4k", {}),
]
RUNS = {"h1": H1, "h2": H2, "h4": H4, "h5": H5}


def run_variant(tag: str, arch: str, shape_name: str, overrides: dict,
                mesh_kind: str, out_dir: str):
    import jax
    from repro import configs
    from repro.launch import dryrun as dr
    from repro.launch.mesh import make_production_mesh
    from repro.models.config import SHAPES

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    cfg = dataclasses.replace(configs.get(arch), **overrides)

    # full-depth compile (memory proof)
    lowered, compiled, t_lower, t_compile = dr._lower_compile(
        jax, mesh, arch, shape_name, cfg=cfg)
    mem = compiled.memory_analysis()
    print(mem)

    # cost pass: reduced depth, unrolled, extrapolated
    p = len(cfg.block_pattern) if cfg.family == "hybrid" else 1
    k1, k2 = p, 2 * p
    _, c1, *_ = dr._lower_compile(jax, mesh, arch, shape_name,
                                  cfg=dr._reduced_cfg(cfg, k1), unroll=True)
    _, c2, *_ = dr._lower_compile(jax, mesh, arch, shape_name,
                                  cfg=dr._reduced_cfg(cfg, k2), unroll=True)
    m1, m2 = dr._cost_metrics(c1), dr._cost_metrics(c2)
    ext = dr._extrapolate(m1, m2, k1, k2, cfg.num_layers)

    shape = SHAPES[shape_name]
    if shape.kind == "train":
        model_flops = 6 * cfg.active_param_count() * \
            shape.seq_len * shape.global_batch
    else:
        model_flops = 2 * cfg.active_param_count() * shape.global_batch
    n_chips = mesh.devices.size
    rec = {
        "tag": tag, "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "status": "ok", "overrides": {k: str(v) for k, v in
                                      overrides.items()},
        "chips": int(n_chips), "compile_s": round(t_compile, 2),
        "per_device_bytes": int(mem.argument_size_in_bytes
                                + mem.temp_size_in_bytes),
        "temp_bytes": int(mem.temp_size_in_bytes),
        "cost_extrapolated": {k: ext[k] for k in
                              ("flops", "bytes", "coll_bytes",
                               "coll_count")},
        "roofline": {
            "compute_s": ext["flops"] / dr.PEAK_FLOPS_BF16,
            "memory_s": ext["bytes"] / dr.HBM_BW,
            "collective_s": ext["coll_bytes"] / dr.ICI_BW},
        "model_flops_global": float(model_flops),
        "useful_flops_ratio": float(model_flops / n_chips / ext["flops"])
        if ext["flops"] else None,
    }
    r = rec["roofline"]
    r["dominant"] = max(("compute", "memory", "collective"),
                        key=lambda k: r[f"{k}_s"])
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, f"{tag}.json"), "w") as f:
        json.dump(rec, f, indent=1)
    print(f"[perf] {tag}: temp={mem.temp_size_in_bytes/2**30:.1f}GiB "
          f"compute={r['compute_s']*1e3:.0f}ms "
          f"memory={r['memory_s']*1e3:.0f}ms "
          f"coll={r['collective_s']*1e3:.0f}ms dom={r['dominant']}")
    return rec


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--run", choices=list(RUNS) + ["one"])
    p.add_argument("--tag")
    p.add_argument("--mesh", default="pod")
    p.add_argument("--all", action="store_true")
    p.add_argument("--out-dir", default="experiments/perf")
    args = p.parse_args(argv)

    if args.all or args.run in RUNS:
        runs = sum(RUNS.values(), []) if args.all else RUNS[args.run]
        rc = 0
        for tag, arch, shape, ov in runs:
            if os.path.exists(os.path.join(args.out_dir, f"{tag}.json")):
                print(f"[perf] cached {tag}")
                continue
            r = subprocess.run(
                [sys.executable, "-m", "repro.launch.perf_variants",
                 "--run", "one", "--tag", tag, "--mesh", args.mesh,
                 "--out-dir", args.out_dir], timeout=2400)
            rc |= r.returncode
        return rc
    # --run one --tag <tag>: execute in THIS process
    for tag, arch, shape, ov in sum(RUNS.values(), []):
        if tag == args.tag:
            run_variant(tag, arch, shape, ov, args.mesh, args.out_dir)
            return 0
    print(f"unknown tag {args.tag}")
    return 1


if __name__ == "__main__":
    sys.exit(main())
