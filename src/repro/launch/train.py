"""Training launcher: ``python -m repro.launch.train --arch glm4-9b ...``

Fault tolerance
---------------
* checkpoints every ``--ckpt-every`` steps (atomic, checksummed);
* ``--resume auto`` restores the newest complete checkpoint and the data
  pipeline skips to the restored step (bitwise-identical stream);
* restore is ELASTIC: the checkpoint stores unsharded arrays, so a run
  restarted on a different mesh (e.g. 512 -> 256 chips after losing a
  pod) re-shards on load;
* a straggler watchdog logs steps exceeding ``--max-step-seconds`` (on
  real fleets this triggers pre-emptive re-scheduling; here it reports).
"""

from __future__ import annotations

import argparse
import signal
import sys
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.data import SyntheticLM
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.models import steps as S
from repro.optim import AdamWConfig, warmup_cosine


def parse_args(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", required=True, choices=configs.all_arch_names())
    p.add_argument("--scale", default="smoke", choices=["smoke", "full"])
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq-len", type=int, default=128)
    p.add_argument("--lr", type=float, default=3e-4)
    p.add_argument("--warmup", type=int, default=20)
    p.add_argument("--mesh", default="none",
                   choices=["none", "debug", "pod", "multipod"])
    p.add_argument("--compute-dtype", default="float32")
    p.add_argument("--ckpt-dir", default="")
    p.add_argument("--ckpt-every", type=int, default=50)
    p.add_argument("--resume", default="none", choices=["none", "auto"])
    p.add_argument("--max-step-seconds", type=float, default=120.0)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--log-every", type=int, default=10)
    return p.parse_args(argv)


def build_mesh(kind: str):
    if kind == "none":
        return None
    if kind == "debug":
        return make_debug_mesh()
    return make_production_mesh(multi_pod=(kind == "multipod"))


def main(argv=None):
    args = parse_args(argv)
    cfg = (configs.get if args.scale == "full" else configs.get_smoke)(
        args.arch)
    mesh = build_mesh(args.mesh)
    opt_cfg = AdamWConfig(lr=args.lr, moment_dtype=cfg.opt_state_dtype)
    compute_dtype = jnp.dtype(args.compute_dtype)

    seq = args.seq_len + (cfg.num_prefix_embeds or 0)
    data = SyntheticLM(cfg, batch=args.batch, seq_len=seq, seed=args.seed)

    state = S.init_train_state(cfg, jax.random.PRNGKey(args.seed), opt_cfg)
    schedule = lambda s: warmup_cosine(s, warmup=args.warmup,
                                       total=args.steps)
    step_fn = S.make_train_step(cfg, opt_cfg, mesh=mesh,
                                compute_dtype=compute_dtype,
                                lr_schedule=schedule)
    if mesh is not None:
        specs = S.state_specs(cfg, jax.eval_shape(lambda: state))
        shardings = jax.tree.map(lambda sp: NamedSharding(mesh, sp), specs,
                                 is_leaf=lambda x: isinstance(x, P))
        state = jax.device_put(state, shardings)
        bspec = S.batch_specs(cfg, jax.eval_shape(lambda: data.batch_at(0)),
                              mesh)
        bshard = jax.tree.map(lambda sp: NamedSharding(mesh, sp), bspec,
                              is_leaf=lambda x: isinstance(x, P))
        step_fn = jax.jit(step_fn, in_shardings=(shardings, bshard),
                          out_shardings=(shardings, None))
    else:
        step_fn = jax.jit(step_fn)

    start = 0
    if args.resume == "auto" and args.ckpt_dir:
        last = latest_step(args.ckpt_dir)
        if last is not None:
            print(f"[train] resuming from step {last}")
            state = restore_checkpoint(args.ckpt_dir, last,
                                       jax.eval_shape(lambda: state))
            start = last

    stop = {"now": False}

    def _sigterm(signum, frame):  # preemption: checkpoint then exit
        stop["now"] = True
    signal.signal(signal.SIGTERM, _sigterm)

    t_all = time.time()
    for step in range(start, args.steps):
        t0 = time.time()
        state, metrics = step_fn(state, data.batch_at(step))
        if step % args.log_every == 0 or step == args.steps - 1:
            loss = float(metrics["loss"])
            dt = time.time() - t0
            print(f"[train] step={step} loss={loss:.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} {dt:.2f}s")
        if time.time() - t0 > args.max_step_seconds:
            print(f"[train] WARNING straggler: step {step} took "
                  f"{time.time()-t0:.1f}s > {args.max_step_seconds}s",
                  file=sys.stderr)
        if args.ckpt_dir and (
                (step + 1) % args.ckpt_every == 0 or stop["now"]
                or step == args.steps - 1):
            path = save_checkpoint(args.ckpt_dir, step + 1, state)
            print(f"[train] checkpoint -> {path}")
        if stop["now"]:
            print("[train] SIGTERM received; checkpointed and exiting")
            return 0
    print(f"[train] done: {args.steps - start} steps in "
          f"{time.time()-t_all:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
