import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input-shape ×
mesh) cell against 512 placeholder devices and extract the roofline terms.

The two lines above MUST stay the first statements in this module (before
any jax import) — jax locks the device count at first init.

Usage:
  python -m repro.launch.dryrun --arch glm4-9b --shape train_4k --mesh pod
  python -m repro.launch.dryrun --all            # every cell, subprocesses

Each cell writes experiments/dryrun/<arch>__<shape>__<mesh>.json with
memory_analysis / cost_analysis / per-collective byte counts; EXPERIMENTS.md
§Dry-run and §Roofline are generated from these files.
"""

import argparse
import json
import re
import subprocess
import sys
import time

# TPU v5e constants (per chip)
PEAK_FLOPS_BF16 = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16}
_SHAPE_RE = re.compile(r"\b(f64|f32|bf16|f16|f8e4m3|f8e5m2|s64|u64|s32|u32|"
                       r"s16|u16|s8|u8|pred|c64|c128)\[([0-9,]*)\]")


def _bytes_of(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def collective_bytes(hlo_text: str) -> dict:
    """Per-device OPERAND bytes of every collective in the optimized HLO.

    The HLO dump puts only the RESULT type on the lhs
    (``%ag = f32[4,128]{..} all-gather(%x), replica_groups=[2,4]<=[8]``),
    so operand size is recovered per kind from the result + group size G
    (parsed from ``replica_groups=[n_groups,G]``):
        all-gather:      operand = result / G
        reduce-scatter:  operand = result * G
        others:          operand = result
    ``-start`` async forms counted once; ``-done`` skipped.
    """
    out = {k: {"count": 0, "bytes": 0} for k in _COLL_KINDS}
    for line in hlo_text.splitlines():
        s = line.strip()
        eq = s.find(" = ")
        if eq < 0:
            continue
        lhs, rhs = s[:eq], s[eq + 3:]
        kind = None
        for k in _COLL_KINDS:
            if re.match(rf"[a-z0-9\[\]{{}},()\s]*{k}(-start)?\(", rhs):
                kind = k
                break
        if kind is None or f"{kind}-done" in rhs:
            continue
        res_bytes = sum(_bytes_of(d, dims)
                        for d, dims in _SHAPE_RE.findall(rhs[:rhs.find("(")]))
        if res_bytes == 0:  # result type sits on the lhs in this dump format
            res_bytes = sum(_bytes_of(d, dims)
                            for d, dims in _SHAPE_RE.findall(lhs))
        if res_bytes == 0:  # scalar or tuple w/o dims: look left of the call
            res_bytes = sum(_bytes_of(d, dims)
                            for d, dims in _SHAPE_RE.findall(rhs))
        g = 1
        mg = _GROUPS_RE.search(rhs)
        if mg:
            g = int(mg.group(2))
        if kind == "all-gather":
            op_bytes = res_bytes // max(g, 1)
        elif kind == "reduce-scatter":
            op_bytes = res_bytes * g
        else:
            op_bytes = res_bytes
        out[kind]["count"] += 1
        out[kind]["bytes"] += op_bytes
    out["total_bytes"] = sum(v["bytes"] for v in out.values()
                             if isinstance(v, dict))
    out["total_count"] = sum(v["count"] for v in out.values()
                             if isinstance(v, dict))
    return out


def _lower_compile(jax, mesh, arch, shape_name, cfg=None, unroll=False):
    """(lowered, compiled, seconds) for one cell, optionally with scans
    unrolled (reduced-depth cost passes)."""
    from repro.launch.specs import input_specs
    from repro.models.scan_ctl import unrolled_scans
    import contextlib
    fn, kwargs, in_sh, out_sh = input_specs(arch, shape_name, mesh, cfg=cfg)
    jfn = jax.jit(fn,
                  in_shardings=None if in_sh is None else
                  tuple(in_sh[k] for k in kwargs),
                  out_shardings=out_sh)
    ctx = unrolled_scans() if unroll else contextlib.nullcontext()
    t0 = time.time()
    with ctx:
        lowered = jfn.lower(*kwargs.values())
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    return lowered, compiled, t_lower, time.time() - t0


def _cost_metrics(compiled) -> dict:
    cost = compiled.cost_analysis()
    colls = collective_bytes(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll_bytes": float(colls["total_bytes"]),
            "coll_count": float(colls["total_count"]),
            "collectives": colls}


def _extrapolate(m1: dict, m2: dict, k1: int, k2: int, L: int) -> dict:
    """Linear depth extrapolation.  XLA occasionally optimizes the deeper
    reduced lowering harder (CSE across unrolled layers), which would give
    a NEGATIVE per-layer delta; clamp at 0 and floor the total at the
    larger observation."""
    out = {}
    for key in ("flops", "bytes", "coll_bytes", "coll_count"):
        per = max(0.0, (m2[key] - m1[key]) / (k2 - k1))
        out[key] = max(m1[key] + (L - k1) * per, m1[key], m2[key])
        out[f"{key}_per_layer"] = per
    return out


def _reduced_cfg(cfg, k: int):
    import dataclasses
    kw = {"num_layers": k}
    if cfg.is_encdec:
        kw["encoder_layers"] = k
    return dataclasses.replace(cfg, **kw)


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str):
    import jax
    from repro import configs
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import input_specs, skip_reason
    from repro.models.config import SHAPES

    reason = skip_reason(arch, shape_name)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "status": "ok"}
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(
        out_dir, f"{arch}__{shape_name}__{mesh_kind}.json".replace("/", "_"))
    if reason:
        rec.update(status="skipped", reason=reason)
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"[dryrun] SKIP {arch} {shape_name} {mesh_kind}: {reason}")
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    n_chips = mesh.devices.size
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]

    # 1) full-depth lowering+compile: THE runnability artifact
    #    (sharding coherence, memory_analysis, compile success)
    lowered, compiled, t_lower, t_compile = _lower_compile(
        jax, mesh, arch, shape_name)
    mem = compiled.memory_analysis()
    print(mem)                      # proves it fits (bytes per device)
    full_metrics = _cost_metrics(compiled)

    # 2) cost pass: XLA counts while bodies once, so lower reduced-depth
    #    configs with every scan unrolled and extrapolate linearly in depth
    #    (EXPERIMENTS.md §Conventions)
    p = len(cfg.block_pattern) if cfg.family == "hybrid" else 1
    k1, k2 = p, 2 * p
    _, comp1, *_ = _lower_compile(jax, mesh, arch, shape_name,
                                  cfg=_reduced_cfg(cfg, k1), unroll=True)
    m1 = _cost_metrics(comp1)
    _, comp2, *_ = _lower_compile(jax, mesh, arch, shape_name,
                                  cfg=_reduced_cfg(cfg, k2), unroll=True)
    m2 = _cost_metrics(comp2)
    ext = _extrapolate(m1, m2, k1, k2, cfg.num_layers)

    flops = ext["flops"]
    bytes_accessed = ext["bytes"]
    coll_bytes = ext["coll_bytes"]
    # per-device HLO: terms are per-chip (see EXPERIMENTS.md conventions)
    compute_s = flops / PEAK_FLOPS_BF16
    memory_s = bytes_accessed / HBM_BW
    coll_s = coll_bytes / ICI_BW

    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        model_flops = 6 * cfg.active_param_count() * tokens
    else:
        tokens = shape.global_batch * (shape.seq_len
                                       if shape.kind == "prefill" else 1)
        model_flops = 2 * cfg.active_param_count() * tokens

    mem_fields = {}
    for f in ("temp_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        mem_fields[f] = int(getattr(mem, f, -1))

    rec.update({
        "chips": int(n_chips),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory_analysis": mem_fields,
        "per_device_bytes": mem_fields["argument_size_in_bytes"]
        + mem_fields["temp_size_in_bytes"],
        "cost_method": f"2-point depth extrapolation (k={k1},{k2} unrolled)",
        "cost_reduced": {"k1": k1, "m1": {k: m1[k] for k in
                                          ("flops", "bytes", "coll_bytes")},
                         "k2": k2, "m2": {k: m2[k] for k in
                                          ("flops", "bytes", "coll_bytes")}},
        "cost_extrapolated": {k: ext[k] for k in
                              ("flops", "bytes", "coll_bytes", "coll_count")},
        "collectives_reduced_k2": m2["collectives"],
        "collectives_fullscan": full_metrics["collectives"],
        "roofline": {
            "compute_s": compute_s, "memory_s": memory_s,
            "collective_s": coll_s,
            "dominant": max(
                [("compute", compute_s), ("memory", memory_s),
                 ("collective", coll_s)], key=lambda kv: kv[1])[0],
        },
        "model_flops_global": float(model_flops),
        "hlo_flops_per_device": flops,
        "useful_flops_ratio": float(model_flops / n_chips / flops)
        if flops else None,
    })
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    dom = rec["roofline"]["dominant"]
    print(f"[dryrun] OK {arch} {shape_name} {mesh_kind}: "
          f"compute={compute_s*1e3:.1f}ms memory={memory_s*1e3:.1f}ms "
          f"coll={coll_s*1e3:.1f}ms dom={dom} "
          f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
    return rec


def run_all(out_dir: str, meshes=("pod", "multipod"), archs=None,
            shapes=None, timeout=3000):
    """Run every cell in a fresh subprocess (isolation + memory release)."""
    from repro import configs as _c
    from repro.models.config import SHAPES as _S
    archs = archs or _c.all_arch_names()
    shapes = shapes or list(_S.keys())
    failures = []
    for arch in archs:
        for shape in shapes:
            for mesh in meshes:
                path = os.path.join(
                    out_dir, f"{arch}__{shape}__{mesh}.json")
                if os.path.exists(path):
                    print(f"[dryrun] cached {path}")
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape, "--mesh", mesh,
                       "--out-dir", out_dir]
                r = subprocess.run(cmd, timeout=timeout)
                if r.returncode != 0:
                    failures.append((arch, shape, mesh))
                    print(f"[dryrun] FAIL {arch} {shape} {mesh}")
    print(f"[dryrun] all done; {len(failures)} failures: {failures}")
    return failures


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch")
    p.add_argument("--shape")
    p.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    p.add_argument("--all", action="store_true")
    p.add_argument("--out-dir", default="experiments/dryrun")
    args = p.parse_args(argv)
    if args.all:
        failures = run_all(args.out_dir)
        return 1 if failures else 0
    run_cell(args.arch, args.shape, args.mesh, args.out_dir)
    return 0


if __name__ == "__main__":
    sys.exit(main())
