import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Dry-run of the PAPER'S OWN workload at production scale: the
distributed Dirac-Wilson solver on the (16,16) pod / (2,16,16) multi-pod
mesh, lattice 128^3 x 256 (a large modern QCD ensemble size).

Because the CG loop is a while-op (body counted once by HloCostAnalysis),
the extracted flops/bytes/collective numbers are PER ITERATION — exactly
the right unit for comparing solver variants:

    cg        f32, 2 reductions/iter          (paper-faithful baseline)
    pipecg    f32, 1 fused reduction/iter     (overlap: DESIGN.md T4)
    mpcg      bf16 inner + f32 reliable update (the paper's Ref.[10], T1)

Writes experiments/dryrun/wilson-<solver>__lattice__<mesh>.json in the
same schema as the LM cells.

  python -m repro.launch.dryrun_wilson --solver pipecg --mesh pod
  python -m repro.launch.dryrun_wilson --all
"""

import argparse
import json
import subprocess
import sys
import time


def run_cell(solver: str, mesh_kind: str, out_dir: str,
             dims=(256, 128, 128, 128), low="bfloat16", rr: int = 25):
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding
    from repro.core import distributed as dist
    from repro.core.lattice import GAUGE_G, SPINOR_S, LatticeShape
    from repro.launch.dryrun import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,
                                     collective_bytes)
    from repro.launch.mesh import make_production_mesh
    from repro.core.wilson import DSLASH_FLOPS_PER_SITE

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    n_chips = mesh.devices.size
    lat = LatticeShape(*dims)
    psi_spec, gauge_spec, sharded = dist.lattice_specs(mesh)

    # ShapeDtypeStruct stand-ins for the packed fields (no allocation)
    t, z, y, x = lat.dims
    up = jax.ShapeDtypeStruct((4, t, z, y, GAUGE_G, x), jnp.float32)
    b = jax.ShapeDtypeStruct((t, z, y, SPINOR_S, x), jnp.float32)

    def step(up_, b_):
        return dist.solve_wilson(mesh, up_, b_, 0.1, solver=solver,
                                 tol=1e-8, maxiter=10_000,
                                 residual_replacement_every=rr,
                                 low_dtype=jnp.dtype(low))

    in_sh = (NamedSharding(mesh, gauge_spec), NamedSharding(mesh, psi_spec))
    t0 = time.time()
    lowered = jax.jit(step, in_shardings=in_sh).lower(up, b)
    compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    print(mem)
    cost = compiled.cost_analysis()
    colls = collective_bytes(compiled.as_text())

    flops = float(cost.get("flops", 0.0))          # per device, PER ITER
    bytes_ = float(cost.get("bytes accessed", 0.0))
    terms = {"compute_s": flops / PEAK_FLOPS_BF16,
             "memory_s": bytes_ / HBM_BW,
             "collective_s": colls["total_bytes"] / ICI_BW}
    terms["dominant"] = max(("compute", "memory", "collective"),
                            key=lambda k: terms[f"{k}_s"])
    # useful flops: 2 dslash (D + D^dag) per CGNR iteration
    model_flops = 2 * DSLASH_FLOPS_PER_SITE * lat.volume
    rec = {
        "arch": f"wilson-{solver}", "shape": f"lattice_{lat}",
        "mesh": mesh_kind, "status": "ok", "chips": int(n_chips),
        "compile_s": round(t_compile, 2), "lower_s": 0.0,
        "memory_analysis": {
            "temp_size_in_bytes": int(mem.temp_size_in_bytes),
            "argument_size_in_bytes": int(mem.argument_size_in_bytes),
            "output_size_in_bytes": int(mem.output_size_in_bytes),
            "alias_size_in_bytes": int(mem.alias_size_in_bytes),
            "generated_code_size_in_bytes":
                int(mem.generated_code_size_in_bytes)},
        "per_device_bytes": int(mem.argument_size_in_bytes
                                + mem.temp_size_in_bytes),
        "cost_method": "per-iteration (while body counted once)",
        "cost_extrapolated": {"flops": flops, "bytes": bytes_,
                              "coll_bytes": float(colls["total_bytes"]),
                              "coll_count": float(colls["total_count"])},
        "collectives_fullscan": colls,
        "roofline": terms,
        "model_flops_global": float(model_flops),
        "hlo_flops_per_device": flops,
        "useful_flops_ratio": float(model_flops / n_chips / flops)
        if flops else None,
    }
    os.makedirs(out_dir, exist_ok=True)
    tag = solver if rr else f"{solver}-norr"
    path = os.path.join(out_dir,
                        f"wilson-{tag}__lattice__{mesh_kind}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"[dryrun-wilson] OK {solver} {mesh_kind}: per-iter "
          f"compute={terms['compute_s']*1e3:.2f}ms "
          f"memory={terms['memory_s']*1e3:.2f}ms "
          f"coll={terms['collective_s']*1e3:.2f}ms "
          f"(ar={colls['all-reduce']['count']} "
          f"cp={colls['collective-permute']['count']})")
    return rec


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--solver", default="cg",
                   choices=["cg", "pipecg", "mpcg", "cg16"])
    p.add_argument("--rr", type=int, default=25,
                   help="pipecg residual replacement period (0=off, for "
                        "steady-state iteration cost accounting)")
    p.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    p.add_argument("--all", action="store_true")
    p.add_argument("--out-dir", default="experiments/dryrun")
    args = p.parse_args(argv)
    if args.all:
        rc = 0
        for sv in ("cg", "pipecg", "mpcg"):
            for mk in ("pod", "multipod"):
                r = subprocess.run(
                    [sys.executable, "-m", "repro.launch.dryrun_wilson",
                     "--solver", sv, "--mesh", mk, "--out-dir",
                     args.out_dir], timeout=1200)
                rc |= r.returncode
        return rc
    run_cell(args.solver, args.mesh, args.out_dir, rr=args.rr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
