"""Lattice solver launcher — the paper's workload end-to-end.

``python -m repro.launch.solve --lattice 8x8x8x16 --solver mpcg``

Builds a random SU(3) gauge configuration, solves D x = b via the chosen
CG variant (optionally distributed over a device mesh, optionally through
the Pallas dslash kernel), and reports iterations / residuals / derived
FLOP rates using the paper's 1320 flop/site dslash convention (§5).
"""

from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp

from repro.core import (LatticeShape, cg, dslash_flops, mpcg, pipecg)
from repro.core import distributed as dist
from repro.core.wilson import (dslash_dagger_packed, dslash_packed,
                               normal_op_packed)
from repro.data import lattice_problem
from repro.kernels.wilson_dslash import dslash as dslash_kernel
from repro.launch.mesh import make_debug_mesh


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--lattice", default="4x4x4x8",
                   help="TxZxYxX extents")
    p.add_argument("--mass", type=float, default=0.2)
    p.add_argument("--solver", default="mpcg",
                   choices=["cg", "pipecg", "mpcg", "cg-pallas"])
    p.add_argument("--tol", type=float, default=1e-6)
    p.add_argument("--maxiter", type=int, default=2000)
    p.add_argument("--mesh", default="none", choices=["none", "debug"])
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    t, z, y, x = (int(v) for v in args.lattice.split("x"))
    shape = LatticeShape(t, z, y, x)
    up, b = lattice_problem(shape, mass=args.mass, seed=args.seed)
    m = args.mass

    t0 = time.time()
    if args.mesh != "none":
        mesh = make_debug_mesh((2, 2), ("data", "model")) \
            if len(jax.devices()) >= 4 else None
        if mesh is None:
            print("[solve] <4 devices; run under "
                  "XLA_FLAGS=--xla_force_host_platform_device_count=8")
            return 1
        upd, bd = dist.shard_lattice_fields(mesh, up, b)
        xsol, st = dist.solve_wilson(mesh, upd, bd, m, solver=args.solver,
                                     tol=args.tol, maxiter=args.maxiter)
        xsol = jax.device_get(xsol)
        iters = int(st.iterations)
    elif args.solver == "cg-pallas":
        from repro.kernels.cg_fused import cg_pallas
        op = lambda v: dslash_dagger_packed(
            up, dslash_kernel(up, v, m), m)
        rhs = dslash_dagger_packed(up, b, m)
        xsol, (k, rs) = cg_pallas(op, rhs, tol=args.tol,
                                  maxiter=args.maxiter)
        iters = int(k)
    else:
        op_hi = lambda v: normal_op_packed(up, v, m)
        rhs = dslash_dagger_packed(up, b, m)
        if args.solver == "cg":
            xsol, st = cg(op_hi, rhs, tol=args.tol, maxiter=args.maxiter)
        elif args.solver == "pipecg":
            xsol, st = pipecg(op_hi, rhs, tol=args.tol,
                              maxiter=args.maxiter)
        else:
            up_lo = up.astype(jnp.bfloat16)
            op_lo = lambda v: normal_op_packed(up_lo, v, m)
            xsol, st = mpcg(op_lo, op_hi, rhs, tol=args.tol,
                            inner_maxiter=args.maxiter)
        iters = int(st.iterations)
    dt = time.time() - t0

    res = dslash_packed(up, jnp.asarray(xsol), m) - b
    rel = float(jnp.linalg.norm(res.ravel()) / jnp.linalg.norm(b.ravel()))
    # each CGNR iteration applies D and D^dag (2 dslash) + vector algebra
    flops = 2 * dslash_flops(shape.volume) * max(iters, 1) * 2
    print(f"[solve] lattice={shape} solver={args.solver} iters={iters} "
          f"rel_res={rel:.2e} time={dt:.2f}s "
          f"~{flops/dt/1e9:.2f} GFLOP/s (CPU, interpret-mode kernels)")
    return 0 if rel < 10 * args.tol else 1


if __name__ == "__main__":
    sys.exit(main())
