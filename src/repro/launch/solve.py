"""Lattice solver launcher — the paper's workload end-to-end, plan-driven.

Every invocation builds ONE :class:`repro.core.plan.SolverPlan` and
executes it — the CLI axes map 1:1 onto plan fields:

    python -m repro.launch.solve --lattice 4x4x4x8 --solver mpcg
    python -m repro.launch.solve --solver cgnr_eo --backend pallas
    python -m repro.launch.solve --parity eo --backend pallas --nrhs 8
    python -m repro.launch.solve --parity eo --nrhs 4 --mesh debug \
        --solver pipecg     # sharded batched Schur, 1 psum/iteration

Builds a random SU(3) gauge configuration, solves D x = b (for one RHS or
an ``--nrhs`` batch) via the planned CG variant, and reports iterations —
per right-hand side for batched solves — plus residuals and derived FLOP
rates using the paper's 1320 flop/site dslash convention (§5).
"""

from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp

from repro.core import LatticeShape, dslash_flops, random_spinor
from repro.core import plan as plan_mod
from repro.core.wilson import dslash
from repro.data import lattice_problem
from repro.launch.mesh import make_debug_mesh

# legacy/compound solver names -> (Krylov loop, precision, parity default).
# "--parity"/"--backend" override the inferred parts, so the historical
# spellings keep working while the plan fields stay orthogonal.
_SOLVER_ALIASES = {
    "cg": ("cgnr", "single", "full"),
    "cgnr": ("cgnr", "single", "full"),
    "pipecg": ("pipecg", "single", None),
    "mpcg": ("cgnr", "mixed", "full"),
    "cg16": ("cgnr", "low", "full"),
    "cg-pallas": ("cgnr", "single", "full"),
    "cgnr_eo": ("cgnr", "single", "eo"),
    "pipecg_eo": ("pipecg", "single", "eo"),
    "cgnr_eo_mp": ("cgnr", "mixed", "eo"),
}


def build_plan(args) -> plan_mod.SolverPlan:
    """Resolve the CLI axes to a SolverPlan (pure; unit-tested)."""
    loop, precision, parity = _SOLVER_ALIASES[args.solver]
    if args.parity is not None:
        parity = args.parity
    elif parity is None:
        parity = "full"
    backend = args.backend
    if args.solver == "cg-pallas":
        backend = "pallas"
    mesh = None
    if args.mesh == "debug":
        mesh = make_debug_mesh((2, 2), ("data", "model")) \
            if len(jax.devices()) >= 4 else None
        if mesh is None:
            raise SystemExit(
                "[solve] <4 devices; run under "
                "XLA_FLAGS=--xla_force_host_platform_device_count=8")
    return plan_mod.SolverPlan(
        operator="eo-schur" if parity == "eo" else "full",
        backend=backend, solver=loop, precision=precision,
        nrhs=args.nrhs, mesh=mesh)


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--lattice", default="4x4x4x8",
                   help="TxZxYxX extents")
    p.add_argument("--mass", type=float, default=0.2)
    p.add_argument("--solver", default="mpcg",
                   choices=sorted(_SOLVER_ALIASES))
    p.add_argument("--parity", choices=["full", "eo"], default=None,
                   help="operator family (default: inferred from --solver)")
    p.add_argument("--backend", choices=["reference", "pallas"],
                   default="reference")
    p.add_argument("--nrhs", type=int, default=None,
                   help="solve N right-hand sides in one masked batched CG "
                        "loop (gauge reads amortized across the batch)")
    p.add_argument("--tol", type=float, default=1e-6)
    p.add_argument("--maxiter", type=int, default=2000)
    p.add_argument("--mesh", default="none", choices=["none", "debug"])
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    t, z, y, x = (int(v) for v in args.lattice.split("x"))
    shape = LatticeShape(t, z, y, x)
    u, b = lattice_problem(shape, mass=args.mass, seed=args.seed,
                           packed=False)
    if args.nrhs is not None:
        key = jax.random.fold_in(jax.random.PRNGKey(args.seed), 1)
        b = jnp.stack([random_spinor(jax.random.fold_in(key, i), shape)
                       for i in range(args.nrhs)])
    m = args.mass

    try:
        plan = build_plan(args)
    except (ValueError, NotImplementedError) as e:
        print(f"[solve] invalid plan: {e}")
        return 1
    print(f"[solve] plan: operator={plan.operator} backend={plan.backend} "
          f"solver={plan.solver} precision={plan.precision} "
          f"nrhs={plan.nrhs} mesh="
          f"{dict(plan.mesh.shape) if plan.mesh is not None else None}")

    t0 = time.time()
    try:
        xsol, st = plan_mod.solve(plan, u, b, m, tol=args.tol,
                                  maxiter=args.maxiter)
    except (ValueError, NotImplementedError) as e:
        # dispatch-time rejections (e.g. full + mesh + nrhs) — same
        # friendly failure as a plan that fails to construct
        print(f"[solve] invalid plan: {e}")
        return 1
    jax.block_until_ready(xsol)
    dt = time.time() - t0
    iters = int(st.iterations)

    if plan.nrhs is not None:
        res = jax.vmap(lambda xx, bb: dslash(u, xx, m) - bb)(xsol, b)
        rels = (jnp.linalg.norm(res.reshape(plan.nrhs, -1), axis=1)
                / jnp.linalg.norm(b.reshape(plan.nrhs, -1), axis=1))
        rel = float(jnp.max(rels))
        per_rhs = [int(v) for v in st.rhs_iterations]
        print("[solve] per-RHS iterations: " + " ".join(
            f"rhs{i}={n}" for i, n in enumerate(per_rhs)))
        print("[solve] per-RHS rel_res:   " + " ".join(
            f"rhs{i}={float(r):.2e}" for i, r in enumerate(rels)))
        n_systems = plan.nrhs
    else:
        res = dslash(u, xsol, m) - b
        rel = float(jnp.linalg.norm(res.ravel())
                    / jnp.linalg.norm(b.ravel()))
        n_systems = 1

    # each CGNR iteration applies D and D^dag (2 dslash) + vector algebra;
    # the even-odd Schur matvec does the same work on half-size fields.
    volume = shape.volume // 2 if plan.operator == "eo-schur" else shape.volume
    flops = 2 * dslash_flops(volume) * max(iters, 1) * 2 * n_systems
    print(f"[solve] lattice={shape} solver={args.solver} iters={iters} "
          f"max_rel_res={rel:.2e} time={dt:.2f}s "
          f"~{flops/dt/1e9:.2f} GFLOP/s (CPU, interpret-mode kernels)")
    return 0 if rel < 10 * args.tol else 1


if __name__ == "__main__":
    sys.exit(main())
