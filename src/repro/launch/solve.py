"""Lattice solver launcher — the paper's workload end-to-end, plan-driven.

Every invocation builds ONE :class:`repro.core.plan.SolverPlan` and
executes it — the CLI axes map 1:1 onto plan fields, including the
operator registry (:mod:`repro.core.operators`):

    python -m repro.launch.solve --lattice 4x4x4x8 --solver mpcg
    python -m repro.launch.solve --parity eo --backend pallas
    python -m repro.launch.solve --parity eo --backend pallas --nrhs 8
    python -m repro.launch.solve --parity eo --operator twisted-mass \
        --mu 0.25                # second operator, same transport stack
    python -m repro.launch.solve --parity eo --nrhs 4 --mesh debug \
        --solver pipecg          # sharded batched Schur, 1 psum/iteration

Builds a random SU(3) gauge configuration, solves D x = b (for one RHS or
an ``--nrhs`` batch) via the planned CG variant, and reports iterations —
per right-hand side for batched solves — plus residuals and derived FLOP
rates using the paper's 1320 flop/site dslash convention (§5).

The compound legacy solver names (``cg-pallas``, ``cgnr_eo``, ...) are
gone: their axes are orthogonal plan fields now (``--parity``,
``--backend``, ``--nrhs``), so ``--solver`` names ONLY the Krylov loop /
precision policy.
"""

from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp

from repro.core import LatticeShape, dslash_flops, random_spinor
from repro.core import plan as plan_mod
from repro.core import solvers
from repro.core.operators import dslash_g, get_operator, operator_names
from repro.data import lattice_problem
from repro.launch.mesh import make_debug_mesh

# solver name -> (Krylov loop, precision policy); parity/backend/operator
# are independent CLI axes
_SOLVERS = {
    "cgnr": ("cgnr", "single"),
    "pipecg": ("pipecg", "single"),
    "blockcg": ("blockcg", "single"),
    "mpcg": ("cgnr", "mixed"),
    "cg16": ("cgnr", "low"),
}


def build_plan(args) -> plan_mod.SolverPlan:
    """Resolve the CLI axes to a SolverPlan (pure; unit-tested)."""
    loop, precision = _SOLVERS[args.solver]
    mesh = None
    if args.mesh == "debug":
        mesh = make_debug_mesh((2, 2), ("data", "model")) \
            if len(jax.devices()) >= 4 else None
        if mesh is None:
            raise SystemExit(
                "[solve] <4 devices; run under "
                "XLA_FLAGS=--xla_force_host_platform_device_count=8")
    return plan_mod.SolverPlan(
        operator="eo-schur" if args.parity == "eo" else "full",
        operator_family=args.operator, mu=args.mu,
        backend=args.backend, solver=loop, precision=precision,
        nrhs=args.nrhs, mesh=mesh)


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--lattice", default="4x4x4x8",
                   help="TxZxYxX extents")
    p.add_argument("--mass", type=float, default=0.2)
    p.add_argument("--solver", default="mpcg", choices=sorted(_SOLVERS),
                   help="Krylov loop / precision policy (blockcg shares "
                        "one search space across an --nrhs batch)")
    p.add_argument("--parity", choices=["full", "eo"], default="full",
                   help="operator shape: full lattice or even-odd Schur")
    p.add_argument("--operator", default="wilson",
                   choices=sorted(operator_names()),
                   help="operator family from the registry: "
                        + "; ".join(f"{n}: {get_operator(n).description}"
                                    for n in operator_names()))
    p.add_argument("--mu", type=float, default=0.0,
                   help="twisted-mass site parameter (i*mu*gamma5 term; "
                        "families that declare 'mu' only)")
    p.add_argument("--backend", choices=["reference", "pallas"],
                   default="reference")
    p.add_argument("--nrhs", type=int, default=None,
                   help="solve N right-hand sides in one masked batched CG "
                        "loop (gauge reads amortized across the batch)")
    p.add_argument("--tol", type=float, default=1e-6)
    p.add_argument("--maxiter", type=int, default=2000)
    p.add_argument("--mesh", default="none", choices=["none", "debug"])
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--checkpoint-dir", default=None,
                   help="segment the solve and snapshot (x, iteration, "
                        "verdict, rhs_mask) here every --checkpoint-every "
                        "iterations (DESIGN.md §11)")
    p.add_argument("--checkpoint-every", type=int, default=50,
                   help="segment length in iterations between snapshots")
    p.add_argument("--resume", action="store_true",
                   help="restore the latest valid checkpoint from "
                        "--checkpoint-dir and defect-correct from the "
                        "saved iterate (fresh checkpointed solve when the "
                        "directory has no checkpoint yet)")
    p.add_argument("--deflate", type=int, default=0, metavar="NEV",
                   help="harvest an NEV-vector EigCG deflation basis from "
                        "a warmup solve on a separate RHS (same gauge/"
                        "mass), then warm-start this solve with it — "
                        "demonstrates the DESIGN.md §12 iteration cut "
                        "(eo parity, cgnr/blockcg, single precision only)")
    p.add_argument("--deflate-harvest-tol", type=float, default=1e-8,
                   help="recursive-residual tolerance the harvest solve "
                        "iterates to (deeper than --tol mines more "
                        "spectrum)")
    args = p.parse_args(argv)
    if args.resume and args.checkpoint_dir is None:
        p.error("--resume requires --checkpoint-dir")
    if args.deflate > 0 and (args.resume or args.checkpoint_dir):
        p.error("--deflate does not compose with checkpointed solves")

    t, z, y, x = (int(v) for v in args.lattice.split("x"))
    shape = LatticeShape(t, z, y, x)
    u, b = lattice_problem(shape, mass=args.mass, seed=args.seed,
                           packed=False)
    if args.nrhs is not None:
        key = jax.random.fold_in(jax.random.PRNGKey(args.seed), 1)
        b = jnp.stack([random_spinor(jax.random.fold_in(key, i), shape)
                       for i in range(args.nrhs)])
    m = args.mass

    try:
        plan = build_plan(args)
    except (ValueError, NotImplementedError) as e:
        print(f"[solve] invalid plan: {e}")
        return 1
    print(f"[solve] plan: operator={plan.operator} "
          f"family={plan.operator_family} mu={plan.mu} "
          f"backend={plan.backend} solver={plan.solver} "
          f"precision={plan.precision} nrhs={plan.nrhs} mesh="
          f"{dict(plan.mesh.shape) if plan.mesh is not None else None}")

    deflation = None
    if args.deflate > 0:
        import dataclasses
        try:
            hplan = dataclasses.replace(plan, solver="cgnr", nrhs=None)
            hkey = jax.random.fold_in(jax.random.PRNGKey(args.seed), 2)
            b_h = random_spinor(hkey, shape)
            th = time.time()
            _, hst, deflation = plan_mod.harvest_deflation(
                hplan, u, b_h, m, tol=args.deflate_harvest_tol,
                maxiter=args.maxiter, nev=args.deflate,
                m_max=max(4 * args.deflate, 48), verify_tol=args.tol)
        except (ValueError, NotImplementedError) as e:
            print(f"[solve] invalid plan: {e}")
            return 1
        print(f"[solve] deflation harvest: nev={deflation.nev} "
              f"iters={int(hst.iterations)} matvecs={int(hst.matvecs)} "
              f"verified={bool(jnp.atleast_1d(hst.verified)[0])} "
              f"time={time.time() - th:.2f}s", flush=True)

    t0 = time.time()
    try:
        if args.resume:
            from repro.core import resilience
            xsol, st, record = resilience.resume_solve(
                plan, u, b, m, checkpoint_dir=args.checkpoint_dir,
                tol=args.tol, maxiter=args.maxiter, missing_ok=True)
            if record.resumed_from_step is None:
                print("[solve] no checkpoint found; fresh checkpointed "
                      "solve", flush=True)
            else:
                print(f"[solve] resumed from step "
                      f"{record.resumed_from_step} "
                      f"({record.checkpoint_iterations} iterations banked, "
                      f"checkpoint verdict "
                      f"{record.checkpoint_verdict})", flush=True)
        elif args.checkpoint_dir is not None:
            policy = plan_mod.CheckpointPolicy(
                dir=args.checkpoint_dir, every_iters=args.checkpoint_every)
            print(f"[solve] checkpointing to {policy.dir} every "
                  f"{policy.every_iters} iterations", flush=True)
            xsol, st = plan_mod.solve(plan, u, b, m, tol=args.tol,
                                      maxiter=args.maxiter,
                                      checkpoint=policy)
        else:
            xsol, st = plan_mod.solve(plan, u, b, m, tol=args.tol,
                                      maxiter=args.maxiter,
                                      deflation=deflation)
    except (ValueError, NotImplementedError) as e:
        # dispatch-time rejections (e.g. full + mesh + nrhs) — same
        # friendly failure as a plan that fails to construct
        print(f"[solve] invalid plan: {e}")
        return 1
    jax.block_until_ready(xsol)
    dt = time.time() - t0
    iters = int(st.iterations)

    # true residual against the FAMILY's full operator (registry oracle)
    twist = plan.twist
    op = lambda v: dslash_g(u, v, m, twist=twist)
    verdicts = jnp.atleast_1d(st.verdict) if st.verdict is not None else None
    verified = jnp.atleast_1d(st.verified) if st.verified is not None else None
    if plan.nrhs is not None:
        res = jax.vmap(lambda xx, bb: op(xx) - bb)(xsol, b)
        rels = (jnp.linalg.norm(res.reshape(plan.nrhs, -1), axis=1)
                / jnp.linalg.norm(b.reshape(plan.nrhs, -1), axis=1))
        rel = float(jnp.max(rels))
        per_rhs = [int(v) for v in st.rhs_iterations]
        print("[solve] per-RHS iterations: " + " ".join(
            f"rhs{i}={n}" for i, n in enumerate(per_rhs)))
        print("[solve] per-RHS matvecs:    " + " ".join(
            f"rhs{i}={int(v)}" for i, v in enumerate(
                jnp.atleast_1d(st.matvecs))))
        print("[solve] per-RHS rel_res:   " + " ".join(
            f"rhs{i}={float(r):.2e}" for i, r in enumerate(rels)))
        if verdicts is not None:
            print("[solve] per-RHS verdict:   " + " ".join(
                f"rhs{i}={solvers.verdict_name(v)}"
                + ("" if bool(verified[i]) else "(UNVERIFIED)")
                for i, v in enumerate(verdicts)))
        n_systems = plan.nrhs
    else:
        res = op(xsol) - b
        rel = float(jnp.linalg.norm(res.ravel())
                    / jnp.linalg.norm(b.ravel()))
        if verdicts is not None:
            print(f"[solve] verdict: {solvers.verdict_name(verdicts[0])} "
                  f"verified={bool(verified[0])}")
        n_systems = 1

    # a solve SUCCEEDS only when every RHS both converged by the taxonomy
    # and passed the true-residual verification matvec (DESIGN.md §10)
    ok = rel < 10 * args.tol
    if verdicts is not None:
        ok = ok and all(
            int(v) == solvers.CONVERGED and bool(verified[i])
            for i, v in enumerate(verdicts))
        if not ok:
            bad = [(i, solvers.verdict_name(v)) for i, v in enumerate(verdicts)
                   if int(v) != solvers.CONVERGED or not bool(verified[i])]
            print("[solve] FAIL: " + " ".join(
                f"rhs{i}:{name}" for i, name in bad))

    # each CGNR iteration applies D and D^dag (2 dslash) + vector algebra;
    # the even-odd Schur matvec does the same work on half-size fields.
    volume = shape.volume // 2 if plan.operator == "eo-schur" else shape.volume
    flops = 2 * dslash_flops(volume) * max(iters, 1) * 2 * n_systems
    mv = jnp.atleast_1d(st.matvecs)
    print(f"[solve] lattice={shape} solver={args.solver} iters={iters} "
          f"matvecs={int(jnp.max(mv))} "
          f"(total {int(jnp.sum(mv))} across {n_systems} RHS) "
          f"max_rel_res={rel:.2e} time={dt:.2f}s "
          f"~{flops/dt/1e9:.2f} GFLOP/s (CPU, interpret-mode kernels)")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
