"""Solver-serving launcher: a continuous-batching solve service under load.

    python -m repro.launch.serve_solver --requests 200 --burst 4
    python -m repro.launch.serve_solver --lattice 4x4x4x8 --gauges 2 \
        --ladder 1,4,8,16 --max-wait-ms 250 --verify
    python -m repro.launch.serve_solver --families wilson \
        --backend pallas --out BENCH_serve.json

Stands up :class:`repro.serve.SolverServer` (queue → coalesce → pad to the
batch-shape ladder → masked batched EO-Schur CGNR → per-request return),
registers ``--gauges`` random hot gauge fields, warms the compiled-plan
cache, then drives the synthetic OPEN-LOOP load generator: bursts of
``--burst`` requests every ``--interarrival-ms``, cycling gauge fields,
operator families and a pool of right-hand sides.  Reports requests/s,
p50/p99 latency, the batch-size histogram and plan-cache hit rates;
``--verify`` re-solves every response through a direct unbatched
``plan.solve`` and fails loudly on deviation > 1e-5 — the same gate the
CI ``serve-smoke`` lane runs (see benchmarks/bench_serve.py).
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.serve.loadgen import WorkloadConfig, run_workload

# family name -> its mu parameter when selected (0 for families without one)
_FAMILY_MU = {"wilson": 0.0, "twisted-mass": 0.25}


def build_config(args) -> WorkloadConfig:
    """Resolve the CLI axes to a WorkloadConfig (pure; unit-tested)."""
    lattice = tuple(int(v) for v in args.lattice.split("x"))
    if len(lattice) != 4:
        raise ValueError(f"--lattice must be TxZxYxX, got {args.lattice!r}")
    families = []
    for name in args.families.split(","):
        name = name.strip()
        if name not in _FAMILY_MU:
            raise ValueError(f"unknown family {name!r}; known: "
                             f"{sorted(_FAMILY_MU)}")
        families.append((name, args.mu if name == "twisted-mass"
                         else _FAMILY_MU[name]))
    ladder = tuple(int(v) for v in args.ladder.split(","))
    return WorkloadConfig(
        lattice=lattice, n_gauge=args.gauges, families=tuple(families),
        mass=args.mass, tol=args.tol, requests=args.requests,
        burst=args.burst, interarrival_s=args.interarrival_ms / 1e3,
        rhs_pool=args.rhs_pool, seed=args.seed, ladder=ladder,
        max_wait_s=args.max_wait_ms / 1e3, max_batch=args.max_batch,
        backend=args.backend, maxiter=args.maxiter,
        warmup=not args.no_warmup, verify=args.verify,
        deadline_s=args.deadline_ms / 1e3 if args.deadline_ms else None,
        chaos=args.chaos, chaos_poison_fraction=args.chaos_poison_fraction,
        chaos_fault_every=args.chaos_fault_every,
        chaos_fault_mode=args.chaos_fault_mode,
        journal_dir=args.journal_dir,
        deflation_nev=args.deflation_nev,
        deflation_m_max=args.deflation_m_max,
        deflation_harvest_tol=args.deflation_harvest_tol)


def make_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--lattice", default="4x4x4x4", help="TxZxYxX extents")
    p.add_argument("--gauges", type=int, default=2,
                   help="number of hot gauge fields")
    p.add_argument("--families", default="wilson,twisted-mass",
                   help="comma list of operator families to mix")
    p.add_argument("--mu", type=float, default=0.25,
                   help="twisted-mass site parameter")
    p.add_argument("--mass", type=float, default=0.1)
    p.add_argument("--tol", type=float, default=1e-6)
    p.add_argument("--requests", type=int, default=200)
    p.add_argument("--burst", type=int, default=4,
                   help="requests fired per arrival instant")
    p.add_argument("--interarrival-ms", type=float, default=50.0,
                   help="open-loop spacing between bursts")
    p.add_argument("--rhs-pool", type=int, default=8,
                   help="distinct right-hand sides cycled across requests")
    p.add_argument("--ladder", default="1,4,8",
                   help="comma list of pre-compiled batch shapes")
    p.add_argument("--max-wait-ms", type=float, default=250.0,
                   help="batching deadline from the first queued request")
    p.add_argument("--max-batch", type=int, default=None,
                   help="dispatch cap (default: top ladder rung)")
    p.add_argument("--backend", choices=["reference", "pallas"],
                   default="reference")
    p.add_argument("--maxiter", type=int, default=500)
    p.add_argument("--seed", type=int, default=7)
    p.add_argument("--no-warmup", action="store_true",
                   help="skip precompiling the ladder (first batches pay "
                        "trace/compile)")
    p.add_argument("--verify", action="store_true",
                   help="re-solve every response directly and compare")
    p.add_argument("--deadline-ms", type=float, default=None,
                   help="per-request deadline; expired requests fail with "
                        "SolveTimeout without consuming a batch slot")
    p.add_argument("--chaos", action="store_true",
                   help="poison a fraction of the RHS stream and (with "
                        "--chaos-fault-every) inject transient faults; "
                        "report goodput + containment counters")
    p.add_argument("--chaos-poison-fraction", type=float, default=0.1,
                   help="fraction of requests given a corrupted RHS "
                        "(alternating NaN and overflow poisons)")
    p.add_argument("--chaos-fault-every", type=int, default=0,
                   help="fire a transient fault on every N-th primary "
                        "batch dispatch (0 = off)")
    p.add_argument("--chaos-fault-mode", default="gauge_nan_plane",
                   choices=["gauge_nan_plane", "gauge_bitflip", "stall",
                            "raise"],
                   help="transient fault model for --chaos-fault-every")
    p.add_argument("--journal-dir", default=None,
                   help="write-ahead journal directory (DESIGN.md §11): "
                        "admitted requests become durable; after a crash, "
                        "SolverServer.recover() replays the incomplete "
                        "entries")
    p.add_argument("--deflation-nev", type=int, default=0,
                   help="EigCG deflation basis size per (gauge, operator) "
                        "coalesce key (0 = off); the first verified solve "
                        "on each key harvests the basis, later requests "
                        "start deflated and converge in fewer iterations")
    p.add_argument("--deflation-m-max", type=int, default=160,
                   help="Lanczos-vector recording depth of the harvest "
                        "solve")
    p.add_argument("--deflation-harvest-tol", type=float, default=None,
                   help="harvest-solve tolerance (default: the triggering "
                        "request's tol; tighter = deeper Krylov space = "
                        "better basis on ill-conditioned operators)")
    p.add_argument("--out", default=None,
                   help="write the BENCH_serve.json report here")
    return p


def main(argv=None):
    args = make_parser().parse_args(argv)
    try:
        cfg = build_config(args)
    except ValueError as e:
        print(f"[serve_solver] invalid config: {e}")
        return 1
    print(f"[serve_solver] lattice={args.lattice} gauges={cfg.n_gauge} "
          f"families={[f for f, _ in cfg.families]} "
          f"requests={cfg.requests} burst={cfg.burst} "
          f"ladder={list(cfg.ladder)} backend={cfg.backend}")
    report = run_workload(cfg)
    lat = report["latency_ms"]
    print(f"[serve_solver] {report['requests']} requests in "
          f"{report['wall_s']:.2f}s = {report['requests_per_s']:.1f} req/s")
    print(f"[serve_solver] latency p50={lat['p50']:.1f}ms "
          f"p99={lat['p99']:.1f}ms mean={lat['mean']:.1f}ms")
    print(f"[serve_solver] batches={report['batches']} "
          f"batch_hist={report['batch_hist']} "
          f"padded_slots={report['padded_slots']}")
    print(f"[serve_solver] plan cache: {report['plan_cache']} "
          f"request_hit_rate={report['request_cache_hit_rate']:.3f}")
    ok = bool(report["all_converged"])
    if not ok:
        print("[serve_solver] FAIL: not every served request converged "
              "and verified")
    if "chaos" in report:
        c = report["chaos"]
        print(f"[serve_solver] chaos: poisoned={c['poisoned']} "
              f"(failed={c['poisoned_failed']} "
              f"served={c['poisoned_served']}) healthy={c['healthy']} "
              f"(ok={c['healthy_ok']} failed={c['healthy_failed']} "
              f"unverified={c['healthy_unverified']} "
              f"rescued={c['healthy_rescued_by_retry']})")
        print(f"[serve_solver] chaos: goodput={c['goodput_rps']:.1f} req/s "
              f"failure_verdicts={c['failure_verdicts']} "
              f"containment={'OK' if c['containment_ok'] else 'FAIL'}")
        ok = ok and c["containment_ok"]
    if "deflation_drop" in report:
        d = report["deflation_drop"]
        cache = report["deflation"]
        print(f"[serve_solver] deflation: {cache['harvests']} harvests, "
              f"{d['hit_requests']} cache-hit requests "
              f"(hit_rate={cache['hit_rate']:.3f}), iteration drop "
              f"{'OK' if d['all_hits_dropped'] else 'FAIL'}")
        ok = ok and d["all_hits_dropped"] and d["hit_requests"] > 0
    if "verify" in report:
        v = report["verify"]
        print(f"[serve_solver] verify: {v['checked']} responses vs "
              f"{v['direct_solves']} direct solves, "
              f"max_abs_err={v['max_abs_err']:.2e} "
              f"({'OK' if v['passed'] else 'FAIL'})")
        ok = ok and v["passed"]
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"[serve_solver] wrote {args.out}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
