"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before any device query).

Topology: TPU v5e-style pods of 256 chips arranged (16, 16) =
(data, model); the multi-pod mesh stacks 2 pods on a leading "pod" axis
(data-parallel across DCN).  Smaller debug meshes for CPU tests come from
``make_debug_mesh``.
"""

from __future__ import annotations

from jax.sharding import Mesh

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_debug_mesh(shape=(2, 2), axes=("data", "model")) -> Mesh:
    return make_mesh(shape, axes)
