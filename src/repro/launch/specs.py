"""ShapeDtypeStruct stand-ins + sharding specs for every dry-run cell.

``input_specs(arch, shape_name, mesh)`` returns (fn, kwargs, in_shardings,
out_shardings) such that

    jax.jit(fn, in_shardings=..., out_shardings=...).lower(**kwargs)

lowers the exact (architecture × input-shape × mesh) cell with NO device
allocation (weak-type-correct ShapeDtypeStructs all the way down).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import configs
from repro.models import encdec as ED
from repro.models import steps as S
from repro.models import transformer as TF
from repro.models.config import SHAPES, ModelConfig, ShapeConfig
from repro.optim import AdamWConfig

BF16 = jnp.bfloat16


def _named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda sp: NamedSharding(mesh, sp), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _batch_struct(cfg: ModelConfig, shape: ShapeConfig, *, seq: int,
                  batch: int, dtype) -> dict:
    sds = jax.ShapeDtypeStruct
    out: dict[str, Any] = {}
    if cfg.is_encdec:
        enc_len = min(seq, cfg.encoder_seq_len or seq)
        out["tokens"] = sds((batch, seq), jnp.int32)
        out["frames"] = sds((batch, enc_len, cfg.d_model), dtype)
    elif cfg.num_prefix_embeds:
        out["tokens"] = sds((batch, seq - cfg.num_prefix_embeds), jnp.int32)
        out["prefix_embeds"] = sds((batch, cfg.num_prefix_embeds,
                                    cfg.d_model), dtype)
    else:
        out["tokens"] = sds((batch, seq), jnp.int32)
    return out


def skip_reason(arch: str, shape_name: str) -> str | None:
    """Cells skipped by design (recorded in DESIGN.md / EXPERIMENTS.md)."""
    cfg = configs.get(arch)
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        return ("full-attention arch: 524k dense attention is O(S^2); "
                "long-context decode runs only for ssm/hybrid families")
    return None


def input_specs(arch: str, shape_name: str, mesh: Mesh,
                cfg: ModelConfig | None = None):
    """Build (fn, kwargs, in_shardings, out_shardings) for one cell.
    ``cfg`` overrides the registry config (reduced-depth cost passes)."""
    cfg = cfg or configs.get(arch)
    shape = SHAPES[shape_name]
    mod = ED if cfg.is_encdec else TF

    if shape.kind == "train":
        opt_cfg = AdamWConfig(moment_dtype=cfg.opt_state_dtype)
        state_shape = jax.eval_shape(
            lambda: S.init_train_state(cfg, jax.random.PRNGKey(0), opt_cfg))
        batch_shape = _batch_struct(cfg, shape, seq=shape.seq_len,
                                    batch=shape.global_batch, dtype=BF16)
        fn = S.make_train_step(cfg, opt_cfg, mesh=mesh, compute_dtype=BF16)
        st_spec = S.state_specs(cfg, state_shape)
        b_spec = S.batch_specs(cfg, batch_shape, mesh)
        in_sh = {"state": _named(mesh, st_spec), "batch": _named(mesh, b_spec)}
        out_sh = (_named(mesh, st_spec), None)

        def train_step(state, batch):
            return fn(state, batch)

        return train_step, {"state": state_shape, "batch": batch_shape}, \
            in_sh, out_sh

    # serving: params are the bf16 inference copy
    params_shape = jax.eval_shape(
        lambda: mod.init_params(cfg, jax.random.PRNGKey(0), BF16))
    p_spec = S.state_specs(cfg, params_shape)

    if shape.kind == "prefill":
        batch_shape = _batch_struct(cfg, shape, seq=shape.seq_len,
                                    batch=shape.global_batch, dtype=BF16)
        fn = S.make_prefill_step(cfg, cache_len=shape.seq_len, mesh=mesh,
                                 compute_dtype=BF16)

        def prefill_step(params, batch):
            return fn(params, batch)

        in_sh = {"params": _named(mesh, p_spec),
                 "batch": _named(mesh, S.batch_specs(cfg, batch_shape, mesh))}
        return prefill_step, {"params": params_shape,
                              "batch": batch_shape}, in_sh, None

    if shape.kind == "decode":
        batch = shape.global_batch
        if cfg.is_encdec:
            enc_len = min(cfg.encoder_seq_len or shape.seq_len,
                          shape.seq_len)
            caches_shape = jax.eval_shape(
                lambda: ED.init_caches(cfg, batch, shape.seq_len, enc_len,
                                       BF16))
        else:
            caches_shape = jax.eval_shape(
                lambda: TF.init_caches(cfg, batch, shape.seq_len, BF16))
        tok_shape = jax.ShapeDtypeStruct((batch, 1), jnp.int32)
        pos_shape = jax.ShapeDtypeStruct((), jnp.int32)
        fn = S.make_decode_step(cfg, mesh=mesh, compute_dtype=BF16)

        def decode_step(params, caches, tokens, pos):
            return fn(params, caches, tokens, pos)

        c_spec = S.cache_specs(cfg, caches_shape, mesh)
        dp = P(S.dp_axes_for(mesh, batch), None)
        in_sh = {"params": _named(mesh, p_spec),
                 "caches": _named(mesh, c_spec),
                 "tokens": NamedSharding(mesh, dp),
                 "pos": NamedSharding(mesh, P())}
        return decode_step, {"params": params_shape, "caches": caches_shape,
                             "tokens": tok_shape, "pos": pos_shape}, \
            in_sh, None

    raise ValueError(shape.kind)
