"""Deterministic fault injection for the defended serving stack.

Chaos harness for DESIGN.md §10: every injector is DETERMINISTIC (fires
on a fixed call schedule, corrupts fixed coordinates) so the containment
tests and the ``loadgen --chaos`` CI lane are exactly reproducible.

Two fault surfaces:

* **Poisoned inputs** — :func:`poison_nan` / :func:`poison_overflow`
  corrupt a right-hand side the way a broken producer would.  A NaN RHS
  is caught at ADMISSION; an overflow RHS (finite entries whose norm²
  overflows float32) passes admission and must be caught by the in-solve
  taxonomy + verification — the defense-in-depth case.
* **Transient faults** — :class:`BatchFaultInjector` wraps the server
  worker's view of ``(gauge, rhs)`` (``SolverServer(fault_injector=...)``)
  and corrupts every N-th SOLVE CALL: a NaN plane or an exponent bit-flip
  in the gauge field (the accelerator-memory fault model of the FPGA
  deployment lineage), a worker stall (the hung-device model, driving
  deadline expiry), or a raised :class:`InjectedFault` (the hard-crash
  model, driving batch bisection).  Faults are transient: the injector
  fires once per schedule slot, so the server's clean individual re-solve
  of an affected batch rescues every healthy member — which is precisely
  the containment property the chaos gate asserts.
"""

from __future__ import annotations

import dataclasses
import os
import signal
import subprocess
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

__all__ = ["InjectedFault", "BatchFaultInjector", "CrashedProcess",
           "poison_nan", "poison_overflow", "nan_plane", "bit_flip",
           "run_and_sigkill"]

_MODES = ("gauge_nan_plane", "gauge_bitflip", "stall", "raise")


class InjectedFault(RuntimeError):
    """Raised by a ``mode="raise"`` injector: the hard-crash fault model."""


# -- poisoned-input helpers (host-side, numpy: requests are built on host) --


def poison_nan(rhs: Array, site: int = 0) -> Array:
    """A NaN-poisoned RHS: what a broken producer hands the server.
    Caught at admission when validation is on; classified ``nonfinite``
    by the solve taxonomy when it is off (defense in depth)."""
    flat = np.asarray(rhs).copy().reshape(-1)
    flat[site] = np.nan
    return jnp.asarray(flat.reshape(np.asarray(rhs).shape))


def poison_overflow(rhs: Array, scale: float = 1e25) -> Array:
    """An overflow-poisoned RHS: every entry FINITE, but ‖b‖² overflows
    float32 — passes the admission finiteness check by construction, so
    only the in-solve nonfinite taxonomy (and the verification matvec)
    can catch it.  The masked batched CG keeps such a lane inactive from
    iteration 0 (its stopping limit is inf/NaN), which is what bounds its
    blast radius to itself."""
    return (jnp.asarray(rhs) * scale).astype(jnp.asarray(rhs).dtype)


# -- transient gauge-field corruptors ---------------------------------------


def nan_plane(u: Array, t: int = 0) -> Array:
    """NaN out one time-plane of the gauge field (axis 1 of the natural
    (4, T, Z, Y, X, 3, 3) layout): the lost-memory-page fault model."""
    return jnp.asarray(u).at[:, t].set(jnp.nan)


def bit_flip(u: Array, site: int = 0) -> Array:
    """Flip the top exponent bit of one float32 word of the gauge field —
    a single-event upset.  The value jumps by a factor ~2^128, so the
    solve's residual recurrence is violently perturbed and verification
    (or the nonfinite taxonomy, once norms overflow) must catch it."""
    host = np.asarray(u).copy()
    words = host.view(np.float32).reshape(-1)
    bits = words[site:site + 1].view(np.uint32)
    bits ^= np.uint32(1 << 30)
    return jnp.asarray(host)


@dataclasses.dataclass
class BatchFaultInjector:
    """Deterministic transient-fault injector for ``SolverServer``.

    Wraps the worker's ``(u, b)`` just before the compiled solve runs.
    Fires when ``calls % every == at`` (0-based call counter), so a
    test or the loadgen chaos lane can schedule exactly which solves are
    hit.  All faults are TRANSIENT: the next call sees clean fields.

    Modes:
      gauge_nan_plane:  NaN one gauge time-plane (→ nonfinite verdicts)
      gauge_bitflip:    exponent bit-flip in one gauge word
      stall:            sleep ``stall_s`` in the worker thread (deadline
                        and backpressure fault model); fields untouched
      raise:            raise :class:`InjectedFault` (batch bisection)
    """

    mode: str = "gauge_nan_plane"
    every: int = 4
    at: int = 0
    stall_s: float = 0.5
    calls: int = 0
    fired: int = 0

    def __post_init__(self):
        if self.mode not in _MODES:
            raise ValueError(
                f"unknown chaos mode {self.mode!r}; pick one of {_MODES}")
        if self.every < 1:
            raise ValueError(f"every must be >= 1, got {self.every}")

    def __call__(self, u: Array, b: Array) -> tuple[Array, Array]:
        fire = self.calls % self.every == self.at % self.every
        self.calls += 1
        if not fire:
            return u, b
        self.fired += 1
        if self.mode == "raise":
            raise InjectedFault(
                f"injected crash (call {self.calls - 1})")
        if self.mode == "stall":
            time.sleep(self.stall_s)
            return u, b
        if self.mode == "gauge_nan_plane":
            return nan_plane(u), b
        return bit_flip(u), b


# -- process-level crash injection (DESIGN.md §11) ---------------------------

@dataclasses.dataclass
class CrashedProcess:
    """Outcome of :func:`run_and_sigkill`."""

    args: tuple
    pid: int
    killed: bool        # True: we SIGKILLed it; False: it exited first
    returncode: int
    stdout: str         # everything the child printed (stderr merged in)


def run_and_sigkill(argv, *, kill_when, env=None, cwd=None,
                    timeout_s: float = 240.0,
                    poll_s: float = 0.05) -> CrashedProcess:
    """Run ``argv`` and SIGKILL it the moment ``kill_when`` triggers.

    ``kill_when`` is either a string — kill once it appears anywhere in
    the child's (merged) output — or a zero/one-argument callable polled
    every ``poll_s`` seconds; callables may inspect the child's output
    (passed as the single argument when accepted) or the filesystem
    (e.g. "a checkpoint step directory exists", "the journal has N admit
    lines").  SIGKILL — not SIGTERM — is the point: the child gets no
    chance to flush, drain, or run atexit hooks, which is exactly the
    crash the durability machinery must survive.

    If the child exits before the trigger fires, ``killed`` is False and
    the caller decides whether that invalidates the experiment.  If
    neither happens within ``timeout_s`` the child is killed and a
    TimeoutError is raised.
    """
    proc = subprocess.Popen(list(argv), stdout=subprocess.PIPE,
                            stderr=subprocess.STDOUT, text=True,
                            env=env, cwd=cwd)
    chunks: list[str] = []

    def _reader():
        for line in proc.stdout:
            chunks.append(line)

    reader = threading.Thread(target=_reader, daemon=True)
    reader.start()

    def _triggered() -> bool:
        out = "".join(chunks)
        if callable(kill_when):
            try:
                return bool(kill_when(out))
            except TypeError:
                return bool(kill_when())
        return str(kill_when) in out

    deadline = time.monotonic() + float(timeout_s)
    killed = False
    while True:
        if proc.poll() is not None:
            break
        if _triggered():
            os.kill(proc.pid, signal.SIGKILL)
            killed = True
            break
        if time.monotonic() > deadline:
            os.kill(proc.pid, signal.SIGKILL)
            proc.wait()
            reader.join(timeout=5)
            raise TimeoutError(
                f"run_and_sigkill: no trigger and no exit within "
                f"{timeout_s}s; output so far:\n" + "".join(chunks))
        time.sleep(poll_s)
    proc.wait()
    reader.join(timeout=5)
    return CrashedProcess(args=tuple(argv), pid=proc.pid, killed=killed,
                          returncode=proc.returncode,
                          stdout="".join(chunks))
