"""Continuous-batching solver server: solve-as-a-service over SolverPlan.

The production shape of "many applications on one optimized CG core"
(PAPER.md; the heterogeneous follow-up arXiv:2111.14958) is a REQUEST
STREAM: many independent clients firing right-hand sides at a small set
of hot gauge fields.  :class:`SolverServer` is that shape as code:

    admit → queue → coalesce → pad to ladder rung → masked batched solve
          → verify → contain → return

* Requests (:class:`SolveRequest`) name ``(operator_family, mu, gauge_id,
  rhs, tol)``; gauge fields are registered once and referenced by id.
* Requests sharing a COALESCE KEY ``(gauge_id, family, mu, mass)`` land
  in one queue and are dispatched together into the gauge-amortized
  multi-RHS batched EO-Schur CGNR path (DESIGN.md §6): one compiled solve
  reads each gauge plane once for the whole batch.
* Batch formation follows :class:`repro.serve.batching.BatchPolicy`:
  dispatch when ``max_batch`` requests are queued or ``max_wait`` seconds
  after the first one, whichever comes first — a lone request is never
  starved.
* Dispatched batches are padded to a fixed ladder of batch shapes and
  solved through the compiled-plan cache
  (:class:`repro.serve.plan_cache.PlanCache`), so steady state never pays
  trace/compile.
* Per-request tolerances ride a per-RHS tolerance VECTOR (a runtime
  argument of the compiled solve), so mixed-tolerance requests coalesce
  into one batch instead of fragmenting the queue.
* Each request completes with the masked-freeze guarantee of PR 3: its
  returned solution is bitwise the iterate an independent solve would
  have produced at ITS OWN convergence point — the batch running on for
  slower systems never perturbs it — and its :class:`RequestStats` report
  the freeze iteration (``SolveStats.rhs_iterations``), queue time, batch
  size and plan-cache hit.

Defense layer (DESIGN.md §10):

* **Admission**: non-finite RHS / tolerance / parameters are rejected at
  ``submit`` with :class:`~repro.serve.errors.RequestRejected` before
  ever touching a queue.
* **Deadlines**: ``SolveRequest.deadline_s`` seconds after submission an
  undispatched request fails with
  :class:`~repro.serve.errors.SolveTimeout` and its batch slot is freed.
* **Backpressure**: each coalesce-key queue is bounded
  (``max_queue_depth``); an arrival over the bound fails immediately
  with :class:`~repro.serve.errors.ServerOverloaded`.
* **Verification + blast-radius containment**: every solved lane must
  pass the plan's true-residual verification (``converged`` AND
  ``verified``).  A failing lane in a multi-request batch is re-solved
  INDIVIDUALLY once (rescuing victims of a transient fault or of a
  poisoned neighbour); a batch whose solve RAISES is bisected the same
  way.  A lane that still fails gets a classified
  :class:`~repro.serve.errors.RequestFailed` — so the blast radius of
  one poisoned RHS is exactly that one request.
* **Drain on close**: ``close()`` completes queued and in-flight work
  before shutting down; ``close(drain=False)`` aborts, failing every
  pending request with :class:`~repro.serve.errors.ServerClosed` instead
  of hanging its awaiter.
* **Fault injection**: ``fault_injector`` (see :mod:`repro.serve.chaos`)
  wraps the worker's view of ``(gauge, rhs)`` — the chaos harness that
  drives the containment tests and the ``loadgen --chaos`` lane.

Single-accelerator model: one worker thread executes solves in dispatch
order (the asyncio loop keeps ingesting and batching while a solve runs —
continuous batching, not stop-and-wait).
"""

from __future__ import annotations

import asyncio
import dataclasses
import math
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import plan as plan_mod
from repro.core.solvers import verdict_name
from repro.serve import journal as journal_mod
from repro.serve.batching import (BatchPolicy, DEFAULT_LADDER, pad_batch,
                                  pad_tols, rung_for, validate_ladder)
from repro.serve.errors import (RequestFailed, RequestRejected, ServerClosed,
                                ServerOverloaded, SolveTimeout)
from repro.serve.plan_cache import DeflationCache, PlanCache

Array = jax.Array

# drain sentinel: close() pushes one through each coalesce queue so the
# dispatcher finishes everything queued ahead of it, then exits cleanly
_CLOSE = object()


@dataclasses.dataclass(frozen=True)
class SolveRequest:
    """One client solve: which operator, which gauge field, which RHS.

    ``rhs`` is a natural-layout (T, Z, Y, X, 4, 3) spinor field.  ``mass``
    defaults to the server's configured mass; like ``mu`` it is a
    trace-time constant of the kernels, so it is part of the coalesce key
    (requests with different masses cannot share a batch).  ``tol`` is a
    RUNTIME per-RHS argument and never fragments batching.

    ``deadline_s`` (seconds from submission, None = no deadline) bounds
    the time the request may sit in the batching queue: a request still
    undispatched at its deadline fails with :class:`SolveTimeout` and
    does NOT consume a slot in the batch it would have joined.
    """

    operator_family: str
    gauge_id: str
    rhs: Array
    tol: float = 1e-6
    mu: float = 0.0
    mass: float | None = None
    deadline_s: float | None = None


@dataclasses.dataclass(frozen=True)
class RequestStats:
    """Per-request serving telemetry."""

    queue_s: float          # submit -> batch dispatch
    solve_s: float          # the batch solve's wall time (shared)
    batch_size: int         # real requests in the dispatched batch
    padded_to: int          # ladder rung the batch was padded to
    iterations: int         # this request's convergence-mask freeze step
    converged: bool
    residual_norm2: float   # final per-RHS ||r||² of the masked CG
    plan_cache_hit: bool    # was the compiled plan already cached
    verdict: str = "converged"        # classified exit (VERDICTS name)
    verified: bool = True             # true-residual verification gate
    true_residual_norm2: float = 0.0  # ‖b - D x‖² from the verify matvec
    retried: bool = False   # served by the individual containment re-solve
    resumed: bool = False   # replayed from the journal after a crash
    # solved with a cached DeflationBasis for this coalesce key (the
    # warm-gauge-field fast path; strictly fewer iterations than the cold
    # solve that harvested the basis)
    deflation_cache_hit: bool = False


@dataclasses.dataclass(frozen=True)
class SolveResult:
    x: Array
    stats: RequestStats


class _Pending(NamedTuple):
    request: SolveRequest
    future: asyncio.Future
    t_enqueue: float
    t_deadline: float | None
    rid: int | None = None    # journal record id (None: journaling off)


class SolverServer:
    """Async continuous-batching front end over the SolverPlan stack."""

    def __init__(self, *, mass: float = 0.1, backend: str = "reference",
                 ladder=DEFAULT_LADDER, policy: BatchPolicy | None = None,
                 maxiter: int = 1000, interpret: bool | None = None,
                 plan_cache: PlanCache | None = None,
                 admission_validation: bool = True,
                 max_queue_depth: int = 256,
                 fault_injector: Callable | None = None,
                 journal_dir: str | None = None,
                 deflation_nev: int = 0, deflation_m_max: int = 160,
                 deflation_harvest_tol: float | None = None,
                 deflation_cache: DeflationCache | None = None):
        self.mass = float(mass)
        self.backend = backend
        self.ladder = validate_ladder(ladder)
        self.policy = policy or BatchPolicy()
        self.maxiter = int(maxiter)
        self.interpret = interpret
        self.plans = plan_cache or PlanCache()
        self.admission_validation = bool(admission_validation)
        if max_queue_depth < 1:
            raise ValueError(
                f"max_queue_depth must be >= 1, got {max_queue_depth}")
        self.max_queue_depth = int(max_queue_depth)
        # test hook (serve/chaos.py): rewrites the worker's (u, b) view
        self.fault_injector = fault_injector
        # EigCG deflation (DESIGN.md §12) — OFF by default (nev=0): the
        # first verified solve on a coalesce key harvests a low-mode
        # basis; later primary dispatches on that key start from the
        # Galerkin projection and converge in strictly fewer iterations.
        # A deflated solve still passes the full §10 verification gate
        # against the ORIGINAL system, so deflation can only ever cost
        # a harvest solve — never correctness.
        if deflation_nev < 0:
            raise ValueError(
                f"deflation_nev must be >= 0, got {deflation_nev}")
        self.deflation_nev = int(deflation_nev)
        self.deflation_m_max = int(deflation_m_max)
        self.deflation_harvest_tol = (
            None if deflation_harvest_tol is None
            else float(deflation_harvest_tol))
        self.deflations = deflation_cache or DeflationCache()
        self._harvest_failures = 0
        # write-ahead journal (serve/journal.py): admitted requests are
        # durable; recover() replays whatever a crash left incomplete
        self.journal = (journal_mod.RequestJournal(journal_dir)
                        if journal_dir is not None else None)
        # continue rids past a previous process's entries when journaling
        # into the same directory (restart-into-same-journal is the
        # recover() deployment shape)
        self._next_rid = (0 if self.journal is None else 1 + max(
            (int(ev["rid"]) for ev in journal_mod.scan_journal(journal_dir)),
            default=-1))
        self._gauges: dict[str, Array] = {}
        self._queues: dict[tuple, asyncio.Queue] = {}
        self._dispatchers: dict[tuple, asyncio.Task] = {}
        # one worker thread = one accelerator: solves execute in dispatch
        # order while the event loop keeps forming the next batches
        self._exec = ThreadPoolExecutor(max_workers=1,
                                        thread_name_prefix="solve")
        self._closed = False
        # serving counters (metrics())
        self._n_requests = 0
        self._n_batches = 0
        self._batch_hist: dict[int, int] = {}
        self._rung_hist: dict[int, int] = {}
        self._padded_slots = 0
        self._served = 0
        self._served_cache_hits = 0
        # containment counters (metrics()["containment"])
        self._admission_rejected = 0
        self._overload_rejected = 0
        self._deadline_expired = 0
        self._batch_failures = 0
        self._lane_retries = 0
        self._lane_retries_rescued = 0
        self._failed_requests = 0
        self._verdict_hist: dict[str, int] = {}

    # -- gauge registry ----------------------------------------------------

    def register_gauge(self, gauge_id: str, u: Array) -> None:
        """Register a hot gauge field clients may reference by id.

        Re-registering an id installs the NEW field and invalidates every
        deflation basis harvested on the old one — a low-mode basis is a
        statement about one specific gauge configuration.
        """
        gid = str(gauge_id)
        if gid in self._gauges:
            self.deflations.invalidate_gauge(gid)
        self._gauges[gid] = u

    def gauge_ids(self) -> tuple[str, ...]:
        return tuple(self._gauges)

    async def warmup(self, families=(("wilson", 0.0),),
                     rungs=None, masses=None) -> int:
        """Precompile the batch-shape ladder for the expected traffic.

        Runs one ZERO-RHS solve per (family, mu) × ladder rung × mass
        against each distinct registered gauge-field shape.  A zero RHS
        converges at iteration 0 under the per-RHS mask (zero limit), so
        each warmup call costs exactly one trace+compile and no Krylov
        iterations — after this, steady-state requests never pay compile
        (``RequestStats.plan_cache_hit`` is True for every batch whose
        rung was warmed).  Returns the number of programs compiled.
        """
        loop = asyncio.get_running_loop()
        rungs = tuple(rungs) if rungs is not None else self.ladder
        masses = tuple(masses) if masses is not None else (self.mass,)
        by_shape = {}
        for u in self._gauges.values():
            by_shape.setdefault(tuple(u.shape), u)
        compiled = 0
        for u in by_shape.values():
            # gauge (4, T, Z, Y, X, 3, 3) -> spinor (T, Z, Y, X, 4, 3)
            sshape = tuple(u.shape[1:5]) + (4, 3)
            for family, mu in families:
                for rung in rungs:
                    for mass in masses:
                        plan = plan_mod.SolverPlan(
                            operator="eo-schur", operator_family=family,
                            mu=float(mu), backend=self.backend, nrhs=rung,
                            interpret=self.interpret)
                        fn, hit = self.plans.get(plan, float(mass),
                                                 self.maxiter)
                        if hit:
                            continue
                        b = jnp.zeros((rung,) + sshape, jnp.complex64)
                        tol = jnp.ones((rung,), jnp.float32)

                        def run(fn=fn, u=u, b=b, tol=tol):
                            jax.block_until_ready(fn(u, b, tol)[0])

                        await loop.run_in_executor(self._exec, run)
                        compiled += 1
        return compiled

    # -- request path ------------------------------------------------------

    def _plan_for(self, request: SolveRequest, nrhs: int | None
                  ) -> plan_mod.SolverPlan:
        return plan_mod.SolverPlan(
            operator="eo-schur", operator_family=request.operator_family,
            mu=float(request.mu), backend=self.backend, nrhs=nrhs,
            interpret=self.interpret)

    def _coalesce_key(self, request: SolveRequest) -> tuple:
        mass = self.mass if request.mass is None else float(request.mass)
        return (str(request.gauge_id), request.operator_family,
                float(request.mu), mass)

    def _admit(self, request: SolveRequest) -> None:
        """Admission-time validation: reject a poisoned request before it
        can touch a queue (first containment ring; see module docstring).
        One host-synced all-finite reduction per request — admission cost,
        never solve-loop cost."""
        tol = float(request.tol) if jnp.ndim(request.tol) == 0 else None
        if tol is None or not math.isfinite(tol) or tol <= 0:
            self._admission_rejected += 1
            raise RequestRejected(
                f"tol must be a finite positive scalar, got {request.tol!r}",
                reason="bad_tol")
        for name, value in (("mu", request.mu), ("mass", request.mass),
                            ("deadline_s", request.deadline_s)):
            if value is not None and not math.isfinite(float(value)):
                self._admission_rejected += 1
                raise RequestRejected(
                    f"{name} must be finite, got {value!r}",
                    reason=f"bad_{name}")
        if not bool(jnp.all(jnp.isfinite(request.rhs))):
            self._admission_rejected += 1
            raise RequestRejected(
                "rhs contains non-finite entries", reason="nonfinite_rhs")

    async def submit(self, request: SolveRequest) -> SolveResult:
        """Enqueue one request; resolves when its solution is ready."""
        if self._closed:
            raise RuntimeError("SolverServer is closed")
        if str(request.gauge_id) not in self._gauges:
            raise KeyError(
                f"unknown gauge_id {request.gauge_id!r}; registered: "
                f"{sorted(self._gauges)}")
        self._plan_for(request, None)  # validate family/mu NOW, not in batch
        if self.admission_validation:
            self._admit(request)
        loop = asyncio.get_running_loop()
        key = self._coalesce_key(request)
        queue = self._queues.get(key)
        if queue is None:
            queue = asyncio.Queue()
            self._queues[key] = queue
            self._dispatchers[key] = loop.create_task(
                self._dispatch_loop(key, queue))
        if queue.qsize() >= self.max_queue_depth:
            self._overload_rejected += 1
            raise ServerOverloaded(
                f"queue for coalesce key {key} is at its bound "
                f"({self.max_queue_depth}); back off and retry",
                queue_depth=queue.qsize())
        future: asyncio.Future = loop.create_future()
        self._n_requests += 1
        rid = None
        if self.journal is not None:
            # write-ahead: the admit record (RHS included) is fsync'd
            # BEFORE the request can be queued — from here on, a SIGKILL
            # cannot lose it, only leave it for recover() to replay
            rid = self._next_rid
            self._next_rid += 1
            self.journal.admit(
                rid, operator_family=request.operator_family,
                gauge_id=str(request.gauge_id), rhs=request.rhs,
                tol=float(request.tol), mu=float(request.mu),
                mass=request.mass, deadline_s=request.deadline_s)
        now = loop.time()
        deadline = (None if request.deadline_s is None
                    else now + float(request.deadline_s))
        queue.put_nowait(_Pending(request, future, now, deadline, rid))
        return await future

    async def _dispatch_loop(self, key: tuple, queue: asyncio.Queue):
        """Form batches: dispatch at max_batch or max_wait after the first."""
        loop = asyncio.get_running_loop()
        max_batch = self.policy.resolved_max_batch(self.ladder)
        while True:
            first = await queue.get()
            if first is _CLOSE:
                return
            batch = [first]
            draining = False
            deadline = loop.time() + self.policy.max_wait
            while len(batch) < max_batch and not draining:
                # drain whatever is already queued before sleeping on the
                # deadline — a backlog dispatches as full batches at once
                while not queue.empty() and len(batch) < max_batch:
                    item = queue.get_nowait()
                    if item is _CLOSE:
                        draining = True
                        break
                    batch.append(item)
                if draining or len(batch) >= max_batch:
                    break
                timeout = deadline - loop.time()
                if timeout <= 0:
                    break
                try:
                    item = await asyncio.wait_for(queue.get(), timeout)
                except asyncio.TimeoutError:
                    break
                if item is _CLOSE:
                    draining = True
                    break
                batch.append(item)
            await self._solve_batch(batch)
            if draining:
                return

    def _journal_complete(self, p: _Pending, status: str):
        if self.journal is not None and p.rid is not None:
            self.journal.complete(p.rid, status)

    def _fail(self, p: _Pending, exc: Exception, verdict: str | None = None):
        self._failed_requests += 1
        if verdict is not None:
            self._verdict_hist[verdict] = (
                self._verdict_hist.get(verdict, 0) + 1)
        # a classified failure IS a completion (the client got a durable
        # answer); ServerClosed is NOT — those requests died with the
        # process and must remain in the replay set
        if not isinstance(exc, ServerClosed):
            self._journal_complete(
                p, verdict if verdict is not None else type(exc).__name__)
        if not p.future.done():
            p.future.set_exception(exc)

    def _drop_expired(self, batch: list[_Pending],
                      now: float) -> list[_Pending]:
        """Deadline containment: an expired request fails with
        SolveTimeout and frees its slot BEFORE the batch is shaped."""
        live = []
        for p in batch:
            if p.t_deadline is not None and now > p.t_deadline:
                self._deadline_expired += 1
                self._fail(p, SolveTimeout(
                    f"deadline_s={p.request.deadline_s} expired after "
                    f"{now - p.t_enqueue:.3f}s in queue"))
            else:
                live.append(p)
        return live

    async def _solve_batch(self, batch: list[_Pending], *,
                           retried: bool = False):
        loop = asyncio.get_running_loop()
        t_dispatch = loop.time()
        batch = self._drop_expired(batch, t_dispatch)
        if not batch:
            return
        requests = [p.request for p in batch]
        first = requests[0]
        rung = rung_for(len(batch), self.ladder)
        mass = self.mass if first.mass is None else float(first.mass)
        key = self._coalesce_key(first)
        # warm-gauge fast path: primary dispatches on a key with a
        # harvested basis run the deflated program; containment re-solves
        # deliberately do NOT (a retry must be the plainest possible
        # solve — if the basis itself were somehow bad, deflation-free
        # retries keep it out of the blast radius)
        basis = (self.deflations.lookup(key)
                 if self.deflation_nev > 0 and not retried else None)
        try:
            plan = self._plan_for(first, rung)
            fn, cache_hit = (
                self.plans.get_deflated(plan, mass, self.maxiter)
                if basis is not None
                else self.plans.get(plan, mass, self.maxiter))
            u = self._gauges[str(first.gauge_id)]
            b = pad_batch([r.rhs for r in requests], rung)
            tol = pad_tols([r.tol for r in requests], rung)
            # the containment re-solve IS the clean retry of the transient
            # fault model: the injector only sees primary dispatches
            injector = None if retried else self.fault_injector

            def run():
                uu, bb = (u, b) if injector is None else injector(u, b)
                x, stats = (fn(uu, bb, tol) if basis is None
                            else fn(uu, bb, tol, basis.w, basis.gram))
                jax.block_until_ready(x)
                return x, stats

            x, stats = await loop.run_in_executor(self._exec, run)
        except asyncio.CancelledError:
            # abort-path close() cancelled the dispatcher mid-solve: never
            # leave awaiters hanging on futures nobody will complete
            for p in batch:
                if not p.future.done():
                    p.future.set_exception(
                        ServerClosed("server closed while solving"))
            raise
        except Exception as e:
            # batch-failure bisection: re-solve members individually so
            # one poisoned request cannot take its neighbours down.  A
            # singleton gets the same single clean re-solve — a transient
            # fault must not kill a lone healthy request either.
            if not retried:
                self._batch_failures += 1
                for p in batch:
                    self._lane_retries += 1
                    await self._solve_batch([p], retried=True)
                return
            for p in batch:
                self._fail(p, RequestFailed(
                    f"solve failed: {e!r}", verdict="error",
                    retried=retried), verdict="error")
            return
        solve_s = loop.time() - t_dispatch
        self._n_batches += 1
        self._batch_hist[len(batch)] = self._batch_hist.get(len(batch), 0) + 1
        self._rung_hist[rung] = self._rung_hist.get(rung, 0) + 1
        self._padded_slots += rung - len(batch)
        self._served += len(batch)
        if cache_hit:
            self._served_cache_hits += len(batch)
        rhs_iters = jax.device_get(stats.rhs_iterations)
        converged = jax.device_get(stats.converged)
        res2 = jax.device_get(stats.residual_norm2)
        verdicts = jax.device_get(stats.verdict)
        verified = jax.device_get(stats.verified)
        true_res2 = jax.device_get(stats.true_residual_norm2)
        retry: list[_Pending] = []
        for i, p in enumerate(batch):
            verdict = verdict_name(verdicts[i])
            ok = bool(converged[i]) and bool(verified[i])
            if not ok:
                if not retried:
                    # containment: one clean INDIVIDUAL re-solve — rescues
                    # a healthy lane hit by a transient fault or by batch
                    # effects of a poisoned neighbour; a genuinely poisoned
                    # request fails the retry too (classified, terminal)
                    retry.append(p)
                else:
                    self._fail(p, RequestFailed(
                        f"request failed verification (verdict={verdict}, "
                        f"true ‖r‖²={float(true_res2[i]):.3e})",
                        verdict=verdict, retried=retried), verdict=verdict)
            else:
                if retried:
                    self._lane_retries_rescued += 1
                st = RequestStats(
                    queue_s=t_dispatch - p.t_enqueue, solve_s=solve_s,
                    batch_size=len(batch), padded_to=rung,
                    iterations=int(rhs_iters[i]),
                    converged=bool(converged[i]),
                    residual_norm2=float(res2[i]), plan_cache_hit=cache_hit,
                    verdict=verdict, verified=bool(verified[i]),
                    true_residual_norm2=float(true_res2[i]), retried=retried,
                    deflation_cache_hit=basis is not None)
                self._journal_complete(p, "ok")
                if not p.future.done():
                    p.future.set_result(SolveResult(x=x[i], stats=st))
        for p in retry:
            self._lane_retries += 1
            await self._solve_batch([p], retried=True)
        # EigCG harvest: the FIRST verified primary batch on a cold key
        # pays one extra unbatched solve to mine the low modes every
        # later request on this (gauge, operator) reuses.  Only a lane
        # that passed the full verification gate may seed the basis — a
        # poisoned or faulted lane never can.
        if (self.deflation_nev > 0 and not retried and basis is None
                and self.deflations.peek(key) is None):
            for i, p in enumerate(batch):
                if bool(converged[i]) and bool(verified[i]):
                    await self._harvest_basis(key, p.request)
                    break

    async def _harvest_basis(self, key: tuple, request: SolveRequest):
        """Harvest a DeflationBasis from one just-verified request.

        Runs :func:`repro.core.plan.harvest_deflation` — an unbatched
        solve of the same system recording its Lanczos data — on the
        worker thread (one accelerator, dispatch order preserved).  The
        harvest tolerance defaults to the triggering request's tol;
        ``deflation_harvest_tol`` overrides it when the operator is ill-
        conditioned enough that a deeper Krylov space buys a better basis.

        Deflation is an accelerator, never a correctness dependency: a
        harvest that fails, diverges, fails verification or produces
        non-finite arrays is dropped (counted in
        ``metrics()["deflation"]["harvest_failures"]``) and serving
        continues undeflated.
        """
        loop = asyncio.get_running_loop()
        u = self._gauges[str(request.gauge_id)]
        mass = key[3]
        htol = (float(request.tol) if self.deflation_harvest_tol is None
                else self.deflation_harvest_tol)
        plan = self._plan_for(request, None)
        nev, m_max, maxiter = (self.deflation_nev, self.deflation_m_max,
                               self.maxiter)

        def run():
            # verification of the harvest x gates at the REQUEST tol: the
            # harvest may deliberately iterate past it (see
            # harvest_deflation), and only the basis is kept anyway
            _, stats, harvested = plan_mod.harvest_deflation(
                plan, u, request.rhs, mass, tol=htol, maxiter=maxiter,
                nev=nev, m_max=m_max, verify_tol=float(request.tol))
            ok = (bool(jax.device_get(stats.converged))
                  and bool(jax.device_get(stats.verified)))
            finite = bool(jnp.all(jnp.isfinite(harvested.w))
                          and jnp.all(jnp.isfinite(harvested.gram)))
            return harvested if ok and finite else None

        try:
            harvested = await loop.run_in_executor(self._exec, run)
        except Exception:
            harvested = None
        if harvested is not None:
            self.deflations.store(key, harvested)
        else:
            self._harvest_failures += 1

    # -- lifecycle / telemetry --------------------------------------------

    def metrics(self) -> dict:
        """Serving counters: requests, batches, histograms, containment."""
        return {
            "requests": self._n_requests,
            "batches": self._n_batches,
            "batch_hist": {str(k): v for k, v
                           in sorted(self._batch_hist.items())},
            "rung_hist": {str(k): v for k, v
                          in sorted(self._rung_hist.items())},
            "padded_slots": self._padded_slots,
            # request-level cache experience: the fraction of SERVED
            # requests whose batch ran through an already-compiled plan
            # (after warmup this is 1.0 in steady state)
            "request_cache_hit_rate": (self._served_cache_hits
                                       / self._served if self._served
                                       else 0.0),
            "plan_cache": self.plans.stats(),
            "deflation": {
                "enabled": self.deflation_nev > 0,
                "nev": self.deflation_nev,
                "harvest_failures": self._harvest_failures,
                **self.deflations.stats(),
            },
            "containment": {
                "admission_rejected": self._admission_rejected,
                "overload_rejected": self._overload_rejected,
                "deadline_expired": self._deadline_expired,
                "batch_failures": self._batch_failures,
                "lane_retries": self._lane_retries,
                "lane_retries_rescued": self._lane_retries_rescued,
                "failed_requests": self._failed_requests,
                "verdict_hist": dict(sorted(self._verdict_hist.items())),
            },
        }

    async def recover(self, journal_dir: str | None = None) -> dict:
        """Replay a dead process's journal: every admitted-but-incomplete
        request is re-submitted through the normal pipeline.

        ``journal_dir`` defaults to this server's own journal directory
        (the usual shape: start a fresh journaled server over the same
        directory, then recover).  Replayed requests drop their original
        deadline — it was measured against a clock that died with the old
        process.  Each replayed entry is retired in the OLD journal with a
        ``recovered`` / ``recovered_failed:*`` mark so a second recovery
        pass finds nothing; requests whose gauge was never re-registered
        are retired as ``skipped_unknown_gauge`` rather than left to poison
        every future recovery.

        Returns a summary: ``{"found", "replayed", "completed", "failed",
        "skipped_unknown_gauge", "results": [(rid, "ok" | "<ExcType>")]}``.
        """
        if journal_dir is None:
            if self.journal is None:
                raise ValueError(
                    "recover() needs a journal_dir when the server itself "
                    "is not journaled")
            journal_dir = self.journal.dir
        entries = journal_mod.incomplete_requests(journal_dir)
        summary = {"found": len(entries), "replayed": 0, "completed": 0,
                   "failed": 0, "skipped_unknown_gauge": 0, "results": []}
        pending: list[tuple[int, asyncio.Future]] = []
        for ev in entries:
            rid = int(ev["rid"])
            if str(ev["gauge_id"]) not in self._gauges:
                journal_mod.mark_complete(
                    journal_dir, rid, "skipped_unknown_gauge")
                summary["skipped_unknown_gauge"] += 1
                continue
            req = SolveRequest(
                operator_family=str(ev["operator_family"]),
                gauge_id=str(ev["gauge_id"]),
                rhs=jnp.asarray(journal_mod.load_rhs(journal_dir, ev)),
                tol=float(ev["tol"]), mu=float(ev["mu"]),
                mass=ev["mass"], deadline_s=None)
            pending.append(
                (rid, asyncio.ensure_future(self.submit(req))))
            summary["replayed"] += 1
        for rid, fut in pending:
            try:
                res = await fut
            except Exception as exc:
                journal_mod.mark_complete(
                    journal_dir, rid, f"recovered_failed:{type(exc).__name__}")
                summary["failed"] += 1
                summary["results"].append((rid, type(exc).__name__))
            else:
                journal_mod.mark_complete(journal_dir, rid, "recovered")
                summary["completed"] += 1
                summary["results"].append((rid, "ok"))
                object.__setattr__(res.stats, "resumed", True)
        return summary

    async def close(self, drain: bool = True):
        """Shut down; by default DRAIN (complete queued + in-flight work).

        ``drain=True``: reject new submissions, push a close sentinel
        through every coalesce queue, and wait for the dispatchers to
        finish everything queued ahead of it — every outstanding future
        completes (with a result or a structured failure) before the
        worker thread is released.  ``drain=False``: abort — cancel
        dispatchers and fail everything still pending with
        :class:`ServerClosed` so no awaiter ever hangs.
        """
        self._closed = True
        if drain:
            for queue in self._queues.values():
                queue.put_nowait(_CLOSE)
            for task in self._dispatchers.values():
                await task
        else:
            for task in self._dispatchers.values():
                task.cancel()
            for task in self._dispatchers.values():
                try:
                    await task
                except asyncio.CancelledError:
                    pass
            # anything still sitting in a queue never reached a dispatcher
            for queue in self._queues.values():
                while not queue.empty():
                    item = queue.get_nowait()
                    if item is _CLOSE:
                        continue
                    if not item.future.done():
                        item.future.set_exception(
                            ServerClosed("server closed before dispatch"))
        self._dispatchers.clear()
        self._queues.clear()
        self._exec.shutdown(wait=True)
        if self.journal is not None:
            self.journal.close()

    async def __aenter__(self):
        return self

    async def __aexit__(self, *exc):
        await self.close()
