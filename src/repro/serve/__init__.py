"""Solve-as-a-service: the continuous-batching solver server.

Public surface:
  server     — SolverServer (async queue → coalesce → pad → batched solve),
               SolveRequest / SolveResult / RequestStats
  batching   — the pre-compiled batch-shape ladder + BatchPolicy
  plan_cache — PlanCache: resolved SolverPlan → jitted solve callable
  loadgen    — WorkloadConfig / run_workload: synthetic open-loop load
               generator + direct-solve verification (BENCH_serve.json)
"""

from repro.serve.batching import (BatchPolicy, DEFAULT_LADDER, pad_batch,
                                  pad_tols, rung_for, validate_ladder)
from repro.serve.loadgen import (WorkloadConfig, build_workload,
                                 drive_open_loop, run_workload,
                                 verify_against_direct)
from repro.serve.plan_cache import PlanCache
from repro.serve.server import (RequestStats, SolveRequest, SolveResult,
                                SolverServer)
