"""Solve-as-a-service: the continuous-batching solver server.

Public surface:
  server     — SolverServer (async admit → queue → coalesce → pad →
               batched solve → verify → contain),
               SolveRequest / SolveResult / RequestStats
  errors     — the structured failure types (RequestRejected,
               ServerOverloaded, SolveTimeout, RequestFailed,
               ServerClosed)
  chaos      — deterministic fault injectors (BatchFaultInjector,
               poisoned-RHS helpers) driving the containment tests and
               the loadgen --chaos lane
  batching   — the pre-compiled batch-shape ladder + BatchPolicy
  plan_cache — PlanCache: resolved SolverPlan → jitted solve callable;
               DeflationCache: per-gauge-field EigCG basis store (LRU
               over gauge ids) behind the warm-gauge serving fast path
  loadgen    — WorkloadConfig / run_workload: synthetic open-loop load
               generator + direct-solve verification (BENCH_serve.json)
"""

from repro.serve.batching import (BatchPolicy, DEFAULT_LADDER, pad_batch,
                                  pad_tols, rung_for, validate_ladder)
from repro.serve.chaos import (BatchFaultInjector, InjectedFault, bit_flip,
                               nan_plane, poison_nan, poison_overflow)
from repro.serve.errors import (RequestFailed, RequestRejected, ServerClosed,
                                ServerOverloaded, SolveTimeout)
from repro.serve.loadgen import (WorkloadConfig, build_workload,
                                 drive_open_loop, run_workload,
                                 verify_against_direct)
from repro.serve.plan_cache import DeflationCache, PlanCache
from repro.serve.server import (RequestStats, SolveRequest, SolveResult,
                                SolverServer)
