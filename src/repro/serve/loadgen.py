"""Synthetic open-loop load generator for the solver server.

Open-loop means arrival times are fixed by the workload (bursts of
``burst`` requests every ``interarrival_s`` seconds), NOT by service
completions — the generator never waits for a response before firing the
next request, so a slow server accumulates backlog and the latency
percentiles honestly include queueing.  This is the standard serving-
benchmark discipline (closed-loop generators hide overload).

The workload models the production shape the ROADMAP names: a small set
of hot gauge fields (``n_gauge``), several operator families
(wilson + twisted-mass by default), and a pool of distinct right-hand
sides cycled deterministically across requests — every (gauge, family)
pair sees traffic, so the plan cache and every per-gauge queue are
exercised.

``run_workload`` is the sync entry point: builds the fields, drives the
server under ``asyncio.run``, and returns the ``BENCH_serve.json`` report
(requests/s, p50/p99 latency, batch-size histogram, plan-cache counters,
iteration stats).  With ``verify=True`` every response is re-solved
through a DIRECT unbatched ``plan.solve`` and compared — the end-to-end
correctness gate CI runs (max abs deviation ≤ 1e-5).
"""

from __future__ import annotations

import asyncio
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.core import LatticeShape, random_gauge, random_spinor
from repro.serve.batching import BatchPolicy, DEFAULT_LADDER
from repro.serve.plan_cache import PlanCache
from repro.serve.server import (ServerClosed, SolveRequest, SolveResult,
                                SolverServer)

VERIFY_TOL = 1e-5


@dataclasses.dataclass(frozen=True)
class WorkloadConfig:
    """A synthetic serving workload, fully determined by its fields."""

    lattice: tuple[int, int, int, int] = (4, 4, 4, 4)
    n_gauge: int = 2
    families: tuple[tuple[str, float], ...] = (("wilson", 0.0),
                                               ("twisted-mass", 0.25))
    mass: float = 0.1
    tol: float = 1e-6
    requests: int = 200
    burst: int = 4              # requests fired at each arrival instant
    interarrival_s: float = 0.05  # spacing between bursts
    rhs_pool: int = 8           # distinct right-hand sides, cycled
    seed: int = 7
    # (1, 4, 8): the CI smoke ladder — drop the 16 rung to keep warmup
    # compile time down; production ladders pass DEFAULT_LADDER
    ladder: tuple[int, ...] = (1, 4, 8)
    max_wait_s: float = 0.25
    max_batch: int | None = None
    backend: str = "reference"
    maxiter: int = 500
    warmup: bool = True
    verify: bool = False
    # optional per-request deadline (seconds in queue before SolveTimeout)
    deadline_s: float | None = None
    # -- chaos mode (DESIGN.md §10): deterministic fault injection ---------
    chaos: bool = False
    # fraction of the stream poisoned; every round(1/f)-th request gets a
    # corrupted RHS, alternating NaN (admission-ring test) and overflow
    # (finite entries, overflowing norm — the defense-in-depth test that
    # must be caught by the solve taxonomy + verification instead)
    chaos_poison_fraction: float = 0.1
    # fire a transient gauge fault on every N-th primary batch dispatch
    # (0 = off); the server's individual clean re-solve must rescue every
    # healthy member of an affected batch
    chaos_fault_every: int = 0
    chaos_fault_mode: str = "gauge_nan_plane"
    # write-ahead journal directory (DESIGN.md §11) — admitted requests
    # become durable and a crashed run's incomplete entries can be
    # replayed by SolverServer.recover()
    journal_dir: str | None = None
    # -- EigCG deflation (DESIGN.md §12): per-gauge-field basis cache ------
    # 0 = off (the plain serving lane keeps its golden metrics bitwise);
    # > 0 turns on harvest-on-first-verified-solve per coalesce key and
    # the report gains a "deflation_drop" section proving hits converge
    # in strictly fewer iterations than the cold solve
    deflation_nev: int = 0
    deflation_m_max: int = 160
    # None: harvest at the triggering request's tol; ill-conditioned
    # operators want a tighter harvest (deeper Krylov space, better basis)
    deflation_harvest_tol: float | None = None


def poisoned_indices(cfg: WorkloadConfig) -> frozenset[int]:
    """Which request indices the chaos mode poisons (deterministic)."""
    if not cfg.chaos or cfg.chaos_poison_fraction <= 0:
        return frozenset()
    stride = max(1, round(1.0 / cfg.chaos_poison_fraction))
    return frozenset(range(0, cfg.requests, stride))


def build_workload(cfg: WorkloadConfig
                   ) -> tuple[dict[str, jax.Array], list[SolveRequest]]:
    """Deterministic gauge fields + request list for a workload config."""
    from repro.serve.chaos import poison_nan, poison_overflow

    lat = LatticeShape(*cfg.lattice)
    key = jax.random.PRNGKey(cfg.seed)
    ku, kb = jax.random.split(key)
    gauges = {f"cfg{g}": random_gauge(jax.random.fold_in(ku, g), lat)
              for g in range(cfg.n_gauge)}
    pool = [random_spinor(jax.random.fold_in(kb, i), lat)
            for i in range(cfg.rhs_pool)]
    gauge_ids = sorted(gauges)
    poison = poisoned_indices(cfg)
    requests = []
    for i in range(cfg.requests):
        family, mu = cfg.families[i % len(cfg.families)]
        rhs = pool[i % cfg.rhs_pool]
        if i in poison:
            # alternate the two poison classes: NaN exercises the
            # admission ring, overflow (finite entries) must sail through
            # admission and be caught by taxonomy + verification
            rhs = (poison_nan(rhs) if (i // max(1, round(
                1.0 / cfg.chaos_poison_fraction))) % 2 == 0
                else poison_overflow(rhs))
        requests.append(SolveRequest(
            operator_family=family, mu=mu,
            gauge_id=gauge_ids[(i // len(cfg.families)) % cfg.n_gauge],
            rhs=rhs, tol=cfg.tol, deadline_s=cfg.deadline_s))
    return gauges, requests


async def drive_open_loop(server: SolverServer,
                          requests: list[SolveRequest], *, burst: int,
                          interarrival_s: float
                          ) -> tuple[list[tuple[float, object]], float]:
    """Fire the request schedule; [(latency_s, outcome)] in request order.

    An outcome is a :class:`SolveResult` OR the structured exception the
    server failed the request with — an open-loop generator must keep
    firing through failures (that is the point of the chaos lane), so
    failures are data here, not aborts.
    """

    async def fire(req: SolveRequest, delay: float):
        await asyncio.sleep(delay)
        t0 = time.perf_counter()
        try:
            result = await server.submit(req)
        except Exception as e:  # containment failures are outcomes
            return time.perf_counter() - t0, e
        return time.perf_counter() - t0, result

    t0 = time.perf_counter()
    tasks = [asyncio.ensure_future(fire(req, (i // burst) * interarrival_s))
             for i, req in enumerate(requests)]
    out = await asyncio.gather(*tasks)
    return list(out), time.perf_counter() - t0


def percentile(sorted_vals: list[float], p: float) -> float:
    """Nearest-rank percentile of an already-sorted sample."""
    if not sorted_vals:
        return float("nan")
    idx = min(len(sorted_vals) - 1,
              max(0, int(round(p / 100.0 * (len(sorted_vals) - 1)))))
    return sorted_vals[idx]


def verify_against_direct(gauges: dict, requests: list[SolveRequest],
                          results: list[tuple[float, SolveResult]],
                          cfg: WorkloadConfig,
                          deflation_bases: dict | None = None) -> dict:
    """Re-solve every request through a direct unbatched plan.solve.

    The masked-freeze contract says a served solution is the iterate its
    own independent solve would have produced — so the direct solve is
    the oracle.  A response served off a deflation-cache hit is re-solved
    with the SAME basis (``deflation_bases``: the server cache snapshot):
    the contract for a deflated lane is "the iterate an independent
    DEFLATED solve would have produced".  Uses a PRIVATE PlanCache (the
    server's hit-rate metrics stay untouched); distinct (gauge, family,
    mu, rhs, deflated?) combinations are memoized since the workload
    cycles a finite RHS pool.
    """
    direct_plans = PlanCache()
    bases = deflation_bases or {}
    memo: dict = {}
    max_err = 0.0
    checked = 0
    for req, (_, res) in zip(requests, results):
        if not isinstance(res, SolveResult):
            continue  # failed outcomes carry no x to verify
        checked += 1
        mass = cfg.mass if req.mass is None else float(req.mass)
        deflated = bool(res.stats.deflation_cache_hit)
        key = (req.gauge_id, req.operator_family, float(req.mu), mass,
               float(req.tol), id(req.rhs), deflated)
        x_direct = memo.get(key)
        if x_direct is None:
            from repro.core import plan as plan_mod
            plan = plan_mod.SolverPlan(
                operator="eo-schur", operator_family=req.operator_family,
                mu=float(req.mu), backend=cfg.backend)
            if deflated:
                basis = bases[(req.gauge_id, req.operator_family,
                               float(req.mu), mass)]
                fn, _ = direct_plans.get_deflated(plan, mass, cfg.maxiter)
                x_direct, _ = fn(gauges[req.gauge_id], req.rhs,
                                 jnp.float32(req.tol), basis.w, basis.gram)
            else:
                fn, _ = direct_plans.get(plan, mass, cfg.maxiter)
                x_direct, _ = fn(gauges[req.gauge_id], req.rhs,
                                 jnp.float32(req.tol))
            memo[key] = x_direct
        err = float(jnp.max(jnp.abs(res.x - x_direct)))
        max_err = max(max_err, err)
    return {"checked": checked, "direct_solves": len(memo),
            "max_abs_err": max_err, "tol": VERIFY_TOL,
            "passed": max_err <= VERIFY_TOL}


def summarize_deflation(cfg: WorkloadConfig, requests: list[SolveRequest],
                        results: list[tuple[float, object]]) -> dict:
    """The warm-gauge acceptance check, per coalesce key.

    For every key: the COLD iteration count is the first served request
    that did NOT hit the deflation cache (the solve that triggered the
    harvest); every deflation-cache HIT on that key must have converged
    in strictly fewer iterations.  ``all_hits_dropped`` is the guarded
    bool (vacuously true for keys that never got a hit — the companion
    ``hit_requests`` floor keeps the check from passing emptily).
    """
    per_key: dict[tuple, dict] = {}
    hit_requests = 0
    for req, (_, res) in zip(requests, results):
        if not isinstance(res, SolveResult):
            continue
        mass = cfg.mass if req.mass is None else float(req.mass)
        key = (req.gauge_id, req.operator_family, float(req.mu), mass)
        entry = per_key.setdefault(
            key, {"cold_iters": None, "hits": 0, "hit_iters_max": 0})
        if res.stats.deflation_cache_hit:
            hit_requests += 1
            entry["hits"] += 1
            entry["hit_iters_max"] = max(entry["hit_iters_max"],
                                         res.stats.iterations)
        elif entry["cold_iters"] is None and not res.stats.retried:
            entry["cold_iters"] = res.stats.iterations
    dropped = all(
        e["hits"] == 0 or (e["cold_iters"] is not None
                           and e["hit_iters_max"] < e["cold_iters"])
        for e in per_key.values())
    return {
        "keys": {"|".join(str(v) for v in k): dict(e)
                 for k, e in sorted(per_key.items())},
        "hit_requests": hit_requests,
        "all_hits_dropped": bool(dropped),
    }


def summarize_chaos(cfg: WorkloadConfig,
                    results: list[tuple[float, object]],
                    wall_s: float, recovery: dict | None = None) -> dict:
    """Containment scorecard: goodput + blast-radius accounting.

    The chaos gate (DESIGN.md §10): every HEALTHY request must return a
    verified solution, every POISONED request must fail with a classified
    verdict, and nothing else may fail — blast radius exactly 1 per
    poisoned request.

    Crash accounting (§11): requests that died with the process
    (:class:`ServerClosed`) are NOT containment failures — they are
    counted in their own ``*_crash_lost`` buckets and must be balanced by
    the recovery summary (``SolverServer.recover``) when one is supplied:
    every crash-lost healthy request must come back completed, every
    crash-lost poisoned request must come back with a classified failure.
    Every submitted request lands in exactly one bucket
    (``all_accounted``).
    """
    poison = poisoned_indices(cfg)
    healthy_ok = healthy_failed = healthy_unverified = 0
    poisoned_failed = poisoned_served = 0
    healthy_crash_lost = poisoned_crash_lost = 0
    rescued = 0
    verdict_hist: dict[str, int] = {}
    for i, (_, res) in enumerate(results):
        if isinstance(res, SolveResult):
            if i in poison:
                poisoned_served += 1  # containment HOLE: must stay 0
            elif not (res.stats.converged and res.stats.verified):
                healthy_unverified += 1  # server must never deliver this
            else:
                healthy_ok += 1
                if res.stats.retried:
                    rescued += 1
        elif isinstance(res, ServerClosed):
            # died with the process — the journal, not this run's results,
            # is responsible for these
            if i in poison:
                poisoned_crash_lost += 1
            else:
                healthy_crash_lost += 1
        else:
            verdict = getattr(res, "verdict",
                              getattr(res, "reason", type(res).__name__))
            verdict_hist[verdict] = verdict_hist.get(verdict, 0) + 1
            if i in poison:
                poisoned_failed += 1
            else:
                healthy_failed += 1
    crash_lost = healthy_crash_lost + poisoned_crash_lost
    accounted = (healthy_ok + healthy_failed + healthy_unverified
                 + poisoned_failed + poisoned_served + crash_lost)
    summary = {
        "poisoned": len(poison),
        "poisoned_failed": poisoned_failed,
        "poisoned_served": poisoned_served,
        "poisoned_crash_lost": poisoned_crash_lost,
        "healthy": len(results) - len(poison),
        "healthy_ok": healthy_ok,
        "healthy_failed": healthy_failed,
        "healthy_unverified": healthy_unverified,
        "healthy_crash_lost": healthy_crash_lost,
        "healthy_rescued_by_retry": rescued,
        "crash_lost": crash_lost,
        "resumed_after_recovery": (0 if recovery is None
                                   else int(recovery.get("completed", 0))),
        "all_accounted": accounted == len(results),
        "failure_verdicts": dict(sorted(verdict_hist.items())),
        "goodput_rps": healthy_ok / max(wall_s, 1e-9),
        "fault_every": cfg.chaos_fault_every,
        "poison_fraction": cfg.chaos_poison_fraction,
        # the acceptance criterion as one bool: blast radius == 1 per
        # poisoned request and zero healthy casualties among requests the
        # process lived to answer
        "containment_ok": (
            healthy_failed == 0 and healthy_unverified == 0
            and poisoned_served == 0
            and poisoned_failed == len(poison) - poisoned_crash_lost),
        # the crash ledger balances: nothing was lost, or a recovery pass
        # completed every crash-lost healthy request and classified every
        # crash-lost poisoned one
        "recovery_ok": (crash_lost == 0 or (
            recovery is not None
            and int(recovery.get("completed", 0)) == healthy_crash_lost
            and int(recovery.get("failed", 0)) == poisoned_crash_lost)),
    }
    if recovery is not None:
        summary["recovery"] = {k: v for k, v in recovery.items()
                               if k != "results"}
    return summary


def run_workload(cfg: WorkloadConfig) -> dict:
    """Build, serve and summarize one synthetic workload (sync wrapper)."""
    gauges, requests = build_workload(cfg)
    injector = None
    if cfg.chaos and cfg.chaos_fault_every > 0:
        from repro.serve.chaos import BatchFaultInjector
        injector = BatchFaultInjector(mode=cfg.chaos_fault_mode,
                                      every=cfg.chaos_fault_every)

    async def main():
        server = SolverServer(
            mass=cfg.mass, backend=cfg.backend, ladder=cfg.ladder,
            policy=BatchPolicy(max_wait=cfg.max_wait_s,
                               max_batch=cfg.max_batch),
            maxiter=cfg.maxiter, fault_injector=injector,
            journal_dir=cfg.journal_dir,
            deflation_nev=cfg.deflation_nev,
            deflation_m_max=cfg.deflation_m_max,
            deflation_harvest_tol=cfg.deflation_harvest_tol)
        for gid, u in gauges.items():
            server.register_gauge(gid, u)
        try:
            warmed = (await server.warmup(families=cfg.families)
                      if cfg.warmup else 0)
            results, wall_s = await drive_open_loop(
                server, requests, burst=cfg.burst,
                interarrival_s=cfg.interarrival_s)
            return (results, wall_s, warmed, server.metrics(),
                    server.deflations.bases())
        finally:
            await server.close()

    results, wall_s, warmed, metrics, bases = asyncio.run(main())

    served = [(lat, res) for lat, res in results
              if isinstance(res, SolveResult)]
    lats_ms = sorted(lat * 1e3 for lat, _ in served)
    iters = [res.stats.iterations for _, res in served]
    report = {
        "schema": 1, "bench": "serve",
        "generated_by": "repro.serve.loadgen",
        "lattice": "x".join(str(v) for v in cfg.lattice),
        "mass": cfg.mass, "tol": cfg.tol, "seed": cfg.seed,
        "backend": cfg.backend,
        "n_gauge": cfg.n_gauge,
        "families": [list(f) for f in cfg.families],
        "requests": len(results),
        "served": len(served),
        "failed": len(results) - len(served),
        "burst": cfg.burst, "interarrival_s": cfg.interarrival_s,
        "ladder": list(cfg.ladder), "max_wait_s": cfg.max_wait_s,
        "warmup_compiled": warmed,
        "wall_s": wall_s,
        "requests_per_s": len(served) / max(wall_s, 1e-9),
        "latency_ms": {
            "p50": percentile(lats_ms, 50),
            "p99": percentile(lats_ms, 99),
            "mean": sum(lats_ms) / max(len(lats_ms), 1),
            "max": lats_ms[-1] if lats_ms else float("nan"),
        },
        "iters": {"max": max(iters) if iters else 0,
                  "mean": sum(iters) / max(len(iters), 1)},
        # every SERVED request must be converged AND verified — failures
        # surface as structured exceptions, never as a bad x
        "all_converged": all(res.stats.converged and res.stats.verified
                             for _, res in served),
        # server metrics count ADMITTED requests; the report's "requests"
        # above counts all outcomes including admission rejections
        **{("admitted" if k == "requests" else k): v
           for k, v in metrics.items()},
    }
    if cfg.chaos:
        report["chaos"] = summarize_chaos(cfg, results, wall_s)
    if cfg.deflation_nev > 0:
        report["deflation_drop"] = summarize_deflation(cfg, requests,
                                                       results)
    if cfg.verify:
        report["verify"] = verify_against_direct(gauges, requests, results,
                                                 cfg, deflation_bases=bases)
    return report
