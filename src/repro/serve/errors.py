"""Structured serving errors: every way a request can fail, as a type.

The containment contract of DESIGN.md §10: a request that cannot be
served NEVER hangs its awaiter and never returns an unverified x — it
fails with one of these, each carrying enough structure for the client
to decide retry/reshape/alert without parsing message strings.
"""

from __future__ import annotations

__all__ = ["RequestRejected", "ServerOverloaded", "SolveTimeout",
           "RequestFailed", "ServerClosed"]


class RequestRejected(ValueError):
    """Admission-time rejection: the request was invalid on arrival
    (non-finite RHS, non-finite/non-positive tolerance, bad parameters)
    and never touched a queue."""

    def __init__(self, message: str, *, reason: str = "invalid"):
        super().__init__(message)
        self.reason = reason


class ServerOverloaded(RuntimeError):
    """Backpressure: the request's coalesce-key queue is at its bound.
    The client should back off and retry; nothing was enqueued."""

    def __init__(self, message: str, *, queue_depth: int):
        super().__init__(message)
        self.queue_depth = queue_depth


class SolveTimeout(TimeoutError):
    """The request's deadline expired before its batch dispatched; it was
    dropped WITHOUT consuming a batch slot."""


class RequestFailed(RuntimeError):
    """The solve ran but could not produce a verified solution, even
    after the containment retry.  ``verdict`` is the classified failure
    (a :data:`repro.core.solvers.VERDICTS` name, or ``"error"`` when the
    solve raised instead of returning)."""

    def __init__(self, message: str, *, verdict: str, retried: bool = False):
        super().__init__(message)
        self.verdict = verdict
        self.retried = retried


class ServerClosed(RuntimeError):
    """The server shut down (abort path) before this request completed."""
