"""Write-ahead request journal: the serving half of durability (§11).

The server journals every ADMITTED request before it can touch a queue
and marks it complete when its future resolves — append-only JSONL with
an fsync per event, so a SIGKILL at any instant loses no admitted
request: on restart, :meth:`SolverServer.recover` replays exactly the
entries with no completion mark.

Layout under ``journal_dir``:

* ``journal.jsonl`` — one JSON object per line.  ``{"event": "admit",
  "rid": N, ...request fields...}`` on admission; ``{"event":
  "complete", "rid": N, "status": ...}`` when the request's future
  resolves (result OR classified failure — both are completions; only a
  crash leaves an entry open).
* ``rhs/<rid>.npy`` — the request's right-hand side, written
  tmp+rename+fsync BEFORE its admit line, so an admit record never
  points at a missing or torn array.

Crash tolerance on READ: the journal's last line may be torn (the
process died mid-append); the scanner ignores a trailing line that does
not parse.  Everything earlier was fsync'd line-atomically.
"""

from __future__ import annotations

import json
import os
import tempfile

import numpy as np

__all__ = ["RequestJournal", "scan_journal", "incomplete_requests",
           "load_rhs", "mark_complete"]

_RHS_DIR = "rhs"
_LOG = "journal.jsonl"


def _fsync_dir(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


class RequestJournal:
    """Append-only admit/complete journal for one server process."""

    def __init__(self, journal_dir: str):
        self.dir = str(journal_dir)
        os.makedirs(os.path.join(self.dir, _RHS_DIR), exist_ok=True)
        self._f = open(os.path.join(self.dir, _LOG), "a",
                       encoding="utf-8")

    def _append(self, record: dict) -> None:
        self._f.write(json.dumps(record, sort_keys=True) + "\n")
        self._f.flush()
        os.fsync(self._f.fileno())

    def admit(self, rid: int, *, operator_family: str, gauge_id: str,
              rhs, tol: float, mu: float, mass: float | None,
              deadline_s: float | None) -> None:
        """Durably record one admitted request (RHS first, then the line)."""
        rel = os.path.join(_RHS_DIR, f"{int(rid)}.npy")
        host = np.asarray(rhs)
        fd, tmp = tempfile.mkstemp(dir=os.path.join(self.dir, _RHS_DIR),
                                   prefix=".tmp_", suffix=".npy")
        try:
            with os.fdopen(fd, "wb") as f:
                np.save(f, host)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, os.path.join(self.dir, rel))
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise
        _fsync_dir(os.path.join(self.dir, _RHS_DIR))
        self._append({
            "event": "admit", "rid": int(rid),
            "operator_family": str(operator_family),
            "gauge_id": str(gauge_id), "rhs": rel,
            "tol": float(tol), "mu": float(mu),
            "mass": None if mass is None else float(mass),
            "deadline_s": None if deadline_s is None else float(deadline_s),
        })

    def complete(self, rid: int, status: str) -> None:
        self._append({"event": "complete", "rid": int(rid),
                      "status": str(status)})

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()


def scan_journal(journal_dir: str) -> list[dict]:
    """All parseable events in append order; a torn last line is skipped.

    A torn line ANYWHERE ELSE is corruption, not a crash artifact, and
    raises — fsync-per-line means only the final append can be partial.
    """
    path = os.path.join(journal_dir, _LOG)
    if not os.path.exists(path):
        return []
    with open(path, encoding="utf-8") as f:
        lines = f.read().splitlines()
    events = []
    for i, line in enumerate(lines):
        if not line.strip():
            continue
        try:
            events.append(json.loads(line))
        except ValueError:
            if i == len(lines) - 1:
                break  # torn tail: the append the crash interrupted
            raise IOError(
                f"journal {path} line {i + 1} is corrupt (not the tail)")
    return events


def incomplete_requests(journal_dir: str) -> list[dict]:
    """Admit records with no completion mark — the replay set, in
    admission order."""
    admitted: dict[int, dict] = {}
    for ev in scan_journal(journal_dir):
        if ev.get("event") == "admit":
            admitted[int(ev["rid"])] = ev
        elif ev.get("event") == "complete":
            admitted.pop(int(ev["rid"]), None)
    return [admitted[rid] for rid in sorted(admitted)]


def load_rhs(journal_dir: str, entry: dict) -> np.ndarray:
    """The journaled right-hand side of one admit record."""
    return np.load(os.path.join(journal_dir, entry["rhs"]))


def mark_complete(journal_dir: str, rid: int, status: str) -> None:
    """Append a completion mark from OUTSIDE the owning server — used by
    recovery to retire replayed entries of a dead process's journal."""
    with open(os.path.join(journal_dir, _LOG), "a", encoding="utf-8") as f:
        f.write(json.dumps({"event": "complete", "rid": int(rid),
                            "status": str(status)}, sort_keys=True) + "\n")
        f.flush()
        os.fsync(f.fileno())
