"""Compiled-plan cache: resolved SolverPlan identity → jitted solve callable.

The serving layer's steady-state latency budget has no room for
trace/lower/compile — a 4⁴ smoke solve compiles in seconds and runs in
tens of milliseconds.  This cache keys one jitted solve callable per
(resolved plan, mass, maxiter):

* the PLAN identity (``SolverPlan.cache_key()``) covers every trace-time
  axis — operator family, mu (folded into kernel epilogues at trace
  time), backend, batch rung, precision, kernel knobs;
* ``mass`` is part of the key because the transport kernels fold the site
  scale ``mass + 4r`` at trace time;
* ``maxiter`` bounds the while_loop and is closed over as a Python int;
* the gauge field, RHS batch and per-RHS tolerance vector are RUNTIME
  arguments — two gauge fields of the same lattice shape share one
  compiled callable, and per-request tolerances never force a retrace.

The callable contract is ``fn(u, b, tol) -> (x, SolveStats)`` with ``b``
shaped to the plan's ``nrhs`` rung and ``tol`` a per-RHS (nrhs,) float32
vector (scalar for unbatched plans).  The DEFLATED variant
(:meth:`PlanCache.get_deflated`) additionally takes the harvested basis
as runtime arguments — ``fn(u, b, tol, w, gram)`` — so one compiled
deflated program serves every gauge field and every re-harvested basis
of the same shape.

:class:`DeflationCache` is the companion state cache (DESIGN.md §12):
harvested :class:`~repro.core.solvers.DeflationBasis` objects keyed by
the server's coalesce key, LRU-bounded over gauge ids.  PlanCache holds
CODE (gauge-independent, lives forever); DeflationCache holds DATA about
one specific gauge field (invalidated when the field changes, evicted
when the field goes cold).
"""

from __future__ import annotations

from typing import Callable

import jax

from repro.core import plan as plan_mod
from repro.core import solvers


class PlanCache:
    """In-process compiled-plan cache with hit/miss accounting."""

    def __init__(self):
        self._fns: dict = {}
        self.hits = 0
        self.misses = 0

    @staticmethod
    def key(plan: plan_mod.SolverPlan, mass: float, maxiter: int):
        """The hashable cache identity of a (plan, mass, maxiter) solve."""
        return (plan.cache_key(), float(mass), int(maxiter))

    def get(self, plan: plan_mod.SolverPlan, mass: float,
            maxiter: int) -> tuple[Callable, bool]:
        """The jitted solve callable for a plan; (callable, was_cached).

        A miss builds ``jax.jit(lambda u, b, tol: solve(plan, u, b, mass,
        tol=tol, maxiter=maxiter))`` — compilation itself happens lazily
        on the first call, per operand shape, inside jax's own cache.
        """
        k = self.key(plan, mass, maxiter)
        fn = self._fns.get(k)
        if fn is not None:
            self.hits += 1
            return fn, True
        self.misses += 1
        mass_f, maxiter_i = float(mass), int(maxiter)

        def solve_fn(u, b, tol, _plan=plan):
            return plan_mod.solve(_plan, u, b, mass_f, tol=tol,
                                  maxiter=maxiter_i)

        fn = jax.jit(solve_fn)
        self._fns[k] = fn
        return fn, False

    def get_deflated(self, plan: plan_mod.SolverPlan, mass: float,
                     maxiter: int) -> tuple[Callable, bool]:
        """The jitted DEFLATED solve callable; (callable, was_cached).

        Contract: ``fn(u, b, tol, w, gram) -> (x, SolveStats)`` where
        ``(w, gram)`` are the arrays of a harvested
        :class:`~repro.core.solvers.DeflationBasis` in the plan's working
        layout.  The basis rides as RUNTIME arguments (rebuilt into a
        NamedTuple inside the traced function), so swapping bases —
        another gauge field, a re-harvest after invalidation — never
        retraces as long as ``nev`` matches.  Keyed separately from the
        plain callable of the same plan: the deflated program has a
        different argument signature and an extra projection prologue.
        """
        k = ("deflated",) + self.key(plan, mass, maxiter)
        fn = self._fns.get(k)
        if fn is not None:
            self.hits += 1
            return fn, True
        self.misses += 1
        mass_f, maxiter_i = float(mass), int(maxiter)

        def solve_fn(u, b, tol, w, gram, _plan=plan):
            basis = solvers.DeflationBasis(w=w, gram=gram)
            return plan_mod.solve(_plan, u, b, mass_f, tol=tol,
                                  maxiter=maxiter_i, deflation=basis)

        fn = jax.jit(solve_fn)
        self._fns[k] = fn
        return fn, False

    def __len__(self) -> int:
        return len(self._fns)

    def __contains__(self, key) -> bool:
        return key in self._fns

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def stats(self) -> dict:
        return {"size": len(self), "hits": self.hits,
                "misses": self.misses, "hit_rate": self.hit_rate}


class DeflationCache:
    """Per-gauge-field deflation-basis cache — the solver's KV cache.

    Keys are the server's COALESCE key ``(gauge_id, family, mu, mass)``:
    the exact identity of the Krylov operator whose low modes a harvested
    basis approximates.  A basis is valid for precisely one gauge FIELD,
    so:

    * re-registering a gauge id (new field, old name) must call
      :meth:`invalidate_gauge` — the server does;
    * memory is bounded by LRU eviction over GAUGE IDS, not individual
      keys: a gauge field owns every basis harvested on it (one per
      operator family/mass it served), and when the field goes cold all
      of them go cold together.

    Lookup/store are O(1) dict operations on the event-loop thread; the
    arrays themselves live on device and are only touched by the worker.
    """

    def __init__(self, max_gauges: int = 8):
        if max_gauges < 1:
            raise ValueError(f"max_gauges must be >= 1, got {max_gauges}")
        self.max_gauges = int(max_gauges)
        self._bases: dict[tuple, solvers.DeflationBasis] = {}
        # gauge_id -> None; insertion order IS recency order (py3.7+ dict)
        self._lru: dict[str, None] = {}
        self.hits = 0
        self.misses = 0
        self.harvests = 0
        self.evictions = 0
        self.invalidations = 0

    @staticmethod
    def _gauge_of(key: tuple) -> str:
        return str(key[0])

    def _touch(self, gauge_id: str) -> None:
        self._lru.pop(gauge_id, None)
        self._lru[gauge_id] = None

    def lookup(self, key: tuple) -> solvers.DeflationBasis | None:
        """The basis for a coalesce key, counting hit/miss and touching
        the owning gauge's LRU slot; None on miss."""
        basis = self._bases.get(key)
        if basis is None:
            self.misses += 1
            return None
        self.hits += 1
        self._touch(self._gauge_of(key))
        return basis

    def peek(self, key: tuple) -> solvers.DeflationBasis | None:
        """Lookup without touching counters or recency (harvest guard)."""
        return self._bases.get(key)

    def store(self, key: tuple, basis: solvers.DeflationBasis) -> None:
        """Record a freshly harvested basis, evicting the least-recently
        used gauge's bases if a NEW gauge would exceed ``max_gauges``."""
        gauge_id = self._gauge_of(key)
        if gauge_id not in self._lru and len(self._lru) >= self.max_gauges:
            coldest = next(iter(self._lru))
            self._drop_gauge(coldest)
            self.evictions += 1
        self._bases[key] = basis
        self._touch(gauge_id)
        self.harvests += 1

    def _drop_gauge(self, gauge_id: str) -> int:
        self._lru.pop(gauge_id, None)
        doomed = [k for k in self._bases if self._gauge_of(k) == gauge_id]
        for k in doomed:
            del self._bases[k]
        return len(doomed)

    def invalidate_gauge(self, gauge_id: str) -> int:
        """Drop every basis of one gauge id (the field changed); returns
        the number of bases invalidated."""
        dropped = self._drop_gauge(str(gauge_id))
        self.invalidations += dropped
        return dropped

    def bases(self) -> dict[tuple, solvers.DeflationBasis]:
        """Snapshot of the cached bases (verification oracles re-solve
        deflated responses with the SAME basis the server used)."""
        return dict(self._bases)

    def __len__(self) -> int:
        return len(self._bases)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def stats(self) -> dict:
        return {"size": len(self._bases), "gauges": len(self._lru),
                "hits": self.hits, "misses": self.misses,
                "hit_rate": self.hit_rate, "harvests": self.harvests,
                "evictions": self.evictions,
                "invalidations": self.invalidations}
