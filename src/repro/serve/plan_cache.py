"""Compiled-plan cache: resolved SolverPlan identity → jitted solve callable.

The serving layer's steady-state latency budget has no room for
trace/lower/compile — a 4⁴ smoke solve compiles in seconds and runs in
tens of milliseconds.  This cache keys one jitted solve callable per
(resolved plan, mass, maxiter):

* the PLAN identity (``SolverPlan.cache_key()``) covers every trace-time
  axis — operator family, mu (folded into kernel epilogues at trace
  time), backend, batch rung, precision, kernel knobs;
* ``mass`` is part of the key because the transport kernels fold the site
  scale ``mass + 4r`` at trace time;
* ``maxiter`` bounds the while_loop and is closed over as a Python int;
* the gauge field, RHS batch and per-RHS tolerance vector are RUNTIME
  arguments — two gauge fields of the same lattice shape share one
  compiled callable, and per-request tolerances never force a retrace.

The callable contract is ``fn(u, b, tol) -> (x, SolveStats)`` with ``b``
shaped to the plan's ``nrhs`` rung and ``tol`` a per-RHS (nrhs,) float32
vector (scalar for unbatched plans).
"""

from __future__ import annotations

from typing import Callable

import jax

from repro.core import plan as plan_mod


class PlanCache:
    """In-process compiled-plan cache with hit/miss accounting."""

    def __init__(self):
        self._fns: dict = {}
        self.hits = 0
        self.misses = 0

    @staticmethod
    def key(plan: plan_mod.SolverPlan, mass: float, maxiter: int):
        """The hashable cache identity of a (plan, mass, maxiter) solve."""
        return (plan.cache_key(), float(mass), int(maxiter))

    def get(self, plan: plan_mod.SolverPlan, mass: float,
            maxiter: int) -> tuple[Callable, bool]:
        """The jitted solve callable for a plan; (callable, was_cached).

        A miss builds ``jax.jit(lambda u, b, tol: solve(plan, u, b, mass,
        tol=tol, maxiter=maxiter))`` — compilation itself happens lazily
        on the first call, per operand shape, inside jax's own cache.
        """
        k = self.key(plan, mass, maxiter)
        fn = self._fns.get(k)
        if fn is not None:
            self.hits += 1
            return fn, True
        self.misses += 1
        mass_f, maxiter_i = float(mass), int(maxiter)

        def solve_fn(u, b, tol, _plan=plan):
            return plan_mod.solve(_plan, u, b, mass_f, tol=tol,
                                  maxiter=maxiter_i)

        fn = jax.jit(solve_fn)
        self._fns[k] = fn
        return fn, False

    def __len__(self) -> int:
        return len(self._fns)

    def __contains__(self, key) -> bool:
        return key in self._fns

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def stats(self) -> dict:
        return {"size": len(self), "hits": self.hits,
                "misses": self.misses, "hit_rate": self.hit_rate}
