"""Shape-ladder padding and the batch-formation policy for the solver server.

The serving layer coalesces requests that share a gauge field into the
multi-RHS batched Schur solve (DESIGN.md §6) — but an arbitrary batch size
per dispatch would retrace/recompile the masked CG loop for every new N.
Instead, every dispatched batch is padded UP to a small ladder of
pre-compiled batch shapes (default N ∈ {1, 4, 8, 16}): after each rung has
compiled once, steady state never pays trace/compile again, whatever the
instantaneous queue depth.

Padding is bitwise-safe by construction: a pad slot is an all-zero RHS,
whose convergence limit ``tol² · ‖b‖²`` is exactly 0, so the per-RHS
convergence mask (repro.core.solvers.cg, ``batched=True``) deactivates it
at iteration 0 — its masked ``alpha`` is 0 forever, it contributes nothing
to any other system's ``alpha``/``beta`` (those are per-RHS), and the loop
trip count is decided by the REAL systems only.  A batch of k padded to
rung N therefore returns the first k solutions bitwise identical to the
unpadded k-RHS solve (tested in tests/test_serve.py at every rung).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

Array = jax.Array

DEFAULT_LADDER = (1, 4, 8, 16)


def validate_ladder(ladder: Sequence[int]) -> tuple[int, ...]:
    """Normalize a batch-shape ladder: sorted, unique, positive rungs."""
    rungs = tuple(sorted({int(n) for n in ladder}))
    if not rungs or rungs[0] < 1:
        raise ValueError(f"batch ladder needs positive rungs, got {ladder!r}")
    return rungs


def rung_for(n: int, ladder: Sequence[int]) -> int:
    """The smallest ladder rung that fits an n-request batch."""
    for rung in ladder:
        if n <= rung:
            return rung
    raise ValueError(
        f"batch of {n} exceeds the top ladder rung {ladder[-1]}; the "
        "dispatcher must cap batches at the top rung (BatchPolicy."
        "resolved_max_batch)")


def pad_batch(rhs_list: Sequence[Array], rung: int) -> Array:
    """Stack k right-hand sides and zero-pad the batch axis up to ``rung``.

    The zero pad slots freeze at iteration 0 under the per-RHS convergence
    mask (zero RHS ⇒ zero limit ⇒ inactive), so the real systems solve
    bitwise as if unpadded — see the module docstring.
    """
    b = jnp.stack(list(rhs_list))
    k = b.shape[0]
    if k > rung:
        raise ValueError(f"batch of {k} does not fit rung {rung}")
    if k == rung:
        return b
    pad = jnp.zeros((rung - k,) + b.shape[1:], b.dtype)
    return jnp.concatenate([b, pad])


def pad_tols(tols: Sequence[float], rung: int) -> Array:
    """Per-RHS tolerance vector for a padded batch.

    Pad slots get tol=1.0 — any value works (their limit is 0 regardless,
    since the padded RHS is zero), 1.0 just keeps the vector unsurprising
    in logs.
    """
    if len(tols) > rung:
        raise ValueError(f"{len(tols)} tolerances do not fit rung {rung}")
    vals = [float(t) for t in tols] + [1.0] * (rung - len(tols))
    return jnp.asarray(vals, jnp.float32)


@dataclasses.dataclass(frozen=True)
class BatchPolicy:
    """When a per-gauge-field queue dispatches a batch.

    ``max_wait``: seconds from the FIRST queued request to forced
    dispatch — the anti-starvation deadline.  A lone request is solved at
    most ``max_wait`` after arrival even if the batch never fills.
    ``max_batch``: dispatch immediately once this many requests are
    queued; ``None`` means the top ladder rung (no padding waste at the
    top).
    """

    max_wait: float = 0.05
    max_batch: int | None = None

    def __post_init__(self):
        if self.max_wait < 0:
            raise ValueError(f"max_wait must be >= 0, got {self.max_wait}")
        if self.max_batch is not None and self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")

    def resolved_max_batch(self, ladder: Sequence[int]) -> int:
        """The dispatch cap: never exceed the top ladder rung."""
        top = ladder[-1]
        if self.max_batch is None:
            return top
        return min(self.max_batch, top)
