"""Mixed-precision AdamW, built from scratch.

The paper's two-precision discipline (T1) carried into training:
  * master weights in f32 (the "high" type),
  * compute/gradient dtype bf16 (the "low" type),
  * m/v moments in a configurable dtype — f32 by default, bf16 for the
    340B-class configs where moment storage dominates HBM (the moment
    update still runs in f32 registers; only storage is narrowed).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"   # "bfloat16" to halve optimizer HBM


def adamw_init(params, cfg: AdamWConfig):
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {"step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params)}


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn2 = sum(jnp.sum(g.astype(F32) ** 2) for g in leaves)
    gnorm = jnp.sqrt(gn2)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(F32) * scale).astype(g.dtype),
                        grads), gnorm


def adamw_update(params, grads, opt_state, cfg: AdamWConfig,
                 lr_scale=1.0):
    """One AdamW step. params: f32 master tree; grads: any dtype tree."""
    step = opt_state["step"] + 1
    t = step.astype(F32)
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        g32 = g.astype(F32)
        m32 = cfg.b1 * m.astype(F32) + (1 - cfg.b1) * g32
        v32 = cfg.b2 * v.astype(F32) + (1 - cfg.b2) * g32 * g32
        mhat = m32 / bc1
        vhat = v32 / bc2
        p32 = p.astype(F32)
        upd = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p32
        return (p32 - lr * upd).astype(p.dtype), m32.astype(m.dtype), \
            v32.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, opt_state["m"], opt_state["v"])
    new_params = jax.tree.map(lambda x: x[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda x: x[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda x: x[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"step": step, "m": new_m, "v": new_v}, gnorm
