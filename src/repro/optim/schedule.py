"""Learning-rate schedules (scale factors multiplying AdamWConfig.lr)."""

from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, warmup: int = 100, total: int = 10_000,
                  min_frac: float = 0.1):
    t = step.astype(jnp.float32)
    warm = t / jnp.maximum(warmup, 1)
    prog = jnp.clip((t - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(t < warmup, warm, cos)
