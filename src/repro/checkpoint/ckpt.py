"""Checkpointing: atomic, checksummed, elastic across mesh shapes.

Layout:  <dir>/step_<N>/arrays.npz + manifest.json   (tmp-dir + rename, so
a crash mid-write never corrupts the latest complete checkpoint).

Elasticity: leaves are stored as full (unsharded) host arrays keyed by
tree path; ``restore_checkpoint`` re-shards onto whatever mesh/sharding
the *current* job uses — a checkpoint written on 512 chips restores on
256 (or on CPU) unchanged.  This is the restart half of fault tolerance;
the data pipeline's step-indexed batches are the other half.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile

import jax
import numpy as np

_SEP = "§"


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", "?"))))
            for k in path)
        out[key] = np.asarray(jax.device_get(leaf))
    return out, treedef


def save_checkpoint(ckpt_dir: str, step: int, tree) -> str:
    """Atomically write ``tree`` as step_<step>. Returns the final path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    arrays, _ = _flatten(tree)
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    try:
        npz = os.path.join(tmp, "arrays.npz")
        np.savez(npz, **arrays)
        with open(npz, "rb") as f:
            digest = hashlib.sha256(f.read()).hexdigest()
        manifest = {"step": int(step), "sha256": digest,
                    "keys": sorted(arrays.keys()),
                    "jax_process_count": jax.process_count()}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        final = os.path.join(ckpt_dir, f"step_{int(step):08d}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        return final
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and \
                os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
            steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, step: int, target_tree,
                       shardings=None):
    """Restore into the structure of ``target_tree`` (shapes must match);
    ``shardings`` (same pytree of NamedSharding/None) re-shards elastically
    onto the current mesh."""
    path = os.path.join(ckpt_dir, f"step_{int(step):08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    npz = os.path.join(path, "arrays.npz")
    with open(npz, "rb") as f:
        digest = hashlib.sha256(f.read()).hexdigest()
    if digest != manifest["sha256"]:
        raise IOError(f"checkpoint {path} failed checksum verification")
    data = np.load(npz)
    flat, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
    shard_flat = (jax.tree_util.tree_flatten(shardings)[0]
                  if shardings is not None else [None] * len(flat))
    leaves = []
    for (pth, leaf), shd in zip(flat, shard_flat):
        key = _SEP.join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", "?"))))
            for k in pth)
        arr = data[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        leaves.append(jax.device_put(arr, shd) if shd is not None
                      else jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves)
