"""Checkpointing: atomic, checksummed, elastic across mesh shapes.

Layout:  <dir>/step_<N>/arrays.npz + manifest.json   (tmp-dir + rename, so
a crash mid-write never corrupts the latest complete checkpoint).

Elasticity: leaves are stored as full (unsharded) host arrays keyed by
tree path; ``restore_checkpoint`` re-shards onto whatever mesh/sharding
the *current* job uses — a checkpoint written on 512 chips restores on
256 (or on CPU) unchanged.  This is the restart half of fault tolerance;
the data pipeline's step-indexed batches are the other half.
"""

from __future__ import annotations

import hashlib
import io
import json
import os
import shutil
import sys
import tempfile

import jax
import numpy as np

_SEP = "§"


def _warn(msg: str) -> None:
    print(f"[ckpt] {msg}", file=sys.stderr)


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", "?"))))
            for k in path)
        out[key] = np.asarray(jax.device_get(leaf))
    return out, treedef


def save_checkpoint(ckpt_dir: str, step: int, tree) -> str:
    """Atomically write ``tree`` as step_<step>. Returns the final path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    arrays, _ = _flatten(tree)
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    try:
        npz = os.path.join(tmp, "arrays.npz")
        np.savez(npz, **arrays)
        with open(npz, "rb") as f:
            digest = hashlib.sha256(f.read()).hexdigest()
        manifest = {"step": int(step), "sha256": digest,
                    "keys": sorted(arrays.keys()),
                    "jax_process_count": jax.process_count()}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        final = os.path.join(ckpt_dir, f"step_{int(step):08d}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        return final
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise


def valid_steps(ckpt_dir: str) -> list[int]:
    """Ascending list of step numbers with a COMPLETE ``step_<N>`` dir.

    Complete means the atomic rename landed (manifest.json present) —
    contents may still fail the checksum; :func:`restore_checkpoint`
    verifies that per step.
    """
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and \
                os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
            steps.append(int(name.split("_")[1]))
    return sorted(steps)


def latest_step(ckpt_dir: str) -> int | None:
    steps = valid_steps(ckpt_dir)
    return steps[-1] if steps else None


def prune_checkpoints(ckpt_dir: str, keep: int) -> None:
    """Delete all but the newest ``keep`` complete checkpoints."""
    if keep < 1:
        raise ValueError(f"keep must be >= 1, got {keep}")
    for step in valid_steps(ckpt_dir)[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{step:08d}"),
                      ignore_errors=True)


def _restore_step(ckpt_dir: str, step: int, target_tree, shardings):
    """Restore exactly ``step_<step>``; IOError on any corruption
    (unreadable/tampered manifest, truncated or checksum-failing npz)."""
    path = os.path.join(ckpt_dir, f"step_{int(step):08d}")
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        with open(os.path.join(path, "arrays.npz"), "rb") as f:
            raw = f.read()
    except (OSError, ValueError) as e:
        raise IOError(f"checkpoint {path} is unreadable ({e})") from e
    if not isinstance(manifest, dict) or "sha256" not in manifest:
        raise IOError(f"checkpoint {path} has a tampered manifest")
    if hashlib.sha256(raw).hexdigest() != manifest["sha256"]:
        raise IOError(f"checkpoint {path} failed checksum verification")
    # checksum passed: the bytes are exactly what the writer wrote, so any
    # error past this point is a CALLER mismatch (wrong target tree), not
    # corruption — those raise and never trigger the fallback walk
    data = np.load(io.BytesIO(raw))
    flat, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
    shard_flat = (jax.tree_util.tree_flatten(shardings)[0]
                  if shardings is not None else [None] * len(flat))
    leaves = []
    for (pth, leaf), shd in zip(flat, shard_flat):
        key = _SEP.join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", "?"))))
            for k in pth)
        arr = data[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        leaves.append(jax.device_put(arr, shd) if shd is not None
                      else jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def restore_checkpoint(ckpt_dir: str, step: int, target_tree,
                       shardings=None):
    """Restore into the structure of ``target_tree`` (shapes must match);
    ``shardings`` (same pytree of NamedSharding/None) re-shards elastically
    onto the current mesh.

    Corruption-tolerant: when ``step_<step>`` fails its checksum (or is
    truncated/unreadable), the restore FALLS BACK to the previous complete
    step instead of raising — a crash mid-write or a bad sector costs one
    checkpoint interval, not the whole run.  Raises IOError only when no
    step at or below ``step`` restores cleanly.
    """
    candidates = [s for s in valid_steps(ckpt_dir) if s <= int(step)]
    last_err: IOError | None = None
    for s in sorted(candidates, reverse=True):
        try:
            return _restore_step(ckpt_dir, s, target_tree, shardings)
        except IOError as e:
            last_err = e
            _warn(f"{e}; falling back to the previous complete step")
    if last_err is not None:
        raise last_err
    raise IOError(f"no complete checkpoint at or below step {int(step)} "
                  f"in {ckpt_dir}")


def restore_latest(ckpt_dir: str, target_tree, shardings=None):
    """``(step, tree)`` from the newest checkpoint that restores cleanly.

    Walks complete steps newest-first, skipping any that fail checksum
    verification (with a warning).  Raises FileNotFoundError when the
    directory holds no complete checkpoint at all, IOError when every
    complete checkpoint is corrupt.
    """
    steps = valid_steps(ckpt_dir)
    if not steps:
        raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    last_err: IOError | None = None
    for s in reversed(steps):
        try:
            return s, _restore_step(ckpt_dir, s, target_tree, shardings)
        except IOError as e:
            last_err = e
            _warn(f"{e}; falling back to the previous complete step")
    raise last_err
