from repro.checkpoint.ckpt import (latest_step, prune_checkpoints,
                                   restore_checkpoint, restore_latest,
                                   save_checkpoint, valid_steps)
