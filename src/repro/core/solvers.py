"""Krylov solvers: CG, CGNR, mixed-precision reliable-update CG, pipelined CG,
BiCGStab.

Design notes
------------

* Every solver takes the operator as a *callable* ``op(x) -> Ax`` and the
  inner product as injectable callables ``dot``/``norm2``.  This is what
  makes the same solver run (a) single-device, (b) inside ``shard_map``
  where vectors are local shards and the injected ``dot`` performs the
  ``psum`` — the paper's "global communications ... for total error
  estimates" become a single fused collective per iteration.

* The per-iteration VECTOR algebra is injectable too: ``cg``/``cg_trace``
  accept ``update(alpha, x, r, p, ap) -> (x', r', ||r'||²)`` and
  ``xpay(beta, r, p) -> p'`` callables (see DESIGN.md, "fused-engine
  contract").  The defaults are the plain jnp expressions; passing
  :func:`repro.kernels.cg_fused.fused_engine`'s pair swaps in the Pallas
  streaming kernels, and the iteration's vector traffic drops from seven
  reads + three writes of HBM to one 4-read/2-write triad kernel plus one
  2-read/1-write direction kernel — the TPU analogue of the FPGA paper
  hiding all vector updates inside the streaming pipeline.

* ``mpcg`` is the paper's central algorithmic feature (its Ref. [10],
  Strzodka–Göddeke): run bulk CG iterations in a *low*-precision type and
  periodically recompute the true residual / accumulate the solution in a
  *high*-precision type ("reliable update" / defect correction).

* ``pipecg`` (Ghysels–Vanroose) restructures CG so each iteration has ONE
  fused reduction, issued alongside the matvec — the cluster-scale
  analogue of the paper's transfer/compute overlap (T4 in DESIGN.md).

* ``cg``/``cg_trace`` (and the cgnr/cgnr_eo/mpcg/mpcg_eo forwarders) are
  **multi-RHS batched** behind ``batched=True``: operands carry a leading
  RHS-batch axis, ``dot``/``norm2`` return per-RHS (N,) scalars, and every
  iteration applies per-RHS ``alpha``/``beta`` under a convergence MASK —
  a converged system's ``alpha`` is forced to 0 (its x/r stay bitwise
  frozen) and its direction update is gated off, so one slow system never
  perturbs the already-converged ones.  The loop runs until every RHS
  meets its own relative tolerance (see DESIGN.md §6).

* All solvers are ``lax.while_loop`` based and fully jittable.
"""

from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.lattice import (field_dot, field_dot_batched, field_norm2,
                                field_norm2_batched)

Array = jax.Array
Op = Callable[[Array], Array]


class SolveStats(NamedTuple):
    iterations: Array          # total (inner) iterations executed
    outer_iterations: Array    # outer/reliable-update cycles (1 for plain CG)
    residual_norm2: Array      # final TRUE residual squared (high precision)
    converged: Array           # bool; per-RHS (N,) for batched solves
    # per-RHS (N,) iteration counts for batched solves: the step at which
    # each system's convergence mask froze it (``iterations`` is the
    # slowest system's count = the masked loop's trip count).  None for
    # unbatched solves, so the pytree structure of legacy stats (and the
    # shard_map out_specs built from them) is unchanged.
    rhs_iterations: Array | None = None
    # failure-taxonomy verdict code (int32; per-RHS (N,) for batched
    # solves): an index into ``VERDICTS``.  Computed from loop-exit state
    # only — no host syncs and no extra device work inside the iteration
    # body.  ``converged`` stays the raw ``rs <= limit`` bool; ``verdict``
    # is the classified WHY when it is False.
    verdict: Array | None = None
    # filled by plan.solve's post-solve verification matvec (None straight
    # out of a raw solver): the squared TRUE residual ``‖b - A x‖²``
    # recomputed through the operator registry, and whether it meets the
    # verification gate.  See plan._attach_verification.
    true_residual_norm2: Array | None = None
    verified: Array | None = None
    # exact count of ITERATION-OPERATOR applications (int32; per-RHS (N,)
    # for batched solves): one "matvec" is one application of the Krylov
    # operator the solver iterates with — for CGNR paths the normal
    # operator D†D / D̂†D̂ counts as ONE matvec (the paper's per-iteration
    # cost unit).  Counts the loop body's applications plus any x0-seeded
    # initial residual and pipecg's init/replacement applications; RHS
    # preparation (D†b) and the post-solve verification D-application are
    # epilogue/prologue work in a different unit and are NOT counted.  In
    # a batched solve every lane rides every block matvec, so per-RHS
    # matvecs equal the loop trip count (a frozen lane still streams
    # through the operator — this is physical work, which is exactly what
    # block CG and deflation reduce).  Derived from loop-exit counters
    # only: the hot body and its carry are untouched.
    matvecs: Array | None = None


# ---------------------------------------------------------------------------
# Failure taxonomy
# ---------------------------------------------------------------------------
#
# Every solver classifies its exit into one of these verdicts, carried on
# ``SolveStats.verdict`` as an int32 code (per-RHS for batched solves).
# Classification reads only loop-exit carries — breakdown/stagnation flags
# are accumulated as cheap scalar lanes inside the while-loop carry, so the
# hot iteration body gains zero host syncs and zero extra field passes.

CONVERGED, MAXITER_EXHAUSTED, BREAKDOWN, STAGNATION, NONFINITE = range(5)
VERDICTS = ("converged", "maxiter_exhausted", "breakdown", "stagnation",
            "nonfinite")

# a solve is "stagnant" when ‖r‖² fails to shrink by STAGNATION_FACTOR over
# the last STAGNATION_WINDOW iterations (healthy CG on these operators
# contracts far faster; see DESIGN.md §10)
STAGNATION_WINDOW = 25
STAGNATION_FACTOR = 0.5


def verdict_name(code) -> str:
    """Host-side: map a verdict code (python int / 0-d array) to its name."""
    return VERDICTS[int(code)]


# ---------------------------------------------------------------------------
# Loop decomposition — the segmented-solving contract (DESIGN.md §11)
# ---------------------------------------------------------------------------


class LoopParts(NamedTuple):
    """A solver's ``lax.while_loop`` decomposed into reusable pieces.

    ``cg``/``pipecg``/``mpcg`` are each exactly
    ``finish(lax.while_loop(cond, body, init))`` over their parts — and a
    SEGMENTED runner may instead iterate
    ``while_loop(lambda c: cond(c) & (counter(c) < stop), body, carry)``
    in bounded chunks, snapshotting the carry between chunks.  Because
    both spellings close over the SAME ``body`` function with the same
    carry avals, the while-loop body jaxpr is bitwise identical — the
    durability layer (plan.CheckpointPolicy) never touches the hot loop,
    only the stopping condition.  Asserted in tests/test_checkpoint_resume.
    """

    init: tuple                 # initial carry (concrete arrays)
    cond: Callable              # carry -> bool   (the solver's own test)
    body: Callable              # carry -> carry  (the hot loop, untouched)
    finish: Callable            # carry -> (x, SolveStats)
    counter: Callable           # carry -> int32 iteration count


def segment_cond(parts: LoopParts) -> Callable:
    """The segmented stopping rule: the solver's own ``cond`` AND an
    iteration bound ``counter(carry) < stop`` (``stop`` traced, so one
    compiled segment program serves every segment)."""

    def cond(carry, stop):
        return jnp.logical_and(parts.cond(carry),
                               parts.counter(carry) < stop)

    return cond


def classify(rs: Array, limit: Array, broken=False, stalled=False) -> Array:
    """Classify a solver exit from its final ``‖r‖²`` and failure flags.

    Precedence (most → least specific): converged, breakdown, nonfinite,
    stagnation, maxiter_exhausted.  NaN comparisons are False, so a
    non-finite residual never classifies as converged.
    """
    rs = jnp.asarray(rs)
    v = jnp.where(jnp.asarray(stalled), STAGNATION, MAXITER_EXHAUSTED)
    v = jnp.where(~jnp.isfinite(rs), NONFINITE, v)
    v = jnp.where(jnp.asarray(broken), BREAKDOWN, v)
    v = jnp.where(rs <= limit, CONVERGED, v)
    return jnp.broadcast_to(v, rs.shape).astype(jnp.int32)


def _real(x):
    return jnp.real(x) if jnp.iscomplexobj(x) else x


def _bcast(s: Array, field: Array) -> Array:
    """Broadcast per-RHS (N,) scalars over a batched field's site axes."""
    return s.reshape(s.shape + (1,) * (field.ndim - 1))


def _batched_defaults(dot, norm2):
    """Swap the unbatched default reductions for their per-RHS versions."""
    if dot is field_dot:
        dot = field_dot_batched
    if norm2 is field_norm2:
        norm2 = field_norm2_batched
    return dot, norm2


# the engine's in-stream norm can be trusted when norm2 is a known default
_DEFAULT_NORM2 = (field_norm2, field_norm2_batched)


def _stop_limit(tol, bs: Array, batched: bool) -> Array:
    """The stopping limit ``tol² · ‖b‖²`` (per-RHS when batched).

    ``tol`` may be a scalar or — for batched solves — a per-RHS (N,)
    vector: each system then stops against ITS OWN tolerance inside one
    masked loop.  This is what lets a serving layer coalesce requests
    with different tolerances into a single batch (the tolerance is a
    runtime argument, not a trace-time constant).  A non-scalar ``tol``
    on an unbatched solve is rejected loudly.
    """
    tol = jnp.asarray(tol)
    if tol.ndim > (1 if batched else 0):
        raise ValueError(
            "tol must be a scalar"
            + (" or a per-RHS (N,) vector" if batched else "")
            + f" ({'' if batched else 'batched=False; '}got shape "
            f"{tol.shape})")
    return (tol.astype(bs.dtype) ** 2) * bs


# ---------------------------------------------------------------------------
# Conjugate Gradient (HPD operator)
# ---------------------------------------------------------------------------

def cg_parts(op: Op, b: Array, x0: Array | None = None, *,
             tol: float = 1e-8, maxiter: int = 1000,
             dot=field_dot, norm2=field_norm2,
             update=None, xpay=None, batched: bool = False) -> LoopParts:
    """:func:`cg` decomposed into :class:`LoopParts` (same arguments).

    ``cg(...)`` is exactly ``parts.finish(while_loop(parts.cond,
    parts.body, parts.init))`` over these parts; a segmented runner reuses
    the identical ``body`` (see :class:`LoopParts`)."""
    if batched:
        dot, norm2 = _batched_defaults(dot, norm2)
    x = jnp.zeros_like(b) if x0 is None else x0
    r = b - op(x) if x0 is not None else b
    p = r
    rs = _real(norm2(r))
    bs = _real(norm2(b))
    limit = _stop_limit(tol, bs, batched)

    def cond(carry):
        k, rs = carry[0], carry[4]
        broken = carry[6] if batched else carry[5]
        # a broken-down system cannot progress: drop it from the loop's
        # liveness test so one poisoned RHS never burns maxiter for the
        # batch (classified BREAKDOWN at exit).  NaN rs compares False, so
        # non-finite systems go inactive here with no extra checks.
        alive = jnp.logical_and(rs > limit, jnp.logical_not(broken))
        return jnp.logical_and(k < maxiter, jnp.any(alive))

    def body(carry):
        k, x, r, p, rs = carry[:5]
        if batched:
            it, broken, rs_mark = carry[5:8]
        else:
            broken, rs_mark = carry[5:7]
        # stagnation watermark: snapshot ‖r‖² every STAGNATION_WINDOW
        # iterations; exit-time classification compares against it
        rs_mark = jnp.where(k % STAGNATION_WINDOW == 0, rs, rs_mark)
        ap = op(p)
        pap = _real(dot(p, ap))
        if batched:
            active = jnp.logical_and(rs > limit, jnp.logical_not(broken))
            # alpha = 0 both for frozen systems AND on p·Ap breakdown: a
            # masked batch must skip the update (matching cg_trace's
            # convention), and the breakdown flag both stops the loop for
            # that system and classifies its exit
            safe = jnp.logical_and(active, pap != 0)
            broken = jnp.logical_or(broken,
                                    jnp.logical_and(active, pap == 0))
            alpha = jnp.where(safe, rs / jnp.where(pap == 0, 1.0, pap), 0.0)
        else:
            # guarded division: on p·Ap breakdown the iterate stays finite
            # and the loop exits with verdict=BREAKDOWN instead of flooding
            # x with inf/NaN (bitwise rs/pap whenever pap != 0)
            safe = pap != 0
            broken = jnp.logical_or(broken, pap == 0)
            alpha = jnp.where(safe, rs / jnp.where(safe, pap, 1.0), 0.0)
        if update is None:
            a = (_bcast(alpha, b) if batched else alpha).astype(b.dtype)
            x = x + a * p
            r = r - a * ap
            rs_new = _real(norm2(r))
        else:
            x, r, rs_new = update(alpha, x, r, p, ap)
            if norm2 not in _DEFAULT_NORM2:  # don't bypass an injected reduction
                rs_new = _real(norm2(r))
        beta = rs_new / (jnp.where(rs == 0, 1.0, rs) if batched else rs)
        if xpay is None:
            bb = (_bcast(beta, b) if batched else beta).astype(b.dtype)
            p_new = r + bb * p
            p = jnp.where(_bcast(safe, b), p_new, p) if batched else p_new
        else:
            p = xpay(beta, r, p, safe) if batched else xpay(beta, r, p)
        if batched:
            # per-RHS trip counts: a system still active this step ran it
            it = jnp.where(active, k + 1, it)
            return (k + 1, x, r, p, rs_new, it, broken, rs_mark)
        return (k + 1, x, r, p, rs_new, broken, rs_mark)

    init = (jnp.asarray(0, jnp.int32), x, r, p, rs)
    if batched:
        init = init + (jnp.zeros_like(rs, jnp.int32),)
    init = init + (jnp.zeros(rs.shape, bool), rs)

    # the x0 branch of the prologue applied op once for the initial residual
    init_mv = jnp.asarray(0 if x0 is None else 1, jnp.int32)

    def finish(out):
        k, x, r, p, rs = out[:5]
        broken, rs_mark = out[-2:]
        # exit-time stagnation test: ran past a full window yet ‖r‖² failed
        # to contract by STAGNATION_FACTOR since the last watermark
        stalled = jnp.logical_and(k >= STAGNATION_WINDOW,
                                  rs > STAGNATION_FACTOR * rs_mark)
        stats = SolveStats(iterations=k,
                           outer_iterations=jnp.asarray(1, jnp.int32),
                           residual_norm2=rs, converged=rs <= limit,
                           rhs_iterations=out[5] if batched else None,
                           verdict=classify(rs, limit, broken, stalled),
                           matvecs=jnp.broadcast_to(k + init_mv, rs.shape))
        return x, stats

    return LoopParts(init=init, cond=cond, body=body, finish=finish,
                     counter=lambda c: c[0])


def cg(op: Op, b: Array, x0: Array | None = None, *,
       tol: float = 1e-8, maxiter: int = 1000,
       dot=field_dot, norm2=field_norm2,
       update=None, xpay=None, batched: bool = False,
       ) -> tuple[Array, SolveStats]:
    """Standard conjugate gradient for a Hermitian positive-definite ``op``.

    Stops when ``||r||^2 <= tol^2 * ||b||^2`` or at ``maxiter``.

    ``update``/``xpay`` inject the iteration's vector algebra (the fused
    vector engine; see the module docstring).  ``update`` must return the
    residual norm it computed alongside the updated ``x``/``r`` so no
    separate ``norm2`` pass over ``r`` is needed.  When a NON-default
    ``norm2`` is also injected (e.g. a psum-ing distributed reduction),
    the engine's locally-reduced norm cannot be trusted and ``norm2(r)``
    is recomputed instead — a distributed fused engine should fold the
    collective into ``update`` itself and leave ``norm2`` for the
    initial residual only.

    ``batched=True``: ``b`` (and ``op``'s in/out) carry a leading RHS-batch
    axis; each system stops against ITS OWN ``tol² ||b_n||²`` through the
    convergence mask — and ``tol`` itself may be a per-RHS (N,) vector
    (see ``_stop_limit``), so systems with different target tolerances
    share one masked loop — a converged system's ``alpha`` is masked to 0 (so
    ``x_n``/``r_n`` freeze bitwise, even inside an injected engine) and
    its direction update is gated off; the loop runs while ANY system is
    active.  Default ``dot``/``norm2`` swap to their per-RHS versions; an
    injected engine must follow the batched contract (per-RHS ``rs`` from
    ``update``, gate argument on ``xpay``; see DESIGN.md §6).
    """
    parts = cg_parts(op, b, x0, tol=tol, maxiter=maxiter, dot=dot,
                     norm2=norm2, update=update, xpay=xpay, batched=batched)
    return parts.finish(jax.lax.while_loop(parts.cond, parts.body,
                                           parts.init))


def cg_trace(op: Op, b: Array, *, iters: int,
             dot=field_dot, norm2=field_norm2,
             update=None, xpay=None, batched: bool = False,
             tol: float | None = None) -> tuple[Array, Array]:
    """CG for a fixed number of iterations, recording ||r||^2 per iteration.

    Used by convergence benchmarks (paper §2/§3.2 mixed-precision study);
    ``lax.scan`` based so the whole history lowers to one XLA program.
    ``update``/``xpay`` inject the fused vector engine exactly as in
    :func:`cg`.

    ``batched=True`` records a per-RHS history of shape (iters, N); when
    ``tol`` is also given, the convergence mask of :func:`cg` applies and
    a converged system's history entries stay flat at their frozen value —
    the mask-freeze property the batched tests assert on.  ``tol`` is a
    masking knob of the batched mode only (a fixed-iteration single-RHS
    trace has nothing to mask) and is rejected without ``batched=True``.
    """
    if tol is not None and not batched:
        raise ValueError("cg_trace: tol enables the per-RHS convergence "
                         "mask and requires batched=True")
    if batched:
        dot, norm2 = _batched_defaults(dot, norm2)
    x = jnp.zeros_like(b)
    r = b
    p = r
    rs = _real(norm2(r))
    limit = (None if tol is None
             else _stop_limit(tol, _real(norm2(b)), batched))

    def step(carry, _):
        x, r, p, rs = carry
        ap = op(p)
        pap = _real(dot(p, ap))
        safe = pap != 0
        alpha = jnp.where(safe, rs / jnp.where(safe, pap, 1.0), 0.0)
        if batched and limit is not None:
            active = rs > limit
            alpha = jnp.where(active, alpha, 0.0)
        else:
            active = None
        if update is None:
            a = (_bcast(alpha, b) if batched else alpha).astype(b.dtype)
            x = x + a * p
            r = r - a * ap
            rs_new = _real(norm2(r))
        else:
            x, r, rs_new = update(alpha, x, r, p, ap)
            if norm2 not in _DEFAULT_NORM2:  # don't bypass an injected reduction
                rs_new = _real(norm2(r))
        beta = jnp.where(rs > 0, rs_new / jnp.where(rs > 0, rs, 1.0), 0.0)
        if xpay is None:
            bb = (_bcast(beta, b) if batched else beta).astype(b.dtype)
            p_new = r + bb * p
            p = (jnp.where(_bcast(active, b), p_new, p)
                 if active is not None else p_new)
        elif batched:
            gate = active if active is not None else jnp.ones_like(rs, bool)
            p = xpay(beta, r, p, gate)
        else:
            p = xpay(beta, r, p)
        return (x, r, p, rs_new), rs_new

    (x, r, p, rs), hist = jax.lax.scan(step, (x, r, p, rs), None, length=iters)
    return x, hist


# ---------------------------------------------------------------------------
# CGNR — CG on the normal equations (the paper's solver for Dirac-Wilson)
# ---------------------------------------------------------------------------

def cgnr(d_op: Op, d_dag_op: Op, b: Array, **kw) -> tuple[Array, SolveStats]:
    """Solve D x = b for non-Hermitian D via D^dag D x = D^dag b.

    Keyword arguments (including ``update``/``xpay``/``batched``) forward
    to :func:`cg`; for a batched solve the operators must accept the
    leading RHS-batch axis.
    """
    return cg(lambda v: d_dag_op(d_op(v)), d_dag_op(b), **kw)


# ---------------------------------------------------------------------------
# Even-odd (Schur) preconditioned CGNR
# ---------------------------------------------------------------------------
#
# For a parity-blocked operator  D = [[M_ee, D_eo], [D_oe, M_oo]]  (the
# Wilson hopping term only couples opposite parities), eliminating the odd
# block of ``D x = b`` leaves the half-size Schur system
#
#     D_hat x_e = b_hat,    D_hat = M_ee - D_eo M_oo^{-1} D_oe
#                           b_hat = b_e  - D_eo M_oo^{-1} b_o
#
# and the odd solution follows by back-substitution
#
#     x_o = M_oo^{-1} (b_o - D_oe x_e).
#
# ``D_hat`` inherits gamma5-hermiticity from D (see repro.core.wilson), so
# CGNR applies unchanged: CG on ``D_hat^dag D_hat x_e = D_hat^dag b_hat``.
# All vectors are half the full-lattice size and the reduced spectrum is
# better conditioned — empirically ~2x fewer iterations at equal tolerance.
# The solvers below stay operator-agnostic: they take the blocks as
# callables, so the same code runs single-device or inside ``shard_map``
# with psum-ing ``dot``/``norm2`` injected, exactly like ``cg``.


def cgnr_eo(dhat: Op, dhat_dag: Op, d_eo: Op, d_oe: Op, m_inv: Op,
            b_e: Array, b_o: Array, x0: Array | None = None, *,
            tol: float = 1e-8, maxiter: int = 1000, dot=field_dot,
            norm2=field_norm2, update=None, xpay=None,
            batched: bool = False,
            ) -> tuple[tuple[Array, Array], SolveStats]:
    """Even-odd Schur-preconditioned CGNR.

    Args:
      dhat, dhat_dag: the Schur operator D_hat and its adjoint on
        even-parity half fields.
      d_eo, d_oe:     the parity-changing hopping blocks.
      m_inv:          applies M_oo^{-1} (for Wilson: scale by 1/(m+4r)).
      b_e, b_o:       the RHS split by parity; a leading RHS-batch axis on
        both (with ``batched=True`` and batch-capable operator blocks)
        solves all N systems in one masked CG loop.
      x0:             optional even-parity initial guess for the Schur
        normal system (deflation projects the RHS into one; see
        :func:`deflate_x0`).  ``None`` keeps the zero-start fast path.
      update, xpay:   optional fused vector engine, forwarded to :func:`cg`.
    Returns:
      ((x_e, x_o), SolveStats) — merge with ``lattice.merge_eo`` for the
      full-lattice solution.  ``iterations`` counts the half-size CG steps.
    """
    b_hat = b_e - d_eo(m_inv(b_o))
    x_e, stats = cg(lambda v: dhat_dag(dhat(v)), dhat_dag(b_hat), x0,
                    tol=tol, maxiter=maxiter, dot=dot, norm2=norm2,
                    update=update, xpay=xpay, batched=batched)
    x_o = m_inv(b_o - d_oe(x_e))
    return (x_e, x_o), stats


def mpcg_eo(a_low: Op, a_high: Op, dhat_dag: Op, d_eo: Op, d_oe: Op,
            m_inv: Op, b_e: Array, b_o: Array, *,
            tol: float = 1e-6, inner_tol: float = 5e-2,
            inner_maxiter: int = 200, max_outer: int = 50,
            low_dtype=jnp.bfloat16, to_low=None, to_high=None,
            dot=field_dot, norm2=field_norm2, update=None, xpay=None,
            batched: bool = False,
            ) -> tuple[tuple[Array, Array], SolveStats]:
    """Even-odd reduction composed with mixed-precision reliable-update CG.

    The paper's two central optimizations finally compose: the half-size
    Schur normal system is solved by ``mpcg`` (bulk iterations through
    ``a_low``, the low-precision D_hat^dag D_hat; true residuals through
    ``a_high``), then the odd sites are back-substituted in high precision.
    ``to_low``/``to_high`` convert iterates between representations (see
    ``mpcg``); complex half fields use the real-pair view helpers in
    :mod:`repro.core.lattice` since complex bf16 does not exist.
    """
    b_hat = b_e - d_eo(m_inv(b_o))
    x_e, stats = mpcg(a_low, a_high, dhat_dag(b_hat), tol=tol,
                      inner_tol=inner_tol, inner_maxiter=inner_maxiter,
                      max_outer=max_outer, low_dtype=low_dtype,
                      to_low=to_low, to_high=to_high, dot=dot, norm2=norm2,
                      update=update, xpay=xpay, batched=batched)
    x_o = m_inv(b_o - d_oe(x_e))
    return (x_e, x_o), stats


# ---------------------------------------------------------------------------
# Mixed-precision reliable-update CG  (the paper's Ref. [10] variant)
# ---------------------------------------------------------------------------

def mpcg_parts(op_low: Op, op_high: Op, b: Array, *,
               tol: float = 1e-6, inner_tol: float = 5e-2,
               inner_maxiter: int = 200, max_outer: int = 50,
               low_dtype=jnp.bfloat16, to_low=None, to_high=None,
               dot=field_dot, norm2=field_norm2,
               update=None, xpay=None, batched: bool = False) -> LoopParts:
    """:func:`mpcg` decomposed into :class:`LoopParts` (same arguments).

    The loop is the OUTER reliable-update cycle, so ``counter`` reads the
    accumulated INNER iteration total (carry slot 1): a segmented runner
    snapshots at reliable-update boundaries — exactly where the true
    residual was just recomputed in high precision — and a segment may
    overshoot its ``stop`` by at most one inner solve.

    Each outer cycle solves ``A d = r`` approximately in low precision
    (relative tolerance ``inner_tol``), then updates ``x += d`` and
    recomputes the TRUE residual ``r = b - A x`` in high precision.
    Equivalent to defect correction / iterative refinement with a CG
    inner solver; converges to the high-precision tolerance while doing
    most arithmetic in the cheap type.

    ``to_low``/``to_high`` convert a vector between the high- and
    low-precision REPRESENTATIONS and default to plain dtype casts.
    Inject them when the representations differ structurally — e.g.
    complex64 fields stored as bf16 real pairs (complex bf16 does not
    exist); ``op_low`` then operates on the low representation.

    ``batched=True``: per-RHS outer residuals; the outer loop (and each
    masked inner solve) runs until every RHS meets the tolerance.  A
    converged system enters the next inner solve with a ZEROED low
    residual, so the inner mask deactivates it at iteration 0 (zero RHS
    ⇒ zero limit), its correction comes back exactly 0, and its solution
    stops moving — without this, the RELATIVE ``inner_tol`` would keep
    iterating on a converged system's noise floor every remaining cycle.
    The reliable update itself is not masked: recomputing an
    already-converged true residual is harmless.
    """
    if batched:
        dot, norm2 = _batched_defaults(dot, norm2)
    high = b.dtype
    if to_low is None:
        to_low = lambda v: v.astype(low_dtype)
    if to_high is None:
        to_high = lambda v: v.astype(high)
    bs = _real(norm2(b))
    limit = _stop_limit(tol, bs, batched)

    def cond(carry):
        outer, rs = carry[0], carry[4]
        broken = carry[-2]
        # drop broken-down systems from the liveness test (see cg.cond);
        # a non-finite reliable-update rs compares False and goes inactive
        # here — this IS the "non-finite detection at reliable-update
        # boundaries" point: no checks inside the inner iteration body
        alive = jnp.logical_and(rs > limit, jnp.logical_not(broken))
        return jnp.logical_and(outer < max_outer, jnp.any(alive))

    def body(carry):
        outer, inner_total, x, r, rs = carry[:5]
        broken, rs_mark = carry[-2:]
        rs_mark = rs  # previous outer cycle's true ‖r‖², for stagnation
        rhs = r
        if batched:  # freeze converged systems: zero RHS -> inactive inner CG
            rhs = jnp.where(_bcast(rs > limit, r), r, jnp.zeros_like(r))
        r_low = to_low(rhs)
        d, st = cg(op_low, r_low, tol=inner_tol, maxiter=inner_maxiter,
                   dot=dot, norm2=norm2, update=update, xpay=xpay,
                   batched=batched)
        broken = jnp.logical_or(broken, st.verdict == BREAKDOWN)
        x = x + to_high(d)
        r = b - op_high(x)                     # reliable update (true residual)
        rs = _real(norm2(r))
        out = (outer + 1, inner_total + st.iterations, x, r, rs)
        if batched:  # per-RHS inner-iteration totals across outer cycles
            out = out + (carry[5] + st.rhs_iterations,)
        return out + (broken, rs_mark)

    init = (jnp.asarray(0, jnp.int32), jnp.asarray(0, jnp.int32),
            jnp.zeros_like(b), b, bs)
    if batched:
        init = init + (jnp.zeros_like(bs, jnp.int32),)
    init = init + (jnp.zeros(bs.shape, bool), bs)

    def finish(out):
        outer, inner_total, x, r, rs = out[:5]
        broken, rs_mark = out[-2:]
        # outer-cycle stagnation: a reliable update that failed to contract
        # the true residual by STAGNATION_FACTOR over the last cycle
        stalled = jnp.logical_and(outer >= 2,
                                  rs > STAGNATION_FACTOR * rs_mark)
        # each outer cycle: the inner CG's per-iteration op_low applications
        # (= inner_total) plus ONE op_high reliable-update application
        stats = SolveStats(iterations=inner_total, outer_iterations=outer,
                           residual_norm2=rs, converged=rs <= limit,
                           rhs_iterations=out[5] if batched else None,
                           verdict=classify(rs, limit, broken, stalled),
                           matvecs=jnp.broadcast_to(inner_total + outer,
                                                    rs.shape))
        return x, stats

    return LoopParts(init=init, cond=cond, body=body, finish=finish,
                     counter=lambda c: c[1])


def mpcg(op_low: Op, op_high: Op, b: Array, *,
         tol: float = 1e-6, inner_tol: float = 5e-2,
         inner_maxiter: int = 200, max_outer: int = 50,
         low_dtype=jnp.bfloat16, to_low=None, to_high=None,
         dot=field_dot, norm2=field_norm2,
         update=None, xpay=None,
         batched: bool = False) -> tuple[Array, SolveStats]:
    """Two-precision CG: bulk iterations in ``low_dtype``, corrected by
    high-precision true-residual "reliable updates".

    See :func:`mpcg_parts` for the full algorithm notes; this is exactly
    its parts run to completion in one ``lax.while_loop``.
    """
    parts = mpcg_parts(op_low, op_high, b, tol=tol, inner_tol=inner_tol,
                       inner_maxiter=inner_maxiter, max_outer=max_outer,
                       low_dtype=low_dtype, to_low=to_low, to_high=to_high,
                       dot=dot, norm2=norm2, update=update, xpay=xpay,
                       batched=batched)
    return parts.finish(jax.lax.while_loop(parts.cond, parts.body,
                                           parts.init))


# ---------------------------------------------------------------------------
# Pipelined CG — one fused reduction per iteration (Ghysels–Vanroose)
# ---------------------------------------------------------------------------

def pipecg_parts(op: Op, b: Array, *, tol: float = 1e-8,
                 maxiter: int = 1000, residual_replacement_every: int = 25,
                 dot=field_dot, norm2=field_norm2, fused_dots=None,
                 batched: bool = False) -> LoopParts:
    """:func:`pipecg` decomposed into :class:`LoopParts` (same arguments).

    Pipelined CG: the two inner products of an iteration are fused into a
    single reduction which the scheduler can overlap with the matvec
    ``A w`` — per-iteration collective count drops from 2-3 to 1.

    Pipelined CG's three-term recurrences drift in floating point, so every
    ``residual_replacement_every`` iterations the TRUE residual
    ``r = b - A x`` is recomputed and the recurrences restarted — the same
    reliable-update idea the paper applies across precisions (Ref. [10]),
    applied here across recurrence drift.  Set 0 to disable.

    ``fused_dots(r, w) -> (gamma, delta)`` injects the iteration's single
    reduction (``gamma = (r, r)``, ``delta = (w, r)``).  The default
    composes the injected ``norm2``/``dot``; a distributed implementation
    should stack both local partials and issue ONE ``psum`` — see
    :func:`repro.core.distributed.make_fused_psum_dots` — making this the
    only collective per iteration.

    ``batched=True`` follows the masked multi-RHS contract of :func:`cg`:
    per-RHS ``gamma``/``delta``/``alpha``/``beta`` of shape (N,), a
    converged system's ``alpha`` masked to 0 (x/r/w freeze) and its
    z/q/p recurrences gated off, the loop running until every RHS meets
    its own relative tolerance.  The residual replacement stays global
    (recomputing a converged system's true residual is harmless).
    """
    if batched:
        dot, norm2 = _batched_defaults(dot, norm2)
    x = jnp.zeros_like(b)
    r = b
    w = op(r)
    dt = b.dtype
    rr = int(residual_replacement_every)

    if fused_dots is None:
        # fused reduction: computed together so a distributed implementation
        # can batch both into one collective.
        def fused_dots(r, w):
            return _real(norm2(r)), _real(dot(w, r))

    gamma, delta = fused_dots(r, w)
    bs = _real(norm2(b))
    limit = _stop_limit(tol, bs, batched)

    zero = jnp.zeros_like(b)
    init = (jnp.asarray(0, jnp.int32), x, r, w, zero, zero, zero,
            gamma, delta, jnp.ones_like(gamma),
            jnp.zeros_like(gamma), jnp.asarray(True))
    if batched:
        init = init + (jnp.zeros_like(gamma, jnp.int32),)
    init = init + (jnp.zeros(gamma.shape, bool),)

    def cond(c):
        k, gamma, broken = c[0], c[7], c[-1]
        alive = jnp.logical_and(gamma > limit, jnp.logical_not(broken))
        return jnp.logical_and(k < maxiter, jnp.any(alive))

    def body(c):
        (k, x, r, w, z, q, p, gamma, delta, alpha_prev, gamma_prev,
         restarted) = c[:12]
        broken = c[-1]
        m = op(w)  # ← overlaps the (gamma, delta) reduction
        beta = jnp.where(restarted, 0.0,
                         gamma / jnp.where(gamma_prev == 0, 1.0, gamma_prev))
        denom = delta - beta * gamma / jnp.where(alpha_prev == 0, 1.0,
                                                 alpha_prev)
        alpha = gamma / jnp.where(denom == 0, 1.0, denom)
        if batched:
            active = jnp.logical_and(gamma > limit, jnp.logical_not(broken))
            broken = jnp.logical_or(broken,
                                    jnp.logical_and(active, denom == 0))
            alpha = jnp.where(active, alpha, 0.0)  # freeze x/r/w bitwise
            bb, aa = _bcast(beta, b).astype(dt), _bcast(alpha, b).astype(dt)
            gate = _bcast(active, b)
            # gate the recurrence vectors too: beta -> 1 for a frozen
            # system (its gamma stopped moving), which would keep GROWING
            # p/q/z without this.
            z = jnp.where(gate, m + bb * z, z)
            q = jnp.where(gate, w + bb * q, q)
            p = jnp.where(gate, r + bb * p, p)
        else:
            bb = aa = None
            broken = jnp.logical_or(broken, denom == 0)
            z = m + beta.astype(dt) * z
            q = w + beta.astype(dt) * q
            p = r + beta.astype(dt) * p
        x = x + (aa if batched else alpha.astype(dt)) * p
        r = r - (aa if batched else alpha.astype(dt)) * q
        w = w - (aa if batched else alpha.astype(dt)) * z

        if rr > 0:
            do_replace = (k + 1) % rr == 0

            def replace(x, r, w):
                r_true = b - op(x)
                return r_true, op(r_true)

            r, w = jax.lax.cond(do_replace, replace,
                                lambda x, r, w: (r, w), x, r, w)
        else:
            do_replace = jnp.asarray(False)
        gamma_new, delta_new = fused_dots(r, w)
        out = (k + 1, x, r, w, z, q, p, gamma_new, delta_new, alpha, gamma,
               do_replace)
        if batched:
            out = out + (jnp.where(active, k + 1, c[12]),)
        return out + (broken,)

    def finish(out):
        k, x, gamma, broken = out[0], out[1], out[7], out[-1]
        # prologue w = op(r) is 1; each body iteration applies op once; a
        # residual replacement (every rr iterations) applies it twice more
        mv = k + 1 + (2 * (k // rr) if rr > 0 else 0)
        stats = SolveStats(iterations=k,
                           outer_iterations=jnp.asarray(1, jnp.int32),
                           residual_norm2=gamma, converged=gamma <= limit,
                           rhs_iterations=out[12] if batched else None,
                           verdict=classify(gamma, limit, broken),
                           matvecs=jnp.broadcast_to(mv, gamma.shape))
        return x, stats

    return LoopParts(init=init, cond=cond, body=body, finish=finish,
                     counter=lambda c: c[0])


def pipecg(op: Op, b: Array, *, tol: float = 1e-8, maxiter: int = 1000,
           residual_replacement_every: int = 25,
           dot=field_dot, norm2=field_norm2, fused_dots=None,
           batched: bool = False) -> tuple[Array, SolveStats]:
    """Pipelined CG — ONE fused reduction per iteration.

    See :func:`pipecg_parts` for the full algorithm notes; this is exactly
    its parts run to completion in one ``lax.while_loop``.
    """
    parts = pipecg_parts(
        op, b, tol=tol, maxiter=maxiter,
        residual_replacement_every=residual_replacement_every,
        dot=dot, norm2=norm2, fused_dots=fused_dots, batched=batched)
    return parts.finish(jax.lax.while_loop(parts.cond, parts.body,
                                           parts.init))


# ---------------------------------------------------------------------------
# BiCGStab — direct non-Hermitian solve (D x = b without normal equations)
# ---------------------------------------------------------------------------

def bicgstab(op: Op, b: Array, *, tol: float = 1e-8, maxiter: int = 1000,
             dot=field_dot, norm2=field_norm2) -> tuple[Array, SolveStats]:
    """BiCGStab for general (non-Hermitian) operators such as D itself.

    ``tol`` goes through :func:`_stop_limit` like every other solver, so a
    per-RHS tolerance VECTOR raises the same loud ``ValueError`` here
    (bicgstab has no batched mode to give it meaning).  The method's
    classic breakdowns — ``(rhat, r) = 0``, ``(rhat, v) = 0`` and a zero
    stabilizer norm ``‖t‖² = 0`` — set the breakdown flag and exit with
    ``verdict=BREAKDOWN`` instead of silently iterating on a guarded-away
    division.
    """
    x = jnp.zeros_like(b)
    r = b
    rhat = r
    dt = b.dtype
    # scalar carries take the dtype of the injected dot (complex for complex b)
    one = dot(b, b) * 0 + 1
    bs = _real(norm2(b))
    limit = _stop_limit(tol, bs, False)

    init = (jnp.asarray(0, jnp.int32), x, r, jnp.zeros_like(b),
            jnp.zeros_like(b), one, one, one, _real(norm2(r)),
            jnp.asarray(False))

    def cond(c):
        k, rs, broken = c[0], c[8], c[9]
        return jnp.logical_and(
            k < maxiter,
            jnp.logical_and(rs > limit, jnp.logical_not(broken)))

    def body(c):
        k, x, r, p, v, rho, alpha, omega, rs, broken = c
        rho_new = dot(rhat, r)
        broken = jnp.logical_or(broken, rho_new == 0)
        beta = (rho_new / jnp.where(rho == 0, 1.0, rho)) * \
               (alpha / jnp.where(omega == 0, 1.0, omega))
        p = r + beta.astype(dt) * (p - omega.astype(dt) * v)
        v = op(p)
        denom = dot(rhat, v)
        broken = jnp.logical_or(broken, denom == 0)
        alpha_new = rho_new / jnp.where(denom == 0, 1.0, denom)
        s = r - alpha_new.astype(dt) * v
        t = op(s)
        tn = _real(norm2(t))
        broken = jnp.logical_or(broken, tn == 0)
        omega_new = dot(t, s) / jnp.where(tn == 0, 1.0, tn)
        x = x + alpha_new.astype(dt) * p + omega_new.astype(dt) * s
        r = s - omega_new.astype(dt) * t
        return (k + 1, x, r, p, v, rho_new, alpha_new, omega_new,
                _real(norm2(r)), broken)

    out = jax.lax.while_loop(cond, body, init)
    k, x, rs, broken = out[0], out[1], out[8], out[9]
    stats = SolveStats(iterations=k, outer_iterations=jnp.asarray(1, jnp.int32),
                       residual_norm2=rs, converged=rs <= limit,
                       verdict=classify(rs, limit, broken),
                       matvecs=2 * k)  # v = op(p) and t = op(s) per iteration
    return x, stats


# ---------------------------------------------------------------------------
# Block CG — one shared Krylov search space for N right-hand sides
# ---------------------------------------------------------------------------
#
# Batched CG (above) shares the MATVEC across N systems but keeps N
# independent Krylov spaces: every RHS burns its own iteration budget.
# Block CG (O'Leary 1980) shares the SEARCH SPACE too — the N scalar
# alpha/beta pairs become small N×N Gram solves, every column's update
# draws on all N directions, and the iteration count drops toward the one
# set by the operator's spectrum divided by the block width.  Per-RHS
# matvecs equal the (smaller) trip count, so the total operator work for
# N systems falls well below N× the single-RHS count (DESIGN.md §12).


def gram(a: Array, b: Array) -> Array:
    """Pairwise inner products ``G[i, j] = ⟨a_i, b_j⟩`` over the leading
    axis (single-device; the site axes are flattened and contracted in one
    einsum).  Real for packed real-pair fields, Hermitian complex for
    natural fields."""
    a2 = a.reshape(a.shape[0], -1)
    b2 = b.reshape(b.shape[0], -1)
    return jnp.einsum("if,jf->ij", a2.conj(), b2)


def _mix(fields: Array, coef: Array) -> Array:
    """Column mixing ``out_j = Σ_i fields_i · coef[i, j]`` over the leading
    RHS axis — the block-CG generalization of ``alpha * p``."""
    f2 = fields.reshape(fields.shape[0], -1)
    return jnp.einsum("ij,if->jf", coef.astype(f2.dtype),
                      f2).reshape(fields.shape)


def _gram_psolve(g: Array, rhs: Array, rcond: float = 1e-7) -> Array:
    """Hermitian pseudo-solve of the N×N Gram system — the block-CG
    RANK-DEFLATION point.  Eigenvalues below ``rcond·λ_max`` (converged
    columns are zeroed out of P, linearly dependent directions collapse)
    get zero inverse weight, so degenerate directions drop out of the
    update instead of poisoning every column through a singular solve."""
    evals, evecs = jnp.linalg.eigh(g)
    cut = rcond * jnp.maximum(jnp.max(jnp.abs(evals)), 1e-30)
    inv = jnp.where(evals > cut, 1.0 / jnp.where(evals > cut, evals, 1.0),
                    0.0)
    return evecs @ (inv[:, None].astype(rhs.dtype)
                    * (evecs.conj().T @ rhs))


def blockcg(op: Op, b: Array, x0: Array | None = None, *,
            tol: float = 1e-8, maxiter: int = 1000,
            norm2=field_norm2_batched) -> tuple[Array, SolveStats]:
    """Block CG for a Hermitian positive-definite ``op`` over a leading
    RHS-batch axis — N systems share ONE Krylov search space.

    Per iteration: one block matvec ``Q = A P`` (the same batched
    operator the masked multi-RHS solvers use — one gauge fetch serves
    all N spinors), then two N×N Gram solves

        alpha = (PᴴAP)⁺ PᴴR          (Galerkin step)
        beta  = −(PᴴAP)⁺ QᴴR₊        (A-orthogonalization)

    with a Hermitian PSEUDO-inverse (:func:`_gram_psolve`): converged
    columns are zeroed out of ``P``/``R`` and linearly dependent search
    directions collapse onto eigenvalues below the cut, so both are
    rank-deflated out of the shared space instead of breaking the solve.
    Columns therefore do NOT freeze bitwise the way the masked batched CG
    freezes them (every update mixes all active directions) — the
    contract degrades gracefully to per-RHS verdicts: per-RHS
    convergence, per-RHS ``rhs_iterations``, per-RHS classification, and
    the §10 true-residual verification gate still applies per RHS.

    ``tol`` may be a per-RHS (N,) vector exactly as in :func:`cg`.
    Single-device only (the Gram einsums contract unsharded site axes).
    """
    if b.ndim < 2:
        raise ValueError("blockcg requires a leading RHS-batch axis")
    _, norm2 = _batched_defaults(field_dot, norm2)  # always per-RHS here
    x = jnp.zeros_like(b) if x0 is None else x0
    r = b - op(x) if x0 is not None else b
    rs = _real(norm2(r))
    bs = _real(norm2(b))
    limit = _stop_limit(tol, bs, True)
    active0 = rs > limit
    # invariant: inactive columns of P are identically zero, so they
    # contribute nothing to the Gram matrices or the shared updates
    p = jnp.where(_bcast(active0, b), r, jnp.zeros_like(b))

    def cond(c):
        k, rs, broken = c[0], c[4], c[6]
        alive = jnp.logical_and(rs > limit, jnp.logical_not(broken))
        return jnp.logical_and(k < maxiter, jnp.any(alive))

    def body(c):
        k, x, r, p, rs, it, broken, rs_mark = c
        rs_mark = jnp.where(k % STAGNATION_WINDOW == 0, rs, rs_mark)
        m = jnp.logical_and(rs > limit, jnp.logical_not(broken))
        q = op(p)
        g = gram(p, q)                       # N×N, PSD (zero inactive slots)
        alpha = _gram_psolve(g, gram(p, r))
        # mask converged/broken columns: their x/r stay untouched
        alpha = alpha * m[None, :].astype(alpha.dtype)
        colbad = jnp.logical_not(jnp.all(jnp.isfinite(alpha), axis=0))
        broken = jnp.logical_or(broken, jnp.logical_and(m, colbad))
        alpha = jnp.where(jnp.isfinite(alpha), alpha, 0.0)
        x = x + _mix(p, alpha)
        r = r - _mix(q, alpha)
        rs_new = _real(norm2(r))
        m_next = jnp.logical_and(rs_new > limit, jnp.logical_not(broken))
        beta = -_gram_psolve(g, gram(q, r))
        beta = beta * m_next[None, :].astype(beta.dtype)
        beta = jnp.where(jnp.isfinite(beta), beta, 0.0)
        p_new = (jnp.where(_bcast(m_next, b), r, jnp.zeros_like(b))
                 + _mix(p, beta))
        it = jnp.where(m, k + 1, it)
        return (k + 1, x, r, p_new, rs_new, it, broken, rs_mark)

    init = (jnp.asarray(0, jnp.int32), x, r, p, rs,
            jnp.zeros_like(rs, jnp.int32), jnp.zeros(rs.shape, bool), rs)
    k, x, r, p, rs, it, broken, rs_mark = jax.lax.while_loop(cond, body,
                                                             init)
    stalled = jnp.logical_and(k >= STAGNATION_WINDOW,
                              rs > STAGNATION_FACTOR * rs_mark)
    init_mv = jnp.asarray(0 if x0 is None else 1, jnp.int32)
    stats = SolveStats(iterations=k,
                       outer_iterations=jnp.asarray(1, jnp.int32),
                       residual_norm2=rs, converged=rs <= limit,
                       rhs_iterations=it,
                       verdict=classify(rs, limit, broken, stalled),
                       matvecs=jnp.broadcast_to(k + init_mv, rs.shape))
    return x, stats


# ---------------------------------------------------------------------------
# EigCG-style deflation — harvest low eigenpairs from early solves, then
# project them out of every later solve on the same gauge field
# ---------------------------------------------------------------------------
#
# CG's alpha/beta coefficients ARE a Lanczos factorization of the Krylov
# operator in the normalized-residual basis: T[k,k] = 1/α_k + β_{k-1}/α_{k-1},
# T[k,k+1] = √β_k / α_k.  Recording the normalized residuals alongside a
# normal solve (``cg_harvest``) therefore yields Ritz pairs of A for free —
# the smallest ones approximate the low modes that dominate the iteration
# count.  A later solve on the same operator projects its RHS against the
# harvested basis (Galerkin: x₀ = W (WᴴAW)⁻¹ Wᴴ b) and init-CGs from that
# x₀ — the low-mode components arrive pre-solved and CG only works on the
# better-conditioned remainder (DESIGN.md §12).


class DeflationBasis(NamedTuple):
    """A harvested low-mode basis for one (gauge, operator) pair.

    ``w``: (nev, *field) approximate low eigenvectors (Ritz vectors) of
    the Krylov operator, in the solver's working layout.  ``gram``: the
    (nev, nev) projected operator ``WᴴAW`` — identity-padded on slots
    beyond the harvested rank, so the Galerkin solve is always
    nonsingular and a padded slot contributes exactly zero correction.
    """

    w: Array
    gram: Array

    @property
    def nev(self) -> int:
        return self.w.shape[0]


def cg_harvest(op: Op, b: Array, *, tol: float = 1e-8, maxiter: int = 1000,
               m_max: int = 48, dot=field_dot, norm2=field_norm2,
               ) -> tuple[Array, SolveStats, tuple[Array, Array, Array]]:
    """:func:`cg` (single-RHS) that additionally records its Lanczos data.

    Returns ``(x, stats, (v, alphas, betas))``: the solution and stats of
    a normal CG solve, plus the first ``min(iterations, m_max)``
    normalized residuals ``v_k = r_k/‖r_k‖`` (the Lanczos vectors of
    ``op`` in the Krylov space) and the CG coefficients they pair with —
    exactly what :func:`ritz_deflation_basis` turns into a
    :class:`DeflationBasis`.  The hot loop gains one buffer write per
    iteration and no extra reductions or matvecs; the while-loop trip
    count (and the iterate trajectory) is bitwise that of :func:`cg`.
    """
    m_max = int(min(m_max, maxiter))
    x = jnp.zeros_like(b)
    r = b
    p = r
    rs = _real(norm2(r))
    bs = _real(norm2(b))
    limit = _stop_limit(tol, bs, False)

    def cond(c):
        k, rs, broken = c[0], c[4], c[5]
        alive = jnp.logical_and(rs > limit, jnp.logical_not(broken))
        return jnp.logical_and(k < maxiter, alive)

    def body(c):
        k, x, r, p, rs, broken, rs_mark, vbuf, albuf, bebuf = c
        rs_mark = jnp.where(k % STAGNATION_WINDOW == 0, rs, rs_mark)
        # record the k-th Lanczos vector (normalized residual) before the
        # update; writes past m_max re-write the last slot with its own
        # value (a no-op), keeping the loop free of conditionals
        idx = jnp.minimum(k, m_max - 1)
        v = r * jnp.where(rs > 0, jax.lax.rsqrt(rs), 0.0).astype(r.dtype)
        keep = jax.lax.dynamic_index_in_dim(vbuf, idx, 0, keepdims=False)
        vbuf = jax.lax.dynamic_update_index_in_dim(
            vbuf, jnp.where(k < m_max, v, keep), idx, 0)
        ap = op(p)
        pap = _real(dot(p, ap))
        safe = pap != 0
        broken = jnp.logical_or(broken, pap == 0)
        alpha = jnp.where(safe, rs / jnp.where(safe, pap, 1.0), 0.0)
        x = x + alpha.astype(b.dtype) * p
        r = r - alpha.astype(b.dtype) * ap
        rs_new = _real(norm2(r))
        beta = rs_new / rs
        p = r + beta.astype(b.dtype) * p
        keep_al = jax.lax.dynamic_index_in_dim(albuf, idx, 0, False)
        keep_be = jax.lax.dynamic_index_in_dim(bebuf, idx, 0, False)
        albuf = jax.lax.dynamic_update_index_in_dim(
            albuf, jnp.where(k < m_max, alpha, keep_al), idx, 0)
        bebuf = jax.lax.dynamic_update_index_in_dim(
            bebuf, jnp.where(k < m_max, beta, keep_be), idx, 0)
        return (k + 1, x, r, p, rs_new, broken, rs_mark, vbuf, albuf, bebuf)

    init = (jnp.asarray(0, jnp.int32), x, r, p, rs,
            jnp.asarray(False), rs,
            jnp.zeros((m_max,) + b.shape, b.dtype),
            jnp.zeros((m_max,), rs.dtype), jnp.zeros((m_max,), rs.dtype))
    out = jax.lax.while_loop(cond, body, init)
    k, x, r, p, rs, broken, rs_mark, vbuf, albuf, bebuf = out
    stalled = jnp.logical_and(k >= STAGNATION_WINDOW,
                              rs > STAGNATION_FACTOR * rs_mark)
    stats = SolveStats(iterations=k,
                       outer_iterations=jnp.asarray(1, jnp.int32),
                       residual_norm2=rs, converged=rs <= limit,
                       verdict=classify(rs, limit, broken, stalled),
                       matvecs=jnp.broadcast_to(k, rs.shape))
    return x, stats, (vbuf, albuf, bebuf)


def ritz_deflation_basis(op: Op, v: Array, alphas: Array, betas: Array,
                         k, nev: int) -> DeflationBasis:
    """Host-side (eager): turn :func:`cg_harvest` records into a
    :class:`DeflationBasis` of exactly ``nev`` slots.

    Builds the k×k Lanczos tridiagonal from the CG coefficients, takes
    its ``min(nev, k)`` SMALLEST Ritz pairs, combines the recorded
    Lanczos vectors into Ritz vectors ``W = V·Y``, and projects the
    operator once: ``gram = WᴴAW`` (costing ``min(nev, k)`` extra
    matvecs, amortized over every later deflated solve on this gauge
    field).  Slots beyond the harvested rank are zero vectors with
    identity gram rows — inert in the Galerkin solve — so the basis shape
    is static regardless of how early the harvest solve converged.
    """
    import numpy as np
    m = int(min(int(k), v.shape[0]))
    if m < 1:
        raise ValueError("ritz_deflation_basis: empty harvest (k < 1)")
    al = np.asarray(alphas)[:m].astype(np.float64)
    be = np.asarray(betas)[:m].astype(np.float64)
    al = np.where(al == 0, 1.0, al)
    diag = 1.0 / al
    diag[1:] += be[:m - 1] / al[:m - 1]
    off = np.sqrt(np.maximum(be[:m - 1], 0.0)) / al[:m - 1]
    t = np.diag(diag) + np.diag(off, 1) + np.diag(off, -1)
    _, y = np.linalg.eigh(t)          # ascending: low modes first
    n_eff = max(1, min(nev, m))
    # the true Lanczos vectors are q_k = (-1)^k r_k/‖r_k‖; the recorded
    # v_k drop the sign, so fold it into the eigenvector rows (combining
    # unsigned v's with unsigned y's would target the WRONG spectrum end)
    signs = (-1.0) ** np.arange(m)
    yk = jnp.asarray((y[:, :n_eff] * signs[:, None]).astype(np.float32))
    vm = v[:m]
    w = jnp.einsum("km,k...->m...", yk.astype(vm.dtype), vm)
    aw = jnp.stack([op(w[i]) for i in range(n_eff)])
    g = gram(w, aw)
    if n_eff < nev:
        pad = jnp.zeros((nev - n_eff,) + w.shape[1:], w.dtype)
        w = jnp.concatenate([w, pad], axis=0)
        g_full = jnp.eye(nev, dtype=g.dtype)
        g = g_full.at[:n_eff, :n_eff].set(g)
    return DeflationBasis(w=w, gram=g)


def deflate_x0(basis: DeflationBasis, rhs: Array) -> Array:
    """Galerkin deflation: ``x₀ = W (WᴴAW)⁻¹ Wᴴ rhs``.

    ``rhs`` may carry a leading RHS-batch axis (same rank as ``basis.w``);
    the projection is per-RHS — no cross-lane mixing, so a poisoned lane's
    NaNs stay in its own x₀ (the §10 blast-radius contract).  A zero rhs
    (serving pad slot) yields exactly zero x₀.
    """
    nev = basis.w.shape[0]
    w2 = basis.w.reshape(nev, -1)
    batched = rhs.ndim == basis.w.ndim
    r2 = rhs.reshape(rhs.shape[0] if batched else 1, -1)
    proj = jnp.einsum("kf,nf->kn", w2.conj(), r2)
    c = jnp.linalg.solve(basis.gram, proj)
    x0 = jnp.einsum("kn,kf->nf", c, w2.astype(c.dtype))
    return x0.reshape(rhs.shape).astype(rhs.dtype)
