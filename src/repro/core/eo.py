"""Even-odd (red-black) Schur-preconditioned Wilson solves, end to end.

This module is the glue between the three layers that implement the
decomposition:

* :mod:`repro.core.lattice` — parity geometry (``split_eo``/``merge_eo``,
  per-parity gauge fields);
* :mod:`repro.core.wilson`  — the parity blocks ``dslash_eo``/``dslash_oe``
  and the Schur operator ``schur_op`` on even half fields;
* :mod:`repro.core.solvers` — ``cgnr_eo``/``mpcg_eo``, operator-agnostic.

``solve_wilson_eo`` takes natural-layout (u, b) and returns the
full-lattice solution; ``solve_wilson_eo_mp`` composes the Schur
reduction with the paper's mixed-precision reliable-update CG: the inner
solve iterates on bf16 real-pair half fields (narrow storage) while the
operator accumulates and the reliable updates run in f32/complex64
(wide arithmetic) — the two central optimizations of the source paper
working together.

With ``use_pallas=True`` the whole Schur solve runs on the Pallas fast
path: the CG iterates on PACKED real half fields (T, Z, Y, 24, Xh), the
matvec is four parity-hop kernel launches (γ5 and the Schur axpy folded
into kernel prologues/epilogues — see :mod:`repro.kernels.wilson_dslash`),
and the per-iteration vector algebra streams through the two fused
``cg_fused`` kernels injected into the solver's ``update``/``xpay`` hooks.
Packing is an isometry (Re⟨a,b⟩ equals the packed real dot product), so
the real-arithmetic CG produces exactly the complex CGNR iterates.

``solve_wilson_eo_batched`` is the multi-RHS entry point: N right-hand
sides against ONE gauge field ride a single masked CG loop whose matvec
amortizes every gauge-plane read across the batch — the workload-scaling
lever of DESIGN.md §6.  Per-RHS convergence masking keeps each system's
returned iterate bitwise identical to its independent single-RHS solve.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import solvers
from repro.core.lattice import (complex_to_real_pair, field_dot, field_norm2,
                                merge_eo, pack_gauge, pack_spinor,
                                real_pair_to_complex, split_eo,
                                split_eo_gauge, unpack_spinor)
from repro.core.wilson import (dslash_eo, dslash_oe, schur_dagger,
                               schur_normal_op, schur_op)

Array = jax.Array


class EOOperators(NamedTuple):
    """The parity blocks of D, bound to a gauge field, as callables."""

    dhat: solvers.Op       # Schur operator on even half fields
    dhat_dag: solvers.Op   # its gamma5-adjoint
    d_eo: solvers.Op       # odd -> even hopping block
    d_oe: solvers.Op       # even -> odd hopping block
    m_inv: solvers.Op      # M_oo^{-1} = 1/(m + 4r)
    u_e: Array             # per-parity link fields (for callers reusing them)
    u_o: Array


def eo_operators(u: Array, mass, r: float = 1.0) -> EOOperators:
    """Split the gauge field by parity and bind the Schur-system blocks."""
    u_e, u_o = split_eo_gauge(u)
    m = mass + 4.0 * r
    return EOOperators(
        dhat=lambda v: schur_op(u_e, u_o, v, mass, r=r),
        dhat_dag=lambda v: schur_dagger(u_e, u_o, v, mass, r=r),
        d_eo=lambda v: dslash_eo(u_e, u_o, v, r=r),
        d_oe=lambda v: dslash_oe(u_e, u_o, v, r=r),
        m_inv=lambda v: v / m,
        u_e=u_e, u_o=u_o)


def eo_operators_packed(u: Array, mass, r: float = 1.0, *,
                        bz: int | None = None,
                        interpret: bool | None = None,
                        use_pallas: bool = True) -> EOOperators:
    """The Schur-system blocks on PACKED half fields, Pallas fast path.

    The returned callables act on packed (T, Z, Y, 24, Xh) real half
    fields — or (N, T, Z, Y, 24, Xh) RHS batches: every block is
    rank-polymorphic, and the Pallas kernels amortize each gauge-plane
    fetch across the whole batch (see DESIGN.md §6).

    Supported-parameter matrix (packed path, ``use_pallas`` either way —
    the packed references round-trip through the same spin-projection
    contract):

    ==========  =======================  ==============================
    parameter   supported                notes
    ==========  =======================  ==============================
    r           1.0 only                 rank-2 (1 ∓ γ_mu) projectors
                                         are baked into the trace-time
                                         half-spinor tables; any other r
                                         raises ``NotImplementedError``
    mass        any float                trace-time constant
    dtype       f32 / bf16 storage       kernels accumulate in f32
    batch       none or leading N axis   gauge read once per grid step
    ==========  =======================  ==============================

    For r != 1 use the natural-layout blocks (:func:`eo_operators`), which
    build the full rank-4 projectors.
    """
    if r != 1.0:  # a real exception, not assert: must survive `python -O`
        raise NotImplementedError(
            "the packed/Pallas parity kernels hard-code r=1 (their "
            "trace-time spin-projection tables need the rank-2 projectors "
            f"(1 -+ gamma_mu)); got r={r}. Use the natural-layout path "
            "(eo_operators / solve_wilson_eo(use_pallas=False)) for r != 1.")
    # local import: repro.core is imported by the kernels package, so a
    # module-level import here would be circular.
    from repro.kernels.wilson_dslash import ops as wops

    u_e, u_o = split_eo_gauge(u)
    upe, upo = pack_gauge(u_e), pack_gauge(u_o)
    m = mass + 4.0 * r
    kw = dict(bz=bz, interpret=interpret, use_pallas=use_pallas)
    return EOOperators(
        dhat=lambda v: wops.schur_op(upe, upo, v, mass, **kw),
        dhat_dag=lambda v: wops.schur_op(upe, upo, v, mass, dagger=True,
                                         **kw),
        d_eo=lambda v: wops.dslash_eo(upe, upo, v, **kw),
        d_oe=lambda v: wops.dslash_oe(upe, upo, v, **kw),
        m_inv=lambda v: v / m,
        u_e=upe, u_o=upo)


def solve_wilson_eo(u: Array, b: Array, mass, *, r: float = 1.0,
                    tol: float = 1e-8, maxiter: int = 1000,
                    dot=field_dot, norm2=field_norm2,
                    use_pallas: bool = False,
                    interpret: bool | None = None, bz: int | None = None,
                    ) -> tuple[Array, solvers.SolveStats]:
    """Solve D x = b by CGNR on the even-sublattice Schur complement.

    Same contract as a plain ``cgnr`` solve: natural-layout inputs, the
    merged full-lattice solution out, but the CG runs on half-size
    vectors against the better-conditioned reduced operator.

    ``use_pallas=True`` moves the whole solve onto the Pallas fast path:
    packed real half fields, parity-hop stencil kernels for the matvec and
    the fused streaming kernels for the per-iteration vector algebra.
    ``interpret``/``bz`` tune the kernels (None = backend defaults).
    """
    if use_pallas:
        from repro.kernels.cg_fused import fused_engine  # see note above

        ops = eo_operators_packed(u, mass, r=r, bz=bz, interpret=interpret)
        b_e, b_o = split_eo(b)
        update, xpay = fused_engine(interpret=interpret)
        (x_e, x_o), stats = solvers.cgnr_eo(
            ops.dhat, ops.dhat_dag, ops.d_eo, ops.d_oe, ops.m_inv,
            pack_spinor(b_e), pack_spinor(b_o),
            tol=tol, maxiter=maxiter, dot=dot, norm2=norm2,
            update=update, xpay=xpay)
        return merge_eo(unpack_spinor(x_e, dtype=b.dtype),
                        unpack_spinor(x_o, dtype=b.dtype)), stats
    ops = eo_operators(u, mass, r=r)
    b_e, b_o = split_eo(b)
    (x_e, x_o), stats = solvers.cgnr_eo(
        ops.dhat, ops.dhat_dag, ops.d_eo, ops.d_oe, ops.m_inv, b_e, b_o,
        tol=tol, maxiter=maxiter, dot=dot, norm2=norm2)
    return merge_eo(x_e, x_o), stats


def solve_wilson_eo_batched(u: Array, b: Array, mass, *, r: float = 1.0,
                            tol: float = 1e-8, maxiter: int = 1000,
                            use_pallas: bool = True,
                            interpret: bool | None = None,
                            bz: int | None = None,
                            ) -> tuple[Array, solvers.SolveStats]:
    """Solve D x_n = b_n for a BATCH of right-hand sides in one CG loop.

    Args:
      u: (4, T, Z, Y, X, 3, 3) gauge field, shared by the whole batch —
        this sharing is the point: the matvec reads each gauge plane once
        per grid step and streams all N spinor planes through it, so the
        dslash arithmetic intensity grows with N (DESIGN.md §6).
      b: (N, T, Z, Y, X, 4, 3) batched RHS.
    Returns:
      (x, stats): x is (N, T, Z, Y, X, 4, 3); ``stats.iterations`` is the
      masked loop's trip count (= the slowest system's iterations) while
      ``stats.residual_norm2``/``stats.converged`` are per-RHS (N,).

    Per-RHS convergence masking freezes each system the iteration it
    meets ITS OWN ``tol``: the returned x_n is bitwise the iterate an
    independent single-RHS solve of b_n would have returned.
    ``use_pallas=True`` runs packed real half fields through the batched
    parity kernels and the batched fused vector engine; ``False`` vmaps
    the natural-layout reference blocks (same Krylov iteration).
    """
    if b.ndim != 7:  # a real exception, not assert: must survive `python -O`
        raise ValueError(
            f"batched RHS must be (N, T, Z, Y, X, 4, 3); got {b.shape}. "
            "For a single RHS use solve_wilson_eo (or add a leading axis).")
    b_e, b_o = jax.vmap(split_eo)(b)
    if use_pallas:
        from repro.kernels.cg_fused import fused_engine_batched  # circularity
        ops = eo_operators_packed(u, mass, r=r, bz=bz, interpret=interpret)
        update, xpay = fused_engine_batched(interpret=interpret)
        (x_e, x_o), stats = solvers.cgnr_eo(
            ops.dhat, ops.dhat_dag, ops.d_eo, ops.d_oe, ops.m_inv,
            pack_spinor(b_e), pack_spinor(b_o),
            tol=tol, maxiter=maxiter, update=update, xpay=xpay,
            batched=True)
        x_e = unpack_spinor(x_e, dtype=b.dtype)
        x_o = unpack_spinor(x_o, dtype=b.dtype)
    else:
        ops = eo_operators(u, mass, r=r)
        (x_e, x_o), stats = solvers.cgnr_eo(
            jax.vmap(ops.dhat), jax.vmap(ops.dhat_dag), jax.vmap(ops.d_eo),
            jax.vmap(ops.d_oe), ops.m_inv, b_e, b_o,
            tol=tol, maxiter=maxiter, batched=True)
    return jax.vmap(merge_eo)(x_e, x_o), stats


def solve_wilson_eo_mp(u: Array, b: Array, mass, *, r: float = 1.0,
                       tol: float = 1e-6, inner_tol: float = 5e-2,
                       inner_maxiter: int = 200, max_outer: int = 50,
                       low_dtype=jnp.bfloat16, dot=field_dot,
                       norm2=field_norm2, use_pallas: bool = False,
                       interpret: bool | None = None, bz: int | None = None,
                       ) -> tuple[Array, solvers.SolveStats]:
    """Even-odd + mixed-precision: bf16 half-size inner CG, f32 updates.

    The low-precision representation is the bf16 real-pair view of the
    complex even half field (complex bf16 does not exist); links are
    rounded to bf16 once up front.  The inner CG's vector updates and
    stored iterates are bf16 while every contraction inside the operator
    still accumulates wide — narrow datapath, wide accumulator, as on
    the paper's FPGA.

    ``use_pallas=True`` keeps the WHOLE mixed-precision solve on the
    packed-field fast path: the low representation is simply the bf16
    packed real half field (kernels read bf16 storage and accumulate in
    f32 registers), so ``to_low``/``to_high`` are plain storage casts at
    the reliable-update boundary — once per outer cycle, on half fields —
    rather than standalone complex<->real-pair conversion passes, and the
    inner CG streams through the parity kernels + fused vector engine.
    Requires r = 1 (raises ``NotImplementedError`` otherwise; see
    :func:`eo_operators_packed` for the supported-parameter matrix).
    """
    if use_pallas:
        return _solve_wilson_eo_mp_pallas(
            u, b, mass, r=r, tol=tol, inner_tol=inner_tol,
            inner_maxiter=inner_maxiter, max_outer=max_outer,
            low_dtype=low_dtype, dot=dot, norm2=norm2,
            interpret=interpret, bz=bz)
    ops = eo_operators(u, mass, r=r)
    b_e, b_o = split_eo(b)
    high = b.dtype

    def round_links(w: Array) -> Array:
        pair = complex_to_real_pair(w, dtype=low_dtype)
        return real_pair_to_complex(pair, dtype=w.dtype)

    u_e_lo, u_o_lo = round_links(ops.u_e), round_links(ops.u_o)

    def a_low(w: Array) -> Array:  # bf16 real-pair in/out, wide inside
        v = real_pair_to_complex(w, dtype=high)
        av = schur_normal_op(u_e_lo, u_o_lo, v, mass, r=r)
        return complex_to_real_pair(av, dtype=low_dtype)

    def a_high(v: Array) -> Array:
        return schur_normal_op(ops.u_e, ops.u_o, v, mass, r=r)

    (x_e, x_o), stats = solvers.mpcg_eo(
        a_low, a_high, ops.dhat_dag, ops.d_eo, ops.d_oe, ops.m_inv,
        b_e, b_o, tol=tol, inner_tol=inner_tol,
        inner_maxiter=inner_maxiter, max_outer=max_outer,
        low_dtype=low_dtype,
        to_low=lambda v: complex_to_real_pair(v, dtype=low_dtype),
        to_high=lambda w: real_pair_to_complex(w, dtype=high),
        dot=dot, norm2=norm2)
    return merge_eo(x_e, x_o), stats


def _solve_wilson_eo_mp_pallas(u: Array, b: Array, mass, *, r, tol,
                               inner_tol, inner_maxiter, max_outer,
                               low_dtype, dot, norm2, interpret, bz,
                               ) -> tuple[Array, solvers.SolveStats]:
    """Mixed-precision Schur solve entirely on packed real half fields.

    Low representation = the packed field itself in ``low_dtype`` storage
    (the packing is already real, so no real-pair view is needed): links
    are rounded once up front, the inner CG's iterates/updates live in
    bf16 through the fused vector engine, and the parity kernels
    accumulate every contraction in f32 registers — T1's narrow storage /
    wide accumulate with zero standalone full-field cast passes inside
    the matvec.
    """
    # local import: see eo_operators_packed.
    from repro.kernels.cg_fused import fused_engine
    from repro.kernels.wilson_dslash import ops as wops

    ops = eo_operators_packed(u, mass, r=r, bz=bz, interpret=interpret)
    b_e, b_o = split_eo(b)
    pb_e = pack_spinor(b_e)
    pb_o = pack_spinor(b_o)
    high = pb_e.dtype

    # one up-front rounding of the links — the low operator's gauge reads
    # then stream bf16 (half the gauge HBM traffic), accumulating wide.
    u_e_lo = ops.u_e.astype(low_dtype)
    u_o_lo = ops.u_o.astype(low_dtype)
    kw = dict(bz=bz, interpret=interpret)

    def a_low(w: Array) -> Array:  # low storage in/out, f32 registers inside
        return wops.schur_normal_op(u_e_lo, u_o_lo, w, mass, **kw)

    def a_high(v: Array) -> Array:
        return wops.schur_normal_op(ops.u_e, ops.u_o, v, mass, **kw)

    update, xpay = fused_engine(interpret=interpret)
    (x_e, x_o), stats = solvers.mpcg_eo(
        a_low, a_high, ops.dhat_dag, ops.d_eo, ops.d_oe, ops.m_inv,
        pb_e, pb_o, tol=tol, inner_tol=inner_tol,
        inner_maxiter=inner_maxiter, max_outer=max_outer,
        low_dtype=low_dtype,
        to_low=lambda v: v.astype(low_dtype),
        to_high=lambda w: w.astype(high),
        dot=dot, norm2=norm2, update=update, xpay=xpay)
    return merge_eo(unpack_spinor(x_e, dtype=b.dtype),
                    unpack_spinor(x_o, dtype=b.dtype)), stats
