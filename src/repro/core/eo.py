"""Even-odd (red-black) Schur-preconditioned Wilson solves.

This module owns the Schur-decomposition PLUMBING shared by every
even-odd solve path:

* :class:`EOOperators`/:func:`eo_operators`/:func:`eo_operators_packed` —
  the parity blocks of D bound to a gauge field, natural-layout reference
  or packed Pallas fast path;
* :func:`eo_context` — the one-stop resolver: operator blocks + RHS/
  solution layout converters + the fused vector engine, derived ONCE for
  a given (backend, batch shape).  This is what the
  :mod:`repro.core.plan` resolver builds every single-device even-odd
  solve from — the three historical ``solve_wilson_eo*`` variants used
  to re-derive the parity gauge/packing independently.

``solve_wilson_eo`` / ``solve_wilson_eo_batched`` / ``solve_wilson_eo_mp``
remain the stable public entry points but are now thin forwarders to the
:class:`repro.core.plan.SolverPlan` machinery: each one names its path as
a plan (operator family, backend, batch shape, precision policy) and the
plan resolver executes it.  Their contracts — including the bitwise
batched-equals-looped-singles guarantee and the packed-path r=1
restriction — are unchanged and tested in tests/test_eo.py.
"""

from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import solvers
from repro.core.lattice import (field_dot, field_norm2, merge_eo, pack_gauge,
                                pack_spinor, split_eo, split_eo_gauge,
                                unpack_spinor)
from repro.core.operators import (SiteTerm, schur_dagger_g, schur_op_g)
from repro.core.wilson import dslash_eo, dslash_oe

Array = jax.Array


class EOOperators(NamedTuple):
    """The parity blocks of D, bound to a gauge field, as callables."""

    dhat: solvers.Op       # Schur operator on even half fields
    dhat_dag: solvers.Op   # its gamma5-adjoint
    d_eo: solvers.Op       # odd -> even hopping block
    d_oe: solvers.Op       # even -> odd hopping block
    m_inv: solvers.Op      # M_oo^{-1} (site-term inverse; 1/(m+4r) Wilson)
    u_e: Array             # per-parity link fields (for callers reusing them)
    u_o: Array


def eo_operators(u: Array, mass, r: float = 1.0,
                 twist: float = 0.0) -> EOOperators:
    """Split the gauge field by parity and bind the Schur-system blocks.

    ``twist`` is the operator registry's site-term twist (the site block
    is ``(m + 4r) + i·twist·γ5``); 0 is Wilson, bitwise the historical
    blocks.  The hop blocks ``d_eo``/``d_oe`` are operator-agnostic
    transport and never see the twist.
    """
    u_e, u_o = split_eo_gauge(u)
    site = SiteTerm(mass + 4.0 * r, twist)
    return EOOperators(
        dhat=lambda v: schur_op_g(u_e, u_o, v, mass, r=r, twist=twist),
        dhat_dag=lambda v: schur_dagger_g(u_e, u_o, v, mass, r=r,
                                          twist=twist),
        d_eo=lambda v: dslash_eo(u_e, u_o, v, r=r),
        d_oe=lambda v: dslash_oe(u_e, u_o, v, r=r),
        m_inv=site.solve,
        u_e=u_e, u_o=u_o)


def eo_operators_packed(u: Array, mass, r: float = 1.0, *,
                        twist: float = 0.0, bz: int | None = None,
                        interpret: bool | None = None,
                        use_pallas: bool = True) -> EOOperators:
    """The Schur-system blocks on PACKED half fields, Pallas fast path.

    The returned callables act on packed (T, Z, Y, 24, Xh) real half
    fields — or (N, T, Z, Y, 24, Xh) RHS batches: every block is
    rank-polymorphic, and the Pallas kernels amortize each gauge-plane
    fetch across the whole batch (see DESIGN.md §6).

    Supported-parameter matrix (packed path, ``use_pallas`` either way —
    the packed references round-trip through the same spin-projection
    contract):

    ==========  =======================  ==============================
    parameter   supported                notes
    ==========  =======================  ==============================
    r           1.0 only                 rank-2 (1 ∓ γ_mu) projectors
                                         are baked into the trace-time
                                         half-spinor tables; any other r
                                         raises ``NotImplementedError``
    mass        any float                trace-time constant
    twist       any float                site-term twist (operator
                                         registry): folded into the
                                         kernel epilogues, still 2
                                         launches per Schur block
    dtype       f32 / bf16 storage       kernels accumulate in f32
    batch       none or leading N axis   gauge read once per grid step
    ==========  =======================  ==============================

    For r != 1 use the natural-layout blocks (:func:`eo_operators`), which
    build the full rank-4 projectors.
    """
    if r != 1.0:  # a real exception, not assert: must survive `python -O`
        raise NotImplementedError(
            "the packed/Pallas parity kernels hard-code r=1 (their "
            "trace-time spin-projection tables need the rank-2 projectors "
            f"(1 -+ gamma_mu)); got r={r}. Use the natural-layout path "
            "(eo_operators / solve_wilson_eo(use_pallas=False)) for r != 1.")
    # local import: repro.core is imported by the kernels package, so a
    # module-level import here would be circular.
    from repro.kernels.wilson_dslash import ops as wops

    u_e, u_o = split_eo_gauge(u)
    upe, upo = pack_gauge(u_e), pack_gauge(u_o)
    site = SiteTerm(mass + 4.0 * r, twist)
    kw = dict(bz=bz, interpret=interpret, use_pallas=use_pallas)
    return EOOperators(
        dhat=lambda v: wops.schur_op(upe, upo, v, mass, twist=twist, **kw),
        dhat_dag=lambda v: wops.schur_op(upe, upo, v, mass, twist=twist,
                                         dagger=True, **kw),
        d_eo=lambda v: wops.dslash_eo(upe, upo, v, **kw),
        d_oe=lambda v: wops.dslash_oe(upe, upo, v, **kw),
        m_inv=site.solve,
        u_e=upe, u_o=upo)


def schur_rhs(ops: EOOperators, b_e: Array, b_o: Array) -> Array:
    """The Schur normal-equation RHS ``D̂†(b_e − D_eo M_oo⁻¹ b_o)``.

    Every even-odd Krylov path iterates against this vector — plain CGNR,
    pipecg, block CG, and the deflation projection all derive it
    identically, so it is built here once.  Prologue work: NOT a counted
    matvec (see ``SolveStats.matvecs``).
    """
    return ops.dhat_dag(b_e - ops.d_eo(ops.m_inv(b_o)))


def back_substitute_odd(ops: EOOperators, b_o: Array, x_e: Array) -> Array:
    """Recover the odd half field: ``x_o = M_oo⁻¹ (b_o − D_oe x_e)``."""
    return ops.m_inv(b_o - ops.d_oe(x_e))


class EOContext(NamedTuple):
    """A resolved even-odd solve: blocks + layout converters + engine.

    ``prepare`` maps the natural-layout RHS ``b`` to the pair of
    working-layout half fields the solver iterates on; ``finish`` inverts
    it for the solution.  ``engine`` is the (update, xpay) fused vector
    engine when the working layout is packed (Pallas streaming triads),
    else None (the solver's default jnp algebra).  The blocks in ``ops``
    already accept the declared batch shape — vmapped natural-layout
    references or rank-polymorphic packed kernels.
    """

    ops: EOOperators
    prepare: Callable[[Array], tuple[Array, Array]]
    finish: Callable[[Array, Array], Array]
    engine: tuple[Callable, Callable] | None
    packed: bool
    batched: bool


def eo_context(u: Array, mass, *, r: float = 1.0, twist: float = 0.0,
               use_pallas: bool = False,
               batched: bool = False, bz: int | None = None,
               interpret: bool | None = None,
               out_dtype=jnp.complex64) -> EOContext:
    """Resolve the even-odd solve pieces for one (backend, batch) shape.

    This is the single place the parity gauge split, the field packing,
    the batch vmapping and the fused-engine choice are derived —
    everything downstream (the plan resolver, and through it the
    ``solve_wilson_eo*`` forwarders) composes these callables.  ``twist``
    selects the operator family's site term (0 = Wilson); the layout
    converters, batching and the fused engine are operator-agnostic and
    identical for every family.
    """
    if use_pallas:
        ops = eo_operators_packed(u, mass, r=r, twist=twist, bz=bz,
                                  interpret=interpret)

        def prepare(b: Array) -> tuple[Array, Array]:
            b_e, b_o = (jax.vmap(split_eo)(b) if batched else split_eo(b))
            return pack_spinor(b_e), pack_spinor(b_o)

        def finish(x_e: Array, x_o: Array) -> Array:
            xe = unpack_spinor(x_e, dtype=out_dtype)
            xo = unpack_spinor(x_o, dtype=out_dtype)
            return (jax.vmap(merge_eo)(xe, xo) if batched
                    else merge_eo(xe, xo))

        # local import: see eo_operators_packed
        from repro.kernels.cg_fused import fused_engine, fused_engine_batched
        engine = (fused_engine_batched(interpret=interpret) if batched
                  else fused_engine(interpret=interpret))
        return EOContext(ops=ops, prepare=prepare, finish=finish,
                         engine=engine, packed=True, batched=batched)

    ops = eo_operators(u, mass, r=r, twist=twist)
    if batched:
        # natural-layout blocks are single-RHS; vmap them (m_inv is
        # elementwise and batch-transparent already)
        ops = ops._replace(dhat=jax.vmap(ops.dhat),
                           dhat_dag=jax.vmap(ops.dhat_dag),
                           d_eo=jax.vmap(ops.d_eo),
                           d_oe=jax.vmap(ops.d_oe))

        return EOContext(ops=ops, prepare=jax.vmap(split_eo),
                         finish=jax.vmap(merge_eo), engine=None,
                         packed=False, batched=True)
    return EOContext(ops=ops, prepare=split_eo, finish=merge_eo,
                     engine=None, packed=False, batched=False)


# ---------------------------------------------------------------------------
# Legacy entry points — thin forwarders to the SolverPlan machinery
# ---------------------------------------------------------------------------


def solve_wilson_eo(u: Array, b: Array, mass, *, r: float = 1.0,
                    tol: float = 1e-8, maxiter: int = 1000,
                    dot=field_dot, norm2=field_norm2,
                    use_pallas: bool = False,
                    interpret: bool | None = None, bz: int | None = None,
                    ) -> tuple[Array, solvers.SolveStats]:
    """Solve D x = b by CGNR on the even-sublattice Schur complement.

    Same contract as a plain ``cgnr`` solve: natural-layout inputs, the
    merged full-lattice solution out, but the CG runs on half-size
    vectors against the better-conditioned reduced operator.

    Forwards to ``plan.solve`` with the equivalent
    ``SolverPlan(operator="eo-schur", backend=...)``; ``use_pallas=True``
    is the ``backend="pallas"`` fast path (packed real half fields,
    parity-hop stencil kernels, fused streaming vector algebra).
    ``interpret``/``bz`` tune the kernels (None = backend defaults).
    """
    from repro.core import plan as plan_mod  # forwarder; avoid import cycle
    p = plan_mod.SolverPlan(
        operator="eo-schur",
        backend="pallas" if use_pallas else "reference",
        r=r, bz=bz, interpret=interpret)
    return plan_mod.solve(p, u, b, mass, tol=tol, maxiter=maxiter,
                          dot=dot, norm2=norm2)


def solve_wilson_eo_batched(u: Array, b: Array, mass, *, r: float = 1.0,
                            tol: float = 1e-8, maxiter: int = 1000,
                            use_pallas: bool = True,
                            interpret: bool | None = None,
                            bz: int | None = None,
                            ) -> tuple[Array, solvers.SolveStats]:
    """Solve D x_n = b_n for a BATCH of right-hand sides in one CG loop.

    Args:
      u: (4, T, Z, Y, X, 3, 3) gauge field, shared by the whole batch —
        this sharing is the point: the matvec reads each gauge plane once
        per grid step and streams all N spinor planes through it, so the
        dslash arithmetic intensity grows with N (DESIGN.md §6).
      b: (N, T, Z, Y, X, 4, 3) batched RHS.
    Returns:
      (x, stats): x is (N, T, Z, Y, X, 4, 3); ``stats.iterations`` is the
      masked loop's trip count (= the slowest system's iterations) while
      ``stats.residual_norm2``/``stats.converged``/``stats.rhs_iterations``
      are per-RHS (N,).

    Per-RHS convergence masking freezes each system the iteration it
    meets ITS OWN ``tol``: the returned x_n is bitwise the iterate an
    independent single-RHS solve of b_n would have returned.  Forwards to
    ``plan.solve`` with ``SolverPlan(operator="eo-schur", nrhs=N)``;
    ``use_pallas`` selects the backend exactly as in
    :func:`solve_wilson_eo`.
    """
    if b.ndim != 7:  # a real exception, not assert: must survive `python -O`
        raise ValueError(
            f"batched RHS must be (N, T, Z, Y, X, 4, 3); got {b.shape}. "
            "For a single RHS use solve_wilson_eo (or add a leading axis).")
    from repro.core import plan as plan_mod  # forwarder; avoid import cycle
    p = plan_mod.SolverPlan(
        operator="eo-schur",
        backend="pallas" if use_pallas else "reference",
        nrhs=b.shape[0], r=r, bz=bz, interpret=interpret)
    return plan_mod.solve(p, u, b, mass, tol=tol, maxiter=maxiter)


def solve_wilson_eo_mp(u: Array, b: Array, mass, *, r: float = 1.0,
                       tol: float = 1e-6, inner_tol: float = 5e-2,
                       inner_maxiter: int = 200, max_outer: int = 50,
                       low_dtype=jnp.bfloat16, dot=field_dot,
                       norm2=field_norm2, use_pallas: bool = False,
                       interpret: bool | None = None, bz: int | None = None,
                       ) -> tuple[Array, solvers.SolveStats]:
    """Even-odd + mixed-precision: bf16 half-size inner CG, f32 updates.

    The low-precision representation is the bf16 real-pair view of the
    complex even half field (complex bf16 does not exist); links are
    rounded to bf16 once up front.  The inner CG's vector updates and
    stored iterates are bf16 while every contraction inside the operator
    still accumulates wide — narrow datapath, wide accumulator, as on
    the paper's FPGA.

    ``use_pallas=True`` keeps the WHOLE mixed-precision solve on the
    packed-field fast path: the low representation is simply the bf16
    packed real half field (kernels read bf16 storage and accumulate in
    f32 registers), so ``to_low``/``to_high`` are plain storage casts at
    the reliable-update boundary — once per outer cycle, on half fields —
    rather than standalone complex<->real-pair conversion passes, and the
    inner CG streams through the parity kernels + fused vector engine.
    Requires r = 1 (raises ``NotImplementedError`` otherwise; see
    :func:`eo_operators_packed` for the supported-parameter matrix).

    Forwards to ``plan.solve`` with ``SolverPlan(operator="eo-schur",
    precision="mixed", low=low_dtype)``.
    """
    from repro.core import plan as plan_mod  # forwarder; avoid import cycle
    p = plan_mod.SolverPlan(
        operator="eo-schur",
        backend="pallas" if use_pallas else "reference",
        precision="mixed", low=low_dtype, r=r, bz=bz, interpret=interpret)
    return plan_mod.solve(p, u, b, mass, tol=tol, inner_tol=inner_tol,
                          inner_maxiter=inner_maxiter, max_outer=max_outer,
                          dot=dot, norm2=norm2)
