"""SolverPlan — one declarative entry point for the whole solve stack.

The paper's architectural claim is that a well-factored CG framework
keeps the data-transport layer fixed while operators swap in; its
heterogeneous follow-up (arXiv:2111.14958) extends the same design
across communicating devices.  This module is that claim as code: a
:class:`SolverPlan` names a solve as data —

    {operator family, backend, batch shape, precision policy, mesh layout}

— and :func:`solve` resolves it to concrete operator blocks, a vector
engine, and reduction callables, then runs the right Krylov loop.  Every
historical entry point (``solve_wilson_eo``/``_mp``/``_batched``,
``distributed.solve_wilson``) is now a thin forwarder that builds the
equivalent plan, and every new scaling axis is a plan FIELD rather than
a new code path.

The physics is a plan field too: ``operator_family`` names a registered
:class:`repro.core.operators.LatticeOperator` ("wilson" default,
"twisted-mass" + ``mu``), and the resolver pulls the family's site term
from the registry — the hop transport underneath every row of the table
below is shared by all families.

Resolution table (DESIGN.md §7 carries the full version):

==========  =========  ======  =====  =========  ==========================
operator    backend    mesh    nrhs   precision  path
==========  =========  ======  =====  =========  ==========================
full        ref/pallas  None    N?    single     CGNR / pipelined CGNR on
                                                 D†D over packed fields
full        ref/pallas  None    N?    mixed/low  reliable-update mpcg /
                                                 all-low cg16
full        ref/pallas  mesh    —     any        shard_map + full-lattice
                                                 halo dslash (PR 0 path)
eo-schur    ref/pallas  None    N?    single     Schur CGNR, optionally
                                                 batched+masked, fused
                                                 Pallas engine on "pallas"
eo-schur    ref/pallas  None    —     mixed      Schur mpcg (bf16 inner)
eo-schur    ref/pallas  mesh    N?    single     parity-compressed halo
                                                 exchange; "pipecg" = ONE
                                                 fused psum per iteration
==========  =========  ======  =====  =========  ==========================

Layering: this module imports the building blocks (``eo_context``, the
halo operators in :mod:`repro.core.distributed`, the solvers) and owns
only orchestration; the legacy modules import *this* module lazily
inside their forwarders.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Mapping, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.core import distributed as dist
from repro.core import solvers
from repro.core.eo import (EOContext, back_substitute_odd, eo_context,
                           schur_rhs)
from repro.core.lattice import (complex_to_real_pair, field_dot,
                                field_norm2, field_norm2_batched, merge_eo,
                                pack_gauge, pack_spinor,
                                real_pair_to_complex, split_eo,
                                split_eo_gauge, unpack_spinor)
from repro.core.operators import (SiteTerm, dslash_g, get_operator,
                                  schur_normal_op_g, unknown_name)
from repro.core.precision import parse_dtype

Array = jax.Array

_OPERATORS = ("full", "eo-schur")
_BACKENDS = ("reference", "pallas")
_SOLVERS = ("cgnr", "pipecg", "blockcg")
_PRECISIONS = ("single", "mixed", "low")


@dataclasses.dataclass(frozen=True)
class SolverPlan:
    """A solve, described declaratively.

    Fields:
      operator:  "full" (CGNR on D†D over the full lattice) or "eo-schur"
        (CGNR on the half-size Schur complement — T3's algorithmic
        reduction).
      operator_family: which registered lattice operator to apply —
        "wilson" (default) or "twisted-mass" (see
        :mod:`repro.core.operators`).  The family contributes ONLY its
        site-local term; the hop transport, batching, precision packing
        and halo exchange are shared by every family.
      mu: the twisted-mass site parameter (``i·mu·γ5`` diagonal term);
        only meaningful for families that declare it (validation rejects
        a nonzero ``mu`` for families that don't).
      backend:   "reference" (jnp, the paper's CPU debugging path) or
        "pallas" (plane-streaming stencil kernels + fused vector engine).
      solver:    "cgnr", "pipecg" (pipelined: ONE fused reduction per
        iteration — T4 at cluster scale) or "blockcg" (block CGNR: the N
        right-hand sides share one Krylov search space through N×N Gram
        solves — fewer iterations, not just cheaper ones; requires
        ``nrhs``, single precision, single device; DESIGN.md §12).
      precision: "single", "mixed" (reliable-update mpcg: bulk iterations
        in ``low``, true residuals wide) or "low" (all-low cg16 — the
        measurement rig for mpcg's inner-loop cost, full operator only).
      low:       the narrow dtype (name or jnp dtype) for mixed/low.
      nrhs:      None for a single RHS, or N — the solve carries a leading
        RHS-batch axis through one masked CG loop (gauge reads amortized
        across the batch, DESIGN.md §6).
      mesh/axis_map: None for single-device, or a device mesh (+ optional
        {lattice axis: mesh axis name} override) — the solve runs under
        ``shard_map`` with halo-corrected local stencils and psum-fused
        reductions.
      r, bz, interpret: Wilson parameter and kernel tuning knobs
        (backend="pallas" requires r=1; see ``eo_operators_packed``).
    """

    operator: str = "eo-schur"
    operator_family: str = "wilson"
    mu: float = 0.0
    backend: str = "reference"
    solver: str = "cgnr"
    precision: str = "single"
    low: object = "bfloat16"
    nrhs: int | None = None
    mesh: Mesh | None = None
    axis_map: Mapping[int, str] | None = None
    r: float = 1.0
    bz: int | None = None
    interpret: bool | None = None

    def __post_init__(self):
        for name, value, allowed in (("operator", self.operator, _OPERATORS),
                                     ("backend", self.backend, _BACKENDS),
                                     ("solver", self.solver, _SOLVERS),
                                     ("precision", self.precision,
                                      _PRECISIONS)):
            if value not in allowed:
                raise ValueError("SolverPlan: " + unknown_name(
                    f"SolverPlan.{name}", value, allowed))
        spec = get_operator(self.operator_family)  # did-you-mean on unknown
        if self.mu != 0.0 and "mu" not in spec.params:
            raise ValueError(
                f"SolverPlan: operator family {spec.name!r} has no site "
                f"parameter 'mu' (got mu={self.mu}); pick a family that "
                "declares it, e.g. operator_family='twisted-mass'")
        if self.precision in ("mixed", "low") and self.solver in ("pipecg",
                                                                  "blockcg"):
            raise ValueError(
                "SolverPlan: the mixed/low precision paths use the "
                f"reliable-update CG loop; solver={self.solver!r} composes "
                "with precision='single' only")
        if self.solver == "blockcg" and self.nrhs is None:
            raise ValueError(
                "SolverPlan: solver='blockcg' shares one Krylov space "
                "across a batch of right-hand sides; set nrhs (a single "
                "RHS has nothing to share — use solver='cgnr')")
        if self.precision == "low" and self.operator != "full":
            raise ValueError(
                "SolverPlan: precision='low' (all-low cg16) exists for the "
                "full operator only")
        if self.nrhs is not None and self.nrhs < 1:
            raise ValueError(f"SolverPlan.nrhs must be >= 1, got {self.nrhs}")

    @property
    def batched(self) -> bool:
        return self.nrhs is not None

    def cache_key(self) -> tuple:
        """The plan's hashable identity — every trace-time axis of a solve.

        Two plans with equal keys resolve to the same compiled program
        (same operator family and ``mu`` epilogues, backend, batch rung,
        precision policy, mesh layout and kernel knobs), so a compiled
        solve callable may be shared between them.  This is the cache key
        of both the sharded-solver cache below and the serving layer's
        :class:`repro.serve.plan_cache.PlanCache`.  ``axis_map`` may be a
        plain (unhashable) dict, hence the sorted-tuple normalization;
        ``mesh`` hashes by device identity.
        """
        axis_map = (None if self.axis_map is None
                    else tuple(sorted(self.axis_map.items())))
        return (self.operator, self.operator_family, self.mu, self.backend,
                self.solver, self.precision, str(self.low), self.nrhs,
                self.mesh, axis_map, self.r, self.bz, self.interpret)

    @property
    def low_dtype(self):
        return parse_dtype(self.low)

    @property
    def twist(self) -> float:
        """The family's site-term twist — the ONE number the transport
        stack needs from the registry (0.0 for Wilson: every consumer
        then emits the historical program bitwise).  Derived from the
        registered ``make_site_term`` (evaluated at mass 0 — a family's
        twist is mass-independent), NOT from any hardcoded parameter
        name, so a family mapping its declared params to the twist
        differently is honoured."""
        return float(self.site_term(0.0).twist)

    def site_term(self, mass) -> SiteTerm:
        """The family's site-local diagonal block for a given bare mass."""
        spec = get_operator(self.operator_family)
        kw = {name: getattr(self, name) for name in spec.params}
        return spec.make_site_term(mass, self.r, **kw)


def _family_site(plan: SolverPlan, mass) -> SiteTerm:
    """The family's site term from the registry, transport-contract checked.

    The transport stack folds the site SCALE as ``mass + 4r`` at kernel
    trace time, so a registered family may vary only the twist; a family
    declaring any other scale fails loudly here instead of being
    silently solved with the Wilson scale.  (Lifting this needs a
    kernel-level scale parameter first.)
    """
    site = plan.site_term(float(mass))
    expected = float(mass) + 4.0 * plan.r
    if float(site.scale) != expected:
        raise NotImplementedError(
            f"operator family {plan.operator_family!r} declared site "
            f"scale {float(site.scale)!r} but the transport kernels fold "
            f"mass + 4r = {expected!r} at trace time; a family with a "
            "different scale needs a kernel-level scale parameter")
    return site


def resolve(plan: SolverPlan, u: Array, mass, *,
            out_dtype=jnp.complex64) -> EOContext:
    """Resolve a single-device even-odd plan to its concrete callables.

    Returns the :class:`repro.core.eo.EOContext` — bound parity blocks,
    layout converters and the fused vector engine — that :func:`solve`
    iterates with.  Mesh plans resolve per-shard inside ``shard_map``
    (the blocks close over local shards) and full-operator plans bind
    the packed normal operator directly; both happen inside
    :func:`solve`.
    """
    if plan.operator != "eo-schur":
        raise ValueError("resolve() returns the even-odd context; "
                         f"plan.operator={plan.operator!r} resolves inside "
                         "solve()")
    return eo_context(u, mass, r=plan.r,
                      twist=_family_site(plan, mass).twist,
                      use_pallas=plan.backend == "pallas",
                      batched=plan.batched, bz=plan.bz,
                      interpret=plan.interpret, out_dtype=out_dtype)


# Post-solve verification gate: the recomputed TRUE residual must satisfy
# ‖b - D x‖ ≤ VERIFY_FACTOR · tol · ‖b‖.  The slack absorbs the gap
# between the CGNR stopping rule (residual of the NORMAL equations) and
# the original system's residual; a solve that misses even this relaxed
# gate cannot be trusted regardless of what the solver's own recurrence
# claimed (see DESIGN.md §10).
VERIFY_FACTOR = 10.0


def _attach_verification(plan: SolverPlan, u: Array, b: Array, mass,
                         x: Array, stats: solvers.SolveStats, tol,
                         layout: str = "natural") -> solvers.SolveStats:
    """One extra matvec: recompute the true residual of ``D x = b``.

    The oracle is the operator REGISTRY's natural-layout ``dslash_g``
    (packed solves verify through the packed transport's ``dslash``, the
    same operator on the wire format) — deliberately independent of the
    Schur/normal-equation transforms the solver iterated on, so a broken
    transport cannot vouch for itself.  Fills ``true_residual_norm2`` and
    ``verified`` on the stats and upgrades the verdict to NONFINITE when
    the true residual is not finite.  Runs entirely on device — inside a
    jitted plan callable it adds zero host syncs and exactly one operator
    application after the iteration loop.
    """
    site = _family_site(plan, mass)
    if layout == "packed":
        # u/b/x are packed real fields here; wops.dslash takes a leading
        # RHS-batch axis natively
        from repro.kernels.wilson_dslash import ops as wops
        ax = wops.dslash(u, x, float(mass), twist=site.twist, bz=plan.bz,
                         interpret=plan.interpret,
                         use_pallas=plan.backend == "pallas")
    else:
        apply_d = lambda v: dslash_g(u, v, mass, r=plan.r, twist=site.twist)
        ax = jax.vmap(apply_d)(x) if plan.batched else apply_d(x)
    r_true = b - ax.astype(b.dtype)
    norm2_fn = field_norm2_batched if plan.batched else field_norm2
    rs_true = jnp.real(norm2_fn(r_true))
    bs = jnp.real(norm2_fn(b))
    tol_a = jnp.asarray(tol).astype(rs_true.dtype)
    gate = (VERIFY_FACTOR * tol_a) ** 2 * bs
    finite = jnp.isfinite(rs_true)
    verified = jnp.logical_and(rs_true <= gate, finite)
    verdict = stats.verdict
    if verdict is not None:
        verdict = jnp.where(finite, verdict,
                            jnp.asarray(solvers.NONFINITE, verdict.dtype))
    return stats._replace(true_residual_norm2=rs_true, verified=verified,
                          verdict=verdict)


def solve(plan: SolverPlan, u: Array, b: Array, mass, *,
          tol: float = 1e-8, maxiter: int = 1000,
          inner_tol: float = 5e-2, inner_maxiter: int = 200,
          max_outer: int = 50, residual_replacement_every: int = 25,
          dot=field_dot, norm2=field_norm2,
          layout: str = "natural",
          verify: bool = True,
          checkpoint: "CheckpointPolicy | None" = None,
          deflation: "solvers.DeflationBasis | None" = None,
          ) -> tuple[Array, solvers.SolveStats]:
    """Execute a :class:`SolverPlan`: the single entry point of the stack.

    Args:
      u, b: gauge field and right-hand side(s).  ``layout="natural"``
        (complex (4,T,Z,Y,X,3,3) / (T,Z,Y,X,4,3), leading N axis when
        ``plan.nrhs``) is the default contract; ``layout="packed"``
        accepts/returns packed real fields for the full operator (the
        legacy ``distributed.solve_wilson`` contract).
      tol/maxiter: CG stopping rule (relative, per-RHS when batched).
      inner_*/max_outer: reliable-update knobs (precision="mixed").
      residual_replacement_every: pipecg drift control.
      dot/norm2: injectable reductions (single-device plans; mesh plans
        build their own psum-fused reductions).
      verify: attach the post-solve true-residual verification matvec
        (one extra operator application AFTER the iteration loop; the
        default).  ``False`` is for callers that verify the solution
        themselves (e.g. the retry ladder, which checks the accumulated
        iterate against the original system) — they must not treat the
        returned x as trusted.
      checkpoint: a :class:`CheckpointPolicy` makes the solve DURABLE —
        the identical while-loop body runs in segments of at most
        ``every_iters`` iterations, snapshotting ``(x, iteration,
        verdict, rhs_mask)`` to ``checkpoint.dir`` between segments (see
        :func:`loop_program`; DESIGN.md §11).  ``None`` (the default)
        runs the historical single-while-loop program.
      deflation: a :class:`solvers.DeflationBasis` harvested by
        :func:`harvest_deflation` on the SAME (gauge, family, mu, mass,
        backend) — the RHS is Galerkin-projected against the basis and
        the CG loop starts from the x₀ correction, cutting the iteration
        count by the deflated low modes (DESIGN.md §12).  Composes with
        the single-precision cg paths ("cgnr"/"blockcg", no mesh, no
        checkpoint); the post-solve verification still gates against the
        ORIGINAL system, so a stale or wrong basis fails loudly instead
        of returning an unconverged x.
    Returns:
      (x, SolveStats) — solution in the input layout; per-RHS stats
      fields (residual_norm2/converged/rhs_iterations) when batched.
    """
    if layout not in ("natural", "packed"):
        raise ValueError(f"layout must be 'natural' or 'packed', "
                         f"got {layout!r}")
    if layout == "packed" and plan.operator != "full":
        raise ValueError("layout='packed' is the full-operator contract; "
                         "the even-odd paths take natural-layout fields")
    _check_batch_shape(plan, b, layout)
    if deflation is not None and (
            plan.mesh is not None or checkpoint is not None
            or plan.solver == "pipecg" or plan.precision != "single"):
        raise NotImplementedError(
            "deflation composes with the single-device single-precision "
            "cg paths (solver='cgnr'/'blockcg', no checkpoint); got "
            f"solver={plan.solver!r} precision={plan.precision!r} "
            f"mesh={'set' if plan.mesh is not None else None} "
            f"checkpoint={'set' if checkpoint is not None else None}")
    if checkpoint is not None:
        return _solve_checkpointed(
            plan, u, b, mass, checkpoint=checkpoint, tol=tol,
            maxiter=maxiter, inner_tol=inner_tol,
            inner_maxiter=inner_maxiter, max_outer=max_outer,
            residual_replacement_every=residual_replacement_every,
            dot=dot, norm2=norm2, layout=layout, verify=verify)
    kw = dict(tol=tol, maxiter=maxiter, inner_tol=inner_tol,
              inner_maxiter=inner_maxiter, max_outer=max_outer,
              residual_replacement_every=residual_replacement_every,
              dot=dot, norm2=norm2)
    if plan.mesh is not None:
        if plan.solver == "blockcg":
            raise NotImplementedError(
                "blockcg is single-device (its N×N Gram einsums contract "
                "unsharded site axes); drop the mesh or use solver='cgnr'")
        if plan.operator == "eo-schur":
            if plan.precision != "single":
                raise NotImplementedError(
                    "sharded eo-schur supports precision='single' (the "
                    "mixed-precision Schur solve is single-device for now)")
            x, stats = _solve_eo_sharded(plan, u, b, mass, **kw)
        else:
            if plan.batched:
                raise NotImplementedError(
                    "sharded full-operator solves are single-RHS; use "
                    "operator='eo-schur' for the sharded batched fast path")
            x, stats = _solve_full_sharded(plan, u, b, mass, layout=layout,
                                           **kw)
    elif plan.operator == "eo-schur":
        if plan.precision == "mixed":
            if plan.batched:
                raise NotImplementedError(
                    "batched mixed-precision eo-schur is not wired yet; "
                    "drop nrhs or precision")
            x, stats = _solve_eo_mp(plan, u, b, mass, **kw)
        else:
            x, stats = _solve_eo(plan, u, b, mass, deflation=deflation,
                                 **kw)
    else:
        x, stats = _solve_full(plan, u, b, mass, layout=layout,
                               deflation=deflation, **kw)
    if verify:
        stats = _attach_verification(plan, u, b, mass, x, stats, tol,
                                     layout=layout)
    return x, stats


def _check_batch_shape(plan: SolverPlan, b: Array, layout: str):
    base = 6 if layout == "natural" else 5
    want = base + 1 if plan.batched else base
    if b.ndim != want:
        raise ValueError(
            f"plan.nrhs={plan.nrhs} expects a rank-{want} {layout} RHS, "
            f"got shape {b.shape}")
    if plan.batched and b.shape[0] != plan.nrhs:
        raise ValueError(f"plan.nrhs={plan.nrhs} but RHS batch axis has "
                         f"extent {b.shape[0]}")


def harvest_deflation(plan: SolverPlan, u: Array, b: Array, mass, *,
                      tol: float = 1e-8, maxiter: int = 1000, nev: int = 8,
                      m_max: int = 48, verify_tol: float | None = None,
                      ) -> tuple[Array, "solvers.SolveStats",
                                 "solvers.DeflationBasis"]:
    """Solve ONE system and harvest a :class:`solvers.DeflationBasis`.

    Runs :func:`solvers.cg_harvest` (bitwise the plain CG trajectory, one
    Lanczos-vector buffer write per iteration) on the plan's Schur normal
    operator, then condenses the recorded Lanczos data into the ``nev``
    smallest Ritz pairs eagerly on the host (the harvest count is a
    concrete loop exit, not a traced value).  The basis lives in the
    plan's WORKING layout — reuse it only via ``plan.solve(...,
    deflation=basis)`` on a plan with the same ``cache_key()`` and the
    same ``(u, mass)``; the serving layer keys its deflation cache
    accordingly (DESIGN.md §12).

    Returns ``(x, stats, basis)`` — ``stats.matvecs`` includes the
    ``min(nev, iterations)`` extra operator applications spent projecting
    the basis (``WᴴAW``), so benchmark accounting charges the harvest
    cost to the harvest solve.  Verification runs against the ORIGINAL
    system exactly as in :func:`solve`, gated at ``verify_tol``
    (default: ``tol``) — a deep harvest deliberately iterates past the
    serving tolerance to mine spectral data, and single precision cannot
    push the TRUE residual below ~1e-7 relative no matter how far the
    recursive residual falls, so the honest verification gate for a
    harvest driven to 1e-8 is the tolerance its ``x`` is actually served
    or compared at.

    Single-device, single-precision, single-RHS eo-schur only: the
    harvest records live alongside an unbatched CG loop.
    """
    if (plan.operator != "eo-schur" or plan.precision != "single"
            or plan.batched or plan.mesh is not None):
        raise NotImplementedError(
            "harvest_deflation needs the single-device single-precision "
            "unbatched eo-schur path; got "
            f"operator={plan.operator!r} precision={plan.precision!r} "
            f"nrhs={plan.nrhs} mesh="
            f"{'set' if plan.mesh is not None else None}")
    ctx = resolve(plan, u, mass, out_dtype=b.dtype)
    b_e, b_o = ctx.prepare(b)
    ops = ctx.ops
    a_hat = lambda v: ops.dhat_dag(ops.dhat(v))
    rhs = schur_rhs(ops, b_e, b_o)
    x_e, stats, (vbuf, albuf, bebuf) = solvers.cg_harvest(
        a_hat, rhs, tol=tol, maxiter=maxiter, m_max=m_max)
    k = int(jax.device_get(stats.iterations))
    basis = solvers.ritz_deflation_basis(a_hat, vbuf, albuf, bebuf, k, nev)
    n_eff = max(1, min(nev, min(k, int(m_max))))
    stats = stats._replace(matvecs=stats.matvecs + n_eff)
    x_o = back_substitute_odd(ops, b_o, x_e)
    x = ctx.finish(x_e, x_o)
    stats = _attach_verification(
        plan, u, b, mass, x, stats,
        tol if verify_tol is None else float(verify_tol), layout="natural")
    return x, stats, basis


# ---------------------------------------------------------------------------
# Single-device even-odd paths
# ---------------------------------------------------------------------------


def _solve_eo(plan, u, b, mass, *, tol, maxiter, dot, norm2,
              residual_replacement_every, deflation=None, **_):
    ctx = resolve(plan, u, mass, out_dtype=b.dtype)
    b_e, b_o = ctx.prepare(b)
    ops = ctx.ops
    if plan.solver == "pipecg":
        # pipelined CGNR on the Schur normal equations: same reduction and
        # back-substitution as cgnr_eo, the pipelined loop in the middle
        # (pipecg has no update/xpay engine hooks — its three-term
        # recurrence is a different vector-algebra shape).
        b_hat = b_e - ops.d_eo(ops.m_inv(b_o))
        x_e, stats = solvers.pipecg(
            lambda v: ops.dhat_dag(ops.dhat(v)), ops.dhat_dag(b_hat),
            tol=tol, maxiter=maxiter,
            residual_replacement_every=residual_replacement_every,
            dot=dot, norm2=norm2, batched=ctx.batched)
        x_o = ops.m_inv(b_o - ops.d_oe(x_e))
    elif plan.solver == "blockcg":
        rhs = schur_rhs(ops, b_e, b_o)
        x0 = None
        if deflation is not None:
            x0 = solvers.deflate_x0(deflation, rhs)
        x_e, stats = solvers.blockcg(
            lambda v: ops.dhat_dag(ops.dhat(v)), rhs, x0,
            tol=tol, maxiter=maxiter, norm2=norm2)
        x_o = back_substitute_odd(ops, b_o, x_e)
    else:
        engine = {}
        if ctx.engine is not None:
            engine = dict(update=ctx.engine[0], xpay=ctx.engine[1])
        x0 = None
        if deflation is not None:
            x0 = solvers.deflate_x0(deflation, schur_rhs(ops, b_e, b_o))
        (x_e, x_o), stats = solvers.cgnr_eo(
            ops.dhat, ops.dhat_dag, ops.d_eo, ops.d_oe, ops.m_inv,
            b_e, b_o, x0=x0, tol=tol, maxiter=maxiter, dot=dot,
            norm2=norm2, batched=ctx.batched, **engine)
    return ctx.finish(x_e, x_o), stats


def _solve_eo_mp(plan, u, b, mass, *, tol, maxiter, inner_tol,
                 inner_maxiter, max_outer, dot, norm2, **_):
    """Even-odd + mixed precision: low-storage inner CG, wide updates.

    Packed backend: the low representation is the packed half field in
    ``low`` storage (kernels read narrow, accumulate f32), casts only at
    reliable-update boundaries.  Reference backend: bf16 real-pair view
    of the complex half field, links rounded once up front.
    """
    low_dtype = plan.low_dtype
    twist = _family_site(plan, mass).twist
    ctx = resolve(plan, u, mass, out_dtype=b.dtype)
    b_e, b_o = ctx.prepare(b)
    ops = ctx.ops
    if ctx.packed:
        # local import: see eo_operators_packed
        from repro.kernels.wilson_dslash import ops as wops

        high = b_e.dtype
        # one up-front rounding of the links — the low operator's gauge
        # reads then stream bf16 (half the gauge HBM traffic), wide inside.
        u_e_lo = ops.u_e.astype(low_dtype)
        u_o_lo = ops.u_o.astype(low_dtype)
        kkw = dict(twist=twist, bz=plan.bz, interpret=plan.interpret)

        def a_low(w):  # low storage in/out, f32 registers inside
            return wops.schur_normal_op(u_e_lo, u_o_lo, w, mass, **kkw)

        def a_high(v):
            return wops.schur_normal_op(ops.u_e, ops.u_o, v, mass, **kkw)

        to_low = lambda v: v.astype(low_dtype)
        to_high = lambda w: w.astype(high)
    else:
        high = b.dtype

        def round_links(w):
            pair = complex_to_real_pair(w, dtype=low_dtype)
            return real_pair_to_complex(pair, dtype=w.dtype)

        u_e_lo, u_o_lo = round_links(ops.u_e), round_links(ops.u_o)

        def a_low(w):  # bf16 real-pair in/out, wide inside
            v = real_pair_to_complex(w, dtype=high)
            av = schur_normal_op_g(u_e_lo, u_o_lo, v, mass, r=plan.r,
                                   twist=twist)
            return complex_to_real_pair(av, dtype=low_dtype)

        def a_high(v):
            return schur_normal_op_g(ops.u_e, ops.u_o, v, mass, r=plan.r,
                                     twist=twist)

        to_low = lambda v: complex_to_real_pair(v, dtype=low_dtype)
        to_high = lambda w: real_pair_to_complex(w, dtype=high)

    engine = {}
    if ctx.engine is not None:
        engine = dict(update=ctx.engine[0], xpay=ctx.engine[1])
    (x_e, x_o), stats = solvers.mpcg_eo(
        a_low, a_high, ops.dhat_dag, ops.d_eo, ops.d_oe, ops.m_inv,
        b_e, b_o, tol=tol, inner_tol=inner_tol,
        inner_maxiter=inner_maxiter, max_outer=max_outer,
        low_dtype=low_dtype, to_low=to_low, to_high=to_high,
        dot=dot, norm2=norm2, **engine)
    return ctx.finish(x_e, x_o), stats


# ---------------------------------------------------------------------------
# Full-operator paths (packed working layout)
# ---------------------------------------------------------------------------


def _solve_full(plan, u, b, mass, *, tol, maxiter, inner_tol,
                inner_maxiter, max_outer, residual_replacement_every,
                dot, norm2, layout, deflation=None):
    # local import: see eo_operators_packed
    from repro.kernels.wilson_dslash import ops as wops

    packed_in = layout == "packed"
    up = u if packed_in else pack_gauge(u)
    pp = b if packed_in else pack_spinor(b)
    m = float(mass)
    kw = dict(twist=_family_site(plan, mass).twist, bz=plan.bz,
              interpret=plan.interpret,
              use_pallas=plan.backend == "pallas")
    op_hi = lambda v: wops.normal_op(up, v, m, **kw)
    rhs = wops.dslash_dagger(up, pp, m, **kw)
    batched = plan.batched
    x0 = None
    if deflation is not None:
        x0 = solvers.deflate_x0(deflation, rhs)
    if plan.precision == "single":
        if plan.solver == "pipecg":
            x, stats = solvers.pipecg(
                op_hi, rhs, tol=tol, maxiter=maxiter,
                residual_replacement_every=residual_replacement_every,
                dot=dot, norm2=norm2, batched=batched)
        elif plan.solver == "blockcg":
            x, stats = solvers.blockcg(op_hi, rhs, x0, tol=tol,
                                       maxiter=maxiter, norm2=norm2)
        else:
            x, stats = solvers.cg(op_hi, rhs, x0, tol=tol, maxiter=maxiter,
                                  dot=dot, norm2=norm2, batched=batched)
    else:
        low_dtype = plan.low_dtype
        up_lo = up.astype(low_dtype)
        op_lo = lambda v: wops.normal_op(up_lo, v, m, **kw)
        if plan.precision == "mixed":
            x, stats = solvers.mpcg(op_lo, op_hi, rhs, tol=tol,
                                    inner_tol=inner_tol,
                                    inner_maxiter=inner_maxiter,
                                    max_outer=max_outer, low_dtype=low_dtype,
                                    dot=dot, norm2=norm2, batched=batched)
        else:  # "low": all-low cg16 — NOT accurate to tol; a measurement rig
            x, stats = solvers.cg(op_lo, rhs.astype(low_dtype), tol=tol,
                                  maxiter=maxiter, dot=dot, norm2=norm2,
                                  batched=batched)
            x = x.astype(pp.dtype)
    if packed_in:
        return x, stats
    return unpack_spinor(x, dtype=b.dtype), stats


def _solve_full_sharded(plan, u, b, mass, *, tol, maxiter, inner_tol,
                        inner_maxiter, max_outer,
                        residual_replacement_every, dot, norm2, layout):
    """The PR-0 distributed path: full-lattice halo dslash under shard_map."""
    import functools

    mesh = plan.mesh
    packed_in = layout == "packed"
    up = u if packed_in else pack_gauge(u)
    pp = b if packed_in else pack_spinor(b)
    psi_spec, gauge_spec, sharded = dist.lattice_specs(mesh, plan.axis_map)
    pdot, pnorm2 = dist.make_psum_dots(mesh)
    use_pallas = plan.backend == "pallas"
    low_dtype = plan.low_dtype
    r = plan.r
    twist = _family_site(plan, mass).twist

    def local_solve(up_l, b_l):
        op = functools.partial(dist.normal_op_halo, mass=mass,
                               sharded=sharded, r=r, use_pallas=use_pallas,
                               twist=twist)
        rhs = dist.dslash_dagger_halo(up_l, b_l, mass, sharded, r=r,
                                      use_pallas=use_pallas, twist=twist)
        if plan.precision == "mixed":
            up_low = up_l.astype(low_dtype)
            return solvers.mpcg(
                lambda v: op(up_low, v), lambda v: op(up_l, v), rhs,
                tol=tol, inner_tol=inner_tol, inner_maxiter=inner_maxiter,
                max_outer=max_outer, low_dtype=low_dtype,
                dot=pdot, norm2=pnorm2)
        if plan.precision == "low":
            # pure low-precision CG (no reliable updates): NOT accurate to
            # tol — exists to measure the low-precision iteration cost that
            # mpcg's inner loop pays (EXPERIMENTS.md §Perf H3)
            up_low = up_l.astype(low_dtype)
            x, st = solvers.cg(lambda v: op(up_low, v),
                               rhs.astype(low_dtype), tol=tol,
                               maxiter=maxiter, dot=pdot, norm2=pnorm2)
            return x.astype(b_l.dtype), st
        if plan.solver == "pipecg":
            return solvers.pipecg(
                lambda v: op(up_l, v), rhs, tol=tol, maxiter=maxiter,
                residual_replacement_every=residual_replacement_every,
                dot=pdot, norm2=pnorm2,
                fused_dots=dist.make_fused_psum_dots(mesh))
        return solvers.cg(lambda v: op(up_l, v), rhs, tol=tol,
                          maxiter=maxiter, dot=pdot, norm2=pnorm2)

    stats_spec = solvers.SolveStats(P(), P(), P(), P(), None, verdict=P(),
                                    matvecs=P())
    shmapped = compat.shard_map(
        local_solve, mesh=mesh,
        in_specs=(gauge_spec, psi_spec),
        out_specs=(psi_spec, stats_spec),
        check_vma=False)
    x, stats = jax.jit(shmapped)(up, pp)
    if packed_in:
        return x, stats
    return unpack_spinor(x, dtype=b.dtype), stats


# ---------------------------------------------------------------------------
# Sharded even-odd Schur path: the distributed fast path
# ---------------------------------------------------------------------------


def _solve_eo_sharded(plan, u, b, mass, *, tol, maxiter,
                      residual_replacement_every, **_):
    """Even-odd Schur CGNR across a device mesh.

    The CG runs under ``shard_map`` on parity-compressed PACKED half
    fields: the matvec is :func:`repro.core.distributed.schur_normal_op_
    halo` (bulk local hop kernels + boundary-plane halo corrections), the
    reductions are psum-fused across mesh AND batch, and with
    ``solver="pipecg"`` each iteration issues exactly ONE collective
    (jaxpr-asserted in tests/test_distributed.py).  The RHS-batch axis is
    never sharded, so every gauge halo plane travels once per direction
    regardless of N.
    """
    batched = plan.batched
    upe, upo, pb_e, pb_o = _eo_sharded_prep(plan, u, b)
    solver = _sharded_eo_solver(plan, float(mass), float(tol), int(maxiter),
                                int(residual_replacement_every))
    x_e, x_o, stats = solver(upe, upo, pb_e, pb_o)
    xe = unpack_spinor(x_e, dtype=b.dtype)
    xo = unpack_spinor(x_o, dtype=b.dtype)
    x = jax.vmap(merge_eo)(xe, xo) if batched else merge_eo(xe, xo)
    return x, stats


def _eo_sharded_prep(plan: SolverPlan, u: Array, b: Array):
    """Validate a sharded even-odd plan and shard its packed parity fields.

    Returns ``(upe, upo, pb_e, pb_o)`` device_put with the mesh shardings
    — the common front half of the one-shot sharded solve AND the
    segmented program (which re-enters shard_map once per segment over
    the same resident shards).
    """
    mesh = plan.mesh
    batched = plan.batched
    if plan.r != 1.0:
        # BOTH backends: the halo corrections (hop_term_packed with the
        # default projectors), the reference hop blocks and the kernels
        # all assume r=1 on this path — fail, never answer wrongly.
        raise NotImplementedError(
            "the sharded parity stack hard-codes r=1 (bulk blocks AND "
            f"boundary corrections); got r={plan.r}. Use the single-device "
            "natural-layout path for r != 1.")
    psi_spec, gauge_spec, sharded = dist.lattice_specs(mesh, plan.axis_map)
    dims = b.shape[1:4] if batched else b.shape[:3]
    for mu, (ax, n) in sorted(sharded.items()):
        ext = dims[mu]
        if ext % n or (ext // n) % 2:
            raise ValueError(
                "sharded even-odd needs EVEN local extents (shard origins "
                "then have even global parity, so each device's local row "
                f"offsets equal the global ones); lattice axis {mu} has "
                f"extent {ext} over {n} '{ax}' shards")

    # global prep in natural layout, then shard the packed parity fields
    u_e, u_o = split_eo_gauge(u)
    upe, upo = pack_gauge(u_e), pack_gauge(u_o)
    b_e, b_o = (jax.vmap(split_eo)(b) if batched else split_eo(b))
    pb_e, pb_o = pack_spinor(b_e), pack_spinor(b_o)
    bspec = P(None, *psi_spec) if batched else psi_spec
    gput = lambda a: jax.device_put(a, NamedSharding(mesh, gauge_spec))
    sput = lambda a: jax.device_put(a, NamedSharding(mesh, bspec))
    return gput(upe), gput(upo), sput(pb_e), sput(pb_o)


# (plan identity, solve params) -> jitted shard_map'd solve.  Reusing the
# SAME jitted callable across calls is what makes repeated solves (and the
# benchmark's warm-up) hit the compilation cache instead of re-tracing a
# fresh shard_map closure every time.
_SHARDED_EO_CACHE: dict = {}


def _sharded_eo_solver(plan: SolverPlan, mass: float, tol: float,
                       maxiter: int, residual_replacement_every: int):
    key = (plan.cache_key(), mass, tol, maxiter, residual_replacement_every)
    cached = _SHARDED_EO_CACHE.get(key)
    if cached is not None:
        return cached
    mesh = plan.mesh
    batched = plan.batched
    psi_spec, gauge_spec, sharded = dist.lattice_specs(mesh, plan.axis_map)
    bspec = P(None, *psi_spec) if batched else psi_spec
    site = _family_site(plan, mass)  # registry site term, contract-checked
    twist = site.twist
    kkw = dict(sharded=sharded, use_pallas=plan.backend == "pallas",
               bz=plan.bz, interpret=plan.interpret)
    skw = dict(twist=twist, **kkw)
    pdot, pnorm2 = dist.make_psum_dots(mesh, batched=batched)

    def local_solve(upe_l, upo_l, pbe_l, pbo_l):
        d_eo = lambda v: dist.parity_hop_halo("eo", upe_l, upo_l, v, **kkw)
        d_oe = lambda v: dist.parity_hop_halo("oe", upe_l, upo_l, v, **kkw)
        dhat_dag = lambda v: dist.schur_op_halo(upe_l, upo_l, v, mass,
                                                dagger=True, **skw)
        a_hat = lambda v: dist.schur_normal_op_halo(upe_l, upo_l, v, mass,
                                                    **skw)
        m_inv = site.solve
        b_hat = pbe_l - d_eo(m_inv(pbo_l))
        rhs = dhat_dag(b_hat)
        if plan.solver == "pipecg":
            x_e, st = solvers.pipecg(
                a_hat, rhs, tol=tol, maxiter=maxiter,
                residual_replacement_every=residual_replacement_every,
                dot=pdot, norm2=pnorm2, batched=batched,
                fused_dots=dist.make_fused_psum_dots(mesh, batched=batched))
        else:
            x_e, st = solvers.cg(a_hat, rhs, tol=tol, maxiter=maxiter,
                                 dot=pdot, norm2=pnorm2, batched=batched)
        x_o = m_inv(pbo_l - d_oe(x_e))
        return x_e, x_o, st

    stats_spec = solvers.SolveStats(P(), P(), P(), P(),
                                    P() if batched else None,
                                    verdict=P(), matvecs=P())
    solver = jax.jit(compat.shard_map(
        local_solve, mesh=mesh,
        in_specs=(gauge_spec, gauge_spec, bspec, bspec),
        out_specs=(bspec, bspec, stats_spec),
        check_vma=False))
    _SHARDED_EO_CACHE[key] = solver
    return solver


# ---------------------------------------------------------------------------
# Segmented solving — durability without touching the hot loop (DESIGN.md §11)
# ---------------------------------------------------------------------------
#
# A CheckpointPolicy runs the SAME ``lax.while_loop`` body in segments of
# at most ``every_iters`` iterations and snapshots ``(x, iteration,
# verdict, rhs_mask)`` between segments.  The decomposition lives in
# ``solvers.LoopParts``: the segmented stopping rule is the solver's own
# ``cond`` AND an iteration bound, so the while-loop BODY jaxpr is bitwise
# identical to the unsegmented solve (asserted in
# tests/test_checkpoint_resume.py) and there are zero host syncs inside
# the loop — all snapshot I/O happens at segment boundaries on the host.


@dataclasses.dataclass(frozen=True)
class CheckpointPolicy:
    """How a durable solve checkpoints.

    Fields:
      dir:         checkpoint directory (``step_<N>`` subdirs; see
        :mod:`repro.checkpoint.ckpt`).
      every_iters: segment length — snapshot after at most this many
        iterations (inner iterations for precision="mixed", whose
        segments end at reliable-update boundaries and may overshoot by
        one inner solve).
      keep:        how many newest checkpoints to retain; older steps are
        pruned after each snapshot.  Keep >= 2 so a crash mid-write plus
        a corrupted latest step still leaves a restorable previous step.
    """

    dir: str
    every_iters: int = 50
    keep: int = 2

    def __post_init__(self):
        if not self.dir:
            raise ValueError("CheckpointPolicy.dir must be a directory path")
        if self.every_iters < 1:
            raise ValueError("CheckpointPolicy.every_iters must be >= 1, "
                             f"got {self.every_iters}")
        if self.keep < 1:
            raise ValueError(f"CheckpointPolicy.keep must be >= 1, "
                             f"got {self.keep}")


class LoopProgram(NamedTuple):
    """A plan's solve as a host-steppable program.

    ``start()`` returns the initial ``(carry, continue?)``; ``step(carry,
    stop)`` runs the solver's OWN while loop bounded by ``counter(carry)
    < stop`` (``stop`` traced — one compiled program serves every
    segment) and returns the advanced ``(carry, continue?)``;
    ``finalize(carry)`` produces ``(x, SolveStats)`` in the plan's output
    layout from ANY carry — which is exactly what a snapshot stores.
    ``counter(carry)`` is the host-side iteration count (one device sync,
    at a segment boundary only).
    """

    start: Callable      # () -> (carry, cont)
    step: Callable       # (carry, stop: int32) -> (carry, cont)
    counter: Callable    # carry -> host int iteration count
    finalize: Callable   # carry -> (x, SolveStats)


def _segmented_program(parts: solvers.LoopParts, post) -> LoopProgram:
    """Wrap single-device :class:`solvers.LoopParts` as a LoopProgram.

    ``post(x_solver, stats)`` maps the solver-space iterate (e.g. the
    even half field) to the plan's output layout — back-substitution,
    unpacking, merging.  It runs at segment boundaries and at the end,
    never inside the loop.
    """
    seg_cond = solvers.segment_cond(parts)

    @jax.jit
    def step(carry, stop):
        out = jax.lax.while_loop(lambda c: seg_cond(c, stop),
                                 parts.body, carry)
        return out, parts.cond(out)

    def start():
        return parts.init, parts.cond(parts.init)

    def counter(carry):
        return int(jax.device_get(parts.counter(carry)))

    def finalize(carry):
        return post(*parts.finish(carry))

    return LoopProgram(start=start, step=step, counter=counter,
                       finalize=finalize)


def _loop_program_eo(plan, u, b, mass, *, tol, maxiter, dot, norm2,
                     residual_replacement_every, **_):
    """Segmented form of :func:`_solve_eo` — same prep, same loop body."""
    ctx = resolve(plan, u, mass, out_dtype=b.dtype)
    b_e, b_o = ctx.prepare(b)
    ops = ctx.ops
    b_hat = b_e - ops.d_eo(ops.m_inv(b_o))
    a_hat = lambda v: ops.dhat_dag(ops.dhat(v))
    rhs = ops.dhat_dag(b_hat)
    if plan.solver == "pipecg":
        parts = solvers.pipecg_parts(
            a_hat, rhs, tol=tol, maxiter=maxiter,
            residual_replacement_every=residual_replacement_every,
            dot=dot, norm2=norm2, batched=ctx.batched)
    else:
        engine = {}
        if ctx.engine is not None:
            engine = dict(update=ctx.engine[0], xpay=ctx.engine[1])
        parts = solvers.cg_parts(a_hat, rhs, tol=tol, maxiter=maxiter,
                                 dot=dot, norm2=norm2, batched=ctx.batched,
                                 **engine)

    def post(x_e, stats):
        x_o = ops.m_inv(b_o - ops.d_oe(x_e))
        return ctx.finish(x_e, x_o), stats

    return _segmented_program(parts, post)


def _loop_program_eo_mp(plan, u, b, mass, *, tol, maxiter, inner_tol,
                        inner_maxiter, max_outer, dot, norm2, **_):
    """Segmented form of :func:`_solve_eo_mp`.

    The segment boundary is a reliable-update boundary (``mpcg_parts``
    counts accumulated inner iterations), so every snapshot holds an
    iterate whose true residual was just recomputed in high precision.
    """
    low_dtype = plan.low_dtype
    twist = _family_site(plan, mass).twist
    ctx = resolve(plan, u, mass, out_dtype=b.dtype)
    b_e, b_o = ctx.prepare(b)
    ops = ctx.ops
    if ctx.packed:
        # local import: see eo_operators_packed
        from repro.kernels.wilson_dslash import ops as wops

        high = b_e.dtype
        u_e_lo = ops.u_e.astype(low_dtype)
        u_o_lo = ops.u_o.astype(low_dtype)
        kkw = dict(twist=twist, bz=plan.bz, interpret=plan.interpret)

        def a_low(w):
            return wops.schur_normal_op(u_e_lo, u_o_lo, w, mass, **kkw)

        def a_high(v):
            return wops.schur_normal_op(ops.u_e, ops.u_o, v, mass, **kkw)

        to_low = lambda v: v.astype(low_dtype)
        to_high = lambda w: w.astype(high)
    else:
        high = b.dtype

        def round_links(w):
            pair = complex_to_real_pair(w, dtype=low_dtype)
            return real_pair_to_complex(pair, dtype=w.dtype)

        u_e_lo, u_o_lo = round_links(ops.u_e), round_links(ops.u_o)

        def a_low(w):
            v = real_pair_to_complex(w, dtype=high)
            av = schur_normal_op_g(u_e_lo, u_o_lo, v, mass, r=plan.r,
                                   twist=twist)
            return complex_to_real_pair(av, dtype=low_dtype)

        def a_high(v):
            return schur_normal_op_g(ops.u_e, ops.u_o, v, mass, r=plan.r,
                                     twist=twist)

        to_low = lambda v: complex_to_real_pair(v, dtype=low_dtype)
        to_high = lambda w: real_pair_to_complex(w, dtype=high)

    engine = {}
    if ctx.engine is not None:
        engine = dict(update=ctx.engine[0], xpay=ctx.engine[1])
    b_hat = b_e - ops.d_eo(ops.m_inv(b_o))
    parts = solvers.mpcg_parts(
        a_low, a_high, ops.dhat_dag(b_hat), tol=tol, inner_tol=inner_tol,
        inner_maxiter=inner_maxiter, max_outer=max_outer,
        low_dtype=low_dtype, to_low=to_low, to_high=to_high,
        dot=dot, norm2=norm2, **engine)

    def post(x_e, stats):
        x_o = ops.m_inv(b_o - ops.d_oe(x_e))
        return ctx.finish(x_e, x_o), stats

    return _segmented_program(parts, post)


def _loop_program_full(plan, u, b, mass, *, tol, maxiter, inner_tol,
                       inner_maxiter, max_outer,
                       residual_replacement_every, dot, norm2, layout):
    """Segmented form of :func:`_solve_full` — same prep, same loop body."""
    # local import: see eo_operators_packed
    from repro.kernels.wilson_dslash import ops as wops

    packed_in = layout == "packed"
    up = u if packed_in else pack_gauge(u)
    pp = b if packed_in else pack_spinor(b)
    m = float(mass)
    kw = dict(twist=_family_site(plan, mass).twist, bz=plan.bz,
              interpret=plan.interpret,
              use_pallas=plan.backend == "pallas")
    op_hi = lambda v: wops.normal_op(up, v, m, **kw)
    rhs = wops.dslash_dagger(up, pp, m, **kw)
    batched = plan.batched
    cast_low = False
    if plan.precision == "single":
        if plan.solver == "pipecg":
            parts = solvers.pipecg_parts(
                op_hi, rhs, tol=tol, maxiter=maxiter,
                residual_replacement_every=residual_replacement_every,
                dot=dot, norm2=norm2, batched=batched)
        else:
            parts = solvers.cg_parts(op_hi, rhs, tol=tol, maxiter=maxiter,
                                     dot=dot, norm2=norm2, batched=batched)
    else:
        low_dtype = plan.low_dtype
        up_lo = up.astype(low_dtype)
        op_lo = lambda v: wops.normal_op(up_lo, v, m, **kw)
        if plan.precision == "mixed":
            parts = solvers.mpcg_parts(op_lo, op_hi, rhs, tol=tol,
                                       inner_tol=inner_tol,
                                       inner_maxiter=inner_maxiter,
                                       max_outer=max_outer,
                                       low_dtype=low_dtype,
                                       dot=dot, norm2=norm2, batched=batched)
        else:  # "low": all-low cg16 — NOT accurate to tol; a measurement rig
            parts = solvers.cg_parts(op_lo, rhs.astype(low_dtype), tol=tol,
                                     maxiter=maxiter, dot=dot, norm2=norm2,
                                     batched=batched)
            cast_low = True

    def post(x, stats):
        if cast_low:
            x = x.astype(pp.dtype)
        if packed_in:
            return x, stats
        return unpack_spinor(x, dtype=b.dtype), stats

    return _segmented_program(parts, post)


# (plan identity, solve params) -> (start, step, finish) jitted shard_maps.
# Same reuse rationale as _SHARDED_EO_CACHE: every segment of every solve
# with the same plan hits the same three compiled programs.
_SHARDED_EO_SEG_CACHE: dict = {}


def _sharded_eo_segment_fns(plan: SolverPlan, mass: float, tol: float,
                            maxiter: int, residual_replacement_every: int):
    """The sharded even-odd solve split into start/step/finish shard_maps.

    Each function rebuilds the SAME LoopParts inside its trace (the
    right-hand-side prep is ~2 matvecs, re-traced per segment boundary
    and dead-code-eliminated where unused); the step's while loop uses
    the identical ``parts.body`` the one-shot sharded solve uses, bounded
    by a TRACED ``stop`` so one compiled step serves every segment.  The
    carry crosses shard_map boundaries with static per-leaf specs
    (fields sharded, scalars/masks replicated) and stays resident on the
    mesh between segments.
    """
    key = (plan.cache_key(), mass, tol, maxiter, residual_replacement_every)
    cached = _SHARDED_EO_SEG_CACHE.get(key)
    if cached is not None:
        return cached
    mesh = plan.mesh
    batched = plan.batched
    psi_spec, gauge_spec, sharded = dist.lattice_specs(mesh, plan.axis_map)
    bspec = P(None, *psi_spec) if batched else psi_spec
    site = _family_site(plan, mass)
    twist = site.twist
    kkw = dict(sharded=sharded, use_pallas=plan.backend == "pallas",
               bz=plan.bz, interpret=plan.interpret)
    skw = dict(twist=twist, **kkw)
    pdot, pnorm2 = dist.make_psum_dots(mesh, batched=batched)

    def make_parts(upe_l, upo_l, pbe_l, pbo_l):
        d_eo = lambda v: dist.parity_hop_halo("eo", upe_l, upo_l, v, **kkw)
        d_oe = lambda v: dist.parity_hop_halo("oe", upe_l, upo_l, v, **kkw)
        dhat_dag = lambda v: dist.schur_op_halo(upe_l, upo_l, v, mass,
                                                dagger=True, **skw)
        a_hat = lambda v: dist.schur_normal_op_halo(upe_l, upo_l, v, mass,
                                                    **skw)
        m_inv = site.solve
        b_hat = pbe_l - d_eo(m_inv(pbo_l))
        rhs = dhat_dag(b_hat)
        if plan.solver == "pipecg":
            parts = solvers.pipecg_parts(
                a_hat, rhs, tol=tol, maxiter=maxiter,
                residual_replacement_every=residual_replacement_every,
                dot=pdot, norm2=pnorm2, batched=batched,
                fused_dots=dist.make_fused_psum_dots(mesh, batched=batched))
        else:
            parts = solvers.cg_parts(a_hat, rhs, tol=tol, maxiter=maxiter,
                                     dot=pdot, norm2=pnorm2, batched=batched)
        return parts, m_inv, d_oe

    # static per-leaf carry specs: half fields sharded like the RHS,
    # counters/scalars/masks replicated (they are psum-consistent across
    # shards, so P() is exact, not an approximation)
    if plan.solver == "pipecg":
        carry_spec = ((P(),) + (bspec,) * 6 + (P(),) * 5
                      + ((P(),) if batched else ()) + (P(),))
    else:
        carry_spec = ((P(),) + (bspec,) * 3 + (P(),)
                      + ((P(),) if batched else ()) + (P(), P()))
    stats_spec = solvers.SolveStats(P(), P(), P(), P(),
                                    P() if batched else None,
                                    verdict=P(), matvecs=P())
    gspecs = (gauge_spec, gauge_spec, bspec, bspec)

    def local_start(upe_l, upo_l, pbe_l, pbo_l):
        parts, _, _ = make_parts(upe_l, upo_l, pbe_l, pbo_l)
        return parts.init, parts.cond(parts.init)

    def local_step(upe_l, upo_l, pbe_l, pbo_l, carry, stop):
        parts, _, _ = make_parts(upe_l, upo_l, pbe_l, pbo_l)
        seg_cond = solvers.segment_cond(parts)
        out = jax.lax.while_loop(lambda c: seg_cond(c, stop),
                                 parts.body, carry)
        return out, parts.cond(out)

    def local_finish(upe_l, upo_l, pbe_l, pbo_l, carry):
        parts, m_inv, d_oe = make_parts(upe_l, upo_l, pbe_l, pbo_l)
        x_e, stats = parts.finish(carry)
        x_o = m_inv(pbo_l - d_oe(x_e))
        return x_e, x_o, stats

    start = jax.jit(compat.shard_map(
        local_start, mesh=mesh, in_specs=gspecs,
        out_specs=(carry_spec, P()), check_vma=False))
    step = jax.jit(compat.shard_map(
        local_step, mesh=mesh, in_specs=gspecs + (carry_spec, P()),
        out_specs=(carry_spec, P()), check_vma=False))
    finish = jax.jit(compat.shard_map(
        local_finish, mesh=mesh, in_specs=gspecs + (carry_spec,),
        out_specs=(bspec, bspec, stats_spec), check_vma=False))
    fns = (start, step, finish)
    _SHARDED_EO_SEG_CACHE[key] = fns
    return fns


def _loop_program_eo_sharded(plan, u, b, mass, *, tol, maxiter,
                             residual_replacement_every, **_):
    """Segmented form of :func:`_solve_eo_sharded`.

    Carry stays sharded on the mesh between segments; ``finalize``
    gathers the global natural-layout iterate — so a snapshot stores
    UNSHARDED host arrays and a checkpoint written on a 2x2x2 mesh
    restores on a smaller mesh or on CPU (the elastic-resume contract).
    """
    batched = plan.batched
    upe, upo, pb_e, pb_o = _eo_sharded_prep(plan, u, b)
    start_f, step_f, finish_f = _sharded_eo_segment_fns(
        plan, float(mass), float(tol), int(maxiter),
        int(residual_replacement_every))

    def start():
        return start_f(upe, upo, pb_e, pb_o)

    def step(carry, stop):
        return step_f(upe, upo, pb_e, pb_o, carry,
                      jnp.asarray(stop, jnp.int32))

    def counter(carry):
        # both cg and pipecg carry the iteration count in slot 0
        return int(jax.device_get(carry[0]))

    def finalize(carry):
        x_e, x_o, stats = finish_f(upe, upo, pb_e, pb_o, carry)
        xe = unpack_spinor(x_e, dtype=b.dtype)
        xo = unpack_spinor(x_o, dtype=b.dtype)
        x = jax.vmap(merge_eo)(xe, xo) if batched else merge_eo(xe, xo)
        return x, stats

    return LoopProgram(start=start, step=step, counter=counter,
                       finalize=finalize)


def loop_program(plan: SolverPlan, u: Array, b: Array, mass, *,
                 tol: float = 1e-8, maxiter: int = 1000,
                 inner_tol: float = 5e-2, inner_maxiter: int = 200,
                 max_outer: int = 50, residual_replacement_every: int = 25,
                 dot=field_dot, norm2=field_norm2,
                 layout: str = "natural") -> LoopProgram:
    """Resolve a plan to its host-steppable :class:`LoopProgram`.

    Mirrors :func:`solve`'s dispatch table; ``finalize(carry)`` after
    stepping to completion is numerically identical to the one-shot
    ``solve`` (and BITWISE identical for the while-loop body — only the
    stopping condition differs; see :class:`solvers.LoopParts`).
    """
    if layout not in ("natural", "packed"):
        raise ValueError(f"layout must be 'natural' or 'packed', "
                         f"got {layout!r}")
    if layout == "packed" and plan.operator != "full":
        raise ValueError("layout='packed' is the full-operator contract; "
                         "the even-odd paths take natural-layout fields")
    if plan.solver == "blockcg":
        raise NotImplementedError(
            "blockcg has no segmented LoopProgram (checkpointing shares "
            "the cg/pipecg carry contracts); use solver='cgnr' for "
            "checkpointed solves")
    _check_batch_shape(plan, b, layout)
    kw = dict(tol=tol, maxiter=maxiter, inner_tol=inner_tol,
              inner_maxiter=inner_maxiter, max_outer=max_outer,
              residual_replacement_every=residual_replacement_every,
              dot=dot, norm2=norm2)
    if plan.mesh is not None:
        if plan.operator != "eo-schur":
            raise NotImplementedError(
                "segmented solving on a mesh is wired for the eo-schur "
                "fast path; use operator='eo-schur' (or drop the mesh)")
        if plan.precision != "single":
            raise NotImplementedError(
                "sharded eo-schur supports precision='single' (the "
                "mixed-precision Schur solve is single-device for now)")
        return _loop_program_eo_sharded(plan, u, b, mass, **kw)
    if plan.operator == "eo-schur":
        if plan.precision == "mixed":
            if plan.batched:
                raise NotImplementedError(
                    "batched mixed-precision eo-schur is not wired yet; "
                    "drop nrhs or precision")
            return _loop_program_eo_mp(plan, u, b, mass, **kw)
        return _loop_program_eo(plan, u, b, mass, **kw)
    return _loop_program_full(plan, u, b, mass, layout=layout, **kw)


def _snapshot(checkpoint: CheckpointPolicy, plan: SolverPlan,
              prog: LoopProgram, carry) -> int:
    """Write one durable snapshot from a segment-boundary carry.

    Stores the plan-layout iterate plus exactly the resume contract —
    ``(x, iteration, verdict, rhs_mask)`` — as UNSHARDED host arrays
    (``ckpt`` gathers on save), keyed by the iteration count as the step
    number.  Returns the step written.
    """
    from repro.checkpoint import ckpt

    x, stats = prog.finalize(carry)
    step = int(jax.device_get(stats.iterations))
    ckpt.save_checkpoint(checkpoint.dir, step, {
        "x": x,
        "iteration": stats.iterations,
        "verdict": stats.verdict,
        "rhs_mask": stats.converged,
    })
    ckpt.prune_checkpoints(checkpoint.dir, checkpoint.keep)
    return step


def _solve_checkpointed(plan, u, b, mass, *, checkpoint, tol, maxiter,
                        inner_tol, inner_maxiter, max_outer,
                        residual_replacement_every, dot, norm2, layout,
                        verify):
    """Run a plan's LoopProgram in segments, snapshotting between them.

    The host loop below is the ONLY durability addition: everything
    between two snapshots is the unsegmented solve's own compiled while
    loop.  A process killed mid-segment loses at most ``every_iters``
    iterations; :func:`repro.core.resilience.resume_solve` picks the run
    back up from the latest valid snapshot.
    """
    prog = loop_program(plan, u, b, mass, tol=tol, maxiter=maxiter,
                        inner_tol=inner_tol, inner_maxiter=inner_maxiter,
                        max_outer=max_outer,
                        residual_replacement_every=residual_replacement_every,
                        dot=dot, norm2=norm2, layout=layout)
    every = int(checkpoint.every_iters)
    carry, cont = prog.start()
    while bool(jax.device_get(cont)):
        stop = prog.counter(carry) + every
        carry, cont = prog.step(carry, jnp.asarray(stop, jnp.int32))
        _snapshot(checkpoint, plan, prog, carry)
    x, stats = prog.finalize(carry)
    if verify:
        stats = _attach_verification(plan, u, b, mass, x, stats, tol,
                                     layout=layout)
    return x, stats
