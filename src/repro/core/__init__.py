"""The paper's primary contribution: mixed-precision Conjugate Gradient for
the Dirac-Wilson operator, adapted from FPGA dataflow to TPU (see DESIGN.md).

Public surface:
  lattice   — geometry, SU(3) fields, layout packing
  wilson    — the Dirac-Wilson operator (natural + packed layouts)
  operators — the operator registry: site-local terms (wilson,
              twisted-mass) decoupled from the shared hop transport
  solvers   — cg / cgnr / cgnr_eo / mpcg / mpcg_eo / pipecg / bicgstab
  eo        — even-odd (Schur) blocks + eo_context; legacy solve forwarders
  plan      — SolverPlan: THE solve entry point ({operator, backend, batch,
              precision, mesh} resolved to callables; solve_plan runs it)
  precision — (low, high) precision-pair policies
  distributed — shard_map domain decomposition, halo-overlap dslash (full
              AND parity-compressed), psum-fused reductions
"""

from repro.core.lattice import (LatticeShape, complex_to_real_pair,
                                eo_row_offset, field_dot, field_dot_batched,
                                field_norm2, field_norm2_batched,
                                merge_eo, merge_eo_gauge, pack_gauge,
                                pack_spinor, parity_masks, random_gauge,
                                random_spinor, real_pair_to_complex,
                                split_eo, split_eo_gauge, unit_gauge,
                                unpack_gauge, unpack_spinor)
from repro.core.operators import (LatticeOperator, SiteTerm, dslash_g,
                                  dslash_dagger_g, get_operator,
                                  normal_op_g, operator_names,
                                  register_operator, schur_dagger_g,
                                  schur_normal_op_g, schur_op_g)
from repro.core.precision import PrecisionPolicy
from repro.core.solvers import (SolveStats, bicgstab, cg, cg_trace, cgnr,
                                cgnr_eo, mpcg, mpcg_eo, pipecg)
from repro.core.wilson import (DSLASH_FLOPS_PER_SITE, apply_gamma5, dslash,
                               dslash_dagger, dslash_dagger_packed,
                               dslash_eo, dslash_flops, dslash_oe,
                               dslash_packed, normal_op, normal_op_packed,
                               schur_dagger, schur_normal_op, schur_op)
from repro.core.eo import (EOContext, EOOperators, eo_context, eo_operators,
                           eo_operators_packed, solve_wilson_eo,
                           solve_wilson_eo_batched, solve_wilson_eo_mp)
from repro.core.plan import SolverPlan
from repro.core.plan import resolve as resolve_plan
from repro.core.plan import solve as solve_plan
