"""The paper's primary contribution: mixed-precision Conjugate Gradient for
the Dirac-Wilson operator, adapted from FPGA dataflow to TPU (see DESIGN.md).

Public surface:
  lattice   — geometry, SU(3) fields, layout packing
  wilson    — the Dirac-Wilson operator (natural + packed layouts)
  solvers   — cg / cgnr / mpcg / pipecg / bicgstab
  precision — (low, high) precision-pair policies
  distributed — shard_map domain decomposition + halo-overlap dslash
"""

from repro.core.lattice import (LatticeShape, field_dot, field_norm2,
                                pack_gauge, pack_spinor, random_gauge,
                                random_spinor, unit_gauge, unpack_gauge,
                                unpack_spinor)
from repro.core.precision import PrecisionPolicy
from repro.core.solvers import (SolveStats, bicgstab, cg, cg_trace, cgnr,
                                mpcg, pipecg)
from repro.core.wilson import (DSLASH_FLOPS_PER_SITE, apply_gamma5, dslash,
                               dslash_dagger, dslash_dagger_packed,
                               dslash_flops, dslash_packed, normal_op,
                               normal_op_packed)
