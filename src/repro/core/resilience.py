"""Retry/escalation for defended solves (DESIGN.md §10).

A verdict from :mod:`repro.core.solvers` classifies WHY a solve exited;
this module decides WHAT TO DO about it.  :func:`defended_solve` walks a
:class:`RetryPolicy` ladder:

1. **Restart** — re-enter the same plan as a defect-correction step: the
   TRUE residual ``r = b - D x`` of the current (finite) iterate is
   recomputed and the solver is asked for the correction ``D d = r``,
   rescaled to the remaining relative tolerance.  Krylov information is
   discarded but accumulated progress is kept — exactly the paper's
   reliable-update idea applied across solve attempts instead of across
   precisions.  A non-finite iterate cannot seed a restart; those
   attempts start over from zero.
2. **Escalate precision** — a ``precision="mixed"``/``"low"`` plan that
   failed re-runs with ``precision="single"``: reliable-update drift and
   low-precision stagnation disappear when every iteration is wide.
3. **Fall back to the reference backend** — a ``backend="pallas"`` plan
   that still fails re-runs on the jnp reference transport, removing the
   optimized kernels from the trust chain entirely.

Attempts are capped; exhaustion raises a structured :class:`SolveFailure`
carrying the per-attempt history, so a caller (the serving layer, a CLI)
can log exactly what was tried and why each rung failed.  Success at any
rung returns stats whose ``verified`` gate passed — ``defended_solve``
never returns an unverified solution.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import plan as plan_mod
from repro.core.lattice import field_norm2, field_norm2_batched
from repro.core.operators import dslash_g
from repro.core.solvers import verdict_name

__all__ = ["AttemptRecord", "RetryPolicy", "SolveFailure", "defended_solve"]


@dataclasses.dataclass(frozen=True)
class AttemptRecord:
    """One rung of the ladder, as it actually ran."""

    attempt: int               # 0-based
    plan_desc: str             # "eo-schur/pallas/mixed" style summary
    restarted: bool            # seeded from the previous finite iterate
    iterations: int
    verdict: str               # VERDICTS name
    verified: bool
    residual_norm2: float      # solver's own final ‖r‖² (recurrence)
    true_residual_norm2: float  # verification matvec's ‖b - D x‖²


class SolveFailure(RuntimeError):
    """Raised when the retry ladder is exhausted without a verified solve.

    Carries the classified verdict of the LAST attempt plus the full
    attempt history — loud and structured, never a silent bad x.
    """

    def __init__(self, message: str, *, verdict: str,
                 attempts: tuple[AttemptRecord, ...]):
        super().__init__(message)
        self.verdict = verdict
        self.attempts = attempts


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """The escalation ladder for :func:`defended_solve`.

    ``max_attempts`` counts total solve attempts (the first try
    included).  Escalations apply in order — precision first (cheap to
    keep the fast transport), backend second — and each stays in effect
    for the remaining attempts.
    """

    max_attempts: int = 3
    escalate_precision: bool = True
    fallback_backend: bool = True
    restart_from_iterate: bool = True

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError(
                f"RetryPolicy.max_attempts must be >= 1, got "
                f"{self.max_attempts}")

    def ladder(self, plan: plan_mod.SolverPlan
               ) -> tuple[plan_mod.SolverPlan, ...]:
        """The distinct plans the policy is willing to run, in order."""
        rungs = [plan]
        if self.escalate_precision and plan.precision != "single":
            rungs.append(dataclasses.replace(plan, precision="single"))
        if self.fallback_backend:
            for rung in list(rungs):
                if rung.backend == "pallas":
                    fallback = dataclasses.replace(rung, backend="reference")
                    if fallback not in rungs:
                        rungs.append(fallback)
        return tuple(rungs)


def _plan_desc(plan: plan_mod.SolverPlan) -> str:
    return (f"{plan.operator}/{plan.operator_family}/{plan.backend}/"
            f"{plan.precision}")


def _scalar(v) -> float:
    return float(np.asarray(v))


def _all(v) -> bool:
    return bool(np.asarray(v).all())


def defended_solve(plan: plan_mod.SolverPlan, u, b, mass, *,
                   tol: float = 1e-8, maxiter: int = 1000,
                   policy: RetryPolicy | None = None,
                   **solve_kw):
    """Run ``plan.solve`` under a retry/escalation ladder.

    Returns ``(x, stats, attempts)`` where every returned solve has
    ``stats.verified`` True for all right-hand sides.  Raises
    :class:`SolveFailure` when ``policy.max_attempts`` attempts across
    the ladder all fail verification.

    Restart semantics: when the previous attempt left a FINITE iterate,
    the next attempt solves the defect system ``D d = r`` with
    ``r = b - D x`` recomputed fresh (one matvec through the registry
    oracle) and a tolerance rescaled by ``‖b‖/‖r‖``, then accumulates
    ``x + d``.  Breakdown/NaN iterates restart from zero instead.
    """
    policy = RetryPolicy() if policy is None else policy
    ladder = policy.ladder(plan)
    site = plan.site_term(float(mass))

    def true_residual(x):
        apply_d = lambda v: dslash_g(u, v, mass, r=plan.r, twist=site.twist)
        if plan.batched:
            return b - jax.vmap(apply_d)(x).astype(b.dtype)
        return b - apply_d(x).astype(b.dtype)

    norm2 = field_norm2_batched if plan.batched else field_norm2
    bs = jnp.real(norm2(b))
    attempts: list[AttemptRecord] = []
    x_acc = None          # accumulated finite iterate (None: start from 0)
    last_verdict = "nonfinite"
    for attempt in range(policy.max_attempts):
        rung = ladder[min(attempt, len(ladder) - 1)]
        restarted = False
        rhs, rhs_tol = b, tol
        if x_acc is not None and policy.restart_from_iterate:
            r = true_residual(x_acc)
            rs = jnp.real(norm2(r))
            if _all(jnp.isfinite(rs)):
                # defect correction: solve D d = r to the REMAINING
                # relative tolerance tol·‖b‖ / ‖r‖ (capped: the restart
                # must still tighten the iterate)
                scale = jnp.sqrt(bs / jnp.where(rs == 0, 1.0, rs))
                rhs_tol = jnp.minimum(
                    jnp.asarray(tol, jnp.float32) * scale.astype(jnp.float32),
                    jnp.float32(0.1))
                rhs = r
                restarted = True
            else:
                x_acc = None  # poisoned iterate: restart from scratch
        x, stats = plan_mod.solve(rung, u, rhs, mass, tol=rhs_tol,
                                  maxiter=maxiter, **solve_kw)
        x_try = x if not restarted else x_acc + x
        # verify the ACCUMULATED iterate against the original system (the
        # per-attempt stats verified the defect system only)
        r_fin = true_residual(x_try)
        rs_fin = jnp.real(norm2(r_fin))
        gate = (plan_mod.VERIFY_FACTOR * jnp.asarray(tol, rs_fin.dtype)) ** 2 * bs
        ok = jnp.logical_and(rs_fin <= gate, jnp.isfinite(rs_fin))
        verdict_code = (stats.verdict if stats.verdict is not None
                        else jnp.where(stats.converged, 0, 1))
        worst = int(np.asarray(verdict_code).max())
        last_verdict = verdict_name(worst) if not _all(ok) else "converged"
        attempts.append(AttemptRecord(
            attempt=attempt, plan_desc=_plan_desc(rung), restarted=restarted,
            iterations=int(np.asarray(stats.iterations)),
            verdict=verdict_name(worst),
            verified=_all(ok),
            residual_norm2=_scalar(np.asarray(stats.residual_norm2).max()),
            true_residual_norm2=_scalar(np.asarray(rs_fin).max())))
        if _all(ok):
            stats = stats._replace(
                true_residual_norm2=rs_fin,
                verified=jnp.broadcast_to(jnp.asarray(True), ok.shape),
                verdict=jnp.zeros_like(jnp.asarray(verdict_code)),
                converged=jnp.broadcast_to(jnp.asarray(True), ok.shape))
            return x_try, stats, tuple(attempts)
        # keep a finite iterate as the next restart seed
        x_acc = x_try if _all(jnp.isfinite(rs_fin)) else None
    raise SolveFailure(
        f"defended_solve: {policy.max_attempts} attempt(s) exhausted "
        f"without a verified solution (last verdict: {last_verdict}; "
        f"ladder: {[_plan_desc(p) for p in ladder]})",
        verdict=last_verdict, attempts=tuple(attempts))
