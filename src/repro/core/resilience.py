"""Retry/escalation for defended solves (DESIGN.md §10).

A verdict from :mod:`repro.core.solvers` classifies WHY a solve exited;
this module decides WHAT TO DO about it.  :func:`defended_solve` walks a
:class:`RetryPolicy` ladder:

1. **Restart** — re-enter the same plan as a defect-correction step: the
   TRUE residual ``r = b - D x`` of the current (finite) iterate is
   recomputed and the solver is asked for the correction ``D d = r``,
   rescaled to the remaining relative tolerance.  Krylov information is
   discarded but accumulated progress is kept — exactly the paper's
   reliable-update idea applied across solve attempts instead of across
   precisions.  A non-finite iterate cannot seed a restart; those
   attempts start over from zero.
2. **Escalate precision** — a ``precision="mixed"``/``"low"`` plan that
   failed re-runs with ``precision="single"``: reliable-update drift and
   low-precision stagnation disappear when every iteration is wide.
3. **Fall back to the reference backend** — a ``backend="pallas"`` plan
   that still fails re-runs on the jnp reference transport, removing the
   optimized kernels from the trust chain entirely.

Attempts are capped; exhaustion raises a structured :class:`SolveFailure`
carrying the per-attempt history, so a caller (the serving layer, a CLI)
can log exactly what was tried and why each rung failed.  Success at any
rung returns stats whose ``verified`` gate passed — ``defended_solve``
never returns an unverified solution.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import plan as plan_mod
from repro.core.lattice import field_norm2, field_norm2_batched
from repro.core.operators import dslash_g
from repro.core.solvers import verdict_name

__all__ = ["AttemptRecord", "ResumeRecord", "RetryPolicy", "SolveFailure",
           "defended_solve", "resume_solve"]


@dataclasses.dataclass(frozen=True)
class AttemptRecord:
    """One rung of the ladder, as it actually ran."""

    attempt: int               # 0-based
    plan_desc: str             # "eo-schur/pallas/mixed" style summary
    restarted: bool            # seeded from the previous finite iterate
    iterations: int
    verdict: str               # VERDICTS name
    verified: bool
    residual_norm2: float      # solver's own final ‖r‖² (recurrence)
    true_residual_norm2: float  # verification matvec's ‖b - D x‖²


class SolveFailure(RuntimeError):
    """Raised when the retry ladder is exhausted without a verified solve.

    Carries the classified verdict of the LAST attempt plus the full
    attempt history — loud and structured, never a silent bad x.
    """

    def __init__(self, message: str, *, verdict: str,
                 attempts: tuple[AttemptRecord, ...]):
        super().__init__(message)
        self.verdict = verdict
        self.attempts = attempts


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """The escalation ladder for :func:`defended_solve`.

    ``max_attempts`` counts total solve attempts (the first try
    included).  Escalations apply in order — precision first (cheap to
    keep the fast transport), backend second — and each stays in effect
    for the remaining attempts.
    """

    max_attempts: int = 3
    escalate_precision: bool = True
    fallback_backend: bool = True
    restart_from_iterate: bool = True

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError(
                f"RetryPolicy.max_attempts must be >= 1, got "
                f"{self.max_attempts}")

    def ladder(self, plan: plan_mod.SolverPlan
               ) -> tuple[plan_mod.SolverPlan, ...]:
        """The distinct plans the policy is willing to run, in order."""
        rungs = [plan]
        if self.escalate_precision and plan.precision != "single":
            rungs.append(dataclasses.replace(plan, precision="single"))
        if self.fallback_backend:
            for rung in list(rungs):
                if rung.backend == "pallas":
                    fallback = dataclasses.replace(rung, backend="reference")
                    if fallback not in rungs:
                        rungs.append(fallback)
        return tuple(rungs)


def _plan_desc(plan: plan_mod.SolverPlan) -> str:
    return (f"{plan.operator}/{plan.operator_family}/{plan.backend}/"
            f"{plan.precision}")


def _scalar(v) -> float:
    return float(np.asarray(v))


def _all(v) -> bool:
    return bool(np.asarray(v).all())


def defended_solve(plan: plan_mod.SolverPlan, u, b, mass, *,
                   tol: float = 1e-8, maxiter: int = 1000,
                   policy: RetryPolicy | None = None,
                   x0=None, checkpoint=None,
                   **solve_kw):
    """Run ``plan.solve`` under a retry/escalation ladder.

    Returns ``(x, stats, attempts)`` where every returned solve has
    ``stats.verified`` True for all right-hand sides.  Raises
    :class:`SolveFailure` when ``policy.max_attempts`` attempts across
    the ladder all fail verification.

    Restart semantics: when the previous attempt left a FINITE iterate,
    the next attempt solves the defect system ``D d = r`` with
    ``r = b - D x`` recomputed fresh (one matvec through the registry
    oracle) and a tolerance rescaled by ``‖b‖/‖r‖``, then accumulates
    ``x + d``.  Breakdown/NaN iterates restart from zero instead.

    ``x0`` seeds the FIRST attempt with an existing iterate through the
    same defect-correction machinery — this is how :func:`resume_solve`
    continues from a checkpoint: the saved x becomes the accumulated
    iterate, attempt 0 solves only the remaining defect, and the
    accumulated solution is verified against the ORIGINAL system.  A
    non-finite ``x0`` is discarded (attempt 0 then starts from zero).

    ``checkpoint`` (a :class:`plan.CheckpointPolicy`) makes the
    from-scratch attempts durable.  Restarted attempts deliberately run
    WITHOUT it: their solver iterate is a defect correction ``d``, not
    the accumulated solution, and snapshotting it would poison a later
    resume — the caller (``resume_solve``) re-checkpoints the verified
    accumulated iterate instead.

    ``deflation`` (a :class:`solvers.DeflationBasis` via ``solve_kw``)
    warm-starts the FIRST attempt only.  Retry and escalation rungs run
    deflation-free: a basis harvested from a bad solve (or one that no
    longer matches the gauge field) must not be able to poison every
    rung of the ladder, and the accumulated iterate is in any case
    verified against the ORIGINAL system above — a misleading deflated
    x0 can waste attempt 0, never corrupt the returned solution.  When
    a ``checkpoint`` policy is in effect the basis is dropped too
    (deflation does not compose with segmented solves).
    """
    policy = RetryPolicy() if policy is None else policy
    ladder = policy.ladder(plan)
    deflation = solve_kw.pop("deflation", None)
    site = plan.site_term(float(mass))

    def true_residual(x):
        apply_d = lambda v: dslash_g(u, v, mass, r=plan.r, twist=site.twist)
        if plan.batched:
            return b - jax.vmap(apply_d)(x).astype(b.dtype)
        return b - apply_d(x).astype(b.dtype)

    norm2 = field_norm2_batched if plan.batched else field_norm2
    bs = jnp.real(norm2(b))
    attempts: list[AttemptRecord] = []
    x_acc = None          # accumulated finite iterate (None: start from 0)
    if x0 is not None:
        x0 = jnp.asarray(x0).astype(b.dtype)
        if x0.shape != b.shape:
            raise ValueError(
                f"defended_solve: x0 shape {x0.shape} does not match the "
                f"RHS shape {b.shape}")
        x_acc = x0  # finiteness is checked by the restart path below
    last_verdict = "nonfinite"
    for attempt in range(policy.max_attempts):
        rung = ladder[min(attempt, len(ladder) - 1)]
        restarted = False
        rhs, rhs_tol = b, tol
        if x_acc is not None and policy.restart_from_iterate:
            r = true_residual(x_acc)
            rs = jnp.real(norm2(r))
            if _all(jnp.isfinite(rs)):
                # defect correction: solve D d = r to the REMAINING
                # relative tolerance tol·‖b‖ / ‖r‖ (capped: the restart
                # must still tighten the iterate)
                scale = jnp.sqrt(bs / jnp.where(rs == 0, 1.0, rs))
                rhs_tol = jnp.minimum(
                    jnp.asarray(tol, jnp.float32) * scale.astype(jnp.float32),
                    jnp.float32(0.1))
                rhs = r
                restarted = True
            else:
                x_acc = None  # poisoned iterate: restart from scratch
        ckw = dict(solve_kw)
        if checkpoint is not None and not restarted:
            ckw["checkpoint"] = checkpoint
        elif (deflation is not None and attempt == 0 and not restarted
                and checkpoint is None):
            ckw["deflation"] = deflation
        x, stats = plan_mod.solve(rung, u, rhs, mass, tol=rhs_tol,
                                  maxiter=maxiter, **ckw)
        x_try = x if not restarted else x_acc + x
        # verify the ACCUMULATED iterate against the original system (the
        # per-attempt stats verified the defect system only)
        r_fin = true_residual(x_try)
        rs_fin = jnp.real(norm2(r_fin))
        gate = (plan_mod.VERIFY_FACTOR * jnp.asarray(tol, rs_fin.dtype)) ** 2 * bs
        ok = jnp.logical_and(rs_fin <= gate, jnp.isfinite(rs_fin))
        verdict_code = (stats.verdict if stats.verdict is not None
                        else jnp.where(stats.converged, 0, 1))
        worst = int(np.asarray(verdict_code).max())
        last_verdict = verdict_name(worst) if not _all(ok) else "converged"
        attempts.append(AttemptRecord(
            attempt=attempt, plan_desc=_plan_desc(rung), restarted=restarted,
            iterations=int(np.asarray(stats.iterations)),
            verdict=verdict_name(worst),
            verified=_all(ok),
            residual_norm2=_scalar(np.asarray(stats.residual_norm2).max()),
            true_residual_norm2=_scalar(np.asarray(rs_fin).max())))
        if _all(ok):
            stats = stats._replace(
                true_residual_norm2=rs_fin,
                verified=jnp.broadcast_to(jnp.asarray(True), ok.shape),
                verdict=jnp.zeros_like(jnp.asarray(verdict_code)),
                converged=jnp.broadcast_to(jnp.asarray(True), ok.shape))
            return x_try, stats, tuple(attempts)
        # keep a finite iterate as the next restart seed
        x_acc = x_try if _all(jnp.isfinite(rs_fin)) else None
    raise SolveFailure(
        f"defended_solve: {policy.max_attempts} attempt(s) exhausted "
        f"without a verified solution (last verdict: {last_verdict}; "
        f"ladder: {[_plan_desc(p) for p in ladder]})",
        verdict=last_verdict, attempts=tuple(attempts))


@dataclasses.dataclass(frozen=True)
class ResumeRecord:
    """How a :func:`resume_solve` picked a run back up."""

    resumed_from_step: int | None   # None: no checkpoint found, fresh solve
    checkpoint_iterations: int      # iterations banked before the crash
    checkpoint_verdict: str | None  # verdict saved with the checkpoint
    attempts: tuple[AttemptRecord, ...]


def resume_solve(plan: plan_mod.SolverPlan, u, b, mass, *,
                 checkpoint_dir: str, tol: float = 1e-8,
                 maxiter: int = 1000, policy: RetryPolicy | None = None,
                 missing_ok: bool = False, **solve_kw):
    """Continue an interrupted checkpointed solve (DESIGN.md §11).

    Restores the latest VALID checkpoint from ``checkpoint_dir``
    (checksum-verified; a corrupt newest step falls back to the previous
    one), seeds :func:`defended_solve` with the saved iterate — which
    defect-corrects against the ORIGINAL system and re-verifies the
    accumulated solution — and finally re-checkpoints the verified
    result, so repeated crash/resume cycles keep converging.

    Checkpoints store UNSHARDED host arrays, so a solve checkpointed on
    a 2x2x2 mesh resumes here on a smaller mesh or on CPU: pass whatever
    ``plan`` fits the surviving hardware — only its lattice/batch shape
    must match the crashed run's.

    ``missing_ok=True`` turns "no checkpoint yet" (a crash before the
    first segment boundary) into a fresh defended solve instead of an
    error.  Returns ``(x, stats, ResumeRecord)``.
    """
    from repro.checkpoint import ckpt

    vshape = (plan.nrhs,) if plan.batched else ()
    target = {
        "iteration": jax.ShapeDtypeStruct((), jnp.int32),
        "rhs_mask": jax.ShapeDtypeStruct(vshape, jnp.bool_),
        "verdict": jax.ShapeDtypeStruct(vshape, jnp.int32),
        "x": jax.ShapeDtypeStruct(b.shape, b.dtype),
    }
    try:
        step, tree = ckpt.restore_latest(checkpoint_dir, target)
    # ONLY "directory holds no checkpoint at all" is a fresh start;
    # "every checkpoint is corrupt" (plain IOError) stays a hard error
    # even under missing_ok — data exists but cannot be trusted
    except FileNotFoundError:
        if not missing_ok:
            raise
        step, ckpt_iters, ckpt_verdict, x0 = None, 0, None, None
    else:
        ckpt_iters = int(np.asarray(tree["iteration"]))
        ckpt_verdict = verdict_name(int(np.asarray(tree["verdict"]).max()))
        x0 = tree["x"]
    x, stats, attempts = defended_solve(
        plan, u, b, mass, tol=tol, maxiter=maxiter, policy=policy,
        x0=x0, checkpoint=(None if x0 is not None else
                           plan_mod.CheckpointPolicy(dir=checkpoint_dir)),
        **solve_kw)
    # bank the verified accumulated iterate: another crash right now
    # resumes from DONE, not from a pre-crash (or mid-ladder) snapshot —
    # defect-correction attempts deliberately never checkpointed, so the
    # newest snapshot on disk may predate the accumulated solution
    new_iters = sum(a.iterations for a in attempts)
    ckpt.save_checkpoint(checkpoint_dir, ckpt_iters + new_iters, {
        "x": x,
        "iteration": jnp.asarray(ckpt_iters + new_iters, jnp.int32),
        "verdict": jnp.broadcast_to(jnp.asarray(0, jnp.int32), vshape),
        "rhs_mask": jnp.broadcast_to(jnp.asarray(True), vshape),
    })
    ckpt.prune_checkpoints(checkpoint_dir, 2)
    return x, stats, ResumeRecord(
        resumed_from_step=step, checkpoint_iterations=ckpt_iters,
        checkpoint_verdict=ckpt_verdict, attempts=attempts)
