"""The Dirac-Wilson operator.

Two implementations, mutually validated in tests:

* ``dslash``        — natural layout, complex arrays, textbook form.  The
                      correctness oracle for everything else.
* ``dslash_packed`` — packed real layout ``(T,Z,Y,S,X)`` (see
                      :mod:`repro.core.lattice`), real arithmetic with
                      explicit re/im planes.  This is the layout the Pallas
                      TPU kernel uses; it also runs in bf16 (the paper's
                      "low precision data type") with f32 accumulation —
                      the TPU analogue of FPGA narrow datapaths feeding
                      wider accumulators.

Operator convention (r = Wilson parameter, m = bare mass):

    D psi(x) = (m + 4r) psi(x)
             - 1/2 sum_mu [ (r - gamma_mu) U_mu(x)       psi(x+mu)
                          + (r + gamma_mu) U_mu(x-mu)^dag psi(x-mu) ]

Directions are ordered (t, z, y, x) matching the array axes.  Gamma
matrices are in the DeGrand-Rossi basis; ``gamma5 D gamma5 = D^dag`` holds
and is tested, giving the daggered operator and the HPD normal operator
``D^dag D`` used by CGNR.

Even-odd (Schur) decomposition
------------------------------

The hopping term only connects sites of opposite parity, so in the
even/odd site ordering of :mod:`repro.core.lattice` the operator is
2x2 block-structured::

    D = [ M_ee   D_eo ]        M_ee = M_oo = (m + 4r) * 1
        [ D_oe   M_oo ]        D_eo : odd -> even hops, D_oe : even -> odd

Block-eliminating the odd sites from ``D x = b`` gives the Schur
complement system on the EVEN sublattice only::

    D_hat x_e = b_hat,   D_hat = M_ee - D_eo M_oo^{-1} D_oe
                         b_hat = b_e  - D_eo M_oo^{-1} b_o

followed by back-substitution ``x_o = M_oo^{-1} (b_o - D_oe x_e)``.
Because ``gamma5 D_eo gamma5 = D_oe^dag`` (each hop inherits the
gamma5-hermiticity of the full operator) and ``M`` is a real scalar,
``gamma5 D_hat gamma5 = D_hat^dag`` holds on the half lattice too — so
CGNR applies to ``D_hat`` unchanged, on vectors HALF the size and with a
better-conditioned spectrum (empirically ~2x fewer iterations; see
``benchmarks/bench_solvers.py``).  Implemented by ``dslash_eo`` /
``dslash_oe`` / ``schur_op`` below; solver orchestration lives in
:mod:`repro.core.eo`.

Half-lattice fields compress X by 2 (see ``split_eo``): within a row
(t, z, y) the neighbour of compressed index j in the x direction is
j + s (forward) or j - (1 - s) (backward) where s is the output row's
parity offset; t/z/y hops keep j and roll the row axes.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lattice import NCOL, NDIRS, NSPIN, eo_row_offset

# ---------------------------------------------------------------------------
# Gamma matrices, DeGrand-Rossi basis, order (t, z, y, x) = axes (0,1,2,3)
# ---------------------------------------------------------------------------

_i = 1j
GAMMA_T = np.array([[0, 0, 1, 0],
                    [0, 0, 0, 1],
                    [1, 0, 0, 0],
                    [0, 1, 0, 0]], dtype=np.complex64)
GAMMA_X = np.array([[0, 0, 0, _i],
                    [0, 0, _i, 0],
                    [0, -_i, 0, 0],
                    [-_i, 0, 0, 0]], dtype=np.complex64)
GAMMA_Y = np.array([[0, 0, 0, -1],
                    [0, 0, 1, 0],
                    [0, 1, 0, 0],
                    [-1, 0, 0, 0]], dtype=np.complex64)
GAMMA_Z = np.array([[0, 0, _i, 0],
                    [0, 0, 0, -_i],
                    [-_i, 0, 0, 0],
                    [0, _i, 0, 0]], dtype=np.complex64)

# axis order (T, Z, Y, X)
GAMMAS = np.stack([GAMMA_T, GAMMA_Z, GAMMA_Y, GAMMA_X])
GAMMA5 = np.diag([1, 1, -1, -1]).astype(np.complex64)  # g5 = gt gx gy gz

EYE4 = np.eye(4, dtype=np.complex64)


def _projectors(r: float):
    """P-[mu] = r - gamma_mu (forward hop), P+[mu] = r + gamma_mu (backward)."""
    pm = np.stack([r * EYE4 - GAMMAS[mu] for mu in range(NDIRS)])
    pp = np.stack([r * EYE4 + GAMMAS[mu] for mu in range(NDIRS)])
    return pm, pp


# ---------------------------------------------------------------------------
# Natural-layout reference operator (complex)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("r",))
def dslash(u: jax.Array, psi: jax.Array, mass: float | jax.Array,
           r: float = 1.0) -> jax.Array:
    """Dirac-Wilson operator, natural layout.

    Args:
      u:    (4, T, Z, Y, X, 3, 3) complex gauge field.
      psi:  (T, Z, Y, X, 4, 3) complex spinor field.
      mass: bare mass m.
    Returns:
      D psi, same shape/dtype as psi.
    """
    pm, pp = _projectors(r)
    pm = jnp.asarray(pm, dtype=psi.dtype)
    pp = jnp.asarray(pp, dtype=psi.dtype)
    out = (mass + 4.0 * r) * psi
    for mu in range(NDIRS):
        umu = u[mu]
        # forward hop: (r - gamma_mu) U_mu(x) psi(x + mu)
        fwd = jnp.roll(psi, -1, axis=mu)
        hf = jnp.einsum("tzyxab,tzyxsb->tzyxsa", umu, fwd)
        hf = jnp.einsum("sp,tzyxpa->tzyxsa", pm[mu], hf)
        # backward hop: (r + gamma_mu) U_mu(x - mu)^dag psi(x - mu)
        bwd = jnp.roll(psi, 1, axis=mu)
        ubw = jnp.roll(umu, 1, axis=mu)
        hb = jnp.einsum("tzyxba,tzyxsb->tzyxsa", jnp.conj(ubw), bwd)
        hb = jnp.einsum("sp,tzyxpa->tzyxsa", pp[mu], hb)
        out = out - 0.5 * (hf + hb)
    return out


def apply_gamma5(psi: jax.Array) -> jax.Array:
    """gamma5 in DeGrand-Rossi = diag(+,+,-,-) on the spin axis (-2)."""
    sign = jnp.asarray([1.0, 1.0, -1.0, -1.0], dtype=psi.dtype)
    return psi * sign[:, None]


@partial(jax.jit, static_argnames=("r",))
def dslash_dagger(u: jax.Array, psi: jax.Array, mass, r: float = 1.0):
    """D^dag psi = gamma5 D gamma5 psi (tested against explicit adjoint)."""
    return apply_gamma5(dslash(u, apply_gamma5(psi), mass, r=r))


@partial(jax.jit, static_argnames=("r",))
def normal_op(u: jax.Array, psi: jax.Array, mass, r: float = 1.0):
    """A = D^dag D — Hermitian positive definite; the CGNR operator."""
    return dslash_dagger(u, dslash(u, psi, mass, r=r), mass, r=r)


# ---------------------------------------------------------------------------
# Even-odd hopping operators and the Schur complement (natural layout)
# ---------------------------------------------------------------------------

def _hop_half(u_out: jax.Array, u_nbr: jax.Array, psi: jax.Array,
              s_out: np.ndarray, r: float) -> jax.Array:
    """Hopping term of D restricted to one parity's output sites.

    Args:
      u_out: (4, T, Z, Y, Xh, 3, 3) links attached to the OUTPUT-parity
             sites (forward hops use U_mu(x) at the output site x).
      u_nbr: links attached to the opposite-parity (neighbour) sites
             (backward hops use U_mu(x - mu)^dag at the neighbour site).
      psi:   (T, Z, Y, Xh, 4, 3) opposite-parity spinor half field.
      s_out: (T, Z, Y) int row offsets of the output parity (see
             ``eo_row_offset``): output sites sit at x = 2*j + s_out.
    Returns:
      (T, Z, Y, Xh, 4, 3) = -1/2 sum_mu [ (r - g_mu) U psi(x+mu)
                                        + (r + g_mu) U^dag psi(x-mu) ].

    For mu in {t, z, y} the neighbour keeps its compressed x index j and
    the row axis rolls.  For mu = x the neighbour index is j + s_out
    (forward) / j - (1 - s_out) (backward) — a row-parity-dependent shift
    implemented as a ``where`` between the field and its rolled copy.
    Periodic wrap in x is exact because the X extent is even.
    """
    t, z, y = psi.shape[:3]
    assert t % 2 == z % 2 == y % 2 == 0, (
        "even-odd operators need even T/Z/Y extents: an odd periodic "
        f"extent breaks bipartiteness, got {(t, z, y)}")
    pm, pp = _projectors(r)
    pm = jnp.asarray(pm, dtype=psi.dtype)
    pp = jnp.asarray(pp, dtype=psi.dtype)
    sel_s = jnp.asarray(s_out == 1).reshape(s_out.shape + (1, 1, 1))
    sel_g = sel_s  # same (T,Z,Y,1,1,1) broadcast works for (T,Z,Y,Xh,3,3)

    out = jnp.zeros_like(psi)
    for mu in range(NDIRS):
        if mu < 3:  # t, z, y: plain rolls on the uncompressed row axes
            fwd = jnp.roll(psi, -1, axis=mu)
            u_fwd = u_out[mu]
            bwd = jnp.roll(psi, 1, axis=mu)
            u_bwd = jnp.roll(u_nbr[mu], 1, axis=mu)
        else:  # x: compressed axis 3, neighbour index depends on s_out
            fwd = jnp.where(sel_s, jnp.roll(psi, -1, axis=3), psi)
            u_fwd = u_out[3]
            bwd = jnp.where(sel_s, psi, jnp.roll(psi, 1, axis=3))
            u_bwd = jnp.where(sel_g, u_nbr[3], jnp.roll(u_nbr[3], 1, axis=3))
        hf = jnp.einsum("tzyjab,tzyjsb->tzyjsa", u_fwd, fwd)
        hf = jnp.einsum("sp,tzyjpa->tzyjsa", pm[mu], hf)
        hb = jnp.einsum("tzyjba,tzyjsb->tzyjsa", jnp.conj(u_bwd), bwd)
        hb = jnp.einsum("sp,tzyjpa->tzyjsa", pp[mu], hb)
        out = out - 0.5 * (hf + hb)
    return out


@partial(jax.jit, static_argnames=("r",))
def dslash_eo(u_e: jax.Array, u_o: jax.Array, psi_o: jax.Array,
              r: float = 1.0) -> jax.Array:
    """D_eo: hopping term from an ODD half field onto EVEN output sites.

    ``u_e``/``u_o`` are the per-parity link fields from ``split_eo_gauge``;
    ``psi_o`` is (T, Z, Y, Xh, 4, 3) odd-parity.  Mass term excluded.
    """
    t, z, y = psi_o.shape[:3]
    return _hop_half(u_e, u_o, psi_o, eo_row_offset(t, z, y), r)


@partial(jax.jit, static_argnames=("r",))
def dslash_oe(u_e: jax.Array, u_o: jax.Array, psi_e: jax.Array,
              r: float = 1.0) -> jax.Array:
    """D_oe: hopping term from an EVEN half field onto ODD output sites."""
    t, z, y = psi_e.shape[:3]
    return _hop_half(u_o, u_e, psi_e, 1 - eo_row_offset(t, z, y), r)


@partial(jax.jit, static_argnames=("r",))
def schur_op(u_e: jax.Array, u_o: jax.Array, psi_e: jax.Array,
             mass, r: float = 1.0) -> jax.Array:
    """Schur complement D_hat psi_e = (m+4r) psi_e - D_eo D_oe psi_e / (m+4r).

    Acts on even-parity half fields only; gamma5-hermitian (tested), so
    CGNR on ``D_hat^dag D_hat`` converges exactly as for the full D.
    """
    m = mass + 4.0 * r
    return m * psi_e - dslash_eo(u_e, u_o, dslash_oe(u_e, u_o, psi_e, r=r),
                                 r=r) / m


@partial(jax.jit, static_argnames=("r",))
def schur_dagger(u_e, u_o, psi_e, mass, r: float = 1.0):
    """D_hat^dag = gamma5 D_hat gamma5 (gamma5 acts on spin axis -2)."""
    return apply_gamma5(schur_op(u_e, u_o, apply_gamma5(psi_e), mass, r=r))


@partial(jax.jit, static_argnames=("r",))
def schur_normal_op(u_e, u_o, psi_e, mass, r: float = 1.0):
    """A_hat = D_hat^dag D_hat — HPD on the even sublattice."""
    return schur_dagger(u_e, u_o, schur_op(u_e, u_o, psi_e, mass, r=r),
                        mass, r=r)


# ---------------------------------------------------------------------------
# Packed-layout operator (real arithmetic, TPU layout)
# ---------------------------------------------------------------------------

def _split_packed_spinor(p: jax.Array):
    """(T,Z,Y,24,X) -> re, im each (T,Z,Y,4,3,X)."""
    t, z, y, s, x = p.shape
    q = p.reshape(t, z, y, NSPIN, NCOL, 2, x)
    return q[..., 0, :], q[..., 1, :]


def _merge_packed_spinor(re: jax.Array, im: jax.Array) -> jax.Array:
    t, z, y, s, c, x = re.shape
    q = jnp.stack([re, im], axis=5)  # (T,Z,Y,4,3,2,X)
    return q.reshape(t, z, y, NSPIN * NCOL * 2, x)


def _split_packed_gauge(up: jax.Array):
    """(4,T,Z,Y,18,X) -> re, im each (4,T,Z,Y,3,3,X)."""
    d, t, z, y, g, x = up.shape
    q = up.reshape(d, t, z, y, NCOL, NCOL, 2, x)
    return q[..., 0, :], q[..., 1, :]


# spinor re/im arrays are (T,Z,Y,spin,color,X): roll axes per direction
_SPINOR_ROLL_AXIS = {0: 0, 1: 1, 2: 2, 3: 5}
# per-mu gauge re/im arrays are (T,Z,Y,row,col,X)
_GAUGE_ROLL_AXIS = {0: 0, 1: 1, 2: 2, 3: 5}


def hop_term_packed(u_mu: jax.Array, psi_nbr: jax.Array, mu: int,
                    forward: bool, r: float = 1.0) -> jax.Array:
    """One hop's contribution ``-1/2 (r ∓ gamma_mu) U psi`` on PRE-ALIGNED
    packed fields (no shifts happen here — callers align neighbours).

    Args:
      u_mu:    (T',Z',Y,18,X) — U_mu at the *output* site (forward hop) or
               at the neighbour site (backward hop; daggered internally).
      psi_nbr: (T',Z',Y,24,X) — psi at the neighbour site.
      forward: True -> (r - gamma) U psi ; False -> (r + gamma) U^dag psi.

    Shared by ``dslash_packed`` (with rolled inputs) and the distributed
    halo fix-ups in :mod:`repro.core.distributed` (with exchanged planes) —
    both the full-lattice ones and the parity-compressed even-odd ones:
    for mu in {t, z, y} a half-field hop keeps the compressed x index, so
    the same plane correction applies verbatim to (T', Z', Y, *, Xh)
    boundary planes with the per-parity link fields swapped in.
    """
    acc = jnp.float32 if psi_nbr.dtype in (jnp.bfloat16, jnp.float16,
                                           jnp.float32) else psi_nbr.dtype
    pm_c, pp_c = _projectors(r)
    P = pm_c[mu] if forward else pp_c[mu]

    t, z, y, s, x = psi_nbr.shape
    q = psi_nbr.reshape(t, z, y, NSPIN, NCOL, 2, x)
    pr, pi = q[..., 0, :], q[..., 1, :]
    g = u_mu.reshape(t, z, y, NCOL, NCOL, 2, x)
    ur, ui = g[..., 0, :], g[..., 1, :]

    sub = "tzyabx,tzysbx->tzysax" if forward else "tzybax,tzysbx->tzysax"
    e = partial(jnp.einsum, sub, preferred_element_type=acc)
    if forward:
        hr, hi = e(ur, pr) - e(ui, pi), e(ur, pi) + e(ui, pr)
    else:  # U^dag
        hr, hi = e(ur, pr) + e(ui, pi), e(ur, pi) - e(ui, pr)

    mr = jnp.asarray(np.real(P), dtype=hr.dtype)
    mi = jnp.asarray(np.imag(P), dtype=hr.dtype)
    es = partial(jnp.einsum, "sp,tzypcx->tzyscx", preferred_element_type=acc)
    outr, outi = es(mr, hr) - es(mi, hi), es(mr, hi) + es(mi, hr)
    out = jnp.stack([outr, outi], axis=5).reshape(t, z, y, s, x)
    return (-0.5 * out).astype(psi_nbr.dtype)


@partial(jax.jit, static_argnames=("r",))
def dslash_packed(up: jax.Array, pp: jax.Array, mass,
                  r: float = 1.0) -> jax.Array:
    """Dirac-Wilson on the packed real layout.

    Args:
      up: (4, T, Z, Y, 18, X) real gauge field.
      pp: (T, Z, Y, 24, X) real spinor field.
    Returns:
      packed D psi, same shape/dtype as ``pp``.

    All contractions accumulate in f32 (``preferred_element_type``) even
    when inputs are bf16 — narrow storage, wide accumulate, as on the
    FPGA's DSP datapath.
    """
    acc = jnp.float32 if pp.dtype in (jnp.bfloat16, jnp.float16,
                                      jnp.float32) else pp.dtype
    pm_c, pp_c = _projectors(r)

    pr, pi = _split_packed_spinor(pp)
    ur, ui = _split_packed_gauge(up)

    outr = ((mass + 4.0 * r) * pr).astype(acc)
    outi = ((mass + 4.0 * r) * pi).astype(acc)

    def cdot_color(ar, ai, br, bi, dag: bool):
        """(U or U^dag) @ psi over color: a=(...,3,3,X), b=(...,4,3,X)."""
        sub = "tzyabx,tzysbx->tzysax" if not dag else "tzybax,tzysbx->tzysax"
        e = partial(jnp.einsum, sub, preferred_element_type=acc)
        if not dag:
            return e(ar, br) - e(ai, bi), e(ar, bi) + e(ai, br)
        return e(ar, br) + e(ai, bi), e(ar, bi) - e(ai, br)

    def spin_mul(mat: np.ndarray, hr, hi):
        """4x4 complex constant acting on the spin axis (3)."""
        mr = jnp.asarray(np.real(mat), dtype=hr.dtype)
        mi = jnp.asarray(np.imag(mat), dtype=hr.dtype)
        e = partial(jnp.einsum, "sp,tzypcx->tzyscx", preferred_element_type=acc)
        return e(mr, hr) - e(mi, hi), e(mr, hi) + e(mi, hr)

    for mu in range(NDIRS):
        sax = _SPINOR_ROLL_AXIS[mu]
        gax = _GAUGE_ROLL_AXIS[mu]
        urm, uim = ur[mu], ui[mu]
        # forward
        fr = jnp.roll(pr, -1, axis=sax)
        fi = jnp.roll(pi, -1, axis=sax)
        hr, hi = cdot_color(urm, uim, fr, fi, dag=False)
        hr, hi = spin_mul(pm_c[mu], hr, hi)
        outr = outr - 0.5 * hr
        outi = outi - 0.5 * hi
        # backward
        br = jnp.roll(pr, 1, axis=sax)
        bi = jnp.roll(pi, 1, axis=sax)
        ubr = jnp.roll(urm, 1, axis=gax)
        ubi = jnp.roll(uim, 1, axis=gax)
        hr, hi = cdot_color(ubr, ubi, br, bi, dag=True)
        hr, hi = spin_mul(pp_c[mu], hr, hi)
        outr = outr - 0.5 * hr
        outi = outi - 0.5 * hi

    return _merge_packed_spinor(outr.astype(pp.dtype), outi.astype(pp.dtype))


def apply_gamma5_packed(p: jax.Array) -> jax.Array:
    """gamma5 on a packed field's S axis (-2); leading axes pass through."""
    assert p.shape[-2] == NSPIN * NCOL * 2
    sign = jnp.repeat(jnp.asarray([1.0, 1.0, -1.0, -1.0], dtype=p.dtype),
                      NCOL * 2)
    return p * sign[:, None]


@partial(jax.jit, static_argnames=("r",))
def dslash_dagger_packed(up, pp, mass, r: float = 1.0):
    return apply_gamma5_packed(
        dslash_packed(up, apply_gamma5_packed(pp), mass, r=r))


@partial(jax.jit, static_argnames=("r",))
def normal_op_packed(up, pp, mass, r: float = 1.0):
    """A = D^dag D on the packed layout."""
    return dslash_dagger_packed(up, dslash_packed(up, pp, mass, r=r),
                                mass, r=r)


# FLOPs per lattice site for one dslash application (the standard count
# for r=1 Wilson dslash with spin projection; the paper's §5 GFLOP/s
# figures use the same convention).
DSLASH_FLOPS_PER_SITE = 1320


def dslash_flops(volume: int) -> int:
    return DSLASH_FLOPS_PER_SITE * volume
