"""Precision-pair policy — the paper's "two data types" as a config object.

The FPGA implementation templates its whole datapath on a (low, high)
precision pair (paper §2, Ref. [10]).  We carry the same idea through the
solver stack *and* the LM training stack:

* solvers: bulk iterations in ``low``, reliable updates in ``high``;
* training: activations/matmuls in ``compute`` (= low), master weights &
  optimizer state in ``param`` (= high), gradient all-reduce optionally in
  ``grad`` (compression knob).
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

_DTYPES = {
    "bfloat16": jnp.bfloat16,
    "float16": jnp.float16,
    "float32": jnp.float32,
    "float64": jnp.float64,
}


def parse_dtype(name):
    if not isinstance(name, str):
        return name
    return _DTYPES[name]


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """(low, high) pair for solvers; (compute, param, grad) for training."""

    low: str = "bfloat16"
    high: str = "float32"
    grad: str | None = None  # None -> same as high (no grad compression)

    @property
    def low_dtype(self):
        return parse_dtype(self.low)

    @property
    def high_dtype(self):
        return parse_dtype(self.high)

    @property
    def grad_dtype(self):
        return parse_dtype(self.grad) if self.grad else self.high_dtype

    # aliases for the training stack
    @property
    def compute_dtype(self):
        return self.low_dtype

    @property
    def param_dtype(self):
        return self.high_dtype


TPU_DEFAULT = PrecisionPolicy(low="bfloat16", high="float32")
CPU_TEST = PrecisionPolicy(low="float32", high="float32")
