"""4D lattice geometry, SU(3) gauge fields and layout packing.

Two layouts are used throughout the package:

* **natural**  — complex arrays in the index order physicists write:
  ``psi[T, Z, Y, X, spin(4), color(3)]`` and
  ``U[mu(4), T, Z, Y, X, color(3), color(3)]``.  This is the layout of the
  pure-jnp reference operator and of all correctness oracles.

* **packed**   — real arrays blocked for the TPU vector unit:
  ``psi[T, Z, Y, S=24, X]`` with ``S = (spin*3 + color)*2 + reim`` and
  ``U[mu(4), T, Z, Y, G=18, X]`` with ``G = (row*3 + col)*2 + reim``.
  ``X`` innermost maps to the 128-wide lane axis, ``S`` to sublanes.
  This is the FPGA paper's "stream one site per cycle" layout re-thought
  for a (8,128)-register machine: one vector op touches 128 lattice sites.

The packing functions below are exact bijections; tests round-trip them.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

NDIRS = 4  # t, z, y, x
NSPIN = 4
NCOL = 3
SPINOR_S = NSPIN * NCOL * 2  # 24 packed real components per site
GAUGE_G = NCOL * NCOL * 2    # 18 packed real components per link


@dataclasses.dataclass(frozen=True)
class LatticeShape:
    """Geometry of the 4D lattice. Axis order is (T, Z, Y, X)."""

    t: int
    z: int
    y: int
    x: int

    @property
    def dims(self) -> tuple[int, int, int, int]:
        return (self.t, self.z, self.y, self.x)

    @property
    def volume(self) -> int:
        return self.t * self.z * self.y * self.x

    def __str__(self) -> str:  # e.g. 8x8x8x16
        return f"{self.t}x{self.z}x{self.y}x{self.x}"


# ---------------------------------------------------------------------------
# Random fields
# ---------------------------------------------------------------------------

def random_spinor(key: jax.Array, lat: LatticeShape,
                  dtype=jnp.complex64) -> jax.Array:
    """Gaussian random spinor field, natural layout (T,Z,Y,X,4,3)."""
    kr, ki = jax.random.split(key)
    shape = lat.dims + (NSPIN, NCOL)
    re = jax.random.normal(kr, shape, dtype=jnp.float32)
    im = jax.random.normal(ki, shape, dtype=jnp.float32)
    return (re + 1j * im).astype(dtype)


def _project_su3(m: jax.Array) -> jax.Array:
    """Project a complex 3x3 matrix onto SU(3) via QR + det normalization."""
    q, r = jnp.linalg.qr(m)
    # Make the decomposition unique (positive diagonal of r) so q is Haar-ish.
    d = jnp.diagonal(r, axis1=-2, axis2=-1)
    q = q * (d / jnp.abs(d))[..., None, :]
    det = jnp.linalg.det(q)
    return q / det[..., None, None] ** (1.0 / 3.0)


def random_gauge(key: jax.Array, lat: LatticeShape,
                 dtype=jnp.complex64) -> jax.Array:
    """Random SU(3) gauge field, natural layout (4,T,Z,Y,X,3,3)."""
    kr, ki = jax.random.split(key)
    shape = (NDIRS,) + lat.dims + (NCOL, NCOL)
    re = jax.random.normal(kr, shape, dtype=jnp.float32)
    im = jax.random.normal(ki, shape, dtype=jnp.float32)
    return _project_su3((re + 1j * im).astype(dtype))


def unit_gauge(lat: LatticeShape, dtype=jnp.complex64) -> jax.Array:
    """Free-field (identity links) gauge configuration."""
    eye = jnp.eye(NCOL, dtype=dtype)
    return jnp.broadcast_to(eye, (NDIRS,) + lat.dims + (NCOL, NCOL))


# ---------------------------------------------------------------------------
# Layout packing (natural complex <-> packed real)
# ---------------------------------------------------------------------------

def pack_spinor(psi: jax.Array, dtype=jnp.float32) -> jax.Array:
    """(T,Z,Y,X,4,3) complex -> (T,Z,Y,24,X) real."""
    re = jnp.real(psi).astype(dtype)
    im = jnp.imag(psi).astype(dtype)
    # (T,Z,Y,X,4,3,2)
    p = jnp.stack([re, im], axis=-1)
    t, z, y, x = psi.shape[:4]
    p = p.reshape(t, z, y, x, SPINOR_S)
    return jnp.moveaxis(p, 3, 4)  # X to innermost


def unpack_spinor(p: jax.Array, dtype=jnp.complex64) -> jax.Array:
    """(T,Z,Y,24,X) real -> (T,Z,Y,X,4,3) complex."""
    t, z, y, s, x = p.shape
    assert s == SPINOR_S
    q = jnp.moveaxis(p, 4, 3).reshape(t, z, y, x, NSPIN, NCOL, 2)
    return (q[..., 0] + 1j * q[..., 1]).astype(dtype)


def pack_gauge(u: jax.Array, dtype=jnp.float32) -> jax.Array:
    """(4,T,Z,Y,X,3,3) complex -> (4,T,Z,Y,18,X) real."""
    re = jnp.real(u).astype(dtype)
    im = jnp.imag(u).astype(dtype)
    p = jnp.stack([re, im], axis=-1)  # (4,T,Z,Y,X,3,3,2)
    d, t, z, y, x = u.shape[:5]
    p = p.reshape(d, t, z, y, x, GAUGE_G)
    return jnp.moveaxis(p, 4, 5)


def unpack_gauge(p: jax.Array, dtype=jnp.complex64) -> jax.Array:
    """(4,T,Z,Y,18,X) real -> (4,T,Z,Y,X,3,3) complex."""
    d, t, z, y, g, x = p.shape
    assert g == GAUGE_G
    q = jnp.moveaxis(p, 5, 4).reshape(d, t, z, y, x, NCOL, NCOL, 2)
    return (q[..., 0] + 1j * q[..., 1]).astype(dtype)


# ---------------------------------------------------------------------------
# Inner products on fields (any layout — they are just arrays)
# ---------------------------------------------------------------------------

def field_dot(a: jax.Array, b: jax.Array) -> jax.Array:
    """<a, b> with complex conjugation if complex; f32/f64 accumulation."""
    if jnp.iscomplexobj(a):
        acc = jnp.complex128 if a.dtype == jnp.complex128 else jnp.complex64
        return jnp.sum(jnp.conj(a) * b, dtype=acc)
    acc = jnp.float64 if a.dtype == jnp.float64 else jnp.float32
    return jnp.sum(a.astype(acc) * b.astype(acc))


def field_norm2(a: jax.Array) -> jax.Array:
    if jnp.iscomplexobj(a):
        acc = jnp.float64 if a.dtype == jnp.complex128 else jnp.float32
        return jnp.sum((jnp.real(a) ** 2 + jnp.imag(a) ** 2).astype(acc))
    acc = jnp.float64 if a.dtype == jnp.float64 else jnp.float32
    return jnp.sum(a.astype(acc) ** 2)
