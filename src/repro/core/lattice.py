"""4D lattice geometry, SU(3) gauge fields and layout packing.

Two layouts are used throughout the package:

* **natural**  — complex arrays in the index order physicists write:
  ``psi[T, Z, Y, X, spin(4), color(3)]`` and
  ``U[mu(4), T, Z, Y, X, color(3), color(3)]``.  This is the layout of the
  pure-jnp reference operator and of all correctness oracles.

* **packed**   — real arrays blocked for the TPU vector unit:
  ``psi[T, Z, Y, S=24, X]`` with ``S = (spin*3 + color)*2 + reim`` and
  ``U[mu(4), T, Z, Y, G=18, X]`` with ``G = (row*3 + col)*2 + reim``.
  ``X`` innermost maps to the 128-wide lane axis, ``S`` to sublanes.
  This is the FPGA paper's "stream one site per cycle" layout re-thought
  for a (8,128)-register machine: one vector op touches 128 lattice sites.

The packing functions below are exact bijections; tests round-trip them.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

NDIRS = 4  # t, z, y, x
NSPIN = 4
NCOL = 3
SPINOR_S = NSPIN * NCOL * 2  # 24 packed real components per site
GAUGE_G = NCOL * NCOL * 2    # 18 packed real components per link


@dataclasses.dataclass(frozen=True)
class LatticeShape:
    """Geometry of the 4D lattice. Axis order is (T, Z, Y, X)."""

    t: int
    z: int
    y: int
    x: int

    @property
    def dims(self) -> tuple[int, int, int, int]:
        return (self.t, self.z, self.y, self.x)

    @property
    def volume(self) -> int:
        return self.t * self.z * self.y * self.x

    def __str__(self) -> str:  # e.g. 8x8x8x16
        return f"{self.t}x{self.z}x{self.y}x{self.x}"


# ---------------------------------------------------------------------------
# Random fields
# ---------------------------------------------------------------------------

def random_spinor(key: jax.Array, lat: LatticeShape,
                  dtype=jnp.complex64) -> jax.Array:
    """Gaussian random spinor field, natural layout (T,Z,Y,X,4,3)."""
    kr, ki = jax.random.split(key)
    shape = lat.dims + (NSPIN, NCOL)
    re = jax.random.normal(kr, shape, dtype=jnp.float32)
    im = jax.random.normal(ki, shape, dtype=jnp.float32)
    return (re + 1j * im).astype(dtype)


def _project_su3(m: jax.Array) -> jax.Array:
    """Project a complex 3x3 matrix onto SU(3) via QR + det normalization."""
    q, r = jnp.linalg.qr(m)
    # Make the decomposition unique (positive diagonal of r) so q is Haar-ish.
    d = jnp.diagonal(r, axis1=-2, axis2=-1)
    q = q * (d / jnp.abs(d))[..., None, :]
    det = jnp.linalg.det(q)
    return q / det[..., None, None] ** (1.0 / 3.0)


def random_gauge(key: jax.Array, lat: LatticeShape,
                 dtype=jnp.complex64) -> jax.Array:
    """Random SU(3) gauge field, natural layout (4,T,Z,Y,X,3,3)."""
    kr, ki = jax.random.split(key)
    shape = (NDIRS,) + lat.dims + (NCOL, NCOL)
    re = jax.random.normal(kr, shape, dtype=jnp.float32)
    im = jax.random.normal(ki, shape, dtype=jnp.float32)
    return _project_su3((re + 1j * im).astype(dtype))


def unit_gauge(lat: LatticeShape, dtype=jnp.complex64) -> jax.Array:
    """Free-field (identity links) gauge configuration."""
    eye = jnp.eye(NCOL, dtype=dtype)
    return jnp.broadcast_to(eye, (NDIRS,) + lat.dims + (NCOL, NCOL))


# ---------------------------------------------------------------------------
# Layout packing (natural complex <-> packed real)
# ---------------------------------------------------------------------------

def pack_spinor(psi: jax.Array, dtype=jnp.float32) -> jax.Array:
    """(..., X, 4, 3) complex -> (..., 24, X) real.

    The canonical site axes are (T, Z, Y, X); any leading axes (e.g. an
    RHS-batch axis in front of T) pass through unchanged.
    """
    re = jnp.real(psi).astype(dtype)
    im = jnp.imag(psi).astype(dtype)
    # (..., X, 4, 3, 2)
    p = jnp.stack([re, im], axis=-1)
    p = p.reshape(psi.shape[:-2] + (SPINOR_S,))
    return jnp.moveaxis(p, -2, -1)  # X to innermost


def unpack_spinor(p: jax.Array, dtype=jnp.complex64) -> jax.Array:
    """(..., 24, X) real -> (..., X, 4, 3) complex (leading axes pass through)."""
    s, x = p.shape[-2:]
    assert s == SPINOR_S
    q = jnp.moveaxis(p, -1, -2).reshape(p.shape[:-2] + (x, NSPIN, NCOL, 2))
    return (q[..., 0] + 1j * q[..., 1]).astype(dtype)


def pack_gauge(u: jax.Array, dtype=jnp.float32) -> jax.Array:
    """(4,T,Z,Y,X,3,3) complex -> (4,T,Z,Y,18,X) real."""
    re = jnp.real(u).astype(dtype)
    im = jnp.imag(u).astype(dtype)
    p = jnp.stack([re, im], axis=-1)  # (4,T,Z,Y,X,3,3,2)
    d, t, z, y, x = u.shape[:5]
    p = p.reshape(d, t, z, y, x, GAUGE_G)
    return jnp.moveaxis(p, 4, 5)


def unpack_gauge(p: jax.Array, dtype=jnp.complex64) -> jax.Array:
    """(4,T,Z,Y,18,X) real -> (4,T,Z,Y,X,3,3) complex."""
    d, t, z, y, g, x = p.shape
    assert g == GAUGE_G
    q = jnp.moveaxis(p, 5, 4).reshape(d, t, z, y, x, NCOL, NCOL, 2)
    return (q[..., 0] + 1j * q[..., 1]).astype(dtype)


# ---------------------------------------------------------------------------
# Even-odd (red-black) parity geometry
# ---------------------------------------------------------------------------
#
# A site (t, z, y, x) has parity (t + z + y + x) mod 2; the Wilson hopping
# term only connects sites of OPPOSITE parity, which is what makes the
# Schur reduction in :mod:`repro.core.wilson` possible.  Half-lattice
# fields compress the X axis by 2: within the row (t, z, y) the sites of a
# given parity sit at x = 2*j + s where the row offset s depends only on
# (t + z + y) mod 2.  Compressed fields keep the natural trailing axes, so
# an even-parity spinor is (T, Z, Y, X//2, 4, 3) and ``pack_spinor`` /
# ``unpack_spinor`` apply to half fields unchanged.
#
# The split/merge bijections only require an even X extent (asserted) —
# the compression never crosses rows.  The even-odd OPERATORS in
# repro.core.wilson additionally need even T/Z/Y extents: with periodic
# boundaries an odd extent creates an odd cycle, the lattice graph stops
# being bipartite, and the hopping term no longer changes parity across
# the wrap.


def eo_row_offset(t: int, z: int, y: int) -> np.ndarray:
    """x-offset of EVEN-parity sites in each (t, z, y) row, shape (T,Z,Y).

    Even sites of row (t, z, y) are x = 2*j + s with s = (t+z+y) mod 2;
    odd sites are x = 2*j + (1 - s).  Returned as a NumPy int array so it
    folds to a constant under ``jit``.
    """
    tt, zz, yy = np.meshgrid(np.arange(t), np.arange(z), np.arange(y),
                             indexing="ij")
    return ((tt + zz + yy) % 2).astype(np.int32)


def parity_masks(lat: LatticeShape) -> tuple[np.ndarray, np.ndarray]:
    """(even_mask, odd_mask) boolean site masks of shape (T, Z, Y, X)."""
    tt, zz, yy, xx = np.meshgrid(np.arange(lat.t), np.arange(lat.z),
                                 np.arange(lat.y), np.arange(lat.x),
                                 indexing="ij")
    even = (tt + zz + yy + xx) % 2 == 0
    return even, ~even


def _eo_row_sel(t: int, z: int, y: int, n_rest: int) -> jax.Array:
    """Broadcastable bool: True where the even-site row offset is 0."""
    s = eo_row_offset(t, z, y)
    return jnp.asarray(s == 0).reshape((t, z, y, 1) + (1,) * n_rest)


def split_eo(field: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Split a natural-layout site field into (even, odd) half fields.

    Args:
      field: (T, Z, Y, X, *rest) with even X — e.g. a spinor (T,Z,Y,X,4,3).
    Returns:
      (even, odd), each (T, Z, Y, X//2, *rest).  Compressed index j of the
      even field addresses site x = 2*j + (t+z+y)%2, the odd field the
      complementary offset.  Exact bijection with :func:`merge_eo`.
    """
    t, z, y, x = field.shape[:4]
    assert x % 2 == 0, f"even-odd split needs even X extent, got {x}"
    rest = field.shape[4:]
    pair = field.reshape((t, z, y, x // 2, 2) + rest)
    lo, hi = pair[:, :, :, :, 0], pair[:, :, :, :, 1]  # x = 2j and 2j+1
    sel = _eo_row_sel(t, z, y, len(rest))
    even = jnp.where(sel, lo, hi)
    odd = jnp.where(sel, hi, lo)
    return even, odd


def merge_eo(even: jax.Array, odd: jax.Array) -> jax.Array:
    """Inverse of :func:`split_eo`: (T,Z,Y,X//2,*rest) pair -> (T,Z,Y,X,*rest)."""
    t, z, y, xh = even.shape[:4]
    rest = even.shape[4:]
    sel = _eo_row_sel(t, z, y, len(rest))
    lo = jnp.where(sel, even, odd)
    hi = jnp.where(sel, odd, even)
    pair = jnp.stack([lo, hi], axis=4)
    return pair.reshape((t, z, y, 2 * xh) + rest)


def split_eo_gauge(u: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Split a (4, T, Z, Y, X, 3, 3) gauge field into per-parity link fields.

    Returns (u_e, u_o), each (4, T, Z, Y, X//2, 3, 3): ``u_e[mu]`` holds the
    links U_mu(x) attached to EVEN sites x (compressed as in
    :func:`split_eo`), ``u_o[mu]`` those attached to odd sites.
    """
    return jax.vmap(split_eo)(u)


def merge_eo_gauge(u_e: jax.Array, u_o: jax.Array) -> jax.Array:
    """Inverse of :func:`split_eo_gauge`."""
    return jax.vmap(merge_eo)(u_e, u_o)


# ---------------------------------------------------------------------------
# Complex <-> real-pair views (for low-precision storage of complex fields)
# ---------------------------------------------------------------------------

def complex_to_real_pair(v: jax.Array, dtype=jnp.float32) -> jax.Array:
    """(..., ) complex -> (..., 2) real, castable to bf16 for narrow storage."""
    return jnp.stack([jnp.real(v), jnp.imag(v)], axis=-1).astype(dtype)


def real_pair_to_complex(w: jax.Array, dtype=jnp.complex64) -> jax.Array:
    """Inverse of :func:`complex_to_real_pair` (widens before recombining)."""
    wf = w.astype(jnp.float32 if dtype == jnp.complex64 else jnp.float64)
    return (wf[..., 0] + 1j * wf[..., 1]).astype(dtype)


# ---------------------------------------------------------------------------
# Inner products on fields (any layout — they are just arrays)
# ---------------------------------------------------------------------------

def field_dot(a: jax.Array, b: jax.Array) -> jax.Array:
    """<a, b> with complex conjugation if complex; f32/f64 accumulation."""
    if jnp.iscomplexobj(a):
        acc = jnp.complex128 if a.dtype == jnp.complex128 else jnp.complex64
        return jnp.sum(jnp.conj(a) * b, dtype=acc)
    acc = jnp.float64 if a.dtype == jnp.float64 else jnp.float32
    return jnp.sum(a.astype(acc) * b.astype(acc))


def field_norm2(a: jax.Array) -> jax.Array:
    if jnp.iscomplexobj(a):
        acc = jnp.float64 if a.dtype == jnp.complex128 else jnp.float32
        return jnp.sum((jnp.real(a) ** 2 + jnp.imag(a) ** 2).astype(acc))
    acc = jnp.float64 if a.dtype == jnp.float64 else jnp.float32
    return jnp.sum(a.astype(acc) ** 2)


# Batched (multi-RHS) reductions: leading axis is the RHS batch, each RHS
# reduced independently to a per-RHS scalar.  Implemented as vmaps of the
# single-RHS reductions so a batched solve accumulates each slice in the
# SAME order as N independent solves — the batched-vs-looped equivalence
# tests rely on this being bitwise.

def field_dot_batched(a: jax.Array, b: jax.Array) -> jax.Array:
    """Per-RHS <a_n, b_n> over all non-batch axes; returns shape (N,)."""
    return jax.vmap(field_dot)(a, b)


def field_norm2_batched(a: jax.Array) -> jax.Array:
    """Per-RHS ||a_n||^2 over all non-batch axes; returns shape (N,)."""
    return jax.vmap(field_norm2)(a)
