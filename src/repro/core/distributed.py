"""Distributed Dirac-Wilson solver: 4D domain decomposition over the device
mesh with halo exchange and communication/compute overlap.

This is the scale-out layer the paper motivates via HPCG ("boundary values
have to be frequently exchanged between the neighbours as well as global
communications ... to establish total error estimates"):

* The lattice is block-decomposed over mesh axes (default: T over ``data``,
  Z over ``model``, and — multi-pod — Y over ``pod``).  Each device owns a
  contiguous 4D sub-volume; X (the lane axis) is never sharded.

* ``dslash_halo`` evaluates the *bulk* stencil entirely locally (periodic
  rolls) and then **corrects only the boundary planes** with
  `collective_permute`d halo planes.  The bulk compute does not depend on
  the halos, so XLA's latency-hiding scheduler overlaps the ppermutes with
  the bulk — the inter-chip version of the paper's streaming overlap (T4).
  The price is one extra plane of hop evaluations per sharded direction —
  O(1/T_local) redundant compute traded for full overlap, the same trade
  the FPGA paper makes with its redundant cyclic-buffer reloads.

* Global reductions inside CG go through an injected ``dot``/``norm2``
  performing a single fused ``psum`` over all mesh axes; with ``pipecg``
  this is ONE collective per iteration.
"""

from __future__ import annotations

import functools
from typing import Mapping

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.core import solvers
from repro.core.wilson import (apply_gamma5_packed, dslash_packed,
                               hop_term_packed)

# lattice axis index -> name, for error messages
_LAT_AXIS_NAMES = {0: "T", 1: "Z", 2: "Y"}


def _take(arr: jax.Array, axis: int, idx: int) -> jax.Array:
    """Single plane at static index ``idx`` (0 or -1), keeping the dim."""
    sl = [slice(None)] * arr.ndim
    sl[axis] = slice(idx, idx + 1) if idx >= 0 else slice(idx, None)
    return arr[tuple(sl)]


def _add_at(arr: jax.Array, axis: int, idx: int, delta: jax.Array):
    sl = [slice(None)] * arr.ndim
    sl[axis] = slice(idx, idx + 1) if idx >= 0 else slice(idx, None)
    return arr.at[tuple(sl)].add(delta.astype(arr.dtype))


def dslash_halo(up: jax.Array, pp: jax.Array, mass,
                sharded: Mapping[int, tuple[str, int]],
                r: float = 1.0, use_pallas: bool = False) -> jax.Array:
    """Dirac-Wilson dslash on a LOCAL shard; call inside ``shard_map``.

    Args:
      up:      local (4, Tl, Zl, Yl, 18, X) gauge shard.
      pp:      local (Tl, Zl, Yl, 24, X) spinor shard.
      sharded: {lattice_axis (0=T,1=Z,2=Y): (mesh_axis_name, axis_size)}.
      use_pallas: run the bulk stencil through the Pallas plane-streaming
        kernel (the TPU deployment path; r=1 only) instead of the jnp op.
    """
    # 1) bulk: local periodic stencil (independent of any communication)
    if use_pallas:
        from repro.kernels.wilson_dslash.kernel import dslash_pallas
        out = dslash_pallas(up, pp, mass)
    else:
        out = dslash_packed(up, pp, mass, r=r)

    # 2) halo exchange + boundary-plane corrections per sharded direction
    for mu, (ax, n) in sorted(sharded.items()):
        if n == 1:
            continue
        fwd = [(i, (i + 1) % n) for i in range(n)]  # recv from prev rank
        bwd = [(i, (i - 1) % n) for i in range(n)]  # recv from next rank
        first = _take(pp, mu, 0)
        last = _take(pp, mu, -1)
        u_mu = up[mu]
        u_last = _take(u_mu, mu, -1)

        psi_prev = lax.ppermute(last, ax, fwd)    # psi at my (axis)-1 edge
        u_prev = lax.ppermute(u_last, ax, fwd)    # U_mu at that edge
        psi_next = lax.ppermute(first, ax, bwd)   # psi at my (axis)+1 edge

        # backward hop into plane 0: bulk used local wrap (last plane)
        wrong_b = hop_term_packed(u_last, last, mu, forward=False, r=r)
        right_b = hop_term_packed(u_prev, psi_prev, mu, forward=False, r=r)
        out = _add_at(out, mu, 0, right_b - wrong_b)

        # forward hop into plane -1: U is local (output site), psi was wrapped
        wrong_f = hop_term_packed(u_last, first, mu, forward=True, r=r)
        right_f = hop_term_packed(u_last, psi_next, mu, forward=True, r=r)
        out = _add_at(out, mu, -1, right_f - wrong_f)
    return out


def dslash_dagger_halo(up, pp, mass, sharded, r: float = 1.0):
    return apply_gamma5_packed(
        dslash_halo(up, apply_gamma5_packed(pp), mass, sharded, r=r))


def normal_op_halo(up, pp, mass, sharded, r: float = 1.0):
    return dslash_dagger_halo(up, dslash_halo(up, pp, mass, sharded, r=r),
                              mass, sharded, r=r)


# ---------------------------------------------------------------------------
# Mesh plumbing
# ---------------------------------------------------------------------------

def lattice_specs(mesh: Mesh, axis_map: Mapping[int, str] | None = None):
    """(psi_spec, gauge_spec, sharded) for decomposing (T,Z,Y) over ``mesh``.

    Default axis map: T->data, Z->model, and Y->pod when present.
    """
    if axis_map is None:
        axis_map = {0: "data", 1: "model"}
        if "pod" in mesh.axis_names:
            axis_map[2] = "pod"
    sharded = {mu: (name, mesh.shape[name]) for mu, name in axis_map.items()}
    spin = [None] * 5
    for mu, name in axis_map.items():
        spin[mu] = name
    psi_spec = P(*spin)
    gauge_spec = P(None, *spin)
    return psi_spec, gauge_spec, sharded


def make_psum_dots(mesh: Mesh):
    """Local-shard inner products with a single fused psum across the mesh."""
    axes = tuple(mesh.axis_names)

    def dot(a, b):
        local = jnp.sum(a.astype(jnp.float32) * b.astype(jnp.float32))
        return lax.psum(local, axes)

    def norm2(a):
        a32 = a.astype(jnp.float32)
        return lax.psum(jnp.sum(a32 * a32), axes)

    return dot, norm2


def solve_wilson(mesh: Mesh, up: jax.Array, b: jax.Array, mass, *,
                 solver: str = "cg", tol: float = 1e-6, maxiter: int = 1000,
                 inner_tol: float = 5e-2, low_dtype=jnp.bfloat16,
                 axis_map: Mapping[int, str] | None = None, r: float = 1.0,
                 residual_replacement_every: int = 25):
    """Solve D x = b (via the HPD normal equations) on a device mesh.

    ``solver``: "cg" | "pipecg" | "mpcg".  Returns (x, SolveStats), both
    with the same sharding as the inputs / replicated scalars.
    """
    psi_spec, gauge_spec, sharded = lattice_specs(mesh, axis_map)
    dot, norm2 = make_psum_dots(mesh)

    def local_solve(up_l, b_l):
        op = functools.partial(normal_op_halo, mass=mass, sharded=sharded,
                               r=r)
        rhs = dslash_dagger_halo(up_l, b_l, mass, sharded, r=r)
        if solver == "cg":
            return solvers.cg(lambda v: op(up_l, v), rhs, tol=tol,
                              maxiter=maxiter, dot=dot, norm2=norm2)
        if solver == "pipecg":
            return solvers.pipecg(
                lambda v: op(up_l, v), rhs, tol=tol, maxiter=maxiter,
                residual_replacement_every=residual_replacement_every,
                dot=dot, norm2=norm2)
        if solver == "mpcg":
            up_low = up_l.astype(low_dtype)
            return solvers.mpcg(
                lambda v: op(up_low, v), lambda v: op(up_l, v), rhs,
                tol=tol, inner_tol=inner_tol, inner_maxiter=maxiter,
                low_dtype=low_dtype, dot=dot, norm2=norm2)
        if solver == "cg16":
            # pure low-precision CG (no reliable updates): NOT accurate to
            # tol — exists to measure the low-precision iteration cost that
            # mpcg's inner loop pays (EXPERIMENTS.md §Perf H3)
            up_low = up_l.astype(low_dtype)
            x, st = solvers.cg(lambda v: op(up_low, v),
                               rhs.astype(low_dtype), tol=tol,
                               maxiter=maxiter, dot=dot, norm2=norm2)
            return x.astype(b_l.dtype), st
        raise ValueError(f"unknown solver {solver!r}")

    shmapped = compat.shard_map(
        local_solve, mesh=mesh,
        in_specs=(gauge_spec, psi_spec),
        out_specs=(psi_spec, solvers.SolveStats(P(), P(), P(), P())),
        check_vma=False)
    return jax.jit(shmapped)(up, b)


def shard_lattice_fields(mesh: Mesh, up: jax.Array, pp: jax.Array,
                         axis_map: Mapping[int, str] | None = None):
    """device_put global packed fields with the lattice decomposition."""
    psi_spec, gauge_spec, _ = lattice_specs(mesh, axis_map)
    return (jax.device_put(up, NamedSharding(mesh, gauge_spec)),
            jax.device_put(pp, NamedSharding(mesh, psi_spec)))
