"""Distributed Dirac-Wilson solver: 4D domain decomposition over the device
mesh with halo exchange and communication/compute overlap.

This is the scale-out layer the paper motivates via HPCG ("boundary values
have to be frequently exchanged between the neighbours as well as global
communications ... to establish total error estimates"):

* The lattice is block-decomposed over mesh axes (default: T over ``data``,
  Z over ``model``, and — multi-pod — Y over ``pod``).  Each device owns a
  contiguous 4D sub-volume; X (the lane axis) is never sharded.

* ``dslash_halo`` evaluates the *bulk* stencil entirely locally (periodic
  rolls) and then **corrects only the boundary planes** with
  `collective_permute`d halo planes.  The bulk compute does not depend on
  the halos, so XLA's latency-hiding scheduler overlaps the ppermutes with
  the bulk — the inter-chip version of the paper's streaming overlap (T4).
  The price is one extra plane of hop evaluations per sharded direction —
  O(1/T_local) redundant compute traded for full overlap, the same trade
  the FPGA paper makes with its redundant cyclic-buffer reloads.

* Global reductions inside CG go through an injected ``dot``/``norm2``
  performing a single fused ``psum`` over all mesh axes; with ``pipecg``
  this is ONE collective per iteration.
"""

from __future__ import annotations

from typing import Mapping

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.operators import apply_igamma5_packed, schur_launch_coeffs
from repro.core.wilson import (apply_gamma5_packed, dslash_packed,
                               hop_term_packed)

# lattice axis index -> name, for error messages
_LAT_AXIS_NAMES = {0: "T", 1: "Z", 2: "Y"}


def _take(arr: jax.Array, axis: int, idx: int) -> jax.Array:
    """Single plane at static index ``idx`` (0 or -1), keeping the dim."""
    sl = [slice(None)] * arr.ndim
    sl[axis] = slice(idx, idx + 1) if idx >= 0 else slice(idx, None)
    return arr[tuple(sl)]


def _add_at(arr: jax.Array, axis: int, idx: int, delta: jax.Array):
    sl = [slice(None)] * arr.ndim
    sl[axis] = slice(idx, idx + 1) if idx >= 0 else slice(idx, None)
    return arr.at[tuple(sl)].add(delta.astype(arr.dtype))


def dslash_halo(up: jax.Array, pp: jax.Array, mass,
                sharded: Mapping[int, tuple[str, int]],
                r: float = 1.0, use_pallas: bool = False,
                twist: float = 0.0) -> jax.Array:
    """Full-lattice dslash on a LOCAL shard; call inside ``shard_map``.

    Args:
      up:      local (4, Tl, Zl, Yl, 18, X) gauge shard.
      pp:      local (Tl, Zl, Yl, 24, X) spinor shard.
      sharded: {lattice_axis (0=T,1=Z,2=Y): (mesh_axis_name, axis_size)}.
      use_pallas: run the bulk stencil through the Pallas plane-streaming
        kernel (the TPU deployment path; r=1 only) instead of the jnp op.
      twist: operator-registry site-term twist (0 = Wilson).  Site-LOCAL
        by construction, so it rides the bulk stencil and the halo
        corrections (hop-only) are untouched — the registry's transport
        contract.
    """
    # 1) bulk: local periodic stencil (independent of any communication)
    if use_pallas:
        from repro.kernels.wilson_dslash.kernel import dslash_pallas
        out = dslash_pallas(up, pp, mass, twist=twist)
    else:
        out = dslash_packed(up, pp, mass, r=r)
        if twist != 0.0:
            out = (out + twist * apply_igamma5_packed(pp)).astype(out.dtype)

    # 2) halo exchange + boundary-plane corrections per sharded direction
    for mu, (ax, n) in sorted(sharded.items()):
        if n == 1:
            continue
        fwd = [(i, (i + 1) % n) for i in range(n)]  # recv from prev rank
        bwd = [(i, (i - 1) % n) for i in range(n)]  # recv from next rank
        first = _take(pp, mu, 0)
        last = _take(pp, mu, -1)
        u_mu = up[mu]
        u_last = _take(u_mu, mu, -1)

        psi_prev = lax.ppermute(last, ax, fwd)    # psi at my (axis)-1 edge
        u_prev = lax.ppermute(u_last, ax, fwd)    # U_mu at that edge
        psi_next = lax.ppermute(first, ax, bwd)   # psi at my (axis)+1 edge

        # backward hop into plane 0: bulk used local wrap (last plane)
        wrong_b = hop_term_packed(u_last, last, mu, forward=False, r=r)
        right_b = hop_term_packed(u_prev, psi_prev, mu, forward=False, r=r)
        out = _add_at(out, mu, 0, right_b - wrong_b)

        # forward hop into plane -1: U is local (output site), psi was wrapped
        wrong_f = hop_term_packed(u_last, first, mu, forward=True, r=r)
        right_f = hop_term_packed(u_last, psi_next, mu, forward=True, r=r)
        out = _add_at(out, mu, -1, right_f - wrong_f)
    return out


def dslash_dagger_halo(up, pp, mass, sharded, r: float = 1.0,
                       use_pallas: bool = False, twist: float = 0.0):
    """D^dag = gamma5 D(-twist) gamma5 on a local shard."""
    return apply_gamma5_packed(
        dslash_halo(up, apply_gamma5_packed(pp), mass, sharded, r=r,
                    use_pallas=use_pallas, twist=-twist))


def normal_op_halo(up, pp, mass, sharded, r: float = 1.0,
                   use_pallas: bool = False, twist: float = 0.0):
    return dslash_dagger_halo(up, dslash_halo(up, pp, mass, sharded, r=r,
                                              use_pallas=use_pallas,
                                              twist=twist),
                              mass, sharded, r=r, use_pallas=use_pallas,
                              twist=twist)


# ---------------------------------------------------------------------------
# Parity-compressed halo exchange: the even-odd Schur fast path, sharded
# ---------------------------------------------------------------------------
#
# The parity hop blocks D_eo / D_oe only roll the UNCOMPRESSED axes
# (T, Z, Y) — the x-direction hops stay inside a row (the lane axis, never
# sharded) — so their halo structure is identical to the full-lattice
# stencil above: evaluate the bulk with local periodic wrap, then correct
# the two boundary planes of every sharded direction with
# `collective_permute`d neighbour planes.  The correction hop for a
# t/z/y direction on a parity-compressed half field is the SAME
# ``hop_term_packed`` used by the full-lattice fix-ups: this is the
# paper's layering argument made concrete — the data-transport layer is
# untouched while the operator underneath swapped from full to parity.
#
# Requirement: every sharded LOCAL extent must be even.  Shard origins
# are then even too, so each device's local row parity equals the global
# row parity and the (local) bulk kernels compute the right projections.
#
# A batched RHS axis (N, T, Z, Y, 24, Xh) rides in front and is never
# sharded: the spinor boundary planes carry the batch, but the GAUGE
# boundary planes don't — each direction's link halo is exchanged once
# per plane regardless of N.


def _g5(p: jax.Array) -> jax.Array:
    """gamma5 on a (possibly batched) plane of a packed half field."""
    return apply_gamma5_packed(p)


def _hop_plane(u_plane: jax.Array, psi_plane: jax.Array, mu: int,
               forward: bool) -> jax.Array:
    """``hop_term_packed`` on one (possibly RHS-batched) boundary plane."""
    if psi_plane.ndim == 6:
        return jax.vmap(
            lambda q: hop_term_packed(u_plane, q, mu, forward=forward))(
                psi_plane)
    return hop_term_packed(u_plane, psi_plane, mu, forward=forward)


def parity_hop_halo(which: str, u_e: jax.Array, u_o: jax.Array,
                    pp: jax.Array, sharded: Mapping[int, tuple[str, int]], *,
                    use_pallas: bool = False, gamma5_in: bool = False,
                    gamma5_out: bool = False, psi_acc: jax.Array | None = None,
                    acc_coeff: float = 0.0, hop_coeff: float = 1.0,
                    acc_twist: float = 0.0, hop_twist: float = 0.0,
                    bz: int | None = None,
                    interpret: bool | None = None) -> jax.Array:
    """Parity hop block on a LOCAL shard; call inside ``shard_map``.

    Computes ``(acc_coeff + acc_twist·iγ5) psi_acc + (hop_coeff +
    hop_twist·iγ5) γ5out Hop(γ5in ψ)`` where Hop is D_eo (``which="eo"``:
    odd ψ in, even out) or D_oe: the bulk via the local-block kernel entry
    (:func:`repro.kernels.wilson_dslash.ops.hop_block`, Pallas or
    reference), the boundary planes of every sharded direction corrected
    with exchanged halos.  γ5 factors — and the operator registry's
    site-term twists — are applied to the correction PLANES only
    (plane-sized work), mirroring the kernels' trace-time folding: no
    standalone full-field γ5/twist pass exists on this path for any
    operator family.
    """
    # local import: repro.core is imported by the kernels package, so a
    # module-level import here would be circular.
    from repro.kernels.wilson_dslash import ops as wops

    out = wops.hop_block(u_e, u_o, pp, which=which, gamma5_in=gamma5_in,
                         gamma5_out=gamma5_out, psi_acc=psi_acc,
                         acc_coeff=acc_coeff, hop_coeff=hop_coeff,
                         acc_twist=acc_twist, hop_twist=hop_twist,
                         use_pallas=use_pallas, bz=bz, interpret=interpret)
    u_out, u_nbr = (u_e, u_o) if which == "eo" else (u_o, u_e)
    batch = pp.ndim - 5  # 0 or 1 leading RHS-batch axes
    hc = jnp.asarray(hop_coeff, jnp.float32)
    for mu, (ax, n) in sorted(sharded.items()):
        if n == 1:
            continue
        fwd = [(i, (i + 1) % n) for i in range(n)]  # recv from prev rank
        bwd = [(i, (i - 1) % n) for i in range(n)]  # recv from next rank
        pax = mu + batch
        first = _take(pp, pax, 0)
        last = _take(pp, pax, -1)
        if gamma5_in:  # fold γ5 into the plane, exactly like the kernels
            first, last = _g5(first), _g5(last)
        u_out_last = _take(u_out[mu], mu, -1)
        u_nbr_last = _take(u_nbr[mu], mu, -1)

        psi_prev = lax.ppermute(last, ax, fwd)    # ψ at my (axis)-1 edge
        u_prev = lax.ppermute(u_nbr_last, ax, fwd)  # U_mu at that edge
        psi_next = lax.ppermute(first, ax, bwd)   # ψ at my (axis)+1 edge

        # backward hop into plane 0: bulk used the local wrap (last plane)
        wrong_b = _hop_plane(u_nbr_last, last, mu, forward=False)
        right_b = _hop_plane(u_prev, psi_prev, mu, forward=False)
        # forward hop into plane -1: U is local (output site), ψ was wrapped
        wrong_f = _hop_plane(u_out_last, first, mu, forward=True)
        right_f = _hop_plane(u_out_last, psi_next, mu, forward=True)

        delta_b, delta_f = right_b - wrong_b, right_f - wrong_f
        if gamma5_out:
            delta_b, delta_f = _g5(delta_b), _g5(delta_f)
        if hop_twist != 0.0:
            # the same (hop_coeff + hop_twist·iγ5) epilogue the bulk kernel
            # folded, applied plane-sized to the corrections
            ht = jnp.asarray(hop_twist, jnp.float32)
            delta_b = hc * delta_b + ht * apply_igamma5_packed(delta_b)
            delta_f = hc * delta_f + ht * apply_igamma5_packed(delta_f)
        else:
            delta_b, delta_f = hc * delta_b, hc * delta_f
        out = _add_at(out, pax, 0, delta_b)
        out = _add_at(out, pax, -1, delta_f)
    return out


def schur_op_halo(u_e, u_o, pp_e, mass, sharded, *, use_pallas: bool = False,
                  twist: float = 0.0, dagger: bool = False,
                  bz: int | None = None, interpret: bool | None = None):
    """Sharded Schur complement D_hat ψ = S ψ - D_eo S^-1 D_oe ψ with the
    registry site term S = (mass+4) + i·twist·γ5 (Wilson: twist = 0).

    Two local hop blocks with the γ5 (``dagger``), the site-term axpy and
    the twist folded exactly as in the single-device kernel path — the
    only extra work versus one device is the boundary-plane corrections
    and their ppermutes, which XLA overlaps with the bulk stencils.
    """
    m = float(mass) + 4.0
    if twist == 0.0:
        tmp_o = parity_hop_halo("oe", u_e, u_o, pp_e, sharded,
                                use_pallas=use_pallas, gamma5_in=dagger,
                                bz=bz, interpret=interpret)
        return parity_hop_halo("eo", u_e, u_o, tmp_o, sharded,
                               use_pallas=use_pallas, gamma5_out=dagger,
                               psi_acc=pp_e, acc_coeff=m,
                               hop_coeff=-1.0 / m,
                               bz=bz, interpret=interpret)
    # twisted: the same two-launch split as the single-device kernels —
    # the sign algebra has ONE home, operators.schur_launch_coeffs
    # (S(∓tw)^-1 into the first block's epilogue, S(±tw) into the
    # second block's accumulator; dagger = γ5 D_hat(-tw) γ5)
    h1c, h1t, acc, acct = schur_launch_coeffs(m, twist, dagger)
    tmp_o = parity_hop_halo("oe", u_e, u_o, pp_e, sharded,
                            use_pallas=use_pallas, gamma5_in=dagger,
                            hop_coeff=h1c, hop_twist=h1t,
                            bz=bz, interpret=interpret)
    return parity_hop_halo("eo", u_e, u_o, tmp_o, sharded,
                           use_pallas=use_pallas, gamma5_out=dagger,
                           psi_acc=pp_e, acc_coeff=acc, acc_twist=acct,
                           hop_coeff=-1.0, bz=bz, interpret=interpret)


def schur_normal_op_halo(u_e, u_o, pp_e, mass, sharded, *,
                         use_pallas: bool = False, twist: float = 0.0,
                         bz: int | None = None,
                         interpret: bool | None = None):
    """A_hat = D_hat^dag D_hat on local shards — four hop blocks, zero
    standalone full-field γ5/axpy/twist passes, halo corrections per
    block, for every registered operator family."""
    w = schur_op_halo(u_e, u_o, pp_e, mass, sharded, use_pallas=use_pallas,
                      twist=twist, bz=bz, interpret=interpret)
    return schur_op_halo(u_e, u_o, w, mass, sharded, use_pallas=use_pallas,
                         twist=twist, dagger=True, bz=bz,
                         interpret=interpret)


# ---------------------------------------------------------------------------
# Mesh plumbing
# ---------------------------------------------------------------------------

def lattice_specs(mesh: Mesh, axis_map: Mapping[int, str] | None = None):
    """(psi_spec, gauge_spec, sharded) for decomposing (T,Z,Y) over ``mesh``.

    Default axis map: T->data, Z->model, and Y->pod when present.
    """
    if axis_map is None:
        axis_map = {0: "data", 1: "model"}
        if "pod" in mesh.axis_names:
            axis_map[2] = "pod"
    sharded = {mu: (name, mesh.shape[name]) for mu, name in axis_map.items()}
    spin = [None] * 5
    for mu, name in axis_map.items():
        spin[mu] = name
    psi_spec = P(*spin)
    gauge_spec = P(None, *spin)
    return psi_spec, gauge_spec, sharded


def make_psum_dots(mesh: Mesh, batched: bool = False):
    """Local-shard inner products with one psum per reduction across the mesh.

    ``batched=True``: operands carry a leading RHS-batch axis and the
    reductions return per-RHS ``(N,)`` scalars — the N local partial sums
    still travel in a SINGLE ``psum`` (one collective for the whole batch),
    never N per-RHS collectives.
    """
    axes = tuple(mesh.axis_names)
    lead = 1 if batched else 0

    def dot(a, b):
        red = tuple(range(lead, a.ndim))
        local = jnp.sum(a.astype(jnp.float32) * b.astype(jnp.float32),
                        axis=red)
        return lax.psum(local, axes)

    def norm2(a):
        a32 = a.astype(jnp.float32)
        return lax.psum(jnp.sum(a32 * a32, axis=tuple(range(lead, a.ndim))),
                        axes)

    return dot, norm2


def make_fused_psum_dots(mesh: Mesh, batched: bool = False):
    """The pipelined-CG reduction: gamma = (r, r) and delta = (w, r) — for
    EVERY right-hand side — fused into ONE ``psum`` per iteration.

    The local partial sums are stacked into a single (2,) or (2, N) array
    before the collective, so the sharded pipelined CGNR pays exactly one
    all-reduce per iteration regardless of batch size (jaxpr-asserted in
    tests/test_distributed.py) — the cluster-scale version of the paper's
    "global communications ... to establish total error estimates" being
    batched into one transfer.
    """
    axes = tuple(mesh.axis_names)
    lead = 1 if batched else 0

    def fused_dots(r, w):
        red = tuple(range(lead, r.ndim))
        r32, w32 = r.astype(jnp.float32), w.astype(jnp.float32)
        local = jnp.stack([jnp.sum(r32 * r32, axis=red),
                           jnp.sum(w32 * r32, axis=red)])
        both = lax.psum(local, axes)      # the iteration's ONLY collective
        return both[0], both[1]

    return fused_dots


# (solver name) -> (plan.solver, plan.precision) for the legacy entry point
_LEGACY_SOLVERS = {"cg": ("cgnr", "single"), "pipecg": ("pipecg", "single"),
                   "mpcg": ("cgnr", "mixed"), "cg16": ("cgnr", "low")}


def solve_wilson(mesh: Mesh, up: jax.Array, b: jax.Array, mass, *,
                 solver: str = "cg", tol: float = 1e-6, maxiter: int = 1000,
                 inner_tol: float = 5e-2, low_dtype=jnp.bfloat16,
                 axis_map: Mapping[int, str] | None = None, r: float = 1.0,
                 residual_replacement_every: int = 25):
    """Solve D x = b (via the HPD normal equations) on a device mesh.

    ``solver``: "cg" | "pipecg" | "mpcg" | "cg16".  Returns (x,
    SolveStats), both with the same sharding as the inputs / replicated
    scalars.  Thin forwarder: builds the equivalent full-operator
    :class:`repro.core.plan.SolverPlan` (packed-layout contract) and
    executes it.
    """
    if solver not in _LEGACY_SOLVERS:
        raise ValueError(f"unknown solver {solver!r}")
    from repro.core import plan as plan_mod  # forwarder; avoid import cycle
    sv, precision = _LEGACY_SOLVERS[solver]
    p = plan_mod.SolverPlan(operator="full", solver=sv, precision=precision,
                            low=low_dtype, mesh=mesh, axis_map=axis_map, r=r)
    return plan_mod.solve(
        p, up, b, mass, tol=tol, maxiter=maxiter, inner_tol=inner_tol,
        inner_maxiter=maxiter,
        residual_replacement_every=residual_replacement_every,
        layout="packed")


def shard_lattice_fields(mesh: Mesh, up: jax.Array, pp: jax.Array,
                         axis_map: Mapping[int, str] | None = None):
    """device_put global packed fields with the lattice decomposition."""
    psi_spec, gauge_spec, _ = lattice_specs(mesh, axis_map)
    return (jax.device_put(up, NamedSharding(mesh, gauge_spec)),
            jax.device_put(pp, NamedSharding(mesh, psi_spec)))
