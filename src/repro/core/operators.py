"""The operator registry: site-local physics decoupled from hop transport.

The paper's central architectural claim is that its framework "allows for
a simple implementation of other linear operators, while keeping the data
transport mechanisms unaltered".  This module is that seam made explicit:

* the **transport layer** — the eight-direction hopping stencil
  (``wilson_dslash`` plane-streaming kernels and their jnp references),
  the parity halo exchange in :mod:`repro.core.distributed`, RHS batching
  and precision packing — is operator-AGNOSTIC and lives where it always
  did;
* an **operator** contributes only its site-local diagonal block, captured
  by :class:`SiteTerm`::

      S = scale * 1 + twist * (i gamma5)

  with an analytic inverse (``S^-1 = (scale - i twist gamma5) /
  (scale^2 + twist^2)`` because gamma5^2 = 1) and an adjoint
  (``S^dag = S(-twist)``).  Both are what the even-odd Schur reduction
  needs: the odd-odd block is inverted in closed form, and the kernels
  fold the site term into their hop epilogues so the Schur normal
  operator stays exactly four kernel launches for EVERY registered
  operator.

Registered operators:

* ``wilson``       — S = (m + 4r) * 1 (twist = 0).  Every twist gate in
  the stack compares the trace-time float against 0.0, so the Wilson path
  emits bitwise the same program it did before the registry existed.
* ``twisted-mass`` — S = (m + 4r) + i mu gamma5 (one Wilson-clover-free
  flavor of the twisted-mass discretization).  Not gamma5-hermitian:
  ``D(mu)^dag = gamma5 D(-mu) gamma5``, so every dagger in the stack
  flips the twist sign alongside the folded gamma5 flags; CGNR on
  ``D^dag D`` is unaffected.

A new operator registers a :class:`LatticeOperator` naming its site term;
it inherits, untouched: both backends (reference jnp and Pallas kernels),
multi-RHS batching, mixed precision, and the sharded one-psum pipelined
path.  See DESIGN.md §8 for the full contract.
"""

from __future__ import annotations

import dataclasses
import difflib
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.lattice import NCOL, NSPIN
from repro.core.wilson import (apply_gamma5, dslash, dslash_eo, dslash_oe,
                               schur_dagger, schur_op)

Array = jax.Array


def unknown_name(kind: str, value, allowed) -> str:
    """Error text for an unknown registry/enum name, with a did-you-mean.

    Shared by the registry lookup and ``SolverPlan`` field validation so
    every unknown-name failure in the stack lists what IS registered and
    suggests the closest match.
    """
    allowed = tuple(allowed)
    msg = (f"unknown {kind} {value!r}; registered names: "
           f"{', '.join(repr(a) for a in allowed)}")
    hits = difflib.get_close_matches(str(value), [str(a) for a in allowed],
                                     n=1, cutoff=0.4)
    if hits:
        msg += f" — did you mean {hits[0]!r}?"
    return msg


# ---------------------------------------------------------------------------
# The site-local term
# ---------------------------------------------------------------------------


def apply_igamma5_packed(p: Array) -> Array:
    """(i gamma5) on a packed field's S axis (-2); leading axes pass through.

    In the packed real layout the S axis interleaves (spin, color, re/im),
    so multiplying by i swaps the re/im planes (re' = -im, im' = re) and
    gamma5 = diag(+,+,-,-) signs the spin blocks.
    """
    s, x = p.shape[-2:]
    assert s == NSPIN * NCOL * 2
    q = p.reshape(p.shape[:-2] + (NSPIN, NCOL, 2, x))
    re, im = q[..., 0, :], q[..., 1, :]  # each (..., NSPIN, NCOL, X)
    sign = jnp.asarray([1.0, 1.0, -1.0, -1.0],
                       p.dtype).reshape((NSPIN, 1, 1))
    out = jnp.stack([-sign * im, sign * re], axis=-2)
    return out.reshape(p.shape)


@dataclasses.dataclass(frozen=True)
class SiteTerm:
    """The site-local diagonal block ``S = scale*1 + twist*(i gamma5)``.

    ``twist`` MUST be a trace-time Python float: every consumer gates on
    ``twist == 0.0`` to keep the Wilson path bitwise identical to the
    pre-registry code (``scale`` may be a float or a traced scalar).
    ``apply``/``solve`` dispatch on the field layout — complex arrays are
    natural layout (gamma5 on spin axis -2), real arrays are the packed
    (..., 24, X) layout — so the same SiteTerm serves the reference
    operators, the packed fast path and the halo boundary planes.
    """

    scale: object
    twist: float = 0.0

    @property
    def dag(self) -> "SiteTerm":
        """S^dag: gamma5 and scale are Hermitian, (i mu gamma5)^dag flips."""
        return SiteTerm(self.scale, -self.twist)

    @property
    def inv(self) -> "SiteTerm":
        """S^-1 = (scale - twist*(i gamma5)) / (scale^2 + twist^2).

        Only for a CONCRETE (Python float) scale: the derived twist must
        itself stay trace-time static.  ``solve`` applies the inverse
        without materializing it and handles traced scales.
        """
        den = self.scale * self.scale + self.twist * self.twist
        return SiteTerm(self.scale / den, -self.twist / den)

    def apply(self, v: Array) -> Array:
        """S v on a natural (complex) or packed (real) field."""
        if self.twist == 0.0:
            return self.scale * v
        if jnp.iscomplexobj(v):
            return self.scale * v + (1j * self.twist) * apply_gamma5(v)
        return self.scale * v + self.twist * apply_igamma5_packed(v)

    def solve(self, v: Array) -> Array:
        """S^-1 v (``v / scale`` bitwise when twist == 0 — the historical
        Wilson ``m_inv``).  Gates on THIS term's trace-time twist only,
        so a traced ``scale`` is fine."""
        if self.twist == 0.0:
            return v / self.scale
        den = self.scale * self.scale + self.twist * self.twist
        if jnp.iscomplexobj(v):
            return (self.scale * v
                    - (1j * self.twist) * apply_gamma5(v)) / den
        return (self.scale * v - self.twist * apply_igamma5_packed(v)) / den


# ---------------------------------------------------------------------------
# The registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LatticeOperator:
    """What a lattice operator must DECLARE to ride the transport stack.

    Fields:
      name:        registry key (also ``SolverPlan.operator_family``).
      description: one line for ``--operator`` help and error messages.
      params:      names of the extra site-local parameters the operator
        consumes beyond ``(mass, r)`` — each must exist as a field on
        :class:`repro.core.plan.SolverPlan` (currently: ``mu``).
      make_site_term: ``(mass, r, **params) -> SiteTerm`` — the ENTIRE
        operator-specific contribution.  The hop term, its kernels, the
        halo exchange, batching and precision packing are inherited.
    """

    name: str
    description: str
    params: tuple[str, ...]
    make_site_term: Callable[..., SiteTerm]

    def site_term(self, mass, r: float = 1.0, **params) -> SiteTerm:
        return self.make_site_term(mass, r, **params)


_REGISTRY: dict[str, LatticeOperator] = {}


def register_operator(spec: LatticeOperator) -> LatticeOperator:
    """Add ``spec`` to the registry (name collisions are an error)."""
    if spec.name in _REGISTRY:
        raise ValueError(f"operator family {spec.name!r} is already "
                         "registered")
    _REGISTRY[spec.name] = spec
    return spec


def operator_names() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_operator(name: str) -> LatticeOperator:
    """Look up a registered operator; unknown names get a did-you-mean."""
    spec = _REGISTRY.get(name)
    if spec is None:
        raise ValueError(unknown_name("operator family", name,
                                      operator_names()))
    return spec


WILSON = register_operator(LatticeOperator(
    name="wilson",
    description="Dirac-Wilson: site term (m + 4r)*1",
    params=(),
    make_site_term=lambda mass, r: SiteTerm(mass + 4.0 * r, 0.0)))

TWISTED_MASS = register_operator(LatticeOperator(
    name="twisted-mass",
    description="twisted-mass Wilson: site term (m + 4r) + i*mu*gamma5",
    params=("mu",),
    make_site_term=lambda mass, r, mu: SiteTerm(mass + 4.0 * r, float(mu))))


# ---------------------------------------------------------------------------
# Generic natural-layout operators (reference backend / correctness oracles)
#
# Each function reduces BITWISE to its repro.core.wilson counterpart when
# twist == 0 — the gates below select the historical expression, not a
# generic one multiplied by zero.
# ---------------------------------------------------------------------------


def dslash_g(u: Array, psi: Array, mass, r: float = 1.0,
             twist: float = 0.0) -> Array:
    """D psi for the (mass, r, twist) operator family, natural layout."""
    out = dslash(u, psi, mass, r=r)
    if twist != 0.0:
        out = out + (1j * twist) * apply_gamma5(psi)
    return out


def dslash_dagger_g(u: Array, psi: Array, mass, r: float = 1.0,
                    twist: float = 0.0) -> Array:
    """D^dag = gamma5 D(-twist) gamma5 (for twist = 0: plain gamma5 D
    gamma5 — the Wilson dagger)."""
    return apply_gamma5(dslash_g(u, apply_gamma5(psi), mass, r=r,
                                 twist=-twist))


def normal_op_g(u: Array, psi: Array, mass, r: float = 1.0,
                twist: float = 0.0) -> Array:
    """A = D^dag D — HPD for every family; the CGNR operator."""
    return dslash_dagger_g(u, dslash_g(u, psi, mass, r=r, twist=twist),
                           mass, r=r, twist=twist)


def schur_launch_coeffs(scale: float, twist: float, dagger: bool
                        ) -> tuple[float, float, float, float]:
    """Epilogue coefficients of the TWO-launch twisted Schur split.

    D_hat(tw) = S(tw) - D_eo S(tw)^-1 D_oe and D_hat(tw)^dag =
    gamma5 D_hat(-tw) gamma5, so with tw = -twist if dagger else twist
    and den = scale^2 + tw^2:

      launch 1 (D_oe, gamma5_in=dagger) folds S(tw)^-1 into its hop
        epilogue: (hop1_coeff, hop1_twist) = (scale, -tw) / den;
      launch 2 (D_eo, gamma5_out=dagger) accumulates S(tw) psi with
        hop_coeff = -1: (acc_coeff, acc_twist) = (scale, tw).

    The ONE home of this sign algebra — the single-device kernels
    (``kernels/wilson_dslash/ops.schur_op``) and the sharded halo path
    (``distributed.schur_op_halo``) both consume it.  Returns
    (hop1_coeff, hop1_twist, acc_coeff, acc_twist).
    """
    tw = -twist if dagger else twist
    den = scale * scale + tw * tw
    return scale / den, -tw / den, scale, tw


def schur_op_g(u_e: Array, u_o: Array, psi_e: Array, mass, r: float = 1.0,
               twist: float = 0.0) -> Array:
    """Schur complement D_hat = S - D_eo S^-1 D_oe on even half fields.

    For twist = 0 the scalar S^-1 commutes with the hops and the
    historical Wilson expression (divide the even output) is emitted
    bitwise; a twisted S^-1 is gamma5-valued and must stay between the
    hops.
    """
    if twist == 0.0:
        return schur_op(u_e, u_o, psi_e, mass, r=r)
    site = SiteTerm(mass + 4.0 * r, twist)
    tmp_o = site.solve(dslash_oe(u_e, u_o, psi_e, r=r))
    return site.apply(psi_e) - dslash_eo(u_e, u_o, tmp_o, r=r)


def schur_dagger_g(u_e: Array, u_o: Array, psi_e: Array, mass,
                   r: float = 1.0, twist: float = 0.0) -> Array:
    """D_hat(twist)^dag = gamma5 D_hat(-twist) gamma5."""
    if twist == 0.0:
        return schur_dagger(u_e, u_o, psi_e, mass, r=r)
    return apply_gamma5(schur_op_g(u_e, u_o, apply_gamma5(psi_e), mass,
                                   r=r, twist=-twist))


def schur_normal_op_g(u_e: Array, u_o: Array, psi_e: Array, mass,
                      r: float = 1.0, twist: float = 0.0) -> Array:
    """A_hat = D_hat^dag D_hat — HPD on the even sublattice."""
    return schur_dagger_g(u_e, u_o,
                          schur_op_g(u_e, u_o, psi_e, mass, r=r,
                                     twist=twist),
                          mass, r=r, twist=twist)
