"""Pallas kernel fusing the CG vector triad into one HBM pass.

A plain CG iteration does, after the matvec:
    x <- x + alpha p        (read x,p; write x)
    r <- r - alpha Ap       (read r,Ap; write r)
    rs <- ||r||^2           (read r; reduce)
    p <- r + beta p         (read r,p; write p)   [next half-step]

Done naively that is 7 reads + 3 writes of HBM per iteration.  The FPGA
paper hides all vector updates inside the streaming pipeline; the TPU
analogue is fusion — one kernel that streams (x, r, p, Ap) through VMEM
once, writes the updated (x, r) and emits per-block partial sums of
||r_new||^2 (4 reads + 2 writes + negligible partials).  ``cg_fused2``
additionally folds the p-update of the *following* iteration once beta is
known.

Vectors are viewed as (rows, 128) with a (block_rows, 128) grid — layout
matches the packed-field flattening, lane axis innermost.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.dispatch import resolve_interpret

LANE = 128


def _update_kernel(alpha_ref, x_ref, r_ref, p_ref, ap_ref,
                   xo_ref, ro_ref, rs_ref):
    alpha = alpha_ref[0, 0]
    p = p_ref[...].astype(jnp.float32)
    ap = ap_ref[...].astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32) + alpha * p
    r = r_ref[...].astype(jnp.float32) - alpha * ap
    xo_ref[...] = x.astype(xo_ref.dtype)
    ro_ref[...] = r.astype(ro_ref.dtype)
    rs_ref[0, 0] = jnp.sum(r * r)


def cg_update_pallas(alpha: jax.Array, x: jax.Array, r: jax.Array,
                     p: jax.Array, ap: jax.Array, *,
                     block_rows: int = 256, interpret: bool | None = None):
    """(x + alpha p, r - alpha Ap, ||r_new||^2) in one fused pass.

    Inputs must be 2D (rows, 128); use ``ops.cg_update`` for arbitrary
    shapes (it handles the reshape/pad).
    """
    rows, lane = x.shape
    assert lane == LANE and rows % block_rows == 0
    nb = rows // block_rows
    vec = pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
    scal = pl.BlockSpec((1, 1), lambda i: (0, 0))
    xo, ro, rs = pl.pallas_call(
        _update_kernel,
        grid=(nb,),
        in_specs=[scal, vec, vec, vec, vec],
        out_specs=[vec, vec, pl.BlockSpec((1, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct(x.shape, x.dtype),
                   jax.ShapeDtypeStruct(r.shape, r.dtype),
                   jax.ShapeDtypeStruct((nb, 1), jnp.float32)],
        interpret=resolve_interpret(interpret),
    )(jnp.asarray(alpha, jnp.float32).reshape(1, 1), x, r, p, ap)
    return xo, ro, jnp.sum(rs)


def _xpay_kernel(beta_ref, r_ref, p_ref, po_ref):
    beta = beta_ref[0, 0]
    po_ref[...] = (r_ref[...].astype(jnp.float32)
                   + beta * p_ref[...].astype(jnp.float32)).astype(po_ref.dtype)


def cg_xpay_pallas(beta: jax.Array, r: jax.Array, p: jax.Array, *,
                   block_rows: int = 256, interpret: bool | None = None):
    """p <- r + beta p (the direction update), streaming layout as above."""
    rows, lane = r.shape
    assert lane == LANE and rows % block_rows == 0
    nb = rows // block_rows
    vec = pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
    scal = pl.BlockSpec((1, 1), lambda i: (0, 0))
    return pl.pallas_call(
        _xpay_kernel,
        grid=(nb,),
        in_specs=[scal, vec, vec],
        out_specs=vec,
        out_shape=jax.ShapeDtypeStruct(p.shape, p.dtype),
        interpret=resolve_interpret(interpret),
    )(jnp.asarray(beta, jnp.float32).reshape(1, 1), r, p)


# ---------------------------------------------------------------------------
# Multi-RHS (batched) variants: vectors are (N, rows, 128), scalars are
# per-RHS (N,).  The grid gains a leading batch dimension; per-RHS partial
# sums land in an (N, nb) output so each right-hand side keeps its own
# residual norm — the solver's convergence mask needs them separately.
# ---------------------------------------------------------------------------


def _update_batched_kernel(alpha_ref, x_ref, r_ref, p_ref, ap_ref,
                           xo_ref, ro_ref, rs_ref):
    alpha = alpha_ref[0, 0]
    p = p_ref[...].astype(jnp.float32)
    ap = ap_ref[...].astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32) + alpha * p
    r = r_ref[...].astype(jnp.float32) - alpha * ap
    xo_ref[...] = x.astype(xo_ref.dtype)
    ro_ref[...] = r.astype(ro_ref.dtype)
    rs_ref[0, 0] = jnp.sum(r * r)


def cg_update_batched_pallas(alpha: jax.Array, x: jax.Array, r: jax.Array,
                             p: jax.Array, ap: jax.Array, *,
                             block_rows: int = 256,
                             interpret: bool | None = None):
    """Per-RHS fused triad: (x + α_n p, r - α_n Ap, ||r'_n||²) in one pass.

    Inputs are (N, rows, 128) with per-RHS ``alpha`` of shape (N,); a
    frozen (converged) RHS rides through with α_n = 0, which leaves its
    x/r slices bitwise untouched.  Returns per-RHS norms of shape (N,).
    """
    n, rows, lane = x.shape
    assert lane == LANE and rows % block_rows == 0
    nb = rows // block_rows
    vec = pl.BlockSpec((1, block_rows, LANE), lambda ni, i: (ni, i, 0))
    scal = pl.BlockSpec((1, 1), lambda ni, i: (ni, 0))
    xo, ro, rs = pl.pallas_call(
        _update_batched_kernel,
        grid=(n, nb),
        in_specs=[scal, vec, vec, vec, vec],
        out_specs=[vec, vec, pl.BlockSpec((1, 1), lambda ni, i: (ni, i))],
        out_shape=[jax.ShapeDtypeStruct(x.shape, x.dtype),
                   jax.ShapeDtypeStruct(r.shape, r.dtype),
                   jax.ShapeDtypeStruct((n, nb), jnp.float32)],
        interpret=resolve_interpret(interpret),
    )(jnp.asarray(alpha, jnp.float32).reshape(n, 1), x, r, p, ap)
    return xo, ro, jnp.sum(rs, axis=1)


def _xpay_batched_kernel(beta_ref, gate_ref, r_ref, p_ref, po_ref):
    beta = beta_ref[0, 0]
    gate = gate_ref[0, 0] != 0
    p32 = p_ref[...].astype(jnp.float32)
    r32 = r_ref[...].astype(jnp.float32)
    po_ref[...] = jnp.where(gate, r32 + beta * p32, p32).astype(po_ref.dtype)


def cg_xpay_batched_pallas(beta: jax.Array, r: jax.Array, p: jax.Array,
                           gate: jax.Array, *, block_rows: int = 256,
                           interpret: bool | None = None):
    """Gated per-RHS direction update: p_n <- r_n + β_n p_n where gate_n,
    else p_n unchanged (the frozen lane of the convergence mask)."""
    n, rows, lane = r.shape
    assert lane == LANE and rows % block_rows == 0
    nb = rows // block_rows
    vec = pl.BlockSpec((1, block_rows, LANE), lambda ni, i: (ni, i, 0))
    scal = pl.BlockSpec((1, 1), lambda ni, i: (ni, 0))
    return pl.pallas_call(
        _xpay_batched_kernel,
        grid=(n, nb),
        in_specs=[scal, scal, vec, vec],
        out_specs=vec,
        out_shape=jax.ShapeDtypeStruct(p.shape, p.dtype),
        interpret=resolve_interpret(interpret),
    )(jnp.asarray(beta, jnp.float32).reshape(n, 1),
      jnp.asarray(gate, jnp.float32).reshape(n, 1), r, p)
