"""Jitted wrappers for the fused CG updates on arbitrary field shapes.

Fields are flattened to a (rows, 128) streaming view; a zero pad (which
contributes 0 to the residual reduction and is sliced off afterwards)
handles sizes that are not multiples of 128*block_rows.

Lowering: like the dslash wrappers, ``interpret=False`` on CPU (where
``pallas_call`` cannot compile) routes to the jnp reference triad — for
these pure vector ops the ref IS the compiled-XLA implementation; XLA
fuses the a*x+y chains into the same streaming passes the kernel
hand-codes, so the "xla" lowering loses nothing.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.cg_fused.kernel import (LANE, cg_update_batched_pallas,
                                           cg_update_pallas,
                                           cg_xpay_batched_pallas,
                                           cg_xpay_pallas)
from repro.kernels.cg_fused.ref import (cg_update_batched_ref, cg_update_ref,
                                        cg_xpay_batched_ref, cg_xpay_ref)
from repro.kernels.dispatch import resolve_lowering

__all__ = ["cg_update", "cg_xpay", "cg_update_batched", "cg_xpay_batched",
           "cg_pallas", "fused_engine", "fused_engine_batched"]


def _pick_block_rows(rows: int) -> int:
    for cand in (256, 128, 64, 32, 16, 8, 4, 2, 1):
        if rows % cand == 0:
            return cand
    return 1


def _to_stream(v: jax.Array):
    n = v.size
    rows = -(-n // LANE)
    pad = rows * LANE - n
    flat = jnp.pad(v.reshape(-1), (0, pad))
    return flat.reshape(rows, LANE), pad


def _to_stream_batched(v: jax.Array):
    """(N, ...) -> (N, rows, 128): each RHS flattened to its own stream."""
    nb = v.shape[0]
    per = v.size // nb
    rows = -(-per // LANE)
    pad = rows * LANE - per
    flat = jnp.pad(v.reshape(nb, -1), ((0, 0), (0, pad)))
    return flat.reshape(nb, rows, LANE), pad


@functools.partial(jax.jit, static_argnames=("interpret", "use_pallas"))
def cg_update(alpha, x, r, p, ap, *, interpret: bool | None = None,
              use_pallas: bool = True):
    """Fused (x + alpha p, r - alpha Ap, ||r_new||^2) for any field shape."""
    if not use_pallas or resolve_lowering(interpret) == "xla":
        return cg_update_ref(alpha, x, r, p, ap)
    shape = x.shape
    xs, _ = _to_stream(x)
    rs_, _ = _to_stream(r)
    ps, _ = _to_stream(p)
    aps, _ = _to_stream(ap)
    br = _pick_block_rows(xs.shape[0])
    xo, ro, rs = cg_update_pallas(alpha, xs, rs_, ps, aps,
                                  block_rows=br, interpret=interpret)
    n = x.size
    return (xo.reshape(-1)[:n].reshape(shape),
            ro.reshape(-1)[:n].reshape(shape), rs)


@functools.partial(jax.jit, static_argnames=("interpret", "use_pallas"))
def cg_xpay(beta, r, p, *, interpret: bool | None = None,
            use_pallas: bool = True):
    """p <- r + beta p for any field shape."""
    if not use_pallas or resolve_lowering(interpret) == "xla":
        return cg_xpay_ref(beta, r, p)
    shape = p.shape
    rstream, _ = _to_stream(r)
    pstream, _ = _to_stream(p)
    br = _pick_block_rows(pstream.shape[0])
    po = cg_xpay_pallas(beta, rstream, pstream, block_rows=br,
                        interpret=interpret)
    return po.reshape(-1)[:p.size].reshape(shape)


@functools.partial(jax.jit, static_argnames=("interpret", "use_pallas"))
def cg_update_batched(alpha, x, r, p, ap, *, interpret: bool | None = None,
                      use_pallas: bool = True):
    """Per-RHS fused triad for (N, ...) fields; ``alpha`` is (N,).

    Returns (x', r', rs) with rs the per-RHS ||r'_n||² of shape (N,).
    A frozen RHS (α_n = 0) keeps its x/r slices bitwise unchanged.
    """
    if not use_pallas or resolve_lowering(interpret) == "xla":
        return cg_update_batched_ref(alpha, x, r, p, ap)
    shape = x.shape
    xs, _ = _to_stream_batched(x)
    rs_, _ = _to_stream_batched(r)
    ps, _ = _to_stream_batched(p)
    aps, _ = _to_stream_batched(ap)
    br = _pick_block_rows(xs.shape[1])
    xo, ro, rs = cg_update_batched_pallas(alpha, xs, rs_, ps, aps,
                                          block_rows=br, interpret=interpret)
    nb = shape[0]
    per = x.size // nb
    return (xo.reshape(nb, -1)[:, :per].reshape(shape),
            ro.reshape(nb, -1)[:, :per].reshape(shape), rs)


@functools.partial(jax.jit, static_argnames=("interpret", "use_pallas"))
def cg_xpay_batched(beta, r, p, gate, *, interpret: bool | None = None,
                    use_pallas: bool = True):
    """Gated per-RHS direction update for (N, ...) fields.

    ``beta``/``gate`` are (N,): where ``gate`` is set the slice gets
    ``r + beta p``; a cleared gate freezes the slice (p returned as-is) —
    the in-kernel form of the solver's convergence mask.
    """
    if not use_pallas or resolve_lowering(interpret) == "xla":
        return cg_xpay_batched_ref(beta, r, p, gate)
    shape = p.shape
    rstream, _ = _to_stream_batched(r)
    pstream, _ = _to_stream_batched(p)
    br = _pick_block_rows(pstream.shape[1])
    po = cg_xpay_batched_pallas(beta, rstream, pstream, gate,
                                block_rows=br, interpret=interpret)
    nb = shape[0]
    per = p.size // nb
    return po.reshape(nb, -1)[:, :per].reshape(shape)


def fused_engine(*, interpret: bool | None = None, use_pallas: bool = True):
    """(update, xpay) pair for the solvers' injectable vector engine.

    Plug straight into :func:`repro.core.solvers.cg`'s ``update=``/``xpay=``
    hooks: the per-iteration vector algebra then runs through the two fused
    streaming kernels (4 reads + 2 writes for the x/r/||r||² triad, 2 reads
    + 1 write for the direction update) instead of seven separate jnp
    passes.
    """
    update = functools.partial(cg_update, interpret=interpret,
                               use_pallas=use_pallas)
    xpay = functools.partial(cg_xpay, interpret=interpret,
                             use_pallas=use_pallas)
    return update, xpay


def fused_engine_batched(*, interpret: bool | None = None,
                         use_pallas: bool = True):
    """(update, xpay) pair for the solvers' BATCHED vector engine.

    For ``cg(..., batched=True)``: ``update`` takes the per-RHS (N,)
    ``alpha`` (already masked to 0 on converged systems) and returns
    per-RHS residual norms; ``xpay`` additionally takes the solver's
    activity ``gate`` so converged directions freeze inside the kernel.
    See DESIGN.md §6 for the contract.
    """
    update = functools.partial(cg_update_batched, interpret=interpret,
                               use_pallas=use_pallas)
    xpay = functools.partial(cg_xpay_batched, interpret=interpret,
                             use_pallas=use_pallas)
    return update, xpay


def cg_pallas(op, b, *, tol=1e-8, maxiter=1000, interpret: bool | None = None):
    """CG whose vector algebra runs through the fused Pallas kernels.

    The matvec ``op`` is arbitrary (e.g. the wilson_dslash normal op);
    everything else is two fused streaming passes per iteration.
    """
    x = jnp.zeros_like(b)
    r = b
    p = r
    rs = jnp.sum(r.astype(jnp.float32) ** 2)
    bs = rs
    limit = (tol ** 2) * bs

    def cond(c):
        k, x, r, p, rs = c
        return jnp.logical_and(k < maxiter, rs > limit)

    def body(c):
        k, x, r, p, rs = c
        ap = op(p)
        pap = jnp.sum(p.astype(jnp.float32) * ap.astype(jnp.float32))
        alpha = rs / pap
        x, r, rs_new = cg_update(alpha, x, r, p, ap, interpret=interpret)
        beta = rs_new / rs
        p = cg_xpay(beta, r, p, interpret=interpret)
        return (k + 1, x, r, p, rs_new)

    k, x, r, p, rs = jax.lax.while_loop(
        cond, body, (jnp.asarray(0, jnp.int32), x, r, p, rs))
    return x, (k, rs)
