"""Pure-jnp oracle for the fused CG vector-update kernels."""

import jax
import jax.numpy as jnp


def cg_update_ref(alpha, x, r, p, ap):
    a32 = jnp.float32(alpha) if not hasattr(alpha, "dtype") else \
        alpha.astype(jnp.float32)
    x32 = x.astype(jnp.float32) + a32 * p.astype(jnp.float32)
    r32 = r.astype(jnp.float32) - a32 * ap.astype(jnp.float32)
    return (x32.astype(x.dtype), r32.astype(r.dtype),
            jnp.sum(r32 * r32, dtype=jnp.float32))


def cg_xpay_ref(beta, r, p):
    b32 = jnp.float32(beta) if not hasattr(beta, "dtype") else \
        beta.astype(jnp.float32)
    return (r.astype(jnp.float32)
            + b32 * p.astype(jnp.float32)).astype(p.dtype)


# Batched (multi-RHS) oracles: leading axis is the RHS batch, scalars are
# per-RHS (N,).  vmaps of the single-RHS refs so each slice reduces in the
# same order as an independent solve.


def cg_update_batched_ref(alpha, x, r, p, ap):
    """Per-RHS (x + α_n p, r - α_n Ap, ||r'_n||²); alpha is (N,)."""
    a = jnp.asarray(alpha, jnp.float32)
    return jax.vmap(cg_update_ref)(a, x, r, p, ap)


def cg_xpay_batched_ref(beta, r, p, gate):
    """Per-RHS gated direction update: frozen (gate_n False) slices keep p."""
    b = jnp.asarray(beta, jnp.float32)
    po = jax.vmap(cg_xpay_ref)(b, r, p)
    sel = jnp.asarray(gate, bool).reshape((-1,) + (1,) * (p.ndim - 1))
    return jnp.where(sel, po, p)
