"""Pure-jnp oracle for the fused CG vector-update kernels."""

import jax.numpy as jnp


def cg_update_ref(alpha, x, r, p, ap):
    a32 = jnp.float32(alpha) if not hasattr(alpha, "dtype") else \
        alpha.astype(jnp.float32)
    x32 = x.astype(jnp.float32) + a32 * p.astype(jnp.float32)
    r32 = r.astype(jnp.float32) - a32 * ap.astype(jnp.float32)
    return (x32.astype(x.dtype), r32.astype(r.dtype),
            jnp.sum(r32 * r32, dtype=jnp.float32))


def cg_xpay_ref(beta, r, p):
    b32 = jnp.float32(beta) if not hasattr(beta, "dtype") else \
        beta.astype(jnp.float32)
    return (r.astype(jnp.float32)
            + b32 * p.astype(jnp.float32)).astype(p.dtype)
