from repro.kernels.cg_fused.kernel import cg_update_pallas, cg_xpay_pallas
from repro.kernels.cg_fused.ops import (cg_pallas, cg_update, cg_xpay,
                                        fused_engine)
from repro.kernels.cg_fused.ref import cg_update_ref, cg_xpay_ref
