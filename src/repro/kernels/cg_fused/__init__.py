from repro.kernels.cg_fused.kernel import (cg_update_batched_pallas,
                                           cg_update_pallas,
                                           cg_xpay_batched_pallas,
                                           cg_xpay_pallas)
from repro.kernels.cg_fused.ops import (cg_pallas, cg_update,
                                        cg_update_batched, cg_xpay,
                                        cg_xpay_batched, fused_engine,
                                        fused_engine_batched)
from repro.kernels.cg_fused.ref import (cg_update_batched_ref, cg_update_ref,
                                        cg_xpay_batched_ref, cg_xpay_ref)
