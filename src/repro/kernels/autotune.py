"""Offline autotuner for the dslash launch space (DESIGN.md §13).

Sweeps the tile knobs of the plane-streaming kernels — z-block ``bz``,
y-block ``by``, RHS-batch placement ``batch``, gauge streaming mode
``stream`` — compiling each candidate and timing warm steady state, then
persists the winner per ``(backend, lattice_shape, nrhs, dtype)`` via
:func:`repro.kernels.dispatch.save_tuning_cache` into the checked-in
``kernels/tuning_cache.json`` that :func:`~repro.kernels.dispatch.
pick_tile` consults at trace time.

Every candidate is **bitwise-identical** to every other (the tile changes
HBM->VMEM data movement only, never per-site FMA order — asserted in
``tests/test_autotune.py``), so the sweep needs no accuracy check and the
cache can only change speed, never results.

The sweep times the lowering the tiles actually steer: the Pallas
interpreter on CPU, compiled Mosaic on GPU/TPU (the compiled-CPU path is
the XLA fallback, which has no tiles — ``resolve_lowering`` routes around
them there).  Interpret-mode ordering on CPU is a *data-movement* signal;
device sweeps produce the numbers that matter and land in the same cache
under their own backend key.

CLI::

    python -m repro.kernels.autotune --dims 4x4x4x8 --nrhs 1 8 \
        --out src/repro/kernels/tuning_cache.json

(paper lineage: arXiv 2111.14958 treats per-device kernel tuning as the
portability layer; this module is that layer for the Pallas port.)
"""

from __future__ import annotations

import argparse
import itertools
import sys
import time

import jax
import jax.numpy as jnp

from repro.core import LatticeShape, pack_gauge, pack_spinor
from repro.kernels.dispatch import (TileConfig, cache_key, device_kind,
                                    load_tuning_cache, save_tuning_cache)
from repro.kernels.wilson_dslash.kernel import _divisors, dslash_pallas

_BENCH_MASS = 0.1


def candidates(lattice_shape: tuple[int, int, int, int], nrhs: int, *,
               max_bz: int = 8, sweep_by: bool = True) -> list[TileConfig]:
    """The candidate tiles for one (lattice, nrhs) point.

    bz sweeps the divisors of Z up to ``max_bz``; by sweeps {Y, Y/2}
    (smaller y-blocks only shrink VMEM working set, the interesting
    boundary); batch="grid" applies only to real batches; stream="db"
    only to the layouts it supports (untiled Y, batch="block").
    """
    t, z, y, x = lattice_shape
    bzs = [c for c in _divisors(z) if c <= max_bz]
    bys = [y]
    if sweep_by and y % 2 == 0 and y > 1:
        bys.append(y // 2)
    batches = ["block"] + (["grid"] if nrhs > 1 else [])
    out = []
    for bz, by, batch, stream in itertools.product(
            bzs, bys, batches, ("blockspec", "db")):
        if stream == "db" and (by < y or batch == "grid"):
            continue
        out.append(TileConfig(bz=bz, by=by, batch=batch, stream=stream))
    return out


def _problem(lattice_shape, nrhs: int, dtype):
    lat = LatticeShape(*lattice_shape)
    key = jax.random.PRNGKey(1234)
    ku, kp = jax.random.split(key)
    from repro.core import random_gauge, random_spinor
    up = pack_gauge(random_gauge(ku, lat)).astype(dtype)
    pp = pack_spinor(random_spinor(kp, lat)).astype(dtype)
    if nrhs > 1:
        pp = jnp.stack([pp] * nrhs)
    return up, pp


def time_tile(up, pp, tile: TileConfig, *, iters: int = 2, reps: int = 3,
              interpret: bool | None = None) -> dict:
    """Compile one candidate and time warm steady state (best-of-reps
    mean-of-iters, the standard min-timing protocol)."""
    fn = jax.jit(lambda u, p: dslash_pallas(
        u, p, _BENCH_MASS, bz=tile.bz, by=tile.by, batch=tile.batch,
        stream=tile.stream, interpret=interpret))
    t0 = time.perf_counter()
    jax.block_until_ready(fn(up, pp))       # compile + first call
    us_first = (time.perf_counter() - t0) * 1e6
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(up, pp)
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) / iters)
    return {"us_warm": best * 1e6, "us_first": us_first}


def sweep(lattice_shape: tuple[int, int, int, int], nrhs: int = 1,
          dtype=jnp.float32, *, max_bz: int = 8, sweep_by: bool = True,
          iters: int = 2, reps: int = 3, interpret: bool | None = None,
          verbose: bool = False) -> tuple[TileConfig, list[dict]]:
    """Time every candidate for one point; returns (winner, all results)."""
    up, pp = _problem(lattice_shape, nrhs, dtype)
    results = []
    for tile in candidates(lattice_shape, nrhs, max_bz=max_bz,
                           sweep_by=sweep_by):
        timing = time_tile(up, pp, tile, iters=iters, reps=reps,
                           interpret=interpret)
        results.append({**tile.to_entry(), **timing})
        if verbose:
            print(f"  {tile.to_entry()} -> {timing['us_warm']:.0f}us warm",
                  file=sys.stderr)
    winner = min(results, key=lambda r: r["us_warm"])
    return (TileConfig(bz=winner["bz"], by=winner["by"],
                       batch=winner["batch"], stream=winner["stream"]),
            results)


def autotune(points: list[tuple[tuple[int, int, int, int], int]],
             dtype=jnp.float32, *, max_bz: int = 8, sweep_by: bool = True,
             iters: int = 2, reps: int = 3, interpret: bool | None = None,
             verbose: bool = False) -> dict:
    """Sweep a list of (lattice_shape, nrhs) points; returns cache entries
    keyed by :func:`~repro.kernels.dispatch.cache_key` (winner tile plus
    its warm timing, for provenance)."""
    backend = jax.default_backend()
    entries = {}
    for lattice_shape, nrhs in points:
        if verbose:
            print(f"sweep {lattice_shape} nrhs={nrhs}", file=sys.stderr)
        winner, results = sweep(lattice_shape, nrhs, dtype, max_bz=max_bz,
                                sweep_by=sweep_by, iters=iters, reps=reps,
                                interpret=interpret, verbose=verbose)
        best = min(results, key=lambda r: r["us_warm"])
        entries[cache_key(backend, lattice_shape, nrhs, dtype)] = {
            **winner.to_entry(),
            "us_warm": round(best["us_warm"], 1),
            "candidates": len(results),
        }
    return entries


def _parse_dims(s: str) -> tuple[int, int, int, int]:
    dims = tuple(int(d) for d in s.lower().split("x"))
    if len(dims) != 4:
        raise argparse.ArgumentTypeError(
            f"dims must be TxZxYxX, got {s!r}")
    return dims


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        description="sweep the dslash launch space, persist winners")
    p.add_argument("--dims", type=_parse_dims, nargs="+",
                   default=[(4, 4, 4, 8)],
                   help="lattice extents TxZxYxX (repeatable)")
    p.add_argument("--nrhs", type=int, nargs="+", default=[1, 8],
                   help="RHS-batch sizes to tune (each is its own key)")
    p.add_argument("--dtype", default="float32",
                   choices=["float32", "bfloat16"])
    p.add_argument("--out", default=None,
                   help="cache JSON path (default: the package's "
                        "tuning_cache.json)")
    p.add_argument("--max-bz", type=int, default=8)
    p.add_argument("--no-by", action="store_true",
                   help="skip the y-tiling dimension")
    p.add_argument("--iters", type=int, default=2)
    p.add_argument("--reps", type=int, default=3)
    p.add_argument("--merge", action="store_true",
                   help="merge into the existing cache instead of "
                        "replacing it (keeps other backends' entries)")
    p.add_argument("-v", "--verbose", action="store_true")
    args = p.parse_args(argv)

    dtype = jnp.dtype(args.dtype)
    points = [(dims, n) for dims in args.dims for n in args.nrhs]
    entries = autotune(points, dtype, max_bz=args.max_bz,
                       sweep_by=not args.no_by, iters=args.iters,
                       reps=args.reps, verbose=args.verbose)
    if args.merge:
        entries = {**load_tuning_cache(args.out), **entries}
    meta = {"backend": jax.default_backend(), "device_kind": device_kind(),
            "jax": jax.__version__}
    path = save_tuning_cache(entries, path=args.out, meta=meta)
    print(f"wrote {len(entries)} entries -> {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
