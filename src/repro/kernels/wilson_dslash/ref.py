"""Pure-jnp oracles for the wilson_dslash Pallas kernels.

The full-lattice reference is the packed-layout operator from the core
library, which is itself validated against the natural-layout complex
operator (and the latter against gamma-matrix algebra identities) in
tests/test_wilson.py.

The parity (even-odd) references round-trip through the natural-layout
complex half-field operators in :mod:`repro.core.wilson` — slow but
maximally independent of the kernel code they validate, "compiled and
executed exclusively on CPU for debugging and reference benchmarking"
in the paper's words.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.lattice import pack_spinor, unpack_gauge, unpack_spinor
from repro.core.operators import (schur_dagger_g, schur_normal_op_g,
                                  schur_op_g)
from repro.core.wilson import apply_gamma5
from repro.core.wilson import dslash_eo as _core_dslash_eo
from repro.core.wilson import dslash_oe as _core_dslash_oe
from repro.core.wilson import dslash_packed as dslash_ref  # noqa: F401
from repro.core.wilson import (dslash_dagger_packed as dslash_dagger_ref,  # noqa: F401
                               normal_op_packed as normal_op_ref)  # noqa: F401


def _via_natural(fn, u_e_p: jax.Array, u_o_p: jax.Array, pp: jax.Array,
                 gamma5_in: bool, gamma5_out: bool) -> jax.Array:
    """Unpack packed half fields, apply a natural-layout op, repack.

    A rank-6 ``pp`` is an (N, T, Z, Y, 24, Xh) RHS batch: the natural-layout
    operator is vmapped over the leading axis (gauge held fixed), so each
    slice reproduces the single-RHS oracle exactly.
    """
    u_e = unpack_gauge(u_e_p.astype(jnp.float32))
    u_o = unpack_gauge(u_o_p.astype(jnp.float32))
    v = unpack_spinor(pp.astype(jnp.float32))
    if gamma5_in:
        v = apply_gamma5(v)
    op = lambda w: fn(u_e, u_o, w)
    out = jax.vmap(op)(v) if pp.ndim == 6 else op(v)
    if gamma5_out:
        out = apply_gamma5(out)
    return pack_spinor(out, dtype=pp.dtype)


def dslash_eo_ref(u_e_p, u_o_p, pp_o, *, gamma5_in=False, gamma5_out=False):
    """D_eo on packed half fields (odd in, even out), via the core oracle."""
    return _via_natural(_core_dslash_eo, u_e_p, u_o_p, pp_o,
                        gamma5_in, gamma5_out)


def dslash_oe_ref(u_e_p, u_o_p, pp_e, *, gamma5_in=False, gamma5_out=False):
    """D_oe on packed half fields (even in, odd out), via the core oracle."""
    return _via_natural(_core_dslash_oe, u_e_p, u_o_p, pp_e,
                        gamma5_in, gamma5_out)


def schur_op_ref(u_e_p, u_o_p, pp_e, mass, *, twist=0.0, dagger=False):
    """Schur complement D_hat (or D_hat^dag) on packed even half fields.

    ``twist`` is the operator registry's site-term twist: the dagger of a
    twisted operator flips it alongside the γ5 wraps
    (``schur_dagger_g`` handles the sign internally).
    """
    fn = schur_dagger_g if dagger else schur_op_g
    return _via_natural(lambda ue, uo, v: fn(ue, uo, v, mass, twist=twist),
                        u_e_p, u_o_p, pp_e, False, False)


def schur_normal_op_ref(u_e_p, u_o_p, pp_e, mass, *, twist=0.0):
    """A_hat = D_hat^dag D_hat on packed even half fields."""
    return _via_natural(
        lambda ue, uo, v: schur_normal_op_g(ue, uo, v, mass, twist=twist),
        u_e_p, u_o_p, pp_e, False, False)
