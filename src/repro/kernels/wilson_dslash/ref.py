"""Pure-jnp oracle for the wilson_dslash Pallas kernel.

The reference is the packed-layout operator from the core library, which is
itself validated against the natural-layout complex operator (and the
latter against gamma-matrix algebra identities) in tests/test_wilson.py.
"""

from repro.core.wilson import dslash_packed as dslash_ref  # noqa: F401
from repro.core.wilson import (dslash_dagger_packed as dslash_dagger_ref,  # noqa: F401
                               normal_op_packed as normal_op_ref)  # noqa: F401
