from repro.kernels.wilson_dslash.kernel import (dslash_eo_pallas,
                                                dslash_oe_pallas,
                                                dslash_pallas)
from repro.kernels.wilson_dslash.ops import (dslash, dslash_dagger,
                                             dslash_eo, dslash_oe, normal_op,
                                             schur_dagger, schur_normal_op,
                                             schur_op)
from repro.kernels.wilson_dslash.ref import (dslash_dagger_ref, dslash_eo_ref,
                                             dslash_oe_ref, dslash_ref,
                                             normal_op_ref,
                                             schur_normal_op_ref,
                                             schur_op_ref)
