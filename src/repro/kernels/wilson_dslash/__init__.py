from repro.kernels.wilson_dslash.kernel import dslash_pallas
from repro.kernels.wilson_dslash.ops import dslash, dslash_dagger, normal_op
from repro.kernels.wilson_dslash.ref import dslash_ref
