"""Pallas TPU kernels for the Dirac-Wilson stencil (packed layout).

This is the TPU re-think of the paper's FPGA compute kernel (Fig. 1) and
cyclic-buffer transport (its Ref. [11]):

* **grid = (T, Z/BZ[, Y/BY][, N])** — the kernel streams (t, z-block)
  lattice *planes* (optionally further tiled along Y, optionally with the
  RHS batch as the trailing grid axis); Pallas's software pipeline
  double-buffers the next planes' HBM->VMEM DMA behind the current
  plane's compute — the cyclic-buffer / II=1 analogue.
* **neighbour planes as extra BlockSpecs** — ψ(t±1), ψ(z-block boundary),
  ψ(y-block boundary when Y is tiled) and the backward links U_t(t-1),
  U_z(z-1), U_y(y-1) arrive through their own index-maps (periodic wrap
  via modular index arithmetic), so the kernel body never touches HBM
  addresses — exactly the paper's separation of "transport mechanism"
  from "stencil evaluation".
* **Y/X hops stay inside the block** — when the block spans full Y those
  neighbours are register/VMEM rolls (X is the 128-lane axis); a tiled Y
  switches to the same boundary-splice scheme as Z, bitwise identically.
* **spin-projection trick** — each hop projects 4-spinors to 2 half
  spinors before the SU(3) multiply (stage 2 of the paper's Fig. 1
  pipeline), halving the matvec work: 8 hops × 2 matvecs = the standard
  1320 flop/site dslash.
* **γ5 folding** — ``gamma5_in``/``gamma5_out`` fold γ5 = diag(+,+,-,-)
  into the trace-time projection/reconstruction tables (a sign flip on
  constant coefficients), so D†ψ = γ5 D γ5 ψ and the CGNR normal operator
  cost ZERO extra full-field HBM passes versus plain D.

**Launch space (DESIGN.md §13).**  Tile parameters — z-block ``bz``,
y-block ``by``, RHS-batch placement ``batch`` ("block" keeps the whole
batch inside every block; "grid" makes it the trailing, fastest-varying
grid axis so consecutive steps revisit one gauge block), and gauge
streaming mode ``stream`` ("blockspec" = the implicit Pallas pipeline;
"db" = explicit double-buffering of the center gauge planes through a
2-slot VMEM scratch with async copies, so the next (t, z-block) plane's
DMA overlaps the current plane's compute) — are all **bitwise-neutral**:
they change HBM->VMEM data movement only, never the per-site FMA order.
When none is given explicitly the wrappers consult the autotuner's
checked-in ``kernels/tuning_cache.json`` (:func:`repro.kernels.dispatch.
pick_tile`); a cold or disabled cache falls back to the deterministic
historical defaults.

**Lowerings.**  ``interpret=None`` interprets on CPU and compiles
(Mosaic) on GPU/TPU; ``interpret=False`` on CPU routes to the
compiled-XLA half-spinor implementation in
:mod:`repro.kernels.wilson_dslash.xla` — ``pallas_call`` cannot compile
on the CPU backend, and the XLA path is this host's honest compiled
number (see :func:`repro.kernels.dispatch.resolve_lowering`).

Two kernel families share the machinery:

* ``dslash_pallas``      — the full-lattice operator (mass term + 8 hops);
* ``dslash_eo_pallas`` / ``dslash_oe_pallas`` — the even-odd parity hop
  blocks D_eo / D_oe on half fields whose X axis is parity-compressed by 2
  (see :mod:`repro.core.lattice`).  Within a row (t, z, y) the x-neighbour
  of compressed index j is j + s (forward) / j - (1 - s) (backward) where
  s is the output row's parity offset — realised as a per-row select
  between the block and its lane-rolled copy.  The parity kernels also
  take an optional accumulator operand (``psi_acc``/``acc_coeff``/
  ``hop_coeff``) so the Schur complement m·ψ - D_eo D_oe ψ / m is TWO
  kernel launches with the axpy folded into the second epilogue — no
  separate full-field scale/add passes.

Both families are **multi-RHS batched**: a spinor field may carry a leading
RHS-batch axis (N, T, Z, Y, 24, X).  The batched BlockSpecs pin the batch
block index to 0 (the whole batch rides in each block) while the gauge
BlockSpecs are untouched — so one HBM fetch of a gauge plane (8 links ×
18 reals = 144 reals/site) feeds all N spinor planes (24 in + 24 out
reals/site each), shrinking per-RHS traffic from 144+48 to 144/N+48
reals/site: an up-to (144+48)/48 ≈ 4x arithmetic-intensity gain before
the compute roof (see DESIGN.md §6).  The kernel bodies are
rank-polymorphic (negative-axis rolls/shifts, broadcasting selects), so
batching adds ZERO trace-time unrolling — compile time is independent of N.

The kernels compute in f32 registers regardless of the (bf16/f32) storage
dtype — narrow storage, wide accumulate, like the FPGA DSP datapath.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.lattice import GAUGE_G, NCOL, NDIRS, NSPIN, SPINOR_S
from repro.core.wilson import _projectors
from repro.kernels.dispatch import pick_tile, resolve_lowering

# ---------------------------------------------------------------------------
# Trace-time tables for the spin-projection trick.
#
# For r=1 every hop matrix P = (1 ∓ γ_mu) has rank 2: rows 2,3 are a complex
# phase times row 0 or 1.  We precompute, per (mu, sign):
#   proj[alpha in {0,1}]   -> list of (beta, coeff) with coeff = P[alpha,beta]
#   recon[alpha in {2,3}]  -> (src_halfspinor_row, phase)
# ---------------------------------------------------------------------------


def _halfspinor_tables():
    pm, pp = _projectors(1.0)
    tables = {}
    for mu in range(NDIRS):
        for sign, P in (("fwd", pm[mu]), ("bwd", pp[mu])):
            proj = []
            for a in range(2):
                terms = [(b, complex(P[a, b])) for b in range(NSPIN)
                         if abs(P[a, b]) > 1e-12]
                proj.append(terms)
            recon = []
            for a in (2, 3):
                row = P[a]
                hit = None
                for src in range(2):
                    ref = P[src]
                    nz = np.nonzero(np.abs(ref) > 1e-12)[0]
                    if np.all((np.abs(row) > 1e-12) == (np.abs(ref) > 1e-12)):
                        phase = row[nz[0]] / ref[nz[0]]
                        if np.allclose(row, phase * ref, atol=1e-12):
                            hit = (src, complex(phase))
                            break
                if hit is None:  # zero row (can happen only for r != 1)
                    raise ValueError("projector is not rank-2; need r=1")
                recon.append(hit)
            tables[(mu, sign)] = (proj, recon)
    return tables


_TABLES = _halfspinor_tables()


def _cmul_phase(gr, gi, phase: complex):
    """(gr + i gi) * phase with trace-time constant folding."""
    cr, ci = phase.real, phase.imag
    outr = 0.0
    outi = 0.0
    if cr != 0.0:
        outr = cr * gr
        outi = cr * gi
    if ci != 0.0:
        outr = outr - ci * gi if cr != 0.0 else -ci * gi
        outi = outi + ci * gr if cr != 0.0 else ci * gr
    return outr, outi


def _hop(out_r, out_i, psi_r, psi_i, u_r, u_i, mu: int, sign: str,
         g5in: bool = False, g5out: bool = False):
    """Accumulate -1/2 * G5out P (U G5in psi) for one hop into out_{r,i}.

    psi_{r,i}: [spin][color] -> (..., X) arrays  (the neighbour spinor)
    u_{r,i}:   [row][col]    -> (..., X) arrays  (U or, for 'bwd', U^dag is
               realized by index transposition + conjugation here)

    γ5 = diag(+,+,-,-) folds into the constant tables: ``g5in`` negates the
    projection coefficients of source spins 2,3 (P -> P γ5), ``g5out``
    negates the reconstruction phases of output spins 2,3 (P -> γ5 P) —
    both are trace-time sign flips, zero runtime cost.
    """
    proj, recon = _TABLES[(mu, sign)]
    if g5in:  # P γ5: columns 2,3 change sign
        proj = [[(b, -coeff if b >= 2 else coeff) for (b, coeff) in terms]
                for terms in proj]
    if g5out:  # γ5 P: rows 2,3 change sign (rows 0,1 untouched)
        recon = [(src, -phase) for (src, phase) in recon]
    dag = sign == "bwd"
    # stage 2a: project to half spinors  h[alpha][c]
    h_r = [[None] * NCOL for _ in range(2)]
    h_i = [[None] * NCOL for _ in range(2)]
    for a in range(2):
        for c in range(NCOL):
            accr, acci = 0.0, 0.0
            for (b, coeff) in proj[a]:
                tr, ti = _cmul_phase(psi_r[b][c], psi_i[b][c], coeff)
                accr = accr + tr
                acci = acci + ti
            h_r[a][c] = accr
            h_i[a][c] = acci
    # stage 2b: SU(3) multiply g[alpha] = U h[alpha]  (or U^dag h)
    g_r = [[None] * NCOL for _ in range(2)]
    g_i = [[None] * NCOL for _ in range(2)]
    for a in range(2):
        for row in range(NCOL):
            accr, acci = 0.0, 0.0
            for col in range(NCOL):
                if not dag:
                    ur, ui = u_r[row][col], u_i[row][col]
                else:  # (U^dag)[row,col] = conj(U[col,row])
                    ur, ui = u_r[col][row], -u_i[col][row]
                hr, hi = h_r[a][col], h_i[a][col]
                accr = accr + ur * hr - ui * hi
                acci = acci + ur * hi + ui * hr
            g_r[a][row] = accr
            g_i[a][row] = acci
    # stage 3: reconstruct 4-spinor rows and accumulate with -1/2
    for c in range(NCOL):
        for a in range(2):
            out_r[a][c] = out_r[a][c] - 0.5 * g_r[a][c]
            out_i[a][c] = out_i[a][c] - 0.5 * g_i[a][c]
        for idx, a in enumerate((2, 3)):
            src, phase = recon[idx]
            pr, pi = _cmul_phase(g_r[src][c], g_i[src][c], phase)
            out_r[a][c] = out_r[a][c] - 0.5 * pr
            out_i[a][c] = out_i[a][c] - 0.5 * pi


def _split_spinor_block(blk):
    """(..., Y, S=24, X) -> [spin][color] re/im lists of (..., Y, X) f32.

    Per-element axis order is (..., BZ, Y, X) — leading axes (e.g. the
    RHS-batch axis of the batched kernels) pass through unchanged.
    """
    x = blk.shape[-1]
    q = blk.reshape(blk.shape[:-2] + (NSPIN, NCOL, 2, x)).astype(jnp.float32)
    re = [[q[..., s_, c_, 0, :] for c_ in range(NCOL)] for s_ in range(NSPIN)]
    im = [[q[..., s_, c_, 1, :] for c_ in range(NCOL)] for s_ in range(NSPIN)]
    return re, im


def _split_gauge_block(blk):
    """(..., Y, G=18, X) -> [row][col] re/im lists of (..., Y, X) f32."""
    x = blk.shape[-1]
    q = blk.reshape(blk.shape[:-2] + (NCOL, NCOL, 2, x)).astype(jnp.float32)
    re = [[q[..., a, b, 0, :] for b in range(NCOL)] for a in range(NCOL)]
    im = [[q[..., a, b, 1, :] for b in range(NCOL)] for a in range(NCOL)]
    return re, im


def _repack_spinor_block(out_r, out_i, dtype):
    """[spin][color] re/im lists of (..., Y, X) -> (..., Y, 24, X)."""
    flat = []
    for s in range(NSPIN):
        for c in range(NCOL):
            flat.append(out_r[s][c])
            flat.append(out_i[s][c])
    return jnp.stack(flat, axis=-2).astype(dtype)


# Within a block element (..., BZ, BY, X): Y rolls on axis -2, X (lane) rolls
# on axis -1, the z-shift splices along axis -3 — negative so the same
# kernel body serves the plain blocks and the batched (NB leading) blocks.
_Y_AXIS, _X_AXIS, _Z_AXIS = -2, -1, -3


def _roll_sc(lists, shift, axis):
    return [[jnp.roll(e, shift, axis=axis) for e in row] for row in lists]


def _where_sc(sel, a_lists, b_lists):
    """Elementwise select between two [..][..] lists of (..., Y, X) blocks."""
    return [[jnp.where(sel, a, b) for a, b in zip(ra, rb)]
            for ra, rb in zip(a_lists, b_lists)]


def _shift(lists, boundary, forward: bool, axis: int):
    """Shift [..][..] lists of (..., BZ, BY, X) along ``axis``, splicing
    the boundary plane (extent 1 on that axis) in at the open end.

    Bitwise-equivalent to ``jnp.roll`` when the block spans the full
    extent — the Y-tiled launch switches rolls to shifts without changing
    any per-site value or FMA order.
    """
    out = []
    for r, row in enumerate(lists):
        orow = []
        for c, e in enumerate(row):
            b = boundary[r][c]
            n = e.shape[axis]
            if forward:  # value at +1: drop plane 0, append boundary
                body = jax.lax.slice_in_dim(e, 1, n, axis=axis)
                orow.append(jnp.concatenate([body, b], axis=axis))
            else:        # value at -1: prepend boundary, drop last
                body = jax.lax.slice_in_dim(e, 0, n - 1, axis=axis)
                orow.append(jnp.concatenate([b, body], axis=axis))
        out.append(orow)
    return out


# ---------------------------------------------------------------------------
# Shared plane-streaming BlockSpecs (full-lattice AND parity kernels)
# ---------------------------------------------------------------------------


def _divisors(n: int) -> list[int]:
    return [c for c in range(1, n + 1) if n % c == 0]


def _pick_bz(z: int, bz: int | None) -> int:
    """Validate/default the z-block size. Default: largest divisor ≤ 4."""
    if bz is None:
        return max(c for c in (1, 2, 3, 4) if z % c == 0)
    bz = int(bz)
    if bz < 1 or z % bz != 0:
        raise ValueError(
            f"bz={bz} does not tile the Z extent {z}: the z-block size "
            f"must be a positive divisor of Z; legal bz values for Z={z}: "
            f"{_divisors(z)}")
    return bz


def _pick_by(y: int, by: int | None) -> int:
    """Validate/default the y-block size. Default: the full Y extent."""
    if by is None:
        return y
    by = int(by)
    if by < 1 or y % by != 0:
        raise ValueError(
            f"by={by} does not tile the Y extent {y}: the y-block size "
            f"must be a positive divisor of Y; legal by values for Y={y}: "
            f"{_divisors(y)}")
    return by


def _site_spec(zblk: int, yblk: int, s: int, x: int, tmap, zmap, ymap,
               nb: int | None, grid_batch: bool, y_tiled: bool):
    """BlockSpec for one (t, z-block[, y-block]) plane of a site field.

    ``nb`` is the RHS-batch extent: None produces the plain 5D layout;
    with a batch the placement decides the block shape — "block"
    (``grid_batch=False``) prepends a FULL batch axis whose block index
    is pinned to 0 (every grid step sees all N spinor planes while the
    gauge specs deliver each link plane exactly once: the
    gauge-amortization contract), "grid" (``grid_batch=True``) prepends a
    size-1 batch axis indexed by the TRAILING grid dimension, so
    consecutive grid steps revisit the same gauge block with an N-times
    smaller spinor working set.
    """
    def site_idx(ids):
        ti, zi = ids[0], ids[1]
        yi = ids[2] if y_tiled else 0
        return (tmap(ti), zmap(zi), ymap(yi), 0, 0)
    if nb is None:
        return pl.BlockSpec((1, zblk, yblk, s, x),
                            lambda *ids: site_idx(ids))
    if grid_batch:
        return pl.BlockSpec((1, 1, zblk, yblk, s, x),
                            lambda *ids: (ids[-1],) + site_idx(ids))
    return pl.BlockSpec((nb, 1, zblk, yblk, s, x),
                        lambda *ids: (0,) + site_idx(ids))


def _spinor_specs(t: int, z: int, bz: int, y: int, by: int, x: int,
                  nb: int | None = None, grid_batch: bool = False):
    """center, t±1, z-boundary (and, when Y is tiled, y-boundary) specs.

    Returns a list of 5 specs (full-Y blocks) or 7 (Y-tiled: +ym, +yp).
    """
    s = SPINOR_S
    y_tiled = by < y
    idf = lambda i: i
    mk = functools.partial(_site_spec, nb=nb, grid_batch=grid_batch,
                           y_tiled=y_tiled)
    c = mk(bz, by, s, x, idf, idf, idf)
    tm = mk(bz, by, s, x, lambda ti: (ti - 1 + t) % t, idf, idf)
    tp = mk(bz, by, s, x, lambda ti: (ti + 1) % t, idf, idf)
    # single boundary planes (block size 1 -> block index = plane idx)
    zm = mk(1, by, s, x, idf, lambda zi: (zi * bz - 1 + z) % z, idf)
    zp = mk(1, by, s, x, idf, lambda zi: (zi * bz + bz) % z, idf)
    specs = [c, tm, tp, zm, zp]
    if y_tiled:
        ym = mk(bz, 1, s, x, idf, idf, lambda yi: (yi * by - 1 + y) % y)
        yp = mk(bz, 1, s, x, idf, idf, lambda yi: (yi * by + by) % y)
        specs += [ym, yp]
    return specs


def _gauge_specs(t: int, z: int, bz: int, y: int, by: int, x: int,
                 grid_batch: bool = False):
    """center (all 4 dirs), U_t(t-1), the U_z(z-1) boundary plane and,
    when Y is tiled, the U_y(y-1) boundary plane.

    Gauge fields never carry a batch axis; with the batch on the grid the
    index maps simply ignore the trailing grid id — consecutive steps
    then ask for the SAME gauge block, which the pipeline need not
    refetch.
    """
    g = GAUGE_G
    y_tiled = by < y

    def gmap(dmap, tfn, zfn, yfn):
        def imap(*ids):
            ti, zi = ids[0], ids[1]
            yi = ids[2] if y_tiled else 0
            return (dmap, tfn(ti), zfn(zi), yfn(yi), 0, 0)
        return imap

    idf = lambda i: i
    c = pl.BlockSpec((NDIRS, 1, bz, by, g, x), gmap(0, idf, idf, idf))
    tm = pl.BlockSpec((1, 1, bz, by, g, x),
                      gmap(0, lambda ti: (ti - 1 + t) % t, idf, idf))
    zm = pl.BlockSpec((1, 1, 1, by, g, x),
                      gmap(1, idf, lambda zi: (zi * bz - 1 + z) % z, idf))
    specs = [c, tm, zm]
    if y_tiled:
        ym = pl.BlockSpec((1, 1, bz, 1, g, x),
                          gmap(2, idf, idf, lambda yi: (yi * by - 1 + y) % y))
        specs.append(ym)
    return specs


def _resolve_tile(bz, by, batch, stream, t, z, y, x, nb, dtype):
    """Explicit args > tuning cache > deterministic defaults.

    Any explicitly-passed knob disables the cache for the whole launch
    (tests and the autotuner stay deterministic); all-None consults
    :func:`repro.kernels.dispatch.pick_tile`, whose miss path IS the
    historical default.
    """
    if bz is None and by is None and batch is None and stream is None:
        tile = pick_tile((t, z, y, x), nb or 1, dtype)
        bz, by, batch, stream = tile.bz, tile.by, tile.batch, tile.stream
    batch = batch or "block"
    stream = stream or "blockspec"
    bz = _pick_bz(z, bz)
    by = _pick_by(y, by)
    y_tiled = by < y
    # an unbatched field has no batch axis to place — "grid" degenerates
    # to the plain layout
    grid_batch = batch == "grid" and nb is not None
    if stream == "db" and (y_tiled or grid_batch):
        raise ValueError(
            "gauge stream 'db' double-buffers whole (t, z-block) gauge "
            "planes and supports only the untiled-Y, batch='block' "
            f"layout; got by={by} (Y={y}), batch={batch!r}")
    return bz, by, batch, stream, y_tiled, grid_batch


def _launch_grid(t, z, bz, y, by, nb, y_tiled, grid_batch):
    grid = (t, z // bz)
    if y_tiled:
        grid += (y // by,)
    if grid_batch:
        grid += (nb,)
    return grid


# ---------------------------------------------------------------------------
# Double-buffered gauge streaming (stream="db")
#
# The center gauge operand (4 dirs × 18 reals = 144 reals/site — the
# dominant stream; boundary fix-up planes stay on the implicit pipeline)
# lives in ANY memory and is copied (t, z-block)-plane by plane into a
# 2-slot VMEM scratch: at grid step i the kernel STARTS the DMA for step
# i+1 into slot (i+1)%2, then WAITS on slot i%2 and computes from it —
# the copy of the next plane overlaps the current plane's compute.  All
# grid/program ids are hoisted OUT of the pl.when closures (a program_id
# primitive inside a cond branch cannot lower on the interpret path).
# ---------------------------------------------------------------------------


def _db_gauge_plane(u_any, u_vmem, sem, bz: int):
    """Prefetch-next / wait-current on one gauge stream; returns the
    current step's (NDIRS, bz, Y, G, X) VMEM plane."""
    ti, zi = pl.program_id(0), pl.program_id(1)
    nzb = pl.num_programs(1)
    total = pl.num_programs(0) * nzb
    step = ti * nzb + zi
    slot = jax.lax.rem(step, 2)
    nxt = step + 1
    nslot = jax.lax.rem(nxt, 2)
    ti_n, zi_n = nxt // nzb, jax.lax.rem(nxt, nzb)

    def start(s, t_, z_):
        pltpu.make_async_copy(
            u_any.at[:, t_, pl.ds(z_ * bz, bz)],
            u_vmem.at[s], sem.at[s]).start()

    @pl.when(step == 0)
    def _prologue():
        start(slot, ti, zi)

    @pl.when(nxt < total)
    def _prefetch():
        start(nslot, ti_n, zi_n)

    pltpu.make_async_copy(
        u_any.at[:, ti, pl.ds(zi * bz, bz)],
        u_vmem.at[slot], sem.at[slot]).wait()
    return u_vmem[slot]


def _db_scratch(bz: int, y: int, x: int, dtype, streams: int):
    """Scratch shapes for ``streams`` double-buffered gauge streams."""
    shapes = []
    for _ in range(streams):
        shapes.append(pltpu.VMEM((2, NDIRS, bz, y, GAUGE_G, x), dtype))
    for _ in range(streams):
        shapes.append(pltpu.SemaphoreType.DMA((2,)))
    return shapes


# ---------------------------------------------------------------------------
# Full-lattice kernel
# ---------------------------------------------------------------------------


def _take_plane(ref, batched: bool):
    """Drop the size-1 T-block axis: axis 0 plain, axis 1 when an RHS-batch
    axis leads the block."""
    return ref[:, 0] if batched else ref[0]


def _dslash_kernel(*refs, mass: float, twist: float = 0.0, g5in: bool,
                   g5out: bool, batched: bool = False, y_tiled: bool = False,
                   stream_db: bool = False, bz_sz: int = 0):
    f32 = jnp.float32
    psi_ym = psi_yp = u_ym = None
    if stream_db:
        (psi_c, psi_tm, psi_tp, psi_zm, psi_zp, u_any, u_tm, u_zm,
         out_ref, u_vmem, sem) = refs
    elif y_tiled:
        (psi_c, psi_tm, psi_tp, psi_zm, psi_zp, psi_ym, psi_yp,
         u_c, u_tm, u_zm, u_ym, out_ref) = refs
    else:
        (psi_c, psi_tm, psi_tp, psi_zm, psi_zp,
         u_c, u_tm, u_zm, out_ref) = refs

    # ---- stage 1: load & unpack (all data now in VMEM) ----
    pc_r, pc_i = _split_spinor_block(_take_plane(psi_c, batched))
    ptm_r, ptm_i = _split_spinor_block(_take_plane(psi_tm, batched))
    ptp_r, ptp_i = _split_spinor_block(_take_plane(psi_tp, batched))
    pzm_r, pzm_i = _split_spinor_block(_take_plane(psi_zm, batched))
    pzp_r, pzp_i = _split_spinor_block(_take_plane(psi_zp, batched))
    if stream_db:
        uv = _db_gauge_plane(u_any, u_vmem, sem, bz_sz)
        u = [_split_gauge_block(uv[mu]) for mu in range(NDIRS)]
    else:
        u = [_split_gauge_block(u_c[mu, 0]) for mu in range(NDIRS)]
    utm_r, utm_i = _split_gauge_block(u_tm[0, 0])
    uzm_r, uzm_i = _split_gauge_block(u_zm[0, 0])

    # mass term m4 * γ5out γ5in ψ: identity when the flags agree (γ5² = 1),
    # γ5 itself (spins 2,3 negated) when exactly one flag is set.
    m4 = f32(mass + 4.0)
    m4_lo = -m4 if (g5in != g5out) else m4
    out_r = [[(m4 if s < 2 else m4_lo) * pc_r[s][c] for c in range(NCOL)]
             for s in range(NSPIN)]
    out_i = [[(m4 if s < 2 else m4_lo) * pc_i[s][c] for c in range(NCOL)]
             for s in range(NSPIN)]

    # site-term twist (operator registry): + γ5out (twist·iγ5) γ5in ψ.
    # γ5 commutes through, so the wrap collapses to i·twist·γ5 ψ when the
    # flags agree (γ5² = 1) and to i·twist·ψ when exactly one is set —
    # per-spin trace-time constants; twist = 0 (Wilson) emits nothing.
    if twist != 0.0:
        for s in range(NSPIN):
            tw = f32(-twist if (g5in == g5out and s >= 2) else twist)
            for c in range(NCOL):
                out_r[s][c] = out_r[s][c] - tw * pc_i[s][c]
                out_i[s][c] = out_i[s][c] + tw * pc_r[s][c]

    hop = functools.partial(_hop, g5in=g5in, g5out=g5out)

    # ---- T direction (mu=0): neighbour planes come from extra refs ----
    hop(out_r, out_i, ptp_r, ptp_i, u[0][0], u[0][1], 0, "fwd")
    hop(out_r, out_i, ptm_r, ptm_i, utm_r, utm_i, 0, "bwd")

    # ---- Z direction (mu=1): in-block shift + boundary planes ----
    fz_r = _shift(pc_r, pzp_r, forward=True, axis=_Z_AXIS)
    fz_i = _shift(pc_i, pzp_i, forward=True, axis=_Z_AXIS)
    hop(out_r, out_i, fz_r, fz_i, u[1][0], u[1][1], 1, "fwd")
    bz_r = _shift(pc_r, pzm_r, forward=False, axis=_Z_AXIS)
    bz_i = _shift(pc_i, pzm_i, forward=False, axis=_Z_AXIS)
    ubz_r = _shift(u[1][0], uzm_r, forward=False, axis=_Z_AXIS)
    ubz_i = _shift(u[1][1], uzm_i, forward=False, axis=_Z_AXIS)
    hop(out_r, out_i, bz_r, bz_i, ubz_r, ubz_i, 1, "bwd")

    # ---- Y direction (mu=2): in-block rolls when the block spans full Y,
    # the Z-style boundary-splice when Y is tiled (bitwise identical) ----
    if y_tiled:
        pym_r, pym_i = _split_spinor_block(_take_plane(psi_ym, batched))
        pyp_r, pyp_i = _split_spinor_block(_take_plane(psi_yp, batched))
        uym_r, uym_i = _split_gauge_block(u_ym[0, 0])
        fy_r = _shift(pc_r, pyp_r, forward=True, axis=_Y_AXIS)
        fy_i = _shift(pc_i, pyp_i, forward=True, axis=_Y_AXIS)
        hop(out_r, out_i, fy_r, fy_i, u[2][0], u[2][1], 2, "fwd")
        by_r = _shift(pc_r, pym_r, forward=False, axis=_Y_AXIS)
        by_i = _shift(pc_i, pym_i, forward=False, axis=_Y_AXIS)
        uby_r = _shift(u[2][0], uym_r, forward=False, axis=_Y_AXIS)
        uby_i = _shift(u[2][1], uym_i, forward=False, axis=_Y_AXIS)
        hop(out_r, out_i, by_r, by_i, uby_r, uby_i, 2, "bwd")
    else:
        hop(out_r, out_i, _roll_sc(pc_r, -1, _Y_AXIS),
            _roll_sc(pc_i, -1, _Y_AXIS), u[2][0], u[2][1], 2, "fwd")
        hop(out_r, out_i, _roll_sc(pc_r, 1, _Y_AXIS),
            _roll_sc(pc_i, 1, _Y_AXIS),
            _roll_sc(u[2][0], 1, _Y_AXIS), _roll_sc(u[2][1], 1, _Y_AXIS),
            2, "bwd")

    # ---- X direction (mu=3): lane rolls ----
    hop(out_r, out_i, _roll_sc(pc_r, -1, _X_AXIS), _roll_sc(pc_i, -1, _X_AXIS),
        u[3][0], u[3][1], 3, "fwd")
    hop(out_r, out_i, _roll_sc(pc_r, 1, _X_AXIS), _roll_sc(pc_i, 1, _X_AXIS),
        _roll_sc(u[3][0], 1, _X_AXIS), _roll_sc(u[3][1], 1, _X_AXIS),
        3, "bwd")

    # ---- stage 4: repack & store ----
    packed = _repack_spinor_block(out_r, out_i, out_ref.dtype)
    if batched:
        out_ref[:, 0] = packed
    else:
        out_ref[0] = packed


def dslash_pallas(up: jax.Array, pp: jax.Array, mass: float, *,
                  bz: int | None = None, by: int | None = None,
                  batch: str | None = None, stream: str | None = None,
                  interpret: bool | None = None,
                  twist: float = 0.0, gamma5_in: bool = False,
                  gamma5_out: bool = False) -> jax.Array:
    """Dirac-Wilson dslash via the Pallas plane-streaming kernel.

    Args:
      up:   (4, T, Z, Y, 18, X) packed gauge field.
      pp:   (T, Z, Y, 24, X) packed spinor field, or (N, T, Z, Y, 24, X)
        for an N-RHS batch: the gauge BlockSpecs carry no batch axis, so
        each link plane is fetched ONCE per grid step and streams all N
        spinor planes through the stencil (multi-RHS gauge amortization).
      mass: bare mass (trace-time constant, like the paper's #define).
      twist: site-term twist (operator registry): adds ``i·twist·γ5 ψ`` to
        the mass term inside the kernel (twisted-mass Wilson); 0 = Wilson.
      bz:   z-planes per block (VMEM working-set knob); must divide Z.
      by:   y-extent per block; must divide Y (default: full Y).
      batch: RHS-batch placement, "block" or "grid" (see DESIGN.md §13).
      stream: gauge streaming, "blockspec" or "db" (double-buffered).
        When bz/by/batch/stream are ALL None the tuning cache decides
        (:func:`repro.kernels.dispatch.pick_tile`); every choice is
        bitwise-neutral.
      interpret: None = interpret only on CPU; True forces the
        interpreter; False forces compiled execution (Mosaic on GPU/TPU,
        the XLA half-spinor lowering on CPU).
      gamma5_in/gamma5_out: compute γ5out D (γ5in ψ) with γ5 folded into the
        constant hop tables — both True gives D† for free.
    Returns:
      packed D psi (or its γ5-conjugations) with the shape/dtype of ``pp``.
    """
    nd, t, z, y, g, x = up.shape
    assert nd == NDIRS and g == GAUGE_G
    assert pp.ndim in (5, 6), f"spinor rank must be 5 or 6, got {pp.ndim}"
    nb = pp.shape[0] if pp.ndim == 6 else None
    tt, zz, yy, s, xx = pp.shape[-5:]
    assert (tt, zz, yy, xx) == (t, z, y, x) and s == SPINOR_S

    lowering = resolve_lowering(interpret)
    if lowering == "xla":
        from repro.kernels.wilson_dslash import xla as _xla
        return _xla.dslash_xla(up, pp, mass, twist=twist,
                               gamma5_in=gamma5_in, gamma5_out=gamma5_out)

    bz, by, batch, stream, y_tiled, grid_batch = _resolve_tile(
        bz, by, batch, stream, t, z, y, x, nb, pp.dtype)
    stream_db = stream == "db"

    psi_specs = _spinor_specs(t, z, bz, y, by, x, nb, grid_batch)
    gauge_specs = _gauge_specs(t, z, bz, y, by, x, grid_batch)
    if stream_db:
        gauge_specs[0] = pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY)

    kernel = functools.partial(_dslash_kernel, mass=float(mass),
                               twist=float(twist), g5in=bool(gamma5_in),
                               g5out=bool(gamma5_out),
                               batched=nb is not None, y_tiled=y_tiled,
                               stream_db=stream_db, bz_sz=bz)
    n_psi = len(psi_specs)
    return pl.pallas_call(
        kernel,
        grid=_launch_grid(t, z, bz, y, by, nb, y_tiled, grid_batch),
        in_specs=psi_specs + gauge_specs,
        out_specs=psi_specs[0],
        out_shape=jax.ShapeDtypeStruct(pp.shape, pp.dtype),
        scratch_shapes=(_db_scratch(bz, y, x, up.dtype, streams=1)
                        if stream_db else ()),
        interpret=lowering == "interpret",
    )(*([pp] * n_psi), *([up] * len(gauge_specs)))


# ---------------------------------------------------------------------------
# Parity (even-odd) hop kernels on half fields
# ---------------------------------------------------------------------------


def _dslash_parity_kernel(*refs, parity: int, hop_coeff: float,
                          acc_coeff: float, has_acc: bool,
                          hop_twist: float = 0.0, acc_twist: float = 0.0,
                          g5in: bool, g5out: bool, batched: bool = False,
                          y_tiled: bool = False, stream_db: bool = False,
                          bz_sz: int = 0):
    """Half-lattice hopping block: hop_coeff · γ5out Hop(γ5in ψ) [+ acc].

    ``u_oc`` holds the links attached to the OUTPUT-parity sites (forward
    hops use U_mu(x) at the output site x), ``u_nc``/``u_ntm``/``u_nzm``
    (and ``u_nym`` when Y is tiled) the links attached to the neighbour
    parity (backward hops use U_mu(x-mu)† at the neighbour site).
    ``parity`` selects which parity the output sites are: output rows sit
    at x = 2j + s_out with s_out = (t + z + y + parity) mod 2.

    ``batched``: the spinor blocks (center, neighbours, accumulator, out)
    carry a leading RHS-batch axis; the gauge blocks never do — one gauge
    fetch feeds all N half-spinor planes, and every hop below is rank-
    polymorphic (negative-axis rolls/shifts, broadcasting selects).
    """
    psi_ym = psi_yp = u_nym = None
    if stream_db:
        (psi_c, psi_tm, psi_tp, psi_zm, psi_zp, uo_any, un_any,
         u_ntm, u_nzm, *rest) = refs
        out_ref = rest[-5]
        uo_vmem, un_vmem, sem_o, sem_n = rest[-4:]
        rest = rest[:-4]
    elif y_tiled:
        (psi_c, psi_tm, psi_tp, psi_zm, psi_zp, psi_ym, psi_yp,
         u_oc, u_nc, u_ntm, u_nzm, u_nym, *rest) = refs
        out_ref = rest[-1]
    else:
        (psi_c, psi_tm, psi_tp, psi_zm, psi_zp,
         u_oc, u_nc, u_ntm, u_nzm, *rest) = refs
        out_ref = rest[-1]
    acc_ref = rest[0] if has_acc else None

    pc_r, pc_i = _split_spinor_block(_take_plane(psi_c, batched))
    ptm_r, ptm_i = _split_spinor_block(_take_plane(psi_tm, batched))
    ptp_r, ptp_i = _split_spinor_block(_take_plane(psi_tp, batched))
    pzm_r, pzm_i = _split_spinor_block(_take_plane(psi_zm, batched))
    pzp_r, pzp_i = _split_spinor_block(_take_plane(psi_zp, batched))
    if stream_db:
        uov = _db_gauge_plane(uo_any, uo_vmem, sem_o, bz_sz)
        unv = _db_gauge_plane(un_any, un_vmem, sem_n, bz_sz)
        uo = [_split_gauge_block(uov[mu]) for mu in range(NDIRS)]
        un = [_split_gauge_block(unv[mu]) for mu in range(NDIRS)]
    else:
        uo = [_split_gauge_block(u_oc[mu, 0]) for mu in range(NDIRS)]
        un = [_split_gauge_block(u_nc[mu, 0]) for mu in range(NDIRS)]
    untm_r, untm_i = _split_gauge_block(u_ntm[0, 0])
    unzm_r, unzm_i = _split_gauge_block(u_nzm[0, 0])

    nbz, ny = pc_r[0][0].shape[-3:-1]
    # Row parity selector: True where the output site offset s_out == 1, i.e.
    # output sites sit at x = 2j + 1 within the row (see lattice.eo_row_offset).
    # Shape (BZ, BY, 1) broadcasts across both the lane axis and any leading
    # RHS-batch axis.  Global row index = t + (zi·bz + local z) +
    # (yi·by + local y) + parity; the yi·by term appears only when Y is
    # tiled (otherwise yi == 0 and local y IS global y).
    zy = (jax.lax.broadcasted_iota(jnp.int32, (nbz, ny, 1), 0)
          + jax.lax.broadcasted_iota(jnp.int32, (nbz, ny, 1), 1))
    row = pl.program_id(0) + pl.program_id(1) * nbz + zy + parity
    if y_tiled:
        row = row + pl.program_id(2) * ny
    sel = row % 2 == 1

    zero = jnp.zeros(pc_r[0][0].shape, jnp.float32)
    out_r = [[zero for _ in range(NCOL)] for _ in range(NSPIN)]
    out_i = [[zero for _ in range(NCOL)] for _ in range(NSPIN)]

    hop = functools.partial(_hop, g5in=g5in, g5out=g5out)

    # ---- T direction (mu=0): neighbour planes come from extra refs ----
    hop(out_r, out_i, ptp_r, ptp_i, uo[0][0], uo[0][1], 0, "fwd")
    hop(out_r, out_i, ptm_r, ptm_i, untm_r, untm_i, 0, "bwd")

    # ---- Z direction (mu=1): in-block shift + boundary planes ----
    fz_r = _shift(pc_r, pzp_r, forward=True, axis=_Z_AXIS)
    fz_i = _shift(pc_i, pzp_i, forward=True, axis=_Z_AXIS)
    hop(out_r, out_i, fz_r, fz_i, uo[1][0], uo[1][1], 1, "fwd")
    bz_r = _shift(pc_r, pzm_r, forward=False, axis=_Z_AXIS)
    bz_i = _shift(pc_i, pzm_i, forward=False, axis=_Z_AXIS)
    ubz_r = _shift(un[1][0], unzm_r, forward=False, axis=_Z_AXIS)
    ubz_i = _shift(un[1][1], unzm_i, forward=False, axis=_Z_AXIS)
    hop(out_r, out_i, bz_r, bz_i, ubz_r, ubz_i, 1, "bwd")

    # ---- Y direction (mu=2): rolls when the block spans full Y, the
    # Z-style boundary splice when Y is tiled (bitwise identical) ----
    if y_tiled:
        pym_r, pym_i = _split_spinor_block(_take_plane(psi_ym, batched))
        pyp_r, pyp_i = _split_spinor_block(_take_plane(psi_yp, batched))
        unym_r, unym_i = _split_gauge_block(u_nym[0, 0])
        fy_r = _shift(pc_r, pyp_r, forward=True, axis=_Y_AXIS)
        fy_i = _shift(pc_i, pyp_i, forward=True, axis=_Y_AXIS)
        hop(out_r, out_i, fy_r, fy_i, uo[2][0], uo[2][1], 2, "fwd")
        by_r = _shift(pc_r, pym_r, forward=False, axis=_Y_AXIS)
        by_i = _shift(pc_i, pym_i, forward=False, axis=_Y_AXIS)
        uby_r = _shift(un[2][0], unym_r, forward=False, axis=_Y_AXIS)
        uby_i = _shift(un[2][1], unym_i, forward=False, axis=_Y_AXIS)
        hop(out_r, out_i, by_r, by_i, uby_r, uby_i, 2, "bwd")
    else:
        hop(out_r, out_i, _roll_sc(pc_r, -1, _Y_AXIS),
            _roll_sc(pc_i, -1, _Y_AXIS), uo[2][0], uo[2][1], 2, "fwd")
        hop(out_r, out_i, _roll_sc(pc_r, 1, _Y_AXIS),
            _roll_sc(pc_i, 1, _Y_AXIS),
            _roll_sc(un[2][0], 1, _Y_AXIS), _roll_sc(un[2][1], 1, _Y_AXIS),
            2, "bwd")

    # ---- X direction (mu=3): parity-compressed lane axis.  The neighbour
    # of compressed index j is j + s_out (forward) / j - (1 - s_out)
    # (backward): a per-row select between the block and its rolled copy.
    hop(out_r, out_i,
        _where_sc(sel, _roll_sc(pc_r, -1, _X_AXIS), pc_r),
        _where_sc(sel, _roll_sc(pc_i, -1, _X_AXIS), pc_i),
        uo[3][0], uo[3][1], 3, "fwd")
    hop(out_r, out_i,
        _where_sc(sel, pc_r, _roll_sc(pc_r, 1, _X_AXIS)),
        _where_sc(sel, pc_i, _roll_sc(pc_i, 1, _X_AXIS)),
        _where_sc(sel, un[3][0], _roll_sc(un[3][0], 1, _X_AXIS)),
        _where_sc(sel, un[3][1], _roll_sc(un[3][1], 1, _X_AXIS)), 3, "bwd")

    # ---- epilogue: site-term maps on the hop and the accumulator ----
    #   out = (acc_coeff + acc_twist·iγ5)(ψ_acc)
    #       + (hop_coeff + hop_twist·iγ5)(γ5out Hop(γ5in ψ))
    # A zero-twist epilogue (Wilson) takes the historical branch verbatim
    # (the bitwise-identity contract of the operator registry).  A twisted
    # scalar mixes each component's re/im planes with a per-spin-block
    # sign — still pure trace-time constants, zero extra memory traffic.
    h = jnp.float32(hop_coeff)
    if hop_twist == 0.0 and acc_twist == 0.0:
        if has_acc:
            a = jnp.float32(acc_coeff)
            ac_r, ac_i = _split_spinor_block(_take_plane(acc_ref, batched))
            out_r = [[h * out_r[s][c] + a * ac_r[s][c] for c in range(NCOL)]
                     for s in range(NSPIN)]
            out_i = [[h * out_i[s][c] + a * ac_i[s][c] for c in range(NCOL)]
                     for s in range(NSPIN)]
        elif hop_coeff != 1.0:
            out_r = [[h * e for e in row] for row in out_r]
            out_i = [[h * e for e in row] for row in out_i]
    else:
        if has_acc:
            a = jnp.float32(acc_coeff)
            ac_r, ac_i = _split_spinor_block(_take_plane(acc_ref, batched))
        new_r = [[None] * NCOL for _ in range(NSPIN)]
        new_i = [[None] * NCOL for _ in range(NSPIN)]
        for sp in range(NSPIN):
            g = 1.0 if sp < 2 else -1.0  # γ5 sign of this spin block
            for c in range(NCOL):
                nr, ni = h * out_r[sp][c], h * out_i[sp][c]
                if hop_twist != 0.0:
                    hg = jnp.float32(hop_twist * g)
                    nr = nr - hg * out_i[sp][c]
                    ni = ni + hg * out_r[sp][c]
                if has_acc:
                    nr = nr + a * ac_r[sp][c]
                    ni = ni + a * ac_i[sp][c]
                    if acc_twist != 0.0:
                        ag = jnp.float32(acc_twist * g)
                        nr = nr - ag * ac_i[sp][c]
                        ni = ni + ag * ac_r[sp][c]
                new_r[sp][c], new_i[sp][c] = nr, ni
        out_r, out_i = new_r, new_i
    packed = _repack_spinor_block(out_r, out_i, out_ref.dtype)
    if batched:
        out_ref[:, 0] = packed
    else:
        out_ref[0] = packed


def _dslash_parity_pallas(u_out: jax.Array, u_nbr: jax.Array, pp: jax.Array,
                          *, parity: int, bz: int | None,
                          by: int | None = None, batch: str | None = None,
                          stream: str | None = None,
                          interpret: bool | None, gamma5_in: bool,
                          gamma5_out: bool, psi_acc: jax.Array | None,
                          acc_coeff: float, hop_coeff: float,
                          acc_twist: float = 0.0,
                          hop_twist: float = 0.0) -> jax.Array:
    nd, t, z, y, g, x = u_out.shape
    assert nd == NDIRS and g == GAUGE_G
    assert u_nbr.shape == u_out.shape
    assert pp.ndim in (5, 6), f"spinor rank must be 5 or 6, got {pp.ndim}"
    nb = pp.shape[0] if pp.ndim == 6 else None
    tt, zz, yy, s, xx = pp.shape[-5:]
    assert (tt, zz, yy, xx) == (t, z, y, x) and s == SPINOR_S
    assert t % 2 == z % 2 == y % 2 == 0, (
        "even-odd kernels need even T/Z/Y extents: an odd periodic extent "
        f"breaks bipartiteness, got {(t, z, y)}")

    lowering = resolve_lowering(interpret)
    if lowering == "xla":
        from repro.kernels.wilson_dslash import xla as _xla
        return _xla.dslash_parity_xla(
            u_out, u_nbr, pp, parity=int(parity) % 2,
            gamma5_in=gamma5_in, gamma5_out=gamma5_out, psi_acc=psi_acc,
            acc_coeff=acc_coeff, hop_coeff=hop_coeff,
            acc_twist=acc_twist, hop_twist=hop_twist)

    bz, by, batch, stream, y_tiled, grid_batch = _resolve_tile(
        bz, by, batch, stream, t, z, y, x, nb, pp.dtype)
    stream_db = stream == "db"

    psi_specs = _spinor_specs(t, z, bz, y, by, x, nb, grid_batch)
    gauge_specs = _gauge_specs(t, z, bz, y, by, x, grid_batch)
    u_c, u_tm, u_zm = gauge_specs[0], gauge_specs[1], gauge_specs[2]
    if stream_db:
        u_c = pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY)
    in_specs = list(psi_specs) + [u_c, u_c, u_tm, u_zm]
    operands = [*([pp] * len(psi_specs)), u_out, *([u_nbr] * 3)]
    if y_tiled:
        in_specs.append(gauge_specs[3])  # U_y(y-1) boundary, neighbour links
        operands.append(u_nbr)
    if psi_acc is not None:
        assert psi_acc.shape == pp.shape
        in_specs.append(psi_specs[0])
        operands.append(psi_acc)

    kernel = functools.partial(
        _dslash_parity_kernel, parity=int(parity) % 2,
        hop_coeff=float(hop_coeff), acc_coeff=float(acc_coeff),
        hop_twist=float(hop_twist), acc_twist=float(acc_twist),
        has_acc=psi_acc is not None, g5in=bool(gamma5_in),
        g5out=bool(gamma5_out), batched=nb is not None, y_tiled=y_tiled,
        stream_db=stream_db, bz_sz=bz)
    return pl.pallas_call(
        kernel,
        grid=_launch_grid(t, z, bz, y, by, nb, y_tiled, grid_batch),
        in_specs=in_specs,
        out_specs=psi_specs[0],
        out_shape=jax.ShapeDtypeStruct(pp.shape, pp.dtype),
        scratch_shapes=(_db_scratch(bz, y, x, u_out.dtype, streams=2)
                        if stream_db else ()),
        interpret=lowering == "interpret",
    )(*operands)


def dslash_eo_pallas(u_e: jax.Array, u_o: jax.Array, pp_o: jax.Array, *,
                     bz: int | None = None, by: int | None = None,
                     batch: str | None = None, stream: str | None = None,
                     interpret: bool | None = None,
                     gamma5_in: bool = False, gamma5_out: bool = False,
                     psi_acc: jax.Array | None = None,
                     acc_coeff: float = 0.0, hop_coeff: float = 1.0,
                     acc_twist: float = 0.0,
                     hop_twist: float = 0.0) -> jax.Array:
    """D_eo: odd -> even hopping block on packed half fields.

    Args:
      u_e, u_o: (4, T, Z, Y, 18, Xh) packed per-parity link fields
                (``pack_gauge`` of ``split_eo_gauge``'s halves).
      pp_o:     (T, Z, Y, 24, Xh) packed ODD-parity spinor half field, or
        (N, T, Z, Y, 24, Xh) for an N-RHS batch — the batched BlockSpecs
        fetch each gauge plane once per grid step and stream all N spinor
        planes through it (multi-RHS gauge amortization).
      psi_acc/acc_coeff/hop_coeff: optional fused epilogue
        ``out = acc_coeff * psi_acc + hop_coeff * hop`` (psi_acc is an
        EVEN-parity half field, batched iff ``pp_o`` is) — lets the Schur
        complement avoid separate scale/add HBM passes.
      acc_twist/hop_twist: the site-term hook of the operator registry —
        each epilogue scalar generalizes to ``coeff + twist·iγ5``
        (trace-time constants; zero extra passes), which is exactly what
        a site-diagonal ``i·μ·γ5`` term (twisted mass) needs to fold its
        Schur blocks into the same two launches as Wilson.
      bz/by/batch/stream: launch-space knobs (DESIGN.md §13); all None
        consults the tuning cache, every choice is bitwise-neutral.
      gamma5_in/gamma5_out: fold γ5 around the hop (tables only, free).
    Returns:
      packed even-parity half field(s), shape/dtype of ``pp_o``.
    """
    return _dslash_parity_pallas(
        u_e, u_o, pp_o, parity=0, bz=bz, by=by, batch=batch, stream=stream,
        interpret=interpret,
        gamma5_in=gamma5_in, gamma5_out=gamma5_out, psi_acc=psi_acc,
        acc_coeff=acc_coeff, hop_coeff=hop_coeff,
        acc_twist=acc_twist, hop_twist=hop_twist)


def dslash_oe_pallas(u_e: jax.Array, u_o: jax.Array, pp_e: jax.Array, *,
                     bz: int | None = None, by: int | None = None,
                     batch: str | None = None, stream: str | None = None,
                     interpret: bool | None = None,
                     gamma5_in: bool = False, gamma5_out: bool = False,
                     psi_acc: jax.Array | None = None,
                     acc_coeff: float = 0.0, hop_coeff: float = 1.0,
                     acc_twist: float = 0.0,
                     hop_twist: float = 0.0) -> jax.Array:
    """D_oe: even -> odd hopping block on packed half fields (see above)."""
    return _dslash_parity_pallas(
        u_o, u_e, pp_e, parity=1, bz=bz, by=by, batch=batch, stream=stream,
        interpret=interpret,
        gamma5_in=gamma5_in, gamma5_out=gamma5_out, psi_acc=psi_acc,
        acc_coeff=acc_coeff, hop_coeff=hop_coeff,
        acc_twist=acc_twist, hop_twist=hop_twist)
