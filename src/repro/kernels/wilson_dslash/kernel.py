"""Pallas TPU kernel for the Dirac-Wilson stencil (packed layout).

This is the TPU re-think of the paper's FPGA compute kernel (Fig. 1) and
cyclic-buffer transport (its Ref. [11]):

* **grid = (T, Z/BZ)** — the kernel streams (t, z-block) lattice *planes*;
  Pallas's software pipeline double-buffers the next planes' HBM->VMEM DMA
  behind the current plane's compute — the cyclic-buffer / II=1 analogue.
* **neighbour planes as extra BlockSpecs** — ψ(t±1), ψ(z-block boundary)
  and the backward links U_t(t-1), U_z(z-1) arrive through their own
  index-maps (periodic wrap via modular index arithmetic), so the kernel
  body never touches HBM addresses — exactly the paper's separation of
  "transport mechanism" from "stencil evaluation".
* **Y/X hops stay inside the block** — the block spans full Y and X, so
  those neighbours are register/VMEM rolls (X is the 128-lane axis).
* **spin-projection trick** — each hop projects 4-spinors to 2 half
  spinors before the SU(3) multiply (stage 2 of the paper's Fig. 1
  pipeline), halving the matvec work: 8 hops × 2 matvecs = the standard
  1320 flop/site dslash.

The kernel computes in f32 registers regardless of the (bf16/f32) storage
dtype — narrow storage, wide accumulate, like the FPGA DSP datapath.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.lattice import GAUGE_G, NCOL, NDIRS, NSPIN, SPINOR_S
from repro.core.wilson import _projectors

# ---------------------------------------------------------------------------
# Trace-time tables for the spin-projection trick.
#
# For r=1 every hop matrix P = (1 ∓ γ_mu) has rank 2: rows 2,3 are a complex
# phase times row 0 or 1.  We precompute, per (mu, sign):
#   proj[alpha in {0,1}]   -> list of (beta, coeff) with coeff = P[alpha,beta]
#   recon[alpha in {2,3}]  -> (src_halfspinor_row, phase)
# ---------------------------------------------------------------------------


def _halfspinor_tables():
    pm, pp = _projectors(1.0)
    tables = {}
    for mu in range(NDIRS):
        for sign, P in (("fwd", pm[mu]), ("bwd", pp[mu])):
            proj = []
            for a in range(2):
                terms = [(b, complex(P[a, b])) for b in range(NSPIN)
                         if abs(P[a, b]) > 1e-12]
                proj.append(terms)
            recon = []
            for a in (2, 3):
                row = P[a]
                hit = None
                for src in range(2):
                    ref = P[src]
                    nz = np.nonzero(np.abs(ref) > 1e-12)[0]
                    if np.all((np.abs(row) > 1e-12) == (np.abs(ref) > 1e-12)):
                        phase = row[nz[0]] / ref[nz[0]]
                        if np.allclose(row, phase * ref, atol=1e-12):
                            hit = (src, complex(phase))
                            break
                if hit is None:  # zero row (can happen only for r != 1)
                    raise ValueError("projector is not rank-2; need r=1")
                recon.append(hit)
            tables[(mu, sign)] = (proj, recon)
    return tables


_TABLES = _halfspinor_tables()


def _cmul_phase(gr, gi, phase: complex):
    """(gr + i gi) * phase with trace-time constant folding."""
    cr, ci = phase.real, phase.imag
    outr = 0.0
    outi = 0.0
    if cr != 0.0:
        outr = cr * gr
        outi = cr * gi
    if ci != 0.0:
        outr = outr - ci * gi if cr != 0.0 else -ci * gi
        outi = outi + ci * gr if cr != 0.0 else ci * gr
    return outr, outi


def _hop(out_r, out_i, psi_r, psi_i, u_r, u_i, mu: int, sign: str):
    """Accumulate -1/2 * P (U psi) for one hop into out_{r,i}.

    psi_{r,i}: [spin][color] -> (..., X) arrays  (the neighbour spinor)
    u_{r,i}:   [row][col]    -> (..., X) arrays  (U or, for 'bwd', U^dag is
               realized by index transposition + conjugation here)
    """
    proj, recon = _TABLES[(mu, sign)]
    dag = sign == "bwd"
    # stage 2a: project to half spinors  h[alpha][c]
    h_r = [[None] * NCOL for _ in range(2)]
    h_i = [[None] * NCOL for _ in range(2)]
    for a in range(2):
        for c in range(NCOL):
            accr, acci = 0.0, 0.0
            for (b, coeff) in proj[a]:
                tr, ti = _cmul_phase(psi_r[b][c], psi_i[b][c], coeff)
                accr = accr + tr
                acci = acci + ti
            h_r[a][c] = accr
            h_i[a][c] = acci
    # stage 2b: SU(3) multiply g[alpha] = U h[alpha]  (or U^dag h)
    g_r = [[None] * NCOL for _ in range(2)]
    g_i = [[None] * NCOL for _ in range(2)]
    for a in range(2):
        for row in range(NCOL):
            accr, acci = 0.0, 0.0
            for col in range(NCOL):
                if not dag:
                    ur, ui = u_r[row][col], u_i[row][col]
                else:  # (U^dag)[row,col] = conj(U[col,row])
                    ur, ui = u_r[col][row], -u_i[col][row]
                hr, hi = h_r[a][col], h_i[a][col]
                accr = accr + ur * hr - ui * hi
                acci = acci + ur * hi + ui * hr
            g_r[a][row] = accr
            g_i[a][row] = acci
    # stage 3: reconstruct 4-spinor rows and accumulate with -1/2
    for c in range(NCOL):
        for a in range(2):
            out_r[a][c] = out_r[a][c] - 0.5 * g_r[a][c]
            out_i[a][c] = out_i[a][c] - 0.5 * g_i[a][c]
        for idx, a in enumerate((2, 3)):
            src, phase = recon[idx]
            pr, pi = _cmul_phase(g_r[src][c], g_i[src][c], phase)
            out_r[a][c] = out_r[a][c] - 0.5 * pr
            out_i[a][c] = out_i[a][c] - 0.5 * pi


def _split_spinor_block(blk):
    """(BZ, Y, S=24, X) -> [spin][color] re/im lists of (BZ, Y, X) f32."""
    bz, y, s, x = blk.shape
    q = blk.reshape(bz, y, NSPIN, NCOL, 2, x).astype(jnp.float32)
    re = [[q[:, :, s_, c_, 0, :] for c_ in range(NCOL)] for s_ in range(NSPIN)]
    im = [[q[:, :, s_, c_, 1, :] for c_ in range(NCOL)] for s_ in range(NSPIN)]
    return re, im


def _split_gauge_block(blk):
    """(BZ, Y, G=18, X) -> [row][col] re/im lists of (BZ, Y, X) f32."""
    bz, y, g, x = blk.shape
    q = blk.reshape(bz, y, NCOL, NCOL, 2, x).astype(jnp.float32)
    re = [[q[:, :, a, b, 0, :] for b in range(NCOL)] for a in range(NCOL)]
    im = [[q[:, :, a, b, 1, :] for b in range(NCOL)] for a in range(NCOL)]
    return re, im


def _roll_sc(lists, shift, axis):
    return [[jnp.roll(e, shift, axis=axis) for e in row] for row in lists]


def _shift_z(lists, boundary, forward: bool):
    """Shift [..][..] lists of (BZ,Y,X) along BZ, splicing the boundary
    plane (1,Y,X) in at the open end."""
    out = []
    for r, row in enumerate(lists):
        orow = []
        for c, e in enumerate(row):
            b = boundary[r][c]
            if forward:  # value at z+1: drop plane 0, append boundary
                orow.append(jnp.concatenate([e[1:], b], axis=0))
            else:        # value at z-1: prepend boundary, drop last
                orow.append(jnp.concatenate([b, e[:-1]], axis=0))
        out.append(orow)
    return out


def _dslash_kernel(psi_c, psi_tm, psi_tp, psi_zm, psi_zp,
                   u_c, u_tm, u_zm, out_ref, *, mass: float, bz: int):
    f32 = jnp.float32
    # ---- stage 1: load & unpack (all data now in VMEM) ----
    pc_r, pc_i = _split_spinor_block(psi_c[0])
    ptm_r, ptm_i = _split_spinor_block(psi_tm[0])
    ptp_r, ptp_i = _split_spinor_block(psi_tp[0])
    pzm_r, pzm_i = _split_spinor_block(psi_zm[0])
    pzp_r, pzp_i = _split_spinor_block(psi_zp[0])
    u = [_split_gauge_block(u_c[mu, 0]) for mu in range(NDIRS)]
    utm_r, utm_i = _split_gauge_block(u_tm[0, 0])
    uzm_r, uzm_i = _split_gauge_block(u_zm[0, 0])

    m4 = f32(mass + 4.0)
    out_r = [[m4 * pc_r[s][c] for c in range(NCOL)] for s in range(NSPIN)]
    out_i = [[m4 * pc_i[s][c] for c in range(NCOL)] for s in range(NSPIN)]

    # ---- T direction (mu=0): neighbour planes come from extra refs ----
    _hop(out_r, out_i, ptp_r, ptp_i, u[0][0], u[0][1], 0, "fwd")
    _hop(out_r, out_i, ptm_r, ptm_i, utm_r, utm_i, 0, "bwd")

    # ---- Z direction (mu=1): in-block shift + boundary planes ----
    fz_r = _shift_z(pc_r, pzp_r, forward=True)
    fz_i = _shift_z(pc_i, pzp_i, forward=True)
    _hop(out_r, out_i, fz_r, fz_i, u[1][0], u[1][1], 1, "fwd")
    bz_r = _shift_z(pc_r, pzm_r, forward=False)
    bz_i = _shift_z(pc_i, pzm_i, forward=False)
    ubz_r = _shift_z(u[1][0], uzm_r, forward=False)
    ubz_i = _shift_z(u[1][1], uzm_i, forward=False)
    _hop(out_r, out_i, bz_r, bz_i, ubz_r, ubz_i, 1, "bwd")

    # ---- Y direction (mu=2): rolls on axis 1 of (BZ, Y, X) ----
    _hop(out_r, out_i, _roll_sc(pc_r, -1, 1), _roll_sc(pc_i, -1, 1),
         u[2][0], u[2][1], 2, "fwd")
    _hop(out_r, out_i, _roll_sc(pc_r, 1, 1), _roll_sc(pc_i, 1, 1),
         _roll_sc(u[2][0], 1, 1), _roll_sc(u[2][1], 1, 1), 2, "bwd")

    # ---- X direction (mu=3): lane rolls on axis 2 ----
    _hop(out_r, out_i, _roll_sc(pc_r, -1, 2), _roll_sc(pc_i, -1, 2),
         u[3][0], u[3][1], 3, "fwd")
    _hop(out_r, out_i, _roll_sc(pc_r, 1, 2), _roll_sc(pc_i, 1, 2),
         _roll_sc(u[3][0], 1, 2), _roll_sc(u[3][1], 1, 2), 3, "bwd")

    # ---- stage 4: repack & store ----
    y, x = out_r[0][0].shape[1], out_r[0][0].shape[2]
    flat = []
    for s in range(NSPIN):
        for c in range(NCOL):
            flat.append(out_r[s][c])
            flat.append(out_i[s][c])
    res = jnp.stack(flat, axis=2)  # (BZ, Y, 24, X)
    out_ref[0] = res.astype(out_ref.dtype)


def dslash_pallas(up: jax.Array, pp: jax.Array, mass: float, *,
                  bz: int | None = None, interpret: bool = True) -> jax.Array:
    """Dirac-Wilson dslash via the Pallas plane-streaming kernel.

    Args:
      up:   (4, T, Z, Y, 18, X) packed gauge field.
      pp:   (T, Z, Y, 24, X) packed spinor field.
      mass: bare mass (trace-time constant, like the paper's #define).
      bz:   z-planes per block (VMEM working-set knob). Default: min(Z, 4).
      interpret: run the kernel body in interpret mode (CPU validation).
    Returns:
      packed D psi with the dtype of ``pp``.
    """
    nd, t, z, y, g, x = up.shape
    assert nd == NDIRS and g == GAUGE_G
    tt, zz, yy, s, xx = pp.shape
    assert (tt, zz, yy, xx) == (t, z, y, x) and s == SPINOR_S
    if bz is None:  # largest divisor of Z not exceeding 4
        bz = max(c for c in (1, 2, 3, 4) if z % c == 0)
    assert z % bz == 0, f"Z={z} must be divisible by bz={bz}"
    nzb = z // bz

    S, G, Y, X = SPINOR_S, GAUGE_G, y, x

    psi_spec = pl.BlockSpec((1, bz, Y, S, X),
                            lambda ti, zi: (ti, zi, 0, 0, 0))
    psi_tm = pl.BlockSpec((1, bz, Y, S, X),
                          lambda ti, zi: ((ti - 1 + t) % t, zi, 0, 0, 0))
    psi_tp = pl.BlockSpec((1, bz, Y, S, X),
                          lambda ti, zi: ((ti + 1) % t, zi, 0, 0, 0))
    # single boundary z-planes (block size 1 on z -> block index = plane idx)
    psi_zm = pl.BlockSpec((1, 1, Y, S, X),
                          lambda ti, zi: (ti, (zi * bz - 1 + z) % z, 0, 0, 0))
    psi_zp = pl.BlockSpec((1, 1, Y, S, X),
                          lambda ti, zi: (ti, (zi * bz + bz) % z, 0, 0, 0))
    u_c = pl.BlockSpec((NDIRS, 1, bz, Y, G, X),
                       lambda ti, zi: (0, ti, zi, 0, 0, 0))
    u_tm = pl.BlockSpec((1, 1, bz, Y, G, X),
                        lambda ti, zi: (0, (ti - 1 + t) % t, zi, 0, 0, 0))
    u_zm = pl.BlockSpec((1, 1, 1, Y, G, X),
                        lambda ti, zi: (1, ti, (zi * bz - 1 + z) % z, 0, 0, 0))
    out_spec = pl.BlockSpec((1, bz, Y, S, X),
                            lambda ti, zi: (ti, zi, 0, 0, 0))

    kernel = functools.partial(_dslash_kernel, mass=float(mass), bz=bz)
    return pl.pallas_call(
        kernel,
        grid=(t, nzb),
        in_specs=[psi_spec, psi_tm, psi_tp, psi_zm, psi_zp, u_c, u_tm, u_zm],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct(pp.shape, pp.dtype),
        interpret=interpret,
    )(pp, pp, pp, pp, pp, up, up, up)
