"""Compiled-XLA lowering of the half-spinor dslash algorithm.

``pallas_call`` cannot compile on the CPU backend ("Only interpret mode
is supported on CPU backend"), so ``interpret=False`` on CPU routes here
(:func:`repro.kernels.dispatch.resolve_lowering`): a whole-field jnp
implementation of the SAME spin-projection algorithm as the Pallas
kernels — project 4-spinors to 2 half spinors (trace-time tables from
:mod:`.kernel`), one batched complex 3x3 einsum per hop with f32
accumulation, reconstruct, γ5 folded into the constant tables.  This is
the honest *compiled* number for this host: measured 1.8–2x the naive
jnp reference (the einsum form; a scalar-FMA transcription of the kernel
body is SLOWER than the reference under XLA-CPU, 0.6–0.9x).

Numerics: same f32 compute precision and the same per-hop -1/2
accumulation as the kernels, but XLA is free to reorder the einsum
reduction — results agree with the interpret-mode kernels and the
reference to f32 roundoff (≤1e-5 relative), NOT bitwise.  Bitwise
contracts (goldens, tile-neutrality) are stated for the Pallas
lowerings only; this path is accuracy-gated in tests instead.

Layout contract is identical to the kernels: packed site fields
(..., T, Z, Y, 24, X) with X innermost, packed gauge (4, T, Z, Y, 18, X);
the parity entry point works on half fields whose X axis is
parity-compressed by 2 and supports the full fused-epilogue surface
(psi_acc/acc_coeff/hop_coeff/acc_twist/hop_twist), so `schur_normal_op`
lowers to 4 calls of this function with zero extra full-field passes —
the launch accounting matches the Pallas path one-for-one.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.lattice import GAUGE_G, NCOL, NDIRS, NSPIN, SPINOR_S
from repro.kernels.wilson_dslash.kernel import _TABLES

# Whole-field axes (from the right): site fields unpack to
# (..., T, Z, Y, spin=4, color=3, X); gauge unpacks to
# (4, T, Z, Y, row=3, col=3, X) — the T/Z/Y/X offsets coincide.
_T_AX, _Z_AX, _Y_AX, _X_AX = -6, -5, -4, -1
_AXIS = {0: _T_AX, 1: _Z_AX, 2: _Y_AX, 3: _X_AX}


def _split_spinor(pp):
    """(..., 24, X) packed -> re/im (..., 4, 3, X) f32."""
    x = pp.shape[-1]
    q = pp.reshape(pp.shape[:-2] + (NSPIN, NCOL, 2, x)).astype(jnp.float32)
    return q[..., 0, :], q[..., 1, :]


def _split_gauge(up):
    """(4, ..., 18, X) packed -> re/im (4, ..., 3, 3, X) f32."""
    x = up.shape[-1]
    q = up.reshape(up.shape[:-2] + (NCOL, NCOL, 2, x)).astype(jnp.float32)
    return q[..., 0, :], q[..., 1, :]


def _repack(out_r, out_i, shape, dtype):
    out = jnp.stack([out_r, out_i], axis=-2)
    return out.reshape(shape).astype(dtype)


def _tables(mu: int, sign: str, g5in: bool, g5out: bool):
    """The kernel's halfspinor tables with γ5 folded in (same sign flips
    as ``kernel._hop``)."""
    proj, recon = _TABLES[(mu, sign)]
    if g5in:
        proj = [[(b, -c if b >= 2 else c) for (b, c) in terms]
                for terms in proj]
    if g5out:
        recon = [(src, -ph) for (src, ph) in recon]
    return proj, recon


def _hop_half(ur, ui, pr, pi, mu: int, sign: str, g5in: bool, g5out: bool):
    """One hop on whole fields: -1/2 · recon(U·proj(ψ)) (U† for 'bwd').

    ur/ui: (..., T, Z, Y, 3, 3, X) gauge re/im at the SOURCE of the
    parallel transport (already rolled by the caller for backward hops);
    pr/pi: (..., T, Z, Y, 4, 3, X) neighbour-spinor re/im (already
    rolled).  Returns the (..., T, Z, Y, 4, 3, X) re/im contribution.
    """
    proj, recon = _tables(mu, sign, g5in, g5out)
    # stage 1: project to half spinors, stacked as (..., 2, 3, X)
    hs_r, hs_i = [], []
    for a in range(2):
        accr, acci = None, None
        for (b, coeff) in proj[a]:
            cr, ci = coeff.real, coeff.imag
            tr = cr * pr[..., b, :, :] - ci * pi[..., b, :, :]
            ti = cr * pi[..., b, :, :] + ci * pr[..., b, :, :]
            accr = tr if accr is None else accr + tr
            acci = ti if acci is None else acci + ti
        hs_r.append(accr)
        hs_i.append(acci)
    hr = jnp.stack(hs_r, axis=-3)
    hi = jnp.stack(hs_i, axis=-3)
    # stage 2: SU(3) multiply, one complex einsum per hop.  'bwd' applies
    # U† = conj(U)ᵀ via the transposed subscript + conjugation signs.
    sub = ("tzyabx,...tzyhbx->...tzyhax" if sign == "fwd"
           else "tzybax,...tzyhbx->...tzyhax")
    e = lambda u, h: jnp.einsum(sub, u, h,
                                preferred_element_type=jnp.float32)
    if sign == "fwd":
        gr = e(ur, hr) - e(ui, hi)
        gi = e(ur, hi) + e(ui, hr)
    else:
        gr = e(ur, hr) + e(ui, hi)
        gi = e(ur, hi) - e(ui, hr)
    # stage 3: reconstruct rows 2,3 from the half spinors by a phase
    rows_r = [gr[..., 0, :, :], gr[..., 1, :, :]]
    rows_i = [gi[..., 0, :, :], gi[..., 1, :, :]]
    for idx in range(2):
        src, phase = recon[idx]
        cr, ci = phase.real, phase.imag
        rr = cr * gr[..., src, :, :] - ci * gi[..., src, :, :]
        ri = cr * gi[..., src, :, :] + ci * gr[..., src, :, :]
        rows_r.append(rr)
        rows_i.append(ri)
    out_r = jnp.stack(rows_r, axis=-3)
    out_i = jnp.stack(rows_i, axis=-3)
    return -0.5 * out_r, -0.5 * out_i


def dslash_xla(up: jax.Array, pp: jax.Array, mass: float, *,
               twist: float = 0.0, gamma5_in: bool = False,
               gamma5_out: bool = False) -> jax.Array:
    """Full-lattice γ5out D (γ5in ψ): mass/twist site term + 8 hops.

    Same signature semantics as ``dslash_pallas`` minus the launch-space
    knobs (tiling is XLA's problem here); accepts the optional leading
    RHS-batch axis.
    """
    nd, t, z, y, g, x = up.shape
    assert nd == NDIRS and g == GAUGE_G
    assert pp.shape[-5:] == (t, z, y, SPINOR_S, x)
    pr, pi = _split_spinor(pp)
    ur, ui = _split_gauge(up)

    m4 = float(mass) + 4.0
    m4_lo = -m4 if (gamma5_in != gamma5_out) else m4
    scale = jnp.asarray([m4, m4, m4_lo, m4_lo], jnp.float32
                        ).reshape(NSPIN, 1, 1)
    out_r = scale * pr
    out_i = scale * pi
    if twist != 0.0:
        tw = [float(twist)] * 2 + (
            [-float(twist)] * 2 if gamma5_in == gamma5_out
            else [float(twist)] * 2)
        twv = jnp.asarray(tw, jnp.float32).reshape(NSPIN, 1, 1)
        out_r = out_r - twv * pi
        out_i = out_i + twv * pr

    for mu in range(NDIRS):
        ax = _AXIS[mu]
        fr, fi = _hop_half(ur[mu], ui[mu],
                           jnp.roll(pr, -1, ax), jnp.roll(pi, -1, ax),
                           mu, "fwd", gamma5_in, gamma5_out)
        br, bi = _hop_half(jnp.roll(ur[mu], 1, ax), jnp.roll(ui[mu], 1, ax),
                           jnp.roll(pr, 1, ax), jnp.roll(pi, 1, ax),
                           mu, "bwd", gamma5_in, gamma5_out)
        out_r = out_r + fr + br
        out_i = out_i + fi + bi
    return _repack(out_r, out_i, pp.shape, pp.dtype)


def dslash_parity_xla(u_out: jax.Array, u_nbr: jax.Array, pp: jax.Array, *,
                      parity: int, gamma5_in: bool = False,
                      gamma5_out: bool = False,
                      psi_acc: jax.Array | None = None,
                      acc_coeff: float = 0.0, hop_coeff: float = 1.0,
                      acc_twist: float = 0.0,
                      hop_twist: float = 0.0) -> jax.Array:
    """Parity hop block on half fields with the full fused epilogue.

    Mirrors ``_dslash_parity_kernel`` on whole half fields: T/Z/Y
    neighbours are rolls, the parity-compressed X neighbour is a per-row
    select between the field and its lane-rolled copy, with the row's
    output-site offset s_out = (t + z + y + parity) mod 2.
    """
    nd, t, z, y, g, x = u_out.shape
    assert nd == NDIRS and g == GAUGE_G and u_nbr.shape == u_out.shape
    assert pp.shape[-5:] == (t, z, y, SPINOR_S, x)
    pr, pi = _split_spinor(pp)
    uor, uoi = _split_gauge(u_out)
    unr, uni = _split_gauge(u_nbr)

    it = jax.lax.broadcasted_iota(jnp.int32, (t, z, y), 0)
    iz = jax.lax.broadcasted_iota(jnp.int32, (t, z, y), 1)
    iy = jax.lax.broadcasted_iota(jnp.int32, (t, z, y), 2)
    # (t, z, y, 1, 1, 1) broadcasts against both the spinor arrays
    # (..., t, z, y, 4, 3, x) and the rank-6 gauge arrays (t, z, y, 3, 3, x)
    sel = ((it + iz + iy + int(parity)) % 2 == 1).reshape(t, z, y, 1, 1, 1)

    hop_r = jnp.zeros_like(pr)
    hop_i = jnp.zeros_like(pi)
    for mu in range(3):  # T, Z, Y: plain rolls on half fields
        ax = _AXIS[mu]
        fr, fi = _hop_half(uor[mu], uoi[mu],
                           jnp.roll(pr, -1, ax), jnp.roll(pi, -1, ax),
                           mu, "fwd", gamma5_in, gamma5_out)
        br, bi = _hop_half(jnp.roll(unr[mu], 1, ax), jnp.roll(uni[mu], 1, ax),
                           jnp.roll(pr, 1, ax), jnp.roll(pi, 1, ax),
                           mu, "bwd", gamma5_in, gamma5_out)
        hop_r = hop_r + fr + br
        hop_i = hop_i + fi + bi
    # X: compressed-lane neighbour j + s_out (fwd) / j - (1 - s_out) (bwd)
    fr, fi = _hop_half(uor[3], uoi[3],
                       jnp.where(sel, jnp.roll(pr, -1, _X_AX), pr),
                       jnp.where(sel, jnp.roll(pi, -1, _X_AX), pi),
                       3, "fwd", gamma5_in, gamma5_out)
    br, bi = _hop_half(jnp.where(sel, unr[3], jnp.roll(unr[3], 1, _X_AX)),
                       jnp.where(sel, uni[3], jnp.roll(uni[3], 1, _X_AX)),
                       jnp.where(sel, pr, jnp.roll(pr, 1, _X_AX)),
                       jnp.where(sel, pi, jnp.roll(pi, 1, _X_AX)),
                       3, "bwd", gamma5_in, gamma5_out)
    hop_r = hop_r + fr + br
    hop_i = hop_i + fi + bi

    # epilogue: out = (acc_coeff + acc_twist·iγ5) ψ_acc
    #               + (hop_coeff + hop_twist·iγ5) hop
    g5 = jnp.asarray([1.0, 1.0, -1.0, -1.0], jnp.float32).reshape(NSPIN, 1, 1)
    h = jnp.float32(hop_coeff)
    out_r = h * hop_r
    out_i = h * hop_i
    if hop_twist != 0.0:
        hg = jnp.float32(hop_twist) * g5
        out_r = out_r - hg * hop_i
        out_i = out_i + hg * hop_r
    if psi_acc is not None:
        assert psi_acc.shape == pp.shape
        ar, ai = _split_spinor(psi_acc)
        a = jnp.float32(acc_coeff)
        out_r = out_r + a * ar
        out_i = out_i + a * ai
        if acc_twist != 0.0:
            ag = jnp.float32(acc_twist) * g5
            out_r = out_r - ag * ai
            out_i = out_i + ag * ar
    return _repack(out_r, out_i, pp.shape, pp.dtype)
