"""Jitted public entry points for the wilson_dslash Pallas kernels.

Full lattice:
``dslash(up, pp, mass)`` — D psi
``dslash_dagger(...)``   — D^dag psi  (gamma5 D gamma5, γ5 FOLDED into the
                           kernel tables — zero extra full-field passes)
``normal_op(...)``       — D^dag D psi (the CGNR operator; two kernel
                           launches, no standalone gamma5 application)

Even-odd half lattice (parity-compressed X axis, see repro.core.lattice):
``dslash_eo``/``dslash_oe`` — the parity-changing hopping blocks
``schur_op``                — D_hat = m psi - D_eo D_oe psi / m, with the
                              axpy folded into the second kernel's epilogue
``schur_dagger``            — D_hat^dag via the folded γ5 flags
``schur_normal_op``         — D_hat^dag D_hat (four kernel launches total)

Every entry point is **multi-RHS batched**: pass a spinor with a leading
RHS-batch axis (N, T, Z, Y, 24, X[h]) and the same operator applies to all
N right-hand sides in the SAME kernel launches — the gauge field is read
once per grid step and amortized across the batch, so ``schur_normal_op``
stays exactly 4 launches (and ``normal_op`` exactly 2) independent of N.

``use_pallas=False`` falls back to the pure-jnp reference — the same
escape hatch the paper's package offers ("compiled and executed exclusively
on CPU for debugging and reference benchmarking").  ``interpret=None``
(default) interprets the kernels only on CPU; GPU/TPU runs compile.
"""

from __future__ import annotations

import functools

import jax

from repro.core.operators import apply_igamma5_packed, schur_launch_coeffs
from repro.core.wilson import apply_gamma5_packed, dslash_packed
from repro.kernels.wilson_dslash.kernel import (dslash_eo_pallas,
                                                dslash_oe_pallas,
                                                dslash_pallas)
from repro.kernels.wilson_dslash.ref import (dslash_eo_ref, dslash_oe_ref,
                                             schur_normal_op_ref,
                                             schur_op_ref)

_STATIC = ("mass", "twist", "bz", "interpret", "use_pallas")
_STATIC_G5 = _STATIC + ("gamma5_in", "gamma5_out")


@functools.partial(jax.jit, static_argnames=_STATIC_G5)
def dslash(up: jax.Array, pp: jax.Array, mass: float, *,
           twist: float = 0.0, bz: int | None = None,
           interpret: bool | None = None, use_pallas: bool = True,
           gamma5_in: bool = False, gamma5_out: bool = False) -> jax.Array:
    """D psi on packed fields; ``pp`` may carry a leading RHS-batch axis.

    ``twist`` is the operator registry's site-term twist: the applied
    operator is ``D_wilson + i·twist·γ5`` (0 = Wilson, bitwise the
    historical path).
    """
    if not use_pallas:
        out = apply_gamma5_packed(pp) if gamma5_in else pp
        if twist == 0.0:
            ref = lambda q: dslash_packed(up, q, mass)
        else:
            ref = lambda q: (dslash_packed(up, q, mass)
                             + twist * apply_igamma5_packed(q)
                             ).astype(q.dtype)
        out = jax.vmap(ref)(out) if pp.ndim == 6 else ref(out)
        return apply_gamma5_packed(out) if gamma5_out else out
    return dslash_pallas(up, pp, mass, bz=bz, interpret=interpret,
                         twist=twist, gamma5_in=gamma5_in,
                         gamma5_out=gamma5_out)


@functools.partial(jax.jit, static_argnames=_STATIC)
def dslash_dagger(up: jax.Array, pp: jax.Array, mass: float, *,
                  twist: float = 0.0, bz: int | None = None,
                  interpret: bool | None = None,
                  use_pallas: bool = True) -> jax.Array:
    """D^dag = gamma5 D(-twist) gamma5, folded into the kernel tables."""
    return dslash(up, pp, mass, twist=-twist, bz=bz, interpret=interpret,
                  use_pallas=use_pallas, gamma5_in=True, gamma5_out=True)


@functools.partial(jax.jit, static_argnames=_STATIC)
def normal_op(up: jax.Array, pp: jax.Array, mass: float, *,
              twist: float = 0.0, bz: int | None = None,
              interpret: bool | None = None,
              use_pallas: bool = True) -> jax.Array:
    """A = D^dag D in exactly two kernel launches: D, then γ5 D(-twist) γ5
    with both γ5 factors folded — no standalone ``apply_gamma5_packed``
    pass for any operator family."""
    dv = dslash(up, pp, mass, twist=twist, bz=bz, interpret=interpret,
                use_pallas=use_pallas)
    return dslash(up, dv, mass, twist=-twist, bz=bz, interpret=interpret,
                  use_pallas=use_pallas, gamma5_in=True, gamma5_out=True)


# ---------------------------------------------------------------------------
# Parity (even-odd) blocks and the Schur complement
# ---------------------------------------------------------------------------

_STATIC_EO = ("bz", "interpret", "use_pallas", "gamma5_in", "gamma5_out")


@functools.partial(jax.jit, static_argnames=_STATIC_EO)
def dslash_eo(u_e: jax.Array, u_o: jax.Array, pp_o: jax.Array, *,
              bz: int | None = None, interpret: bool | None = None,
              use_pallas: bool = True, gamma5_in: bool = False,
              gamma5_out: bool = False) -> jax.Array:
    """D_eo: ODD half field in, EVEN half field out (hopping term only).

    ``u_e``/``u_o`` are packed per-parity link fields (4, T, Z, Y, 18, Xh);
    ``pp_o`` is a packed (T, Z, Y, 24, Xh) odd-parity spinor half field or
    an (N, T, Z, Y, 24, Xh) RHS batch (gauge amortized across the batch).
    """
    if not use_pallas:
        return dslash_eo_ref(u_e, u_o, pp_o, gamma5_in=gamma5_in,
                             gamma5_out=gamma5_out)
    return dslash_eo_pallas(u_e, u_o, pp_o, bz=bz, interpret=interpret,
                            gamma5_in=gamma5_in, gamma5_out=gamma5_out)


@functools.partial(jax.jit, static_argnames=_STATIC_EO)
def dslash_oe(u_e: jax.Array, u_o: jax.Array, pp_e: jax.Array, *,
              bz: int | None = None, interpret: bool | None = None,
              use_pallas: bool = True, gamma5_in: bool = False,
              gamma5_out: bool = False) -> jax.Array:
    """D_oe: EVEN half field in, ODD half field out (hopping term only)."""
    if not use_pallas:
        return dslash_oe_ref(u_e, u_o, pp_e, gamma5_in=gamma5_in,
                             gamma5_out=gamma5_out)
    return dslash_oe_pallas(u_e, u_o, pp_e, bz=bz, interpret=interpret,
                            gamma5_in=gamma5_in, gamma5_out=gamma5_out)


_STATIC_HOP = ("which", "bz", "interpret", "use_pallas", "gamma5_in",
               "gamma5_out", "acc_coeff", "hop_coeff", "acc_twist",
               "hop_twist")


@functools.partial(jax.jit, static_argnames=_STATIC_HOP)
def hop_block(u_e: jax.Array, u_o: jax.Array, pp: jax.Array, *,
              which: str, gamma5_in: bool = False, gamma5_out: bool = False,
              psi_acc: jax.Array | None = None, acc_coeff: float = 0.0,
              hop_coeff: float = 1.0, acc_twist: float = 0.0,
              hop_twist: float = 0.0, bz: int | None = None,
              interpret: bool | None = None,
              use_pallas: bool = True) -> jax.Array:
    """One parity hop block with the full fused-epilogue surface exposed:

        out = (acc_coeff + acc_twist·iγ5) psi_acc
            + (hop_coeff + hop_twist·iγ5) γ5out Hop_which(γ5in ψ)

    This is the shard_map-compatible LOCAL building block of the
    distributed even-odd fast path (:mod:`repro.core.distributed`): called
    on a per-device shard it evaluates the bulk stencil with local periodic
    wrap, and the halo layer corrects only the boundary planes.  ``which``
    is ``"eo"`` (odd in, even out) or ``"oe"`` (even in, odd out); ``pp``
    may carry a leading RHS-batch axis.  The twist terms are the operator
    registry's site-term hook (twisted-mass Schur blocks; 0 for Wilson).
    The ``use_pallas=False`` reference composes the same epilogue out of
    the round-trip oracle blocks.
    """
    if which not in ("eo", "oe"):  # must survive `python -O`
        raise ValueError(f"hop_block: which must be 'eo' or 'oe', "
                         f"got {which!r}")
    if not use_pallas:
        ref = dslash_eo_ref if which == "eo" else dslash_oe_ref
        hop = ref(u_e, u_o, pp, gamma5_in=gamma5_in, gamma5_out=gamma5_out)
        out = hop if hop_coeff == 1.0 else hop_coeff * hop
        if hop_twist != 0.0:
            out = out + hop_twist * apply_igamma5_packed(hop)
        if psi_acc is not None:
            acc = acc_coeff * psi_acc
            if acc_twist != 0.0:
                acc = acc + acc_twist * apply_igamma5_packed(psi_acc)
            out = acc + out
        return out.astype(pp.dtype)
    kern = dslash_eo_pallas if which == "eo" else dslash_oe_pallas
    return kern(u_e, u_o, pp, bz=bz, interpret=interpret,
                gamma5_in=gamma5_in, gamma5_out=gamma5_out,
                psi_acc=psi_acc, acc_coeff=acc_coeff, hop_coeff=hop_coeff,
                acc_twist=acc_twist, hop_twist=hop_twist)


_STATIC_SCHUR = ("mass", "twist", "bz", "interpret", "use_pallas", "dagger")


@functools.partial(jax.jit, static_argnames=_STATIC_SCHUR)
def schur_op(u_e: jax.Array, u_o: jax.Array, pp_e: jax.Array, mass: float, *,
             twist: float = 0.0, bz: int | None = None,
             interpret: bool | None = None, use_pallas: bool = True,
             dagger: bool = False) -> jax.Array:
    """Schur complement D_hat psi = S psi - D_eo S^-1 D_oe psi, where S is
    the registry site term ``(mass+4) + i·twist·γ5`` (Wilson: twist = 0).

    Two kernel launches for EVERY operator family: D_oe streams the even
    field to a temporary odd field with ``S^-1`` folded into its epilogue
    (for Wilson the scalar commutes and rides the second launch's
    ``hop_coeff`` — bitwise the historical path), then D_eo's fused
    epilogue computes ``S psi - hop`` in one pass via
    ``acc_coeff``/``acc_twist`` — no separate scale/add/γ5 HBM traffic.
    ``dagger=True`` gives D_hat(twist)^dag = γ5 D_hat(-twist) γ5 by
    folding γ5 into the first kernel's prologue and the second kernel's
    hop epilogue and flipping the twist signs (S commutes with γ5).
    """
    if not use_pallas:
        return schur_op_ref(u_e, u_o, pp_e, mass, twist=twist,
                            dagger=dagger)
    m = float(mass) + 4.0
    if twist == 0.0:
        tmp_o = dslash_oe_pallas(u_e, u_o, pp_e, bz=bz, interpret=interpret,
                                 gamma5_in=dagger)
        return dslash_eo_pallas(u_e, u_o, tmp_o, bz=bz, interpret=interpret,
                                gamma5_out=dagger, psi_acc=pp_e,
                                acc_coeff=m, hop_coeff=-1.0 / m)
    # twisted site term: the two-launch split's sign algebra lives in
    # repro.core.operators.schur_launch_coeffs (shared with the sharded
    # halo path) — S(∓tw)^-1 folded into launch 1's hop epilogue,
    # S(±tw) into launch 2's accumulator.
    h1c, h1t, acc, acct = schur_launch_coeffs(m, twist, dagger)
    tmp_o = dslash_oe_pallas(u_e, u_o, pp_e, bz=bz, interpret=interpret,
                             gamma5_in=dagger, hop_coeff=h1c,
                             hop_twist=h1t)
    return dslash_eo_pallas(u_e, u_o, tmp_o, bz=bz, interpret=interpret,
                            gamma5_out=dagger, psi_acc=pp_e, acc_coeff=acc,
                            acc_twist=acct, hop_coeff=-1.0)


@functools.partial(jax.jit, static_argnames=_STATIC)
def schur_dagger(u_e: jax.Array, u_o: jax.Array, pp_e: jax.Array,
                 mass: float, *, twist: float = 0.0, bz: int | None = None,
                 interpret: bool | None = None,
                 use_pallas: bool = True) -> jax.Array:
    """D_hat^dag = gamma5 D_hat(-twist) gamma5, folded into the kernels."""
    return schur_op(u_e, u_o, pp_e, mass, twist=twist, bz=bz,
                    interpret=interpret, use_pallas=use_pallas, dagger=True)


@functools.partial(jax.jit, static_argnames=_STATIC)
def schur_normal_op(u_e: jax.Array, u_o: jax.Array, pp_e: jax.Array,
                    mass: float, *, twist: float = 0.0,
                    bz: int | None = None, interpret: bool | None = None,
                    use_pallas: bool = True) -> jax.Array:
    """A_hat = D_hat^dag D_hat — the even-sublattice CGNR operator.

    Four parity-kernel launches total for EVERY registered operator
    family; every γ5, every site-term axpy and every twist is folded into
    a kernel prologue/epilogue, so the whole HPD matvec touches HBM
    exactly as often as its four hopping stencils demand.
    """
    if not use_pallas:
        return schur_normal_op_ref(u_e, u_o, pp_e, mass, twist=twist)
    w = schur_op(u_e, u_o, pp_e, mass, twist=twist, bz=bz,
                 interpret=interpret)
    return schur_op(u_e, u_o, w, mass, twist=twist, bz=bz,
                    interpret=interpret, dagger=True)
