"""Jitted public entry points for the wilson_dslash Pallas kernel.

``dslash(up, pp, mass)`` — D psi
``dslash_dagger(...)``   — D^dag psi  (gamma5 D gamma5, reusing the kernel)
``normal_op(...)``       — D^dag D psi (the CGNR operator)

``use_pallas=False`` falls back to the pure-jnp reference — the same
escape hatch the paper's package offers ("compiled and executed exclusively
on CPU for debugging and reference benchmarking").
"""

from __future__ import annotations

import functools

import jax

from repro.core.wilson import apply_gamma5_packed, dslash_packed
from repro.kernels.wilson_dslash.kernel import dslash_pallas


@functools.partial(jax.jit,
                   static_argnames=("mass", "bz", "interpret", "use_pallas"))
def dslash(up: jax.Array, pp: jax.Array, mass: float, *,
           bz: int | None = None, interpret: bool = True,
           use_pallas: bool = True) -> jax.Array:
    if not use_pallas:
        return dslash_packed(up, pp, mass)
    return dslash_pallas(up, pp, mass, bz=bz, interpret=interpret)


@functools.partial(jax.jit,
                   static_argnames=("mass", "bz", "interpret", "use_pallas"))
def dslash_dagger(up: jax.Array, pp: jax.Array, mass: float, *,
                  bz: int | None = None, interpret: bool = True,
                  use_pallas: bool = True) -> jax.Array:
    out = dslash(up, apply_gamma5_packed(pp), mass, bz=bz,
                 interpret=interpret, use_pallas=use_pallas)
    return apply_gamma5_packed(out)


@functools.partial(jax.jit,
                   static_argnames=("mass", "bz", "interpret", "use_pallas"))
def normal_op(up: jax.Array, pp: jax.Array, mass: float, *,
              bz: int | None = None, interpret: bool = True,
              use_pallas: bool = True) -> jax.Array:
    return dslash_dagger(up, dslash(up, pp, mass, bz=bz, interpret=interpret,
                                    use_pallas=use_pallas),
                         mass, bz=bz, interpret=interpret,
                         use_pallas=use_pallas)
