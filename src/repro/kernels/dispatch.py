"""Backend-aware kernel dispatch: lowering resolution + the tuning cache.

Two concerns live here, shared by every Pallas kernel package:

**Lowering resolution.**  ``interpret=None`` (the default everywhere)
resolves to interpret mode only when JAX is running on CPU — the
validation/debug platform — and to compiled Mosaic kernels on GPU/TPU.
Passing an explicit bool always wins, so tests can force interpret mode
and device runs can force compilation.  ``resolve_lowering`` refines the
same tri-state into the THREE real lowerings:

* ``"interpret"`` — the Pallas interpreter (bitwise reference; slow).
* ``"mosaic"``    — native Pallas compilation (GPU/TPU).
* ``"xla"``       — a compiled-XLA implementation of the same half-spinor
  algorithm (:mod:`repro.kernels.wilson_dslash.xla`).  This is what
  ``interpret=False`` means on CPU, where ``pallas_call`` cannot compile
  ("Only interpret mode is supported on CPU backend"): the honest
  compiled-backend number for this host, labeled as such in benchmarks.

**Tile selection (the tuning cache).**  The dslash launch space — z-block
``bz``, y-block ``by``, RHS-batch placement, gauge streaming mode — is
swept offline by :mod:`repro.kernels.autotune`, and the winner per
``(backend, lattice_shape, nrhs, dtype)`` is checked in at
``kernels/tuning_cache.json``.  Kernel wrappers call :func:`pick_tile`
at trace time; a cache miss (or ``REPRO_TUNING_CACHE=0``) falls back to
the deterministic heuristic defaults, so golden/jaxpr tests stay bitwise
with the cache cold or disabled.  All tile choices are bitwise-neutral
by construction (they change data movement, never per-site FMA order) —
the cache can only change *speed*, not results.

Environment overrides (read at trace time):

* ``REPRO_TUNING_CACHE=0``      — disable cache lookups entirely.
* ``REPRO_TUNING_CACHE_PATH``   — read this JSON instead of the default.
* ``REPRO_DSLASH_TILE``         — force a tile, e.g. ``bz=2,by=4,
  batch=grid,stream=db`` (keys may be omitted; beats the cache).
"""

from __future__ import annotations

import dataclasses
import functools
import json
import os

import jax

DEFAULT_CACHE_PATH = os.path.join(os.path.dirname(__file__),
                                  "tuning_cache.json")

_BATCH_PLACEMENTS = ("block", "grid")
_GAUGE_STREAMS = ("blockspec", "db")


def resolve_interpret(interpret: bool | None) -> bool:
    """Resolve the tri-state ``interpret`` flag against the active backend."""
    if interpret is None:
        return jax.default_backend() == "cpu"
    return bool(interpret)


def resolve_lowering(interpret: bool | None) -> str:
    """Map the tri-state ``interpret`` flag to a lowering name.

    ``None``  -> "interpret" on CPU, "mosaic" on GPU/TPU (the historical
    default behaviour of :func:`resolve_interpret`).
    ``True``  -> "interpret" everywhere.
    ``False`` -> compiled execution: "mosaic" where Pallas can compile,
    "xla" on CPU where it cannot.
    """
    if interpret is None:
        return "interpret" if jax.default_backend() == "cpu" else "mosaic"
    if interpret:
        return "interpret"
    return "xla" if jax.default_backend() == "cpu" else "mosaic"


def device_kind() -> str:
    """Human-readable device model of the default backend ("cpu",
    "TPU v4", "NVIDIA H100", ...) — the per-entry benchmark label."""
    return jax.devices()[0].device_kind


@dataclasses.dataclass(frozen=True)
class TileConfig:
    """One point in the dslash launch space (see DESIGN.md §13).

    ``bz``/``by``: z/y planes per block (None = heuristic default:
    largest divisor of Z ≤ 4 for bz, full Y for by).  ``batch``: where
    the RHS-batch axis rides — "block" pins the whole batch inside every
    block (one gauge fetch feeds N spinor planes); "grid" makes it the
    trailing (fastest-varying) grid dimension, so consecutive steps
    revisit the same gauge block with a smaller VMEM footprint.
    ``stream``: "blockspec" uses the implicit Pallas pipeline for the
    gauge operands; "db" double-buffers the center gauge planes through
    an explicit 2-slot VMEM scratch with async copies (DESIGN.md §13).

    Every field is bitwise-neutral: per-site FMA order never depends on
    the tile, only HBM->VMEM data movement does.
    """
    bz: int | None = None
    by: int | None = None
    batch: str = "block"
    stream: str = "blockspec"

    def __post_init__(self):
        if self.batch not in _BATCH_PLACEMENTS:
            raise ValueError(
                f"batch placement must be one of {_BATCH_PLACEMENTS}, "
                f"got {self.batch!r}")
        if self.stream not in _GAUGE_STREAMS:
            raise ValueError(
                f"gauge stream must be one of {_GAUGE_STREAMS}, "
                f"got {self.stream!r}")

    def to_entry(self) -> dict:
        return {"bz": self.bz, "by": self.by, "batch": self.batch,
                "stream": self.stream}


DEFAULT_TILE = TileConfig()


def cache_key(backend: str, lattice_shape: tuple[int, ...], nrhs: int,
              dtype) -> str:
    """Tuning-cache key: ``backend|TxZxYxX|nrhsN|dtype``.

    ``lattice_shape`` is the (T, Z, Y, X) extent of the field the kernel
    actually sees — parity kernels key on the compressed X, so full- and
    half-lattice launches tune independently.
    """
    dims = "x".join(str(int(d)) for d in lattice_shape)
    return f"{backend}|{dims}|nrhs{int(nrhs)}|{jax.numpy.dtype(dtype).name}"


def parse_tile(spec: str) -> TileConfig:
    """Parse ``"bz=2,by=4,batch=grid,stream=db"`` (any subset of keys)."""
    kw: dict = {}
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        key, _, val = part.partition("=")
        key, val = key.strip(), val.strip()
        if key in ("bz", "by"):
            kw[key] = None if val in ("", "none", "None") else int(val)
        elif key in ("batch", "stream"):
            kw[key] = val
        else:
            raise ValueError(
                f"unknown tile key {key!r} in REPRO_DSLASH_TILE={spec!r}; "
                "legal keys: bz, by, batch, stream")
    return TileConfig(**kw)


@functools.lru_cache(maxsize=None)
def _load_cache(path: str, mtime: float) -> dict:
    with open(path) as f:
        doc = json.load(f)
    return doc.get("entries", {})


def load_tuning_cache(path: str | None = None) -> dict:
    """Entries of the tuning-cache JSON ({} when absent/disabled)."""
    if os.environ.get("REPRO_TUNING_CACHE", "1") in ("0", "off"):
        return {}
    path = path or os.environ.get("REPRO_TUNING_CACHE_PATH",
                                  DEFAULT_CACHE_PATH)
    try:
        mtime = os.path.getmtime(path)
    except OSError:
        return {}
    return _load_cache(path, mtime)


def pick_tile(lattice_shape: tuple[int, ...], nrhs: int, dtype,
              backend: str | None = None) -> TileConfig:
    """Tile selection at trace time: env override > cache hit > defaults.

    Deterministic on a cold/disabled cache (returns :data:`DEFAULT_TILE`,
    i.e. the historical heuristics), so tests and goldens never depend on
    which cache file happens to be checked out.
    """
    forced = os.environ.get("REPRO_DSLASH_TILE")
    if forced:
        return parse_tile(forced)
    backend = backend or jax.default_backend()
    entry = load_tuning_cache().get(
        cache_key(backend, lattice_shape, nrhs, dtype))
    if entry is None:
        return DEFAULT_TILE
    return TileConfig(bz=entry.get("bz"), by=entry.get("by"),
                      batch=entry.get("batch", "block"),
                      stream=entry.get("stream", "blockspec"))


def save_tuning_cache(entries: dict, path: str | None = None,
                      meta: dict | None = None) -> str:
    """Write a tuning-cache JSON (autotune.py's persistence hook)."""
    path = path or os.environ.get("REPRO_TUNING_CACHE_PATH",
                                  DEFAULT_CACHE_PATH)
    doc = {"schema": 1,
           "comment": "dslash launch-space winners per (backend, lattice, "
                      "nrhs, dtype); regenerate with python -m "
                      "repro.kernels.autotune",
           "entries": dict(sorted(entries.items()))}
    if meta:
        doc["meta"] = meta
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    _load_cache.cache_clear()
    return path
