"""Backend-aware kernel dispatch knobs shared by all Pallas kernel packages.

``interpret=None`` (the default everywhere) resolves to interpret mode only
when JAX is running on CPU — the validation/debug platform — and to compiled
Mosaic kernels on GPU/TPU.  Passing an explicit bool always wins, so tests
can force interpret mode and device runs can force compilation.
"""

from __future__ import annotations

import jax


def resolve_interpret(interpret: bool | None) -> bool:
    """Resolve the tri-state ``interpret`` flag against the active backend."""
    if interpret is None:
        return jax.default_backend() == "cpu"
    return bool(interpret)
