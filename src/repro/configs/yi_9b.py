"""yi-9b [dense] — 48L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000,
llama-arch GQA.  [arXiv:2403.04652; hf]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-9b", family="dense",
    num_layers=48, d_model=4096, num_heads=32, num_kv_heads=4,
    d_ff=11008, vocab_size=64000, head_dim=128,
    mlp="swiglu", rope_theta=10_000.0, tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="yi-9b-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=96, vocab_size=512, head_dim=16,
    mlp="swiglu", tie_embeddings=False,
)
