"""Architecture registry: one module per assigned architecture (exact
public-literature configs) plus the paper's own lattice workloads.

``get(name)`` returns the full-size ModelConfig; ``get_smoke(name)`` a
reduced same-family config for CPU smoke tests.
"""

from __future__ import annotations

import dataclasses
import importlib

ARCHS = [
    "glm4_9b", "yi_9b", "gemma_7b", "nemotron_4_340b",
    "qwen3_moe_235b_a22b", "qwen2_moe_a2_7b",
    "recurrentgemma_9b", "rwkv6_1_6b", "pixtral_12b",
    "seamless_m4t_large_v2",
]

# canonical ids as assigned (dashes) -> module names
CANON = {a.replace("_", "-"): a for a in ARCHS}
CANON.update({
    "glm4-9b": "glm4_9b", "yi-9b": "yi_9b", "gemma-7b": "gemma_7b",
    "nemotron-4-340b": "nemotron_4_340b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "rwkv6-1.6b": "rwkv6_1_6b", "pixtral-12b": "pixtral_12b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
})


def _module(name: str):
    mod = CANON.get(name, name.replace("-", "_").replace(".", "_"))
    return importlib.import_module(f"repro.configs.{mod}")


def get(name: str):
    return _module(name).CONFIG


def get_smoke(name: str):
    return _module(name).SMOKE


ASSIGNED_IDS = [
    "glm4-9b", "yi-9b", "gemma-7b", "nemotron-4-340b",
    "qwen3-moe-235b-a22b", "qwen2-moe-a2.7b", "recurrentgemma-9b",
    "rwkv6-1.6b", "pixtral-12b", "seamless-m4t-large-v2",
]


def all_arch_names() -> list[str]:
    return list(ASSIGNED_IDS)
