"""seamless-m4t-large-v2 [audio] — enc-dec, 24L+24L d_model=1024 16H
(MHA kv=16) d_ff=8192 vocab=256206.  [arXiv:2308.11596; hf]

Backbone only: the speech frontend is a STUB — ``input_specs()`` supplies
pre-computed frame embeddings (B, Se, d).  Decode shapes decode the text
decoder (self-attn KV cache of seq_len) with a 4096-frame cross-attention
cache (speech encoders emit ~6 frames/s; 4096 frames covers the inputs).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="audio",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=8192, vocab_size=256206, head_dim=64,
    mlp="swiglu", rope_theta=10_000.0, tie_embeddings=True,
    encoder_layers=24, encoder_seq_len=4096,
)

SMOKE = ModelConfig(
    name="seamless-smoke", family="audio",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=512, head_dim=16,
    mlp="swiglu", tie_embeddings=True,
    encoder_layers=2, encoder_seq_len=32,
)
