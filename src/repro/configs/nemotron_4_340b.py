"""nemotron-4-340b [dense] — 96L d_model=18432 96H (GQA kv=8) d_ff=73728
vocab=256000, squared-ReLU (non-gated).  [arXiv:2402.16819; unverified]

Memory note (DESIGN.md §5): at 340B params the AdamW m/v moments are kept
in bf16 (the paper's two-precision discipline applied to optimizer state)
so master+moments fit the 16 GB/chip HBM budget on a single pod.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b", family="dense",
    num_layers=96, d_model=18432, num_heads=96, num_kv_heads=8,
    d_ff=73728, vocab_size=256000, head_dim=192,
    mlp="squared_relu", rope_theta=10_000.0, tie_embeddings=False,
    opt_state_dtype="bfloat16",
)

SMOKE = ModelConfig(
    name="nemotron-4-340b-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=8, num_kv_heads=2,
    d_ff=256, vocab_size=512, head_dim=8,
    mlp="squared_relu", tie_embeddings=False,
    opt_state_dtype="bfloat16",
)
