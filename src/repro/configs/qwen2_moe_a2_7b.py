"""qwen2-moe-a2.7b [moe] — 24L d_model=2048 16H (MHA kv=16) expert
d_ff=1408, vocab=151936, 60 routed experts top-4 + shared expert
(d_ff=5632).  [hf:Qwen/Qwen1.5-MoE-A2.7B; hf]

Experts are padded 60 -> 64 so the expert dimension divides the 16-wide
``model`` mesh axis; pads are masked out of routing (moe.py).
"""

from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe",
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1408, vocab_size=151936, head_dim=128,
    mlp="swiglu", rope_theta=1_000_000.0, tie_embeddings=False,
    moe=MoEConfig(num_experts=60, top_k=4, d_expert=1408,
                  shared_d_ff=5632, num_experts_padded=64),
)

SMOKE = ModelConfig(
    name="qwen2-moe-smoke", family="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=64, vocab_size=512, head_dim=16,
    mlp="swiglu", tie_embeddings=False,
    moe=MoEConfig(num_experts=6, top_k=2, d_expert=48, shared_d_ff=96,
                  num_experts_padded=8),
)
