"""rwkv6-1.6b "Finch" [ssm] — 24L d_model=2048 (attention-free) d_ff=7168
vocab=65536, data-dependent decay.  [arXiv:2404.05892; unverified]

Sub-quadratic: runs the long_500k decode shape (O(1) per-head state).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b", family="ssm",
    num_layers=24, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=7168, vocab_size=65536, head_dim=64, rwkv_head_dim=64,
    mlp="swiglu", tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="rwkv6-smoke", family="ssm",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=512, head_dim=16, rwkv_head_dim=16,
    mlp="swiglu", tie_embeddings=False,
)
