"""pixtral-12b [vlm] — 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072; pixtral-ViT frontend + mistral-nemo backbone.
[hf:mistralai/Pixtral-12B-2409; unverified]

Backbone only, per the assignment: the vision tower is a STUB —
``input_specs()`` supplies 1024 pre-computed patch embeddings (B, 1024, d)
prepended to the token sequence.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b", family="vlm",
    num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=131072, head_dim=128,
    mlp="swiglu", rope_theta=1_000_000.0, tie_embeddings=False,
    num_prefix_embeds=1024,
)

SMOKE = ModelConfig(
    name="pixtral-smoke", family="vlm",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=512, head_dim=16,
    mlp="swiglu", tie_embeddings=False,
    num_prefix_embeds=8,
)
