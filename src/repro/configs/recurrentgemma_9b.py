"""recurrentgemma-9b [hybrid] — 38L d_model=4096 16H (MQA kv=1) d_ff=12288
vocab=256000, RG-LRU + local attention, pattern (rec, rec, attn),
window 2048.  [arXiv:2402.19427; unverified]

Sub-quadratic: runs the long_500k decode shape (O(1) recurrent state +
2048-slot ring-buffer KV).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    num_layers=38, d_model=4096, num_heads=16, num_kv_heads=1,
    d_ff=12288, vocab_size=256000, head_dim=256,
    mlp="geglu", rope_theta=10_000.0, tie_embeddings=True,
    block_pattern=("rec", "rec", "attn"), window=2048,
    lru_width=4096, conv_width=4,
)

SMOKE = ModelConfig(
    name="recurrentgemma-smoke", family="hybrid",
    num_layers=5, d_model=64, num_heads=4, num_kv_heads=1,
    d_ff=128, vocab_size=512, head_dim=16,
    mlp="geglu", tie_embeddings=True,
    block_pattern=("rec", "rec", "attn"), window=16,
    lru_width=64, conv_width=4,
)
