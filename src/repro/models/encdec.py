"""Encoder-decoder transformer backbone (seamless-m4t-large-v2).

Per the assignment the speech frontend is a STUB: the encoder consumes
pre-computed frame embeddings (B, Se, d) supplied via ``input_specs()``.
The decoder is a standard causal transformer with per-layer cross
attention over the encoder output; decode shapes carry a decoder
self-attention KV cache plus a prefill-computed cross-attention cache.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import blocks as B
from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.parallel.sharding import constrain_batch

F32 = jnp.float32


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _enc_layer_init(key, cfg, dtype):
    ks = jax.random.split(key, 2)
    return {"ln1": jnp.zeros((cfg.d_model,), F32),
            "ln2": jnp.zeros((cfg.d_model,), F32),
            "attn": B.attn_init(ks[0], cfg, dtype),
            "mlp": L.mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp, dtype)}


def _dec_layer_init(key, cfg, dtype):
    ks = jax.random.split(key, 3)
    return {"ln1": jnp.zeros((cfg.d_model,), F32),
            "lnx": jnp.zeros((cfg.d_model,), F32),
            "ln2": jnp.zeros((cfg.d_model,), F32),
            "attn": B.attn_init(ks[0], cfg, dtype),
            "xattn": B.cross_attn_init(ks[1], cfg, dtype),
            "mlp": L.mlp_init(ks[2], cfg.d_model, cfg.d_ff, cfg.mlp, dtype)}


def init_params(cfg: ModelConfig, key, dtype=jnp.float32) -> dict:
    ke, kenc, kdec = jax.random.split(key, 3)
    enc_keys = jax.random.split(kenc, cfg.encoder_layers)
    dec_keys = jax.random.split(kdec, cfg.num_layers)
    return {
        "embed": L.embed_init(ke, cfg.vocab_size, cfg.d_model, dtype,
                              cfg.tie_embeddings,
                              padded_vocab=cfg.padded_vocab),
        "enc_norm": jnp.zeros((cfg.d_model,), F32),
        "final_norm": jnp.zeros((cfg.d_model,), F32),
        "encoder": jax.vmap(lambda k: _enc_layer_init(k, cfg, dtype))(enc_keys),
        "decoder": jax.vmap(lambda k: _dec_layer_init(k, cfg, dtype))(dec_keys),
    }


# ---------------------------------------------------------------------------
# Encoder
# ---------------------------------------------------------------------------

def encode(cfg: ModelConfig, params, frames: jax.Array,
           compute_dtype=jnp.float32) -> jax.Array:
    """frames: (B, Se, d) stub frontend embeddings -> encoder output."""
    h = frames.astype(compute_dtype)

    def body(h, lp):
        a, _ = B.attn_apply(lp["attn"], L.rms_norm(h, lp["ln1"]), cfg,
                            pos0=0, window=0, cache=None, causal=False)
        h = h + a
        h = h + L.mlp_apply(lp["mlp"], L.rms_norm(h, lp["ln2"]), cfg.mlp)
        return constrain_batch(h), None

    if cfg.remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    from repro.models.scan_ctl import maybe_scan
    h, _ = maybe_scan(body, h, params["encoder"])
    return L.rms_norm(h, params["enc_norm"])


# ---------------------------------------------------------------------------
# Decoder
# ---------------------------------------------------------------------------

def _dec_stack(cfg, params, h, *, pos0, enc_out, self_caches, cross_caches,
               update_cache: bool):
    def body(h, xs):
        lp, sc, cc = xs
        a, nsc = B.attn_apply(lp["attn"], L.rms_norm(h, lp["ln1"]), cfg,
                              pos0=pos0, window=0, cache=sc,
                              update_cache=update_cache)
        h = h + a
        x, ncc = B.cross_attn_apply(lp["xattn"], L.rms_norm(h, lp["lnx"]),
                                    enc_out, cfg, cache=cc,
                                    update_cache=update_cache)
        h = h + x
        h = h + L.mlp_apply(lp["mlp"], L.rms_norm(h, lp["ln2"]), cfg.mlp)
        ys = (nsc, ncc) if update_cache else None
        return constrain_batch(h), ys

    if cfg.remat and not update_cache:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    from repro.models.scan_ctl import maybe_scan
    h, ys = maybe_scan(body, h, (params["decoder"], self_caches,
                                 cross_caches))
    return h, ys


def forward(cfg: ModelConfig, params, tokens, *, frames,
            compute_dtype=jnp.float32):
    """Training: encoder over frames, causal decoder over tokens."""
    enc_out = encode(cfg, params, frames, compute_dtype)
    h = L.embed_lookup(params["embed"], tokens, compute_dtype)
    h, _ = _dec_stack(cfg, params, h, pos0=0, enc_out=enc_out,
                      self_caches=None, cross_caches=None,
                      update_cache=False)
    h = L.rms_norm(h, params["final_norm"])
    return L.logits_out(params["embed"], h, cfg.vocab_size), {"load_balance_loss":
                                              jnp.zeros((), F32)}


def prefill(cfg: ModelConfig, params, tokens, *, frames, cache_len: int,
            compute_dtype=jnp.float32):
    """Encode + run the decoder prompt; returns (logits, caches) where
    caches = (self_kv, cross_kv) stacked over decoder layers."""
    b, s = tokens.shape
    enc_out = encode(cfg, params, frames, compute_dtype)
    self_c = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (cfg.num_layers,) + x.shape),
        B.make_kv_cache(cfg, b, cache_len, compute_dtype))
    # cross cache is produced by the layer itself; seed with zeros
    se = frames.shape[1]
    zero_x = {"k": jnp.zeros((b, se, cfg.num_kv_heads, cfg.head_dim),
                             compute_dtype),
              "v": jnp.zeros((b, se, cfg.num_kv_heads, cfg.head_dim),
                             compute_dtype)}
    cross_c = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (cfg.num_layers,) + x.shape), zero_x)
    # recompute cross k/v from enc_out inside the stack (cache=None path)
    h = L.embed_lookup(params["embed"], tokens, compute_dtype)

    def body(h, xs):
        lp, sc = xs
        a, nsc = B.attn_apply(lp["attn"], L.rms_norm(h, lp["ln1"]), cfg,
                              pos0=0, window=0, cache=sc, update_cache=True)
        h = h + a
        x, ncc = B.cross_attn_apply(lp["xattn"], L.rms_norm(h, lp["lnx"]),
                                    enc_out, cfg, cache=None,
                                    update_cache=True)
        h = h + x
        h = h + L.mlp_apply(lp["mlp"], L.rms_norm(h, lp["ln2"]), cfg.mlp)
        return constrain_batch(h), (nsc, ncc)

    from repro.models.scan_ctl import maybe_scan
    h, (self_c, cross_c) = maybe_scan(body, h, (params["decoder"], self_c))
    h = L.rms_norm(h[:, -1:], params["final_norm"])
    return L.logits_out(params["embed"], h, cfg.vocab_size), (self_c, cross_c)


def init_caches(cfg: ModelConfig, batch: int, cache_len: int,
                enc_len: int, dtype=jnp.float32):
    """(self_kv, cross_kv) cache skeletons for decode input_specs."""
    stack = lambda x: jnp.broadcast_to(x, (cfg.num_layers,) + x.shape)
    self_c = jax.tree.map(stack, B.make_kv_cache(cfg, batch, cache_len,
                                                 dtype))
    kv = {"k": jnp.zeros((batch, enc_len, cfg.num_kv_heads, cfg.head_dim),
                         dtype),
          "v": jnp.zeros((batch, enc_len, cfg.num_kv_heads, cfg.head_dim),
                         dtype)}
    cross_c = jax.tree.map(stack, kv)
    return self_c, cross_c


def decode_step(cfg: ModelConfig, params, tokens, pos, caches, *,
                compute_dtype=jnp.float32):
    self_c, cross_c = caches
    h = L.embed_lookup(params["embed"], tokens, compute_dtype)
    h, (self_c, cross_c) = _dec_stack(
        cfg, params, h, pos0=pos, enc_out=None,
        self_caches=self_c, cross_caches=cross_c, update_cache=True)
    h = L.rms_norm(h, params["final_norm"])
    return L.logits_out(params["embed"], h, cfg.vocab_size), (self_c, cross_c)
