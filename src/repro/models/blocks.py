"""Attention blocks with KV caches (full, sliding-window ring buffer) and
cross-attention for the encoder-decoder family.

Caches are plain dicts of arrays so they pytree-flatten naturally and get
ShapeDtypeStruct stand-ins in the dry-run.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models.config import ModelConfig


# ---------------------------------------------------------------------------
# Self-attention block (GQA + RoPE; optional sliding window)
# ---------------------------------------------------------------------------

def attn_init(key, cfg: ModelConfig, dtype) -> dict:
    d, a = cfg.d_model, cfg.attn_dim
    kv = cfg.num_kv_heads * cfg.head_dim
    ks = jax.random.split(key, 4)
    s = 0.02
    return {
        "wq": jax.random.normal(ks[0], (d, a), dtype) * s,
        "wk": jax.random.normal(ks[1], (d, kv), dtype) * s,
        "wv": jax.random.normal(ks[2], (d, kv), dtype) * s,
        "wo": jax.random.normal(ks[3], (a, d), dtype) * (s / np.sqrt(2)),
    }


def make_kv_cache(cfg: ModelConfig, batch: int, length: int, dtype,
                  ring: bool = False) -> dict:
    """Empty per-layer KV cache. ``ring=True`` -> sliding-window buffer of
    size cfg.window with explicit position slots."""
    if ring:
        length = min(length, cfg.window)
    shape = (batch, length, cfg.num_kv_heads, cfg.head_dim)
    cache = {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    if ring:
        cache["pos"] = jnp.full((length,), -1, jnp.int32)
    return cache


def attn_apply(p: dict, x: jax.Array, cfg: ModelConfig, *,
               pos0: jax.Array | int = 0,
               window: int = 0,
               cache: dict | None = None,
               update_cache: bool = False,
               causal: bool = True):
    """Self-attention.

    Train/prefill: x is (B, S, d), pos0 the absolute position of x[:,0].
    Decode: x is (B, 1, d) and ``cache`` holds past K/V; the new K/V is
    written at ``pos0`` (or ring slot pos0 % window).
    Returns (out, new_cache_or_None).
    """
    b, s, d = x.shape
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = L.dot(x, p["wq"], "bsd,da->bsa").reshape(b, s, hq, hd)
    k = L.dot(x, p["wk"], "bsd,da->bsa").reshape(b, s, hkv, hd)
    v = L.dot(x, p["wv"], "bsd,da->bsa").reshape(b, s, hkv, hd)

    q_pos = pos0 + jnp.arange(s, dtype=jnp.int32)
    q = L.rope(q, q_pos[None, :], cfg.rope_theta)
    k = L.rope(k, q_pos[None, :], cfg.rope_theta)

    new_cache = None
    if cache is None:
        kk, vv, kv_pos = k, v, q_pos
    else:
        ring = "pos" in cache
        if ring:
            w = cache["k"].shape[1]
            if s == 1:        # decode: write one slot, attend over the ring
                slot = pos0 % w
                kk = jax.lax.dynamic_update_slice(cache["k"], k,
                                                  (0, slot, 0, 0))
                vv = jax.lax.dynamic_update_slice(cache["v"], v,
                                                  (0, slot, 0, 0))
                kv_pos = jax.lax.dynamic_update_slice(cache["pos"], q_pos,
                                                      (slot,))
                new_cache = {"k": kk, "v": vv, "pos": kv_pos}
            else:
                # prefill: attend over the fresh sequence (each query sees
                # its own window); the cache keeps the trailing w tokens at
                # their canonical ring slots pos % w
                if s >= w:
                    tk, tv, tp = k[:, -w:], v[:, -w:], q_pos[-w:]
                else:
                    tk, tv, tp = k, v, q_pos
                slots = tp % w
                ck = cache["k"].at[:, slots].set(tk)
                cv = cache["v"].at[:, slots].set(tv)
                cp = cache["pos"].at[slots].set(tp)
                new_cache = {"k": ck, "v": cv, "pos": cp}
                kk, vv, kv_pos = k, v, q_pos
        else:
            kk = jax.lax.dynamic_update_slice(cache["k"], k, (0, pos0, 0, 0))
            vv = jax.lax.dynamic_update_slice(cache["v"], v, (0, pos0, 0, 0))
            kv_pos = jnp.arange(kk.shape[1], dtype=jnp.int32)
            new_cache = {"k": kk, "v": vv}
        if not update_cache:
            new_cache = None

    out = L.attention(q, kk, vv, q_pos=q_pos, kv_pos=kv_pos,
                      causal=causal, window=window)
    out = L.dot(out.reshape(b, s, hq * hd), p["wo"], "bsa,ad->bsd")
    return out, new_cache


# ---------------------------------------------------------------------------
# Cross-attention (encoder-decoder)
# ---------------------------------------------------------------------------

def cross_attn_init(key, cfg: ModelConfig, dtype) -> dict:
    return attn_init(key, cfg, dtype)


def cross_attn_apply(p: dict, x: jax.Array, enc: jax.Array | None,
                     cfg: ModelConfig, *,
                     cache: dict | None = None, update_cache: bool = False):
    """Cross-attention over encoder output ``enc`` (B, Se, d).  At decode
    time pass the prefill-computed ``cache`` instead of ``enc``."""
    b, s, d = x.shape
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = L.dot(x, p["wq"], "bsd,da->bsa").reshape(b, s, hq, hd)
    if cache is None:
        se = enc.shape[1]
        k = L.dot(enc, p["wk"], "bsd,da->bsa").reshape(b, se, hkv, hd)
        v = L.dot(enc, p["wv"], "bsd,da->bsa").reshape(b, se, hkv, hd)
        new_cache = {"k": k, "v": v} if update_cache else None
    else:
        k, v = cache["k"], cache["v"]
        new_cache = cache if update_cache else None
    kv_pos = jnp.arange(k.shape[1], dtype=jnp.int32)
    q_pos = jnp.zeros((s,), jnp.int32)  # non-causal: positions unused
    out = L.attention(q, k, v, q_pos=q_pos, kv_pos=kv_pos, causal=False)
    out = L.dot(out.reshape(b, s, hq * hd), p["wo"], "bsa,ad->bsd")
    return out, new_cache
