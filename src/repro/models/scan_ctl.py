"""Scan control: a context that turns ``lax.scan`` into a Python loop.

XLA's HloCostAnalysis counts a while-loop body ONCE regardless of trip
count, so FLOP/byte numbers for scanned layer stacks (and chunked
attention/recurrence scans) understate the true cost.  The dry-run's cost
pass lowers reduced-depth configs inside ``unrolled_scans()`` — every
scan in the model becomes straight-line HLO with exact counts — and
extrapolates linearly to full depth (EXPERIMENTS.md §Conventions).
Production lowering keeps real scans (compact HLO, fast compiles).
"""

from __future__ import annotations

import contextlib

import jax

_STATE = {"unroll": False}


@contextlib.contextmanager
def unrolled_scans():
    prev = _STATE["unroll"]
    _STATE["unroll"] = True
    try:
        yield
    finally:
        _STATE["unroll"] = prev


def scans_unrolled() -> bool:
    return _STATE["unroll"]


def maybe_scan(f, init, xs, length: int | None = None):
    """lax.scan, or an unrolled Python loop inside ``unrolled_scans()``."""
    if not _STATE["unroll"]:
        return jax.lax.scan(f, init, xs, length=length)
    if xs is None:
        n = length
        get = lambda i: None
    else:
        leaves = jax.tree.leaves(xs)
        n = leaves[0].shape[0] if leaves else length
        get = lambda i: jax.tree.map(lambda a: a[i], xs)
    carry = init
    ys = []
    for i in range(n):
        carry, y = f(carry, get(i))
        ys.append(y)
    if not ys:
        return carry, None
    if jax.tree.structure(ys[0]).num_leaves == 0:  # e.g. all-None ys
        return carry, ys[0]
    return carry, jax.tree.map(lambda *a: jax.numpy.stack(a), *ys)
