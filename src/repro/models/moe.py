"""Mixture-of-Experts layer: token-choice top-k routing with capacity,
scatter/gather dispatch (no O(N·E·C) one-hot tensors), optional shared
expert (qwen2-moe style).

Experts are sharded over the ``model`` mesh axis (expert parallelism);
``num_experts_padded`` rounds the expert count up so it divides the axis
(e.g. qwen2's 60 -> 64; pads are masked out of routing).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.parallel import sharding as shd
from jax.sharding import NamedSharding, PartitionSpec as P

F32 = jnp.float32


def constrain_experts(buf):
    """(G, E, cap, d) expert buffers: capacity groups over (pod, data),
    experts over `model` (expert parallelism).  The reshard from
    token-layout to this layout is the canonical MoE all-to-all."""
    mesh = shd._CTX["mesh"]
    if mesh is None:
        return buf
    tp = shd.tp_axis_for(buf.shape[1])
    gax = shd.batch_axes(mesh, buf.shape[0])
    return jax.lax.with_sharding_constraint(
        buf, NamedSharding(mesh, P(gax, tp, None, None)))


def moe_init(key, cfg: ModelConfig, dtype) -> dict:
    m = cfg.moe
    d, de, e = cfg.d_model, m.d_expert, m.padded
    ks = jax.random.split(key, 6)
    s = 0.02
    p = {
        "router": jax.random.normal(ks[0], (d, e), F32) * s,
        "wg": jax.random.normal(ks[1], (e, d, de), dtype) * s,
        "wu": jax.random.normal(ks[2], (e, d, de), dtype) * s,
        "wd": jax.random.normal(ks[3], (e, de, d), dtype) * (s / np.sqrt(2)),
    }
    if m.shared_d_ff:
        p["shared"] = L.mlp_init(ks[4], d, m.shared_d_ff, "swiglu", dtype)
        p["shared_gate"] = jax.random.normal(ks[5], (d,), F32) * s
    return p


def moe_apply(p: dict, x: jax.Array, cfg: ModelConfig):
    """Token-choice top-k with PER-SEQUENCE capacity groups (GShard-style).

    Grouping by batch row keeps the position-in-expert cumsum and the
    dispatch scatter local to each data shard — the only cross-chip
    traffic is the (G-over-data, E-over-model) buffer resharding, i.e.
    the canonical MoE all-to-all.  ``cfg.moe_dispatch_shard=False`` falls
    back to a single global group (the §Perf H4 baseline: the global
    cumsum then drags ~B×S×E traffic across the mesh every layer).

    Returns (out, aux) with aux = {"load_balance_loss": scalar}.
    """
    m = cfg.moe
    b, s, d = x.shape
    e, k = m.padded, m.top_k
    if cfg.moe_dispatch_shard:
        g, sg = b, s                       # one capacity group per sequence
    else:
        g, sg = 1, b * s                   # single global group (baseline)
    cap = int(np.ceil(m.capacity_factor * k * sg / e))
    cap = max(4, -(-cap // 4) * 4)

    xg = x.reshape(g, sg, d)
    logits = jnp.einsum("gsd,de->gse", xg, p["router"],
                        preferred_element_type=F32)
    if e != m.num_experts:  # mask padded experts out of routing
        pad_mask = jnp.arange(e) >= m.num_experts
        logits = jnp.where(pad_mask[None, None, :], L.NEG_INF, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, k)                     # (g, sg, k)
    topv = topv / jnp.sum(topv, axis=-1, keepdims=True)

    # position-in-expert by token priority within the group
    sel = jax.nn.one_hot(topi, e, dtype=jnp.int8)            # (g, sg, k, e)
    cnt = jnp.sum(sel, axis=2).astype(jnp.int32)             # (g, sg, e)
    cum = jnp.cumsum(cnt, axis=1) - cnt                      # exclusive
    pos = jnp.take_along_axis(cum, topi, axis=2)             # (g, sg, k)
    keep = pos < cap

    # dispatch INDICES (no token duplication): slot -> source position
    flat = jnp.where(keep, topi * cap + pos, e * cap)        # (g, sg, k)
    src = jnp.broadcast_to(jnp.arange(sg)[None, :, None],
                           (g, sg, k)).reshape(g, sg * k)
    idxbuf = jnp.full((g, e * cap + 1), sg, jnp.int32)       # sg = pad row
    rows = jnp.arange(g)[:, None]
    idxbuf = idxbuf.at[rows, flat.reshape(g, sg * k)].set(src)
    idxbuf = idxbuf[:, :-1]                                  # (g, e*cap)

    xpad = jnp.concatenate(
        [xg, jnp.zeros((g, 1, d), x.dtype)], axis=1)
    buf = jnp.take_along_axis(xpad, idxbuf[..., None], axis=1)
    buf = buf.reshape(g, e, cap, d)
    # the (group-over-data, expert-over-model) reshard = MoE all-to-all
    buf = constrain_experts(buf)

    # expert FFN (gated), batched over experts; weights broadcast over g
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, p["wg"],
                               preferred_element_type=F32))
    h = h.astype(x.dtype) * jnp.einsum("gecd,edf->gecf", buf, p["wu"],
                                       preferred_element_type=F32
                                       ).astype(x.dtype)
    out_buf = jnp.einsum("gecf,efd->gecd", h, p["wd"],
                         preferred_element_type=F32).astype(x.dtype)
    out_buf = constrain_experts(out_buf)

    out_buf = jnp.concatenate(
        [out_buf.reshape(g, e * cap, d), jnp.zeros((g, 1, d), x.dtype)],
        axis=1)
    gathered = jnp.take_along_axis(
        out_buf, flat.reshape(g, sg * k)[..., None], axis=1)
    w = (topv * keep).astype(x.dtype).reshape(g, sg * k)
    yt = jnp.sum((gathered * w[..., None]).reshape(g, sg, k, d), axis=2)

    if "shared" in p:
        gate = jax.nn.sigmoid(
            jnp.einsum("gsd,d->gs", xg, p["shared_gate"],
                       preferred_element_type=F32))
        yt = yt + L.mlp_apply(p["shared"], xg, "swiglu") * \
            gate[..., None].astype(x.dtype)

    # GShard load-balance aux loss: E * sum_e f_e * P_e
    f = jnp.mean(cnt.astype(F32), axis=(0, 1))     # fraction routed
    pbar = jnp.mean(probs, axis=(0, 1))
    lb = m.num_experts * jnp.sum(f * pbar)
    return yt.reshape(b, s, d), {"load_balance_loss": lb}
