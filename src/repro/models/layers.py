"""Shared neural-net layers: RMSNorm, RoPE, online-softmax attention
(full / sliding-window / cross), MLP variants, embeddings.

Conventions:
  * activations keep the configured compute dtype (bf16 on TPU); every
    contraction accumulates in f32 (``preferred_element_type``) — the
    paper's narrow-storage / wide-accumulate discipline (DESIGN.md T1).
  * attention is **chunked online-softmax** (flash-style scan over KV
    chunks): O(seq) memory, which is what makes the 32k-prefill shapes
    lowerable — the stencil-streaming idea (T2/T3) applied to attention.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

F32 = jnp.float32
NEG_INF = -1e30


def dot(x: jax.Array, w: jax.Array, sub: str) -> jax.Array:
    """einsum with f32 accumulation, result cast back to x.dtype."""
    return jnp.einsum(sub, x, w, preferred_element_type=F32).astype(x.dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(F32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(F32))
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding on the last axis. x: (..., S, H, hd); pos: (..., S)."""
    hd = x.shape[-1]
    freqs = 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=F32) / hd))
    ang = pos.astype(F32)[..., None] * freqs          # (..., S, hd/2)
    cos = jnp.cos(ang)[..., None, :]                  # (..., S, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(F32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention: flash-style online softmax over KV chunks, with a custom VJP
# that saves only (q, k, v, out, logsumexp) and RECOMPUTES scores blockwise
# in the backward — O(seq) residual memory instead of O(seq^2/chunk) stored
# probabilities.  This is the T2/T3 stencil-streaming discipline applied to
# attention, and what lets the 32k-token train/prefill cells fit HBM.
# ---------------------------------------------------------------------------

def _mask_for(pj, q_pos, causal: bool, window: int):
    valid = pj[None, :] >= 0
    if causal:
        valid &= pj[None, :] <= q_pos[:, None]
    if window > 0:
        valid &= q_pos[:, None] - pj[None, :] < window
    return valid  # (sq, chunk)


def _chunk_kv(t, chunk):
    b, skv, hkv, hd = t.shape
    return t.reshape(b, skv // chunk, chunk, hkv, hd).swapaxes(0, 1)


def _flash_fwd_inner(qg, k, v, q_pos, kv_pos, causal, window, chunk):
    from repro.models.scan_ctl import maybe_scan
    b, sq, hkv, g, hd = qg.shape
    scale = 1.0 / np.sqrt(hd)
    kc = _chunk_kv(k, chunk)
    vc = _chunk_kv(v, chunk)
    pc = kv_pos.reshape(-1, chunk)

    def step(carry, blk):
        m, denom, acc = carry
        kj, vj, pj = blk
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kj,
                       preferred_element_type=F32) * scale
        valid = _mask_for(pj, q_pos, causal, window)
        s = jnp.where(valid[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(valid[None, None, None], p, 0.0)
        denom_new = denom * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(k.dtype), vj,
                        preferred_element_type=F32)
        acc_new = acc * corr[..., None] + pv
        return (m_new, denom_new, acc_new), None

    m0 = jnp.full((b, hkv, g, sq), NEG_INF, F32)
    l0 = jnp.zeros((b, hkv, g, sq), F32)
    a0 = jnp.zeros((b, hkv, g, sq, hd), F32)
    (m, denom, acc), _ = maybe_scan(step, (m0, l0, a0), (kc, vc, pc))
    out = acc / jnp.maximum(denom, 1e-30)[..., None]   # (b,hkv,g,sq,hd) f32
    lse = m + jnp.log(jnp.maximum(denom, 1e-30))       # logsumexp
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7))
def _flash(qg, k, v, q_pos, kv_pos, causal, window, chunk):
    out, _ = _flash_fwd_inner(qg, k, v, q_pos, kv_pos, causal, window, chunk)
    return out


def _flash_fwd(qg, k, v, q_pos, kv_pos, causal, window, chunk):
    out, lse = _flash_fwd_inner(qg, k, v, q_pos, kv_pos, causal, window,
                                chunk)
    return out, (qg, k, v, q_pos, kv_pos, out, lse)


def _flash_bwd(causal, window, chunk, res, dout):
    from repro.models.scan_ctl import maybe_scan
    from repro.parallel.sharding import constrain_heads, tp_axis_for
    qg, k, v, q_pos, kv_pos, out, lse = res
    b, sq, hkv, g, hd = qg.shape
    scale = 1.0 / np.sqrt(hd)
    kc = _chunk_kv(k, chunk)
    vc = _chunk_kv(v, chunk)
    pc = kv_pos.reshape(-1, chunk)
    # mirror the forward's TP layout so SPMD never has to reshard the
    # (b,h,g,sq,chunk) score tensors (see DESIGN.md §5)
    h_ax = 1 if tp_axis_for(hkv) else 2                # score head axis
    dout = constrain_heads(dout.astype(F32), h_ax)
    out = constrain_heads(out, h_ax)
    lse = constrain_heads(lse, h_ax)
    delta = jnp.sum(dout * out, axis=-1)               # (b,hkv,g,sq)
    delta = constrain_heads(delta, h_ax)

    def step(dq, blk):
        kj, vj, pj = blk
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kj,
                       preferred_element_type=F32) * scale
        valid = _mask_for(pj, q_pos, causal, window)
        p = jnp.exp(s - lse[..., None])
        p = jnp.where(valid[None, None, None], p, 0.0)
        p = constrain_heads(p, h_ax)
        dv_j = jnp.einsum("bhgqk,bhgqd->bkhd", p, dout,
                          preferred_element_type=F32)
        dv_j = constrain_heads(dv_j, 2)
        dp = jnp.einsum("bhgqd,bkhd->bhgqk", dout, vj,
                        preferred_element_type=F32)
        ds = p * (dp - delta[..., None]) * scale
        ds = constrain_heads(ds, h_ax)
        dq = dq + jnp.einsum("bhgqk,bkhd->bqhgd", ds, kj,
                             preferred_element_type=F32)
        dk_j = jnp.einsum("bhgqk,bqhgd->bkhd", ds, qg.astype(F32),
                          preferred_element_type=F32)
        dk_j = constrain_heads(dk_j, 2)
        return dq, (dk_j, dv_j)

    dq0 = jnp.zeros((b, sq, hkv, g, hd), F32)
    dq0 = constrain_heads(dq0, 2 if tp_axis_for(hkv) else 3)
    dq, (dkc, dvc) = maybe_scan(step, dq0, (kc, vc, pc))
    dk = dkc.swapaxes(0, 1).reshape(k.shape[0], -1, *k.shape[2:])
    dv = dvc.swapaxes(0, 1).reshape(v.shape[0], -1, *v.shape[2:])
    return (dq.astype(qg.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
            None, None)


_flash.defvjp(_flash_fwd, _flash_bwd)


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              q_pos: jax.Array, kv_pos: jax.Array,
              causal: bool = True, window: int = 0,
              chunk: int = 1024) -> jax.Array:
    """Grouped-query flash attention (chunked online softmax).

    q: (B, Sq, Hq, hd);  k, v: (B, Skv, Hkv, hd);  Hq % Hkv == 0.
    q_pos: (Sq,) int32; kv_pos: (Skv,) int32 (−1 marks an empty cache slot).
    window > 0 limits attention to the last ``window`` positions.
    """
    from repro.parallel.sharding import (constrain_heads, tp_axis_for,
                                         tp_size)

    b, sq, hq, hd = q.shape
    _, skv, hkv, _ = k.shape
    g = hq // hkv
    # KV-head replication (EXPERIMENTS.md §Perf H5): when neither the kv
    # heads nor the GQA group divides the TP axis but rep=tp/hkv does,
    # duplicate each kv head rep× so attention shards over tp virtual kv
    # heads (rep-1 extra K/V copies per chip beats full replication).
    t = tp_size()
    if (sq > 1 and t and hkv % t and g % t and t % hkv == 0
            and g % (t // hkv) == 0 and t // hkv > 1):
        rep = t // hkv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
        hkv *= rep
        g //= rep
    qg = q.reshape(b, sq, hkv, g, hd)
    # TP sharding: kv-heads over `model` when divisible, else the GQA group
    # dim (keeps softmax fully chip-local; K/V replicate across the groups)
    qg = constrain_heads(qg, 2 if tp_axis_for(hkv) else 3)
    k = constrain_heads(k, 2)
    v = constrain_heads(v, 2)

    if sq == 1:
        # decode: one query — run the whole cache as a single chunk.  The
        # max/sum/PV contractions over S then partition cleanly when the
        # cache is SEQUENCE-sharded over `model` (GQA archs whose kv-head
        # count cannot cover the TP axis; see EXPERIMENTS.md §Perf H2).
        chunk = skv
    chunk = min(chunk, skv)
    if skv % chunk:  # pad KV to a chunk multiple with masked slots
        pad = chunk - skv % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, (0, pad), constant_values=-1)

    out = _flash(qg, k, v, q_pos, kv_pos, causal, window, chunk)
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, hq, hd)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# MLP variants
# ---------------------------------------------------------------------------

def mlp_apply(p: dict, x: jax.Array, kind: str) -> jax.Array:
    if kind == "swiglu":
        h = jax.nn.silu(dot(x, p["wg"], "bsd,df->bsf").astype(F32)).astype(x.dtype)
        h = h * dot(x, p["wu"], "bsd,df->bsf")
    elif kind == "geglu":
        h = jax.nn.gelu(dot(x, p["wg"], "bsd,df->bsf").astype(F32),
                        approximate=True).astype(x.dtype)
        h = h * dot(x, p["wu"], "bsd,df->bsf")
    elif kind == "squared_relu":
        h = jax.nn.relu(dot(x, p["wu"], "bsd,df->bsf"))
        h = h * h
    else:
        raise ValueError(kind)
    return dot(h, p["wd"], "bsf,fd->bsd")


def mlp_init(key, d: int, ff: int, kind: str, dtype) -> dict:
    ks = jax.random.split(key, 3)
    std_in, std_out = 0.02, 0.02 / np.sqrt(2.0)
    p = {"wu": jax.random.normal(ks[0], (d, ff), dtype) * std_in,
         "wd": jax.random.normal(ks[1], (ff, d), dtype) * std_out}
    if kind in ("swiglu", "geglu"):
        p["wg"] = jax.random.normal(ks[2], (d, ff), dtype) * std_in
    return p


# ---------------------------------------------------------------------------
# Embedding
# ---------------------------------------------------------------------------

def embed_init(key, vocab: int, d: int, dtype, tie: bool,
               padded_vocab: int | None = None) -> dict:
    pv = padded_vocab or vocab
    ks = jax.random.split(key)
    p = {"tok": jax.random.normal(ks[0], (pv, d), dtype) * 0.02}
    if not tie:
        p["out"] = jax.random.normal(ks[1], (pv, d), dtype) * 0.02
    return p


def embed_lookup(p: dict, tokens: jax.Array, dtype) -> jax.Array:
    return jnp.take(p["tok"], tokens, axis=0).astype(dtype)


def logits_out(p: dict, x: jax.Array, vocab: int | None = None) -> jax.Array:
    w = p.get("out", p["tok"])
    logits = jnp.einsum("bsd,vd->bsv", x, w, preferred_element_type=F32)
    pv = w.shape[0]
    if vocab is not None and pv != vocab:  # mask vocab-padding rows
        logits = jnp.where(jnp.arange(pv) < vocab, logits, NEG_INF)
    return logits
