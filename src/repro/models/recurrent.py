"""Recurrent families: the RG-LRU block (RecurrentGemma/Griffin) and the
RWKV-v6 "Finch" time/channel mix with data-dependent decay.

Both are linear recurrences, i.e. 1-D stencils: training uses a parallel
form (associative scan for RG-LRU, chunked scan for RWKV) and decoding is
an O(1) state update — which is why these archs run the ``long_500k``
shape that full attention skips.

Simplifications vs the released checkpoints (noted per DESIGN.md):
  * RG-LRU input/recurrence gates are per-channel (diagonal) rather than
    block-diagonal linear — same data-dependent gating structure.
  * RWKV6 group-norm over heads is RMS-per-head.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models.config import ModelConfig

F32 = jnp.float32
_LRU_C = 8.0


# ===========================================================================
# RG-LRU recurrent block (Griffin)
# ===========================================================================

def rglru_init(key, cfg: ModelConfig, dtype) -> dict:
    d, w, cw = cfg.d_model, cfg.lru_width, cfg.conv_width
    ks = jax.random.split(key, 8)
    s = 0.02
    # Lambda init so that a ∈ (0.9, 0.999) at sigma(r)=0.5 (Griffin app. A)
    lam = jax.random.uniform(ks[0], (w,), F32, 0.9, 0.999)
    a_param = jnp.log(jnp.expm1(-jnp.log(lam) / (_LRU_C * 0.5)))
    return {
        "wx": jax.random.normal(ks[1], (d, w), dtype) * s,     # x branch
        "wg": jax.random.normal(ks[2], (d, w), dtype) * s,     # gelu gate
        "wo": jax.random.normal(ks[3], (w, d), dtype) * (s / np.sqrt(2)),
        "conv": jax.random.normal(ks[4], (cw, w), dtype) * s,
        "a_param": a_param,                                    # Λ
        "wa": jax.random.normal(ks[5], (w,), F32) * s,         # recurrence gate
        "ba": jnp.zeros((w,), F32),
        "wi": jax.random.normal(ks[6], (w,), F32) * s,         # input gate
        "bi": jnp.zeros((w,), F32),
    }


def make_rglru_state(cfg: ModelConfig, batch: int, dtype) -> dict:
    w, cw = cfg.lru_width, cfg.conv_width
    return {"h": jnp.zeros((batch, w), F32),
            "conv": jnp.zeros((batch, cw - 1, w), dtype)}


def _lru_coeffs(p, u):
    """Data-dependent decay a_t and scaled input b_t from branch input u."""
    u32 = u.astype(F32)
    r = jax.nn.sigmoid(u32 * p["wa"] + p["ba"])
    i = jax.nn.sigmoid(u32 * p["wi"] + p["bi"])
    log_a = -_LRU_C * jax.nn.softplus(p["a_param"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * u32)
    return a, b


def rglru_apply(p: dict, x: jax.Array, cfg: ModelConfig, *,
                state: dict | None = None, update_state: bool = False):
    """x: (B, S, d). Train/prefill when state is None or S>1 (associative
    scan over time); decode when S==1 with a carried state."""
    b, s, d = x.shape
    cw = cfg.conv_width
    u = L.dot(x, p["wx"], "bsd,dw->bsw")
    gate = jax.nn.gelu(L.dot(x, p["wg"], "bsd,dw->bsw").astype(F32),
                       approximate=True)

    # causal depthwise conv, width cw
    if state is None:
        upad = jnp.pad(u, ((0, 0), (cw - 1, 0), (0, 0)))
    else:
        upad = jnp.concatenate([state["conv"].astype(u.dtype), u], axis=1)
    conv = sum(upad[:, i:i + s, :] * p["conv"][i][None, None, :]
               for i in range(cw))

    a, bt = _lru_coeffs(p, conv)
    if s == 1 and state is not None:
        h = a[:, 0] * state["h"] + bt[:, 0]
        hseq = h[:, None, :]
    else:
        h0 = state["h"][:, None, :] if state is not None else None

        def compose(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, a2 * b1 + b2

        if h0 is not None:  # fold initial state into the first element
            bt = bt.at[:, 0, :].add(a[:, 0, :] * state["h"])
        _, hseq = jax.lax.associative_scan(compose, (a, bt), axis=1)
        h = hseq[:, -1, :]

    y = (hseq * gate).astype(x.dtype)
    out = L.dot(y, p["wo"], "bsw,wd->bsd")
    new_state = None
    if update_state:
        tail = upad[:, -(cw - 1):, :] if cw > 1 else \
            jnp.zeros((b, 0, u.shape[-1]), u.dtype)
        new_state = {"h": h, "conv": tail}
    return out, new_state


# ===========================================================================
# RWKV-v6 (Finch)
# ===========================================================================

def rwkv_init(key, cfg: ModelConfig, dtype) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    hd = cfg.rwkv_head_dim
    nh = d // hd
    ks = jax.random.split(key, 12)
    s = 0.02
    lora = 64
    return {
        # time mix
        "mu": jax.random.uniform(ks[0], (5, d), F32),  # shift mix r,k,v,w,g
        "wr": jax.random.normal(ks[1], (d, d), dtype) * s,
        "wk": jax.random.normal(ks[2], (d, d), dtype) * s,
        "wv": jax.random.normal(ks[3], (d, d), dtype) * s,
        "wg": jax.random.normal(ks[4], (d, d), dtype) * s,
        "wo": jax.random.normal(ks[5], (d, d), dtype) * (s / np.sqrt(2)),
        "w0": jnp.full((d,), -5.0, F32),               # base decay
        "wa": jax.random.normal(ks[6], (d, lora), F32) * s,   # decay LoRA
        "wb": jax.random.normal(ks[7], (lora, d), F32) * s,
        "u": jax.random.normal(ks[8], (nh, hd), F32) * s,     # bonus
        # channel mix
        "cmu": jax.random.uniform(ks[9], (2, d), F32),
        "ck": jax.random.normal(ks[10], (d, ff), dtype) * s,
        "cv": jax.random.normal(ks[11], (ff, d), dtype) * (s / np.sqrt(2)),
        "cr": jax.random.normal(ks[0], (d, d), dtype) * s,
    }


def make_rwkv_state(cfg: ModelConfig, batch: int, dtype) -> dict:
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    nh = d // hd
    return {"tm_x": jnp.zeros((batch, d), dtype),
            "cm_x": jnp.zeros((batch, d), dtype),
            "S": jnp.zeros((batch, nh, hd, hd), F32)}


def _token_shift(x, prev):
    """x_{t-1} along the sequence; ``prev`` is the carry for decode."""
    if x.shape[1] == 1 and prev is not None:
        return prev[:, None, :]
    shifted = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1, :]
    if prev is not None:
        shifted = shifted.at[:, 0, :].set(prev)
    return shifted


def _wkv_chunk_size(s: int) -> int:
    # chunk large enough that the chunk COUNT stays <= 64: keeps the
    # (C,C) intra-chunk matmuls MXU-sized at 4k and the scan short at 32k+
    target = max(64, s // 64)
    for c in (target, 64, 32, 16, 8, 4, 2, 1):
        if s % c == 0:
            return c
    return 1


def _wkv_chunked(r, k, v, w, u, S0):
    """Chunked (matmul-form) WKV recurrence — the MXU-native formulation.

    Within a chunk of C tokens the recurrence
        S_t = diag(w_t) S_{t-1} + k_t v_t^T ;  y_t = r_t (S_{t-1} + u k_t v_t^T)
    unrolls to one (C,dk)x(dk,dv) inter-chunk matmul + one causal (C,C)
    intra-chunk attention matmul, using cumulative log-decays relative to
    the chunk start.  Chunks are processed by a scan carrying S — the 1-D
    stencil-streaming structure of the paper's cyclic buffer (DESIGN.md T2)
    applied to the time dimension.

    r,k,v,w: (B, S, H, D) f32 (w = per-channel decay in (0,1)); u: (H, D).
    Returns (S_final, y) with y (B, S, H, D).
    """
    b, s, h, d = r.shape
    c = _wkv_chunk_size(s)
    n = s // c
    rc, kc, vc, wc = (t.reshape(b, n, c, h, d).transpose(1, 0, 3, 2, 4)
                      for t in (r, k, v, w))          # (n, b, h, c, d)
    logw = jnp.log(jnp.maximum(wc, 1e-38))            # (n, b, h, c, d)
    # L_i = sum_{j<=i} log w_j within the chunk (inclusive cumulative decay)
    L = jnp.cumsum(logw, axis=3)

    causal = jnp.tril(jnp.ones((c, c), bool), k=-1)   # strictly lower

    def chunk_step(S, inp):
        rj, kj, vj, Lj, lwj = inp                     # (b, h, c, d) each
        a_in = jnp.exp(Lj - lwj)    # decay from chunk start to t-1 (excl. t)
        r_t = rj * a_in             # \tilde r
        k_t = kj * jnp.exp(-Lj)     # \tilde k
        # inter-chunk: r_t S (state from previous chunks)
        inter = jnp.einsum("bhcd,bhdv->bhcv", r_t, S)
        # intra-chunk: causal scores + bonus diagonal
        scores = jnp.einsum("bhid,bhjd->bhij", r_t, k_t)
        scores = jnp.where(causal[None, None], scores, 0.0)
        diag = jnp.einsum("bhcd,hd,bhcd->bhc", rj, u, kj)
        intra = jnp.einsum("bhij,bhjv->bhiv", scores, vj) + \
            diag[..., None] * vj
        # state to the next chunk: S_C = diag(A_C) S + sum_j (A_C/A_j) k_j v_j^T
        decay_all = jnp.exp(Lj[:, :, -1, :])          # (b, h, d)
        k_hat = kj * jnp.exp(Lj[:, :, -1:, :] - Lj)   # (b, h, c, d)
        S_new = S * decay_all[..., :, None] + \
            jnp.einsum("bhcd,bhcv->bhdv", k_hat, vj)
        return S_new, inter + intra

    from repro.models.scan_ctl import maybe_scan
    S, yc = maybe_scan(chunk_step, S0, (rc, kc, vc, L, logw))
    y = yc.transpose(1, 0, 3, 2, 4).reshape(b, s, h, d)
    return S, y


def rwkv_time_mix(p: dict, x: jax.Array, cfg: ModelConfig, *,
                  state: dict | None = None):
    b, s, d = x.shape
    hd = cfg.rwkv_head_dim
    nh = d // hd
    prev = state["tm_x"] if state is not None else None
    xs = _token_shift(x, prev)

    def mix(i):
        m = p["mu"][i].astype(x.dtype)
        return x * m + xs * (1 - m)

    r = L.dot(mix(0), p["wr"], "bsd,de->bse").reshape(b, s, nh, hd)
    k = L.dot(mix(1), p["wk"], "bsd,de->bse").reshape(b, s, nh, hd)
    v = L.dot(mix(2), p["wv"], "bsd,de->bse").reshape(b, s, nh, hd)
    g = L.dot(mix(4), p["wg"], "bsd,de->bse")
    # data-dependent decay (Finch): w_t = exp(-exp(w0 + tanh(x A) B))
    dd = jnp.tanh(jnp.einsum("bsd,dl->bsl", mix(3).astype(F32), p["wa"]))
    dd = jnp.einsum("bsl,ld->bsd", dd, p["wb"]) + p["w0"]
    w = jnp.exp(-jnp.exp(dd)).reshape(b, s, nh, hd)     # ∈ (0,1)

    r32, k32, v32 = (t.astype(F32) for t in (r, k, v))
    u = p["u"]
    S0 = state["S"] if state is not None else jnp.zeros((b, nh, hd, hd), F32)

    if s == 1:  # decode: single recurrence step
        rt, kt, vt, wt = (t[:, 0] for t in (r32, k32, v32, w))
        kv = kt[..., :, None] * vt[..., None, :]
        out = jnp.einsum("bhk,bhkv->bhv", rt, S0 + u[None, :, :, None] * kv)
        S = wt[..., :, None] * S0 + kv
        y = out[:, None].reshape(b, 1, nh, hd)
    else:
        S, y = _wkv_chunked(r32, k32, v32, w, u, S0)

    # per-head RMS norm, then gate and output proj
    y = y * jax.lax.rsqrt(jnp.mean(y * y, axis=-1, keepdims=True) + 1e-6)
    y = (y.reshape(b, s, d) * jax.nn.silu(g.astype(F32))).astype(x.dtype)
    out = L.dot(y, p["wo"], "bsd,de->bse")
    new_state = {"tm_x": x[:, -1, :], "S": S}
    return out, new_state


def rwkv_channel_mix(p: dict, x: jax.Array, *, state: dict | None = None):
    prev = state["cm_x"] if state is not None else None
    xs = _token_shift(x, prev)
    mk = p["cmu"][0].astype(x.dtype)
    mr = p["cmu"][1].astype(x.dtype)
    xk = x * mk + xs * (1 - mk)
    xr = x * mr + xs * (1 - mr)
    h = jax.nn.relu(L.dot(xk, p["ck"], "bsd,df->bsf"))
    h = h * h
    r = jax.nn.sigmoid(L.dot(xr, p["cr"], "bsd,de->bse").astype(F32))
    out = (r.astype(x.dtype) * L.dot(h, p["cv"], "bsf,fd->bsd"))
    return out, {"cm_x": x[:, -1, :]}
