"""Unified decoder-only model covering the dense / moe / hybrid / ssm / vlm
families.  Encoder-decoder (audio) lives in :mod:`repro.models.encdec`.

Depth is organized into **segments**: maximal runs of a repeating block
pattern, each executed as one ``lax.scan`` over stacked parameters — the
HLO (and compile time, which matters at 512 fake devices on one CPU) is
O(#distinct patterns), not O(depth).  E.g. recurrentgemma-9b (38 layers,
pattern rec,rec,attn) becomes scan((rec,rec,attn) ×12) + scan((rec,) ×2).

API (pure functions, params are pytrees of arrays):
  init_params(cfg, key)                         -> params
  forward(cfg, params, tokens, ...)             -> (logits, aux)
  prefill(cfg, params, tokens, ...)             -> (logits, caches)
  decode_step(cfg, params, tokens, pos, caches) -> (logits, caches)
  init_caches(cfg, batch, length, dtype)        -> caches
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import blocks as B
from repro.models import layers as L
from repro.models import moe as M
from repro.models import recurrent as R
from repro.models.config import ModelConfig
from repro.parallel.sharding import constrain_batch

F32 = jnp.float32


# ---------------------------------------------------------------------------
# Depth plan
# ---------------------------------------------------------------------------

def stack_plan(cfg: ModelConfig) -> list[tuple[tuple[str, ...], int]]:
    """[(block-pattern, scan-length)] covering cfg.num_layers layers."""
    kinds = cfg._layer_kinds()
    pat = {"dense": ("attn",), "moe": ("moe",), "ssm": ("rwkv",),
           "vlm": ("attn",), "audio": ("attn",),
           "hybrid": cfg.block_pattern}[cfg.family]
    plen = len(pat)
    full, tail = divmod(len(kinds), plen)
    plan = []
    if full:
        plan.append((tuple(pat), full))
    if tail:
        plan.append((tuple(pat[:tail]), 1))
    return plan


# ---------------------------------------------------------------------------
# Per-block init / apply
# ---------------------------------------------------------------------------

def _block_init(key, cfg: ModelConfig, kind: str, dtype) -> dict:
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    p: dict[str, Any] = {"ln1": jnp.zeros((d,), F32),
                         "ln2": jnp.zeros((d,), F32)}
    if kind == "attn":
        p["attn"] = B.attn_init(ks[0], cfg, dtype)
        p["mlp"] = L.mlp_init(ks[1], d, cfg.d_ff, cfg.mlp, dtype)
    elif kind == "moe":
        p["attn"] = B.attn_init(ks[0], cfg, dtype)
        p["moe"] = M.moe_init(ks[1], cfg, dtype)
    elif kind == "rec":
        p["rec"] = R.rglru_init(ks[0], cfg, dtype)
        p["mlp"] = L.mlp_init(ks[1], d, cfg.d_ff, cfg.mlp, dtype)
    elif kind == "rwkv":
        p["tm"] = R.rwkv_init(ks[0], cfg, dtype)
    else:
        raise ValueError(kind)
    return p


def _block_cache(cfg: ModelConfig, kind: str, batch: int, length: int,
                 dtype) -> dict:
    if kind in ("attn", "moe"):
        ring = cfg.family == "hybrid" and cfg.window > 0
        return B.make_kv_cache(cfg, batch, length, dtype, ring=ring)
    if kind == "rec":
        return R.make_rglru_state(cfg, batch, dtype)
    if kind == "rwkv":
        return R.make_rwkv_state(cfg, batch, dtype)
    raise ValueError(kind)


def _block_apply(bp: dict, h: jax.Array, cfg: ModelConfig, kind: str, *,
                 pos0, cache, update_cache: bool):
    aux = jnp.zeros((), F32)
    new_cache = None
    if kind in ("attn", "moe"):
        a, nc = B.attn_apply(bp["attn"], L.rms_norm(h, bp["ln1"]), cfg,
                             pos0=pos0, window=cfg.window, cache=cache,
                             update_cache=update_cache)
        h = h + a
        if kind == "attn":
            m = L.mlp_apply(bp["mlp"], L.rms_norm(h, bp["ln2"]), cfg.mlp)
        else:
            m, ad = M.moe_apply(bp["moe"], L.rms_norm(h, bp["ln2"]), cfg)
            aux = ad["load_balance_loss"]
        h = h + m
        new_cache = nc
    elif kind == "rec":
        a, ns = R.rglru_apply(bp["rec"], L.rms_norm(h, bp["ln1"]), cfg,
                              state=cache, update_state=update_cache)
        h = h + a
        h = h + L.mlp_apply(bp["mlp"], L.rms_norm(h, bp["ln2"]), cfg.mlp)
        new_cache = ns
    elif kind == "rwkv":
        a, ts = R.rwkv_time_mix(bp["tm"], L.rms_norm(h, bp["ln1"]), cfg,
                                state=cache)
        h = h + a
        c, cs = R.rwkv_channel_mix(bp["tm"], L.rms_norm(h, bp["ln2"]),
                                   state=cache)
        h = h + c
        if update_cache:
            new_cache = {**ts, **cs}
    h = constrain_batch(h)
    return h, new_cache, aux


# ---------------------------------------------------------------------------
# Model init
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key, dtype=jnp.float32) -> dict:
    ke, kf, *seg_keys = jax.random.split(key, 2 + len(stack_plan(cfg)))
    params: dict[str, Any] = {
        "embed": L.embed_init(ke, cfg.vocab_size, cfg.d_model, dtype,
                              cfg.tie_embeddings,
                              padded_vocab=cfg.padded_vocab),
        "final_norm": jnp.zeros((cfg.d_model,), F32),
        "segments": [],
    }
    for (pat, count), sk in zip(stack_plan(cfg), seg_keys):
        pks = jax.random.split(sk, count)

        def one(k, pat=pat):
            bks = jax.random.split(k, len(pat))
            return {f"b{j}": _block_init(bk, cfg, kind, dtype)
                    for j, (kind, bk) in enumerate(zip(pat, bks))}

        params["segments"].append(jax.vmap(one)(pks))
    return params


def init_caches(cfg: ModelConfig, batch: int, length: int,
                dtype=jnp.float32) -> list:
    caches = []
    for pat, count in stack_plan(cfg):
        seg = {}
        for j, kind in enumerate(pat):
            c = _block_cache(cfg, kind, batch, length, dtype)
            seg[f"b{j}"] = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (count,) + x.shape), c)
        caches.append(seg)
    return caches


# ---------------------------------------------------------------------------
# Stack execution
# ---------------------------------------------------------------------------

def _run_segments(cfg: ModelConfig, params, h, *, pos0, caches,
                  update_cache: bool):
    new_caches = []
    aux_total = jnp.zeros((), F32)
    for si, (pat, count) in enumerate(stack_plan(cfg)):
        seg_p = params["segments"][si]
        seg_c = caches[si] if caches is not None else None

        def body(carry, xs, pat=pat):
            h, aux = carry
            bp_all, bc_all = xs
            ncs = {}
            for j, kind in enumerate(pat):
                bc = bc_all[f"b{j}"] if bc_all is not None else None
                h, nc, a = _block_apply(bp_all[f"b{j}"], h, cfg, kind,
                                        pos0=pos0, cache=bc,
                                        update_cache=update_cache)
                ncs[f"b{j}"] = nc
                aux = aux + a
            return (h, aux), (ncs if update_cache else None)

        if cfg.remat and caches is None:  # remat only on the training path
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        from repro.models.scan_ctl import maybe_scan
        (h, aux_total), seg_nc = maybe_scan(
            body, (h, aux_total), (seg_p, seg_c))
        new_caches.append(seg_nc)
    return h, (new_caches if update_cache else None), aux_total


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def _embed_inputs(cfg: ModelConfig, params, tokens, prefix_embeds, dtype):
    h = L.embed_lookup(params["embed"], tokens, dtype)
    if prefix_embeds is not None:
        h = jnp.concatenate([prefix_embeds.astype(dtype), h], axis=1)
    return h


def forward(cfg: ModelConfig, params, tokens, *, prefix_embeds=None,
            compute_dtype=jnp.float32):
    """Training/eval forward: full-sequence logits (f32) + aux losses."""
    h = _embed_inputs(cfg, params, tokens, prefix_embeds, compute_dtype)
    h, _, aux = _run_segments(cfg, params, h, pos0=0, caches=None,
                              update_cache=False)
    h = L.rms_norm(h, params["final_norm"])
    logits = L.logits_out(params["embed"], h, cfg.vocab_size)
    return logits, {"load_balance_loss": aux}


def prefill(cfg: ModelConfig, params, tokens, *, cache_len: int,
            prefix_embeds=None, compute_dtype=jnp.float32):
    """Run the prompt, returning last-position logits + caches of
    ``cache_len`` slots (prompt K/V written at positions 0..S-1)."""
    b, s = tokens.shape
    caches = init_caches(cfg, b, cache_len, compute_dtype)
    h = _embed_inputs(cfg, params, tokens, prefix_embeds, compute_dtype)
    h, caches, _ = _run_segments(cfg, params, h, pos0=0, caches=caches,
                                 update_cache=True)
    h = L.rms_norm(h[:, -1:], params["final_norm"])
    logits = L.logits_out(params["embed"], h, cfg.vocab_size)
    return logits, caches


def decode_step(cfg: ModelConfig, params, tokens, pos, caches, *,
                compute_dtype=jnp.float32):
    """One decode step: tokens (B,1) at absolute position ``pos``."""
    h = L.embed_lookup(params["embed"], tokens, compute_dtype)
    h, caches, _ = _run_segments(cfg, params, h, pos0=pos, caches=caches,
                                 update_cache=True)
    h = L.rms_norm(h, params["final_norm"])
    logits = L.logits_out(params["embed"], h, cfg.vocab_size)
    return logits, caches
