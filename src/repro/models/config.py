"""Model configuration for the assigned architecture pool.

One frozen dataclass covers all six families (dense / moe / hybrid / ssm /
vlm / audio); family-specific fields default to None/0 and are validated in
``__post_init__``.  Exact per-arch instantiations live in
``src/repro/configs/<arch>.py``.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "hybrid", "ssm", "vlm", "audio"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int                  # per-expert FFN width
    shared_d_ff: int = 0           # always-on shared expert width (qwen2-moe)
    capacity_factor: float = 1.25
    # experts padded up so they divide the model axis (e.g. 60 -> 64)
    num_experts_padded: int = 0

    @property
    def padded(self) -> int:
        return self.num_experts_padded or self.num_experts


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // num_heads
    mlp: str = "swiglu"            # swiglu | geglu | squared_relu
    rope_theta: float = 10_000.0
    tie_embeddings: bool = True
    moe: MoEConfig | None = None

    # hybrid (recurrentgemma): block pattern repeated over depth
    block_pattern: tuple[str, ...] = ("attn",)   # e.g. ("rec","rec","attn")
    window: int = 0                # sliding-window size for local attention
    lru_width: int = 0             # RG-LRU width (0 -> d_model)
    conv_width: int = 4            # causal conv in the recurrent block

    # ssm (rwkv6)
    rwkv_head_dim: int = 64

    # vlm: number of prefix (patch) embeddings supplied by the stub frontend
    num_prefix_embeds: int = 0

    # audio / enc-dec
    encoder_layers: int = 0        # >0 -> encoder-decoder
    encoder_seq_len: int = 0       # max encoder length (frames), decode-time

    # training-memory knobs (per-arch overrides, see DESIGN.md)
    opt_state_dtype: str = "float32"   # AdamW m/v dtype ("bfloat16" for 340B)
    remat: bool = True
    # Megatron-SP-style sequence sharding of residual activations over the
    # `model` axis (see EXPERIMENTS.md §Perf for the before/after)
    seq_shard: bool = True
    # shard decode KV caches over `model` along the SEQUENCE dim when the
    # kv-head count cannot cover the TP axis (EXPERIMENTS.md §Perf H2)
    kv_seq_shard: bool = True
    # shard MoE dispatch buffers' capacity dim over `data` (§Perf H4)
    moe_dispatch_shard: bool = True

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.family == "hybrid" and self.lru_width == 0:
            object.__setattr__(self, "lru_width", self.d_model)
        if self.family == "moe":
            assert self.moe is not None
        if self.family == "audio":
            assert self.encoder_layers > 0
        if self.num_heads % max(self.num_kv_heads, 1) != 0:
            raise ValueError("num_heads must be divisible by num_kv_heads")

    @property
    def attn_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to 256 so embeddings shard over the TP axis
        (only seamless's 256206 is affected; pad logits are masked)."""
        return -(-self.vocab_size // 256) * 256

    @property
    def sub_quadratic(self) -> bool:
        """True when long-context decode is supported (SSM/hybrid)."""
        return self.family in ("ssm", "hybrid")

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    def param_count(self) -> int:
        """Approximate parameter count (used for 6·N·D roofline terms)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        n = 0
        per_layer = {}
        attn = d * self.attn_dim + 2 * d * self.num_kv_heads * self.head_dim \
            + self.attn_dim * d
        gated = self.mlp in ("swiglu", "geglu")
        mlp = (3 if gated else 2) * d * ff
        for kind in self._layer_kinds():
            if kind == "attn":
                n += attn + mlp
            elif kind == "moe":
                m = self.moe
                e_mlp = m.num_experts * 3 * d * m.d_expert + d * m.num_experts
                if m.shared_d_ff:
                    e_mlp += 3 * d * m.shared_d_ff + d
                n += attn + e_mlp
            elif kind == "rec":
                w = self.lru_width
                rec = 2 * d * w + w * d + self.conv_width * w + 3 * w
                n += rec + mlp
            elif kind == "rwkv":
                # time-mix (5 proj + decay lora) + channel-mix
                n += 5 * d * d + 2 * d * ff
        n += v * d * (1 if self.tie_embeddings else 2)
        if self.is_encdec:
            # encoder self-attn+mlp, decoder cross-attn already in layers?
            n += self.encoder_layers * (attn + mlp)
            n += self.num_layers * attn  # decoder cross-attention
        return n

    def active_param_count(self) -> int:
        """Params touched per token (= param_count for non-MoE)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        m = self.moe
        attn = d * self.attn_dim + 2 * d * self.num_kv_heads * self.head_dim \
            + self.attn_dim * d
        act = m.top_k * 3 * d * m.d_expert + d * m.num_experts
        if m.shared_d_ff:
            act += 3 * d * m.shared_d_ff
        n = self.num_layers * (attn + act)
        n += self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return n

    def _layer_kinds(self) -> list[str]:
        """Expanded per-layer block kinds for the decoder stack."""
        if self.family == "moe":
            return ["moe"] * self.num_layers
        if self.family == "ssm":
            return ["rwkv"] * self.num_layers
        if self.family == "hybrid":
            pat = self.block_pattern
            return [pat[i % len(pat)] for i in range(self.num_layers)]
        return ["attn"] * self.num_layers


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape) cell: what gets lowered in the dry-run."""
    name: str
    kind: Literal["train", "prefill", "decode"]
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}
